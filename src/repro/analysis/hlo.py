"""Call-tree-aware cost analysis of optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any model that
``lax.scan``s its layer stack under-reports FLOPs/bytes/collectives by the
trip count (61x for deepseek-v3). This module re-derives the three roofline
inputs from ``compiled.as_text()`` directly:

  * flops            — dot ops: 2 * prod(result_shape) * prod(contract_dims),
                       multiplied up the call tree by while trip counts.
  * hbm_bytes        — per top-level data-moving op, operand + result bytes
                       (a "every fusion reads its inputs from HBM and writes
                       its outputs once" traffic model).
  * collective bytes — per collective, ring-model link traffic (see below),
                       also trip-count multiplied.

After SPMD partitioning the module is the per-device program, so every
number this module reports is PER DEVICE; the roofline terms divide by
per-chip peaks only (never by chip count again).

Ring traffic model per collective (bytes = full result size r, group n):
  all-reduce          2 * r * (n-1)/n
  all-gather          r * (n-1)/n
  reduce-scatter      r * (n-1)          (operand = n * result)
  all-to-all          r * (n-1)/n
  collective-permute  r
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b(pred|bf16|f8e4m3fn|f8e5m2|[sufc]\d+|token)"
                       r"\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\([^)]*.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|body|condition|to_apply)=(%[\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# top-level ops whose operands+results we charge as HBM traffic
_MOVER_PREFIXES = (
    "fusion", "dot", "convolution", "copy", "gather", "scatter",
    "dynamic-slice", "dynamic-update-slice", "broadcast", "transpose",
    "reduce", "reduce-window", "select-and-scatter", "concatenate", "pad",
    "reverse", "slice", "convert", "iota", "custom-call", "sort", "rng",
    "cholesky", "triangular-solve", "exponential", "log", "tanh", "add",
    "multiply", "subtract", "divide", "maximum", "minimum", "compare",
    "select", "clamp", "negate", "abs", "sign", "floor", "ceil", "round",
) + _COLLECTIVES


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _shape_dims(type_str: str) -> list:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d.strip()]


@dataclasses.dataclass
class _Instr:
    name: str
    type_str: str
    opcode: str
    operands: list
    line: str


@dataclasses.dataclass
class ModuleCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0          # ring-model link traffic
    collective_result_bytes: float = 0.0   # raw summed result sizes
    collective_counts: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    dot_count: float = 0.0
    while_trips: list = dataclasses.field(default_factory=list)

    def as_dict(self) -> dict:
        return {"flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "collective_bytes": self.collective_bytes,
                "collective_result_bytes": self.collective_result_bytes,
                "collective_counts": dict(self.collective_counts),
                "dot_count": self.dot_count,
                "while_trips": list(self.while_trips)}


def _split_rhs(rhs: str):
    """RHS of an instruction: '<type> <opcode>(<operands>), attrs...'."""
    rhs = rhs.strip()
    if rhs.startswith("("):                       # tuple type
        depth, i = 0, 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        type_str, rest = rhs[:i + 1], rhs[i + 1:].strip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return rhs, "", ""
        type_str, rest = rhs[:sp], rhs[sp + 1:].strip()
    p = rest.find("(")
    if p < 0:
        return type_str, rest, ""
    opcode = rest[:p]
    depth, j = 0, p
    for j in range(p, len(rest)):
        depth += rest[j] == "("
        depth -= rest[j] == ")"
        if depth == 0:
            break
    return type_str, opcode, rest[p + 1:j]


def _parse_module(text: str):
    """-> (comps: {name: [instr]}, entry_name, symbols: {name: type_str})."""
    comps, symbols = {}, {}
    cur, cur_name, entry = None, None, None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        hdr = _COMP_HDR_RE.match(line)
        if hdr and ("->" in line or line.lstrip().startswith(("ENTRY", "%"))) \
                and line.endswith("{") and "=" not in line.split("(")[0]:
            cur_name = hdr.group(1)
            cur = []
            comps[cur_name] = cur
            if line.lstrip().startswith("ENTRY"):
                entry = cur_name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        type_str, opcode, operand_str = _split_rhs(rhs)
        operands = re.findall(r"%[\w.\-]+", operand_str)
        instr = _Instr(name, type_str, opcode, operands, line)
        cur.append(instr)
        symbols[name] = type_str
    if entry is None and comps:
        entry = next(iter(comps))
    return comps, entry, symbols


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return default


def _collective_traffic(op: str, result_bytes: int, n: int) -> float:
    n = max(n, 2)
    if op == "all-reduce":
        return 2.0 * result_bytes * (n - 1) / n
    if op == "reduce-scatter":
        return float(result_bytes) * (n - 1)
    if op == "collective-permute":
        return float(result_bytes)
    return float(result_bytes) * (n - 1) / n     # all-gather / all-to-all


def _base_op(opcode: str) -> str:
    """'all-reduce-start' -> 'all-reduce'; 'all-gather-done' -> skip tag."""
    if opcode.endswith("-done"):
        return ""
    if opcode.endswith("-start"):
        opcode = opcode[:-6]
    return opcode


def analyze_module(text: str, default_group: int = 16) -> ModuleCost:
    """Walk the call tree from ENTRY, multiplying while bodies by their
    known_trip_count. Returns per-device ModuleCost."""
    comps, entry, symbols = _parse_module(text)
    memo = {}

    # computations reached via `calls=` from fusions: count dot/collectives
    # (they execute), but NOT generic operand bytes (the fusion call site
    # already charges its HBM reads/writes).
    def comp_cost(name: str, inside_fusion: bool) -> ModuleCost:
        key = (name, inside_fusion)
        if key in memo:
            return memo[key]
        memo[key] = ModuleCost()               # cycle guard
        cost = ModuleCost()
        for ins in comps.get(name, ()):
            op = ins.opcode
            if op == "while":
                m = _TRIP_RE.search(ins.line)
                trips = int(m.group(1)) if m else 1
                cost.while_trips.append(trips)
                refs = _CALLS_RE.findall(ins.line)
                for r in refs:
                    sub = comp_cost(r, inside_fusion)
                    _accumulate(cost, sub, trips)
                continue
            if op in ("conditional",):
                m = _BRANCHES_RE.search(ins.line)
                refs = (re.findall(r"%[\w.\-]+", m.group(1)) if m
                        else _CALLS_RE.findall(ins.line))
                if refs:   # charge the max-cost branch
                    subs = [comp_cost(r, inside_fusion) for r in refs]
                    best = max(subs, key=lambda c: c.flops + c.hbm_bytes)
                    _accumulate(cost, best, 1)
                continue
            if op == "call":
                for r in _CALLS_RE.findall(ins.line):
                    _accumulate(cost, comp_cost(r, inside_fusion), 1)
                continue
            if op.startswith("fusion"):
                refs = _CALLS_RE.findall(ins.line)
                if not inside_fusion:
                    cost.hbm_bytes += _fusion_write_bytes(
                        ins, refs[0] if refs else None, comps)
                    cost.hbm_bytes += _fusion_read_bytes(
                        ins, refs[0] if refs else None, comps, symbols)
                for r in refs:
                    _accumulate(cost, comp_cost(r, True), 1)
                continue
            base = _base_op(op)
            if not base:
                continue
            if base in _COLLECTIVES:
                rb = _type_bytes(ins.type_str)
                n = _group_size(ins.line, default_group)
                cost.collective_result_bytes += rb
                cost.collective_bytes += _collective_traffic(base, rb, n)
                cost.collective_counts[base] += 1
                if not inside_fusion:
                    cost.hbm_bytes += _io_bytes(ins, symbols)
                continue
            if base.startswith("dot"):
                cost.flops += _dot_flops(ins, symbols)
                cost.dot_count += 1
                if not inside_fusion:
                    cost.hbm_bytes += _io_bytes(ins, symbols)
                continue
            if base.startswith("convolution"):
                cost.flops += _conv_flops(ins, symbols)
                if not inside_fusion:
                    cost.hbm_bytes += _io_bytes(ins, symbols)
                continue
            if not inside_fusion and any(base.startswith(p)
                                         for p in _MOVER_PREFIXES):
                cost.hbm_bytes += _mover_bytes(ins, symbols)
        memo[key] = cost
        return cost

    def _accumulate(dst: ModuleCost, src: ModuleCost, mult: float):
        dst.flops += mult * src.flops
        dst.hbm_bytes += mult * src.hbm_bytes
        dst.collective_bytes += mult * src.collective_bytes
        dst.collective_result_bytes += mult * src.collective_result_bytes
        dst.dot_count += mult * src.dot_count
        for k, v in src.collective_counts.items():
            dst.collective_counts[k] += mult * v
        dst.while_trips.extend(src.while_trips)

    return comp_cost(entry, False)


def _io_bytes(ins: _Instr, symbols: dict) -> float:
    total = float(_type_bytes(ins.type_str))
    for o in ins.operands:
        total += _type_bytes(symbols.get(o, ""))
    return total


_SLICE_OPS = ("dynamic-slice", "gather", "slice")


def _mover_bytes(ins: _Instr, symbols: dict) -> float:
    """HBM traffic of one top-level op: slice-like ops touch only the
    sliced region; dynamic-update-slice / scatter are in-place and touch
    only the update; everything else reads operands + writes result."""
    base = _base_op(ins.opcode)
    if any(base.startswith(p) for p in _SLICE_OPS):
        return 2.0 * _type_bytes(ins.type_str)
    if base.startswith("dynamic-update-slice"):
        upd = (_type_bytes(symbols.get(ins.operands[1], ""))
               if len(ins.operands) > 1 else 0)
        return 2.0 * upd
    if base.startswith("scatter"):
        upd = (_type_bytes(symbols.get(ins.operands[-1], ""))
               if ins.operands else 0)
        return 2.0 * upd
    return _io_bytes(ins, symbols)


# ops that merely "view" their single data operand (element-count-preserving,
# fusable without extra traffic)
_VIEW_OPS = ("convert", "bitcast", "copy", "reshape", "transpose",
             "broadcast", "negate")


def _view_chains(inner):
    """name -> root parameter name, following single-operand view chains."""
    view_of = {}
    for i in inner:
        if i.opcode.startswith("parameter"):
            view_of[i.name] = i.name
        elif any(i.opcode.startswith(v) for v in _VIEW_OPS) \
                and len(i.operands) == 1 and i.operands[0] in view_of:
            view_of[i.name] = view_of[i.operands[0]]
    return view_of


def _fusion_write_bytes(ins: _Instr, comp_name, comps) -> float:
    """Write traffic of a fusion. An in-place dynamic-update-slice root
    (or tuple element / view of one) writes only the update region."""
    if comp_name is None or comp_name not in comps:
        return float(_type_bytes(ins.type_str))
    inner = comps[comp_name]
    if not inner:
        return float(_type_bytes(ins.type_str))
    local = {i.name: i for i in inner}

    def resolve(name):
        """Walk back through view ops to the producing 'real' op."""
        seen = 0
        while name in local and seen < 32:
            i = local[name]
            if any(i.opcode.startswith(v) for v in _VIEW_OPS) \
                    and len(i.operands) == 1:
                name = i.operands[0]
                seen += 1
                continue
            return i
        return None

    def one(i: _Instr) -> float:
        r = resolve(i.name) or i
        if _base_op(r.opcode).startswith("dynamic-update-slice"):
            upd = local.get(r.operands[1]) if len(r.operands) > 1 else None
            return float(_type_bytes(upd.type_str)) if upd else \
                float(_type_bytes(r.type_str))
        return float(_type_bytes(i.type_str))

    root = inner[-1]
    if root.opcode.startswith("tuple"):
        total = 0.0
        for o in root.operands:
            total += one(local[o]) if o in local else 0.0
        return total
    return one(root)


def _fusion_read_bytes(ins: _Instr, comp_name, comps, symbols) -> float:
    """Utilization-aware read traffic of a fusion: a parameter consumed only
    through view chains ending in slice-like ops is charged at slice size;
    a view chain ending as operand 0 of an in-place dynamic-update-slice is
    charged at the update size. Anything else is a full read."""
    if comp_name is None or comp_name not in comps:
        return sum(_type_bytes(symbols.get(o, "")) for o in ins.operands)
    inner = comps[comp_name]
    local = {i.name: i.type_str for i in inner}
    view_of = _view_chains(inner)
    param_ix = {}
    for i in inner:
        if i.opcode.startswith("parameter"):
            m = re.search(r"parameter\((\d+)\)", i.line)
            if m:
                param_ix[i.name] = int(m.group(1))
    charges = {}   # operand index -> bytes charged (max over consumers)
    for i in inner:
        base = _base_op(i.opcode)
        is_view = any(i.opcode.startswith(v) for v in _VIEW_OPS) \
            and len(i.operands) == 1
        for pos, o in enumerate(i.operands):
            p = view_of.get(o)
            if p is None or p not in param_ix:
                continue
            if is_view:
                continue                       # deferred to chain consumer
            ix = param_ix[p]
            full = _type_bytes(symbols.get(ins.operands[ix], "")
                               if ix < len(ins.operands) else "")
            if any(base.startswith(s) for s in _SLICE_OPS) and pos == 0:
                c = float(_type_bytes(i.type_str))
            elif base.startswith("dynamic-update-slice") and pos == 0:
                c = float(_type_bytes(local.get(i.operands[1], "")))
            else:
                c = float(full)
            charges[ix] = max(charges.get(ix, 0.0), min(c, float(full)))
    # a view chain that reaches the fusion ROOT directly (pure reformat
    # fusion) is a full read of that parameter
    root = inner[-1] if inner else None
    if root is not None:
        names = ([root.name] if not root.opcode.startswith("tuple")
                 else list(root.operands))
        for nm in names:
            p = view_of.get(nm)
            if p in param_ix:
                r = _resolve_nonview(nm, {i.name: i for i in inner})
                if r is None or not _base_op(r.opcode).startswith(
                        "dynamic-update-slice"):
                    ix = param_ix[p]
                    full = _type_bytes(
                        symbols.get(ins.operands[ix], "")
                        if ix < len(ins.operands) else "")
                    charges[ix] = max(charges.get(ix, 0.0), float(full))
    return sum(charges.values())


def _resolve_nonview(name, local):
    seen = 0
    while name in local and seen < 32:
        i = local[name]
        if any(i.opcode.startswith(v) for v in _VIEW_OPS) \
                and len(i.operands) == 1:
            name = i.operands[0]
            seen += 1
            continue
        return i
    return None


def _dot_flops(ins: _Instr, symbols: dict) -> float:
    result_elems = 1
    for d in _shape_dims(ins.type_str):
        result_elems *= d
    m = _CDIMS_RE.search(ins.line)
    if not m or not ins.operands:
        return 2.0 * result_elems            # degenerate: dot as outer prod
    lhs_dims = _shape_dims(symbols.get(ins.operands[0], ""))
    contract = 1
    for ci in m.group(1).split(","):
        ci = ci.strip()
        if ci and int(ci) < len(lhs_dims):
            contract *= lhs_dims[int(ci)]
    return 2.0 * result_elems * contract


def _conv_flops(ins: _Instr, symbols: dict) -> float:
    # output elems * 2 * (kernel spatial * in_channels): approximate via
    # rhs (kernel) total elems / out_channels
    result_elems = 1
    for d in _shape_dims(ins.type_str):
        result_elems *= d
    if len(ins.operands) < 2:
        return 2.0 * result_elems
    k_dims = _shape_dims(symbols.get(ins.operands[1], ""))
    k_elems = 1
    for d in k_dims:
        k_elems *= d
    out_ch = k_dims[-1] if k_dims else 1
    return 2.0 * result_elems * max(k_elems // max(out_ch, 1), 1)
