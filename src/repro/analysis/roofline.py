"""Roofline terms for TPU v5e from per-device HLO cost (see hlo.py).

All three terms are per-chip seconds for one step:
  compute_s    = flops_per_device / peak_flops
  memory_s     = hbm_bytes_per_device / hbm_bw
  collective_s = collective_link_bytes_per_device / (links * link_bw)

The dominant term lower-bounds the step time; fraction-of-roofline for the
iteration log is dominant / sum (how close the step is to being purely
bound by its bottleneck).
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12      # bf16 / chip
    hbm_bw: float = 819e9           # bytes/s
    link_bw: float = 50e9           # bytes/s/link (ICI)
    n_links: int = 4                # v5e: 4 usable ICI links per chip (2D)
    vmem_bytes: int = 128 * 2 ** 20
    hbm_bytes: int = 16 * 2 ** 30


V5E = HW()


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    hbm_bytes: float
    collective_bytes: float
    model_flops: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (remat / redundancy waste detector)."""
        return self.model_flops / self.flops if self.flops else 0.0

    def as_dict(self) -> dict:
        return {"compute_s": self.compute_s, "memory_s": self.memory_s,
                "collective_s": self.collective_s, "dominant": self.dominant,
                "flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "collective_bytes": self.collective_bytes,
                "model_flops": self.model_flops,
                "useful_ratio": self.useful_ratio}


def roofline_terms(cost, hw: HW = V5E, model_flops: float = 0.0
                   ) -> RooflineTerms:
    """cost: analysis.hlo.ModuleCost (per-device numbers)."""
    return RooflineTerms(
        compute_s=cost.flops / hw.peak_flops,
        memory_s=cost.hbm_bytes / hw.hbm_bw,
        collective_s=cost.collective_bytes / (hw.n_links * hw.link_bw),
        flops=cost.flops,
        hbm_bytes=cost.hbm_bytes,
        collective_bytes=cost.collective_bytes,
        model_flops=model_flops,
    )


def model_flops(cfg: ModelConfig, n_tokens: int, kind: str) -> float:
    """MODEL_FLOPS = 6*N*D (train) or 2*N*D (fwd-only) with N = active
    params (MoE top-k counts only routed-active experts)."""
    n = cfg.active_param_count()
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * n_tokens
