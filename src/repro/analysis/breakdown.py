"""Per-instruction HBM/FLOP breakdown of a dry-run cell — the 'profile'
used by the §Perf hillclimbing loop (we have no wall-clock on CPU; the
lowered per-device HLO is the ground truth we optimize against).

Usage:
  PYTHONPATH=src python -m repro.analysis.breakdown --arch yi-34b \
      --shape decode_32k [--multi-pod] [--top 30] [--collectives]
"""
import os

if __name__ == "__main__":
    # Only the CLI lowers a cell over a fake 512-device host mesh; the
    # flag must land before jax's backend initializes. Library importers
    # (instruction_rows is pure HLO-text analysis) must NOT inherit 512
    # virtual CPU devices — a process that picks this up at import poisons
    # every later sharded computation with a 512-way mesh of one core.
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=512")

import argparse

from repro.analysis import hlo as H


def instruction_rows(text: str):
    """[(bytes, flops, mult, opcode, line)] for every charged instruction,
    scaled by enclosing while trip counts (one level, matching
    analyze_module's call-tree walk for top-level scans)."""
    comps, entry, symbols = H._parse_module(text)
    trip_of = {}
    for name, instrs in comps.items():
        for ins in instrs:
            if ins.opcode == "while":
                m = H._TRIP_RE.search(ins.line)
                t = int(m.group(1)) if m else 1
                for r in H._CALLS_RE.findall(ins.line):
                    trip_of[r] = trip_of.get(r, 1) * t

    rows = []
    for cname, instrs in comps.items():
        mult = trip_of.get(cname, 1 if cname == entry else 0)
        if mult == 0:
            continue
        for ins in instrs:
            op = ins.opcode
            b = f = 0.0
            if op.startswith("fusion"):
                refs = H._CALLS_RE.findall(ins.line)
                ref = refs[0] if refs else None
                b = (H._fusion_write_bytes(ins, ref, comps)
                     + H._fusion_read_bytes(ins, ref, comps, symbols))
            elif H._base_op(op).startswith("dot"):
                f = H._dot_flops(ins, symbols)
                b = H._mover_bytes(ins, symbols)
            elif H._base_op(op) in H._COLLECTIVES:
                b = H._mover_bytes(ins, symbols)
            elif any(H._base_op(op).startswith(p)
                     for p in H._MOVER_PREFIXES):
                b = H._mover_bytes(ins, symbols)
            else:
                continue
            rows.append((b * mult, f * mult, mult, op, ins.line))
    return rows


def main() -> None:
    from repro.launch.dryrun import lower_cell
    from repro.launch.mesh import make_production_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=30)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--collectives", action="store_true")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    remat = args.remat
    if remat is None and args.shape.startswith("train"):
        remat = "full"
    lowered, cfg, meta = lower_cell(args.arch, args.shape, mesh, remat=remat,
                                    seq_parallel=args.seq_parallel)
    text = lowered.compile().as_text()
    rows = instruction_rows(text)
    rows.sort(key=lambda r: r[0], reverse=True)
    tot_b = sum(r[0] for r in rows)
    tot_f = sum(r[1] for r in rows)
    print(f"total bytes {tot_b/1e9:.2f} GB   total dot flops {tot_f/1e12:.3f}"
          f" TFLOP   ({len(rows)} charged instructions)")
    print(f"{'GB':>9} {'GFLOP':>9} {'x':>4}  instruction")
    for b, f, m, op, line in rows[:args.top]:
        print(f"{b/1e9:9.3f} {f/1e9:9.1f} {m:4d}  {line[:140]}")
    if args.collectives:
        print("\ncollectives:")
        for b, f, m, op, line in rows:
            if H._base_op(op) in H._COLLECTIVES:
                print(f"{b/1e9:9.3f}GB x{m:4d}  {line[:130]}")


if __name__ == "__main__":
    main()
