from .hlo import ModuleCost, analyze_module
from .roofline import HW, RooflineTerms, roofline_terms, model_flops

__all__ = ["ModuleCost", "analyze_module", "HW", "RooflineTerms",
           "roofline_terms", "model_flops"]
