"""The technology bank: registry of ``TechnologyParams`` records.

``resolve_technology`` is the single name→record lookup the mapper, the
planner, and the benches share; an unregistered name raises
``UnknownTechnologyError`` (a ``ValueError``) that lists the registered
technologies — the named early failure ``mapper.compile_mapping`` surfaces
instead of dying deep in the latency rollup.

``ANCHOR`` is the calibration point: every per-pass primitive scale factor
is a ratio to the anchor's parameters, so pricing the anchor itself is the
exact identity (scale 1.0 bit-for-bit) and the calibrated Table-1 numbers
are reproduced unchanged (the acceptance contract of
``benchmarks/tech_sweep.py``).
"""
from __future__ import annotations

from .params import FEFET, RERAM, SOT_MRAM, SRAM, TechnologyParams

ANCHOR = "sot-mram"

_REGISTRY: dict = {}


class UnknownTechnologyError(ValueError):
    """An inventory or candidate referenced a technology the bank does not
    know. Carries the known names so callers can print an actionable list."""

    def __init__(self, name, known):
        self.name = name
        self.known = tuple(known)
        super().__init__(
            f"unknown device technology {name!r}; registered technologies: "
            f"{', '.join(self.known)}")


def register_technology(tech: TechnologyParams) -> TechnologyParams:
    """Add (or replace) one technology record; returns it for chaining."""
    if not isinstance(tech, TechnologyParams):
        raise TypeError(f"expected TechnologyParams, got {type(tech)!r}")
    _REGISTRY[tech.name] = tech
    return tech


def known_technologies() -> tuple:
    """Registered technology names, registration order."""
    return tuple(_REGISTRY)


def resolve_technology(tech) -> TechnologyParams:
    """Name or record → registered ``TechnologyParams``.

    Accepts a ``TechnologyParams`` (returned as-is — ad-hoc records need
    no registration) or a registered name; anything else raises
    ``UnknownTechnologyError`` naming the known technologies.
    """
    if isinstance(tech, TechnologyParams):
        return tech
    rec = _REGISTRY.get(tech)
    if rec is None:
        raise UnknownTechnologyError(tech, known_technologies())
    return rec


def anchor_technology() -> TechnologyParams:
    """The calibration-point record every scale factor is a ratio to."""
    return _REGISTRY[ANCHOR]


def primitive_scales(tech) -> tuple:
    """(latency_scale, energy_scale) of ``tech`` relative to the anchor.

    Read-path ratios: crossbar MVM passes and CAM searches are read
    operations (weights are programmed once per model load). The anchor's
    own scales are exactly (1.0, 1.0) — multiplying the calibrated
    primitives by them is the bit-for-bit identity.
    """
    t = resolve_technology(tech)
    a = anchor_technology()
    return (t.read_latency_s / a.read_latency_s,
            t.read_energy_j / a.read_energy_j)


def technology_table() -> list:
    """JSON-ready rows of every registered technology (docs/bench table)."""
    return [dict(name=t.name, read_latency_s=t.read_latency_s,
                 write_latency_s=t.write_latency_s,
                 read_energy_j=t.read_energy_j,
                 write_energy_j=t.write_energy_j,
                 cell_bits=t.cell_bits, on_off_ratio=t.on_off_ratio,
                 noise_sigma=t.noise_sigma, endurance=t.endurance)
            for t in _REGISTRY.values()]


for _t in (SOT_MRAM, RERAM, SRAM, FEFET):
    register_technology(_t)
del _t
