"""Device-technology subsystem (DESIGN.md §13).

Three parts, one contract:

  * **bank** — ``TechnologyParams`` records (SOT-MRAM / ReRAM / SRAM /
    FeFET) and the registry ``resolve_technology``; the mapper scales its
    calibrated per-pass primitives by each technology's ratio to the
    SOT-MRAM anchor (bit-for-bit identity at the anchor itself).
  * **variation** — seeded Monte-Carlo conductance noise injected into the
    bit-accurate ``crossbar_mvm`` path; ``VariationBounds`` (mean/p99
    output error, end-to-end logit flip rate) is what lets the planner
    reject technologies whose noise breaks the bit-accurate contract.
  * **calibrate** — fit the per-pass primitives from measured kernel
    wall-clocks on the current host; the ``HostCalibration`` artifact
    feeds ``costmodel.predict(mode="derived", calibration=...)`` and is
    platform-stamped (stale on any other platform).

``bank``/``params`` are dependency-free (pure dataclasses); the heavier
imports (jax, kernels) live inside ``variation``/``calibrate`` call paths.
"""
from .bank import (ANCHOR, UnknownTechnologyError, anchor_technology,
                   known_technologies, primitive_scales, register_technology,
                   resolve_technology, technology_table)
from .calibrate import (CALIBRATION_PATH, CalibrationStaleError,
                        HostCalibration, calibrate, load_calibration,
                        measure_primitives, save_calibration)
from .params import FEFET, RERAM, SOT_MRAM, SRAM, TechnologyParams
from .variation import (NOISE_GRID, VariationBounds, accuracy_bounds,
                        layer_noise, modeled_p99_error, mvm_error_bounds,
                        noisy_forward, sample_conductance_noise)

__all__ = [
    "ANCHOR", "UnknownTechnologyError", "anchor_technology",
    "known_technologies", "primitive_scales", "register_technology",
    "resolve_technology", "technology_table",
    "CALIBRATION_PATH", "CalibrationStaleError", "HostCalibration",
    "calibrate", "load_calibration", "measure_primitives",
    "save_calibration",
    "FEFET", "RERAM", "SOT_MRAM", "SRAM", "TechnologyParams",
    "NOISE_GRID", "VariationBounds", "accuracy_bounds", "layer_noise",
    "modeled_p99_error", "mvm_error_bounds", "noisy_forward",
    "sample_conductance_noise",
]
