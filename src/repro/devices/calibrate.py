"""On-host auto-calibration of the costmodel's per-pass primitives.

The derived cost model (``mapper.PassPrimitives``) normally inverts its
per-pass latencies from the paper's Table 1 — a fixed point that says
nothing about the host actually running the kernels. This harness measures
them instead: one CAM search pass, one aggregation-crossbar pass, and one
fx-crossbar pass are timed with the same min-of-iters protocol the
autotuner uses (``tuning.measure.time_callable``), and the fit is written
to a JSON artifact that ``costmodel.predict(mode="derived",
calibration=...)`` (and ``compile_mapping(calibration=...)``) consumes in
place of the Table-1 inversion — ``mode="derived"`` then tracks the
current host, anywhere.

Staleness rule (DESIGN.md §13): the artifact records the platform tag it
was measured on (``tuning.current_platform()`` — jax backend plus
``-interp`` when Pallas would run interpreted). Loading it on a different
platform raises ``CalibrationStaleError`` unless ``strict=False`` — a
CPU-interpreter fit silently pricing TPU hardware is exactly the bug the
rule exists to prevent. The artifact uploads from CI alongside the
tuned-config cache (``ci.yml``).
"""
from __future__ import annotations

import dataclasses
import json
import os

CALIBRATION_PATH = os.path.join("results", "host_calibration.json")


class CalibrationStaleError(ValueError):
    """A calibration artifact measured on another platform was loaded
    strictly. Re-measure with ``calibrate()`` or pass ``strict=False``."""


@dataclasses.dataclass(frozen=True)
class HostCalibration:
    """Measured per-pass primitive latencies [s] on one host platform.

    ``t_cam`` — one CAM search pass (a query block against one
    ``cam_rows`` entry block); ``t_agg`` / ``t_fx`` — one full
    aggregation / feature-extraction crossbar pass at the calibration
    geometry (``agg_rows x agg_cols`` / ``fx_rows x fx_cols``). Geometry
    scaling on top of these is ``PassPrimitives.derive``'s job — the
    artifact is the measured anchor, not the whole model.
    """
    platform: str
    t_cam: float
    t_agg: float
    t_fx: float
    iters: int = 3
    seed: int = 0

    def __post_init__(self):
        for f in ("t_cam", "t_agg", "t_fx"):
            if getattr(self, f) <= 0:
                raise ValueError(f"measured {f} must be > 0, "
                                 f"got {getattr(self, f)}")

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "HostCalibration":
        return cls(**{f.name: d[f.name] for f in dataclasses.fields(cls)})


def _cam_runner(hw, seed: int, interpret):
    """() -> (match, counts) for one CAM search pass on the pallas path."""
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels.cam_match.ops import search
    rng = np.random.default_rng(seed)
    ci = jnp.asarray(rng.integers(0, 4096, hw.cam_rows).astype(np.int32))
    q = jnp.asarray(rng.integers(0, 4096, 8).astype(np.int32))

    def run():
        return search(ci, q, backend="pallas", interpret=interpret)
    return run


def measure_primitives(hw=None, iters: int = 3, warmup: int = 1,
                       seed: int = 0, interpret=None) -> "HostCalibration":
    """Measure the three per-pass primitives on the current host.

    Crossbar passes reuse the autotuner's runner builders at the
    calibration geometries (an 8-row activation block over one full
    ``rows x cols`` array — the launch computes exactly one logical
    pass); the CAM pass drives the search kernel over one entry block.
    Min-of-``iters`` wall-clocks, compile excluded (the runner protocol).
    """
    from repro.tuning.autotune import current_platform
    from repro.tuning.measure import crossbar_runner, time_callable
    from repro.tuning.space import CrossbarConfig, CrossbarGeometry
    if hw is None:
        from repro.core.costmodel import DEFAULT_HW
        hw = DEFAULT_HW
    cfg = CrossbarConfig()
    geoms = {
        "t_agg": CrossbarGeometry(m=8, k=hw.agg_rows, n=hw.agg_cols,
                                  rows_per_xbar=hw.agg_rows),
        "t_fx": CrossbarGeometry(m=8, k=hw.fx_rows, n=hw.fx_cols,
                                 rows_per_xbar=hw.fx_rows),
    }
    t = {name: time_callable(crossbar_runner(g, cfg, seed=seed,
                                             interpret=interpret),
                             iters=iters, warmup=warmup)
         for name, g in geoms.items()}
    t["t_cam"] = time_callable(_cam_runner(hw, seed, interpret),
                               iters=iters, warmup=warmup)
    return HostCalibration(platform=current_platform(), t_cam=t["t_cam"],
                           t_agg=t["t_agg"], t_fx=t["t_fx"],
                           iters=iters, seed=seed)


def save_calibration(cal: HostCalibration,
                     path: str = CALIBRATION_PATH) -> str:
    """Write the artifact (deterministic JSON, the BENCH/cache convention)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        f.write(json.dumps(cal.as_dict(), sort_keys=True, indent=2) + "\n")
    return path


def load_calibration(path: str = CALIBRATION_PATH,
                     strict: bool = True) -> "HostCalibration":
    """Load an artifact; enforce the platform staleness rule.

    ``strict=True`` raises ``CalibrationStaleError`` when the artifact's
    platform tag differs from the current one; ``strict=False`` returns it
    anyway (cross-platform inspection, comparison tables).
    """
    with open(path) as f:
        cal = HostCalibration.from_dict(json.load(f))
    if strict:
        from repro.tuning.autotune import current_platform
        here = current_platform()
        if cal.platform != here:
            raise CalibrationStaleError(
                f"calibration artifact {path!r} was measured on "
                f"{cal.platform!r} but this host is {here!r}; re-run "
                f"devices.calibrate() here or load with strict=False")
    return cal


def calibrate(path: str | None = CALIBRATION_PATH, hw=None, iters: int = 3,
              warmup: int = 1, seed: int = 0,
              interpret=None) -> "HostCalibration":
    """Measure + persist in one call; ``path=None`` skips the write."""
    cal = measure_primitives(hw, iters=iters, warmup=warmup, seed=seed,
                             interpret=interpret)
    if path is not None:
        save_calibration(cal, path)
    return cal
