"""Monte-Carlo conductance-variation pass (DESIGN.md §13).

Programmed crossbar conductances are not exact: every technology's
``noise_sigma`` is the relative std of one stored level. This module
samples that noise, injects it into the bit-accurate ``crossbar_mvm``
numerics, and turns the trials into the per-technology accuracy bounds the
planner's accuracy evaluator consumes — mean/p99 relative output error of
one MVM and the end-to-end GNN logit flip rate on a concrete dataset.

Design constraints that shape the implementation:

  * **Byte-identical where the backends are.** The composed ``jnp`` and
    ``pallas`` backends share the oracle crossbar stage bit-for-bit, and
    noise draws are quantized to a ``1/NOISE_GRID`` conductance-level
    grid so perturbed codes stay exactly representable in f32
    (|sum| * NOISE_GRID < 2^24 at the stack's geometries) — the same
    seed therefore produces byte-identical outputs *and bounds* on both.
    The ``fused`` kernel is allclose-level vs the oracle by its existing
    contract (tests/test_kernels_fused_layer.py); under noise it stays
    exactly seed-deterministic (same seed → byte-identical rerun) and
    inside the same tolerance.
  * **Platform-determinism.** Draws come from numpy's seeded Philox-free
    ``default_rng`` (bit-reproducible everywhere) rather than device-side
    RNG, and the error statistics are reduced in float64 numpy, so a
    bound is a pure function of ``(technology, seed)`` — safe for the
    deterministic METRICS of ``benchmarks/tech_sweep.py``.
  * **Same physical device, same noise.** A signed MVM drives the same
    programmed arrays twice (pos/neg DAC passes); the noise tensor is
    sampled once per weight matrix and shared by both passes and, end to
    end, by every trial's full forward.

The per-trial MVMs run one jitted call per draw on every backend (one
trace — the noise tensor is a traced argument and shapes are constant);
they are deliberately *not* vmapped: batching re-fuses the matmuls and
splits the backends at the last bit.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .bank import resolve_technology

# noise codes land on a 1/8 conductance-level grid: fine enough that the
# quantization is ~1% of one level's sigma, coarse enough that every f32
# partial sum stays exactly representable (see module docstring)
NOISE_GRID = 8

_Z99 = 2.326   # one-sided 99th-percentile z-score of a standard normal


def sample_conductance_noise(seed, shape, tech, cfg=None) -> np.ndarray:
    """One additive conductance-code noise draw, grid-quantized.

    ``seed`` may be an int or a sequence of ints (trial substreams derive
    as ``[seed, trial]`` — disjoint, reproducible). Returns float32
    ``shape``-d codes in units of conductance codes: multiples of
    ``1/NOISE_GRID``, std ``noise_sigma * w_levels``.
    """
    tech = resolve_technology(tech)
    if cfg is None:
        from repro.kernels.crossbar_mvm import CrossbarNumerics
        cfg = CrossbarNumerics()
    rng = np.random.default_rng(seed)
    eps = rng.standard_normal(shape)
    delta = tech.noise_sigma * cfg.w_levels * eps
    return (np.round(delta * NOISE_GRID) / NOISE_GRID).astype(np.float32)


def layer_noise(seed, params, tech, cfg) -> list:
    """Per-layer weight-noise tensors for one GNN parameter list (one draw
    per programmed array — shared by every pass that reads it)."""
    return [sample_conductance_noise([*np.atleast_1d(seed), i],
                                     layer["w"].shape, tech, cfg)
            for i, layer in enumerate(params)]


@dataclasses.dataclass(frozen=True)
class VariationBounds:
    """Accuracy bounds of one technology under conductance noise.

    ``mean_err`` / ``p99_err`` — relative output error (|noisy - clean| /
    max|clean|) over all elements and trials; ``ci95`` — 95% confidence
    half-width of ``mean_err`` over the per-trial means (what a
    different-seed rerun must land inside); ``flip_rate`` — fraction of
    nodes whose argmax logit flipped (end-to-end runs only).
    """
    technology: str
    trials: int
    seed: int
    mean_err: float
    p99_err: float
    ci95: float
    flip_rate: float | None = None

    def within_ci(self, other: "VariationBounds", k: float = 2.0) -> bool:
        """Same-population check: the two mean errors agree within ``k``x
        their combined confidence half-widths (different seeds of the same
        technology must pass; see tests/test_devices.py)."""
        return abs(self.mean_err - other.mean_err) <= (
            k * (self.ci95 + other.ci95) + 1e-12)


def modeled_p99_error(tech, k_rows: int, cfg=None) -> float:
    """Closed-form first-order p99 relative MVM output error.

    The per-source-line signal grows linearly with the active rows
    ``r = min(k, rows_per_xbar)`` while the conductance noise accumulates
    in quadrature, so the relative error of one crossbar tile is
    ``~ z99 * sigma * sqrt(2/r)``; digital accumulation over ``n_k`` K
    tiles averages another ``sqrt(n_k)`` away. Deliberately coarse — the
    cheap evaluator the planner prices every candidate with; the
    Monte-Carlo bounds (same ordering, measured constants) ground it in
    ``benchmarks/tech_sweep.py``.
    """
    tech = resolve_technology(tech)
    if tech.noise_sigma <= 0.0:
        return 0.0
    if cfg is None:
        from repro.kernels.crossbar_mvm import CrossbarNumerics
        cfg = CrossbarNumerics()
    r = max(1, min(int(k_rows), cfg.rows_per_xbar))
    n_k = max(1, math.ceil(int(k_rows) / cfg.rows_per_xbar))
    return _Z99 * tech.noise_sigma * math.sqrt(2.0 / r) / math.sqrt(n_k)


def _mvm(x, w, cfg, w_noise, backend: str, interpret):
    """One (optionally noisy) bit-accurate MVM on the requested backend."""
    from repro.kernels.crossbar_mvm import crossbar_matmul_signed_ref
    from repro.kernels.crossbar_mvm.ops import crossbar_matmul_signed
    if backend == "jnp":
        return crossbar_matmul_signed_ref(x, w, cfg, w_noise=w_noise)
    assert backend == "pallas", backend
    return crossbar_matmul_signed(x, w, cfg, interpret=interpret,
                                  w_noise=w_noise)


def _bounds_from_trials(tech, seed, clean: np.ndarray,
                        noisy: np.ndarray, flip_rate=None) -> VariationBounds:
    """Fold stacked per-trial outputs into a ``VariationBounds`` (float64
    numpy reductions — platform-deterministic)."""
    clean64 = np.asarray(clean, np.float64)
    noisy64 = np.asarray(noisy, np.float64)
    scale = max(float(np.abs(clean64).max()), 1e-30)
    err = np.abs(noisy64 - clean64[None]) / scale
    per_trial = err.reshape(err.shape[0], -1).mean(axis=1)
    trials = err.shape[0]
    ci95 = (1.96 * float(per_trial.std(ddof=1)) / math.sqrt(trials)
            if trials > 1 else 0.0)
    return VariationBounds(
        technology=resolve_technology(tech).name, trials=trials,
        seed=int(np.atleast_1d(seed)[0]),
        mean_err=float(err.mean()), p99_err=float(np.quantile(err, 0.99)),
        ci95=ci95, flip_rate=flip_rate)


def mvm_error_bounds(tech, cfg=None, m: int = 32, k: int = 216, n: int = 64,
                     trials: int = 8, seed: int = 0, backend: str = "jnp",
                     interpret=None) -> VariationBounds:
    """Monte-Carlo relative-error bounds of one noisy bit-accurate MVM.

    The input matrices are fixed (seed-independent) so every seed samples
    noise for the *same* workload — the ``within_ci`` contract: two seeds
    estimate one population mean and must agree within their combined
    confidence intervals. The ``trials`` noise draws are applied one
    jitted call each — the noise tensor is a traced argument, so every
    trial reuses one trace, and deliberately *not* vmapped: batching
    re-fuses the matmuls and splits the backends at the last bit, while
    per-trial calls keep every backend byte-identical (what
    ``tests/test_devices.py`` asserts).
    """
    import jax.numpy as jnp
    from repro.kernels.crossbar_mvm import CrossbarNumerics
    tech = resolve_technology(tech)
    cfg = cfg or CrossbarNumerics()
    rng = np.random.default_rng(0x0DA7A)
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    w = jnp.asarray((rng.standard_normal((k, n)) * 0.1).astype(np.float32))
    clean = np.asarray(_mvm(x, w, cfg, None, backend, interpret))
    noise = np.stack([sample_conductance_noise([seed, t], (k, n), tech, cfg)
                      for t in range(trials)])
    noisy = np.stack([np.asarray(_mvm(x, w, cfg, jnp.asarray(nz),
                                      backend, interpret))
                      for nz in noise])
    return _bounds_from_trials(tech, seed, clean, noisy)


def noisy_forward(params, x, neighbors, weights, cfg, noise: list,
                  interpret=None):
    """GNN forward with per-layer conductance noise on any backend.

    Mirrors ``core.gnn.forward`` (same layer loop, same activations) with
    the noise tensors of ``layer_noise`` riding on each layer's programmed
    weights. ``cfg`` is a ``GNNConfig`` with bit-accurate numerics.
    """
    import jax
    import jax.numpy as jnp
    from repro.kernels.crossbar_mvm import crossbar_matmul_signed_ref
    from repro.kernels.csr_aggregate import aggregate
    from repro.kernels.fused_layer import fused_gnn_layer
    assert not cfg.numerics.ideal, \
        "conductance noise models the bit-accurate path only"
    h = x
    n_layers = len(params)
    for i, layer in enumerate(params):
        nz = None if noise[i] is None else jnp.asarray(noise[i])
        act = i < n_layers - 1 or cfg.final_activation
        if cfg.backend == "fused":
            h = fused_gnn_layer(h, neighbors, weights, layer["w"],
                                layer["b"], cfg.numerics, relu=act,
                                tuned=cfg.tuned, interpret=interpret,
                                w_noise=nz)
            continue
        z = aggregate(h, neighbors, weights, backend=cfg.backend,
                      interpret=interpret)
        h = crossbar_matmul_signed_ref(z, layer["w"], cfg.numerics,
                                       w_noise=nz) + layer["b"]
        if act:
            h = jax.nn.relu(h)
    return h


def accuracy_bounds(tech, dataset: str = "taxi", scale: float = 0.02,
                    trials: int = 4, seed: int = 0, backend: str = "jnp",
                    hidden: int = 32, out_dim: int = 10, sample: int = 8,
                    cfg=None, interpret=None) -> VariationBounds:
    """End-to-end bounds: logit error + argmax flip rate on one dataset.

    Builds a downscaled ``dataset_like`` graph, runs the clean bit-accurate
    forward, then ``trials`` noisy forwards (fresh per-layer draws each),
    and reports relative logit error plus the flip rate — the quantity
    that decides whether a technology's noise breaks the bit-accurate
    serving contract.
    """
    import jax
    from repro.core import gnn
    from repro.core.graph import dataset_like
    from repro.kernels.crossbar_mvm import CrossbarNumerics
    tech = resolve_technology(tech)
    g = dataset_like(dataset, scale=scale, seed=seed).gcn_normalize()
    numerics = cfg or CrossbarNumerics()
    gcfg = gnn.GNNConfig(in_dim=g.feature_len, hidden_dims=(hidden,),
                         out_dim=out_dim, sample=sample, numerics=numerics,
                         backend=backend)
    params = gnn.init_params(jax.random.key(seed), gcfg)
    import jax.numpy as jnp
    nb, wt = g.neighbor_sample(sample)
    xs = (jnp.asarray(g.features), jnp.asarray(nb), jnp.asarray(wt))
    clean = np.asarray(gnn.forward(params, *xs, gcfg))
    noisy = np.stack([np.asarray(noisy_forward(
        params, *xs, gcfg, layer_noise([seed, t], params, tech, numerics),
        interpret=interpret)) for t in range(trials)])
    flips = float((noisy.argmax(-1) != clean.argmax(-1)[None]).mean())
    return _bounds_from_trials(tech, seed, clean, noisy, flip_rate=flips)
