"""Per-technology device parameter records (DESIGN.md §13).

One ``TechnologyParams`` describes the cell-level behaviour of an in-memory
compute technology — the quantities the mapper's per-pass rollup, the
Monte-Carlo variation pass, and the planner's accuracy/energy evaluators
consume. The records are *relative* models: the paper's Table 1 calibrates
one SOT-MRAM geometry, and every other technology is priced by scaling the
calibrated per-pass primitives with its read-latency / read-energy ratio to
that anchor (``bank.ANCHOR``). That keeps the anchor bit-for-bit identical
to the calibrated path (ratio exactly 1.0) while letting the planner trade
technologies per tier from literature-class parameters.

Conventions:

  * latencies/energies are *per cell access* [s] / [J] — absolute values
    matter only through their ratio to the anchor's;
  * ``cell_bits`` is the weight resolution one physical column group
    stores before bit-slicing. The Table-1 calibration maps one 8-bit
    weight per crossbar column, so the anchor records 8; multi-level-cell
    technologies with fewer bits trigger column bit-slicing in
    ``mapper.tiling`` (more arrays, more energy) exactly as a low
    ``XbarInventory.cell_bits`` does;
  * ``noise_sigma`` is the relative conductance-noise std of one
    programmed level (σ_G / G_max): the Monte-Carlo variation pass
    (``devices.variation``) perturbs quantized conductance codes by
    ``noise_sigma * w_levels`` per draw. Digital technologies (SRAM)
    record 0.0;
  * ``endurance`` is write cycles before wear-out — reported so streaming
    refresh churn can be turned into a device lifetime, not used in the
    latency rollup.

Dependency-free by design (pure dataclasses): the mapper and the planner's
candidate space import this module without pulling in jax.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TechnologyParams:
    """Cell-level parameters of one in-memory compute technology."""
    name: str
    read_latency_s: float
    write_latency_s: float
    read_energy_j: float
    write_energy_j: float
    cell_bits: int            # weight bits one column group stores
    on_off_ratio: float       # G_on / G_off conductance window
    noise_sigma: float        # relative conductance-level noise std
    endurance: float          # write cycles before wear-out

    def __post_init__(self):
        if not self.name:
            raise ValueError("technology name must be non-empty")
        for f in ("read_latency_s", "write_latency_s", "read_energy_j",
                  "write_energy_j", "on_off_ratio", "endurance"):
            if getattr(self, f) <= 0:
                raise ValueError(f"{self.name}: {f} must be > 0, "
                                 f"got {getattr(self, f)}")
        if self.cell_bits < 1:
            raise ValueError(f"{self.name}: cell_bits must be >= 1, "
                             f"got {self.cell_bits}")
        if self.noise_sigma < 0:
            raise ValueError(f"{self.name}: noise_sigma must be >= 0, "
                             f"got {self.noise_sigma}")

    @property
    def conductance_levels(self) -> int:
        """Distinct programmable levels per column group (2^cell_bits)."""
        return 2 ** self.cell_bits

    def lifetime_writes(self, writes_per_tick: float) -> float:
        """Ticks until wear-out at a given per-cell write rate."""
        return self.endurance / max(writes_per_tick, 1e-30)


# The paper's calibration point (Table 1 / §4.1): SOT-MRAM crossbars.
# Separate read/write paths give MRAM its fast, low-energy reads; the
# 8 cell_bits record the Table-1 one-weight-per-column mapping convention.
SOT_MRAM = TechnologyParams(
    name="sot-mram",
    read_latency_s=3e-9, write_latency_s=2e-9,
    read_energy_j=25e-15, write_energy_j=350e-15,
    cell_bits=8, on_off_ratio=3.0, noise_sigma=0.01, endurance=1e15)

# ReRAM: dense multi-level cells, slow energetic writes, large
# device-to-device conductance variation.
RERAM = TechnologyParams(
    name="reram",
    read_latency_s=10e-9, write_latency_s=100e-9,
    read_energy_j=10e-15, write_energy_j=2e-12,
    cell_bits=4, on_off_ratio=100.0, noise_sigma=0.05, endurance=1e9)

# SRAM: digital 8T compute macro — fastest access, no conductance noise,
# effectively unlimited endurance, but leaky and area-hungry.
SRAM = TechnologyParams(
    name="sram",
    read_latency_s=1e-9, write_latency_s=1e-9,
    read_energy_j=50e-15, write_energy_j=50e-15,
    cell_bits=8, on_off_ratio=1e6, noise_sigma=0.0, endurance=1e16)

# FeFET: ultra-low read energy (field-effect read, no static current),
# moderate multi-level precision, limited program/erase endurance.
FEFET = TechnologyParams(
    name="fefet",
    read_latency_s=5e-9, write_latency_s=10e-9,
    read_energy_j=5e-15, write_energy_j=100e-15,
    cell_bits=4, on_off_ratio=1e4, noise_sigma=0.03, endurance=1e8)
