"""Deterministic synthetic LM token pipeline.

Step-indexed PRNG => exact resume after checkpoint restore and bitwise
reproducibility across restarts/elastic re-sharding (every batch is a pure
function of (seed, step)). A Markov-ish structure makes the stream learnable
so the example training drivers show real loss curves.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenStream:
    vocab: int
    batch: int
    seq: int
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        return synthetic_batch(self.vocab, self.batch, self.seq,
                               self.seed, step)


def synthetic_batch(vocab: int, batch: int, seq: int, seed: int,
                    step: int) -> dict:
    """Learnable stream: each next token depends deterministically on the
    previous token plus slowly varying noise (so CE can fall well below
    log(vocab))."""
    key = jax.random.fold_in(jax.random.key(seed), step)
    k1, k2 = jax.random.split(key)
    first = jax.random.randint(k1, (batch, 1), 0, vocab)
    noise = jax.random.bernoulli(k2, 0.15, (batch, seq - 1))

    def step_fn(tok, nz):
        nxt = jnp.where(nz, (tok * 31 + 17) % vocab, (tok * 7 + 1) % vocab)
        return nxt, nxt

    _, rest = jax.lax.scan(step_fn, first[:, 0], noise.T)
    tokens = jnp.concatenate([first, rest.T], axis=1)
    labels = jnp.concatenate([tokens[:, 1:],
                              jnp.full((batch, 1), -1, tokens.dtype)], axis=1)
    return {"tokens": tokens.astype(jnp.int32),
            "labels": labels.astype(jnp.int32)}
