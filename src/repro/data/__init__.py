from .tokens import TokenStream, synthetic_batch
from .graphs import graph_batches

__all__ = ["TokenStream", "synthetic_batch", "graph_batches"]
