"""Graph data pipeline: deterministic mini-batched node sampling for GNN
training/serving (neighbor-sampled subgraph batches, step-indexed)."""
from __future__ import annotations

import numpy as np

from repro.core.graph import Graph


def graph_batches(g: Graph, batch_nodes: int, sample: int, seed: int = 0):
    """Yields dicts of (node_ids, neighbors, weights, features) forever,
    deterministic in (seed, step)."""
    nbr, wts = g.neighbor_sample(sample)
    step = 0
    while True:
        rng = np.random.default_rng((seed << 20) ^ step)
        ids = rng.choice(g.n_nodes, size=min(batch_nodes, g.n_nodes),
                         replace=False)
        yield {"node_ids": ids.astype(np.int32),
               "neighbors": nbr[ids],
               "weights": wts[ids],
               "features": g.features[ids] if g.features is not None else None,
               "step": step}
        step += 1
