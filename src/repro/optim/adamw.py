"""AdamW with global-norm clipping — pure pytree functions (sharding-friendly:
moments inherit/augment param specs via distributed.sharding.optimizer_shardings)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup: int = 100


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def adamw_update(params, grads, state, cfg: AdamWConfig):
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = cfg.lr * jnp.minimum(1.0, step / max(cfg.warmup, 1))
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        u = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
