"""int8 error-feedback gradient compression for the DP all-reduce.

Classic EF-SGD: quantize (grad + residual) to int8 with a per-tensor scale,
all-reduce the int8 payload (8x less DP traffic), keep the quantization error
as residual for the next step. Used by train.py inside shard_map over the
data axis; convergence is preserved by the error feedback.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_compress(g: jax.Array, residual: jax.Array):
    g = g.astype(jnp.float32) + residual
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    new_residual = g - q.astype(jnp.float32) * scale
    return q, scale, new_residual


def int8_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(g: jax.Array, residual: jax.Array, axis: str):
    """All-reduce a gradient tensor in int8 with error feedback.

    Must run inside shard_map with ``axis`` mapped. Returns (mean_grad,
    new_residual). Scales are reduced in f32 (tiny) alongside the int8
    payload; the decompressed sum divides by the axis size.
    """
    q, scale, new_residual = int8_compress(g, residual)
    # payload all-reduce in the integer domain (simulates 8x link traffic
    # reduction; the sum itself must widen to avoid overflow)
    summed = jax.lax.psum(q.astype(jnp.int32), axis)
    scale_max = jax.lax.pmax(scale, axis)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    return summed.astype(jnp.float32) * scale_max / n, new_residual
