from .adamw import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm
from .compress import int8_compress, int8_decompress, compressed_psum

__all__ = ["AdamWConfig", "adamw_init", "adamw_update",
           "clip_by_global_norm", "int8_compress", "int8_decompress",
           "compressed_psum"]
