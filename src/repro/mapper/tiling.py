"""Weight/activation tiling onto physical crossbar tiles — pure shape math.

This module is the dependency-free bottom of the mapper (no jax, no repro
imports), so the kernel ops layer can consume its padded grids without an
import cycle: ``crossbar_mvm.ops`` / ``fused_layer.ops`` ask ``padded_grid``
for the (bm, bk, bn) tiling instead of hard-coding divisibility
preconditions, and the compiler (``repro.mapper.compile``) builds
``LayerTiling`` plans from the same arithmetic, so the shapes the kernels
execute and the shapes the cost rollup prices are one computation.

Two views of the same layer:

  * ``TileGrid``    — the *kernel* view: an [M, K] x [K, N] matmul padded to
    a (bm, bk, bn) block grid with bk = one physical crossbar's rows (the
    ADC reduction-tree position) and bm/bn MXU/VPU lane-aligned.
  * ``LayerTiling`` — the *hardware* view: how many rows x cols crossbar
    tiles an F_in x F_out weight matrix occupies, including the bit-slicing
    plan when a device cell stores fewer bits than the weight precision
    (OpenNVRAM-style: the array module is sized from the requested rows,
    not the other way round).
"""
from __future__ import annotations

import dataclasses
import math


def _ceil_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


@dataclasses.dataclass(frozen=True)
class TileGrid:
    """Padded (bm, bk, bn) block grid of an [M, K] x [K, N] matmul.

    ``bk`` is one physical crossbar's row count (K-tiles are accumulated
    digitally post-ADC); ``bm``/``bn`` are the MXU block shape. The padded
    dims are the smallest multiples covering the logical shape — the ops
    layer zero-pads to them, the kernel asserts nothing.
    """
    m: int
    k: int
    n: int
    bm: int
    bk: int
    bn: int

    @property
    def m_pad(self) -> int:
        return _ceil_to(self.m, self.bm)

    @property
    def k_pad(self) -> int:
        return _ceil_to(self.k, self.bk)

    @property
    def n_pad(self) -> int:
        return _ceil_to(self.n, self.bn)

    @property
    def grid(self) -> tuple:
        """Pallas grid (M-tiles, N-tiles, K-tiles)."""
        return (self.m_pad // self.bm, self.n_pad // self.bn,
                self.k_pad // self.bk)

    @property
    def k_tiles(self) -> int:
        return self.k_pad // self.bk


def padded_grid(m: int, k: int, n: int, rows_per_xbar: int,
                bm: int = 128, bn: int = 128) -> TileGrid:
    """The (bm, bk, bn) grid mapping an arbitrary [M, K] x [K, N] matmul
    onto ``rows_per_xbar``-row crossbars — what the kernels pad to.

    Any positive M/K/N is mappable; this is the API the kernel layer's
    shape errors point at.
    """
    if min(m, k, n) < 1:
        raise ValueError(f"degenerate matmul shape M={m}, K={k}, N={n}")
    if rows_per_xbar < 1 or bm < 1 or bn < 1:
        raise ValueError(
            f"invalid tile geometry rows_per_xbar={rows_per_xbar}, "
            f"bm={bm}, bn={bn}")
    return TileGrid(m, k, n, bm=bm, bk=rows_per_xbar, bn=bn)


@dataclasses.dataclass(frozen=True)
class LayerTiling:
    """Physical occupancy of one F_in x F_out weight matrix on rows x cols
    crossbar tiles, with the bit-slicing plan.

    A device cell pair stores ``cell_bits``; a ``w_bits`` weight therefore
    spans ``bit_slices`` adjacent physical columns, shrinking the logical
    column capacity of one array to ``cols // bit_slices``.
    """
    f_in: int
    f_out: int
    rows: int
    cols: int
    w_bits: int = 8
    cell_bits: int = 8

    def __post_init__(self):
        if min(self.f_in, self.f_out) < 1:
            raise ValueError(f"degenerate layer {self.f_in}x{self.f_out}")
        if self.cols < self.bit_slices:
            raise ValueError(
                f"crossbar of {self.cols} columns cannot hold one "
                f"{self.w_bits}-bit weight at {self.cell_bits} bits/cell "
                f"({self.bit_slices} slices needed)")

    @property
    def bit_slices(self) -> int:
        return max(1, math.ceil(self.w_bits / self.cell_bits))

    @property
    def logical_cols(self) -> int:
        """Weight columns one physical array holds after bit-slicing."""
        return self.cols // self.bit_slices

    @property
    def k_tiles(self) -> int:
        return math.ceil(self.f_in / self.rows)

    @property
    def n_tiles(self) -> int:
        return math.ceil(self.f_out / self.logical_cols)

    @property
    def n_arrays(self) -> int:
        """Physical arrays one resident copy of the weight matrix occupies."""
        return self.k_tiles * self.n_tiles

    @property
    def pad_k(self) -> int:
        return self.k_tiles * self.rows - self.f_in

    @property
    def pad_n(self) -> int:
        return self.n_tiles * self.logical_cols - self.f_out

    @property
    def utilization(self) -> float:
        """Programmed cells / total cells over the occupied arrays."""
        used = self.f_in * self.f_out * self.bit_slices
        total = self.n_arrays * self.rows * self.cols
        return used / total

    def kernel_grid(self, m: int, bm: int = 128, bn: int = 128) -> TileGrid:
        """The kernel-view grid for an [m, F_in] activation batch."""
        return padded_grid(m, self.f_in, self.f_out, self.rows, bm=bm, bn=bn)


def tile_layer(f_in: int, f_out: int, rows: int, cols: int,
               w_bits: int = 8, cell_bits: int = 8) -> LayerTiling:
    """Tile an F_in x F_out layer onto rows x cols crossbars."""
    return LayerTiling(f_in, f_out, rows, cols, w_bits=w_bits,
                       cell_bits=cell_bits)


def execute_tiled(x, w, tiling: LayerTiling):
    """Execute x @ w tile-by-tile exactly as the tiling maps it to hardware:
    pad K/N to the tile grid, run one partial matmul per (K-tile, N-tile),
    and accumulate K-tiles digitally. Pure numpy, ideal numerics.

    This is the mapper's correctness oracle: for any tiling, the result
    equals the dense matmul (bit-exactly on integer-valued inputs) — the
    property test in tests/test_mapper.py pins it.
    """
    import numpy as np

    x = np.asarray(x)
    w = np.asarray(w)
    m, k = x.shape
    k2, n = w.shape
    if k != k2 or (k, n) != (tiling.f_in, tiling.f_out):
        raise ValueError(f"shape mismatch: x {x.shape}, w {w.shape}, "
                         f"tiling {tiling.f_in}x{tiling.f_out}")
    r, c = tiling.rows, tiling.logical_cols
    xp = np.zeros((m, tiling.k_tiles * r), x.dtype)
    xp[:, :k] = x
    wp = np.zeros((tiling.k_tiles * r, tiling.n_tiles * c), w.dtype)
    wp[:k, :n] = w
    out = np.zeros((m, tiling.n_tiles * c), np.result_type(x, w, np.float64))
    for kt in range(tiling.k_tiles):        # digital cross-crossbar add
        for nt in range(tiling.n_tiles):    # independent column tiles
            out[:, nt * c:(nt + 1) * c] += (
                xp[:, kt * r:(kt + 1) * r] @ wp[kt * r:(kt + 1) * r,
                                               nt * c:(nt + 1) * c])
    return out[:, :n]
