"""Pass schedules: turn per-core allocations into an executable timeline.

A ``PassSchedule`` is the ordered crossbar-pass program of one inference on
one device: per pipeline stage (traversal → aggregation → feature
extraction), how many serialized pass rounds run and how long one round
takes. Two latency views:

  * ``t_serial``    — stages back-to-back, Σ rounds_i x t_pass_i. This is
    the Eq. 1-compatible number the cost model's calibrated path also
    computes, so it is the cross-validation anchor.
  * ``t_pipelined`` — stages overlapped wave-by-wave (the paper's cores
    form a pipeline, Fig. 1): bottleneck-stage drain plus one fill pass of
    every other stage. Always <= t_serial; the gap is the pipelining
    headroom the mapper exposes.

Round counts can reach millions on big graphs (LiveJournal centralized), so
the timeline is generated lazily — ``slots(limit)`` enumerates the first
``limit`` concrete passes and summarizes the tail.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator


@dataclasses.dataclass(frozen=True)
class Stage:
    """One pipeline stage: a core's serialized pass rounds."""
    name: str
    rounds: int
    t_pass: float           # seconds per serialized round
    arrays_busy: int        # arrays active in a full round

    @property
    def latency(self) -> float:
        return self.rounds * self.t_pass


@dataclasses.dataclass(frozen=True)
class PassSlot:
    """One concrete pass in the serialized timeline."""
    step: int
    stage: str
    round_index: int
    t_start: float
    t_end: float


@dataclasses.dataclass(frozen=True)
class PassSchedule:
    stages: tuple

    @property
    def total_rounds(self) -> int:
        return sum(s.rounds for s in self.stages)

    @property
    def t_serial(self) -> float:
        return sum(s.latency for s in self.stages)

    @property
    def t_pipelined(self) -> float:
        live = [s for s in self.stages if s.rounds > 0]
        if not live:
            return 0.0
        bottleneck = max(s.latency for s in live)
        fill = sum(s.t_pass for s in live) - max(
            s.t_pass for s in live if s.latency == bottleneck)
        return bottleneck + fill

    def slots(self, limit: int = 64) -> Iterator[PassSlot]:
        """Lazily enumerate the serial timeline's first ``limit`` passes."""
        t = 0.0
        step = 0
        for s in self.stages:
            for r in range(s.rounds):
                if step >= limit:
                    return
                yield PassSlot(step, s.name, r, t, t + s.t_pass)
                t += s.t_pass
                step += 1

    def describe(self, limit: int = 8) -> str:
        lines = [f"{'stage':14s} {'rounds':>10s} {'t_pass':>11s} "
                 f"{'latency':>11s} {'arrays':>7s}"]
        for s in self.stages:
            lines.append(f"{s.name:14s} {s.rounds:10d} {s.t_pass:11.3e} "
                         f"{s.latency:11.3e} {s.arrays_busy:7d}")
        lines.append(f"serial {self.t_serial:.3e} s, "
                     f"pipelined {self.t_pipelined:.3e} s "
                     f"({self.total_rounds} rounds)")
        shown = list(self.slots(limit))
        if shown:
            lines.append(f"first {len(shown)} passes: " + ", ".join(
                f"{p.stage}[{p.round_index}]@{p.t_start:.2e}s"
                for p in shown[:limit]))
            tail = self.total_rounds - len(shown)
            if tail > 0:
                lines.append(f"... {tail} more rounds")
        return "\n".join(lines)


def build_schedule(allocations, t_passes) -> PassSchedule:
    """Zip per-core ``CoreAllocation``s with per-round latencies.

    ``allocations``: iterable of CoreAllocation in pipeline order;
    ``t_passes``: matching per-round latencies [s].
    """
    stages = tuple(
        Stage(a.core, a.rounds, t, a.arrays_used)
        for a, t in zip(allocations, t_passes))
    return PassSchedule(stages)
