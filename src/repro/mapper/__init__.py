"""Crossbar mapper/compiler: derive hardware mappings from first principles.

``compile_mapping(model, stats, ...)`` turns (GNN layer dims, graph stats,
array inventory) into a ``CompiledMapping`` — per-layer weight tilings with
padding/bit-slicing, array allocation (duplication vs pass serialization),
a pipeline pass schedule, and derived latency/energy rollups. DESIGN.md §8.

The shape-math bottom (``tiling``, ``inventory``) is import-light so the
kernel ops layer can consume padded grids without a cycle; the compiler
modules (which pull in ``repro.core``) load lazily via PEP-562.
"""
from __future__ import annotations

from .inventory import XbarInventory
from .tiling import (LayerTiling, TileGrid, execute_tiled, padded_grid,
                     tile_layer)

_LAZY = {
    "CompiledMapping": "compile",
    "LayerMapping": "compile",
    "PassPrimitives": "compile",
    "compile_mapping": "compile",
    "items_per_device": "compile",
    "CoreAllocation": "allocate",
    "allocate": "allocate",
    "PassSchedule": "schedule",
    "Stage": "schedule",
    "build_schedule": "schedule",
}

__all__ = ["XbarInventory", "LayerTiling", "TileGrid", "padded_grid",
           "tile_layer", "execute_tiled", *sorted(_LAZY)]


def __getattr__(name: str):
    if name in _LAZY:
        import importlib
        mod = importlib.import_module(f".{_LAZY[name]}", __name__)
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
