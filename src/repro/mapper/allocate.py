"""Array allocation: map per-node tile work onto a device's array pool.

One allocation rule covers all three cores and both scarcity regimes:

  * **plentiful** — when a device has more arrays than one work item needs
    (``arrays >= tiles_per_item``), the weight tiles are *duplicated*
    ``copies = arrays // tiles_per_item`` times and that many items are
    processed per pass round (the paper's §4.3 "more crossbars per node →
    linear speed-up" made explicit).
  * **scarce** — when one item's tiles exceed the pool
    (``tiles_per_item > arrays``), the item is *serialized* over
    ``groups = ceil(tiles_per_item / arrays)`` pass rounds, time-
    multiplexing the pool across tile groups.

``rounds = ceil(items / copies) * groups`` is then the number of serialized
crossbar pass rounds this core needs per inference — latency is
``rounds x t_pass``; energy is ``tile_passes x e_pass`` (idle arrays in a
ragged last round draw no read energy, so energy counts work, not rounds).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class CoreAllocation:
    """Allocation of one core's array pool for one inference."""
    core: str               # "traversal" | "aggregation" | "fx"
    tiles_per_item: int     # tile-passes one work item (node) needs
    n_items: int            # work items this device processes per inference
    arrays: int             # physical arrays of this kind on the device

    def __post_init__(self):
        if self.tiles_per_item < 1 or self.n_items < 0 or self.arrays < 1:
            raise ValueError(f"invalid allocation {self}")

    @property
    def groups(self) -> int:
        """Sequential tile groups when one item overflows the pool."""
        return math.ceil(self.tiles_per_item / self.arrays)

    @property
    def copies(self) -> int:
        """Parallel duplicates of the item's tile set across the pool."""
        return max(1, self.arrays // self.tiles_per_item)

    @property
    def rounds(self) -> int:
        """Serialized pass rounds per inference (latency multiplier)."""
        if self.n_items == 0:
            return 0
        return math.ceil(self.n_items / self.copies) * self.groups

    @property
    def tile_passes(self) -> int:
        """Total tile-level passes executed (energy multiplier)."""
        return self.n_items * self.tiles_per_item

    @property
    def arrays_used(self) -> int:
        """Arrays the schedule actually exercises."""
        return min(self.arrays, self.copies * self.tiles_per_item)

    @property
    def occupancy(self) -> float:
        """Work / capacity over the schedule: tile_passes / (rounds*arrays)."""
        if self.rounds == 0:
            return 0.0
        return self.tile_passes / (self.rounds * self.arrays)

    @property
    def resident(self) -> bool:
        """True when one full tile set fits the pool (no time-multiplexing)."""
        return self.groups == 1


def allocate(core: str, tiles_per_item: int, n_items: int,
             arrays: int) -> CoreAllocation:
    """Allocate ``arrays`` physical arrays to ``n_items`` work items of
    ``tiles_per_item`` tiles each. See module docstring for the rule."""
    return CoreAllocation(core, tiles_per_item, n_items, arrays)
