"""The crossbar mapper/compiler: (model, workload, device) → CompiledMapping.

Where ``core/costmodel.py`` *calibrates* per-core latencies to the paper's
Table 1 and can therefore only price the exact configurations the paper
measured, this module *derives* them from first principles:

  1. **Tile** every GNN layer's F_in x F_out weight matrix onto the
     device's physical fx crossbars (``tiling.LayerTiling`` — padding and
     bit-slicing make arbitrary shapes mappable), and count the traversal /
     aggregation tile passes one node's neighborhood needs.
  2. **Allocate** the device's array inventory (``allocate.allocate``):
     duplicate weight tiles for throughput when arrays are plentiful,
     serialize passes when they are scarce.
  3. **Schedule** the pass rounds per pipeline stage
     (``schedule.PassSchedule``) and roll up latency (rounds x t_pass) and
     energy (tile passes x per-array read energy).

The per-*pass* primitives are the only calibrated quantities — one CAM
search, one 512x512 aggregation pass, one 128x128 fx pass, inverted from
Table 1 exactly as ``costmodel`` does (t_fx_pass = t3 / 2: the calibration
workload is a 216→128 layer, two fx tiles serialized on one array). Pass
latency scales with the ADC column count and read energy with the cell
count, so changing the crossbar geometry or the inventory moves the rollup
— that is the derivation the calibrated path cannot do. At the paper's own
geometry the two paths agree to ceil-rounding (< 10%, asserted in
tests/test_mapper.py); away from it they diverge, and the divergence is
the measurement (benchmarks/mapper_sweep.py).
"""
from __future__ import annotations

import dataclasses
import math

from .allocate import CoreAllocation, allocate
from .inventory import XbarInventory
from .schedule import PassSchedule, build_schedule
from .tiling import LayerTiling, TileGrid, padded_grid, tile_layer

SETTINGS = ("centralized", "decentralized", "semi")


@dataclasses.dataclass(frozen=True)
class PassPrimitives:
    """Per-round latency [s] and per-tile-pass read energy [J] per core,
    derived from the calibrated ``HardwareParams`` (or a measured
    ``HostCalibration``), scaled to the target inventory's geometry and
    device technology."""
    t_cam: float
    t_agg: float
    t_fx: float
    e_cam: float
    e_agg: float
    e_fx: float

    @classmethod
    def derive(cls, hw, inv: XbarInventory, tech=None,
               calibration=None) -> "PassPrimitives":
        # per-round latencies at the calibration geometry (Table-1 inversion:
        # decentralized = 1 array/core; taxi fx workload = 2 serialized
        # tiles) — or, when a HostCalibration artifact is supplied, the
        # per-pass wall-clocks measured on the current host
        # (devices.calibrate; same geometry convention)
        if calibration is not None:
            t_cam_cal, t_agg_cal, t_fx_cal = (calibration.t_cam,
                                              calibration.t_agg,
                                              calibration.t_fx)
        else:
            t_cam_cal, t_agg_cal, t_fx_cal = hw.t1, hw.t2, hw.t3 / 2.0
        # MVM pass latency tracks the ADC read-out serialization over
        # columns; the bit-serial DAC cycle count is geometry-independent.
        # CAM search is match-line parallel: constant per pass.
        t_agg = t_agg_cal * inv.agg_cols / hw.agg_cols
        t_fx = t_fx_cal * inv.fx_cols / hw.fx_cols
        # read energy tracks the activated cell count; per-array density
        # from the centralized bank (p_core / M_i arrays active per round)
        e_cam = (hw.p_cores_cent[0] / hw.m1) * t_cam_cal
        e_agg = ((hw.p_cores_cent[1] / hw.m2) * t_agg_cal
                 * (inv.agg_rows * inv.agg_cols) / (hw.agg_rows * hw.agg_cols))
        e_fx = ((hw.p_cores_cent[2] / hw.m3) * t_fx_cal
                * (inv.fx_rows * inv.fx_cols) / (hw.fx_rows * hw.fx_cols))
        t_cam = t_cam_cal
        if tech is not None:
            # technology scaling: read-path ratios to the SOT-MRAM anchor
            # (devices.bank) — exactly (1.0, 1.0) at the anchor itself, so
            # the Table-1 calibration point is reproduced bit-for-bit
            from repro.devices.bank import primitive_scales
            lat, ene = primitive_scales(tech)
            t_cam, t_agg, t_fx = t_cam * lat, t_agg * lat, t_fx * lat
            e_cam, e_agg, e_fx = e_cam * ene, e_agg * ene, e_fx * ene
        return cls(t_cam, t_agg, t_fx, e_cam, e_agg, e_fx)


@dataclasses.dataclass(frozen=True)
class LayerMapping:
    """One GNN layer's weight tiling plus its kernel-facing padded grid."""
    index: int
    tiling: LayerTiling
    grid: TileGrid          # (bm, bk, bn) the ops layer pads to

    def describe(self) -> str:
        t = self.tiling
        return (f"layer {self.index}: {t.f_in}x{t.f_out} -> "
                f"{t.k_tiles}x{t.n_tiles} tiles of {t.rows}x{t.cols} "
                f"(pad K+{t.pad_k}, N+{t.pad_n}, "
                f"{t.bit_slices} bit-slice(s), "
                f"util {t.utilization:.1%}); kernel grid "
                f"bm={self.grid.bm}, bk={self.grid.bk}, bn={self.grid.bn}")


@dataclasses.dataclass(frozen=True)
class CompiledMapping:
    """The mapper's output: tilings, allocations, schedule, and the
    first-principles latency/energy rollup for one device of one setting."""
    setting: str
    n_devices: int
    items_per_device: int
    inventory: XbarInventory
    layers: tuple                   # LayerMapping per GNN layer
    cam: CoreAllocation
    agg: CoreAllocation
    fx: CoreAllocation
    primitives: PassPrimitives
    schedule: PassSchedule
    sample: int | None = None
    technology: str = "sot-mram"

    # ---- latency rollup (rounds x t_pass), Eq. 1-compatible serial sum ----
    @property
    def t_traversal(self) -> float:
        return self.cam.rounds * self.primitives.t_cam

    @property
    def t_aggregation(self) -> float:
        return self.agg.rounds * self.primitives.t_agg

    @property
    def t_fx(self) -> float:
        return self.fx.rounds * self.primitives.t_fx

    @property
    def t_compute(self) -> float:
        return self.t_traversal + self.t_aggregation + self.t_fx

    @property
    def t_compute_pipelined(self) -> float:
        return self.schedule.t_pipelined

    # ---- energy rollup (tile passes x per-array read energy) ----
    @property
    def energy_j(self) -> float:
        p = self.primitives
        return (self.cam.tile_passes * p.e_cam
                + self.agg.tile_passes * p.e_agg
                + self.fx.tile_passes * p.e_fx)

    @property
    def weight_arrays(self) -> int:
        """fx arrays one resident copy of all layer weights occupies."""
        return sum(lm.tiling.n_arrays for lm in self.layers)

    @property
    def weight_utilization(self) -> float:
        """Programmed cells / cells over the occupied weight arrays."""
        used = sum(lm.tiling.utilization * lm.tiling.n_arrays
                   for lm in self.layers)
        return used / max(self.weight_arrays, 1)

    @property
    def array_utilization(self) -> tuple:
        """(cam, agg, fx) schedule occupancy: work / (rounds x arrays)."""
        return (self.cam.occupancy, self.agg.occupancy, self.fx.occupancy)

    def core_latency(self):
        """The rollup as a ``repro.core.costmodel.CoreLatency``."""
        from repro.core.costmodel import CoreLatency
        return CoreLatency(self.t_traversal, self.t_aggregation, self.t_fx)

    def mapping_report(self) -> str:
        inv = self.inventory
        u = self.array_utilization
        lines = [
            f"CompiledMapping[{self.setting}] — {self.n_devices} device(s), "
            f"{self.items_per_device} node(s)/device/inference",
            f"inventory: CAM {inv.cam_arrays}x({inv.cam_rows}x{inv.cam_cols})"
            f", AGG {inv.agg_arrays}x({inv.agg_rows}x{inv.agg_cols}), "
            f"FX {inv.fx_arrays}x({inv.fx_rows}x{inv.fx_cols}), "
            f"{inv.cell_bits} bits/cell, technology {self.technology}",
        ]
        lines += [lm.describe() for lm in self.layers]
        lines += [
            f"weights: {self.weight_arrays} fx arrays resident "
            f"(cell util {self.weight_utilization:.1%}); fx copies "
            f"{self.fx.copies}, groups {self.fx.groups}",
            f"allocation: cam {self.cam.rounds} rounds (occ {u[0]:.1%}), "
            f"agg {self.agg.rounds} rounds (occ {u[1]:.1%}), "
            f"fx {self.fx.rounds} rounds (occ {u[2]:.1%})",
            f"derived: T_compute {self.t_compute:.3e} s (pipelined "
            f"{self.t_compute_pipelined:.3e} s), E {self.energy_j:.3e} J",
            self.schedule.describe(limit=4),
        ]
        return "\n".join(lines)


def _layer_dims(model) -> tuple:
    """Accept a GNNConfig-like object (``.dims``) or a plain dims tuple."""
    dims = tuple(getattr(model, "dims", model))
    if len(dims) < 2 or any(int(d) < 1 for d in dims):
        raise ValueError(f"need >= 2 positive layer dims, got {dims!r}")
    return tuple(int(d) for d in dims)


def items_per_device(setting: str, n_nodes: int, n_clusters: int = 1) -> int:
    """Nodes one device processes per inference (Eq. 2/3 conventions)."""
    if setting == "centralized":
        return max(n_nodes - 1, 1)
    if setting == "decentralized":
        return 1
    assert setting == "semi", setting
    return max(math.ceil(n_nodes / max(n_clusters, 1)) - 1, 1)


def compile_mapping(model, stats, hw=None, inventory: XbarInventory = None,
                    setting: str = "centralized", n_clusters: int = 1,
                    sample: int | None = None, w_bits: int | None = None,
                    technology=None, calibration=None) -> CompiledMapping:
    """Compile (GNN layer dims, graph stats, hardware) into a CompiledMapping.

    ``model``: a ``GNNConfig``-like object exposing ``.dims`` or a plain
    tuple of layer feature dims; ``stats``: ``GraphStats``; ``hw``: the
    calibrated ``HardwareParams`` supplying the per-pass primitives
    (default ``DEFAULT_HW``); ``inventory``: the device's array inventory
    (default: the setting's paper inventory via
    ``XbarInventory.from_hardware``); ``sample``: the runtime's neighbor
    sample size (default: the Table-2 ``avg_cs`` heuristic).

    ``technology``: a registered technology name or ``TechnologyParams``
    overriding the inventory's; the per-pass primitives are scaled by the
    technology's read-path ratios to the SOT-MRAM anchor (exact identity
    at the anchor). An unregistered name raises the named
    ``UnknownTechnologyError`` here, before any latency rollup.
    ``calibration``: a measured ``HostCalibration`` replacing the Table-1
    inversion as the primitives' anchor point (``devices.calibrate``).
    """
    if setting not in SETTINGS:
        raise ValueError(f"unknown setting {setting!r}; one of {SETTINGS}")
    if hw is None:
        from repro.core.costmodel import DEFAULT_HW
        hw = DEFAULT_HW
    inv = inventory or XbarInventory.from_hardware(hw, setting)
    # resolve the technology up front: a typo'd name must fail with the
    # named registry error, not deep inside the latency rollup
    from repro.devices.bank import resolve_technology
    tech = resolve_technology(
        technology if technology is not None else inv.technology)
    if technology is not None and inv.technology != tech.name:
        # explicit override: rebuild the arrays from the named technology
        # (cell_bits follows it); an inventory already carrying a custom
        # technology/cell_bits pairing is the caller's explicit choice
        inv = inv.with_technology(tech)
    dims = _layer_dims(model)
    prim = PassPrimitives.derive(hw, inv, tech=tech, calibration=calibration)

    items = items_per_device(setting, stats.n_nodes, n_clusters)
    n_devices = (1 if setting == "centralized"
                 else (n_clusters if setting == "semi" else stats.n_nodes))

    # traversal: one CAM search per cam_rows block of the neighbor list
    cs = max(stats.avg_cs, 1.0)
    cam_tiles = math.ceil(cs / inv.cam_rows)
    # aggregation: per layer, (sampled neighbors x that layer's input
    # features) tiled onto the aggregation crossbar
    s = sample if sample is not None else min(cs, inv.agg_rows)
    agg_tiles = sum(math.ceil(max(s, 1) / inv.agg_rows)
                    * math.ceil(f_in / inv.agg_cols) for f_in in dims[:-1])
    # feature extraction: every layer's weight matrix resident on fx arrays
    layers = []
    for i, (f_in, f_out) in enumerate(zip(dims[:-1], dims[1:])):
        # weight precision is a property of the numerics, not the device:
        # default to the stack-wide 8-bit CrossbarNumerics so low-precision
        # cells (cell_bits < 8) correctly trigger bit-slicing
        t = tile_layer(f_in, f_out, inv.fx_rows, inv.fx_cols,
                       w_bits=w_bits or 8, cell_bits=inv.cell_bits)
        layers.append(LayerMapping(i, t, padded_grid(
            max(items, 1), f_in, f_out, inv.fx_rows)))
    fx_tiles = sum(lm.tiling.n_arrays for lm in layers)

    cam = allocate("traversal", cam_tiles, items, inv.cam_arrays)
    agg = allocate("aggregation", agg_tiles, items, inv.agg_arrays)
    fx = allocate("fx", fx_tiles, items, inv.fx_arrays)
    sched = build_schedule((cam, agg, fx),
                           (prim.t_cam, prim.t_agg, prim.t_fx))

    return CompiledMapping(setting, n_devices, items, inv, tuple(layers),
                           cam, agg, fx, prim, sched, sample=sample,
                           technology=tech.name)
