"""Per-device crossbar array inventories (paper §4.1 / Table 1, Fig. 2).

IMA-GNN's devices are bags of physical arrays: the centralized accelerator
carries 2000x(512x32) CAM arrays (traversal), 1000x(512x512) MVM crossbars
(aggregation) and 256x(128x128) MVM crossbars (feature extraction); a
decentralized edge node carries one of each. ``XbarInventory`` is that
inventory as data — counts and geometries per core — so the mapper can
allocate against *any* device, not just the two the paper measured.

Dependency-free by design (duck-types ``HardwareParams``): the kernel layer
may import this module without pulling in the core package.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class XbarInventory:
    """Physical array inventory of one accelerator device.

    Per core (traversal CAM / aggregation MVM / feature-extraction MVM):
    array count and rows x cols geometry. ``cell_bits`` is the storage
    resolution of one device pair — fewer bits than the weight precision
    forces bit-slicing across columns (see ``tiling.LayerTiling``).
    ``technology`` names the device technology the arrays are built from
    (``repro.devices.bank``); the default is the paper's SOT-MRAM
    calibration point, and the name is resolved — and validated — by
    ``compile_mapping``, which scales its per-pass primitives by the
    technology's ratio to that anchor.
    """
    cam_arrays: int = 2000
    cam_rows: int = 512
    cam_cols: int = 32
    agg_arrays: int = 1000
    agg_rows: int = 512
    agg_cols: int = 512
    fx_arrays: int = 256
    fx_rows: int = 128
    fx_cols: int = 128
    cell_bits: int = 8
    technology: str = "sot-mram"

    def __post_init__(self):
        for f in dataclasses.fields(self):
            if f.type == "int" and getattr(self, f.name) < 1:
                raise ValueError(f"inventory field {f.name} must be >= 1, "
                                 f"got {getattr(self, f.name)}")
        if not self.technology:
            raise ValueError("inventory technology must be non-empty")

    @property
    def total_cells(self) -> tuple:
        """(cam, agg, fx) total device cells — the silicon budget."""
        return (self.cam_arrays * self.cam_rows * self.cam_cols,
                self.agg_arrays * self.agg_rows * self.agg_cols,
                self.fx_arrays * self.fx_rows * self.fx_cols)

    @classmethod
    def from_hardware(cls, hw, setting: str = "centralized") -> "XbarInventory":
        """Inventory implied by a ``HardwareParams``-like object.

        ``centralized``/``semi`` (a cluster head is a full centralized
        accelerator, paper §5) get the (m1, m2, m3) multiplicities;
        ``decentralized`` gets ``n_xbar_dec`` of each.
        """
        if setting == "decentralized":
            counts = tuple(int(c) for c in hw.n_xbar_dec)
        else:
            counts = (int(hw.m1), int(hw.m2), int(hw.m3))
        return cls(cam_arrays=counts[0], cam_rows=hw.cam_rows,
                   cam_cols=hw.cam_cols,
                   agg_arrays=counts[1], agg_rows=hw.agg_rows,
                   agg_cols=hw.agg_cols,
                   fx_arrays=counts[2], fx_rows=hw.fx_rows,
                   fx_cols=hw.fx_cols)

    def with_xbar_size(self, size: int, iso_cells: bool = False
                       ) -> "XbarInventory":
        """Re-geometry the MVM crossbars (aggregation + feature extraction)
        to ``size x size`` arrays; the CAM keeps its entry-width geometry.

        ``iso_cells=True`` rescales the array counts to preserve each
        core's total cell budget (the iso-silicon comparison the mapper
        sweep reports); ``False`` keeps the counts — same arrays, different
        geometry.
        """
        agg_n, fx_n = self.agg_arrays, self.fx_arrays
        if iso_cells:
            _, agg_cells, fx_cells = self.total_cells
            agg_n = max(1, agg_cells // (size * size))
            fx_n = max(1, fx_cells // (size * size))
        return dataclasses.replace(self, agg_arrays=agg_n, agg_rows=size,
                                   agg_cols=size, fx_arrays=fx_n,
                                   fx_rows=size, fx_cols=size)

    def with_technology(self, tech) -> "XbarInventory":
        """Rebuild the arrays from another device technology.

        ``tech`` is a registered name or a ``TechnologyParams``
        (``repro.devices.bank.resolve_technology`` — an unknown name
        raises the named ``UnknownTechnologyError``). The cell storage
        resolution follows the technology (fewer ``cell_bits`` triggers
        column bit-slicing in the tiling); the per-pass latency/energy
        scaling happens in ``compile_mapping``'s primitive derivation.
        """
        from repro.devices.bank import resolve_technology
        t = resolve_technology(tech)
        return dataclasses.replace(self, technology=t.name,
                                   cell_bits=t.cell_bits)
