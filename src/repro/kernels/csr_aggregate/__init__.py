from .ref import csr_aggregate_ref, pad_neighbors
from .ops import aggregate

__all__ = ["csr_aggregate_ref", "pad_neighbors", "aggregate"]
