"""Pallas TPU kernel for the IMA-GNN aggregation core (node-stationary gather-reduce).

TPU adaptation: the paper activates crossbar rows per incoming edge and sums
analog currents; on TPU the same node-stationary dataflow becomes a
scalar-prefetch gather. Neighbor indices are scalar-prefetched so the
BlockSpec ``index_map`` can steer each HBM->VMEM feature-row fetch directly —
the gather never materializes an [Nd, S, F] tensor. The destination node's
accumulator lives in VMEM (the output block is revisited across the S grid
axis), mirroring the paper's destination-stationary accumulation.

Grid: (node, F // bf, S). Feature rows are fetched in (1, bf) blocks with
bf a multiple of 128 (VPU lane aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._interpret import resolve_interpret


def _kernel(nbr_ref, wts_ref, x_ref, out_ref):
    i = pl.program_id(0)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w = wts_ref[i, s]                       # scalar edge weight (SMEM)
    out_ref[...] += w * x_ref[...].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("bf", "interpret"))
def csr_aggregate(x: jax.Array, neighbors: jax.Array, weights: jax.Array,
                  bf: int = 128,
                  interpret: bool | None = None) -> jax.Array:
    """Weighted neighbor-feature aggregation via scalar-prefetch gather.

    x: [N, F] float, F % bf == 0; neighbors: [Nd, S] int32; weights: [Nd, S].
    Returns z: [Nd, F] float32. Matches ``ref.csr_aggregate_ref`` exactly.
    """
    interpret = resolve_interpret(interpret)
    n, f = x.shape
    nd, s = neighbors.shape
    assert f % bf == 0, (f, bf)
    grid = (nd, f // bf, s)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,              # neighbors, weights
        grid=grid,
        in_specs=[
            # one neighbor feature row block, steered by the prefetched index
            pl.BlockSpec((1, bf), lambda i, j, ss, nbr, wts: (nbr[i, ss], j)),
        ],
        out_specs=pl.BlockSpec((1, bf), lambda i, j, ss, nbr, wts: (i, j)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nd, f), jnp.float32),
        interpret=interpret,
    )(neighbors, weights.astype(jnp.float32), x)
