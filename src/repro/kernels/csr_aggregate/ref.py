"""Pure-jnp oracle for the aggregation core (IMA-GNN Fig. 2(a)-3).

The paper's aggregation core consumes, per destination node, the set of
source-node rows activated by the traversal core and reduces their feature
vectors (weighted by the edge weights from the CSR E array). Following the
paper's Table-2 note — "a given vertex is mapped deterministically to a
fixed-sized, uniform sample of its neighbors" — the kernel-facing format is a
*padded neighbor sample*: for each destination node, ``sample`` slots of
(source index, edge weight), weight 0 on padding.

    z[i] = sum_s  weight[i, s] * x[neighbors[i, s]]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def csr_aggregate_ref(x: jax.Array, neighbors: jax.Array,
                      weights: jax.Array) -> jax.Array:
    """x: [N, F] float; neighbors: [Nd, S] int32 in [0, N); weights: [Nd, S].

    Returns z: [Nd, F] float32, the weighted neighbor-feature reduction.
    """
    gathered = x[neighbors]                       # [Nd, S, F]
    return jnp.einsum(
        "nsf,ns->nf", gathered.astype(jnp.float32),
        weights.astype(jnp.float32))


def pad_neighbors(indptr, indices, edge_weights, sample: int,
                  *, self_loops: bool = False, self_loop_weight=None):
    """Host-side CSR -> padded neighbor sample conversion (numpy, not jitted).

    Deterministic: takes the first ``sample`` neighbors of each node (the
    paper's deterministic fixed-size uniform mapping); pads with index 0 /
    weight 0. Returns (neighbors [N, S] int32, weights [N, S] float32).

    ``self_loop_weight`` (scalar or [N] array) is the weight of the implicit
    self loop appended when ``self_loops=True``. It defaults to 1.0 (plain
    ``A + I`` on unweighted graphs); a GCN-normalized graph must pass
    ``1 / (d_i + 1)`` so the sample realizes the documented contract
    ``A_hat = D^-1/2 (A + I) D^-1/2`` (see ``Graph.gcn_normalize``).
    """
    import numpy as np
    n = len(indptr) - 1
    nbr = np.zeros((n, sample), np.int32)
    wts = np.zeros((n, sample), np.float32)
    if self_loop_weight is None:
        self_loop_weight = np.ones(n, np.float32)
    else:
        self_loop_weight = np.broadcast_to(
            np.asarray(self_loop_weight, np.float32), (n,))
    for i in range(n):
        lo, hi = int(indptr[i]), int(indptr[i + 1])
        take = min(hi - lo, sample - (1 if self_loops else 0))
        nbr[i, :take] = indices[lo:lo + take]
        wts[i, :take] = (edge_weights[lo:lo + take]
                         if edge_weights is not None else 1.0)
        if self_loops:
            nbr[i, take] = i
            wts[i, take] = self_loop_weight[i]
    return nbr, wts
