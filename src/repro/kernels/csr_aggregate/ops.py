"""Jitted public wrapper for the aggregation-core kernel.

Pads the feature dim to the 128-lane block multiple and exposes a
``backend`` switch: ``pallas`` (interpret-mode on CPU, compiled on TPU) or
``jnp`` (the oracle — used on the distributed hot path where XLA's own fusion
is preferable on a host backend).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .csr_aggregate import csr_aggregate as _pallas_aggregate
from .ref import csr_aggregate_ref


@functools.partial(jax.jit, static_argnames=("backend", "bf", "interpret"))
def aggregate(x: jax.Array, neighbors: jax.Array, weights: jax.Array,
              backend: str = "jnp", bf: int = 128,
              interpret: bool | None = None) -> jax.Array:
    if backend == "jnp":
        return csr_aggregate_ref(x, neighbors, weights)
    assert backend == "pallas", backend
    n, f = x.shape
    pad = (-f) % bf
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    out = _pallas_aggregate(x, neighbors, weights, bf=bf, interpret=interpret)
    return out[:, :f]
