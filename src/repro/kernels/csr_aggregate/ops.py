"""Jitted public wrapper for the aggregation-core kernel.

Pads the feature dim to the block-feature (``bf``) multiple and exposes a
``backend`` switch: ``pallas`` (interpret-mode on CPU, compiled on TPU) or
``jnp`` (the oracle — used on the distributed hot path where XLA's own fusion
is preferable on a host backend).

``bf`` resolves like the fused kernel's (DESIGN.md §11): an explicit value
wins, else a ``TunedKernels`` bundle passed via ``tuned=`` (threaded from
``GNNConfig.tuned`` by the distributed layer), else the process-wide tuning
registry, else 128. All candidates are bit-identical — ``bf`` only re-tiles
the feature axis; the S-axis accumulation order is unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .csr_aggregate import csr_aggregate as _pallas_aggregate
from .ref import csr_aggregate_ref

DEFAULT_BF = 128


def _validate_bf(bf) -> None:
    """An explicit ``bf=0`` is a caller bug, not a default request — the
    falsy-``or`` resolution this replaces silently substituted DEFAULT_BF."""
    if bf is not None and int(bf) < 1:
        raise ValueError(f"bf must be a positive feature block size, got "
                         f"{bf!r} (pass None to resolve tuned/default)")


def _resolve_bf(x, neighbors, bf, tuned) -> int:
    if bf is not None:
        return int(bf)
    from repro.tuning.registry import lookup as _registry_lookup
    from repro.tuning.space import AggregateGeometry
    geom = AggregateGeometry(nd=int(neighbors.shape[0]), n=int(x.shape[0]),
                             f=int(x.shape[1]),
                             sample=int(neighbors.shape[1]))
    cfg = tuned.lookup(geom.key()) if tuned is not None else None
    if cfg is None:
        cfg = _registry_lookup(geom.key())
    return int(cfg.bf) if cfg is not None else DEFAULT_BF


@functools.partial(jax.jit, static_argnames=("backend", "bf", "interpret"))
def _aggregate(x: jax.Array, neighbors: jax.Array, weights: jax.Array,
               backend: str, bf: int,
               interpret: bool | None) -> jax.Array:
    if backend == "jnp":
        return csr_aggregate_ref(x, neighbors, weights)
    assert backend == "pallas", backend
    n, f = x.shape
    pad = (-f) % bf
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    out = _pallas_aggregate(x, neighbors, weights, bf=bf, interpret=interpret)
    return out[:, :f]


def aggregate(x: jax.Array, neighbors: jax.Array, weights: jax.Array,
              backend: str = "jnp", bf: int | None = None,
              tuned=None, interpret: bool | None = None) -> jax.Array:
    """Weighted neighbor aggregation Z = sum_s w[:, s] * X[nbr[:, s]].

    ``bf=None`` resolves the feature block size from ``tuned`` (a
    ``repro.tuning.TunedKernels``), then the registry, then 128 — shape
    resolution is eager (outside jit) so the block size is a static arg of
    the underlying kernel launch."""
    _validate_bf(bf)
    if backend == "pallas":
        bf = _resolve_bf(x, neighbors, bf, tuned)
    else:
        bf = DEFAULT_BF if bf is None else int(bf)
    return _aggregate(x, neighbors, weights, backend, bf, interpret)
