"""Jitted public wrappers for the fused GNN-layer kernel.

``fused_gnn_layer`` pads to block multiples, handles the bit-accurate path's
global DAC-scale dependency (the one piece of the composed pipeline that
cannot live inside a block-local kernel: the DAC scale is a full-tensor max
over Z), and dispatches to the right kernel:

  * ideal numerics     — one fused kernel launch; Z never touches HBM.
  * bit-accurate       — a scale pass (``fused_zmax``, writes [Nd, 2] scalars
    instead of the [Nd, F] Z block) followed by the fused quantized kernel.
    Both passes keep Z in VMEM; HBM traffic for Z drops from 4 full
    materializations (write + quantize-max read + pos/neg DAC reads) to
    2*Nd floats.

``fused_gnn_forward`` is the multi-layer driver (the full-graph network),
``fused_gnn_forward_batched`` maps it over a leading cluster/device axis —
the building block the decentralized/semi serving paths use per device.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.crossbar_mvm.ref import (CrossbarNumerics,
                                            apply_conductance_noise,
                                            quantize_weights)
from repro.mapper.tiling import padded_grid
from repro.tuning import registry as _tuning_registry
from repro.tuning.space import FusedGeometry

from .fused_layer import fused_ideal_layer, fused_quant_layer, fused_zmax


def _pad_cols(a: jax.Array, to: int) -> jax.Array:
    pad = to - a.shape[-1]
    return jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, pad)]) if pad else a


def _pad_rows(a: jax.Array, to: int) -> jax.Array:
    pad = to - a.shape[0]
    return jnp.pad(a, [(0, pad)] + [(0, 0)] * (a.ndim - 1)) if pad else a


def _resolve_bf(x, neighbors, w, cfg, bf, tuned):
    """Lane block for this launch: explicit ``bf`` wins, else the tuned
    bundle, else the process tuning registry, else the 128 default.
    Resolution is eager (outside the jitted impl); callers inside an outer
    jit thread ``tuned`` so the decision is part of the jit key."""
    if bf is not None:
        return bf
    geom = FusedGeometry(nd=neighbors.shape[0], n=x.shape[0],
                         f_in=x.shape[1], f_out=w.shape[1],
                         sample=neighbors.shape[1], ideal=cfg.ideal,
                         rows_per_xbar=cfg.rows_per_xbar)
    c = ((tuned.lookup(geom.key()) if tuned is not None else None)
         or _tuning_registry.lookup(geom.key()))
    return c.bf if c else 128


def fused_gnn_layer(x: jax.Array, neighbors: jax.Array, weights: jax.Array,
                    w: jax.Array, b: jax.Array,
                    cfg: CrossbarNumerics = CrossbarNumerics(ideal=True),
                    *, relu: bool = False, bf: int | None = None,
                    tuned=None, interpret: bool | None = None,
                    w_noise: jax.Array | None = None) -> jax.Array:
    """act((A_hat @ X) @ W + b) with Z resident in VMEM throughout.

    x: [N, F]; neighbors: [Nd, S] int32; weights: [Nd, S]; w: [F, H]; b: [H].
    Matches ``ref.fused_layer_ref`` (the composed csr_aggregate +
    crossbar_mvm path) for both ideal and bit-accurate ``cfg``. ``bf``
    left at ``None`` resolves through the tuned bundle / registry
    (``repro.tuning``); padding is zeros either way, so outputs are
    bit-identical across bf choices. ``w_noise``: optional [F, H]
    conductance-code perturbation on the programmed weights
    (``devices.variation``) — ignored on the ideal path.
    """
    bf = _resolve_bf(x, neighbors, w, cfg, bf, tuned)
    return _fused_gnn_layer(x, neighbors, weights, w, b, cfg, relu=relu,
                            bf=bf, interpret=interpret, w_noise=w_noise)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "relu", "bf", "interpret"))
def _fused_gnn_layer(x: jax.Array, neighbors: jax.Array, weights: jax.Array,
                     w: jax.Array, b: jax.Array,
                     cfg: CrossbarNumerics,
                     *, relu: bool, bf: int,
                     interpret: bool | None,
                     w_noise: jax.Array | None = None) -> jax.Array:
    n, f = x.shape
    f2, h = w.shape
    assert f == f2, (x.shape, w.shape)
    # the mapper emits the padded tile grid for either numerics path: K
    # tiled into physical rows_per_xbar crossbars (bit-accurate) or into
    # bf-lane MXU blocks (ideal), H lane-aligned to bf — arbitrary F/H map.
    grid = padded_grid(n, f, h, bf if cfg.ideal else cfg.rows_per_xbar,
                       bm=1, bn=bf)
    if cfg.ideal:
        xp = _pad_cols(x, grid.k_pad)
        wp = _pad_cols(_pad_rows(w, grid.k_pad), grid.n_pad)
        bp = _pad_cols(b[None], grid.n_pad)[0]
        out = fused_ideal_layer(xp, neighbors, weights, wp, bp,
                                relu=relu, interpret=interpret)
        return out[:, :h]
    xp = _pad_cols(x, grid.k_pad)
    zmax = fused_zmax(xp, neighbors, weights, interpret=interpret)
    # global DAC scales of max(Z,0) / max(-Z,0) — identical to
    # quantize_inputs() on the materialized Z of the composed path
    scale_pos = jnp.maximum(jnp.max(zmax[:, 0]), 1e-8) / cfg.in_levels
    scale_neg = jnp.maximum(jnp.max(zmax[:, 1]), 1e-8) / cfg.in_levels
    wq, w_scale = quantize_weights(w, cfg)
    wq = apply_conductance_noise(wq, w_noise, cfg)
    wqp = _pad_cols(_pad_rows(wq, grid.k_pad), grid.n_pad)
    bp = _pad_cols(b[None], grid.n_pad)[0]
    scales = jnp.stack([scale_pos, scale_neg, w_scale])
    out = fused_quant_layer(xp, neighbors, weights, wqp, bp, scales, cfg,
                            relu=relu, interpret=interpret)
    return out[:, :h]


@functools.partial(jax.jit,
                   static_argnames=("cfg", "final_activation", "bf",
                                    "interpret"))
def fused_gnn_forward(params: list, x: jax.Array, neighbors: jax.Array,
                      weights: jax.Array,
                      cfg: CrossbarNumerics = CrossbarNumerics(ideal=True),
                      *, final_activation: bool = False, bf: int = 128,
                      interpret: bool | None = None) -> jax.Array:
    """Multi-layer fused driver: the full-graph GNN forward, one fused
    kernel launch per layer (plus the scale pass on the bit-accurate path).

    params: [{'w': [F_i, F_i+1], 'b': [F_i+1]}, ...]; x: [N, F_0];
    neighbors/weights: [N, S]. Semantics match ``repro.core.gnn.forward``.
    """
    h = x
    n_layers = len(params)
    for i, layer in enumerate(params):
        relu = i < n_layers - 1 or final_activation
        h = fused_gnn_layer(h, neighbors, weights, layer["w"], layer["b"],
                            cfg, relu=relu, bf=bf, interpret=interpret)
    return h


@functools.partial(jax.jit,
                   static_argnames=("cfg", "final_activation", "bf",
                                    "interpret"))
def fused_gnn_forward_batched(params: list, x: jax.Array,
                              neighbors: jax.Array, weights: jax.Array,
                              cfg: CrossbarNumerics = CrossbarNumerics(
                                  ideal=True),
                              *, final_activation: bool = False,
                              bf: int = 128,
                              interpret: bool | None = None) -> jax.Array:
    """Batched multi-layer driver over a leading cluster/device axis.

    x: [K, N, F]; neighbors/weights: [K, N, S]. Each cluster runs the fused
    multi-layer forward on its own subgraph (static unroll — K is the
    partition fan-out, small by construction). Returns [K, N, out_dim].
    """
    outs = [fused_gnn_forward(params, x[k], neighbors[k], weights[k], cfg,
                              final_activation=final_activation, bf=bf,
                              interpret=interpret)
            for k in range(x.shape[0])]
    return jnp.stack(outs)
