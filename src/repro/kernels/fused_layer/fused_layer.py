"""Fused GNN-layer Pallas kernels: gather-reduce + crossbar MVM in one pass.

The paper's per-layer dataflow (Fig. 1) is two back-to-back in-memory stages:
aggregation ``Z = A_hat @ X`` on the traversal/aggregation cores feeding
feature extraction ``H = act(Z @ W + b)`` on the MVM crossbar core — the
intermediate ``Z`` never leaves the accelerator. The composed TPU path
(``csr_aggregate`` then ``crossbar_mvm``) loses exactly that property: ``Z``
makes a full HBM round-trip between the two kernels. Here both stages share
one grid step, so the destination node's accumulator row is handed to the
MXU matmul while still resident in VMEM (DESIGN.md §5):

  grid (node i, sample s):
    s == 0     : z_acc[1, F]  = 0                  (VMEM scratch)
    every s    : z_acc       += w[i,s] * X[nbr[i,s]]   (scalar-prefetch gather)
    s == S - 1 : out[i]       = act(z_acc @ W + b)     (MXU, Z stays in VMEM)

Three kernels share the gather loop:

  * ``_fused_ideal_kernel``  — float32 feature extraction (ideal numerics).
  * ``_fused_zmax_kernel``   — emits only per-node (max(z,0), max(-z,0));
    the bit-accurate path needs the *global* DAC scale of Z before it can
    quantize, and this pass provides it without materializing Z in HBM
    (output is [Nd, 2] scalars, an F/2-fold traffic reduction vs writing Z).
  * ``_fused_quant_kernel``  — DAC-quantizes the VMEM-resident z row with the
    prefetched scales and runs the bit-serial crossbar MVM (per-K-tile ADC +
    shift-&-add, pos/neg DAC passes) exactly as ``crossbar_mvm`` does.

Weight matrices of GNN layers are small (F x H, both <= a few 1000), so W is
held fully resident in VMEM across the whole grid rather than K-tiled by
BlockSpec; K-tiling for the per-crossbar ADC happens *inside* the kernel on
the VMEM-resident block, which keeps the reduction-tree position of the ADC
identical to the standalone ``crossbar_mvm`` kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._interpret import resolve_interpret
from repro.kernels.crossbar_mvm.ref import CrossbarNumerics


def _fused_ideal_kernel(nbr_ref, wts_ref, x_ref, w_ref, b_ref, out_ref,
                        z_ref, *, n_s: int, relu: bool):
    i = pl.program_id(0)
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        z_ref[...] = jnp.zeros_like(z_ref)

    w_edge = wts_ref[i, s]                  # scalar edge weight (SMEM)
    z_ref[...] += w_edge * x_ref[...].astype(jnp.float32)

    @pl.when(s == n_s - 1)
    def _transform():
        h = jnp.dot(z_ref[...], w_ref[...],
                    preferred_element_type=jnp.float32) + b_ref[...]
        out_ref[...] = jnp.maximum(h, 0.0) if relu else h


def _fused_zmax_kernel(nbr_ref, wts_ref, x_ref, out_ref, z_ref, *, n_s: int):
    i = pl.program_id(0)
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        z_ref[...] = jnp.zeros_like(z_ref)

    w_edge = wts_ref[i, s]
    z_ref[...] += w_edge * x_ref[...].astype(jnp.float32)

    @pl.when(s == n_s - 1)
    def _reduce():
        z = z_ref[...]
        out_ref[0, 0] = jnp.max(jnp.maximum(z, 0.0))
        out_ref[0, 1] = jnp.max(jnp.maximum(-z, 0.0))


def _bit_serial_mvm(codes, wq_ref, cfg: CrossbarNumerics, n_k: int):
    """Bit-serial crossbar MVM of one [1, n_k * r] code row against the VMEM-
    resident conductance matrix, ADC per (bit-plane, K-tile) partial sum and
    digital shift-&-add — the same reduction tree as ``crossbar_mvm``."""
    r = cfg.rows_per_xbar
    full_scale = float(r * cfg.w_levels)
    lsb = full_scale / (2 ** cfg.adc_bits - 1)
    acc = jnp.zeros((1, wq_ref.shape[1]), jnp.float32)
    for t in range(n_k):                    # physical crossbars along K
        wq_t = wq_ref[t * r:(t + 1) * r, :]
        codes_t = codes[:, t * r:(t + 1) * r]
        for b in range(cfg.in_bits):        # bit-serial DAC cycles
            plane = ((codes_t >> b) & 1).astype(jnp.float32)
            partial = jnp.dot(plane, wq_t,
                              preferred_element_type=jnp.float32)
            partial = jnp.round(
                jnp.clip(partial, -full_scale, full_scale) / lsb) * lsb
            acc = acc + partial * (2.0 ** b)
    return acc


def _fused_quant_kernel(nbr_ref, wts_ref, scales_ref, x_ref, wq_ref, b_ref,
                        out_ref, z_ref, *, cfg: CrossbarNumerics, n_s: int,
                        n_k: int, relu: bool):
    i = pl.program_id(0)
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        z_ref[...] = jnp.zeros_like(z_ref)

    w_edge = wts_ref[i, s]
    z_ref[...] += w_edge * x_ref[...].astype(jnp.float32)

    @pl.when(s == n_s - 1)
    def _transform():
        z = z_ref[...]
        # signed activations: two DAC passes (pos / neg), digital recombine
        scale_pos = scales_ref[0]           # DAC scale of max(Z, 0)
        scale_neg = scales_ref[1]           # DAC scale of max(-Z, 0)
        w_scale = scales_ref[2]             # conductance de-quantization
        acc = jnp.zeros((1, out_ref.shape[1]), jnp.float32)
        for sign, scale in ((1.0, scale_pos), (-1.0, scale_neg)):
            part = jnp.maximum(sign * z, 0.0)
            codes = jnp.clip(jnp.round(part / scale),
                             0, cfg.in_levels).astype(jnp.int32)
            acc += sign * scale * _bit_serial_mvm(codes, wq_ref, cfg, n_k)
        h = acc * w_scale + b_ref[...]
        out_ref[...] = jnp.maximum(h, 0.0) if relu else h


def _gather_spec(bf: int):
    # one neighbor feature row, steered by the prefetched index table
    return pl.BlockSpec((1, bf), lambda i, s, *prefetch: (prefetch[0][i, s], 0))


@functools.partial(jax.jit,
                   static_argnames=("relu", "interpret"))
def fused_ideal_layer(x: jax.Array, neighbors: jax.Array, weights: jax.Array,
                      w: jax.Array, b: jax.Array, *, relu: bool = False,
                      interpret: bool | None = None) -> jax.Array:
    """act((A_hat @ X) @ W + b) in one kernel, ideal float numerics.

    x: [N, F]; neighbors/weights: [Nd, S]; w: [F, H]; b: [H].
    Returns [Nd, H] float32. Z never touches HBM.
    """
    interpret = resolve_interpret(interpret)
    n, f = x.shape
    nd, n_s = neighbors.shape
    f2, h = w.shape
    assert f == f2, (x.shape, w.shape)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,              # neighbors, weights
        grid=(nd, n_s),
        in_specs=[
            _gather_spec(f),
            pl.BlockSpec((f, h), lambda i, s, *_: (0, 0)),    # W resident
            pl.BlockSpec((1, h), lambda i, s, *_: (0, 0)),    # bias
        ],
        out_specs=pl.BlockSpec((1, h), lambda i, s, *_: (i, 0)),
        scratch_shapes=[pltpu.VMEM((1, f), jnp.float32)],     # z row
    )
    return pl.pallas_call(
        functools.partial(_fused_ideal_kernel, n_s=n_s, relu=relu),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nd, h), jnp.float32),
        interpret=interpret,
    )(neighbors, weights.astype(jnp.float32), x,
      w.astype(jnp.float32), b.astype(jnp.float32).reshape(1, h))


@functools.partial(jax.jit, static_argnames="interpret")
def fused_zmax(x: jax.Array, neighbors: jax.Array, weights: jax.Array,
               *, interpret: bool | None = None) -> jax.Array:
    """Per-node (max(z, 0), max(-z, 0)) of Z = A_hat @ X, Z kept in VMEM.

    Returns [Nd, 2] float32 — the scale pass of the bit-accurate fused layer
    (HBM write volume Nd*2 instead of Nd*F).
    """
    interpret = resolve_interpret(interpret)
    n, f = x.shape
    nd, n_s = neighbors.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nd, n_s),
        in_specs=[_gather_spec(f)],
        out_specs=pl.BlockSpec((1, 2), lambda i, s, *_: (i, 0)),
        scratch_shapes=[pltpu.VMEM((1, f), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_fused_zmax_kernel, n_s=n_s),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nd, 2), jnp.float32),
        interpret=interpret,
    )(neighbors, weights.astype(jnp.float32), x)


@functools.partial(jax.jit, static_argnames=("cfg", "relu", "interpret"))
def fused_quant_layer(x: jax.Array, neighbors: jax.Array, weights: jax.Array,
                      wq: jax.Array, b: jax.Array, scales: jax.Array,
                      cfg: CrossbarNumerics, *, relu: bool = False,
                      interpret: bool | None = None) -> jax.Array:
    """Bit-accurate fused layer on pre-quantized conductances.

    x: [N, F] with F == n_k * cfg.rows_per_xbar (caller pads);
    wq: [F, H] signed conductance codes; b: [H] float bias;
    scales: [3] = (dac_scale_pos, dac_scale_neg, w_scale).
    Returns [Nd, H] float32 == act(crossbar_matmul_signed(Z, W) + b).
    """
    interpret = resolve_interpret(interpret)
    n, f = x.shape
    nd, n_s = neighbors.shape
    f2, h = wq.shape
    assert f == f2 and f % cfg.rows_per_xbar == 0, (x.shape, wq.shape, cfg)
    n_k = f // cfg.rows_per_xbar
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,              # neighbors, weights, scales
        grid=(nd, n_s),
        in_specs=[
            _gather_spec(f),
            pl.BlockSpec((f, h), lambda i, s, *_: (0, 0)),    # Wq resident
            pl.BlockSpec((1, h), lambda i, s, *_: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h), lambda i, s, *_: (i, 0)),
        scratch_shapes=[pltpu.VMEM((1, f), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_fused_quant_kernel, cfg=cfg, n_s=n_s, n_k=n_k,
                          relu=relu),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nd, h), jnp.float32),
        interpret=interpret,
    )(neighbors, weights.astype(jnp.float32), scales.astype(jnp.float32),
      x, wq.astype(jnp.float32), b.astype(jnp.float32).reshape(1, h))
