from .fused_layer import fused_ideal_layer, fused_quant_layer, fused_zmax
from .ops import (fused_gnn_forward, fused_gnn_forward_batched,
                  fused_gnn_layer)
from .ref import fused_layer_ref

__all__ = [
    "fused_ideal_layer", "fused_quant_layer", "fused_zmax",
    "fused_gnn_layer", "fused_gnn_forward", "fused_gnn_forward_batched",
    "fused_layer_ref",
]
