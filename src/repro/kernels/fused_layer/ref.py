"""Composed-path oracle for the fused GNN layer.

The fused kernel must match ``csr_aggregate`` -> ``crossbar_mvm`` run
back-to-back (the two-kernel path with the HBM round-trip of Z); this module
is that composition expressed through the existing oracles, so the fused
kernel, the composed Pallas kernels, and the jnp references all agree on one
definition of a GNN layer:

    fused_layer_ref(x, nbr, wts, W, b) = act(agg(x, nbr, wts) @ W + b)
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.crossbar_mvm.ref import (CrossbarNumerics,
                                            crossbar_matmul_signed_ref)
from repro.kernels.csr_aggregate.ref import csr_aggregate_ref


@partial(jax.jit, static_argnames=("cfg", "relu"))
def fused_layer_ref(x: jax.Array, neighbors: jax.Array, weights: jax.Array,
                    w: jax.Array, b: jax.Array,
                    cfg: CrossbarNumerics = CrossbarNumerics(ideal=True),
                    relu: bool = False) -> jax.Array:
    """One GNN layer through the composed two-stage path (the HBM-round-trip
    reference the fused kernel is checked against)."""
    z = csr_aggregate_ref(x, neighbors, weights)
    if cfg.ideal:
        h = jnp.dot(z, w.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
    else:
        h = crossbar_matmul_signed_ref(z, w, cfg)
    h = h + b.astype(jnp.float32)
    return jnp.maximum(h, 0.0) if relu else h
