"""Jitted public wrapper for the traversal-core search CAM."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .cam_match import cam_search as _pallas_search
from .ref import cam_search_ref, cam_scan_ref


@functools.partial(jax.jit, static_argnames=("backend", "bq", "be", "interpret"))
def search(ci: jax.Array, queries: jax.Array, backend: str = "jnp",
           bq: int = 8, be: int = 128, interpret: bool | None = None):
    """Match queries against the CSR column-index array.

    Returns (match [Q, E] int8, counts [Q] int32). Pads E/Q internally; pad
    edges use sentinel -1 (never a valid node id) so they can't match.
    """
    if backend == "jnp":
        return cam_search_ref(ci, queries)
    assert backend == "pallas", backend
    e, = ci.shape
    q, = queries.shape
    pe, pq = (-e) % be, (-q) % bq
    ci_p = jnp.pad(ci, (0, pe), constant_values=-1)
    q_p = jnp.pad(queries, (0, pq), constant_values=-2)
    match, counts = _pallas_search(ci_p, q_p, bq=bq, be=be,
                                   interpret=interpret)
    return match[:q, :e], counts[:q, 0]


scan = cam_scan_ref  # RP scan is a searchsorted — pure jnp on all backends
