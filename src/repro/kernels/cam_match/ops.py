"""Jitted public wrapper for the traversal-core search CAM.

``bq``/``be`` (query/entry block) resolve like the other kernels' block
params (DESIGN.md §11): an explicit value wins, else a ``TunedKernels``
bundle passed via ``tuned=``, else the process-wide tuning registry, else
the hand-picked 8/128. Every candidate is bit-identical — the blocks only
re-tile independent equality compares, and pad edges use non-matching
sentinels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .cam_match import cam_search as _pallas_search
from .ref import cam_search_ref, cam_scan_ref

DEFAULT_BQ = 8
DEFAULT_BE = 128


def _validate_blocks(bq, be) -> None:
    """Explicit blocks must be positive — ``bq=0`` is a caller bug, not a
    request for the default (the falsy-``or`` resolution this replaces
    silently substituted DEFAULT_BQ)."""
    for name, val in (("bq", bq), ("be", be)):
        if val is not None and int(val) < 1:
            raise ValueError(f"{name} must be a positive block size, got "
                             f"{val!r} (pass None to resolve tuned/default)")


def _resolve_blocks(ci, queries, bq, be, tuned) -> tuple:
    if bq is not None and be is not None:
        return int(bq), int(be)
    from repro.tuning.registry import lookup as _registry_lookup
    from repro.tuning.space import CamGeometry
    geom = CamGeometry(e=int(ci.shape[0]), q=int(queries.shape[0]))
    cfg = tuned.lookup(geom.key()) if tuned is not None else None
    if cfg is None:
        cfg = _registry_lookup(geom.key())
    return (int(bq if bq is not None
                else (cfg.bq if cfg is not None else DEFAULT_BQ)),
            int(be if be is not None
                else (cfg.be if cfg is not None else DEFAULT_BE)))


@functools.partial(jax.jit,
                   static_argnames=("backend", "bq", "be", "interpret"))
def _search(ci: jax.Array, queries: jax.Array, backend: str,
            bq: int, be: int, interpret: bool | None):
    if backend == "jnp":
        return cam_search_ref(ci, queries)
    assert backend == "pallas", backend
    e, = ci.shape
    q, = queries.shape
    pe, pq = (-e) % be, (-q) % bq
    ci_p = jnp.pad(ci, (0, pe), constant_values=-1)
    q_p = jnp.pad(queries, (0, pq), constant_values=-2)
    match, counts = _pallas_search(ci_p, q_p, bq=bq, be=be,
                                   interpret=interpret)
    # negative queries match nothing (ref.cam_search_ref's contract): the
    # raw equality kernel would let a -1 query activate every -1 pad slot
    valid = (queries >= 0)
    match = match[:q, :e] * valid[:, None].astype(jnp.int8)
    return match, counts[:q, 0] * valid.astype(jnp.int32)


def search(ci: jax.Array, queries: jax.Array, backend: str = "jnp",
           bq: int | None = None, be: int | None = None, tuned=None,
           interpret: bool | None = None):
    """Match queries against the CSR column-index array.

    Returns (match [Q, E] int8, counts [Q] int32). Pads E/Q internally;
    pad *edge* slots use sentinel -1 and pad *query* slots -2, and
    negative query ids match nothing by contract on both backends (valid
    node ids are non-negative, so a -1 query — a plausible upstream
    invalid-slot encoding — returns an all-zero row and count 0 instead
    of activating every pad slot). Block resolution is eager (outside
    jit) so the blocks are static args of the underlying kernel launch;
    an explicit non-positive block raises (it is not a default request).
    """
    _validate_blocks(bq, be)
    if backend == "pallas":
        bq, be = _resolve_blocks(ci, queries, bq, be, tuned)
    else:
        bq = DEFAULT_BQ if bq is None else int(bq)
        be = DEFAULT_BE if be is None else int(be)
    return _search(ci, queries, backend, bq, be, interpret)


scan = cam_scan_ref  # RP scan is a searchsorted — pure jnp on all backends
