"""Pure-jnp oracle for the traversal core's CAM search (IMA-GNN Fig. 3(c)-(d)).

Search CAM: each query (destination node id) is matched against the CSR
column-index array; matching rows activate. Scan CAM then resolves the source
nodes via the row-pointer array. On the oracle side this is a broadcast
equality compare plus a popcount, and a searchsorted over RP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cam_search_ref(ci: jax.Array, queries: jax.Array):
    """ci: [E] int32 CSR column indices; queries: [Q] int32 node ids.

    Returns (match [Q, E] int8, counts [Q] int32) — the match-line bitmap of
    the search CAM and the per-query activation count. Negative query ids
    (plausible upstream invalid-slot encodings) match nothing: valid node
    ids are non-negative, and a -1 query must not activate -1 pad slots.
    """
    match = (ci[None, :] == queries[:, None]) & (queries >= 0)[:, None]
    return match.astype(jnp.int8), match.sum(axis=1).astype(jnp.int32)


def cam_scan_ref(rp: jax.Array, positions: jax.Array) -> jax.Array:
    """Scan/compare: map flat edge positions to their source row via RP.

    rp: [N+1] int32 row pointers; positions: [P] int32 edge positions.
    Returns [P] int32 source node ids (the row whose [rp[r], rp[r+1]) range
    contains the position) — the compare-CAM's increasing-reference trick.
    """
    return (jnp.searchsorted(rp, positions, side="right") - 1).astype(jnp.int32)
