"""Pallas TPU kernel for the traversal core's search CAM (IMA-GNN Fig. 2(c)).

TPU adaptation: the TCAM's one-shot analog XNOR match across all rows becomes
a blocked vectorized equality compare — each grid step matches a (bq,) query
block against a (be,) edge block held in VMEM (8x128 VPU lanes replace the
match lines; the MLSA read-out becomes an int8 bitmap + per-block popcount).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._interpret import resolve_interpret


def _kernel(ci_ref, q_ref, match_ref, count_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        count_ref[...] = jnp.zeros_like(count_ref)

    ci = ci_ref[...]                       # [1, be]
    q = q_ref[...]                         # [bq, 1]
    m = (ci == q)                          # [bq, be] broadcast XNOR match
    match_ref[...] = m.astype(jnp.int8)
    count_ref[...] += m.sum(axis=1, keepdims=True).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bq", "be", "interpret"))
def cam_search(ci: jax.Array, queries: jax.Array, bq: int = 8, be: int = 128,
               interpret: bool | None = None):
    """ci: [E] int32 (E % be == 0); queries: [Q] int32 (Q % bq == 0).

    Returns (match [Q, E] int8, counts [Q, 1] int32).
    """
    interpret = resolve_interpret(interpret)
    e, = ci.shape
    q, = queries.shape
    for dim, size, mult in (("E", e, be), ("Q", q, bq)):
        if size % mult:
            raise ValueError(
                f"cam_search needs {dim} divisible by "
                f"{'be' if dim == 'E' else 'bq'}={mult} (one "
                f"{'entry' if dim == 'E' else 'query'} block per grid "
                f"step), got {dim}={size}. Use "
                f"repro.kernels.cam_match.search — the ops layer pads "
                f"E/Q to the block multiples with non-matching sentinels "
                f"for arbitrary shapes.")
    grid = (q // bq, e // be)
    match, counts = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, be), lambda i, j: (0, j)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, be), lambda i, j: (i, j)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, e), jnp.int8),
            jax.ShapeDtypeStruct((q, 1), jnp.int32),
        ],
        interpret=interpret,
    )(ci.reshape(1, e), queries.reshape(q, 1))
    return match, counts
