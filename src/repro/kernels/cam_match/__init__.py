from .ref import cam_search_ref, cam_scan_ref
from .ops import search, scan

__all__ = ["cam_search_ref", "cam_scan_ref", "search", "scan"]
