"""Pallas TPU kernel for the bit-serial RRAM crossbar MVM (IMA-GNN Fig. 2(b)).

TPU adaptation of the paper's analog MVM crossbar: one grid step owns one
(M-tile, N-tile, K-tile) block where the K tile is exactly one physical
crossbar's ``rows_per_xbar`` (so the ADC is applied at the same point in the
reduction tree as the hardware applies it). Bit-planes of the DAC-quantized
input are streamed through the MXU; ADC clipping/quantization and the
shift-&-add recombination run on the VPU; cross-crossbar (K-tile) accumulation
is digital via output-block revisiting.

Block shapes are MXU/VPU aligned: (bm, bk) x (bk, bn) with bk = rows_per_xbar
(a multiple of 128 on real configs) and bn a multiple of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._interpret import resolve_interpret
from .ref import CrossbarNumerics


def _kernel(xq_ref, wq_ref, out_ref, *, in_bits: int, adc_bits: int,
            rows_per_xbar: int, w_levels: int, depth: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    r = rows_per_xbar
    full_scale = float(r * w_levels)
    lsb = full_scale / (2 ** adc_bits - 1)

    # ``depth`` physical crossbars per grid step (tuner pipeline-depth
    # knob): each owns one rows_per_xbar K-slice of the VMEM-resident
    # block, keeping the ADC at the same reduction-tree position — and the
    # digital cross-crossbar accumulation in the same order — as depth=1,
    # so outputs are bit-identical at any depth.
    for t in range(depth):
        xq = xq_ref[:, t * r:(t + 1) * r]   # [bm, r] uint32 DAC codes
        wq = wq_ref[t * r:(t + 1) * r, :]   # [r, bn] f32 conductance codes
        acc = jnp.zeros(out_ref.shape, jnp.float32)
        for b in range(in_bits):            # bit-serial DAC cycles
            plane = ((xq >> b) & 1).astype(jnp.float32)
            partial = jnp.dot(plane, wq, preferred_element_type=jnp.float32)
            # ADC: clip to full scale, uniform quantize (mid-tread)
            partial = jnp.round(
                jnp.clip(partial, -full_scale, full_scale) / lsb) * lsb
            acc = acc + partial * (2.0 ** b)  # shift & add
        out_ref[...] += acc


@functools.partial(jax.jit,
                   static_argnames=("cfg", "bm", "bn", "depth", "interpret"))
def crossbar_matmul_quantized(xq: jax.Array, wq: jax.Array,
                              cfg: CrossbarNumerics,
                              bm: int = 128, bn: int = 128, depth: int = 1,
                              interpret: bool | None = None) -> jax.Array:
    """Bit-serial crossbar matmul on pre-quantized codes.

    xq: [M, K] uint32 input DAC codes (values < 2**in_bits)
    wq: [K, N] float32 signed conductance codes
    K must be a multiple of cfg.rows_per_xbar; M of bm; N of bn.
    ``depth`` (tuner knob) gives each grid step ``depth`` physical
    crossbars along K (``depth`` must divide K / rows_per_xbar); outputs
    are bit-identical at any depth.
    Returns the *integer-domain* accumulation [M, N] f32 (caller rescales).
    """
    interpret = resolve_interpret(interpret)
    m, k = xq.shape
    k2, n = wq.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: xq K={k} vs wq K={k2}")
    for dim, size, mult in (("M", m, bm), ("K", k, cfg.rows_per_xbar),
                            ("N", n, bn)):
        if size % mult:
            raise ValueError(
                f"crossbar_matmul_quantized needs {dim} divisible by "
                f"{mult} (one {'physical crossbar' if dim == 'K' else 'MXU block'}"
                f" per grid step), got {dim}={size}. Pad to the grid from "
                f"repro.mapper.tiling.padded_grid(M, K, N, rows_per_xbar, "
                f"bm, bn) — the ops-layer crossbar_matmul does this for "
                f"arbitrary shapes.")
    if depth < 1 or (k // cfg.rows_per_xbar) % depth:
        raise ValueError(
            f"pipeline depth {depth} must divide the physical crossbar "
            f"count K/rows_per_xbar = {k // cfg.rows_per_xbar} "
            f"(repro.tuning only proposes legal depths)")
    bk = depth * cfg.rows_per_xbar
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(
            _kernel, in_bits=cfg.in_bits, adc_bits=cfg.adc_bits,
            rows_per_xbar=cfg.rows_per_xbar, w_levels=cfg.w_levels,
            depth=depth),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(xq, wq)
