"""Pure-jnp oracle for the RRAM crossbar MVM numerics (IMA-GNN Fig. 2(b)).

Models the analog dataflow of the paper's aggregation / feature-extraction
cores on a digital substrate, bit-exactly:

  1. DAC      — unsigned uniform quantization of the input activations to
                ``in_bits`` and bit-serial application (one bit-plane per cycle).
  2. crossbar — weights quantized symmetrically to ``w_bits`` and stored as a
                positive and a negative conductance column (1T1R pair); the
                analog dot-product along a source line is an integer matmul of
                a bit-plane against the conductance matrix.
  3. ADC      — each source-line partial sum is sampled by an ADC with
                ``adc_bits`` of resolution over the full-scale range of one
                ``rows_per_xbar`` tile; values are clipped + uniformly
                quantized (this is where analog error enters).
  4. Shift&Add — bit-plane partials are recombined digitally; crossbar row
                tiles (the K dimension split across physical crossbars) are
                accumulated digitally *after* the ADC, as in the paper.

The oracle is intentionally simple jnp so it can double as a reference for
both the Pallas kernel and the behavioural cost model in ``repro.core``.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CrossbarNumerics:
    """Numeric configuration of one resistive MVM crossbar fabric."""

    in_bits: int = 8          # DAC resolution (input bit-serial width)
    w_bits: int = 8           # conductance levels per device pair (signed)
    adc_bits: int = 8         # ADC resolution per source line read-out
    rows_per_xbar: int = 512  # physical rows — K-dim tile accumulated post-ADC
    ideal: bool = False       # True: skip quantization entirely (float matmul)

    @property
    def w_levels(self) -> int:
        return 2 ** (self.w_bits - 1) - 1

    @property
    def in_levels(self) -> int:
        return 2 ** self.in_bits - 1


def quantize_inputs(x: jax.Array, cfg: CrossbarNumerics):
    """DAC input quantization: unsigned uniform over [0, max|x|].

    Returns (codes uint32 [.., K], scale f32 scalar). Negative inputs are
    clipped — the paper's cores operate post-ReLU; callers that need signed
    activations split sign digitally (see ``crossbar_matmul_signed``).
    """
    x = x.astype(jnp.float32)   # quantize in f32: fusion-order independent
    x_max = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8)
    scale = x_max / cfg.in_levels
    codes = jnp.clip(jnp.round(x / scale), 0, cfg.in_levels).astype(jnp.uint32)
    return codes, scale


def quantize_weights(w: jax.Array, cfg: CrossbarNumerics):
    """Symmetric weight quantization to signed conductance codes."""
    w = w.astype(jnp.float32)   # quantize in f32: fusion-order independent
    w_max = jnp.maximum(jnp.max(jnp.abs(w)), 1e-8)
    scale = w_max / cfg.w_levels
    codes = jnp.clip(jnp.round(w / scale), -cfg.w_levels, cfg.w_levels)
    return codes.astype(jnp.float32), scale


def apply_conductance_noise(wq: jax.Array, w_noise, cfg: CrossbarNumerics):
    """Perturb programmed conductance codes by an additive noise tensor.

    ``w_noise`` is a ``[K, N]`` float32 draw in conductance-code units
    (``devices.variation.sample_conductance_noise`` — grid-quantized so
    every partial sum stays exactly representable in f32 and the three
    backends remain byte-identical). The result is clipped to the physical
    code range; ``None`` is the clean path, returned untouched. A signed
    MVM shares one draw across both DAC passes — same programmed arrays.
    """
    if w_noise is None:
        return wq
    return jnp.clip(wq + w_noise.astype(jnp.float32),
                    -cfg.w_levels, cfg.w_levels)


def _adc(partial: jax.Array, cfg: CrossbarNumerics) -> jax.Array:
    """ADC transfer function on one source-line partial sum (integer domain).

    Full-scale range = rows_per_xbar * w_levels (max conductance sum for a
    single active bit-plane). Uniform mid-tread quantization + clipping.
    """
    full_scale = cfg.rows_per_xbar * cfg.w_levels
    lsb = full_scale / (2 ** cfg.adc_bits - 1)
    return jnp.round(jnp.clip(partial, -full_scale, full_scale) / lsb) * lsb


@partial(jax.jit, static_argnames="cfg")
def crossbar_matmul_ref(x: jax.Array, w: jax.Array,
                        cfg: CrossbarNumerics = CrossbarNumerics(),
                        w_noise: jax.Array | None = None) -> jax.Array:
    """Behavioural crossbar MVM: y = x @ w through DAC/crossbar/ADC numerics.

    x: [M, K] float (expected >= 0; clipped otherwise), w: [K, N] float.
    ``w_noise``: optional [K, N] conductance-code perturbation
    (``apply_conductance_noise``) — the Monte-Carlo variation hook.
    Returns [M, N] float32.
    """
    if cfg.ideal:
        return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    xq, xs = quantize_inputs(x, cfg)
    wq, ws = quantize_weights(w, cfg)
    wq = apply_conductance_noise(wq, w_noise, cfg)

    r = cfg.rows_per_xbar
    n_tiles = -(-k // r)
    pad = n_tiles * r - k
    if pad:
        xq = jnp.pad(xq, ((0, 0), (0, pad)))
        wq = jnp.pad(wq, ((0, pad), (0, 0)))
    xq = xq.reshape(m, n_tiles, r)
    wq = wq.reshape(n_tiles, r, n)

    def one_tile(xq_t, wq_t):
        # bit-serial over input bits; ADC applied per bit-plane partial.
        acc = jnp.zeros((m, n), jnp.float32)
        for b in range(cfg.in_bits):
            plane = ((xq_t >> b) & 1).astype(jnp.float32)
            partial = jnp.dot(plane, wq_t, preferred_element_type=jnp.float32)
            acc = acc + _adc(partial, cfg) * (2.0 ** b)
        return acc

    acc = jnp.zeros((m, n), jnp.float32)
    for t in range(n_tiles):
        acc = acc + one_tile(xq[:, t, :], wq[t])   # digital cross-tile add
    return acc * (xs * ws)


@partial(jax.jit, static_argnames="cfg")
def crossbar_matmul_signed_ref(x: jax.Array, w: jax.Array,
                               cfg: CrossbarNumerics = CrossbarNumerics(),
                               w_noise: jax.Array | None = None) -> jax.Array:
    """Signed-activation variant: x is split into positive/negative parts that
    are driven in two passes and recombined digitally (2 DAC passes); one
    ``w_noise`` draw is shared by both — same programmed arrays."""
    if cfg.ideal:
        return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    pos = crossbar_matmul_ref(jnp.maximum(x, 0.0), w, cfg, w_noise)
    neg = crossbar_matmul_ref(jnp.maximum(-x, 0.0), w, cfg, w_noise)
    return pos - neg
