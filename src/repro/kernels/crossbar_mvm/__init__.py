from .ref import (CrossbarNumerics, apply_conductance_noise,
                  crossbar_matmul_ref, crossbar_matmul_signed_ref)
from .ops import crossbar_matmul, crossbar_matmul_signed

__all__ = [
    "CrossbarNumerics", "apply_conductance_noise", "crossbar_matmul_ref",
    "crossbar_matmul_signed_ref", "crossbar_matmul",
    "crossbar_matmul_signed",
]
