"""Jitted public wrapper around the crossbar MVM Pallas kernel.

Handles global DAC/weight quantization (a full-tensor max-reduction that can
not live inside a block-local kernel), padding to block multiples, and the
final de-quantization rescale, so that::

    crossbar_matmul(x, w, cfg)  ==  ref.crossbar_matmul_ref(x, w, cfg)

bit-exactly (both compute the same integer-domain math).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .crossbar_mvm import crossbar_matmul_quantized
from .ref import CrossbarNumerics, quantize_inputs, quantize_weights


def _pad_to(a: jax.Array, axis: int, mult: int) -> jax.Array:
    size = a.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@functools.partial(jax.jit, static_argnames=("cfg", "bm", "bn", "interpret"))
def crossbar_matmul(x: jax.Array, w: jax.Array,
                    cfg: CrossbarNumerics = CrossbarNumerics(),
                    bm: int = 128, bn: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """y = x @ w through the crossbar numerics, via the Pallas kernel.

    x: [M, K] float (clipped to >= 0, as in the post-ReLU cores)
    w: [K, N] float
    """
    if cfg.ideal:
        return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    m, k = x.shape
    _, n = w.shape
    xq, xs = quantize_inputs(x, cfg)
    wq, ws = quantize_weights(w, cfg)
    xq = _pad_to(_pad_to(xq, 0, bm), 1, cfg.rows_per_xbar)
    wq = _pad_to(_pad_to(wq, 0, cfg.rows_per_xbar), 1, bn)
    out = crossbar_matmul_quantized(xq, wq, cfg, bm=bm, bn=bn,
                                    interpret=interpret)
    return out[:m, :n] * (xs * ws)


@functools.partial(jax.jit, static_argnames=("cfg", "bm", "bn", "interpret"))
def crossbar_matmul_signed(x: jax.Array, w: jax.Array,
                           cfg: CrossbarNumerics = CrossbarNumerics(),
                           bm: int = 128, bn: int = 128,
                           interpret: bool | None = None) -> jax.Array:
    """Signed-activation variant (two DAC passes, digital recombine)."""
    if cfg.ideal:
        return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    pos = crossbar_matmul(jnp.maximum(x, 0.0), w, cfg, bm, bn, interpret)
    neg = crossbar_matmul(jnp.maximum(-x, 0.0), w, cfg, bm, bn, interpret)
    return pos - neg
