"""Jitted public wrapper around the crossbar MVM Pallas kernel.

Handles global DAC/weight quantization (a full-tensor max-reduction that can
not live inside a block-local kernel), padding to the mapper-emitted
(bm, bk, bn) tile grid (``repro.mapper.tiling.padded_grid`` — any M/K/N is
mappable; the kernel itself only ever sees divisible shapes), and the final
de-quantization rescale, so that::

    crossbar_matmul(x, w, cfg)  ==  ref.crossbar_matmul_ref(x, w, cfg)

bit-exactly (both compute the same integer-domain math).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.mapper.tiling import padded_grid
from repro.tuning import registry as _tuning_registry
from repro.tuning.space import CrossbarGeometry

from .crossbar_mvm import crossbar_matmul_quantized
from .ref import (CrossbarNumerics, apply_conductance_noise,
                  quantize_inputs, quantize_weights)


def _resolve_blocks(x, w, cfg, bm, bn, depth, tuned):
    """(bm, bn, depth) with ``None``s filled from the tuned-config bundle,
    then the process tuning registry, then the hand-picked defaults.

    Resolution is eager (outside the jitted impl) so a registry update
    reaches the next call instead of a stale jit trace; callers inside an
    outer jit should thread ``tuned`` (see repro.tuning)."""
    if bm is None or bn is None or depth is None:
        geom = CrossbarGeometry(m=x.shape[0], k=x.shape[1], n=w.shape[1],
                                rows_per_xbar=cfg.rows_per_xbar,
                                in_bits=cfg.in_bits)
        c = ((tuned.lookup(geom.key()) if tuned is not None else None)
             or _tuning_registry.lookup(geom.key()))
        bm = bm if bm is not None else (c.bm if c else 128)
        bn = bn if bn is not None else (c.bn if c else 128)
        depth = depth if depth is not None else (c.depth if c else 1)
    return bm, bn, depth


@functools.partial(jax.jit,
                   static_argnames=("cfg", "bm", "bn", "depth", "interpret"))
def _crossbar_matmul(x: jax.Array, w: jax.Array, cfg: CrossbarNumerics,
                     bm: int, bn: int, depth: int,
                     interpret: bool | None,
                     w_noise: jax.Array | None = None) -> jax.Array:
    m, k = x.shape
    _, n = w.shape
    grid = padded_grid(m, k, n, cfg.rows_per_xbar, bm=bm, bn=bn)
    xq, xs = quantize_inputs(x, cfg)
    wq, ws = quantize_weights(w, cfg)
    wq = apply_conductance_noise(wq, w_noise, cfg)
    xq = jnp.pad(xq, ((0, grid.m_pad - m), (0, grid.k_pad - k)))
    wq = jnp.pad(wq, ((0, grid.k_pad - k), (0, grid.n_pad - n)))
    out = crossbar_matmul_quantized(xq, wq, cfg, bm=bm, bn=bn, depth=depth,
                                    interpret=interpret)
    return out[:m, :n] * (xs * ws)


def crossbar_matmul(x: jax.Array, w: jax.Array,
                    cfg: CrossbarNumerics = CrossbarNumerics(),
                    bm: int | None = None, bn: int | None = None,
                    depth: int | None = None,
                    interpret: bool | None = None, tuned=None,
                    w_noise: jax.Array | None = None) -> jax.Array:
    """y = x @ w through the crossbar numerics, via the Pallas kernel.

    x: [M, K] float (clipped to >= 0, as in the post-ReLU cores)
    w: [K, N] float
    ``bm``/``bn``/``depth`` left at ``None`` resolve through the tuned
    bundle / tuning registry (defaults 128/128/1 on a miss); explicit
    values always win. Numerics are block-size and depth invariant.
    ``w_noise``: optional [K, N] conductance-code perturbation applied to
    the programmed weights (``devices.variation``) — ignored on the ideal
    path, which has no conductances.
    """
    if cfg.ideal:
        return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    bm, bn, depth = _resolve_blocks(x, w, cfg, bm, bn, depth, tuned)
    return _crossbar_matmul(x, w, cfg, bm, bn, depth, interpret, w_noise)


def crossbar_matmul_signed(x: jax.Array, w: jax.Array,
                           cfg: CrossbarNumerics = CrossbarNumerics(),
                           bm: int | None = None, bn: int | None = None,
                           depth: int | None = None,
                           interpret: bool | None = None,
                           tuned=None,
                           w_noise: jax.Array | None = None) -> jax.Array:
    """Signed-activation variant (two DAC passes, digital recombine); one
    ``w_noise`` draw is shared by both passes — same programmed arrays."""
    if cfg.ideal:
        return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    bm, bn, depth = _resolve_blocks(x, w, cfg, bm, bn, depth, tuned)
    pos = _crossbar_matmul(jnp.maximum(x, 0.0), w, cfg, bm, bn, depth,
                           interpret, w_noise)
    neg = _crossbar_matmul(jnp.maximum(-x, 0.0), w, cfg, bm, bn, depth,
                           interpret, w_noise)
    return pos - neg
