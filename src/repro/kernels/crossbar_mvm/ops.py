"""Jitted public wrapper around the crossbar MVM Pallas kernel.

Handles global DAC/weight quantization (a full-tensor max-reduction that can
not live inside a block-local kernel), padding to the mapper-emitted
(bm, bk, bn) tile grid (``repro.mapper.tiling.padded_grid`` — any M/K/N is
mappable; the kernel itself only ever sees divisible shapes), and the final
de-quantization rescale, so that::

    crossbar_matmul(x, w, cfg)  ==  ref.crossbar_matmul_ref(x, w, cfg)

bit-exactly (both compute the same integer-domain math).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.mapper.tiling import padded_grid

from .crossbar_mvm import crossbar_matmul_quantized
from .ref import CrossbarNumerics, quantize_inputs, quantize_weights


@functools.partial(jax.jit, static_argnames=("cfg", "bm", "bn", "interpret"))
def crossbar_matmul(x: jax.Array, w: jax.Array,
                    cfg: CrossbarNumerics = CrossbarNumerics(),
                    bm: int = 128, bn: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """y = x @ w through the crossbar numerics, via the Pallas kernel.

    x: [M, K] float (clipped to >= 0, as in the post-ReLU cores)
    w: [K, N] float
    """
    if cfg.ideal:
        return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    m, k = x.shape
    _, n = w.shape
    grid = padded_grid(m, k, n, cfg.rows_per_xbar, bm=bm, bn=bn)
    xq, xs = quantize_inputs(x, cfg)
    wq, ws = quantize_weights(w, cfg)
    xq = jnp.pad(xq, ((0, grid.m_pad - m), (0, grid.k_pad - k)))
    wq = jnp.pad(wq, ((0, grid.k_pad - k), (0, grid.n_pad - n)))
    out = crossbar_matmul_quantized(xq, wq, cfg, bm=bm, bn=bn,
                                    interpret=interpret)
    return out[:m, :n] * (xs * ws)


@functools.partial(jax.jit, static_argnames=("cfg", "bm", "bn", "interpret"))
def crossbar_matmul_signed(x: jax.Array, w: jax.Array,
                           cfg: CrossbarNumerics = CrossbarNumerics(),
                           bm: int = 128, bn: int = 128,
                           interpret: bool | None = None) -> jax.Array:
    """Signed-activation variant (two DAC passes, digital recombine)."""
    if cfg.ideal:
        return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    pos = crossbar_matmul(jnp.maximum(x, 0.0), w, cfg, bm, bn, interpret)
    neg = crossbar_matmul(jnp.maximum(-x, 0.0), w, cfg, bm, bn, interpret)
    return pos - neg
