"""Shared platform-aware ``interpret`` default for every Pallas kernel.

All kernels take ``interpret: bool | None = None``. ``None`` resolves at
trace time to "interpret everywhere except a real TPU": CPU/GPU hosts (the
tier-1 CI) get the Pallas interpreter, a TPU gets the compiled kernel —
instead of the old hard-coded ``True`` that silently ran every kernel
interpreted on real hardware. Pass an explicit bool to override (e.g.
``interpret=True`` on TPU to debug a kernel).
"""
from __future__ import annotations

import jax


def resolve_interpret(interpret: bool | None) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret
