"""Mixture-of-Experts FFN with sort-based capacity dispatch.

The dispatch is deliberately built on the IMA-GNN aggregation dataflow
(DESIGN.md §Arch-applicability): token->expert routing is a sparse
gather-reduce exactly like neighbor aggregation — router top-k plays the role
of the traversal core's edge list, the expert buffers are the "clusters", and
the weighted combine is the aggregation core's reduction. Expert-parallel
sharding places the [E, C, D] buffers on the 'model' axis (or the expert FFN
hidden dim when E < axis size), with GSPMD inserting the all-to-alls.

Dispatch algorithm (fixed shapes, jit/SPMD-friendly):
  1. router logits -> top-k expert ids + gates per token,
  2. stable-sort token-slots by expert id,
  3. rank-within-expert via sorted-position - expert-start (capacity drop),
  4. scatter tokens into [E, C, D]; batched expert matmul; weighted combine.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import init_dense, shard
from .config import ModelConfig


def init_moe(key, cfg: ModelConfig) -> dict:
    mo = cfg.moe
    d, f, e = cfg.d_model, mo.d_ff_expert, mo.n_experts
    ks = jax.random.split(key, 5)
    p = {"router": init_dense(ks[0], (d, e), dtype="float32"),
         "wi": init_dense(ks[1], (e, d, 2 * f), dtype=cfg.dtype),
         "wo": init_dense(ks[2], (e, f, d), dtype=cfg.dtype)}
    if mo.n_shared:
        fs = f * mo.n_shared
        p["shared_wi"] = init_dense(ks[3], (d, 2 * fs), dtype=cfg.dtype)
        p["shared_wo"] = init_dense(ks[4], (fs, d), dtype=cfg.dtype)
    return p


def _route(params, x2d, cfg: ModelConfig):
    """Router: returns (expert_ids [T, k], gates [T, k])."""
    mo = cfg.moe
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32),
                        params["router"])
    if mo.router == "sigmoid":           # deepseek-v3 style
        scores = jax.nn.sigmoid(logits)
        gates, ids = jax.lax.top_k(scores, mo.top_k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    else:                                # grok/softmax style
        gates, ids = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), mo.top_k)
    return ids.astype(jnp.int32), gates


def _group_dispatch(x_g, ids_g, e: int, cap: int):
    """Sort-based dispatch WITHIN one token group. x_g: [S, D];
    ids_g: [S, k]. Returns (buf [E, cap, D], slot maps).

    The buffer is built with a GATHER over the sort order (buf[e, c] =
    x[token of the c-th slot routed to e]) rather than a scatter: GSPMD
    partitions gathers on the output dims, so an expert-sharded buffer is
    produced locally per shard with no all-reduce (EXPERIMENTS.md §Perf
    deepseek iteration 2). Combine needs no scatter either — a token's k
    slots are contiguous in flat order, so it is a gather + reshape + sum."""
    s, d = x_g.shape
    k = ids_g.shape[-1]
    flat_ids = ids_g.reshape(-1)                         # [S*k]
    order = jnp.argsort(flat_ids, stable=True)           # sorted slot order
    sorted_ids = flat_ids[order]
    starts = jnp.searchsorted(sorted_ids, jnp.arange(e), side="left")
    ends = jnp.searchsorted(sorted_ids, jnp.arange(e), side="right")
    # buf[e, c] = x_g[token of sorted slot starts[e] + c]
    pos = starts[:, None] + jnp.arange(cap)[None, :]     # [E, C]
    valid = pos < ends[:, None]
    slot = order[jnp.clip(pos, 0, s * k - 1)]            # original slot id
    token = slot // k                                    # [E, C]
    buf = jnp.where(valid[:, :, None], x_g[token], 0)    # gather
    # combine maps: rank of every slot within its expert (inverse perm)
    inv = jnp.zeros((s * k,), jnp.int32).at[order].set(
        jnp.arange(s * k, dtype=jnp.int32))
    rank = inv - starts[flat_ids]
    keep = rank < cap
    return buf, (flat_ids, rank, keep)


def moe_ffn(params, x, cfg: ModelConfig):
    """x: [B, S, D] -> [B, S, D]. Returns (out, aux) with load-balance stats.

    GShard-style grouped dispatch: each batch row is a dispatch group, so
    the [G, E, C, D] buffer shards over BOTH the data axis (G) and the
    model axis (E) — no device ever materializes the global buffer, and
    GSPMD lowers the group->expert reshard to an all-to-all (the paper's
    decentralized cluster->cluster edge traffic, here token->expert)."""
    mo = cfg.moe
    b, s, d = x.shape
    t = b * s
    k = mo.top_k
    e = mo.n_experts
    x2d = x.reshape(t, d)
    ids, gates = _route(params, x2d, cfg)                # [T, k]

    cap = int(mo.capacity_factor * s * k / e) + 1        # per-group capacity
    ids_g = ids.reshape(b, s, k)
    gates_g = gates.reshape(b, s, k)
    x_g = x                                              # [B(G), S, D]
    buf, (slot_e, rank, keep) = jax.vmap(
        _group_dispatch, in_axes=(0, 0, None, None))(x_g, ids_g, e, cap)
    buf = shard(buf, "expert_buf")                       # [G, E, C, D]

    # ---- expert compute (batched swiglu; E model-sharded, G data-sharded)
    h = jnp.einsum("gecd,edf->gecf", buf, params["wi"])
    gate, up = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    h = shard(h, "expert_hidden")
    y_buf = jnp.einsum("gecf,efd->gecd", h, params["wo"])
    # replicate expert outputs over 'model' BEFORE the combine gather: one
    # bf16 all-gather instead of GSPMD's masked-partial-gather + f32
    # all-reduce of the [S*k, D] combine tensor (2x the traffic)
    y_buf = shard(y_buf, "expert_out")

    # ---- weighted combine back to tokens (gather + reshape-sum over k) ----
    def _combine(y_g, slot_e_g, rank_g, keep_g, gates_one):
        got = y_g[slot_e_g, jnp.clip(rank_g, 0, cap - 1)]   # [S*k, D]
        w = jnp.where(keep_g, gates_one.reshape(-1), 0.0)
        got = got * w[:, None].astype(got.dtype)            # bf16 slot space
        return got.reshape(s, k, d).sum(axis=1,
                                        dtype=jnp.float32)  # f32 k-reduce

    out = jax.vmap(_combine)(y_buf, slot_e, rank, keep, gates_g)
    out = out.reshape(t, d).astype(x.dtype)
    flat_ids = ids.reshape(-1)
    keep_frac = keep.reshape(-1).mean()

    if mo.n_shared:
        hs = jnp.einsum("td,df->tf", x2d, params["shared_wi"])
        sg, su = jnp.split(hs, 2, axis=-1)
        hs = jax.nn.silu(sg.astype(jnp.float32)).astype(x.dtype) * su
        out = out + jnp.einsum("tf,fd->td", hs, params["shared_wo"])

    # aux: load-balance loss terms (mean gate fraction x token fraction)
    me = jnp.zeros((e,), jnp.float32).at[flat_ids].add(1.0) / (t * k)
    pe = jnp.zeros((e,), jnp.float32).at[ids[:, 0]].add(
        gates[:, 0].astype(jnp.float32)) / t
    aux = {"load_balance": e * jnp.sum(me * pe),
           "dropped_frac": 1.0 - keep_frac}
    return out.reshape(b, s, d), aux
