"""Attention-free mixers: RG-LRU (RecurrentGemma) and RWKV-6 "Finch".

Both expose the same interface as the attention mixers:
  * full-sequence mode (train/prefill) via lax.scan over time,
  * single-step decode against a small recurrent state (their "KV cache"),
so ``long_500k`` decode is O(1) in sequence length.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import init_dense, shard
from .config import ModelConfig


# ================================================================ RG-LRU
def init_rglru(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    w = cfg.rglru_width or d
    ks = jax.random.split(key, 6)
    return {
        "wx": init_dense(ks[0], (d, 2 * w), dtype=cfg.dtype),  # rnn + gate br.
        "conv": init_dense(ks[1], (4, w), scale=0.5, dtype=cfg.dtype),
        "w_a": init_dense(ks[2], (w, w), dtype=cfg.dtype),     # recurrence gate
        "w_i": init_dense(ks[3], (w, w), dtype=cfg.dtype),     # input gate
        # Lambda parameterized so a = exp(-8*softplus(lam)*sigmoid(.)) starts
        # near long memory
        "lam": jnp.full((w,), 0.5, jnp.float32),
        "wo": init_dense(ks[4], (w, d), dtype=cfg.dtype),
    }


def init_rglru_state(cfg: ModelConfig, batch: int) -> dict:
    w = cfg.rglru_width or cfg.d_model
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, 4, w), jnp.float32)}


_C = 8.0


def _rglru_gates(params, x):
    """Per-timestep gate terms of the RG-LRU recurrence. x: [..., W] f32
    (post-conv). Returns (a, gated) with h_t = a_t * h_{t-1} + gated_t.

    All dots live HERE — outside the time recurrence — so TP weight-gradient
    all-reduces happen once per call, not once per timestep (EXPERIMENTS.md
    §Perf recurrentgemma iteration 1)."""
    r = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", x,
                                  params["w_a"].astype(jnp.float32)))
    i = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", x,
                                  params["w_i"].astype(jnp.float32)))
    log_a = -_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * (i * x)
    return a, gated


def _rglru_step(params, h, x_t):
    """One RG-LRU decode step. x_t: [B, W] (post-conv); h: [B, W]."""
    a, gated = _rglru_gates(params, x_t)
    return a * h + gated


def rglru_mixer(params, x, cfg: ModelConfig, state: dict | None = None):
    """x: [B, S, D]. Full-sequence when state is None; else one decode step."""
    b, s, d = x.shape
    w = cfg.rglru_width or d
    xb = jnp.einsum("bsd,dw->bsw", x, params["wx"])
    rnn_in, gate = jnp.split(xb, 2, axis=-1)
    rnn_in = rnn_in.astype(jnp.float32)

    if state is None:
        # temporal conv (width 4, causal) over the rnn branch
        pad = jnp.pad(rnn_in, ((0, 0), (3, 0), (0, 0)))
        conv = sum(pad[:, i:i + s] * params["conv"][i].astype(jnp.float32)
                   for i in range(4))
        # purely elementwise linear recurrence h_t = a_t h_{t-1} + g_t,
        # evaluated with a log-depth associative scan (parallel over time on
        # TPU instead of a 4096-long sequential loop). (An explicit width-
        # sharding tag was tried and REFUTED — see EXPERIMENTS.md §Perf
        # recurrentgemma iteration 3.)
        a, g = _rglru_gates(params, conv)               # [B, S, W]

        def comb(lhs, rhs):
            a1, g1 = lhs
            a2, g2 = rhs
            return a1 * a2, g2 + a2 * g1

        _, hs = jax.lax.associative_scan(comb, (a, g), axis=1)
        y = hs                                          # [B, S, W]
        new_state = None
    else:
        # decode: roll the conv window, one recurrence step
        win = jnp.concatenate([state["conv"][:, 1:], rnn_in], axis=1)
        conv_t = jnp.einsum("bkw,kw->bw", win,
                            params["conv"].astype(jnp.float32))
        h = _rglru_step(params, state["h"], conv_t)
        y = h[:, None, :]
        new_state = {"h": h, "conv": win}

    out = y.astype(x.dtype) * jax.nn.gelu(gate.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsw,wd->bsd", out, params["wo"])
    return (out, new_state) if state is not None else out


# ================================================================ RWKV-6
def init_rwkv(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    dh = cfg.rwkv_head_dim
    ks = jax.random.split(key, 10)
    return {
        # data-dependent token-shift mix coefficients (Finch ddlerp, shared
        # low-rank path simplified to per-channel mu + one lora)
        "mu": init_dense(ks[0], (5, d), scale=0.5, dtype="float32"),
        "w1": init_dense(ks[1], (d, 64), dtype=cfg.dtype),
        "w2": init_dense(ks[2], (64, d), dtype=cfg.dtype),
        "decay_base": jnp.full((d,), -2.0, jnp.float32),
        "u": init_dense(ks[3], (d,), scale=0.5, dtype="float32"),  # bonus
        "wr": init_dense(ks[4], (d, d), dtype=cfg.dtype),
        "wk": init_dense(ks[5], (d, d), dtype=cfg.dtype),
        "wv": init_dense(ks[6], (d, d), dtype=cfg.dtype),
        "wg": init_dense(ks[7], (d, d), dtype=cfg.dtype),
        "wo": init_dense(ks[8], (d, d), dtype=cfg.dtype),
        "ln_x": jnp.ones((d,), jnp.float32),
    }


def init_rwkv_state(cfg: ModelConfig, batch: int) -> dict:
    d = cfg.d_model
    dh = cfg.rwkv_head_dim
    h = d // dh
    return {"s": jnp.zeros((batch, h, dh, dh), jnp.float32),
            "x_prev": jnp.zeros((batch, d), jnp.float32)}


def _rwkv_inner(params, r, k, v, w, u, s0):
    """Finch recurrence over time. r,k,v,w: [B, S, H, Dh] (f32); s0:[B,H,Dh,Dh].

    y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    """
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                        # [B, H, Dh]
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., None] * s + kv
        return s, y

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, w))
    s, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3), s                  # [B, S, H, Dh]


def rwkv_mixer(params, x, cfg: ModelConfig, state: dict | None = None):
    """RWKV-6 time-mix. x: [B, S, D]."""
    b, s, d = x.shape
    dh = cfg.rwkv_head_dim
    h = d // dh
    xf = x.astype(jnp.float32)
    if state is None:
        x_prev = jnp.pad(xf, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        x_prev = state["x_prev"][:, None, :]
    delta = x_prev - xf
    mu = params["mu"].astype(jnp.float32)
    # data-dependent shift amount (shared lora across the five mixes)
    dd = jnp.tanh(jnp.einsum("bsd,dr->bsr", xf, params["w1"].astype(jnp.float32)))
    dd = jnp.einsum("bsr,rd->bsd", dd, params["w2"].astype(jnp.float32))
    mix = lambda i: xf + delta * jax.nn.sigmoid(mu[i] + dd)
    xr, xk, xv, xg, xw = (mix(i) for i in range(5))

    r = jnp.einsum("bsd,de->bse", xr, params["wr"].astype(jnp.float32))
    k = jnp.einsum("bsd,de->bse", xk, params["wk"].astype(jnp.float32))
    v = jnp.einsum("bsd,de->bse", xv, params["wv"].astype(jnp.float32))
    g = jnp.einsum("bsd,de->bse", xg, params["wg"].astype(jnp.float32))
    # data-dependent decay (the Finch signature): w in (0,1)
    w = jnp.exp(-jnp.exp(params["decay_base"] + xw))

    hd = lambda a: a.reshape(b, s, h, dh)
    u = params["u"].astype(jnp.float32).reshape(h, dh)
    s0 = (state["s"] if state is not None
          else jnp.zeros((b, h, dh, dh), jnp.float32))
    y, s_new = _rwkv_inner(params, hd(r), hd(k), hd(v), hd(w), u, s0)
    y = y.reshape(b, s, d)
    # group-norm per head (ln_x), then output gate
    yh = y.reshape(b, s, h, dh)
    yh = (yh - yh.mean(-1, keepdims=True)) * jax.lax.rsqrt(
        yh.var(-1, keepdims=True) + 1e-5)
    y = yh.reshape(b, s, d) * params["ln_x"]
    y = y * jax.nn.silu(g)
    out = jnp.einsum("bsd,de->bse", y.astype(x.dtype), params["wo"])
    if state is not None:
        return out, {"s": s_new, "x_prev": xf[:, -1]}
    return out


def init_rwkv_channel(key, cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    return {"mu_k": init_dense(ks[0], (d,), scale=0.5, dtype="float32"),
            "mu_r": init_dense(ks[1], (d,), scale=0.5, dtype="float32"),
            "wk": init_dense(ks[2], (d, f), dtype=cfg.dtype),
            "wv": init_dense(ks[3], (f, d), dtype=cfg.dtype),
            "wr": init_dense(jax.random.fold_in(key, 9), (d, d),
                             dtype=cfg.dtype)}


def rwkv_channel_mix(params, x, cfg: ModelConfig,
                     x_prev: jax.Array | None = None):
    """RWKV channel-mix ("FFN") with token shift. x: [B, S, D]."""
    xf = x.astype(jnp.float32)
    if x_prev is None:
        prev = jnp.pad(xf, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        prev = x_prev[:, None, :]
    delta = prev - xf
    xk = xf + delta * jax.nn.sigmoid(params["mu_k"])
    xr = xf + delta * jax.nn.sigmoid(params["mu_r"])
    kk = jnp.einsum("bsd,df->bsf", xk.astype(x.dtype), params["wk"])
    kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(x.dtype)
    vv = jnp.einsum("bsf,fd->bsd", kk, params["wv"])
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr,
                                   params["wr"].astype(jnp.float32)))
    out = rr.astype(x.dtype) * vv
    if x_prev is not None:
        return out, xf[:, -1]
    return out
