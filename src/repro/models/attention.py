"""Attention mixers: GQA (full / sliding-window / M-RoPE) and MLA
(DeepSeek/MiniCPM3 multi-head latent attention), with memory-bounded chunked
prefill (online softmax over KV chunks) and single-token decode against
KV caches (ring-buffered for sliding windows, latent-compressed for MLA).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import apply_rope, init_dense, rms_norm, shard
from .config import ModelConfig

NEG_INF = -1e30


# ------------------------------------------------------------ chunked core
def chunked_attention(q, k, v, q_pos, k_pos, *, causal: bool, window: int,
                      chunk: int, k_valid=None, canonical: bool = False):
    """Online-softmax attention, O(S * chunk) memory.

    q: [B, Sq, H, Dk]; k: [B, Sk, KV, Dk]; v: [B, Sk, KV, Dv]
    q_pos/k_pos: [B, Sq] / [B, Sk] absolute positions for masking.
    KV grouping (GQA) handled by reshaping H = KV * G.
    Returns [B, Sq, H, Dv] (f32 accumulated, cast back to q.dtype).

    ``canonical``: positions are known to be arange(Sq)/arange(Sk) (train /
    prefill). Masks are then derived from the chunk indices carried through
    the scans (scalar + iota, [cq, ck] per step) instead of the position
    tensors — XLA would otherwise hoist the full O(Sq*Sk) mask table out of
    the loops and materialize it (EXPERIMENTS.md §Perf, cross-cutting fix).
    """
    b, sq, h, dk = q.shape
    _, sk, kv, _ = k.shape
    dv = v.shape[-1]
    g = h // kv
    scale = dk ** -0.5

    cq = min(chunk, sq)
    ck = min(chunk, sk)
    pad_q = (-sq) % cq
    pad_k = (-sk) % ck
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad_k)), constant_values=-1)
        if k_valid is not None:
            k_valid = jnp.pad(k_valid, ((0, 0), (0, pad_k)))
    if k_valid is None:
        k_valid = (k_pos >= 0)
    nq, nk = (sq + pad_q) // cq, (sk + pad_k) // ck

    qc = q.reshape(b, nq, cq, kv, g, dk).transpose(1, 0, 2, 3, 4, 5)
    kc = k.reshape(b, nk, ck, kv, dk).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nk, ck, kv, dv).transpose(1, 0, 2, 3, 4)
    if canonical:
        qp = jnp.arange(nq, dtype=jnp.int32)             # chunk index only
        kp = jnp.arange(nk, dtype=jnp.int32)
        kval = None
    else:
        qp = q_pos.reshape(b, nq, cq).transpose(1, 0, 2)
        kp = k_pos.reshape(b, nk, ck).transpose(1, 0, 2)
        kval = k_valid.reshape(b, nk, ck).transpose(1, 0, 2)

    iq = jnp.arange(cq, dtype=jnp.int32)
    ik = jnp.arange(ck, dtype=jnp.int32)

    def q_step(_, qi):
        q_i, qp_i = qi                              # [B,cq,KV,G,Dk], [B,cq]|[]

        def kv_step(carry, kj):
            m, l, acc = carry
            if canonical:
                k_j, v_j, kj_idx = kj
                qpos = qp_i * cq + iq                        # [cq]
                kpos = kj_idx * ck + ik                      # [ck]
                mask = (kpos < sk)[None, :]                  # [1, ck]
                if causal:
                    rel = qpos[:, None] - kpos[None, :]      # [cq, ck]
                    mask = mask & (rel >= 0)
                    if window:
                        mask = mask & (rel < window)
                mask = mask[None, :, None, None, :]          # [1,cq,1,1,ck]
            else:
                k_j, v_j, kp_j, kv_j = kj
                mask = kv_j[:, None, None, None, :]
                if causal:
                    rel = qp_i[:, :, None, None, None] \
                        - kp_j[:, None, None, None, :]
                    mask = mask & (rel >= 0)
                    if window:
                        mask = mask & (rel < window)
            s = jnp.einsum("bqkgd,bckd->bqkgc", q_i.astype(jnp.float32),
                           k_j.astype(jnp.float32)) * scale
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqkgc,bckd->bqkgd", p, v_j.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        init = (jnp.full((b, cq, kv, g), NEG_INF, jnp.float32),
                jnp.zeros((b, cq, kv, g), jnp.float32),
                jnp.zeros((b, cq, kv, g, dv), jnp.float32))
        kxs = (kc, vc, kp) if canonical else (kc, vc, kp, kval)
        # flash-attention backward: recompute scores/probs per chunk pair in
        # reverse-mode instead of saving the O(Sq*Sk) probability tensor
        # (EXPERIMENTS.md §Perf, cross-cutting iteration)
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step, prevent_cse=False), init, kxs)
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out

    _, out = jax.lax.scan(q_step, None, (qc, qp))   # [nq, B, cq, KV, G, Dv]
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * cq, h, dv)
    return out[:, :sq].astype(q.dtype)


# ------------------------------------------------------------ GQA
def init_gqa(key, cfg: ModelConfig) -> dict:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh
    ks = jax.random.split(key, 4)
    return {"wq": init_dense(ks[0], (d, h * dh), dtype=cfg.dtype),
            "wk": init_dense(ks[1], (d, kv * dh), dtype=cfg.dtype),
            "wv": init_dense(ks[2], (d, kv * dh), dtype=cfg.dtype),
            "wo": init_dense(ks[3], (h * dh, d), dtype=cfg.dtype)}


def init_gqa_cache(cfg: ModelConfig, batch: int, capacity: int,
                   window: int) -> dict:
    cap = min(capacity, window) if window else capacity
    kv, dh = cfg.n_kv_heads, cfg.dh
    dt = jnp.dtype(cfg.dtype)
    return {"k": jnp.zeros((batch, cap, kv, dh), dt),
            "v": jnp.zeros((batch, cap, kv, dh), dt),
            "pos": jnp.full((batch, cap), -1, jnp.int32),
            "idx": jnp.zeros((), jnp.int32)}


def gqa_attention(params, x, pos, cfg: ModelConfig, *, window: int,
                  cache: dict | None = None, mrope_pos=None):
    """x: [B, S, D]. Prefill/train when cache is None (returns out only);
    decode when cache is given (S == 1; returns out, new_cache)."""
    b, s, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    q = jnp.einsum("bsd,de->bse", x, params["wq"]).reshape(b, s, h, dh)
    k = jnp.einsum("bsd,de->bse", x, params["wk"]).reshape(b, s, kv, dh)
    v = jnp.einsum("bsd,de->bse", x, params["wv"]).reshape(b, s, kv, dh)
    rp = mrope_pos if mrope_pos is not None else pos
    q = apply_rope(q, rp, cfg.rope_theta, cfg.mrope_sections)
    k = apply_rope(k, rp, cfg.rope_theta, cfg.mrope_sections)
    q = shard(q, "heads")

    if cache is None:
        out = chunked_attention(q, k, v, pos, pos, causal=True,
                                window=window, chunk=cfg.attn_chunk,
                                canonical=True)
    else:
        cap = cache["k"].shape[1]
        slot = cache["idx"] % cap
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        cpos = jax.lax.dynamic_update_slice(cache["pos"], pos, (0, slot))
        valid = cpos >= 0
        if window:
            valid = valid & (pos[:, :1] - cpos < window)
        g = h // kv
        qg = q.reshape(b, s, kv, g, dh).astype(jnp.float32)
        s_ = jnp.einsum("bqkgd,bckd->bqkgc", qg,
                        ck.astype(jnp.float32)) * (dh ** -0.5)
        s_ = jnp.where(valid[:, None, None, None, :], s_, NEG_INF)
        p = jax.nn.softmax(s_, axis=-1)
        out = jnp.einsum("bqkgc,bckd->bqkgd", p, cv.astype(jnp.float32))
        out = out.reshape(b, s, h, dh).astype(x.dtype)
        cache = {"k": ck, "v": cv, "pos": cpos, "idx": cache["idx"] + s}

    y = jnp.einsum("bse,ed->bsd", out.reshape(b, s, h * dh), params["wo"])
    y = shard(y, "residual")
    return (y, cache) if cache is not None else y


# ------------------------------------------------------------ MLA
def init_mla(key, cfg: ModelConfig) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    p = {"wdkv": init_dense(ks[0], (d, m.kv_lora + m.rope_dim), dtype=cfg.dtype),
         "kv_norm": jnp.zeros((m.kv_lora,), jnp.float32),
         "wukv": init_dense(ks[1], (m.kv_lora, h * (m.nope_dim + m.v_dim)),
                            dtype=cfg.dtype),
         "wo": init_dense(ks[2], (h * m.v_dim, d), dtype=cfg.dtype)}
    if m.q_lora:
        p["wdq"] = init_dense(ks[3], (d, m.q_lora), dtype=cfg.dtype)
        p["q_norm"] = jnp.zeros((m.q_lora,), jnp.float32)
        p["wuq"] = init_dense(ks[4], (m.q_lora, h * (m.nope_dim + m.rope_dim)),
                              dtype=cfg.dtype)
    else:
        p["wuq"] = init_dense(ks[4], (d, h * (m.nope_dim + m.rope_dim)),
                              dtype=cfg.dtype)
    return p


def init_mla_cache(cfg: ModelConfig, batch: int, capacity: int) -> dict:
    m = cfg.mla
    dt = jnp.dtype(cfg.dtype)
    return {"ckv": jnp.zeros((batch, capacity, m.kv_lora), dt),
            "kpe": jnp.zeros((batch, capacity, m.rope_dim), dt),
            "pos": jnp.full((batch, capacity), -1, jnp.int32),
            "idx": jnp.zeros((), jnp.int32)}


def _mla_q(params, x, pos, cfg):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    if m.q_lora:
        cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, params["wdq"]),
                      params["q_norm"], cfg.norm_eps)
    else:
        cq = x
    q = jnp.einsum("bsr,re->bse", cq, params["wuq"]).reshape(
        b, s, h, m.nope_dim + m.rope_dim)
    q_nope, q_pe = q[..., :m.nope_dim], q[..., m.nope_dim:]
    q_pe = apply_rope(q_pe, pos, cfg.rope_theta)
    return q_nope, q_pe


def mla_attention(params, x, pos, cfg: ModelConfig,
                  cache: dict | None = None):
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads
    q_nope, q_pe = _mla_q(params, x, pos, cfg)
    dkv = jnp.einsum("bsd,dr->bsr", x, params["wdkv"])
    ckv_new, kpe_new = dkv[..., :m.kv_lora], dkv[..., m.kv_lora:]
    kpe_new = apply_rope(kpe_new[:, :, None, :], pos, cfg.rope_theta)[:, :, 0]

    if cache is None:
        # prefill: reconstruct per-head keys/values from the latent
        kvu = jnp.einsum("bsr,re->bse",
                         rms_norm(ckv_new, params["kv_norm"], cfg.norm_eps),
                         params["wukv"]).reshape(b, s, h, m.nope_dim + m.v_dim)
        k_nope, v = kvu[..., :m.nope_dim], kvu[..., m.nope_dim:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kpe_new[:, :, None, :],
                                      (b, s, h, m.rope_dim))], axis=-1)
        q = jnp.concatenate([q_nope, q_pe], axis=-1)
        out = chunked_attention(q, k, v, pos, pos, causal=True, window=0,
                                chunk=cfg.attn_chunk, canonical=True)
        y = jnp.einsum("bse,ed->bsd", out.reshape(b, s, h * m.v_dim),
                       params["wo"])
        return shard(y, "residual")

    # decode: absorbed attention in latent space (cache = latent + rope key).
    # The cache stores the POST-kv_norm latent: rms_norm is per-position, so
    # normalizing once at insertion is exact and avoids re-normalizing (and
    # materializing in f32) the whole cache every step — see EXPERIMENTS.md
    # §Perf minicpm3 iteration 2. Score/value dots run on bf16 operands with
    # f32 accumulation (flash-decoding numerics).
    f32 = jnp.float32
    cap = cache["ckv"].shape[1]
    slot = cache["idx"] % cap
    ckv_new_n = rms_norm(ckv_new, params["kv_norm"], cfg.norm_eps)
    ckv = jax.lax.dynamic_update_slice(cache["ckv"], ckv_new_n, (0, slot, 0))
    kpe = jax.lax.dynamic_update_slice(cache["kpe"], kpe_new, (0, slot, 0))
    cpos = jax.lax.dynamic_update_slice(cache["pos"], pos, (0, slot))
    wukv = params["wukv"].reshape(m.kv_lora, h, m.nope_dim + m.v_dim)
    w_uk, w_uv = wukv[..., :m.nope_dim], wukv[..., m.nope_dim:]
    # absorb: q_lat[b,s,h,r] = q_nope . w_uk^T
    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk,
                       preferred_element_type=f32)
    scores = jnp.einsum("bshr,bcr->bshc", q_lat.astype(x.dtype), ckv,
                        preferred_element_type=f32) \
        + jnp.einsum("bshp,bcp->bshc", q_pe.astype(x.dtype), kpe,
                     preferred_element_type=f32)
    scores = scores * ((m.nope_dim + m.rope_dim) ** -0.5)
    scores = jnp.where((cpos >= 0)[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bshc,bcr->bshr", p.astype(x.dtype), ckv,
                         preferred_element_type=f32)
    out = jnp.einsum("bshr,rhv->bshv", out_lat.astype(x.dtype), w_uv,
                     preferred_element_type=f32)
    y = jnp.einsum("bse,ed->bsd",
                   out.reshape(b, s, h * m.v_dim).astype(x.dtype),
                   params["wo"])
    new_cache = {"ckv": ckv, "kpe": kpe, "pos": cpos, "idx": cache["idx"] + s}
    return shard(y, "residual"), new_cache


# ------------------------------------------------------------ cross-attn
def init_cross(key, cfg: ModelConfig) -> dict:
    return init_gqa(key, cfg)


def cross_attention(params, x, enc_kv, cfg: ModelConfig):
    """x: [B, S, D] decoder; enc_kv: (k, v) each [B, T, KV, Dh] precomputed."""
    b, s, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    q = jnp.einsum("bsd,de->bse", x, params["wq"]).reshape(b, s, h, dh)
    k, v = enc_kv
    t = k.shape[1]
    pos_q = jnp.zeros((b, s), jnp.int32)
    pos_k = jnp.zeros((b, t), jnp.int32)
    out = chunked_attention(q, k, v, pos_q, pos_k, causal=False, window=0,
                            chunk=cfg.attn_chunk, canonical=True)
    return jnp.einsum("bse,ed->bsd", out.reshape(b, s, h * dh), params["wo"])


def encode_cross_kv(params, enc_out, cfg: ModelConfig):
    b, t, d = enc_out.shape
    kv, dh = cfg.n_kv_heads, cfg.dh
    k = jnp.einsum("btd,de->bte", enc_out, params["wk"]).reshape(b, t, kv, dh)
    v = jnp.einsum("btd,de->bte", enc_out, params["wv"]).reshape(b, t, kv, dh)
    return k, v
