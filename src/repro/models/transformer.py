"""Unified decoder substrate: every assigned architecture is an instance.

Layer stack = [prelude] + scan(cycles of cfg.pattern) + [tail]:
  * prelude — leading dense-FFN layers (deepseek-v3's first 3),
  * cycles  — lax.scan over stacked parameters (compile-time O(1) in depth),
  * tail    — remainder when n_layers % len(pattern) != 0.

Pre-norm residual blocks; mixer dispatch by pattern entry ('attn' | 'local' |
'rglru' | 'rwkv'); FFN = dense SwiGLU/GELU, MoE, or RWKV channel-mix.
Encoder-decoder (whisper) adds a bidirectional encoder + per-layer
cross-attention. Decode carries per-layer caches (KV / latent / recurrent
state). Cross-entropy is chunked over the sequence so the [B, S, V] logits
tensor is never materialized.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_lib
from . import recurrent as rec
from .common import embed, ffn, init_dense, init_embed, init_ffn, rms_norm, shard, unembed
from .config import ModelConfig


# ================================================================ layers
def _layer_kinds(cfg: ModelConfig):
    """(prelude_kinds, cycle_pattern, n_cycles, tail_kinds)."""
    n_prelude = cfg.moe.n_dense_layers if cfg.moe else 0
    prelude = tuple(cfg.pattern[i % len(cfg.pattern)]
                    for i in range(n_prelude))
    rest = cfg.n_layers - n_prelude
    if cfg.is_encdec or not cfg.scan_layers:
        # enc-dec (whisper, 6 layers) unrolls: per-layer cross-KV wiring
        return prelude, cfg.pattern, 0, tuple(
            cfg.pattern[i % len(cfg.pattern)] for i in range(rest))
    n_cycles = rest // len(cfg.pattern)
    tail = tuple(cfg.pattern[i % len(cfg.pattern)]
                 for i in range(rest - n_cycles * len(cfg.pattern)))
    return prelude, cfg.pattern, n_cycles, tail


def _init_mixer(key, kind: str, cfg: ModelConfig) -> dict:
    if kind in ("attn", "local"):
        return attn.init_mla(key, cfg) if cfg.mla else attn.init_gqa(key, cfg)
    if kind == "rglru":
        return rec.init_rglru(key, cfg)
    if kind == "rwkv":
        return rec.init_rwkv(key, cfg)
    raise ValueError(kind)


def _init_block(key, kind: str, cfg: ModelConfig, use_moe: bool) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p = {"ln1": jnp.zeros((d,), jnp.float32),
         "ln2": jnp.zeros((d,), jnp.float32),
         "mixer": _init_mixer(ks[0], kind, cfg)}
    if kind == "rwkv":
        p["ffn"] = rec.init_rwkv_channel(ks[1], cfg)
    elif use_moe:
        p["ffn"] = moe_lib.init_moe(ks[1], cfg)
    else:
        d_ff = (cfg.moe.d_ff_dense or cfg.d_ff) if (
            cfg.moe and cfg.moe.n_dense_layers) else cfg.d_ff
        p["ffn"] = init_ffn(ks[1], cfg, d_ff)
    if cfg.is_encdec:
        p["ln_x"] = jnp.zeros((d,), jnp.float32)
        p["cross"] = attn.init_cross(ks[2], cfg)
    return p


def _init_cache(kind: str, cfg: ModelConfig, batch: int, capacity: int):
    if kind == "attn":
        if cfg.mla:
            return attn.init_mla_cache(cfg, batch, capacity)
        return attn.init_gqa_cache(cfg, batch, capacity, cfg.window)
    if kind == "local":
        return attn.init_gqa_cache(cfg, batch, capacity, cfg.local_window)
    if kind == "rglru":
        return rec.init_rglru_state(cfg, batch)
    if kind == "rwkv":
        st = rec.init_rwkv_state(cfg, batch)
        st["chan_prev"] = jnp.zeros((batch, cfg.d_model), jnp.float32)
        return st
    raise ValueError(kind)


def _apply_block(params, x, pos, kind: str, cfg: ModelConfig, use_moe: bool,
                 cache=None, enc_kv=None, mrope_pos=None):
    """Returns (x, new_cache, aux)."""
    aux = {}
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    new_cache = None
    if kind in ("attn", "local"):
        window = cfg.window if kind == "attn" else cfg.local_window
        if cfg.mla:
            r = attn.mla_attention(params["mixer"], h, pos, cfg, cache=cache)
        else:
            r = attn.gqa_attention(params["mixer"], h, pos, cfg,
                                   window=window, cache=cache,
                                   mrope_pos=mrope_pos)
        if cache is not None:
            r, new_cache = r
    elif kind == "rglru":
        r = rec.rglru_mixer(params["mixer"], h, cfg, state=cache)
        if cache is not None:
            r, new_cache = r
    else:  # rwkv
        if cache is not None:
            r, st = rec.rwkv_mixer(params["mixer"], h, cfg,
                                   state={"s": cache["s"],
                                          "x_prev": cache["x_prev"]})
            new_cache = dict(cache, **st)
        else:
            r = rec.rwkv_mixer(params["mixer"], h, cfg)
    x = x + r
    if cfg.is_encdec and enc_kv is not None:
        hx = rms_norm(x, params["ln_x"], cfg.norm_eps)
        x = x + attn.cross_attention(params["cross"], hx, enc_kv, cfg)
    h2 = rms_norm(x, params["ln2"], cfg.norm_eps)
    if kind == "rwkv":
        if cache is not None:
            f, chan_prev = rec.rwkv_channel_mix(params["ffn"], h2, cfg,
                                                x_prev=cache["chan_prev"])
            new_cache["chan_prev"] = chan_prev
        else:
            f = rec.rwkv_channel_mix(params["ffn"], h2, cfg)
    elif use_moe:
        f, aux = moe_lib.moe_ffn(params["ffn"], h2, cfg)
    else:
        f = ffn(params["ffn"], h2, cfg)
    return x + f, new_cache, aux


# ================================================================ model
@dataclasses.dataclass(frozen=True)
class Transformer:
    cfg: ModelConfig

    # ------------------------------------------------------------ init
    def init(self, key) -> dict:
        cfg = self.cfg
        prelude, pattern, n_cycles, tail = _layer_kinds(cfg)
        keys = jax.random.split(key, 8)
        params = {"embed": init_embed(keys[0], cfg),
                  "final_ln": jnp.zeros((cfg.d_model,), jnp.float32)}
        params["prelude"] = [
            _init_block(jax.random.fold_in(keys[1], i), k, cfg, use_moe=False)
            for i, k in enumerate(prelude)]

        def cycle_init(ck):
            return {f"sub{j}": _init_block(jax.random.fold_in(ck, j), kind,
                                           cfg, use_moe=cfg.moe is not None)
                    for j, kind in enumerate(pattern)}
        if n_cycles:
            params["main"] = jax.vmap(cycle_init)(
                jax.random.split(keys[2], n_cycles))
        params["tail"] = [
            _init_block(jax.random.fold_in(keys[3], i), k, cfg,
                        use_moe=cfg.moe is not None)
            for i, k in enumerate(tail)]
        if cfg.is_encdec:
            enc = cfg.encoder
            params["enc"] = {
                "blocks": [_init_block(jax.random.fold_in(keys[4], i), "attn",
                                       dataclasses.replace(cfg, encoder=None),
                                       use_moe=False)
                           for i in range(enc.n_layers)],
                "final_ln": jnp.zeros((cfg.d_model,), jnp.float32)}
        if cfg.mtp:
            params["mtp"] = {
                "proj": init_dense(keys[5], (2 * cfg.d_model, cfg.d_model),
                                   dtype=cfg.dtype),
                "block": _init_block(keys[6], "attn", cfg,
                                     use_moe=cfg.moe is not None),
                "ln": jnp.zeros((cfg.d_model,), jnp.float32)}
        return params

    # ------------------------------------------------------------ encoder
    def encode(self, params, frames):
        """Whisper encoder over precomputed frame embeddings [B, T, D]."""
        cfg = self.cfg
        b, t, _ = frames.shape
        pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
        x = frames
        for blk in params["enc"]["blocks"]:
            h = rms_norm(x, blk["ln1"], cfg.norm_eps)
            # bidirectional chunked attention (no causal mask)
            hq = jnp.einsum("bsd,de->bse", h, blk["mixer"]["wq"]).reshape(
                b, t, cfg.n_heads, cfg.dh)
            hk = jnp.einsum("bsd,de->bse", h, blk["mixer"]["wk"]).reshape(
                b, t, cfg.n_kv_heads, cfg.dh)
            hv = jnp.einsum("bsd,de->bse", h, blk["mixer"]["wv"]).reshape(
                b, t, cfg.n_kv_heads, cfg.dh)
            out = attn.chunked_attention(hq, hk, hv, pos, pos, causal=False,
                                         window=0, chunk=cfg.attn_chunk,
                                         canonical=True)
            r = jnp.einsum("bse,ed->bsd",
                           out.reshape(b, t, cfg.n_heads * cfg.dh),
                           blk["mixer"]["wo"])
            x = x + r
            h2 = rms_norm(x, blk["ln2"], cfg.norm_eps)
            x = x + ffn(blk["ffn"], h2, cfg)
        return rms_norm(x, params["enc"]["final_ln"], cfg.norm_eps)

    # ------------------------------------------------------------ trunk
    def _trunk(self, params, x, pos, enc_kvs=None, mrope_pos=None):
        """Full-sequence trunk (train/prefill). Returns (hidden, aux)."""
        cfg = self.cfg
        prelude, pattern, n_cycles, tail = _layer_kinds(cfg)
        aux_sum = jnp.zeros((), jnp.float32)
        drop_sum = jnp.zeros((), jnp.float32)
        li = 0
        for i, kind in enumerate(prelude):
            x, _, aux = _apply_block(params["prelude"][i], x, pos, kind, cfg,
                                     use_moe=False,
                                     enc_kv=_idx_enc(enc_kvs, li),
                                     mrope_pos=mrope_pos)
            li += 1

        if n_cycles:
            def cycle(carry, xs):
                x, aux_s, drop_s = carry
                cyc_params, enc_kv = xs
                for j, kind in enumerate(pattern):
                    x, _, aux = _apply_block(
                        cyc_params[f"sub{j}"], x, pos, kind, cfg,
                        use_moe=cfg.moe is not None,
                        enc_kv=(enc_kv if enc_kv is not None else None),
                        mrope_pos=mrope_pos)
                    if aux:
                        aux_s = aux_s + aux["load_balance"]
                        drop_s = drop_s + aux["dropped_frac"]
                return (x, aux_s, drop_s), None

            fn = cycle
            if cfg.remat == "full":
                fn = jax.checkpoint(cycle, prevent_cse=False)
            enc_stack = _stack_enc(enc_kvs, li, n_cycles, len(pattern))
            (x, aux_sum, drop_sum), _ = jax.lax.scan(
                fn, (x, aux_sum, drop_sum), (params["main"], enc_stack))
            li += n_cycles * len(pattern)

        for i, kind in enumerate(tail):
            x, _, aux = _apply_block(params["tail"][i], x, pos, kind, cfg,
                                     use_moe=cfg.moe is not None,
                                     enc_kv=_idx_enc(enc_kvs, li),
                                     mrope_pos=mrope_pos)
            if aux:
                aux_sum = aux_sum + aux["load_balance"]
                drop_sum = drop_sum + aux["dropped_frac"]
            li += 1
        x = rms_norm(x, params["final_ln"], cfg.norm_eps)
        return x, {"load_balance": aux_sum, "dropped": drop_sum}

    # ------------------------------------------------------------ losses
    def loss(self, params, batch):
        """Next-token CE (+ MoE aux + MTP). batch: tokens/labels [B, S]
        (+ frames for enc-dec, + mrope_pos for M-RoPE)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        x = embed(params["embed"], tokens, cfg)
        enc_kvs = None
        if cfg.is_encdec:
            enc_out = self.encode(params, batch["frames"])
            enc_kvs = self._cross_kvs(params, enc_out)
        h, aux = self._trunk(params, x, pos, enc_kvs,
                             mrope_pos=batch.get("mrope_pos"))
        loss = _chunked_ce(params["embed"], h, batch["labels"], cfg)
        total = loss + 0.01 * aux["load_balance"]
        if cfg.mtp:
            total = total + 0.3 * self._mtp_loss(params, h, tokens,
                                                 batch["labels"], pos)
        return total, dict(aux, ce=loss)

    def _mtp_loss(self, params, h, tokens, labels, pos):
        """DeepSeek-style MTP: one extra block predicts token t+2 from
        [h_t ; emb(token_{t+1})]."""
        cfg = self.cfg
        emb_next = embed(params["embed"], jnp.roll(tokens, -1, axis=1), cfg)
        hcat = jnp.concatenate(
            [rms_norm(h, params["mtp"]["ln"], cfg.norm_eps), emb_next],
            axis=-1)
        h2 = jnp.einsum("bsd,de->bse", hcat, params["mtp"]["proj"])
        h2, _, _ = _apply_block(params["mtp"]["block"], h2, pos, "attn", cfg,
                                use_moe=cfg.moe is not None)
        labels2 = jnp.roll(labels, -1, axis=1)
        return _chunked_ce(params["embed"], h2, labels2, cfg)

    def _cross_kvs(self, params, enc_out):
        """Per-decoder-layer cross-attention KV (enc-dec is unrolled)."""
        cfg = self.cfg
        kvs = [attn.encode_cross_kv(blk["cross"], enc_out, cfg)
               for blk in params["prelude"]]
        kvs += [attn.encode_cross_kv(blk["cross"], enc_out, cfg)
                for blk in params["tail"]]
        return kvs

    # ------------------------------------------------------------ serving
    def init_caches(self, batch: int, capacity: int):
        cfg = self.cfg
        prelude, pattern, n_cycles, tail = _layer_kinds(cfg)
        caches = {"prelude": [_init_cache(k, cfg, batch, capacity)
                              for k in prelude],
                  "tail": [_init_cache(k, cfg, batch, capacity)
                           for k in tail]}
        if n_cycles:
            caches["main"] = {
                f"sub{j}": jax.tree.map(
                    lambda a: jnp.broadcast_to(
                        a[None], (n_cycles,) + a.shape).copy(),
                    _init_cache(kind, cfg, batch, capacity))
                for j, kind in enumerate(pattern)}
        return caches

    def decode_step(self, params, token, caches, pos_idx, enc_kvs=None):
        """One serving step. token: [B, 1] int32; pos_idx: scalar int32
        (cache fill level). Returns (logits [B, 1, V], new caches)."""
        cfg = self.cfg
        prelude, pattern, n_cycles, tail = _layer_kinds(cfg)
        b = token.shape[0]
        pos = jnp.full((b, 1), pos_idx, jnp.int32)
        mrope = (jnp.broadcast_to(pos[None], (3, b, 1))
                 if cfg.mrope_sections else None)
        x = embed(params["embed"], token, cfg)
        new_caches = {"prelude": [], "tail": []}
        li = 0
        for i, kind in enumerate(prelude):
            x, c, _ = _apply_block(params["prelude"][i], x, pos, kind, cfg,
                                   use_moe=False, cache=caches["prelude"][i],
                                   enc_kv=_idx_enc(enc_kvs, li),
                                   mrope_pos=mrope)
            new_caches["prelude"].append(c)
            li += 1
        if n_cycles:
            def cycle(x, xs):
                cyc_params, cyc_cache, enc_kv = xs
                outs = {}
                for j, kind in enumerate(pattern):
                    x, c, _ = _apply_block(
                        cyc_params[f"sub{j}"], x, pos, kind, cfg,
                        use_moe=cfg.moe is not None,
                        cache=cyc_cache[f"sub{j}"],
                        enc_kv=(enc_kv if enc_kv is not None else None),
                        mrope_pos=mrope)
                    outs[f"sub{j}"] = c
                return x, outs

            enc_stack = _stack_enc(enc_kvs, li, n_cycles, len(pattern))
            x, main_caches = jax.lax.scan(
                cycle, x, (params["main"], caches["main"], enc_stack))
            new_caches["main"] = main_caches
            li += n_cycles * len(pattern)
        for i, kind in enumerate(tail):
            x, c, _ = _apply_block(params["tail"][i], x, pos, kind, cfg,
                                   use_moe=cfg.moe is not None,
                                   cache=caches["tail"][i],
                                   enc_kv=_idx_enc(enc_kvs, li),
                                   mrope_pos=mrope)
            new_caches["tail"].append(c)
            li += 1
        x = rms_norm(x, params["final_ln"], cfg.norm_eps)
        logits = unembed(params["embed"], x, cfg)
        return logits, new_caches

    def prefill(self, params, tokens, frames=None, mrope_pos=None):
        """Prefill hidden states (logits for the last position)."""
        cfg = self.cfg
        b, s = tokens.shape
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        x = embed(params["embed"], tokens, cfg)
        enc_kvs = None
        if cfg.is_encdec and frames is not None:
            enc_kvs = self._cross_kvs(params, self.encode(params, frames))
        h, aux = self._trunk(params, x, pos, enc_kvs, mrope_pos=mrope_pos)
        return unembed(params["embed"], h[:, -1:], cfg), aux


def _idx_enc(enc_kvs, li):
    return None if enc_kvs is None else enc_kvs[li]


def _stack_enc(enc_kvs, li, n_cycles, cyc_len):
    # scanned cycles never coexist with enc-dec (enc-dec unrolls) — a dummy
    # scan input keeps the xs tree static.
    return None if enc_kvs is None else None


def _chunked_ce(embed_params, h, labels, cfg: ModelConfig, chunk: int = 512):
    """Sequence-chunked cross entropy: never materializes [B, S, V] f32."""
    b, s, d = h.shape
    pad = (-s) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    n = (s + pad) // chunk
    hc = h.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n, chunk).transpose(1, 0, 2)

    def step(carry, xs):
        tot, cnt = carry
        hx, lx = xs
        logits = unembed(embed_params, hx, cfg).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lx, 0)[..., None], axis=-1)[..., 0]
        valid = (lx >= 0).astype(jnp.float32)
        tot = tot + jnp.sum((logz - gold) * valid)
        cnt = cnt + valid.sum()
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, lc))
    return tot / jnp.maximum(cnt, 1.0)
