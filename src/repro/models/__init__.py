from .config import ModelConfig, MoEConfig, MLAConfig, EncoderConfig
from .transformer import Transformer
from .common import activation_sharding

def build(cfg: ModelConfig) -> Transformer:
    return Transformer(cfg)

__all__ = ["ModelConfig", "MoEConfig", "MLAConfig", "EncoderConfig",
           "Transformer", "build", "activation_sharding"]
