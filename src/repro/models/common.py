"""Shared building blocks: norms, RoPE/M-RoPE, FFNs, init, sharding hooks."""
from __future__ import annotations

import contextlib
import threading

import jax
import jax.numpy as jnp

from .config import ModelConfig

# ---------------------------------------------------------------- sharding
# Logical activation-sharding hooks. launch/ installs a {name: PartitionSpec}
# map; inside the model we tag activations by logical name. With no map
# installed (unit tests, single device) this is a no-op.
_CTX = threading.local()


@contextlib.contextmanager
def activation_sharding(rules: dict):
    old = getattr(_CTX, "rules", None)
    _CTX.rules = rules
    try:
        yield
    finally:
        _CTX.rules = old


def shard(x: jax.Array, name: str) -> jax.Array:
    rules = getattr(_CTX, "rules", None)
    if rules and name in rules:
        return jax.lax.with_sharding_constraint(x, rules[name])
    return x


# ---------------------------------------------------------------- numerics
def cast(x, cfg: ModelConfig):
    return x.astype(jnp.dtype(cfg.dtype))


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def init_dense(key, shape, scale: float | None = None, dtype="bfloat16"):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


# ---------------------------------------------------------------- RoPE
def rope_freqs(dh: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, jnp.float32) / dh))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float,
               sections: tuple = ()) -> jax.Array:
    """Rotary embedding. x: [..., S, H, Dh]; pos: [B, S] or [3, B, S] (M-RoPE).

    With ``sections`` (qwen2-vl M-RoPE), the Dh/2 frequency pairs are split
    into len(sections) groups, group g rotating by pos[g] (temporal/height/
    width axes). Text-only inputs pass identical pos per group.
    """
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # [Dh/2]
    if sections:
        assert sum(sections) == dh // 2, (sections, dh)
        assert pos.ndim == 3, "M-RoPE needs pos [3, B, S]"
        parts = []
        start = 0
        for g, sec in enumerate(sections):
            f = freqs[start:start + sec]
            parts.append(pos[g].astype(jnp.float32)[..., None] * f)
            start += sec
        angles = jnp.concatenate(parts, axis=-1)        # [B, S, Dh/2]
    else:
        if pos.ndim == 3:
            pos = pos[0]
        angles = pos.astype(jnp.float32)[..., None] * freqs
    cos = jnp.cos(angles)[..., None, :]                 # [B, S, 1, Dh/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- FFN
def init_ffn(key, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2 = jax.random.split(key)
    if cfg.act == "swiglu":
        return {"wi": init_dense(k1, (d, 2 * f), dtype=cfg.dtype),
                "wo": init_dense(k2, (f, d), dtype=cfg.dtype)}
    return {"wi": init_dense(k1, (d, f), dtype=cfg.dtype),
            "wo": init_dense(k2, (f, d), dtype=cfg.dtype)}


def ffn(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, params["wi"])
    if cfg.act == "swiglu":
        gate, up = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    h = shard(h, "ffn_hidden")
    return jnp.einsum("...f,fd->...d", h, params["wo"])


# ---------------------------------------------------------------- embedding
def init_embed(key, cfg: ModelConfig) -> dict:
    p = {"tok": init_dense(key, (cfg.vocab, cfg.d_model), scale=1.0,
                           dtype=cfg.dtype)}
    if not cfg.tie_embeddings:
        p["head"] = init_dense(jax.random.fold_in(key, 1),
                               (cfg.d_model, cfg.vocab), dtype=cfg.dtype)
    return p


def embed(params: dict, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    return shard(params["tok"][tokens], "embed")


def unembed(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    w = params["tok"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("...d,dv->...v", x, w)
    return shard(logits, "logits")
