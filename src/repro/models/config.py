"""Unified model configuration for the 10 assigned architectures.

One frozen (hashable, jit-static) dataclass drives the whole zoo; every
architecture is a point in this config space (see repro/configs/*.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0              # shared experts (deepseek-v3: 1)
    router: str = "softmax"        # 'softmax' (grok) | 'sigmoid' (deepseek)
    capacity_factor: float = 1.25
    n_dense_layers: int = 0        # leading dense layers (deepseek-v3: 3)
    d_ff_dense: int = 0            # their hidden size


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora: int                    # query low-rank dim (0 = full-rank q)
    kv_lora: int                   # KV latent dim (the cache-compressed dim)
    rope_dim: int                  # decoupled RoPE key dim per head
    nope_dim: int                  # non-positional q/k dim per head
    v_dim: int                     # value dim per head


@dataclasses.dataclass(frozen=True)
class EncoderConfig:               # whisper-style encoder (stub frontend)
    n_layers: int
    n_frames: int = 1500           # 30 s of audio at 50 Hz post-conv


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                       # 0 => d_model // n_heads
    pattern: Tuple[str, ...] = ("attn",)    # mixer cycle: attn|local|rglru|rwkv
    window: int = 0                         # SWA window for 'attn' (0 = full)
    local_window: int = 2048                # window for 'local' entries
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mrope_sections: Tuple[int, ...] = ()    # qwen2-vl M-RoPE (t, h, w) pairs
    encoder: Optional[EncoderConfig] = None  # whisper
    act: str = "swiglu"                     # 'swiglu' | 'gelu'
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: str = "none"                     # 'none' | 'full'
    attn_chunk: int = 1024                  # chunked-attention block size
    rwkv_head_dim: int = 64
    rglru_width: int = 0                    # 0 => d_model
    mtp: bool = False                       # deepseek multi-token prediction
    scan_layers: bool = True                # lax.scan over layer stack

    @property
    def dh(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def n_cycles(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def tail(self) -> Tuple[str, ...]:
        """Remainder layers when n_layers % len(pattern) != 0."""
        return self.pattern[: self.n_layers % len(self.pattern)]

    @property
    def is_encdec(self) -> bool:
        return self.encoder is not None

    def param_count(self) -> int:
        """Total parameters (for MODEL_FLOPS / roofline)."""
        d, v = self.d_model, self.vocab
        n = v * d * (1 if self.tie_embeddings else 2)   # embed (+ head)
        per_layer = {}
        dh = self.dh
        # mixers
        if self.mla is not None:
            m = self.mla
            q_in = m.q_lora or d
            attn = (d * m.q_lora if m.q_lora else 0) \
                + q_in * self.n_heads * (m.nope_dim + m.rope_dim) \
                + d * (m.kv_lora + m.rope_dim) \
                + m.kv_lora * self.n_heads * (m.nope_dim + m.v_dim) \
                + self.n_heads * m.v_dim * d
        else:
            attn = d * self.n_heads * dh + 2 * d * self.n_kv_heads * dh \
                + self.n_heads * dh * d
        per_layer["attn"] = per_layer["local"] = attn
        w = self.rglru_width or d
        per_layer["rglru"] = d * 2 * w + 4 * w + 2 * w * w + w * d + 2 * w
        per_layer["rwkv"] = 6 * d * d + d * 64 * 2   # r,k,v,g,o,w-lora approx
        # ffn
        ffn_dense = d * self.d_ff * (3 if self.act == "swiglu" else 2)
        counts = {}
        for i in range(self.n_layers):
            kind = self.pattern[i % len(self.pattern)]
            counts[kind] = counts.get(kind, 0) + 1
            if self.moe is not None and kind in ("attn", "local", "rwkv", "rglru"):
                pass
        n += sum(per_layer[k] * c for k, c in counts.items())
        if self.moe is None:
            n += self.n_layers * ffn_dense
        else:
            mo = self.moe
            e_ffn = d * mo.d_ff_expert * 3
            moe_layers = self.n_layers - mo.n_dense_layers
            n += mo.n_dense_layers * d * (mo.d_ff_dense or self.d_ff) * 3
            n += moe_layers * (mo.n_experts + mo.n_shared) * e_ffn
            n += moe_layers * d * mo.n_experts            # router
        if self.encoder is not None:
            n += self.encoder.n_layers * (attn + ffn_dense)
            n += self.n_layers * attn                      # cross-attn
        n += 2 * d * self.n_layers                         # norms (approx)
        return n

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE top-k) for MODEL_FLOPS."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        mo = self.moe
        full = self.param_count()
        moe_layers = self.n_layers - mo.n_dense_layers
        e_ffn = d * mo.d_ff_expert * 3
        inactive = moe_layers * (mo.n_experts - mo.top_k) * e_ffn
        return full - inactive
