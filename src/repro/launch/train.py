"""Fault-tolerant training driver.

Config-driven: picks any assigned architecture (full or smoke-reduced),
builds the device mesh from whatever devices exist (1 CPU in tests, a pod
slice in production), applies the sharding rules, and runs the train loop
with step-atomic checkpointing, deterministic step-indexed data (exact
resume), crash retry, and optional int8 error-feedback gradient compression
on the data-parallel axis.

  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b --smoke \
      --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt --mesh 1x1
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data.tokens import TokenStream
from repro.distributed.sharding import (activation_rules, batch_shardings,
                                        optimizer_shardings, param_shardings)
from repro.launch.mesh import make_mesh, set_mesh
from repro.launch.steps import make_train_step
from repro.models import build
from repro.optim import AdamWConfig, adamw_init


@dataclasses.dataclass
class TrainConfig:
    arch: str = "internlm2-1.8b"
    smoke: bool = True
    steps: int = 100
    batch: int = 8
    seq: int = 64
    lr: float = 3e-4
    seed: int = 0
    mesh: str = ""              # "DxM"; empty => all devices on 'data'
    accum_steps: int = 1        # gradient-accumulation microbatches
    ckpt_dir: str = ""
    ckpt_every: int = 50
    log_every: int = 10
    max_retries: int = 2        # crash retry-from-checkpoint budget


def build_mesh(spec: str):
    n = len(jax.devices())
    if spec:
        d, m = (int(x) for x in spec.split("x"))
    else:
        d, m = n, 1
    assert d * m <= n, f"mesh {d}x{m} needs {d * m} devices, have {n}"
    return make_mesh((d, m), ("data", "model"))


def _ns(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def train(cfg: TrainConfig, *, hooks=None) -> dict:
    """Run the loop; returns final metrics. ``hooks`` (test seam): dict with
    optional ``on_step(step, metrics)`` and ``fault(step)`` callables —
    ``fault`` raising simulates a node failure mid-run."""
    hooks = hooks or {}
    mesh = build_mesh(cfg.mesh)
    mcfg = get_config(cfg.arch, smoke=cfg.smoke)
    model = build(mcfg)
    rules = activation_rules(mcfg, mesh)

    params = model.init(jax.random.key(cfg.seed))
    opt_state = adamw_init(params)
    p_spec = param_shardings(params, mcfg, mesh)
    m_spec = optimizer_shardings(p_spec, params, mesh)
    o_spec = {"m": m_spec, "v": m_spec, "step": P()}
    p_ns, o_ns = _ns(mesh, p_spec), _ns(mesh, o_spec)
    params = jax.device_put(params, p_ns)
    opt_state = jax.device_put(opt_state, o_ns)

    stream = TokenStream(mcfg.vocab, cfg.batch, cfg.seq, cfg.seed)
    b_ns = _ns(mesh, batch_shardings(mesh, "train", stream.batch_at(0)))

    step_fn = jax.jit(make_train_step(model, AdamWConfig(lr=cfg.lr), rules,
                                      accum_steps=cfg.accum_steps),
                      in_shardings=(p_ns, o_ns, b_ns),
                      out_shardings=(p_ns, o_ns, None),
                      donate_argnums=(0, 1))

    ckpt = CheckpointManager(cfg.ckpt_dir, every=cfg.ckpt_every) \
        if cfg.ckpt_dir else None
    start = 0
    if ckpt is not None:
        restored, at = ckpt.restore({"params": params, "opt": opt_state},
                                    mesh=mesh,
                                    shardings={"params": p_spec,
                                               "opt": o_spec})
        if restored is not None:
            params, opt_state = restored["params"], restored["opt"]
            start = at + 1
            print(f"[train] resumed from step {at}")

    metrics = {}
    retries = 0
    step = start
    t0 = time.time()
    with set_mesh(mesh):
        while step < cfg.steps:
            try:
                if "fault" in hooks:
                    hooks["fault"](step)
                batch = jax.device_put(stream.batch_at(step), b_ns)
                params, opt_state, metrics = step_fn(params, opt_state,
                                                     batch)
                if step % cfg.log_every == 0:
                    m = {k: float(v) for k, v in metrics.items()}
                    dt = (time.time() - t0) / max(step - start + 1, 1)
                    print(f"[train] step {step} loss {m['loss']:.4f} "
                          f"gnorm {m['gnorm']:.3f} {dt*1e3:.0f} ms/step",
                          flush=True)
                if "on_step" in hooks:
                    hooks["on_step"](step, metrics)
                if ckpt is not None:
                    ckpt.maybe_save(step, {"params": params,
                                           "opt": opt_state})
                step += 1
            except (RuntimeError, ValueError):
                raise
            except Exception as e:   # simulated node failure -> restart
                retries += 1
                if ckpt is None or retries > cfg.max_retries:
                    raise
                print(f"[train] step {step} failed ({e}); "
                      f"restoring (retry {retries}/{cfg.max_retries})")
                restored, at = ckpt.restore(
                    {"params": params, "opt": opt_state}, mesh=mesh,
                    shardings={"params": p_spec, "opt": o_spec})
                if restored is None:
                    params = jax.device_put(
                        model.init(jax.random.key(cfg.seed)), p_ns)
                    opt_state = jax.device_put(adamw_init(params), o_ns)
                    step = 0
                else:
                    params, opt_state = restored["params"], restored["opt"]
                    step = at + 1
    if ckpt is not None:
        ckpt.maybe_save(cfg.steps, {"params": params, "opt": opt_state})
        ckpt.finalize()
    return {k: float(v) for k, v in metrics.items()} | {"last_step": step - 1}


def main() -> None:
    ap = argparse.ArgumentParser()
    for f in dataclasses.fields(TrainConfig):
        if f.type in ("bool", bool):
            ap.add_argument(f"--{f.name.replace('_', '-')}",
                            action="store_true", default=f.default)
        else:
            ap.add_argument(f"--{f.name.replace('_', '-')}",
                            type=type(f.default), default=f.default)
    args = ap.parse_args()
    cfg = TrainConfig(**{f.name: getattr(args, f.name)
                         for f in dataclasses.fields(TrainConfig)})
    out = train(cfg)
    print(json.dumps(out))


if __name__ == "__main__":
    main()
