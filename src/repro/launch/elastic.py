"""Elastic scaling: resume a checkpoint onto a different mesh.

Checkpoints are stored device-agnostic (host numpy), so elastic re-sharding
is restore + device_put with the new mesh's shardings. ``reshard`` is the
library entry; the CLI demonstrates shrink/grow:

  PYTHONPATH=src python -m repro.launch.elastic --arch internlm2-1.8b \
      --ckpt-dir /tmp/ckpt --mesh 2x1   # resume a 4x1 run on 2 devices

At 1000+-node scale the same path implements failure recovery: the launcher
detects a lost slice, rebuilds the mesh from surviving hosts (shrunk on the
'data' axis), and calls ``reshard`` — training continues from the last
atomic checkpoint with bitwise-identical data order (step-indexed PRNG)."""
from __future__ import annotations

import argparse

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.distributed.sharding import optimizer_shardings, param_shardings
from repro.launch.mesh import make_mesh
from repro.models import build
from repro.optim import adamw_init


def reshard(ckpt_dir: str, arch: str, mesh, *, smoke: bool = True):
    """Restore the latest checkpoint onto ``mesh``. Returns
    (params, opt_state, step) or (None, None, None)."""
    cfg = get_config(arch, smoke=smoke)
    model = build(cfg)
    params_like = jax.eval_shape(model.init, jax.random.key(0))
    opt_like = jax.eval_shape(adamw_init, params_like)
    p_spec = param_shardings(params_like, cfg, mesh)
    m_spec = optimizer_shardings(p_spec, params_like, mesh)
    o_spec = {"m": m_spec, "v": m_spec, "step": P()}
    mgr = CheckpointManager(ckpt_dir)
    like = {"params": jax.tree.map(lambda s: np.zeros(s.shape, s.dtype),
                                   params_like),
            "opt": jax.tree.map(lambda s: np.zeros(s.shape, s.dtype),
                                opt_like)}
    tree, step = mgr.restore(like, mesh=mesh,
                             shardings={"params": p_spec, "opt": o_spec})
    if tree is None:
        return None, None, None
    return tree["params"], tree["opt"], step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--mesh", default="", help="DxM; empty = all devices")
    ap.add_argument("--steps", type=int, default=0,
                    help="continue training this many extra steps")
    args = ap.parse_args()

    n = len(jax.devices())
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
    else:
        d, m = n, 1
    mesh = make_mesh((d, m), ("data", "model"))
    params, opt, step = reshard(args.ckpt_dir, args.arch, mesh)
    if params is None:
        raise SystemExit("no checkpoint found")
    print(f"resharded step-{step} checkpoint onto {d}x{m} mesh; "
          f"{sum(x.size for x in jax.tree.leaves(params)):,} params")
    if args.steps:
        from repro.launch.train import TrainConfig, train
        cfg = TrainConfig(arch=args.arch, steps=step + 1 + args.steps,
                          ckpt_dir=args.ckpt_dir, mesh=f"{d}x{m}")
        out = train(cfg)
        print(out)


if __name__ == "__main__":
    main()
