import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell we build the production mesh (16x16 single-pod or 2x16x16
multi-pod), construct abstract params / optimizer state / inputs as
ShapeDtypeStructs (no allocation), attach the sharding rules from
``repro.distributed.sharding``, then ``jit(...).lower(...).compile()``.
A successful compile proves the distribution config is coherent: every
parameter / activation / cache spec matches, the collectives the partitioner
emits are supported, and the per-device program fits in principle.

The compiled artifact is mined for the roofline inputs (per-device FLOPs /
HBM traffic / collective link bytes; see repro.analysis) and everything is
appended to a JSON report consumed by EXPERIMENTS.md and benchmarks.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
      --out results/dryrun.json
"""
import argparse
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import analyze_module, model_flops, roofline_terms
from repro.configs import ARCHS, SHAPES, cells, get_config
from repro.distributed.sharding import (activation_rules, batch_shardings,
                                        cache_shardings, optimizer_shardings,
                                        param_shardings)
from repro.launch.mesh import make_production_mesh, set_mesh
from repro.launch.steps import (batch_struct, make_prefill_step,
                                make_serve_step, make_train_step)
from repro.models import build
from repro.optim import AdamWConfig, adamw_init


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _struct(tree):
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)


def lower_cell(arch: str, shape_name: str, mesh, *, remat: str | None = None,
               overrides: dict | None = None, seq_parallel: bool = False):
    """Returns (lowered, cfg, meta) for one cell on ``mesh``."""
    import dataclasses as _dc
    cfg = get_config(arch)
    if remat is not None:
        cfg = _dc.replace(cfg, remat=remat)
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    spec = SHAPES[shape_name]
    model = build(cfg)
    rules = activation_rules(cfg, mesh, seq_parallel=seq_parallel)

    params_s = _struct(jax.eval_shape(model.init, jax.random.key(0)))
    p_ns = _ns(mesh, param_shardings(params_s, cfg, mesh))
    meta = {"arch": arch, "shape": shape_name, "kind": spec.kind,
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count()}

    with set_mesh(mesh):
        if spec.kind == "train":
            opt_s = _struct(jax.eval_shape(adamw_init, params_s))
            mom_specs = optimizer_shardings(
                param_shardings(params_s, cfg, mesh), params_s, mesh)
            o_ns = _ns(mesh, {"m": mom_specs, "v": mom_specs, "step": P()})
            batch_s = batch_struct(cfg, spec.global_batch, spec.seq_len)
            b_ns = _ns(mesh, batch_shardings(mesh, "train", batch_s))
            step = make_train_step(model, AdamWConfig(), rules)
            jitted = jax.jit(step, in_shardings=(p_ns, o_ns, b_ns),
                             out_shardings=(p_ns, o_ns, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_s, opt_s, batch_s)
            n_tokens = spec.global_batch * spec.seq_len
        elif spec.kind == "prefill":
            batch_s = batch_struct(cfg, spec.global_batch, spec.seq_len)
            b_ns = _ns(mesh, batch_shardings(mesh, "prefill", batch_s))
            step = make_prefill_step(model, rules)
            jitted = jax.jit(step, in_shardings=(p_ns, b_ns))
            lowered = jitted.lower(params_s, batch_s)
            n_tokens = spec.global_batch * spec.seq_len
        else:  # decode
            b = spec.global_batch
            caches_s = _struct(jax.eval_shape(
                functools.partial(model.init_caches, b, spec.seq_len)))
            c_ns = _ns(mesh, cache_shardings(caches_s, cfg, mesh))
            tok_s = jax.ShapeDtypeStruct((b, 1), jnp.int32)
            pos_s = jax.ShapeDtypeStruct((), jnp.int32)
            t_ns = _ns(mesh, batch_shardings(mesh, "decode", tok_s))
            if cfg.is_encdec:
                enc_s = _struct(jax.eval_shape(
                    lambda p, f: model._cross_kvs(p, model.encode(p, f)),
                    params_s, jax.ShapeDtypeStruct(
                        (b, cfg.encoder.n_frames, cfg.d_model),
                        jnp.dtype(cfg.dtype))))
                e_ns = _ns(mesh, batch_shardings(mesh, "decode", enc_s))
                step = make_serve_step(model, rules, with_enc=True)
                jitted = jax.jit(step, in_shardings=(
                    p_ns, c_ns, t_ns, None, e_ns),
                    out_shardings=(None, c_ns), donate_argnums=(1,))
                lowered = jitted.lower(params_s, caches_s, tok_s, pos_s,
                                       enc_s)
            else:
                step = make_serve_step(model, rules)
                jitted = jax.jit(step, in_shardings=(p_ns, c_ns, t_ns, None),
                                 out_shardings=(None, c_ns),
                                 donate_argnums=(1,))
                lowered = jitted.lower(params_s, caches_s, tok_s, pos_s)
            n_tokens = b  # one new token per sequence
    meta["n_tokens"] = n_tokens
    return lowered, cfg, meta


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             remat: str | None = None, overrides: dict | None = None,
             seq_parallel: bool = False, mesh=None) -> dict:
    """``mesh``: optional explicit mesh for ablations (e.g. 32x8 for
    yi-34b's 56-head TP=8 layout — EXPERIMENTS.md §Perf); the default is
    the fixed production mesh the dry-run gate requires."""
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    t0 = time.time()
    lowered, cfg, meta = lower_cell(arch, shape_name, mesh, remat=remat,
                                    overrides=overrides,
                                    seq_parallel=seq_parallel)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    mc = analyze_module(hlo, default_group=16)
    mf = model_flops(cfg, meta["n_tokens"], meta["kind"]) / n_dev
    rt = roofline_terms(mc, model_flops=mf)

    rec = dict(meta)
    rec.update({
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            # per-device live = args + temps (aliased args are reused)
            "live_bytes": mem.argument_size_in_bytes
            + mem.temp_size_in_bytes - mem.alias_size_in_bytes,
        },
        "xla_cost_analysis_flops": cost.get("flops", -1.0),
        "roofline": rt.as_dict(),
        "collective_counts": dict(mc.collective_counts),
        "while_trips": mc.while_trips[:8],
        "ok": True,
    })
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"],
                    default="off")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--out", default=None, help="append JSONL here")
    ap.add_argument("--skip-done", action="store_true",
                    help="skip cells already present in --out")
    args = ap.parse_args()

    todo = []
    if args.all:
        for a, s, skip, reason in cells(ARCHS):
            todo.append((a, s, skip, reason))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        sk = [c for c in cells([args.arch]) if c[1] == args.shape][0]
        todo.append(sk)

    pods = {"off": [False], "on": [True], "both": [False, True]}[
        args.multi_pod]
    done = set()
    if args.out and args.skip_done and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"]))
                except Exception:
                    pass

    n_fail = 0
    for arch, shape, skip, reason in todo:
        for mp in pods:
            mesh_name = "2x16x16" if mp else "16x16"
            key = (arch, shape, mesh_name)
            if key in done:
                print(f"[skip-done] {arch} x {shape} @ {mesh_name}")
                continue
            if skip:
                rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "ok": True, "skipped": True, "reason": reason}
                print(f"[SKIP] {arch} x {shape}: {reason}")
            else:
                print(f"[dryrun] {arch} x {shape} @ {mesh_name} ...",
                      flush=True)
                # train steps default to full remat (activations do not fit
                # HBM otherwise — see EXPERIMENTS.md §Perf iteration 0)
                remat = args.remat
                if remat is None and SHAPES[shape].kind == "train":
                    remat = "full"
                try:
                    rec = run_cell(arch, shape, multi_pod=mp, remat=remat)
                    r = rec["roofline"]
                    print(f"  ok: compile={rec['compile_s']}s "
                          f"live={rec['memory']['live_bytes']/2**30:.2f}GiB/dev "
                          f"compute={r['compute_s']*1e3:.2f}ms "
                          f"memory={r['memory_s']*1e3:.2f}ms "
                          f"collective={r['collective_s']*1e3:.2f}ms "
                          f"dominant={r['dominant']} "
                          f"useful={r['useful_ratio']:.2f}", flush=True)
                except Exception as e:
                    n_fail += 1
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "ok": False, "error": f"{type(e).__name__}: {e}"}
                    print(f"  FAIL: {type(e).__name__}: {e}")
                    traceback.print_exc()
            if args.out:
                os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")
    print("all cells ok")


if __name__ == "__main__":
    main()
