"""Production mesh construction (single-pod 16x16, multi-pod 2x16x16).

``jax.sharding.AxisType`` (and the ``axis_types`` kwarg of ``jax.make_mesh``)
only exist from jax 0.5.x; on older installs every axis is implicitly Auto,
so the fallback simply omits the kwarg — semantics are identical.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType as _AxisType
except ImportError:  # older jax: axes are Auto-typed by default
    _AxisType = None


def set_mesh(mesh):
    """Compat for ``jax.set_mesh`` (jax >= 0.5): on older jax the Mesh object
    itself is the context manager that installs the ambient mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def _mesh(shape, axes):
    if _AxisType is None:
        return jax.make_mesh(tuple(shape), tuple(axes))
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(_AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh(shape, axes):
    """General helper for tests/examples (Auto axis types, any size)."""
    return _mesh(shape, axes)


def preferred_tp(cfg, n_chips: int, max_tp: int = 16) -> int:
    """Divisibility-aware TP degree for an architecture.

    The 16x16 production mesh is the compatibility gate, but a TP degree
    that does not divide the head count (yi-34b: 56 heads), the expert
    count (grok-1: 8 experts), or the FFN width forces GSPMD to replicate
    or reshard attention/dispatch internals — measured 2-13x collective and
    ~2x memory penalties (EXPERIMENTS.md §Perf). Pick the largest TP that
    divides every sharded quantity; the launcher uses it when --mesh is
    not forced.
    """
    tp = max_tp
    while tp > 1:
        ok = (n_chips % tp == 0 and cfg.n_heads % tp == 0
              and cfg.d_ff % tp == 0)
        if cfg.moe is not None:
            # EP-first: splitting an expert's hidden dim costs ~3x vs exact
            # expert parallelism (grok-1 measurement, EXPERIMENTS.md §Perf)
            ok = ok and cfg.moe.n_experts % tp == 0
        if ok:
            return tp
        tp //= 2
    return 1


def preferred_mesh(cfg, n_chips: int = 256):
    """(data, model) mesh with the arch-preferred TP degree."""
    tp = preferred_tp(cfg, n_chips)
    return make_mesh((n_chips // tp, tp), ("data", "model"))
