from .gnn import GNNServer
from .mesh import make_mesh, make_production_mesh, set_mesh
from .steps import (batch_struct, make_prefill_step, make_serve_step,
                    make_train_step)

__all__ = ["GNNServer", "make_mesh", "make_production_mesh", "set_mesh",
           "batch_struct", "make_prefill_step", "make_serve_step",
           "make_train_step"]
