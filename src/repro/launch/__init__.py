from .mesh import make_mesh, make_production_mesh
from .steps import (batch_struct, make_prefill_step, make_serve_step,
                    make_train_step)

__all__ = ["make_mesh", "make_production_mesh", "batch_struct",
           "make_prefill_step", "make_serve_step", "make_train_step"]
