"""GNN embedding-serving driver over an ExecutionPlan.

The GNN analogue of ``launch.serve``: requests are node-embedding lookups
against a graph whose embeddings are refreshed by running the plan's forward
(centralized, decentralized, or semi-decentralized — paper Fig. 4 / §5), on
any of the kernel backends (``jnp``, ``pallas``, ``fused``). The fused
backend runs each layer's aggregation + crossbar MVM in a single kernel with
Z resident in VMEM (DESIGN.md §5), so every setting benefits — this is the
serving-path entry point the benchmark sweep and the examples drive.

  PYTHONPATH=src python -m repro.launch.gnn --setting semi --backend fused \
      --clusters 4 --sample 8 --requests 64

Streaming mode (``--stream N``) serves the same plan through
``repro.streaming.StreamingGNNServer``: N synthetic feature ticks are
ingested under the chosen refresh ``--policy``, embeddings refresh
incrementally over the k-hop dirty frontier, and the driver prints
recomputed-node fraction and measured incremental traffic (DESIGN.md §9):

  PYTHONPATH=src python -m repro.launch.gnn --setting decentralized \
      --stream 16 --churn 0.05 --policy bounded-staleness

``--plan auto`` delegates the configuration choice to the adaptive planner
(``repro.planner``, DESIGN.md §10): setting, backend, cluster count,
refresh policy, and neighbor mode come from the planner's recommendation
for this dataset's statistics and the requested churn/query workload.

Feature-similarity scenarios (``--dataset recsys|anomaly``) arrive as bare
feature vectors: the served graph is *built* by CAM-backed k-NN search
(``repro.neighbors``, DESIGN.md §15) on the ``--neighbor-mode`` path —
``cam`` / ``cam-pallas`` run associative band matching on the traversal
CAM kernel, ``topk`` the result-identical host fallback. In stream mode
the same flag routes dirty-frontier membership through the CAM
(``streaming.frontier``):

  PYTHONPATH=src python -m repro.launch.gnn --dataset recsys \
      --neighbor-mode cam --stream 8 --churn 0.05
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import telemetry as tel
from repro.core import costmodel, dataset_like, gnn
from repro.core.partition import ExecutionPlan, plan_execution
from repro.launch.mesh import make_mesh


class GNNServer:
    """Embedding server: refresh via the plan's forward, serve row lookups.

    Staleness is version-tracked: ``update_params`` / ``update_plan`` bump
    ``self.version``, and ``query`` refreshes whenever the served
    embeddings were computed at an older version (not only when they have
    never been computed). Mutating ``self.params`` in place bypasses the
    tracking — use the setters.
    """

    def __init__(self, plan: ExecutionPlan, cfg: gnn.GNNConfig,
                 params=None, mesh=None, seed: int = 0,
                 mode: str = "alltoall"):
        self.plan = plan
        self.cfg = plan.gnn_config(cfg)
        self.params = params if params is not None else gnn.init_params(
            jax.random.key(seed), self.cfg)
        self._mesh = mesh
        self._forward = None    # built lazily: subclasses that refresh
        #                         through another engine never pay for it
        self.mode = mode
        self.embeddings: np.ndarray | None = None
        self.refreshes = 0
        self.version = 0            # params/graph generation counter
        self._served_version = -1   # version the embeddings were built at

    def update_params(self, params) -> None:
        """Swap model parameters; served embeddings become stale."""
        self.params = params
        self.version += 1

    def update_plan(self, plan: ExecutionPlan, cfg=None) -> None:
        """Swap the execution plan (graph changed / repartitioned); rebuilds
        the forward and marks served embeddings stale."""
        cfg = cfg if cfg is not None else self.cfg
        self.plan = plan
        self.cfg = plan.gnn_config(cfg)
        self._forward = None
        self.version += 1

    @property
    def stale(self) -> bool:
        return self.embeddings is None or self._served_version != self.version

    def refresh(self) -> float:
        """Recompute all node embeddings; returns wall-clock seconds."""
        t0 = time.perf_counter()
        with tel.span("server.refresh", setting=self.plan.setting):
            if self._forward is None:
                self._forward = self.plan.make_forward(
                    self.cfg, mesh=self._mesh, mode=self.mode)
            out = jax.block_until_ready(self._forward(self.params))
            # bucketed plans return a tuple of ragged per-bucket tables;
            # scatter handles both shapes (np.asarray would choke on a tuple)
            self.embeddings = self.plan.scatter(out)
        self.refreshes += 1
        self._served_version = self.version
        return time.perf_counter() - t0

    def query(self, node_ids) -> np.ndarray:
        """Serve one batch of embedding lookups (refresh if stale).

        Batched: ids are validated against the *served* embedding table
        (out-of-range ids raise IndexError naming the offending bound —
        after ``update_plan`` to a smaller graph, stale ids fail loudly
        instead of wrapping); any batch shape gathers in one fancy index.
        """
        with tel.span("server.query"):
            if self.stale:
                self.refresh()
            ids = np.asarray(node_ids, np.int64)
            n = len(self.embeddings)
            if ids.size and (ids.min() < 0 or ids.max() >= n):
                raise IndexError(
                    f"node ids must be in [0, {n}); batch spans "
                    f"[{ids.min()}, {ids.max()}]")
            out = self.embeddings[ids]
            tel.counter("server.queries").inc(ids.size)
        return out


def stream_main(args, g, plan, cfg) -> None:
    """--stream driver: ingest a synthetic tick stream, serve batched
    lookups between commits, report incremental refresh statistics."""
    from repro.streaming import StreamingGNNServer
    frontier = {"topk": "numpy", "cam": "cam",
                "cam-pallas": "cam-pallas"}[args.neighbor_mode]
    srv = StreamingGNNServer(plan, cfg, mode=args.mode, policy=args.policy,
                             frontier_mode=frontier)
    t_cold = srv.refresh()
    print(f"plan: {args.setting}/{args.backend}, {g.n_nodes} nodes, "
          f"{plan.n_clusters} clusters; policy {args.policy}; "
          f"frontier membership via {frontier}; "
          f"cold full refresh {t_cold * 1e3:.1f} ms")
    rng = np.random.default_rng(0)
    served = 0
    inc_bytes = 0
    loop_commits = 0
    t0 = time.perf_counter()
    for tick in range(args.stream):
        n_mut = max(int(g.n_nodes * args.churn), 1)
        nodes = rng.choice(g.n_nodes, n_mut, replace=False)
        rows = rng.normal(size=(n_mut, g.feature_len)).astype(np.float32)
        upd = srv.ingest(nodes=nodes, rows=rows)
        if upd is not None:
            loop_commits += 1
            if upd.traffic is not None:
                inc_bytes += upd.traffic.total_bytes()
        served += len(srv.query(rng.integers(0, g.n_nodes, args.batch)))
    dt = time.perf_counter() - t0
    # the cold-start commit is a full refresh by construction — keep it out
    # of the incremental statistics it would otherwise bias
    fracs = [u.recompute_fraction for u in srv.updates if not u.full]
    print(f"{args.stream} ticks, {srv.commits} commits "
          f"({srv.full_refreshes} full), mean incremental recompute "
          f"fraction {float(np.mean(fracs)) if fracs else 1.0:.3f}")
    if plan.setting != "centralized" and loop_commits:
        print(f"measured incremental traffic {inc_bytes / 1e6:.3f} MB "
              f"(full-refresh equivalent "
              f"{plan.measured_traffic(srv.cfg, mode=args.mode).total_bytes() * loop_commits / 1e6:.3f} MB)")
    print(f"served {served} lookups alongside the stream in "
          f"{dt * 1e3:.1f} ms")


def _dump_telemetry(args) -> None:
    """--metrics / --trace exit dumps (telemetry enabled in main)."""
    if args.metrics:
        n = tel.export_metrics(args.metrics)
        print(f"telemetry: wrote {n} metric/event lines to {args.metrics}")
    if args.trace:
        n = tel.export_trace(args.trace)
        print(f"telemetry: wrote {n} span trees to {args.trace}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--setting", default="decentralized",
                    choices=("centralized", "decentralized", "semi"))
    ap.add_argument("--backend", default="fused",
                    choices=gnn.BACKENDS)
    ap.add_argument("--dataset", default="collab",
                    help="a Table-2 name / 'taxi' (dataset_like), or a "
                         "feature-similarity scenario 'recsys'/'anomaly' "
                         "whose graph is built by k-NN search "
                         "(repro.neighbors)")
    ap.add_argument("--scale", type=float, default=0.001)
    ap.add_argument("--neighbor-mode", default="topk", dest="neighbor_mode",
                    choices=("topk", "cam", "cam-pallas"),
                    help="neighbor selection / frontier membership path "
                         "(DESIGN.md §15): scenario k-NN construction and "
                         "stream-mode dirty-frontier tests run on the "
                         "traversal CAM ('cam' = jnp oracle kernel, "
                         "'cam-pallas' = Pallas kernel) or the "
                         "result-identical host fallback ('topk')")
    ap.add_argument("--clusters", type=int, default=0,
                    help="default: one per device (decentralized) / "
                         "4 heads (semi)")
    ap.add_argument("--spokes", type=int, default=4,
                    help="semi: member edge devices per cluster head")
    ap.add_argument("--mode", default="alltoall",
                    choices=("allgather", "alltoall"),
                    help="halo-exchange strategy (semi: tier-1)")
    ap.add_argument("--buckets", default="off", metavar="auto|off|N",
                    help="capacity-bucketed ragged layout (DESIGN.md §12): "
                         "'auto' buckets clusters by pow2 capacity, an int "
                         "caps the bucket count, 'off' keeps dense padding")
    ap.add_argument("--sample", type=int, default=8)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--mapping", action="store_true",
                    help="print the compiled crossbar mapping report "
                         "(DESIGN.md §8)")
    ap.add_argument("--tune", action="store_true",
                    help="autotune the plan's Pallas kernel launches "
                         "before serving (repro.tuning, DESIGN.md §11); "
                         "winners cache to --tune-cache")
    ap.add_argument("--tune-cache", default=None, metavar="PATH",
                    help="tuned-config cache file (default: "
                         "results/tuned_configs.json)")
    ap.add_argument("--stream", type=int, default=0, metavar="TICKS",
                    help="serve a TICKS-long synthetic feature stream "
                         "through StreamingGNNServer (incremental refresh)")
    ap.add_argument("--churn", type=float, default=0.05,
                    help="stream mode: fraction of nodes mutated per tick")
    ap.add_argument("--policy", default="eager",
                    choices=("eager", "interval", "bounded-staleness"),
                    help="stream mode: refresh policy")
    ap.add_argument("--plan", default="manual", dest="plan_mode",
                    choices=("manual", "auto"),
                    help="auto: let repro.planner pick setting/backend/"
                         "clusters/policy for this workload (DESIGN.md §10)")
    ap.add_argument("--tech", default=None, metavar="NAME[+NAME]",
                    help="device technology for the derived cost/mapping "
                         "reports (sot-mram, reram, sram, fefet; "
                         "DESIGN.md §13); a 'spoke+head' pair like "
                         "'reram+sram' bills ReRAM spoke storage under "
                         "SRAM cluster heads (semi setting); with "
                         "--plan auto the planner searches within it")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="enable telemetry; dump the metrics registry "
                         "(counters/gauges/histograms + audit events) as "
                         "JSONL to PATH on exit (DESIGN.md §14)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable telemetry; export the recorded span trees "
                         "as JSONL to PATH on exit")
    args = ap.parse_args()
    if args.metrics or args.trace:
        tel.enable()

    tech = None
    if args.tech:
        tech = (tuple(args.tech.split("+")) if "+" in args.tech
                else args.tech)
        from repro.devices import resolve_technology
        for t in (tech if isinstance(tech, tuple) else (tech,)):
            resolve_technology(t)       # typos fail here, by name
    from repro.neighbors import SCENARIOS, scenario_graph
    if args.dataset in SCENARIOS:
        g = scenario_graph(
            args.dataset, n_nodes=max(int(200_000 * args.scale), 32),
            feature_len=32, k=args.sample,
            neighbor_mode="topk" if args.neighbor_mode == "topk" else "cam",
            backend="pallas" if args.neighbor_mode == "cam-pallas"
            else "jnp").gcn_normalize()
        print(f"{args.dataset}: built k-NN graph on the "
              f"{args.neighbor_mode} path — {g.n_nodes} nodes, "
              f"{g.n_edges} similarity edges")
    else:
        g = dataset_like(args.dataset, scale=args.scale,
                         seed=0).gcn_normalize()
    if args.plan_mode == "auto":
        from repro.planner import WorkloadProfile, plan as plan_search
        wl = WorkloadProfile(
            churn=args.churn if args.stream else 0.0,
            queries_per_tick=float(args.batch),
            sample=args.sample)
        objective = "throughput" if args.stream else "latency"
        result = plan_search(g, objective, workload=wl, shortlist=2,
                             **(dict(technologies=(tech,)) if tech else {}))
        print(result.summary())
        rec = result.recommended.candidate
        args.setting, args.backend = rec.setting, rec.backend
        args.clusters, args.policy = rec.n_clusters, rec.policy
        if args.neighbor_mode != "cam-pallas":
            # keep an explicit pallas request; otherwise follow the
            # planner's priced neighbor_mode axis
            args.neighbor_mode = rec.neighbor_mode
    n_dev = len(jax.devices())
    k = args.clusters or (n_dev if args.setting == "decentralized" else 4)
    buckets = args.buckets if args.buckets in ("auto", "off") \
        else int(args.buckets)
    plan = plan_execution(g, args.setting, backend=args.backend,
                          sample=args.sample,
                          n_clusters=None if args.setting == "centralized"
                          else k,
                          spokes_per_head=args.spokes,
                          buckets=buckets)
    mesh = (make_mesh((n_dev,), ("data",))
            if plan.n_clusters == n_dev and args.setting != "centralized"
            and plan.bucketed is None else None)
    if plan.bucketed is not None:
        ls = plan.layout_stats()
        print(f"bucketed layout: {plan.bucketed.n_buckets} buckets, "
              f"caps {plan.bucketed.n_caps}; padding ratio "
              f"{ls['padding_ratio']:.2f}x vs dense "
              f"{ls['dense_padding_ratio']:.2f}x, peak device bytes "
              f"{ls['peak_device_bytes']:,} vs dense "
              f"{ls['dense_peak_device_bytes']:,}")
    cfg = gnn.GNNConfig(in_dim=g.feature_len, hidden_dims=(args.hidden,),
                        out_dim=16, sample=args.sample)
    if args.tune:
        from repro.tuning import DEFAULT_CACHE_PATH, TuneCache
        cache = TuneCache.load(args.tune_cache or DEFAULT_CACHE_PATH)
        tuned = plan.tune_kernels(cfg, cache=cache)
        print(f"autotuned {len(tuned)} kernel geometries "
              f"(cache: {cache.path}, {len(cache)} entries)")
    if args.stream:
        stream_main(args, g, plan, cfg)
        return _dump_telemetry(args)
    srv = GNNServer(plan, cfg, mesh=mesh, mode=args.mode)

    dt = srv.refresh()
    print(f"plan: {args.setting}/{args.backend}, {g.n_nodes} nodes, "
          f"{plan.n_clusters} clusters on {n_dev} devices; "
          f"embedding refresh {dt * 1e3:.1f} ms")
    if args.setting != "centralized":
        print("measured traffic —",
              plan.measured_traffic(cfg, mode=args.mode).summary())

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    served = 0
    for _ in range(args.requests):
        ids = rng.integers(0, g.n_nodes, args.batch)
        out = srv.query(ids)
        served += len(ids)
    dt = time.perf_counter() - t0
    print(f"served {served} lookups in {dt * 1e3:.1f} ms "
          f"({served / dt:.0f} lookups/s)")

    # a per-tier pair prices the mapper with the head (compute) tier; the
    # spoke tier only bills storage energy, which the planner accounts
    head_tech = tech[-1] if isinstance(tech, tuple) else tech
    m = plan.predicted_metrics(**(dict(mode="derived", technology=head_tech)
                                  if tech else {}))
    label = f"{args.setting}, {args.tech}" if tech else args.setting
    print(f"cost model ({label}): T_compute {m.t_compute:.3e} s, "
          f"T_comm {m.t_communicate:.3e} s, P {m.p_net * 1e3:.1f} mW")
    mapping = plan.compile_mapping(cfg, technology=head_tech)
    print(f"mapper-derived T_compute {mapping.t_compute:.3e} s "
          f"({mapping.t_compute / max(m.t_compute, 1e-30):.2f}x calibrated); "
          f"run with --mapping for the full report")
    if args.mapping:
        print(plan.mapping_report())    # reuses the cached mapping
    best, _ = costmodel.pick_setting(g.stats(args.dataset),
                                     n_clusters=plan.n_clusters)
    print(f"cost-model guideline for this graph: {best}")
    _dump_telemetry(args)


if __name__ == "__main__":
    main()
