"""GNN embedding-serving driver over an ExecutionPlan.

The GNN analogue of ``launch.serve``: requests are node-embedding lookups
against a graph whose embeddings are refreshed by running the plan's forward
(centralized, decentralized, or semi-decentralized — paper Fig. 4 / §5), on
any of the kernel backends (``jnp``, ``pallas``, ``fused``). The fused
backend runs each layer's aggregation + crossbar MVM in a single kernel with
Z resident in VMEM (DESIGN.md §5), so every setting benefits — this is the
serving-path entry point the benchmark sweep and the examples drive.

  PYTHONPATH=src python -m repro.launch.gnn --setting semi --backend fused \
      --clusters 4 --sample 8 --requests 64
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import costmodel, dataset_like, gnn
from repro.core.partition import ExecutionPlan, plan_execution
from repro.launch.mesh import make_mesh


class GNNServer:
    """Embedding server: refresh via the plan's forward, serve row lookups.

    Staleness is version-tracked: ``update_params`` / ``update_plan`` bump
    ``self.version``, and ``query`` refreshes whenever the served
    embeddings were computed at an older version (not only when they have
    never been computed). Mutating ``self.params`` in place bypasses the
    tracking — use the setters.
    """

    def __init__(self, plan: ExecutionPlan, cfg: gnn.GNNConfig,
                 params=None, mesh=None, seed: int = 0,
                 mode: str = "alltoall"):
        self.plan = plan
        self.cfg = plan.gnn_config(cfg)
        self.params = params if params is not None else gnn.init_params(
            jax.random.key(seed), self.cfg)
        self._mesh = mesh
        self._forward = plan.make_forward(cfg, mesh=mesh, mode=mode)
        self.mode = mode
        self.embeddings: np.ndarray | None = None
        self.refreshes = 0
        self.version = 0            # params/graph generation counter
        self._served_version = -1   # version the embeddings were built at

    def update_params(self, params) -> None:
        """Swap model parameters; served embeddings become stale."""
        self.params = params
        self.version += 1

    def update_plan(self, plan: ExecutionPlan, cfg=None) -> None:
        """Swap the execution plan (graph changed / repartitioned); rebuilds
        the forward and marks served embeddings stale."""
        cfg = cfg if cfg is not None else self.cfg
        self.plan = plan
        self.cfg = plan.gnn_config(cfg)
        self._forward = plan.make_forward(cfg, mesh=self._mesh,
                                          mode=self.mode)
        self.version += 1

    @property
    def stale(self) -> bool:
        return self.embeddings is None or self._served_version != self.version

    def refresh(self) -> float:
        """Recompute all node embeddings; returns wall-clock seconds."""
        t0 = time.perf_counter()
        out = jax.block_until_ready(self._forward(self.params))
        self.embeddings = self.plan.scatter(np.asarray(out))
        self.refreshes += 1
        self._served_version = self.version
        return time.perf_counter() - t0

    def query(self, node_ids) -> np.ndarray:
        """Serve one batch of embedding lookups (refresh if stale)."""
        if self.stale:
            self.refresh()
        return self.embeddings[np.asarray(node_ids)]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--setting", default="decentralized",
                    choices=("centralized", "decentralized", "semi"))
    ap.add_argument("--backend", default="fused",
                    choices=gnn.BACKENDS)
    ap.add_argument("--dataset", default="collab")
    ap.add_argument("--scale", type=float, default=0.001)
    ap.add_argument("--clusters", type=int, default=0,
                    help="default: one per device (decentralized) / "
                         "4 heads (semi)")
    ap.add_argument("--spokes", type=int, default=4,
                    help="semi: member edge devices per cluster head")
    ap.add_argument("--mode", default="alltoall",
                    choices=("allgather", "alltoall"),
                    help="halo-exchange strategy (semi: tier-1)")
    ap.add_argument("--sample", type=int, default=8)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--mapping", action="store_true",
                    help="print the compiled crossbar mapping report "
                         "(DESIGN.md §8)")
    args = ap.parse_args()

    g = dataset_like(args.dataset, scale=args.scale, seed=0).gcn_normalize()
    n_dev = len(jax.devices())
    k = args.clusters or (n_dev if args.setting == "decentralized" else 4)
    plan = plan_execution(g, args.setting, backend=args.backend,
                          sample=args.sample,
                          n_clusters=None if args.setting == "centralized"
                          else k,
                          spokes_per_head=args.spokes)
    mesh = (make_mesh((n_dev,), ("data",))
            if plan.n_clusters == n_dev and args.setting != "centralized"
            else None)
    cfg = gnn.GNNConfig(in_dim=g.feature_len, hidden_dims=(args.hidden,),
                        out_dim=16, sample=args.sample)
    srv = GNNServer(plan, cfg, mesh=mesh, mode=args.mode)

    dt = srv.refresh()
    print(f"plan: {args.setting}/{args.backend}, {g.n_nodes} nodes, "
          f"{plan.n_clusters} clusters on {n_dev} devices; "
          f"embedding refresh {dt * 1e3:.1f} ms")
    if args.setting != "centralized":
        print("measured traffic —",
              plan.measured_traffic(cfg, mode=args.mode).summary())

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    served = 0
    for _ in range(args.requests):
        ids = rng.integers(0, g.n_nodes, args.batch)
        out = srv.query(ids)
        served += len(ids)
    dt = time.perf_counter() - t0
    print(f"served {served} lookups in {dt * 1e3:.1f} ms "
          f"({served / dt:.0f} lookups/s)")

    m = plan.predicted_metrics()
    print(f"cost model ({args.setting}): T_compute {m.t_compute:.3e} s, "
          f"T_comm {m.t_communicate:.3e} s, P {m.p_net * 1e3:.1f} mW")
    mapping = plan.compile_mapping(cfg)
    print(f"mapper-derived T_compute {mapping.t_compute:.3e} s "
          f"({mapping.t_compute / max(m.t_compute, 1e-30):.2f}x calibrated); "
          f"run with --mapping for the full report")
    if args.mapping:
        print(plan.mapping_report())    # reuses the cached mapping
    best, _ = costmodel.pick_setting(g.stats(args.dataset),
                                     n_clusters=plan.n_clusters)
    print(f"cost-model guideline for this graph: {best}")


if __name__ == "__main__":
    main()
