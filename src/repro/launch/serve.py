"""Batched serving driver: length-bucketed cohort batching.

Requests are bucketed by prompt length; a cohort of up to ``slots`` equal-
length prompts shares one compiled decode step (one cache pool, one position
counter — fixed shapes, so a single XLA executable serves the whole
workload). Prefill is teacher-forced batched decode over the prompt;
finished sequences idle (their sampled tokens are discarded) until the
cohort retires. This is the static-batching strategy production serving
stacks fall back to when per-slot position vectors are unavailable; the
dry-run's ``decode_32k`` cell is exactly one such cohort step at scale.

  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
      --slots 4 --max-new 16 --requests 8
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from collections import defaultdict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.steps import make_serve_step
from repro.models import build


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    """Length-bucketed static batching over ``slots`` concurrent slots."""

    def __init__(self, arch: str, *, smoke: bool = True, slots: int = 4,
                 capacity: int = 128, seed: int = 0):
        self.cfg = get_config(arch, smoke=smoke)
        assert not self.cfg.is_encdec, "serve driver targets decoder LMs"
        self.model = build(self.cfg)
        self.params = self.model.init(jax.random.key(seed))
        self.slots = slots
        self.capacity = capacity
        self.buckets: dict = defaultdict(list)      # prompt len -> requests
        self._step = jax.jit(make_serve_step(self.model))
        self.steps_run = 0

    def submit(self, req: Request):
        self.buckets[len(req.prompt)].append(req)

    # ------------------------------------------------------------ cohorts
    def _next_cohort(self) -> list:
        for ln in sorted(self.buckets, key=lambda l: -len(self.buckets[l])):
            if self.buckets[ln]:
                reqs = self.buckets[ln][:self.slots]
                self.buckets[ln] = self.buckets[ln][len(reqs):]
                return reqs
        return []

    def _run_cohort(self, reqs: list):
        b = self.slots
        plen = len(reqs[0].prompt)
        max_new = max(r.max_new for r in reqs)
        assert plen + max_new <= self.capacity, "capacity too small"
        caches = self.model.init_caches(b, self.capacity)
        prompts = np.zeros((b, plen), np.int32)
        for i, r in enumerate(reqs):
            prompts[i] = r.prompt
        # teacher-forced batched prefill (shared position counter)
        logits = None
        for p in range(plen):
            tok = jnp.asarray(prompts[:, p:p + 1])
            logits, caches = self._step(self.params, caches, tok,
                                        jnp.int32(p))
            self.steps_run += 1
        # batched decode; finished slots idle until cohort retires
        tok = jnp.argmax(logits[:, :, :], axis=-1).astype(jnp.int32)
        for n in range(max_new):
            for i, r in enumerate(reqs):
                if len(r.out) < r.max_new:
                    r.out.append(int(tok[i, 0]))
                    r.done = len(r.out) >= r.max_new
            if all(r.done for r in reqs):
                break
            logits, caches = self._step(self.params, caches, tok,
                                        jnp.int32(plen + n))
            self.steps_run += 1
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def run(self) -> int:
        """Serve everything queued. Returns total generated tokens."""
        total = 0
        while True:
            cohort = self._next_cohort()
            if not cohort:
                break
            self._run_cohort(cohort)
            total += sum(len(r.out) for r in cohort)
        return total


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--full", action="store_true",
                    help="full config (default: smoke-reduced)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()

    srv = Server(args.arch, smoke=not args.full, slots=args.slots,
                 capacity=args.capacity)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(
        0, srv.cfg.vocab, int(rng.choice([3, 3, 5]))).tolist(),
        args.max_new) for i in range(args.requests)]
    for r in reqs:
        srv.submit(r)
    t0 = time.time()
    total = srv.run()
    dt = time.time() - t0
    print(f"served {len(reqs)} requests, {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s, {srv.steps_run} batched steps)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt {r.prompt} -> {r.out[:8]}...")


if __name__ == "__main__":
    main()
