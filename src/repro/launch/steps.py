"""Step-function builders shared by the trainer, server, and dry-run.

``make_train_step`` closes over (model, optimizer config, activation rules)
and returns a pure (params, opt_state, batch) -> (params, opt_state, metrics)
function. ``make_serve_step`` returns the single-token decode step.
Activation-sharding rules are installed *around tracing* so the logical
constraints bake into the jaxpr.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import Transformer, activation_sharding
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_update


def make_train_step(model: Transformer, opt_cfg: AdamWConfig,
                    act_rules: dict | None = None, accum_steps: int = 1):
    """``accum_steps`` > 1: microbatched gradient accumulation — the global
    batch is split on the leading dim and scanned; one optimizer update per
    outer step. Besides fitting bigger global batches, the per-microbatch
    backward lets XLA overlap the DP gradient all-reduce of microbatch i
    with the compute of microbatch i+1 (latency hiding)."""
    rules = act_rules or {}

    def grad_fn(params, batch):
        with activation_sharding(rules):
            return jax.value_and_grad(model.loss, has_aux=True)(params,
                                                                batch)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            (loss, aux), grads = grad_fn(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)

            def acc(carry, mb):
                g_sum, l_sum, lb_sum = carry
                (l, aux), g = grad_fn(params, mb)
                g_sum = jax.tree.map(jnp.add, g_sum, g)
                return (g_sum, l_sum + l,
                        lb_sum + aux.get("load_balance", 0.0)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (g_sum, l_sum, lb_sum), _ = jax.lax.scan(
                acc, (zeros, jnp.zeros(()), jnp.zeros(())), micro)
            grads = jax.tree.map(lambda g: g / accum_steps, g_sum)
            loss = l_sum / accum_steps
            aux = {"ce": loss, "load_balance": lb_sum / accum_steps}
        params, opt_state, gnorm = adamw_update(params, grads, opt_state,
                                                opt_cfg)
        metrics = {"loss": loss, "gnorm": gnorm,
                   "ce": aux.get("ce", loss),
                   "load_balance": aux.get("load_balance", jnp.zeros(()))}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(model: Transformer, act_rules: dict | None = None):
    rules = act_rules or {}

    def prefill_step(params, batch):
        with activation_sharding(rules):
            logits, _ = model.prefill(params, batch["tokens"],
                                      frames=batch.get("frames"),
                                      mrope_pos=batch.get("mrope_pos"))
        return logits

    return prefill_step


def make_serve_step(model: Transformer, act_rules: dict | None = None,
                    with_enc: bool = False):
    rules = act_rules or {}

    if with_enc:
        def serve_step(params, caches, token, pos_idx, enc_kvs):
            with activation_sharding(rules):
                logits, caches = model.decode_step(params, token, caches,
                                                   pos_idx, enc_kvs=enc_kvs)
            return logits, caches
    else:
        def serve_step(params, caches, token, pos_idx):
            with activation_sharding(rules):
                logits, caches = model.decode_step(params, token, caches,
                                                   pos_idx)
            return logits, caches

    return serve_step


def batch_struct(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """Abstract train/prefill batch (ShapeDtypeStructs, no allocation)."""
    i32 = jnp.int32
    out = {"tokens": jax.ShapeDtypeStruct((batch, seq), i32),
           "labels": jax.ShapeDtypeStruct((batch, seq), i32)}
    if cfg.is_encdec:
        out["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder.n_frames, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.mrope_sections:
        out["mrope_pos"] = jax.ShapeDtypeStruct((3, batch, seq), i32)
    return out
