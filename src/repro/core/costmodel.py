"""IMA-GNN network model (paper §3, Eqs. 1-7) — latency & power of centralized,
decentralized, and (beyond-paper) semi-decentralized GNN execution.

The paper composes its numbers bottom-up: HSPICE/NVSIM-CAM/MNSIM extract
per-core latency/power primitives, and a MATLAB network model applies
Eqs. 1-7. This module replaces that MATLAB layer 1:1. The per-core
primitives are *calibrated to the paper's own Table 1* (the circuit-level
stack has no TPU analogue — see DESIGN.md §2), and the link constants are
calibrated so that both Table 1's taxi numbers and the two headline averages
(~790x communication, ~1400x computation) are reproduced from first
principles rather than hard-coded.

Calibration (derivations in EXPERIMENTS.md §Paper-validation):
  * Core multiplicities  M = (2000, 1000, 256)  — the centralized setting has
    2Kx(512x32) CAM, 1Kx(512x512) MVM, 256x(128x128) MVM crossbars vs one of
    each per decentralized node (paper §4.1), i.e. M_i = #crossbars.
  * Per-node core latencies t = Table-1 centralized values inverted through
    Eq. 3 with N = 10 000: t_i = T_cent_i / (N-1) * M_i.
  * Link model: t(L_n) = 3.3 ms (V2X, 864-byte packet — paper §4.2);
    t(L_c), t_e solved from {Table-1 decentralized comm = 406 ms with c_s=10}
    and {4-dataset mean centralized comm speed-up = 790x}:
    t(L_c) = 18.496 ms, t_e = 18.04 ms.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

from .graph import GraphStats, TAXI_STATS, TABLE2_DATASETS

Setting = Literal["centralized", "decentralized", "semi"]


@dataclasses.dataclass(frozen=True)
class HardwareParams:
    """Calibrated IMA-GNN hardware model parameters."""
    # centralized-core crossbar multiplicities (M1, M2, M3), paper §3
    m1: float = 2000.0
    m2: float = 1000.0
    m3: float = 256.0
    # per-node, per-inference core latencies [s] for the calibration workload
    # (taxi: S<=512 sampled neighbors, 216-dim features)
    t1: float = 38.43e-9 / 9999 * 2000    # traversal   = 7.687 ns
    t2: float = 142.77e-6 / 9999 * 1000   # aggregation = 14.278 us
    t3: float = 14.53e-6 / 9999 * 256     # feat. extr. = 0.372 us
    # core power draws [W] (Table 1)
    p_cores_cent: tuple = (10.8e-3, 780.1e-3, 32.21e-3)
    p_cores_dec: tuple = (0.21e-3, 41.6e-3, 3.68e-3)
    # link model [s] / [W] / [J/bit]
    t_ln: float = 3.3e-3       # inter-network (V2X) one concurrent transfer
    t_lc: float = 18.496e-3    # inter-cluster ad-hoc hop latency
    t_e: float = 18.04e-3      # peer connection establishment
    p_ln: float = 100e-3       # inter-network link power
    e_per_bit: float = 50e-9   # ad-hoc radio energy per bit (Eq. 7)
    # crossbar geometry (paper §4.1), used by the workload-scaled mode
    cam_rows: int = 512
    cam_cols: int = 32
    agg_rows: int = 512
    agg_cols: int = 512
    fx_rows: int = 128
    fx_cols: int = 128
    # decentralized per-node crossbar counts (1 each in the paper's baseline;
    # §4.3 notes linear scaling until the feature data fits)
    n_xbar_dec: tuple = (1, 1, 1)


DEFAULT_HW = HardwareParams()


@dataclasses.dataclass(frozen=True)
class CoreLatency:
    traversal: float
    aggregation: float
    feature_extraction: float

    @property
    def total(self) -> float:
        return self.traversal + self.aggregation + self.feature_extraction


@dataclasses.dataclass(frozen=True)
class NetMetrics:
    """Eq. 1 / Eq. 6 outputs plus the per-core breakdown."""
    setting: str
    compute: CoreLatency
    t_compute: float
    t_communicate: float
    p_compute: float
    p_communicate: float

    @property
    def t_net(self) -> float:
        return self.t_compute + self.t_communicate

    @property
    def p_net(self) -> float:
        return self.p_compute + self.p_communicate


def _workload_passes(stats: GraphStats, hw: HardwareParams,
                     sample: int | None = None):
    """Crossbar passes per node for (traversal, aggregation, fx), relative to
    the taxi calibration workload (1 pass per core).

    Traversal: one CAM search per ceil(neighbors / cam_rows) block.
    Aggregation: neighbor rows x feature columns tiling of the MVM crossbar.
    Feature extraction: F x F_hidden matmul tiled on the fx crossbar; the
    taxi calibration point is a 216->128 layer (one 2-tile pass, normalized).
    """
    s = sample if sample is not None else min(stats.avg_cs, hw.agg_rows)
    f = max(stats.feature_len, 1)
    trav = math.ceil(max(stats.avg_cs, 1) / hw.cam_rows)
    agg = math.ceil(s / hw.agg_rows) * math.ceil(f / hw.agg_cols)
    # calibration workload: ceil(216/128)*ceil(128/128) = 2 fx passes
    fx = (math.ceil(f / hw.fx_rows) * math.ceil(128 / hw.fx_cols)) / 2.0
    return trav, agg, fx


def per_node_latency(stats: GraphStats, hw: HardwareParams = DEFAULT_HW,
                     workload_scaled: bool = False,
                     sample: int | None = None) -> CoreLatency:
    """(t1, t2, t3) for one decentralized node on this workload.

    ``workload_scaled=False`` is the paper-faithful mode: the per-node core
    latencies are workload-independent constants (this is what reproduces the
    published ~1400x average exactly). ``True`` scales each core by the
    crossbar-pass count implied by Table-2 statistics (beyond-paper mode).
    """
    if not workload_scaled:
        return CoreLatency(hw.t1, hw.t2, hw.t3)
    k1, k2, k3 = _workload_passes(stats, hw, sample)
    x1, x2, x3 = hw.n_xbar_dec
    # §4.3: more crossbars per node -> linear speed-up until saturation
    return CoreLatency(hw.t1 * k1 / min(x1, k1),
                       hw.t2 * k2 / min(x2, k2),
                       hw.t3 * k3 / min(x3, max(k3, 1e-9)))


def compute_latency(setting: Setting, stats: GraphStats,
                    hw: HardwareParams = DEFAULT_HW,
                    workload_scaled: bool = False,
                    n_clusters: int = 1,
                    sample: int | None = None,
                    mode: str = "calibrated",
                    inventory=None,
                    layer_dims: tuple | None = None,
                    technology=None, calibration=None) -> CoreLatency:
    """Eq. 2 (decentralized) / Eq. 3 (centralized) / semi (beyond-paper).

    ``sample`` is the runtime's configured neighbor-sample size; the
    workload-scaled mode uses it for the aggregation-core pass count
    (``None`` falls back to the Table-2 ``avg_cs`` heuristic).

    ``mode="derived"`` routes the compute latency through the crossbar
    mapper (``repro.mapper``, DESIGN.md §8): tile counts, array allocation
    and pass rounds are derived from first principles for the given
    ``inventory`` (default: the setting's paper inventory) and
    ``layer_dims`` (default: the calibration workload, one
    ``feature_len -> 128`` layer). At the paper's geometry the two modes
    agree to ceil-rounding (< 10%, cross-validated in tests); away from it
    the derived mode is the only one that can answer.

    ``technology`` (device-technology name / ``TechnologyParams``) and
    ``calibration`` (measured ``HostCalibration``) are derived-mode knobs
    forwarded to ``compile_mapping`` (DESIGN.md §13): the calibrated mode
    *is* the SOT-MRAM Table-1 fixed point and cannot price any other
    device, so passing either with ``mode="calibrated"`` raises."""
    if mode not in ("calibrated", "derived"):
        raise ValueError(f"unknown mode {mode!r}; "
                         f"one of ('calibrated', 'derived')")
    if mode == "derived":
        from repro.mapper.compile import compile_mapping
        dims = layer_dims or (max(stats.feature_len, 1), 128)
        return compile_mapping(dims, stats, hw, inventory, setting,
                               n_clusters, sample, technology=technology,
                               calibration=calibration).core_latency()
    if technology is not None or calibration is not None:
        raise ValueError(
            "technology/calibration require mode='derived': the calibrated "
            "mode is the paper's SOT-MRAM Table-1 fixed point")
    t = per_node_latency(stats, hw, workload_scaled, sample)
    if setting == "decentralized":
        return t
    if setting == "centralized":
        k = stats.n_nodes - 1
        return CoreLatency(t.traversal / hw.m1 * k,
                           t.aggregation / hw.m2 * k,
                           t.feature_extraction / hw.m3 * k)
    assert setting == "semi", setting
    # semi: n_clusters cluster-heads, each a centralized accelerator over its
    # own n/k-node cluster, all heads operating in parallel (paper §5).
    k = max(math.ceil(stats.n_nodes / max(n_clusters, 1)) - 1, 1)
    return CoreLatency(t.traversal / hw.m1 * k,
                       t.aggregation / hw.m2 * k,
                       t.feature_extraction / hw.m3 * k)


def communicate_latency(setting: Setting, stats: GraphStats,
                        hw: HardwareParams = DEFAULT_HW,
                        n_clusters: int = 1) -> float:
    """Eq. 4 (decentralized, sequential intra-cluster peer hops) /
    Eq. 5 (centralized, one concurrent inter-network transfer)."""
    if setting == "centralized":
        return hw.t_ln
    if setting == "decentralized":
        return (hw.t_e + stats.avg_cs * hw.t_lc) * 2.0
    assert setting == "semi", setting
    # semi ([26], paper §5): nodes reach their cluster head over one
    # concurrent inter-network hop; heads are infrastructure edge servers
    # exchanging boundary data with a bounded set of *adjacent* heads over
    # inter-network-class links (pre-established, no t_e).
    adj_heads = min(max(n_clusters - 1, 0), 6)   # spatial adjacency bound
    return hw.t_ln + 2.0 * adj_heads * hw.t_ln


def refresh_communicate_latency(setting: Setting, stats: GraphStats,
                                hw: HardwareParams = DEFAULT_HW,
                                n_clusters: int = 1,
                                dirty_frac: float = 1.0) -> float:
    """Communication latency of one *incremental* refresh commit whose
    dirty frontier covers ``dirty_frac`` of the rows (Eqs. 4/5 scaled to
    the streaming runtime's dirty-rows-only exchange — DESIGN.md §9/§10).

    The fixed per-commit parts survive any frontier: the centralized
    inter-network transfer is one concurrent upload regardless of how many
    rows move (Eq. 5), decentralized peers still pay connection
    establishment ``t_e``, and a semi spoke→head upload is one concurrent
    intra-region hop. Only the per-row parts — sequential ad-hoc peer hops
    (Eq. 4) and head↔head boundary rows — scale with the dirty share.
    ``dirty_frac=1`` recovers ``communicate_latency`` exactly.
    """
    frac = min(max(dirty_frac, 0.0), 1.0)
    if setting == "centralized":
        return hw.t_ln
    if setting == "decentralized":
        return (hw.t_e + frac * stats.avg_cs * hw.t_lc) * 2.0
    assert setting == "semi", setting
    adj_heads = min(max(n_clusters - 1, 0), 6)
    return hw.t_ln + frac * 2.0 * adj_heads * hw.t_ln


def power(setting: Setting, stats: GraphStats,
          hw: HardwareParams = DEFAULT_HW, gnn_layers: int = 2,
          alpha: tuple | None = None) -> tuple:
    """Eq. 6/7 — (P_compute, P_communicate) per accelerator device."""
    if setting == "centralized":
        p_comp = sum(hw.p_cores_cent)
        p_comm = hw.p_ln * 2.0
        return p_comp, p_comm
    # decentralized / semi edge node
    p_comp = sum(hw.p_cores_dec)
    # Eq. 7: activations crossing layers, radiated at e_per_bit over t(L_c)
    if alpha is None:
        alpha = tuple([stats.feature_len * 32] * (gnn_layers + 1))  # bits
    bits = sum(alpha[1:gnn_layers])
    p_comm = bits * hw.e_per_bit / hw.t_lc if gnn_layers > 1 else 0.0
    return p_comp, p_comm


def predict(setting: Setting, stats: GraphStats,
            hw: HardwareParams = DEFAULT_HW, workload_scaled: bool = False,
            n_clusters: int = 1, gnn_layers: int = 2,
            sample: int | None = None,
            mode: str = "calibrated",
            inventory=None,
            layer_dims: tuple | None = None,
            technology=None, calibration=None) -> NetMetrics:
    """Full Eq. 1 + Eq. 6 evaluation for one setting on one workload.

    ``mode="calibrated"`` (default) prices compute from the Table-1
    constants; ``mode="derived"`` compiles the workload onto the crossbar
    ``inventory`` via ``repro.mapper`` and rolls up pass rounds (see
    ``compute_latency``), optionally re-anchored by a device
    ``technology`` and/or a measured host ``calibration`` (DESIGN.md
    §13). The link model (Eqs. 4/5/7) is shared — crossbar geometry does
    not move the radio."""
    comp = compute_latency(setting, stats, hw, workload_scaled, n_clusters,
                           sample, mode=mode, inventory=inventory,
                           layer_dims=layer_dims, technology=technology,
                           calibration=calibration)
    comm = communicate_latency(setting, stats, hw, n_clusters)
    p_comp, p_comm = power(setting, stats, hw, gnn_layers)
    return NetMetrics(setting, comp, comp.total, comm, p_comp, p_comm)


def headline_averages(hw: HardwareParams = DEFAULT_HW):
    """The paper's two headline claims, recomputed over Table 2.

    Returns (compute_speedup_dec_over_cent, comm_speedup_cent_over_dec),
    expected ~1400x and ~790x.
    """
    comp, comm = [], []
    for stats in TABLE2_DATASETS.values():
        c = predict("centralized", stats, hw)
        d = predict("decentralized", stats, hw)
        comp.append(c.t_compute / d.t_compute)
        comm.append(d.t_communicate / c.t_communicate)
    return sum(comp) / len(comp), sum(comm) / len(comm)


def table1(hw: HardwareParams = DEFAULT_HW):
    """Reproduce Table 1 (taxi case study) from the model."""
    out = {}
    for setting in ("centralized", "decentralized"):
        m = predict(setting, TAXI_STATS, hw)
        out[setting] = {
            "traversal_s": m.compute.traversal,
            "aggregation_s": m.compute.aggregation,
            "feature_extraction_s": m.compute.feature_extraction,
            "computation_s": m.t_compute,
            "communication_s": m.t_communicate,
            "p_compute_w": m.p_compute,
        }
    return out


def pick_setting(stats: GraphStats, hw: HardwareParams = DEFAULT_HW,
                 candidates: tuple = ("centralized", "decentralized", "semi"),
                 n_clusters: int = 16) -> tuple:
    """The executable 'design guideline': choose the setting minimizing T_net.

    Returns (best_setting, {setting: NetMetrics}).
    """
    metrics = {s: predict(s, stats, hw, n_clusters=n_clusters)
               for s in candidates}
    best = min(metrics, key=lambda s: metrics[s].t_net)
    return best, metrics
