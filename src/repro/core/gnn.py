"""GNN inference/training in JAX on the IMA-GNN dataflow.

The model family the paper accelerates (Fig. 1): per layer,
  aggregation         Z = A_hat @ X     (traversal + aggregation cores)
  feature extraction  H = act(Z @ W + b)  (MVM crossbar core)

Both stages run through the kernel stack: aggregation via the
``csr_aggregate`` padded-sample kernel, feature extraction either ideal
(float matmul) or through the ``crossbar_mvm`` numerics — switching
``CrossbarNumerics(ideal=False)`` gives bit-accurate in-memory inference.

Backends (``GNNConfig.backend``):
  * ``jnp``    — composed path on XLA oracles (differentiable; training).
  * ``pallas`` — composed path, aggregation on the ``csr_aggregate`` kernel.
  * ``fused``  — both stages in one ``fused_gnn_layer`` kernel launch: Z
    stays resident in VMEM between aggregation and feature extraction
    (DESIGN.md §5). Inference/serving only — the fused kernel has no VJP.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.kernels.crossbar_mvm import CrossbarNumerics, crossbar_matmul_signed_ref
from repro.kernels.csr_aggregate import aggregate
from repro.kernels.fused_layer import fused_gnn_layer

BACKENDS = ("jnp", "pallas", "fused")


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    in_dim: int
    hidden_dims: tuple = (128,)
    out_dim: int = 16
    sample: int = 16                       # padded neighbor sample size S
    numerics: CrossbarNumerics = CrossbarNumerics(ideal=True)
    backend: str = "jnp"                   # one of BACKENDS
    final_activation: bool = False
    tuned: object | None = None            # TunedKernels bundle (repro.tuning)
    #                                        — hashable, so swapping tuned
    #                                        configs retraces jitted forwards

    @property
    def dims(self) -> tuple:
        return (self.in_dim, *self.hidden_dims, self.out_dim)


def init_params(key: jax.Array, cfg: GNNConfig) -> list:
    """Glorot-initialized (W, b) per layer."""
    params = []
    dims = cfg.dims
    for i in range(len(dims) - 1):
        key, sub = jax.random.split(key)
        fan_in, fan_out = dims[i], dims[i + 1]
        w = jax.random.normal(sub, (fan_in, fan_out), jnp.float32)
        w = w * jnp.sqrt(2.0 / (fan_in + fan_out))
        params.append({"w": w, "b": jnp.zeros((fan_out,), jnp.float32)})
    return params


def _transform(z: jax.Array, w: jax.Array, cfg: GNNConfig) -> jax.Array:
    if cfg.numerics.ideal:
        return jnp.dot(z, w, preferred_element_type=jnp.float32)
    return crossbar_matmul_signed_ref(z, w, cfg.numerics)


@partial(jax.jit, static_argnames="cfg")
def forward(params: list, x: jax.Array, neighbors: jax.Array,
            weights: jax.Array, cfg: GNNConfig) -> jax.Array:
    """Full-graph GNN forward.

    x: [N, F_in]; neighbors/weights: [N, S] padded sample (self loops should
    be included in the sample). Returns [N, out_dim] embeddings/logits.
    """
    assert cfg.backend in BACKENDS, cfg.backend
    h = x
    n_layers = len(params)
    for i, layer in enumerate(params):
        act = i < n_layers - 1 or cfg.final_activation
        if cfg.backend == "fused":
            h = fused_gnn_layer(h, neighbors, weights, layer["w"],
                                layer["b"], cfg.numerics, relu=act,
                                tuned=cfg.tuned)
            continue
        z = aggregate(h, neighbors, weights, backend=cfg.backend)  # message+agg
        h = _transform(z, layer["w"], cfg) + layer["b"]
        if act:
            h = jax.nn.relu(h)
    return h


@partial(jax.jit, static_argnames="cfg")
def loss_fn(params: list, x, neighbors, weights, labels, cfg: GNNConfig):
    """Cross-entropy node-classification loss (mean over labeled nodes)."""
    logits = forward(params, x, neighbors, weights, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1).squeeze(-1)
    return jnp.mean(nll)


grad_fn = jax.jit(jax.value_and_grad(loss_fn), static_argnames="cfg")
