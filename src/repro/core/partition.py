"""Graph partitioning + the centralized/decentralized/semi execution planner.

``partition(graph, k)`` splits a CSR graph into k clusters (BFS-grown, a
METIS-lite heuristic that balances node counts and keeps neighborhoods
together), and derives everything the runtime and the cost model need:

  * per-cluster node assignment and *padded, device-local* subgraphs whose
    neighbor indices point into a device-local feature table,
  * halo structure — which remote nodes each cluster must receive
    (the paper's bidirectional inter-device communication volume e_ij),
  * per-cluster statistics (local c_s, boundary bytes) for Eqs. 4/7.

``ExecutionPlan`` is the paper's technique as a first-class object: the same
GNN runs centralized (one device owns everything), decentralized (one cluster
per device, halo exchange per layer), or semi-decentralized — a genuine
two-tier hierarchy built by ``hier_partition``: cluster heads own regions,
member spokes upload features to their head (tier 0), and heads exchange
boundary halos among themselves (tier 1) — the paper's §5 guideline made
executable (DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .graph import Graph, GraphStats


@dataclasses.dataclass
class Partition:
    assignment: np.ndarray        # [N] int32 cluster id per node
    n_clusters: int
    # device-local tensors, all padded to uniform sizes across clusters:
    local_nodes: np.ndarray       # [K, n_max] int32 global node ids (pad: -1)
    local_mask: np.ndarray        # [K, n_max] bool
    halo_nodes: np.ndarray        # [K, h_max] int32 global ids needed from
    halo_src: np.ndarray          # [K, h_max] int32 owning cluster (pad: -1)
    comm_volume: np.ndarray       # [K, K] int64 e_ij: feature rows cluster i
    #                               receives from cluster j per layer (unique
    #                               remote sources of its boundary edges — the
    #                               rows the alltoall exchange ships)
    sample: int | None = None     # the neighbor-sample size the halo/comm
    #                               tables were pruned to (None: unpruned)

    @property
    def n_max(self) -> int:
        return self.local_nodes.shape[1]

    @property
    def h_max(self) -> int:
        return self.halo_nodes.shape[1]

    def cluster_stats(self, g: Graph, k: int) -> GraphStats:
        nodes = self.local_nodes[k][self.local_mask[k]]
        deg = np.diff(g.indptr)[nodes] if len(nodes) else np.zeros(1)
        return GraphStats(f"cluster{k}", len(nodes), int(deg.sum()),
                          g.feature_len, float(deg.mean() if len(deg) else 0))


def _bfs_clusters(g: Graph, k: int, seed: int = 0) -> np.ndarray:
    """Greedy balanced BFS growth from k spread-out seeds."""
    n = g.n_nodes
    target = -(-n // k)
    rng = np.random.default_rng(seed)
    assignment = np.full(n, -1, np.int32)
    seeds = rng.choice(n, size=min(k, n), replace=False)
    frontiers = [[int(s)] for s in seeds]
    sizes = np.zeros(k, np.int64)
    for c, s in enumerate(seeds):
        assignment[s] = c
        sizes[c] = 1
    active = True
    while active:
        active = False
        for c in range(k):
            if sizes[c] >= target or not frontiers[c]:
                continue
            nxt = []
            for u in frontiers[c]:
                for v in g.indices[g.indptr[u]:g.indptr[u + 1]]:
                    if assignment[v] == -1 and sizes[c] < target:
                        assignment[v] = c
                        sizes[c] += 1
                        nxt.append(int(v))
            frontiers[c] = nxt
            active = active or bool(nxt)
    # orphans (disconnected): round-robin to the emptiest clusters
    for u in np.nonzero(assignment == -1)[0]:
        c = int(np.argmin(sizes))
        assignment[u] = c
        sizes[c] += 1
    return assignment


def _chunk_clusters(g: Graph, k: int) -> np.ndarray:
    """Contiguous node-balanced split: cluster of node i is ``i * k // N``.

    O(N), locality-preserving for graphs whose node order is meaningful
    (CSR builders emit destination-sorted ids) — the partitioner that makes
    million-node graphs tractable where the BFS grower's Python frontier
    loop is not."""
    n = max(g.n_nodes, 1)
    return (np.arange(g.n_nodes, dtype=np.int64) * k // n).astype(np.int32)


def _edge_clusters(g: Graph, k: int) -> np.ndarray:
    """Contiguous *edge*-balanced split: each cluster owns ~E/k edges.

    On power-law graphs this deliberately skews the node counts (a chunk of
    hubs is short, a chunk of leaves is long) — balanced per-device compute,
    unbalanced per-device rows. That skew is exactly what the dense
    ``[K, n_max, S]`` padding amplifies and the bucketed layout absorbs."""
    deg = np.diff(g.indptr).astype(np.int64) + 1     # +1 keeps isolated
    #                                                  nodes spreading
    before = np.cumsum(deg) - deg                    # edge mass before node i
    total = max(int(deg.sum()), 1)
    return np.minimum(before * k // total, k - 1).astype(np.int32)


PARTITION_METHODS = ("bfs", "chunk", "edge")


def _sample_edge_mask(g: Graph, sample: int | None,
                      self_loops: bool = True) -> np.ndarray:
    """Boolean [E] mask of the edges the padded-sample runtime reads.

    ``build_local_subgraphs``/``pad_neighbors`` truncate each node to its
    first ``sample - 1`` neighbors (one slot is the self loop); halo and
    comm tables built from *all* edges would ship rows the kernels never
    touch. ``sample=None`` keeps every edge."""
    if sample is None:
        return np.ones(g.n_edges, bool)
    cap = sample - 1 if self_loops else sample
    deg = np.diff(g.indptr)
    pos = np.arange(g.n_edges) - np.repeat(g.indptr[:-1], deg)
    return pos < cap


def partition(g: Graph, n_clusters: int, seed: int = 0,
              sample: int | None = None,
              self_loops: bool = True,
              method: str = "bfs") -> Partition:
    """Split into ``n_clusters`` clusters and derive all exchange tables.

    ``sample`` (optional) prunes the halo/comm tables to the edges the
    padded-sample runtime actually reads, so tabulated e_ij equals the rows
    the alltoall exchange measurably ships (``plan_execution`` passes its
    sample through here). ``method`` selects the assignment heuristic:
    ``bfs`` (quality default), ``chunk`` (O(N) node-balanced contiguous) or
    ``edge`` (O(N) edge-balanced contiguous — skewed node counts on
    power-law graphs, pair with ``bucket_partition``)."""
    if method not in PARTITION_METHODS:
        raise ValueError(f"unknown partition method {method!r}; "
                         f"choose from {PARTITION_METHODS}")
    if method == "chunk":
        assignment = _chunk_clusters(g, n_clusters)
    elif method == "edge":
        assignment = _edge_clusters(g, n_clusters)
    else:
        assignment = _bfs_clusters(g, n_clusters, seed)
    return _from_assignment(g, assignment, n_clusters, sample=sample,
                            self_loops=self_loops)


@dataclasses.dataclass
class LocalSubgraph:
    """Per-device padded subgraph in device-local index space.

    Feature table layout per device: rows [0, n_max) are owned nodes,
    rows [n_max, n_max + h_max) are halo (received) nodes. Neighbor indices
    point into this concatenated table.
    """
    neighbors: np.ndarray   # [K, n_max, S] int32 local-space indices
    weights: np.ndarray     # [K, n_max, S] float32 (0 = padding)
    node_mask: np.ndarray   # [K, n_max] bool


def _owner_slots(part: Partition) -> np.ndarray:
    """[N] local slot of each node in its owning cluster's table.

    Members are stored in ascending global-id order (``np.nonzero``), so a
    stable argsort of the assignment reproduces every cluster's row order
    without a per-cluster scan."""
    a = part.assignment
    order = np.argsort(a, kind="stable")
    counts = np.bincount(a, minlength=part.n_clusters)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    slot = np.empty(len(a), np.int64)
    slot[order] = np.arange(len(a)) - np.repeat(starts, counts)
    return slot


def _local_tables(g: Graph, part: Partition, cluster_ids, n_rows: int,
                  s_cap: int, halo_base: int,
                  self_loops: bool = True):
    """Vectorized padded neighbor/weight tables for the given clusters.

    Rows are the clusters' owned nodes (ascending global id), columns the
    first ``s_cap - 1`` CSR neighbors plus the self loop; neighbor indices
    point into the device-local table (owned rows [0, n_rows), halo rows
    [halo_base, halo_base + h)). Shared by the dense layout
    (``n_rows = n_max``, ``s_cap = sample``) and the bucketed one
    (per-bucket caps)."""
    cluster_ids = np.asarray(cluster_ids, np.int64)
    nbr = np.zeros((len(cluster_ids), n_rows, s_cap), np.int32)
    wts = np.zeros((len(cluster_ids), n_rows, s_cap), np.float32)
    cap = s_cap - 1 if self_loops else s_cap
    # self-loop weight honors the graph's normalization (gcn_normalize sets
    # A_hat's diagonal 1/(d_i+1); unnormalized graphs keep A + I's 1.0)
    sl = (g.self_loop if g.self_loop is not None
          else np.ones(g.n_nodes, np.float32))
    slot = _owner_slots(part)
    assignment = part.assignment
    h_counts = (part.halo_src >= 0).sum(axis=1)
    for out_i, c in enumerate(cluster_ids):
        rows = part.local_nodes[c][part.local_mask[c]]
        m = len(rows)
        if m == 0:
            continue
        deg = (g.indptr[rows + 1] - g.indptr[rows]).astype(np.int64)
        take = np.minimum(deg, cap)
        if cap > 0 and g.indices.size:  # edgeless graphs: self-loops only
            e_idx = g.indptr[rows][:, None] + np.arange(cap)[None, :]
            valid = np.arange(cap)[None, :] < take[:, None]
            e_idx = np.where(valid, e_idx, 0)
            v = g.indices[e_idx]
            w = (g.edge_weight[e_idx] if g.edge_weight is not None
                 else np.ones_like(e_idx, np.float32))
            # halo_nodes are unique-sorted, so searchsorted recovers the
            # halo row of every sample-reachable remote neighbor
            hn = part.halo_nodes[c][:h_counts[c]]
            remote = assignment[v] != c
            loc = np.where(remote,
                           halo_base + np.searchsorted(hn, v),
                           slot[v])
            nbr[out_i, :m, :cap] = np.where(valid, loc, 0)
            wts[out_i, :m, :cap] = np.where(valid, w, 0.0)
        if self_loops:
            nbr[out_i, np.arange(m), take] = np.arange(m)
            wts[out_i, np.arange(m), take] = sl[rows]
    return nbr, wts


def build_local_subgraphs(g: Graph, part: Partition, sample: int,
                          self_loops: bool = True) -> LocalSubgraph:
    if part.sample is not None and sample > part.sample:
        raise ValueError(
            f"subgraph sample {sample} exceeds the sample {part.sample} the "
            f"partition's halo tables were pruned to — neighbors past the "
            f"pruning cut have no halo row; rebuild the partition with "
            f"sample >= {sample}")
    nbr, wts = _local_tables(g, part, np.arange(part.n_clusters),
                             part.n_max, sample, part.n_max,
                             self_loops=self_loops)
    return LocalSubgraph(nbr, wts, part.local_mask)


def gather_features(g: Graph, part: Partition) -> np.ndarray:
    """[K, n_max, F] owned-node features per device (pad rows zero)."""
    k, n_max = part.n_clusters, part.n_max
    f = g.feature_len
    out = np.zeros((k, n_max, f), np.float32)
    for c in range(k):
        m = part.local_mask[c]
        out[c, m] = g.features[part.local_nodes[c][m]]
    return out


_MIN_CAP = 8          # smallest bucket capacity (bounds retrace churn when
#                       streaming rebuilds nudge tiny clusters around)


def _pow2ceil(n: int, floor: int = 1) -> int:
    b = max(int(floor), 1)
    while b < n:
        b <<= 1
    return b


@dataclasses.dataclass
class BucketedPartition:
    """Capacity-bucketed ragged layout over a dense :class:`Partition`.

    Dense plans pad every cluster to the global ``n_max``/``h_max``/``S`` —
    one hub cluster inflates every device's tensors. Here clusters are
    grouped into power-of-two *capacity buckets*: all clusters in bucket b
    share ``n_caps[b]`` owned rows, ``h_caps[b]`` halo rows and a neighbor
    width ``s_caps[b]``, so each device pays for its bucket's capacity, not
    the hub's. Power-of-two caps keep JIT shapes stable across streaming
    rebuilds (DESIGN.md §12). The wrapped dense ``part`` (assignment, halo
    and comm tables) stays the single source of truth for traffic
    accounting; only the padded runtime tensors go ragged.
    """
    part: Partition
    clusters: tuple               # per-bucket int32 cluster ids (ascending)
    n_caps: tuple                 # per-bucket owned-row capacity (pow2)
    h_caps: tuple                 # per-bucket halo-row capacity (pow2)
    s_caps: tuple                 # per-bucket neighbor width (<= sample)
    bucket_of: np.ndarray         # [K] bucket index of each cluster
    index_in: np.ndarray          # [K] row of each cluster inside its bucket

    @property
    def n_buckets(self) -> int:
        return len(self.clusters)

    def real_rows(self) -> int:
        return int(self.part.local_mask.sum())

    def padded_rows(self) -> int:
        return sum(len(cl) * cap
                   for cl, cap in zip(self.clusters, self.n_caps))

    def dense_padded_rows(self) -> int:
        return self.part.n_clusters * self.part.n_max

    def padding_ratio(self) -> float:
        """Padded rows / real rows of the bucketed layout (>= 1)."""
        return self.padded_rows() / max(self.real_rows(), 1)

    def dense_padding_ratio(self) -> float:
        """Padded rows / real rows the dense layout would pay."""
        return self.dense_padded_rows() / max(self.real_rows(), 1)

    def covers(self) -> bool:
        """Every cluster's real rows/halos/neighbors fit its bucket's caps."""
        sizes = self.part.local_mask.sum(axis=1)
        halos = (self.part.halo_src >= 0).sum(axis=1)
        for b, cl in enumerate(self.clusters):
            if len(cl) == 0:
                continue
            if int(sizes[cl].max()) > self.n_caps[b]:
                return False
            if int(halos[cl].max()) > self.h_caps[b]:
                return False
        return True


def bucket_partition(part: Partition, g: Graph | None = None,
                     sample: int | None = None, max_buckets: int = 0,
                     like: "BucketedPartition | None" = None,
                     self_loops: bool = True) -> BucketedPartition:
    """Group a dense partition's clusters into power-of-two capacity buckets.

    ``n_caps`` is the pow2 ceiling of each cluster's size (floor
    ``_MIN_CAP``); ``h_caps`` the pow2 ceiling of the largest halo count in
    the bucket; ``s_caps`` trims the neighbor width to the largest *used*
    slot count in the bucket (needs ``g`` + ``sample``; falls back to
    ``sample``). ``max_buckets > 0`` merges the smallest-capacity buckets
    upward until at most that many remain. ``like=`` reuses an existing
    bucketing's grouping and never shrinks its caps — streaming rebuilds
    keep JIT shapes stable (same assignment => same groups)."""
    sample = sample if sample is not None else part.sample
    sizes = part.local_mask.sum(axis=1)
    hcounts = (part.halo_src >= 0).sum(axis=1)
    if like is not None:
        groups = [np.asarray(cl, np.int64) for cl in like.clusters]
        n_caps = [max(c, _pow2ceil(int(sizes[cl].max(initial=0)), _MIN_CAP))
                  for c, cl in zip(like.n_caps, groups)]
    else:
        caps = np.array([_pow2ceil(int(s), _MIN_CAP) for s in sizes])
        uniq = sorted(set(caps.tolist()))
        groups = [np.nonzero(caps == u)[0].astype(np.int64) for u in uniq]
        n_caps = list(uniq)
        while max_buckets > 0 and len(groups) > max_buckets:
            groups[1] = np.sort(np.concatenate([groups[0], groups[1]]))
            n_caps[1] = max(n_caps[0], n_caps[1])
            groups, n_caps = groups[1:], n_caps[1:]
    h_caps, s_caps = [], []
    deg = np.diff(g.indptr) if g is not None else None
    for b, cl in enumerate(groups):
        hc = _pow2ceil(int(hcounts[cl].max(initial=0)), 1)
        sc = int(sample) if sample is not None else 1
        if deg is not None and sample is not None and len(cl):
            cap = sample - 1 if self_loops else sample
            rows = np.concatenate(
                [part.local_nodes[c][part.local_mask[c]] for c in cl])
            used = int(np.minimum(deg[rows], cap).max(initial=0))
            used += 1 if self_loops else 0
            sc = min(int(sample), _pow2ceil(max(used, 1)))
        if like is not None:
            hc = max(hc, like.h_caps[b])
            sc = max(sc, like.s_caps[b])
        h_caps.append(hc)
        s_caps.append(sc)
    bucket_of = np.zeros(part.n_clusters, np.int32)
    index_in = np.zeros(part.n_clusters, np.int32)
    for b, cl in enumerate(groups):
        bucket_of[cl] = b
        index_in[cl] = np.arange(len(cl))
    return BucketedPartition(part, tuple(groups), tuple(int(c) for c in n_caps),
                             tuple(h_caps), tuple(s_caps),
                             bucket_of, index_in)


def build_bucketed_subgraphs(g: Graph, bpart: BucketedPartition,
                             self_loops: bool = True):
    """Per-bucket padded neighbor/weight tables.

    Returns (neighbors, weights): tuples of per-bucket arrays
    ``[K_b, n_caps[b], s_caps[b]]`` in the same device-local index
    convention as :class:`LocalSubgraph` — owned rows first, halo rows at
    ``n_caps[b] + h``. Trailing neighbor slots past ``s_caps[b]`` carry
    weight zero in the dense layout, and the kernels accumulate the S axis
    sequentially, so dropping them is bit-identical (DESIGN.md §12)."""
    nbrs, wtss = [], []
    for b, cl in enumerate(bpart.clusters):
        nbr, wts = _local_tables(g, bpart.part, cl, bpart.n_caps[b],
                                 bpart.s_caps[b], bpart.n_caps[b],
                                 self_loops=self_loops)
        nbrs.append(nbr)
        wtss.append(wts)
    return tuple(nbrs), tuple(wtss)


def gather_bucketed_features(g: Graph, bpart: BucketedPartition):
    """Tuple of per-bucket ``[K_b, n_caps[b], F]`` owned-feature tables."""
    part = bpart.part
    out = []
    for b, cl in enumerate(bpart.clusters):
        f = np.zeros((len(cl), bpart.n_caps[b], g.feature_len), np.float32)
        for j, c in enumerate(cl):
            m = part.local_mask[c]
            f[j, :int(m.sum())] = g.features[part.local_nodes[c][m]]
        out.append(f)
    return tuple(out)


@dataclasses.dataclass
class HierPartition:
    """Two-tier semi-decentralized partition (the paper's §5 hierarchy).

    The graph is split into ``n_heads`` *regions*, each fronted by a cluster
    head (an infrastructure edge server). Every region's nodes are spread
    over ``spokes_per_region`` member edge devices (spokes) that hold the raw
    features. Tier 0 is the intra-region spoke->head feature upload; tier 1
    is the head<->head boundary halo exchange over ``region``'s tables.
    """
    region: Partition             # tier-1 partition over the R regions
    n_heads: int
    spokes_per_region: int
    spoke_nodes: np.ndarray       # [R, P, m_max] int32 global ids (pad: -1)
    spoke_mask: np.ndarray        # [R, P, m_max] bool
    gather_spoke: np.ndarray      # [R, n_max] spoke owning each region row
    gather_slot: np.ndarray       # [R, n_max] slot in that spoke's table

    @property
    def m_max(self) -> int:
        return self.spoke_nodes.shape[2]


def hier_partition(g: Graph, n_heads: int, nodes_per_region: int = 4,
                   sample: int | None = None, seed: int = 0) -> HierPartition:
    """Region-level partition (cluster heads) nested over member clusters.

    ``nodes_per_region`` is the number of member edge devices (spokes) under
    each head; a region's owned nodes are split into that many balanced
    contiguous spoke tables. ``sample`` prunes the tier-1 halo/comm tables
    exactly as in ``partition``.
    """
    region = partition(g, n_heads, seed=seed, sample=sample)
    p = max(int(nodes_per_region), 1)
    n_max = region.n_max
    spoke_id = np.zeros((n_heads, n_max), np.int32)
    sizes = np.zeros((n_heads, p), np.int64)
    for r in range(n_heads):
        m = int(region.local_mask[r].sum())
        for i in range(m):
            spoke_id[r, i] = i * p // max(m, 1)
        np.add.at(sizes[r], spoke_id[r, :m], 1)
    m_max = max(int(sizes.max()), 1)
    spoke_nodes = np.full((n_heads, p, m_max), -1, np.int32)
    spoke_mask = np.zeros((n_heads, p, m_max), bool)
    gather_spoke = np.zeros((n_heads, n_max), np.int32)
    gather_slot = np.zeros((n_heads, n_max), np.int32)
    fill = np.zeros((n_heads, p), np.int64)
    for r in range(n_heads):
        m = int(region.local_mask[r].sum())
        for i in range(m):
            s = int(spoke_id[r, i])
            t = int(fill[r, s])
            fill[r, s] += 1
            spoke_nodes[r, s, t] = region.local_nodes[r, i]
            spoke_mask[r, s, t] = True
            gather_spoke[r, i] = s
            gather_slot[r, i] = t
    return HierPartition(region, n_heads, p, spoke_nodes, spoke_mask,
                         gather_spoke, gather_slot)


def gather_spoke_features(g: Graph, hier: HierPartition) -> np.ndarray:
    """[R, P, m_max, F] spoke-resident node features (pad rows zero)."""
    r, p, m_max = hier.spoke_nodes.shape
    out = np.zeros((r, p, m_max, g.feature_len), np.float32)
    m = hier.spoke_mask
    out[m] = g.features[hier.spoke_nodes[m]]
    return out


def halo_exchange_tables(part: Partition):
    """Precomputed gather plan for the halo exchange.

    Returns (src_cluster [K, h_max] int32, src_slot [K, h_max] int32,
    halo_mask [K, h_max] bool): device c's halo row h is the feature at
    (src_cluster[c, h], src_slot[c, h]) — an all-gather + gather realizes the
    exchange (see repro.distributed.halo).
    """
    k, h_max = part.n_clusters, part.h_max
    slot = np.zeros((k, h_max), np.int32)
    owner_slot = _owner_slots(part)
    for c in range(k):
        valid = part.halo_src[c] >= 0
        slot[c, valid] = owner_slot[part.halo_nodes[c][valid]]
    return part.halo_src, slot, part.halo_src >= 0


@dataclasses.dataclass
class ExecutionPlan:
    """The paper's technique as a first-class object: one GNN, three
    execution settings, one switchable kernel backend.

      * ``centralized``   — one device owns the full graph (paper Fig. 4a).
      * ``decentralized`` — one cluster per device, halo exchange per layer
        (Fig. 4b).
      * ``semi``          — the genuine two-tier hierarchy (paper §5 /
        DESIGN.md §7): ``n_clusters`` cluster heads, each centralized over
        its own region; spokes upload features to their head (tier 0), heads
        exchange boundary halos per layer (tier 1).

    ``backend`` selects the per-layer kernel path everywhere the plan runs:
    ``jnp``/``pallas`` (composed aggregation -> MVM with the Z HBM
    round-trip) or ``fused`` (single fused kernel, Z resident in VMEM —
    DESIGN.md §5). Build with ``plan_execution``; call ``make_forward`` for
    the runnable per-setting forward and ``scatter`` to map device-local
    outputs back to global node order.
    """
    setting: str
    backend: str
    sample: int
    n_clusters: int
    graph: Graph
    part: Partition | None          # None for centralized; the region-level
    #                                 (tier-1) partition for semi
    sub: LocalSubgraph | None
    feats: np.ndarray               # [K, n_max, F] (centralized: [1, N, F];
    #                                 semi: [R, P, m_max, F] spoke tables;
    #                                 bucketed non-semi: tuple of per-bucket
    #                                 [K_b, n_cap, F] tables)
    neighbors: np.ndarray           # [K, n_max, S] device-local sample
    #                                 (bucketed: tuple of [K_b, n_cap, s_cap])
    weights: np.ndarray             # [K, n_max, S] (bucketed: tuple)
    hier: HierPartition | None = None   # set for setting == "semi"
    mapping: object | None = None   # cached CompiledMapping (repro.mapper)
    tuned: object | None = None     # cached TunedKernels (repro.tuning)
    bucketed: BucketedPartition | None = None   # ragged layout (DESIGN §12)

    def gnn_config(self, cfg):
        """Rebind a GNNConfig to this plan's backend/sample (and its tuned
        kernel configs, when ``tune_kernels`` has run)."""
        tuned = self.tuned if self.tuned is not None else cfg.tuned
        return dataclasses.replace(cfg, backend=self.backend,
                                   sample=self.sample, tuned=tuned)

    def tune_kernels(self, cfg, cache=None, **tune_kw):
        """Autotune the Pallas kernel launches this plan's forward makes
        (repro.tuning, DESIGN.md §11) and cache the winners on
        ``self.tuned`` so ``make_forward`` picks them up. ``cache`` is a
        ``repro.tuning.TuneCache`` (or a path to load one from); winners
        are roofline-pruned, measured on the current platform, and
        bit-identical to the defaults by construction. Returns the
        ``TunedKernels`` bundle (empty on the jnp backend)."""
        from repro.tuning import TuneCache, tune_plan
        if isinstance(cache, str):
            cache = TuneCache.load(cache)
        self.tuned = tune_plan(self, self.gnn_config(cfg), cache=cache,
                               **tune_kw)
        return self.tuned

    def make_forward(self, cfg, mesh=None, mode: str = "alltoall",
                     overlap: str = "overlap"):
        """Runnable forward for this plan: ``fn(params) -> [K, n_max, out]``.

        ``mesh`` (optional) with exactly ``n_clusters`` devices selects the
        SPMD shard_map runtime; otherwise the mesh-free emulated exchange
        runs the identical dataflow on however many devices exist. ``mode``
        picks the halo-exchange strategy (``allgather``/``alltoall``) on
        both runtimes and, for semi, on the tier-1 head<->head exchange.

        Bucketed plans return a *tuple* of per-bucket ``[K_b, n_cap, out]``
        arrays (``scatter`` accepts it) and run the mesh-free double-buffered
        exchange: ``overlap="overlap"`` dispatches every bucket's halo
        gather before any bucket's layer step so the sends overlap the MVMs;
        ``"serial"`` interleaves them (same values — DESIGN.md §12).

        The returned callable carries telemetry instrumentation (a
        ``plan.forward`` span with exact wire-byte accounting from this
        plan's ``measured_traffic`` tables — DESIGN.md §14); with telemetry
        disabled (the default) the wrapper is a single flag check.
        """
        from repro.telemetry import instrument_forward
        fwd = self._build_forward(cfg, mesh=mesh, mode=mode, overlap=overlap)
        return instrument_forward(self, self.gnn_config(cfg), mode, fwd)

    def _build_forward(self, cfg, mesh=None, mode: str = "alltoall",
                       overlap: str = "overlap"):
        import jax.numpy as jnp
        from repro.core import gnn
        cfg = self.gnn_config(cfg)
        if self.bucketed is not None:
            from repro.distributed.halo import (
                build_bucketed_halo_plan, make_emulated_bucketed_forward,
                make_emulated_bucketed_semi_forward)
            bplan = build_bucketed_halo_plan(self.bucketed)
            nbrs = tuple(jnp.asarray(x) for x in self.neighbors)
            wtss = tuple(jnp.asarray(x) for x in self.weights)
            if self.setting == "semi":
                fn = make_emulated_bucketed_semi_forward(
                    cfg, bplan, self.hier, self.bucketed, mode=mode,
                    overlap=overlap)
                spoke = jnp.asarray(self.feats)
                return lambda params: fn(params, spoke, nbrs, wtss)
            fn = make_emulated_bucketed_forward(cfg, bplan, mode=mode,
                                                overlap=overlap)
            feats = tuple(jnp.asarray(f) for f in self.feats)
            return lambda params: fn(params, feats, nbrs, wtss)
        feats = jnp.asarray(self.feats)
        nbr = jnp.asarray(self.neighbors)
        wts = jnp.asarray(self.weights)
        if self.setting == "centralized":
            def forward(params):
                return gnn.forward(params, feats[0], nbr[0], wts[0],
                                   cfg)[None]
            return forward
        spmd = mesh is not None and mesh.size == self.n_clusters
        if self.setting == "semi":
            from repro.distributed.halo import (build_two_tier_plan,
                                                make_emulated_semi_forward,
                                                make_semi_forward)
            plan = build_two_tier_plan(self.hier)
            fn = (make_semi_forward(mesh, cfg, plan, mode=mode) if spmd
                  else make_emulated_semi_forward(cfg, plan, mode=mode))
            return lambda params: fn(params, feats, nbr, wts)
        from repro.distributed.halo import (build_halo_plan,
                                            make_decentralized_forward,
                                            make_emulated_forward)
        plan = build_halo_plan(self.part)
        if spmd:
            fn = make_decentralized_forward(mesh, cfg, plan, self.part.n_max,
                                            mode=mode)
        else:
            fn = make_emulated_forward(cfg, plan, mode=mode)
        return lambda params: fn(params, feats, nbr, wts)

    def scatter(self, out) -> np.ndarray:
        """Map per-cluster outputs [K, n_max, D] to global node order.

        Bucketed plans pass the forward's tuple of per-bucket
        ``[K_b, n_cap, D]`` arrays."""
        if self.bucketed is not None and isinstance(out, (list, tuple)):
            parts = [np.asarray(o) for o in out]
            full = np.zeros((self.graph.n_nodes, parts[0].shape[-1]),
                            parts[0].dtype)
            sizes = self.part.local_mask.sum(axis=1)
            for b, cl in enumerate(self.bucketed.clusters):
                for j, c in enumerate(cl):
                    m = int(sizes[c])
                    full[self.part.local_nodes[c, :m]] = parts[b][j, :m]
            return full
        out = np.asarray(out)
        if self.setting == "centralized":
            return out[0]
        full = np.zeros((self.graph.n_nodes, out.shape[-1]), out.dtype)
        for c in range(self.n_clusters):
            m = self.part.local_mask[c]
            full[self.part.local_nodes[c][m]] = out[c][m]
        return full

    def layout_stats(self, cfg=None) -> dict:
        """Deterministic padded-layout accounting for this plan.

        ``padding_ratio`` is padded rows / real rows of the layout the plan
        actually runs; ``dense_*`` keys price the uniform dense layout for
        the same partition so the bucketing win is a ratio of two numbers
        from one partition. ``peak_device_bytes`` models the largest single
        device's live working set (feature table + halo rows + activation
        double-buffer + neighbor/weight tables at the widest layer dim of
        ``cfg``, float32/int32)."""
        f_max = int(max(cfg.dims)) if cfg is not None else max(
            int(self.graph.feature_len), 1)

        def _peak(n_rows: int, h_rows: int, s: int) -> int:
            return 4 * (2 * n_rows * f_max + h_rows * f_max
                        + 2 * n_rows * s)

        if self.part is None:                     # dense centralized
            rows = max(int(self.graph.n_nodes), 1)
            peak = _peak(rows, 0, self.sample)
            return {"layout": "dense", "real_rows": rows,
                    "padded_rows": rows, "padding_ratio": 1.0,
                    "dense_padded_rows": rows, "dense_padding_ratio": 1.0,
                    "peak_device_bytes": peak,
                    "dense_peak_device_bytes": peak}
        real = max(int(self.part.local_mask.sum()), 1)
        dense_rows = self.part.n_clusters * self.part.n_max
        dense_peak = _peak(self.part.n_max, self.part.h_max, self.sample)
        if self.bucketed is None:
            rows, peak, layout = dense_rows, dense_peak, "dense"
        else:
            bp = self.bucketed
            rows, layout = bp.padded_rows(), "bucketed"
            peak = max(_peak(bp.n_caps[b], bp.h_caps[b], bp.s_caps[b])
                       for b in range(bp.n_buckets))
        return {"layout": layout, "real_rows": real, "padded_rows": rows,
                "padding_ratio": rows / real,
                "dense_padded_rows": dense_rows,
                "dense_padding_ratio": dense_rows / real,
                "peak_device_bytes": peak,
                "dense_peak_device_bytes": dense_peak}

    def predicted_metrics(self, workload_scaled: bool = False,
                          mode: str = "calibrated", inventory=None,
                          layer_dims: tuple | None = None,
                          technology=None, calibration=None):
        """Cost-model (Eqs. 1-7) prediction for this plan's setting.

        ``mode="derived"`` prices compute through the crossbar mapper
        instead of the Table-1 calibration (DESIGN.md §8); ``inventory`` /
        ``layer_dims`` / ``technology`` / ``calibration`` are forwarded
        to it (DESIGN.md §13)."""
        from repro.core import costmodel
        return costmodel.predict(
            self.setting, self.graph.stats("plan"),
            workload_scaled=workload_scaled, n_clusters=self.n_clusters,
            sample=self.sample, mode=mode, inventory=inventory,
            layer_dims=layer_dims, technology=technology,
            calibration=calibration)

    def compile_mapping(self, cfg=None, hw=None, inventory=None,
                        technology=None, calibration=None):
        """Compile this plan's workload onto a crossbar inventory.

        ``cfg`` (a GNNConfig, optional) supplies the layer dims — without
        it the mapper prices the calibration workload (one
        ``feature_len -> 128`` layer). ``technology`` / ``calibration``
        re-anchor the per-pass primitives (DESIGN.md §13). The result is
        cached on ``self.mapping`` and returned (a
        ``repro.mapper.CompiledMapping``: per-layer tilings, array
        allocation, pass schedule, derived latency/energy)."""
        from repro.mapper.compile import compile_mapping
        dims = (cfg.dims if cfg is not None
                else (max(self.graph.feature_len, 1), 128))
        self.mapping = compile_mapping(
            dims, self.graph.stats("plan"), hw, inventory, self.setting,
            self.n_clusters, self.sample, technology=technology,
            calibration=calibration)
        return self.mapping

    def mapping_report(self, cfg=None, hw=None, inventory=None,
                       technology=None, calibration=None) -> str:
        """Human-readable report of the compiled hardware mapping (tile
        shapes, padding, duplication/serialization, pass schedule, derived
        latency/energy). Compiles on first use; recompiles when any
        argument is given."""
        if (self.mapping is None or cfg is not None or hw is not None
                or inventory is not None or technology is not None
                or calibration is not None):
            self.compile_mapping(cfg, hw=hw, inventory=inventory,
                                 technology=technology,
                                 calibration=calibration)
        return self.mapping.mapping_report()

    def measured_traffic(self, cfg=None, mode: str = "alltoall"):
        """Measured wire traffic of this plan's exchanges — the runtime
        counterpart of ``predicted_metrics`` (bytes per device per layer,
        counted on the executed send/recv tables; DESIGN.md §7). ``cfg``
        (a GNNConfig) supplies per-layer feature dims; without it a single
        input-dim layer is assumed. Returns a
        ``repro.distributed.traffic.TrafficReport``."""
        from repro.distributed.traffic import measure_execution
        return measure_execution(self, cfg=cfg, mode=mode)


def _parse_buckets(buckets) -> int | None:
    """Normalize the ``buckets`` knob: None => dense, 0 => unlimited
    buckets, N > 0 => at most N buckets."""
    if buckets in (None, 0, "off", "dense", False):
        return None
    if buckets in ("auto", -1, True):
        return 0
    n = int(buckets)
    if n <= 0:
        raise ValueError(f"buckets must be 'auto', 'off' or a positive "
                         f"count, got {buckets!r}")
    return n


def plan_execution(g: Graph, setting: str = "centralized",
                   backend: str = "jnp", sample: int = 16,
                   n_clusters: int | None = None,
                   seed: int = 0,
                   spokes_per_head: int = 4,
                   buckets=None,
                   partition_method: str = "bfs") -> ExecutionPlan:
    """Build the ExecutionPlan for one (setting, backend) combination.

    ``n_clusters`` defaults per setting: 1 (centralized), 8 (decentralized
    — one per edge device), 4 (semi — cluster heads, each fronting
    ``spokes_per_head`` member edge devices). Halo/comm tables are pruned
    to the ``sample``-reachable edges the kernels read.

    ``buckets`` selects the capacity-bucketed ragged layout (DESIGN.md
    §12): ``None``/``"off"`` keeps the uniform dense padding, ``"auto"``
    buckets clusters by their natural pow2 capacities, an int N caps the
    bucket count at N. ``partition_method`` picks the cluster heuristic
    (``bfs``/``chunk``/``edge`` — see ``partition``)."""
    assert setting in ("centralized", "decentralized", "semi"), setting
    max_b = _parse_buckets(buckets)
    if setting == "centralized" and max_b is None:
        nbr, wts = g.neighbor_sample(sample)
        return ExecutionPlan(setting, backend, sample, 1, g, None, None,
                             g.features[None], nbr[None], wts[None])
    k = 1 if setting == "centralized" else (
        n_clusters or (8 if setting == "decentralized" else 4))
    # a cluster must own at least one node: planner sweeps over tiny test
    # graphs would otherwise build empty devices (configuration-space
    # robustness, DESIGN.md §10)
    k = max(min(k, g.n_nodes), 1)
    if setting == "semi":
        hier = hier_partition(g, k, nodes_per_region=spokes_per_head,
                              sample=sample, seed=seed)
        if max_b is not None:
            bp = bucket_partition(hier.region, g, sample, max_buckets=max_b)
            nbrs, wtss = build_bucketed_subgraphs(g, bp)
            feats = gather_spoke_features(g, hier)
            return ExecutionPlan(setting, backend, sample, k, g,
                                 hier.region, None, feats, nbrs, wtss,
                                 hier=hier, bucketed=bp)
        sub = build_local_subgraphs(g, hier.region, sample)
        feats = gather_spoke_features(g, hier)
        return ExecutionPlan(setting, backend, sample, k, g, hier.region,
                             sub, feats, sub.neighbors, sub.weights,
                             hier=hier)
    if setting == "centralized":
        part = _from_assignment(g, np.zeros(g.n_nodes, np.int32), 1,
                                sample=sample)
    else:
        part = partition(g, k, seed=seed, sample=sample,
                         method=partition_method)
    if max_b is not None:
        bp = bucket_partition(part, g, sample, max_buckets=max_b)
        nbrs, wtss = build_bucketed_subgraphs(g, bp)
        feats = gather_bucketed_features(g, bp)
        return ExecutionPlan(setting, backend, sample, k, g, part, None,
                             feats, nbrs, wtss, bucketed=bp)
    sub = build_local_subgraphs(g, part, sample)
    feats = gather_features(g, part)
    return ExecutionPlan(setting, backend, sample, k, g, part, sub,
                         feats, sub.neighbors, sub.weights)


def rebalance(g: Graph, part: Partition, latency: np.ndarray,
              frac: float = 0.25, seed: int = 0) -> Partition:
    """Straggler mitigation: shift load away from slow clusters.

    ``latency``: [K] observed (or cost-model-predicted) per-cluster step
    latency. Boundary nodes of clusters slower than the mean are handed to
    their fastest adjacent cluster (at most ``frac`` of the slow cluster's
    nodes move), then the partition tables are rebuilt. Deterministic in
    ``seed``. This is the serving-path analogue of the launcher's
    retry-with-shrunk-mesh: the paper's decentralized setting re-balances
    c_s when a node's latency spikes (DESIGN.md §6).
    """
    latency = np.asarray(latency, np.float64)
    k = part.n_clusters
    assignment = part.assignment.copy()
    mean = latency.mean()
    for c in np.argsort(-latency):
        if latency[c] <= mean * 1.05:
            break
        members = np.nonzero(assignment == c)[0]
        budget = max(int(len(members) * frac), 1)
        # boundary nodes: owned nodes with at least one out-of-cluster edge
        moved = 0
        for u in members:
            lo, hi = int(g.indptr[u]), int(g.indptr[u + 1])
            nbr_clusters = assignment[g.indices[lo:hi]]
            remote = nbr_clusters[nbr_clusters != c]
            if len(remote) == 0:
                continue
            # move to the fastest adjacent cluster that is below the mean
            cand = np.unique(remote)
            cand = cand[latency[cand] < mean]
            if len(cand) == 0:
                continue
            target = int(cand[np.argmin(latency[cand])])
            assignment[u] = target
            moved += 1
            if moved >= budget:
                break
    # rebuild partition tables from the adjusted assignment, keeping the
    # original tables' sample pruning
    return _from_assignment(g, assignment, k, sample=part.sample)


def _from_assignment(g: Graph, assignment: np.ndarray, k: int,
                     sample: int | None = None,
                     self_loops: bool = True) -> Partition:
    """Build full Partition tables from a given node->cluster assignment.

    Halo and comm tables are restricted to ``sample``-reachable edges (see
    ``_sample_edge_mask``); ``comm_volume[i, j]`` counts the *unique* remote
    rows i needs from j — the feature rows an alltoall exchange ships, so
    measured traffic and tabulated e_ij agree by construction."""
    members = [np.nonzero(assignment == c)[0].astype(np.int32)
               for c in range(k)]
    n_max = max(max(len(m) for m in members), 1)
    halos, comm = [], np.zeros((k, k), np.int64)
    used = _sample_edge_mask(g, sample, self_loops)
    dst_cluster = assignment[np.repeat(np.arange(g.n_nodes),
                                       np.diff(g.indptr))]
    src_cluster = assignment[g.indices]
    for c in range(k):
        mask = used & (dst_cluster == c) & (src_cluster != c)
        remote = np.unique(g.indices[mask])
        halos.append(remote.astype(np.int32))
        pairs, counts = np.unique(assignment[remote], return_counts=True)
        comm[c, pairs] = counts
    h_max = max(max((len(h) for h in halos), default=0), 1)
    local_nodes = np.full((k, n_max), -1, np.int32)
    local_mask = np.zeros((k, n_max), bool)
    halo_nodes = np.full((k, h_max), 0, np.int32)
    halo_src = np.full((k, h_max), -1, np.int32)
    for c in range(k):
        local_nodes[c, :len(members[c])] = members[c]
        local_mask[c, :len(members[c])] = True
        halo_nodes[c, :len(halos[c])] = halos[c]
        halo_src[c, :len(halos[c])] = assignment[halos[c]]
    return Partition(assignment, k, local_nodes, local_mask,
                     halo_nodes, halo_src, comm, sample=sample)
