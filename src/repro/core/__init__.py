"""IMA-GNN core: the paper's contribution as composable JAX modules."""
from .graph import Graph, GraphStats, TABLE2_DATASETS, TAXI_STATS, random_graph, dataset_like
from .costmodel import (HardwareParams, DEFAULT_HW, NetMetrics, CoreLatency,
                        predict, compute_latency, communicate_latency, power,
                        headline_averages, table1, pick_setting)
from .partition import (ExecutionPlan, HierPartition, hier_partition,
                        plan_execution)
from . import gnn, taxi, partition

__all__ = [
    "ExecutionPlan", "HierPartition", "hier_partition", "plan_execution",
    "Graph", "GraphStats", "TABLE2_DATASETS", "TAXI_STATS", "random_graph",
    "dataset_like", "HardwareParams", "DEFAULT_HW", "NetMetrics",
    "CoreLatency", "predict", "compute_latency", "communicate_latency",
    "power", "headline_averages", "table1", "pick_setting",
    "gnn", "taxi", "partition",
]
