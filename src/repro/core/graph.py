"""Graph containers, CSR utilities, and the paper's dataset statistics.

Two representations:
  * ``Graph`` — a concrete CSR graph (numpy host-side) with features; used by
    the GNN runtime, the examples, and the tests.
  * ``GraphStats`` — the Table-2 summary statistics (nodes / edges / feature
    length / average cluster size c_s); all the analytical cost model needs.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class GraphStats:
    """Key statistics of a graph workload (IMA-GNN Table 2)."""
    name: str
    n_nodes: int
    n_edges: int
    feature_len: int
    avg_cs: float          # average cluster size / neighbors per node


# IMA-GNN Table 2 — the four evaluation datasets, plus the §4.2 taxi graph
# (10 000 nodes, c_s = 10, 864-byte messages => 216 fp32 feature dims).
TABLE2_DATASETS = {
    "livejournal": GraphStats("livejournal", 4_847_571, 68_993_773, 1, 9),
    "collab":      GraphStats("collab",        372_475, 24_574_995, 496, 263),
    "cora":        GraphStats("cora",             2_708,      5_429, 1433, 4),
    "citeseer":    GraphStats("citeseer",         3_327,      4_732, 3703, 2),
}
TAXI_STATS = GraphStats("taxi", 10_000, 100_000, 216, 10)


@dataclasses.dataclass
class Graph:
    """A concrete CSR graph with node features (host-side numpy)."""
    indptr: np.ndarray            # [N+1] int64
    indices: np.ndarray           # [E]   int32
    edge_weight: np.ndarray | None  # [E] float32 (None => unweighted)
    features: np.ndarray | None     # [N, F] float32
    self_loop: np.ndarray | None = None  # [N] implicit self-loop weight
    #                                      (None => 1.0, i.e. plain A + I)

    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def n_edges(self) -> int:
        return len(self.indices)

    @property
    def feature_len(self) -> int:
        return 0 if self.features is None else self.features.shape[1]

    @property
    def avg_degree(self) -> float:
        return self.n_edges / max(self.n_nodes, 1)

    def stats(self, name: str = "graph") -> GraphStats:
        return GraphStats(name, self.n_nodes, self.n_edges,
                          self.feature_len, self.avg_degree)

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def gcn_normalize(self) -> "Graph":
        """Symmetric GCN normalization ``A_hat = D^-1/2 (A + I) D^-1/2``:
        w_ij = 1/sqrt((d_i+1)(d_j+1)) on the stored edges, and the implicit
        self loop added by the aggregation layer carries A_hat's diagonal
        weight 1/(d_i+1) (recorded in ``self_loop``)."""
        deg = self.degrees().astype(np.float64) + 1.0
        src = self.indices
        dst = np.repeat(np.arange(self.n_nodes), np.diff(self.indptr))
        w = 1.0 / np.sqrt(deg[dst] * deg[src])
        return Graph(self.indptr, self.indices, w.astype(np.float32),
                     self.features, (1.0 / deg).astype(np.float32))

    def neighbor_sample(self, sample: int, self_loops: bool = True):
        """Padded fixed-size neighbor sample (paper Table-2 mapping)."""
        from repro.kernels.csr_aggregate import pad_neighbors
        return pad_neighbors(self.indptr, self.indices, self.edge_weight,
                             sample, self_loops=self_loops,
                             self_loop_weight=self.self_loop)


def random_graph(n_nodes: int, n_edges: int, feature_len: int,
                 seed: int = 0, weighted: bool = True) -> Graph:
    """Synthetic CSR graph with a skewed (power-law-ish) degree profile,
    matching the scale statistics of a requested dataset."""
    rng = np.random.default_rng(seed)
    # skewed destination distribution => realistic degree imbalance
    raw = rng.zipf(1.6, size=n_edges * 2) % n_nodes
    dst = raw[:n_edges].astype(np.int64)
    src = rng.integers(0, n_nodes, size=n_edges).astype(np.int32)
    order = np.argsort(dst, kind="stable")
    dst, src = dst[order], src[order]
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.add.at(indptr, dst + 1, 1)
    indptr = np.cumsum(indptr)
    ew = (rng.random(n_edges).astype(np.float32) if weighted else None)
    feats = rng.normal(size=(n_nodes, feature_len)).astype(np.float32)
    return Graph(indptr, src, ew, feats)


def dataset_like(name: str, scale: float = 1.0, seed: int = 0) -> Graph:
    """A synthetic graph with (optionally downscaled) Table-2 statistics.

    Valid names are the Table-2 datasets plus ``"taxi"`` (the §4.2 case
    study); anything else raises ``ValueError`` — a typo must not silently
    substitute a wrong-scale graph.
    """
    datasets = dict(TABLE2_DATASETS, taxi=TAXI_STATS)
    if name not in datasets:
        raise ValueError(f"unknown dataset {name!r}; valid names: "
                         f"{sorted(datasets)}")
    s = datasets[name]
    n = max(int(s.n_nodes * scale), 8)
    e = max(int(s.n_edges * scale), 16)
    return random_graph(n, e, s.feature_len, seed=seed)
