"""hetGNN-LSTM taxi demand/supply forecaster (IMA-GNN §4.2, ref [26]).

The case-study model: a heterogeneous GNN message-passes over three edge
types (road connectivity, location proximity, destination similarity), then
an LSTM consumes the P-step history of fused node states and predicts the
Q-step future demand/supply maps X_{t+1:t+Q} in an m x n region around each
taxi. Faithful to the structure of [26] (Fig. 7): per-edge-type relational
aggregation -> fuse -> LSTM -> linear head.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.csr_aggregate import aggregate


@dataclasses.dataclass(frozen=True)
class TaxiConfig:
    m: int = 8                 # region rows
    n: int = 8                 # region cols
    p_hist: int = 6            # history length P
    q_future: int = 3          # prediction horizon Q
    hidden: int = 64           # hetGNN fused embedding
    lstm_hidden: int = 64
    n_edge_types: int = 3      # road / proximity / destination
    sample: int = 8            # neighbor sample per edge type

    @property
    def region(self) -> int:
        return self.m * self.n


def init_params(key: jax.Array, cfg: TaxiConfig) -> dict:
    k = jax.random.split(key, 8)
    f_in = cfg.region                      # flattened demand+supply map / step
    glorot = lambda kk, a, b: jax.random.normal(kk, (a, b), jnp.float32) * jnp.sqrt(2.0 / (a + b))
    return {
        # one relational transform per edge type + a self transform
        "w_rel": jnp.stack(
            [glorot(jax.random.fold_in(k[0], r), f_in, cfg.hidden)
             for r in range(cfg.n_edge_types)]),
        "w_self": glorot(k[1], f_in, cfg.hidden),
        "b_fuse": jnp.zeros((cfg.hidden,), jnp.float32),
        # LSTM cell
        "w_i": glorot(k[2], cfg.hidden, 4 * cfg.lstm_hidden),
        "w_h": glorot(k[3], cfg.lstm_hidden, 4 * cfg.lstm_hidden),
        "b_lstm": jnp.zeros((4 * cfg.lstm_hidden,), jnp.float32),
        # head: Q future region maps
        "w_out": glorot(k[4], cfg.lstm_hidden, cfg.q_future * cfg.region),
        "b_out": jnp.zeros((cfg.q_future * cfg.region,), jnp.float32),
    }


def het_message_pass(params: dict, x_t: jax.Array, neighbors: jax.Array,
                     weights: jax.Array, cfg: TaxiConfig) -> jax.Array:
    """One hetGNN step at one time slice.

    x_t: [N, region]; neighbors/weights: [R, N, S] per edge type.
    Returns fused node state [N, hidden].
    """
    h = jnp.dot(x_t, params["w_self"])
    for r in range(cfg.n_edge_types):
        z_r = aggregate(x_t, neighbors[r], weights[r])      # [N, region]
        h = h + jnp.dot(z_r, params["w_rel"][r])
    return jax.nn.relu(h + params["b_fuse"])


def _lstm_cell(params, h, c, x):
    gates = x @ params["w_i"] + h @ params["w_h"] + params["b_lstm"]
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h, c


@partial(jax.jit, static_argnames="cfg")
def forward(params: dict, x_hist: jax.Array, neighbors: jax.Array,
            weights: jax.Array, cfg: TaxiConfig) -> jax.Array:
    """x_hist: [P, N, m*n] history; returns [N, Q, m, n] predictions."""
    n_nodes = x_hist.shape[1]
    h = jnp.zeros((n_nodes, cfg.lstm_hidden), jnp.float32)
    c = jnp.zeros((n_nodes, cfg.lstm_hidden), jnp.float32)

    def step(carry, x_t):
        h, c = carry
        m_t = het_message_pass(params, x_t, neighbors, weights, cfg)
        h, c = _lstm_cell(params, h, c, m_t)
        return (h, c), None

    (h, _), _ = jax.lax.scan(step, (h, c), x_hist)
    out = h @ params["w_out"] + params["b_out"]
    return out.reshape(n_nodes, cfg.q_future, cfg.m, cfg.n)


@partial(jax.jit, static_argnames="cfg")
def loss_fn(params, x_hist, neighbors, weights, target, cfg: TaxiConfig):
    """MSE over the Q-step future maps. target: [N, Q, m, n]."""
    pred = forward(params, x_hist, neighbors, weights, cfg)
    return jnp.mean((pred - target) ** 2)


grad_fn = jax.jit(jax.value_and_grad(loss_fn), static_argnames="cfg")


def synthetic_stream(key: jax.Array, n_nodes: int, steps: int,
                     cfg: TaxiConfig):
    """Deterministic synthetic spatiotemporal demand stream: a smooth
    sinusoidal field + node-specific phase, so the model has learnable
    structure. Returns [steps, N, m*n]."""
    t = jnp.arange(steps, dtype=jnp.float32)[:, None, None]
    node_phase = jax.random.uniform(key, (1, n_nodes, 1)) * 6.28
    cell = jnp.arange(cfg.region, dtype=jnp.float32)[None, None, :]
    base = jnp.sin(0.3 * t + node_phase + 0.1 * cell)
    noise = 0.05 * jax.random.normal(jax.random.fold_in(key, 1),
                                     (steps, n_nodes, cfg.region))
    return base + noise
