"""Architecture registry: --arch <id> resolution for all assigned configs."""
from __future__ import annotations

import importlib

_MODULES = {
    "minicpm3-4b": "minicpm3_4b",
    "internlm2-1.8b": "internlm2_1_8b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "yi-34b": "yi_34b",
    "whisper-base": "whisper_base",
    "grok-1-314b": "grok1_314b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "rwkv6-3b": "rwkv6_3b",
    "qwen2-vl-2b": "qwen2_vl_2b",
}

ARCHS = tuple(_MODULES)


def get_config(name: str, smoke: bool = False):
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.SMOKE if smoke else mod.CONFIG


from .shapes import SHAPES, LONG_OK, cells, ShapeSpec  # noqa: E402

__all__ = ["ARCHS", "get_config", "SHAPES", "LONG_OK", "cells", "ShapeSpec"]
