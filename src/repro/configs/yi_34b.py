"""Yi-34B [arXiv:2403.04652]: llama-arch dense GQA."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b", n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab=64000, head_dim=128, remat="full",
)

SMOKE = ModelConfig(
    name="yi-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, attn_chunk=8,
)
