"""RWKV-6 "Finch" 3B [arXiv:2404.05892]: attention-free, data-dependent
decay time-mix + channel-mix."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=8960, vocab=65536, pattern=("rwkv",), rwkv_head_dim=64,
)

SMOKE = ModelConfig(
    name="rwkv6-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, pattern=("rwkv",), rwkv_head_dim=16, attn_chunk=8,
)
