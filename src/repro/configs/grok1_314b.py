"""Grok-1 314B [hf:xai-org/grok-1]: MoE, 8 experts top-2."""
from repro.models.config import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab=131072, head_dim=128,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32768), remat="full",
)

SMOKE = ModelConfig(
    name="grok1-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256,
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64), attn_chunk=8,
)
