"""RecurrentGemma-9B [arXiv:2402.19427]: RG-LRU + local attention, 2:1
(pattern rglru, rglru, local; MQA kv=1; window 2048)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", n_layers=38, d_model=4096, n_heads=16,
    n_kv_heads=1, d_ff=12288, vocab=256000, head_dim=256,
    pattern=("rglru", "rglru", "local"), local_window=2048,
    rglru_width=4096, tie_embeddings=True, remat="full",
)

SMOKE = ModelConfig(
    name="rgemma-smoke", n_layers=3, d_model=64, n_heads=4, n_kv_heads=1,
    d_ff=128, vocab=256, head_dim=16,
    pattern=("rglru", "rglru", "local"), local_window=16, rglru_width=64,
    tie_embeddings=True, attn_chunk=8,
)
