"""Whisper-base [arXiv:2212.04356]: encoder-decoder, conv frontend stubbed —
``input_specs()`` supplies precomputed frame embeddings [B, 1500, d_model]."""
from repro.models.config import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51865, act="gelu",
    encoder=EncoderConfig(n_layers=6, n_frames=1500),
)

SMOKE = ModelConfig(
    name="whisper-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, act="gelu",
    encoder=EncoderConfig(n_layers=2, n_frames=16), attn_chunk=8,
)
