"""Qwen2-VL-2B [arXiv:2409.12191]: GQA + M-RoPE text backbone; the vision
patch frontend is stubbed — ``input_specs()`` supplies 3-axis M-RoPE position
ids (temporal/height/width), identical per axis for pure text."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab=151936, head_dim=128,
    mrope_sections=(16, 24, 24), tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="qwen2vl-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, head_dim=16,
    mrope_sections=(2, 3, 3), tie_embeddings=True, attn_chunk=8,
)
