"""Assigned input shapes and the per-arch skip policy.

LM transformer shapes are seq_len x global_batch. ``decode_*`` / ``long_*``
lower ``serve_step`` (one token against a seq_len cache); the others lower
``train_step`` / ``prefill_step``.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str             # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# long_500k needs sub-quadratic attention: run only for SSM / hybrid /
# windowed archs (see DESIGN.md §Shape/skip policy).
LONG_OK = {"rwkv6-3b", "recurrentgemma-9b", "h2o-danube-3-4b"}


def cells(arch_names):
    """All (arch, shape) cells with skip annotations."""
    out = []
    for a in arch_names:
        for s in SHAPES.values():
            skip = (s.name == "long_500k" and a not in LONG_OK)
            reason = ("full-attention arch: 500k decode is quadratic-cost "
                      "with no windowing in the published config"
                      if skip else "")
            out.append((a, s.name, skip, reason))
    return out
