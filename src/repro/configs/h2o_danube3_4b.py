"""H2O-Danube3-4B [arXiv:2401.16818]: llama+mistral mix, sliding-window attn."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", n_layers=24, d_model=3840, n_heads=32,
    n_kv_heads=8, d_ff=10240, vocab=32000, head_dim=120,
    window=4096,                       # mistral-style SWA
)

SMOKE = ModelConfig(
    name="danube3-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, window=16, attn_chunk=8,
)
