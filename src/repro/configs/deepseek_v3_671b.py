"""DeepSeek-V3 671B [arXiv:2412.19437]: MLA + 1 shared / 256 routed top-8
MoE (sigmoid router), 3 leading dense layers, MTP."""
from repro.models.config import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", n_layers=61, d_model=7168, n_heads=128,
    n_kv_heads=128, d_ff=2048, vocab=129280,
    mla=MLAConfig(q_lora=1536, kv_lora=512, rope_dim=64, nope_dim=128,
                  v_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048, n_shared=1,
                  router="sigmoid", n_dense_layers=3, d_ff_dense=18432),
    mtp=True, remat="full",
)

SMOKE = ModelConfig(
    name="deepseek-smoke", n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256,
    mla=MLAConfig(q_lora=32, kv_lora=16, rope_dim=8, nope_dim=16, v_dim=16),
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=32, n_shared=1,
                  router="sigmoid", n_dense_layers=1, d_ff_dense=128),
    mtp=True, attn_chunk=8,
)
