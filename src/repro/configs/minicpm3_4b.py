"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B]: dense, MLA attention."""
from repro.models.config import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=6400, vocab=73448, head_dim=96,
    mla=MLAConfig(q_lora=768, kv_lora=256, rope_dim=32, nope_dim=64, v_dim=64),
    tie_embeddings=True, remat="full",
)

SMOKE = ModelConfig(
    name="minicpm3-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, head_dim=16,
    mla=MLAConfig(q_lora=32, kv_lora=16, rope_dim=8, nope_dim=16, v_dim=16),
    tie_embeddings=True, attn_chunk=8,
)
