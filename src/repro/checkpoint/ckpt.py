"""Fault-tolerant checkpointing: step-atomic, checksummed, async, elastic.

Layout: <dir>/step_<n>/{arrays.npz, tree.json, checksum.txt} written to a
tmp dir and atomically renamed, so a crash mid-write never corrupts the
latest checkpoint. Restore verifies the checksum and falls back to the
previous step on corruption. Arrays are saved device-agnostic (gathered to
host), so a checkpoint taken on one mesh restores onto any other mesh —
elastic re-sharding is just restore + device_put with new shardings.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _checksum(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def save_checkpoint(ckpt_dir: str, step: int, tree, *, blocking: bool = True):
    """Atomically persist a pytree at a step. Returns the final directory."""
    leaves, treedef = _flatten(tree)

    # npz can't round-trip ml_dtypes (bf16/f8): store those upcast to f32
    # (lossless) — restore casts back to the target leaf dtype.
    def _to_np(x):
        a = np.asarray(x)
        if a.dtype.kind == "V" or str(a.dtype) in (
                "bfloat16", "float8_e4m3fn", "float8_e5m2"):
            return a.astype(np.float32)
        return a

    arrays = {f"a{i}": _to_np(x) for i, x in enumerate(leaves)}
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = final + ".tmp"

    def write():
        os.makedirs(tmp, exist_ok=True)
        npz = os.path.join(tmp, "arrays.npz")
        np.savez(npz, **arrays)
        with open(os.path.join(tmp, "tree.json"), "w") as f:
            json.dump({"treedef": str(treedef), "n": len(leaves),
                       "step": step}, f)
        with open(os.path.join(tmp, "checksum.txt"), "w") as f:
            f.write(_checksum(npz))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if blocking:
        write()
        return final
    t = threading.Thread(target=write, daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, like, step: int | None = None):
    """Restore into the structure of ``like``. Verifies integrity; on a
    corrupt checkpoint falls back to the previous step. Returns
    (tree, step) or (None, None)."""
    while True:
        step = step if step is not None else latest_step(ckpt_dir)
        if step is None:
            return None, None
        d = os.path.join(ckpt_dir, f"step_{step:010d}")
        npz = os.path.join(d, "arrays.npz")
        try:
            with open(os.path.join(d, "checksum.txt")) as f:
                expect = f.read().strip()
            if _checksum(npz) != expect:
                raise IOError("checksum mismatch")
            data = np.load(npz)
            leaves, treedef = _flatten(like)
            assert len(data.files) == len(leaves), "leaf count mismatch"
            new_leaves = [data[f"a{i}"].astype(np.asarray(l).dtype)
                          for i, l in enumerate(leaves)]
            return treedef.unflatten(new_leaves), step
        except Exception:
            # corruption: drop this step, try the previous one
            prev = [s for s in (latest_step(ckpt_dir),) if s is not None]
            steps = [int(x.split("_")[1]) for x in os.listdir(ckpt_dir)
                     if x.startswith("step_") and not x.endswith(".tmp")
                     and int(x.split("_")[1]) < step]
            if not steps:
                return None, None
            step = max(steps)


class CheckpointManager:
    """Keeps the last ``keep`` checkpoints, saves every ``every`` steps,
    supports async save and elastic restore onto a new mesh."""

    def __init__(self, ckpt_dir: str, every: int = 100, keep: int = 3):
        self.dir = ckpt_dir
        self.every = every
        self.keep = keep
        self._pending = None

    def maybe_save(self, step: int, tree, blocking: bool = False):
        if step % self.every:
            return False
        if self._pending is not None and hasattr(self._pending, "join"):
            self._pending.join()
        self._pending = save_checkpoint(self.dir, step, tree,
                                        blocking=blocking)
        self._gc()
        return True

    def finalize(self):
        if self._pending is not None and hasattr(self._pending, "join"):
            self._pending.join()

    def _gc(self):
        if not os.path.isdir(self.dir):
            return
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    def restore(self, like, mesh=None, shardings=None):
        """Restore latest; if mesh+shardings given, place shards (elastic)."""
        tree, step = restore_checkpoint(self.dir, like)
        if tree is None:
            return None, None
        if mesh is not None and shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(
                    x, jax.sharding.NamedSharding(mesh, s)), tree, shardings)
        return tree, step
