"""CAM-backed k-nearest-neighbor graph construction.

The traversal core's search CAM (IMA-GNN Fig. 2(c), ``kernels.cam_match``)
does one thing — O(1) associative equality match with a per-query popcount
— and that is exactly the primitive approximate-nearest-neighbor selection
over LSH band signatures needs: load every node's tagged band signatures
(``signature.tag_bands``) into one flat CAM array, search each query
node's tagged bands against it, and the per-(query, node) match count *is*
the number of agreeing bands, i.e. the similarity score. Top-k over those
scores (self excluded, deterministic tie-break toward smaller node id)
yields the edge list.

Two result-equivalent paths compute the scores:

  * ``mode="cam"``     — through ``kernels.cam_match.search`` (its
    ``backend=`` picks the jnp oracle or the Pallas kernel), the bitmap
    folded per band pair. Query rows are chunked so the [Qc*B, N*B] match
    bitmap stays bounded.
  * ``mode="topk"``    — the fallback: a direct ``jnp`` signature compare
    reduced over bands, no CAM anywhere.

Both produce the *same integer score matrix* — band tags make cross-band
CAM matches impossible and tagged entries are non-negative, so the CAM
bitmap folds to exactly the per-band equality count — and selection runs
through one shared ``lax.top_k`` on a collision-free combined key, so the
resulting edge lists are identical by construction (gated bit-for-bit in
``benchmarks/cam_topk.py`` and ``tests/test_neighbors.py``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph
from repro.kernels.cam_match import search as cam_search
from repro.neighbors.signature import (DEFAULT_BAND_BITS, DEFAULT_BANDS,
                                       lsh_signatures, tag_bands)

NEIGHBOR_MODES = ("topk", "cam")

# bound on the CAM match-bitmap footprint per query chunk: Qc*B * N*B int8
_BITMAP_BUDGET = 1 << 24


def band_match_counts(sig_e: np.ndarray, sig_q: np.ndarray,
                      mode: str = "topk", backend: str = "jnp",
                      band_bits: int = DEFAULT_BAND_BITS,
                      interpret: bool | None = None) -> np.ndarray:
    """[N, B] entry sigs x [Q, B] query sigs -> [Q, N] int32 band-match
    counts (agreeing bands per pair). ``mode="cam"`` routes through the
    traversal CAM kernel; ``mode="topk"`` through the jnp oracle compare —
    identical outputs by construction.
    """
    if mode not in NEIGHBOR_MODES:
        raise ValueError(f"unknown neighbor mode {mode!r}; "
                         f"one of {NEIGHBOR_MODES}")
    sig_e = np.asarray(sig_e, np.int32)
    sig_q = np.asarray(sig_q, np.int32)
    if sig_e.ndim != 2 or sig_q.ndim != 2 or sig_e.shape[1] != sig_q.shape[1]:
        raise ValueError(f"band mismatch: entries {sig_e.shape} vs queries "
                         f"{sig_q.shape}")
    n, b = sig_e.shape
    q = sig_q.shape[0]
    if mode == "topk":
        counts = (jnp.asarray(sig_q)[:, None, :]
                  == jnp.asarray(sig_e)[None, :, :]).sum(axis=2)
        return np.asarray(counts, np.int32)
    entries = jnp.asarray(tag_bands(sig_e, band_bits))        # [N * B]
    tagged_q = tag_bands(sig_q, band_bits).reshape(q, b)
    chunk = max(_BITMAP_BUDGET // max(n * b * b, 1), 1)
    out = np.empty((q, n), np.int32)
    for lo in range(0, q, chunk):
        qc = tagged_q[lo:lo + chunk]                          # [Qc, B]
        match, _ = cam_search(entries, jnp.asarray(qc.reshape(-1)),
                              backend=backend, interpret=interpret)
        # [Qc*B, N*B] bitmap -> per-(query, node) agreeing-band count:
        # tags zero every cross-band block, so the double band-sum is the
        # same-band equality count
        folded = np.asarray(match, np.int32) \
            .reshape(len(qc), b, n, b).sum(axis=(1, 3))
        out[lo:lo + len(qc)] = folded
    return out


def select_topk(counts: np.ndarray, k: int,
                exclude_self: bool = False) -> tuple:
    """Deterministic top-k selection shared by every mode.

    counts: [Q, N] integer scores. Returns (neighbors [Q, k] int32,
    scores [Q, k] int32), ordered by (score desc, node id asc) — the
    combined key is collision-free, so ``lax.top_k``'s tie policy can
    never leak in and CAM/top-k paths select identically.
    """
    counts = np.asarray(counts)
    q, n = counts.shape
    if not 1 <= k <= n - (1 if exclude_self else 0):
        raise ValueError(f"k={k} out of range for {n} candidate nodes"
                         f"{' (self excluded)' if exclude_self else ''}")
    c = counts.astype(np.int64)
    if exclude_self:
        if q != n:
            raise ValueError(f"exclude_self needs a square score matrix, "
                             f"got {counts.shape}")
        c = c.copy()
        np.fill_diagonal(c, -1)
    ids = np.arange(n, dtype=np.int64)
    key = c * n + (n - 1 - ids)[None, :]
    if abs(key).max(initial=0) >= np.iinfo(np.int32).max:
        raise ValueError(f"combined selection key overflows int32 for "
                         f"{n} nodes at max score {counts.max()}")
    top, _ = jax.lax.top_k(jnp.asarray(key.astype(np.int32)), k)
    top = np.asarray(top, np.int64)
    nbr = (n - 1 - (top % n)).astype(np.int32)
    return nbr, (top // n).astype(np.int32)


def knn_graph(features, k: int = 8, n_bands: int = DEFAULT_BANDS,
              band_bits: int = DEFAULT_BAND_BITS, seed: int = 0,
              mode: str = "topk", backend: str = "jnp",
              min_bands: int = 1,
              interpret: bool | None = None) -> Graph:
    """Build the feature-similarity ``Graph`` the runtimes serve.

    Row ``i`` of the CSR holds node i's selected similar nodes as incoming
    sources (the repo's dst-major edge convention), weighted by the
    agreeing-band fraction. Candidates matching fewer than ``min_bands``
    bands are dropped (a zero-band match carries no similarity evidence),
    so degrees are at most — not exactly — ``k``. ``mode``/``backend``
    pick the scoring path; every combination yields the identical graph.
    """
    x = np.asarray(features, np.float32)
    sigs = lsh_signatures(x, n_bands=n_bands, band_bits=band_bits, seed=seed)
    counts = band_match_counts(sigs, sigs, mode=mode, backend=backend,
                               band_bits=band_bits, interpret=interpret)
    nbr, score = select_topk(counts, k, exclude_self=True)
    keep = score >= max(min_bands, 1)
    degrees = keep.sum(axis=1)
    indptr = np.zeros(x.shape[0] + 1, np.int64)
    np.cumsum(degrees, out=indptr[1:])
    indices = nbr[keep].astype(np.int32)
    weights = (score[keep].astype(np.float32) / float(n_bands))
    return Graph(indptr, indices, weights, x)
