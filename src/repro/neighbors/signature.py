"""Quantized LSH band signatures — the discrete keys the edge CAM matches.

A CAM does exact associative lookup, so similarity search over continuous
feature vectors needs a discretization whose *collisions* encode
similarity. The classic construction is random-hyperplane LSH (sign-random
projections): project a feature vector onto ``band_bits`` random
hyperplanes and pack the sign bits into one integer — one *band
signature*. Two vectors agree on a band with probability
``(1 - theta/pi) ** band_bits`` (theta the angle between them), so the
number of agreeing bands out of ``n_bands`` independent bands is a
monotone similarity estimate — and counting agreeing bands is exactly what
the search CAM's match lines + popcount compute (``kernels.cam_match``).

Band signatures are deliberately small non-negative int32s so they can
live in the same CAM entry format as CSR column indices: valid signatures
occupy ``[0, 2**band_bits)`` and the band *tag* (``tag_bands``) offsets
band ``b`` into its own disjoint id range, so a single flat CAM array
holds every band of every node and cross-band matches are impossible by
construction — the one-array layout ``knn.band_match_counts`` searches.
"""
from __future__ import annotations

import numpy as np

DEFAULT_BANDS = 8
DEFAULT_BAND_BITS = 8

# band tags must keep tagged ids inside int32 (the CAM entry dtype)
_MAX_TAG_BITS = 30


def lsh_signatures(features, n_bands: int = DEFAULT_BANDS,
                   band_bits: int = DEFAULT_BAND_BITS,
                   seed: int = 0) -> np.ndarray:
    """[N, F] float features -> [N, n_bands] int32 band signatures.

    Deterministic in (seed, n_bands, band_bits, F): the hyperplane bank is
    drawn once from ``default_rng(seed)``, so signatures — and therefore
    the k-NN graphs built from them — reproduce exactly across runs and
    across the CAM/top-k selection paths.
    """
    if n_bands < 1 or band_bits < 1:
        raise ValueError(f"need n_bands >= 1 and band_bits >= 1, got "
                         f"({n_bands}, {band_bits})")
    if int(np.ceil(np.log2(max(n_bands, 1))) + band_bits) > _MAX_TAG_BITS:
        raise ValueError(
            f"n_bands={n_bands} x band_bits={band_bits} overflows the "
            f"int32 CAM entry space; keep log2(n_bands) + band_bits <= "
            f"{_MAX_TAG_BITS}")
    x = np.asarray(features, np.float32)
    if x.ndim != 2:
        raise ValueError(f"features must be [N, F], got shape {x.shape}")
    rng = np.random.default_rng(seed)
    planes = rng.normal(size=(x.shape[1], n_bands * band_bits)) \
        .astype(np.float32)
    bits = (x @ planes) > 0.0                      # [N, n_bands * band_bits]
    bits = bits.reshape(x.shape[0], n_bands, band_bits)
    weights = (1 << np.arange(band_bits, dtype=np.int64))
    return (bits * weights).sum(axis=2).astype(np.int32)


def tag_bands(sigs: np.ndarray, band_bits: int = DEFAULT_BAND_BITS
              ) -> np.ndarray:
    """[N, B] band signatures -> [N * B] flat tagged CAM entries.

    Entry ``i * B + b`` is ``b * 2**band_bits + sigs[i, b]`` — band ``b``
    signatures occupy their own disjoint non-negative id range, so a flat
    equality match (the CAM search) can only pair same-band signatures.
    """
    sigs = np.asarray(sigs, np.int64)
    if sigs.ndim != 2:
        raise ValueError(f"sigs must be [N, n_bands], got shape {sigs.shape}")
    if sigs.min(initial=0) < 0 or sigs.max(initial=0) >= (1 << band_bits):
        raise ValueError(f"signatures must lie in [0, 2**{band_bits}); got "
                         f"range [{sigs.min()}, {sigs.max()}]")
    bands = np.arange(sigs.shape[1], dtype=np.int64)[None, :]
    tagged = bands * (1 << band_bits) + sigs
    return tagged.reshape(-1).astype(np.int32)
