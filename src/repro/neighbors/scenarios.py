"""Feature-similarity workloads: the non-taxi scenarios the CAM opens.

The taxi/Table-2 graphs arrive with explicit edges; recommendation and
stream-anomaly workloads arrive as bare feature vectors and the *graph is
built* by nearest-neighbor search — the step ``knn.knn_graph`` runs on the
CAM. Two ``dataset_like``-style synthetic generators, deterministic in
(name, seed):

  * ``recsys``  — users drawn around ``n_topics`` latent taste centroids
    (mixture of Gaussians): the k-NN graph's edges connect same-taste
    users, the structure collaborative-filtering GNNs aggregate over.
  * ``anomaly`` — a stream of mostly-nominal readings plus a small
    fraction of far-outlier rows: nominal nodes form a dense mutual k-NN
    core while anomalies attach by weak (few-band) edges — the structural
    signal a GNN anomaly scorer reads.

``scenario_graph`` returns the served ``Graph`` (features attached);
``scenario_features`` exposes the raw table plus ground-truth labels
(topic id / anomaly flag) for model-quality experiments.
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import Graph
from repro.neighbors.knn import knn_graph

SCENARIOS = ("recsys", "anomaly")


def scenario_features(name: str, n_nodes: int = 512, feature_len: int = 32,
                      seed: int = 0, n_topics: int = 8,
                      anomaly_frac: float = 0.05) -> tuple:
    """(features [N, F] float32, labels [N] int32) for one scenario.

    ``recsys`` labels are topic ids; ``anomaly`` labels are 0 (nominal) /
    1 (outlier). Unknown names raise ``ValueError`` naming the valid set —
    a typo must not silently substitute a wrong workload.
    """
    if name not in SCENARIOS:
        raise ValueError(f"unknown scenario {name!r}; valid names: "
                         f"{sorted(SCENARIOS)}")
    if n_nodes < 2 or feature_len < 1:
        raise ValueError(f"need n_nodes >= 2 and feature_len >= 1, got "
                         f"({n_nodes}, {feature_len})")
    rng = np.random.default_rng(seed)
    if name == "recsys":
        topics = rng.integers(0, max(n_topics, 1), size=n_nodes)
        centroids = rng.normal(size=(max(n_topics, 1), feature_len)) * 3.0
        x = centroids[topics] + rng.normal(size=(n_nodes, feature_len)) * 0.7
        return x.astype(np.float32), topics.astype(np.int32)
    base = rng.normal(size=feature_len) * 2.0
    x = base[None, :] + rng.normal(size=(n_nodes, feature_len)) * 0.5
    n_anom = max(int(n_nodes * anomaly_frac), 1)
    anom = rng.choice(n_nodes, size=n_anom, replace=False)
    x[anom] += rng.normal(size=(n_anom, feature_len)) * 6.0
    labels = np.zeros(n_nodes, np.int32)
    labels[anom] = 1
    return x.astype(np.float32), labels


def scenario_graph(name: str, n_nodes: int = 512, feature_len: int = 32,
                   k: int = 8, seed: int = 0, neighbor_mode: str = "topk",
                   backend: str = "jnp", **knn_kw) -> Graph:
    """Build one scenario's served feature-similarity ``Graph``.

    ``neighbor_mode``/``backend`` pick the scoring path exactly as
    ``knn.knn_graph`` does; every combination yields the identical graph
    (the fallback contract), so the choice is purely a hardware/pricing
    decision — the planner's ``neighbor_mode`` axis.
    """
    x, _ = scenario_features(name, n_nodes=n_nodes, feature_len=feature_len,
                             seed=seed)
    return knn_graph(x, k=k, seed=seed, mode=neighbor_mode,
                     backend=backend, **knn_kw)
