"""Neighbor selection on the traversal CAM (DESIGN.md §15).

CAM-backed k-nearest-neighbor graph construction over LSH band signatures
plus the synthetic feature-similarity scenarios it opens. The streaming
counterpart — CAM dirty-frontier membership — lives in
``repro.streaming.frontier`` (``mode="cam"``); the planner prices both
under the ``neighbor_mode`` axis (``repro.planner.space``).
"""
from .knn import (NEIGHBOR_MODES, band_match_counts, knn_graph,  # noqa: F401
                  select_topk)
from .scenarios import (SCENARIOS, scenario_features,  # noqa: F401
                        scenario_graph)
from .signature import lsh_signatures, tag_bands  # noqa: F401

__all__ = ["NEIGHBOR_MODES", "band_match_counts", "knn_graph",
           "select_topk", "SCENARIOS", "scenario_features",
           "scenario_graph", "lsh_signatures", "tag_bands"]
