"""Persistent (geometry, platform)-keyed store of tuning winners.

The mapper caches a ``CompiledMapping`` on its ``ExecutionPlan``; the
tuner needs the same property across *processes* — measurement is the
expensive step, and a serving process should never re-time a geometry a
previous run already decided. Entries are keyed by the geometry key plus
the platform tag (``cpu-interp``, ``tpu``, ...), because a winner on the
interpreter says nothing about a winner on hardware.

Serialization is deterministic: sorted keys, fixed indent — two caches
holding the same decisions are byte-identical files (regression-tested in
tests/test_tuning.py), which makes the CI cache artifact diffable the
same way BENCH_*.json artifacts are.
"""
from __future__ import annotations

import json
import os

from .space import CONFIG_TYPES

DEFAULT_CACHE_PATH = os.path.join("results", "tuned_configs.json")


def _key_str(geom_key: tuple, platform: str) -> str:
    return "|".join(str(p) for p in (*geom_key, platform))


class TuneCache:
    """Dict-of-records tuning cache with deterministic JSON round-trip."""

    def __init__(self, path: str | None = None, entries: dict | None = None):
        self.path = path
        self.entries = dict(entries or {})

    @classmethod
    def load(cls, path: str = DEFAULT_CACHE_PATH) -> "TuneCache":
        entries = {}
        if os.path.exists(path):
            with open(path) as f:
                entries = json.load(f)
        return cls(path, entries)

    # ---- record access ----------------------------------------------------
    def get(self, geom, platform: str):
        """The cached winner config for (geometry, platform), or None."""
        rec = self.entries.get(_key_str(geom.key(), platform))
        if rec is None:
            return None
        return CONFIG_TYPES[rec["kernel"]](**rec["config"])

    def put(self, geom, platform: str, config, **meta) -> None:
        self.entries[_key_str(geom.key(), platform)] = dict(
            kernel=geom.kernel, geometry=geom.as_dict(),
            platform=platform, config=config.as_dict(), **meta)

    def configs_for(self, platform: str):
        """[(geometry_key_str_prefix, config)] — feeds registry.activate.

        Yields (geometry key tuple, config) pairs for one platform; the
        key tuple is rebuilt from the stored geometry dict."""
        from .space import GEOMETRY_TYPES
        for rec in self.entries.values():
            if rec.get("platform") != platform:
                continue
            gd = dict(rec["geometry"])
            gd.pop("kernel", None)
            geom = GEOMETRY_TYPES[rec["kernel"]](**gd)
            yield geom.key(), CONFIG_TYPES[rec["kernel"]](**rec["config"])

    # ---- deterministic persistence ---------------------------------------
    def dumps(self) -> str:
        return json.dumps(self.entries, sort_keys=True, indent=2,
                          default=str) + "\n"

    def save(self, path: str | None = None) -> str:
        path = path or self.path or DEFAULT_CACHE_PATH
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            f.write(self.dumps())
        self.path = path
        return path

    def __len__(self) -> int:
        return len(self.entries)
