"""Process-level table of active tuned kernel configs.

The registry is the *eager* resolution path: kernel ops wrappers whose
block params default to ``None`` consult it (outside their jitted impls)
and fall back to the hand-picked defaults on a miss. The *jit-safe* path
is ``TunedKernels`` on ``GNNConfig.tuned`` — prefer it for anything that
runs inside an outer ``jax.jit`` (serving forwards), because a registry
mutation cannot invalidate an already-cached trace that resolved against
the old table.

Keys are geometry keys (``space.*Geometry.key()``, kernel name included);
platform scoping happens at activation time — ``activate(cache)`` only
loads cache entries recorded for the current platform.
"""
from __future__ import annotations

_ACTIVE: dict = {}


def register(key: tuple, config) -> None:
    _ACTIVE[tuple(key)] = config


def lookup(key: tuple):
    return _ACTIVE.get(tuple(key))


def clear() -> None:
    _ACTIVE.clear()


def active() -> dict:
    return dict(_ACTIVE)


def activate(cache, platform: str | None = None) -> int:
    """Bulk-register a ``TuneCache``'s entries for one platform (default:
    the current one). Returns the number of configs activated."""
    from .autotune import current_platform
    platform = platform or current_platform()
    n = 0
    for key, config in cache.configs_for(platform):
        register(key, config)
        n += 1
    return n
