"""The tuning driver: enumerate -> roofline-prune -> measure -> cache.

``tune`` decides one (geometry, platform); ``tune_plan`` walks an
``ExecutionPlan``'s per-layer kernel geometries and returns the
``TunedKernels`` bundle the plan threads into its forwards. Winners are
cached (``TuneCache``) keyed by (geometry, platform) the way the mapper
caches mappings on the plan — a cache hit skips measurement entirely.

Determinism contract (tests/test_tuning.py): with a deterministic
``measure_fn``, the winner, the cache record, and the serialized cache
bytes are pure functions of (geometry, backend platform, seed). With the
real timer the *candidate set* is still deterministic (pure roofline
arithmetic); only the measured ranking is machine-dependent — which is
why benches quarantine the winner under ``timing`` keys.
"""
from __future__ import annotations

from repro.analysis.roofline import HW, V5E

from . import registry
from .cache import TuneCache
from .measure import measure as _real_measure
from .prune import prune
from .space import (AggregateGeometry, FusedGeometry, TunedKernels,
                    default_config)


def current_platform() -> str:
    """Cache/registry platform tag: jax backend, '-interp' when Pallas
    kernels would run interpreted there (repro.kernels._interpret)."""
    import jax
    from repro.kernels._interpret import resolve_interpret
    base = jax.default_backend()
    return f"{base}-interp" if resolve_interpret(None) else base


def tune(geom, *, cache: TuneCache | None = None, hw: HW = V5E,
         seed: int = 0, iters: int = 3, warmup: int = 1,
         slack: float = 2.0, max_survivors: int = 4,
         measure_fn=None, force: bool = False,
         register_result: bool = True):
    """Decide the config for one kernel geometry on the current platform.

    Returns ``(config, info)``; ``info`` records whether the cache
    answered (``cached``), the deterministic survivor list with roofline
    bounds, and — when measurement ran — per-survivor seconds including
    the hand-picked default's (``default_s`` / ``winner_s``).
    """
    platform = current_platform()
    info = {"platform": platform, "cached": False}
    if cache is not None and not force:
        hit = cache.get(geom, platform)
        if hit is not None:
            if register_result:
                registry.register(geom.key(), hit)
            info["cached"] = True
            return hit, info

    survivors = prune(geom, hw=hw, slack=slack, max_survivors=max_survivors)
    info["survivors"] = [(c.as_dict(), b) for c, b in survivors]
    measure_fn = measure_fn or (
        lambda g, c: _real_measure(g, c, seed=seed, iters=iters,
                                   warmup=warmup))
    timed = [(measure_fn(geom, c), c, b) for c, b in survivors]
    # winner: fastest, ties broken by config order so reruns agree
    t_win, winner, bound = min(timed, key=lambda r: (r[0], r[1]))
    default = default_config(geom)
    t_default = next(t for t, c, _ in timed if c == default)
    info.update(winner_s=t_win, default_s=t_default,
                measured=[(c.as_dict(), t) for t, c, _ in timed],
                n_candidates=len(survivors))
    if cache is not None:
        cache.put(geom, platform, winner, bound_s=bound,
                  measured_s=round(t_win, 6), default_s=round(t_default, 6),
                  n_measured=len(timed), seed=seed)
        if cache.path is not None:
            cache.save()
    if register_result:
        registry.register(geom.key(), winner)
    return winner, info


def plan_geometries(plan, cfg) -> list:
    """Per-layer kernel geometries an ExecutionPlan's forward launches.

    ``fused`` launches the fused GNN-layer kernel, composed ``pallas``
    launches the standalone aggregation kernel (its crossbar stage is the
    jnp oracle); ``jnp`` is pure XLA, so it tunes nothing — an empty
    bundle, not an error. Bucketed plans launch one kernel shape per
    capacity bucket, so every distinct (rows, table, width) triple gets
    its own geometry.
    """
    if cfg.backend not in ("fused", "pallas"):
        return []
    if getattr(plan, "bucketed", None) is not None:
        bp = plan.bucketed
        shapes = sorted({(bp.n_caps[b], bp.n_caps[b] + bp.h_caps[b],
                          bp.s_caps[b]) for b in range(bp.n_buckets)})
    else:
        nd = int(plan.neighbors.shape[-2])
        # gather table rows: owned + halo rows on distributed settings
        n = nd + (int(plan.part.h_max) if plan.part is not None else 0)
        shapes = [(nd, n, int(plan.sample))]
    dims = cfg.dims
    geoms = []
    for nd, n, s in shapes:
        for f_in, f_out in zip(dims[:-1], dims[1:]):
            if cfg.backend == "fused":
                geoms.append(FusedGeometry(
                    nd=int(nd), n=int(n), f_in=int(f_in), f_out=int(f_out),
                    sample=int(s), ideal=bool(cfg.numerics.ideal),
                    rows_per_xbar=int(cfg.numerics.rows_per_xbar)))
            else:
                geoms.append(AggregateGeometry(
                    nd=int(nd), n=int(n), f=int(f_in), sample=int(s)))
    return geoms


def tune_plan(plan, cfg, *, cache: TuneCache | None = None,
              **tune_kw) -> TunedKernels:
    """Tune every kernel geometry of one plan; returns the TunedKernels
    bundle (also registered process-wide and cached when ``cache``)."""
    mapping = {}
    for geom in plan_geometries(plan, cfg):
        key = geom.key()
        if key in mapping:
            continue
        config, _ = tune(geom, cache=cache, **tune_kw)
        mapping[key] = config
    return TunedKernels.of(mapping)
