"""Tuning search space: kernel geometries, tunable configs, and the
hashable ``TunedKernels`` bundle that threads winners through jit.

A *geometry* is the static shape signature of one kernel launch — the
things that decide which block-size/pipeline-depth choices are legal and
how much data each candidate moves. A *config* is one point in the
tunable space:

  * ``crossbar_mvm`` — ``(bm, bn, depth)``: the MXU output block and the
    pipeline depth (how many physical ``rows_per_xbar`` crossbars one grid
    step owns; the ADC stays per-crossbar inside the step, so numerics are
    bit-identical at any depth — see kernels/crossbar_mvm).
  * ``fused_layer`` — ``(bf,)``: the lane-alignment block the ops layer
    pads F/H to (zero padding; bit-identical at any bf).
  * ``csr_aggregate`` — ``(bf,)``: the feature block of the standalone
    aggregation kernel the composed ``pallas`` backend launches (zero
    padding of F; the S-axis accumulation order never changes, so every
    candidate is bit-identical).
  * ``cam_match`` — ``(bq, be)``: the query/entry block of the traversal
    CAM search (sentinel padding of Q/E; every step is an independent
    equality compare and the per-query popcount is an integer sum, so
    every candidate is bit-identical).

Candidate enumeration is deterministic and divisibility-aware; the
roofline pruning and measurement live in ``prune.py`` / ``autotune.py``.

``TunedKernels`` is a frozen, hashable bundle of (geometry key -> config)
pairs. It rides on ``GNNConfig.tuned`` — a *static* jit argument — so a
changed tuning decision retraces every downstream jitted forward instead
of silently reusing a stale trace (the failure mode a mutable global
lookup inside a jitted function would have).
"""
from __future__ import annotations

import dataclasses
import math

# hand-picked defaults the kernels shipped with — always candidate #0, so
# the measured winner can never be worse than the default under the same
# measurement protocol (the fused_vs_composed gate relies on this)
DEFAULT_BF = 128
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_DEPTH = 1

DEFAULT_BQ = 8
DEFAULT_BE = 128

BF_CANDIDATES = (128, 256, 512)
BM_CANDIDATES = (8, 16, 32, 64, 128, 256)
BN_CANDIDATES = (128, 256, 512)
DEPTH_CANDIDATES = (1, 2, 4)
BQ_CANDIDATES = (8, 16, 32)
BE_CANDIDATES = (128, 256, 512)


@dataclasses.dataclass(frozen=True, order=True)
class CrossbarConfig:
    """One tunable point for the ``crossbar_mvm`` kernel."""
    bm: int = DEFAULT_BM
    bn: int = DEFAULT_BN
    depth: int = DEFAULT_DEPTH    # physical K-crossbars per grid step

    def as_dict(self) -> dict:
        return {"bm": self.bm, "bn": self.bn, "depth": self.depth}


@dataclasses.dataclass(frozen=True, order=True)
class FusedConfig:
    """One tunable point for the ``fused_layer`` kernel family."""
    bf: int = DEFAULT_BF          # lane-alignment block for F/H padding

    def as_dict(self) -> dict:
        return {"bf": self.bf}


@dataclasses.dataclass(frozen=True, order=True)
class AggregateConfig:
    """One tunable point for the standalone ``csr_aggregate`` kernel."""
    bf: int = DEFAULT_BF          # feature block per grid step

    def as_dict(self) -> dict:
        return {"bf": self.bf}


@dataclasses.dataclass(frozen=True, order=True)
class CamConfig:
    """One tunable point for the traversal ``cam_match`` search kernel."""
    bq: int = DEFAULT_BQ          # query block (sublane axis)
    be: int = DEFAULT_BE          # entry block (lane axis)

    def as_dict(self) -> dict:
        return {"bq": self.bq, "be": self.be}


CONFIG_TYPES = {"crossbar_mvm": CrossbarConfig, "fused_layer": FusedConfig,
                "csr_aggregate": AggregateConfig, "cam_match": CamConfig}


@dataclasses.dataclass(frozen=True)
class CrossbarGeometry:
    """Static signature of one ``crossbar_matmul_quantized`` launch."""
    m: int
    k: int
    n: int
    rows_per_xbar: int = 512
    in_bits: int = 8

    kernel = "crossbar_mvm"

    @property
    def n_k(self) -> int:
        """Physical crossbars along the (padded) contraction dim."""
        return math.ceil(self.k / self.rows_per_xbar)

    def key(self) -> tuple:
        return (self.kernel, self.m, self.k, self.n,
                self.rows_per_xbar, self.in_bits)

    def as_dict(self) -> dict:
        return {"kernel": self.kernel, "m": self.m, "k": self.k,
                "n": self.n, "rows_per_xbar": self.rows_per_xbar,
                "in_bits": self.in_bits}


@dataclasses.dataclass(frozen=True)
class FusedGeometry:
    """Static signature of one ``fused_gnn_layer`` launch.

    ``n`` is the feature-table row count the gather reads (owned + halo
    rows on distributed settings); ``nd`` the destination rows the grid
    iterates."""
    nd: int
    n: int
    f_in: int
    f_out: int
    sample: int
    ideal: bool = True
    rows_per_xbar: int = 512

    kernel = "fused_layer"

    def key(self) -> tuple:
        return (self.kernel, self.nd, self.n, self.f_in, self.f_out,
                self.sample, self.ideal, self.rows_per_xbar)

    def as_dict(self) -> dict:
        return {"kernel": self.kernel, "nd": self.nd, "n": self.n,
                "f_in": self.f_in, "f_out": self.f_out,
                "sample": self.sample, "ideal": self.ideal,
                "rows_per_xbar": self.rows_per_xbar}


@dataclasses.dataclass(frozen=True)
class AggregateGeometry:
    """Static signature of one standalone ``aggregate`` launch.

    ``n`` is the feature-table row count (owned + halo), ``nd`` the
    destination rows, ``f`` the feature width the grid tiles by ``bf``."""
    nd: int
    n: int
    f: int
    sample: int

    kernel = "csr_aggregate"

    def key(self) -> tuple:
        return (self.kernel, self.nd, self.n, self.f, self.sample)

    def as_dict(self) -> dict:
        return {"kernel": self.kernel, "nd": self.nd, "n": self.n,
                "f": self.f, "sample": self.sample}


@dataclasses.dataclass(frozen=True)
class CamGeometry:
    """Static signature of one traversal CAM ``search`` launch.

    ``e`` is the CSR column-index (entry) length, ``q`` the query count —
    the ops layer pads both with non-matching sentinels, so any (bq, be)
    is legal."""
    e: int
    q: int

    kernel = "cam_match"

    def key(self) -> tuple:
        return (self.kernel, self.e, self.q)

    def as_dict(self) -> dict:
        return {"kernel": self.kernel, "e": self.e, "q": self.q}


GEOMETRY_TYPES = {"crossbar_mvm": CrossbarGeometry,
                  "fused_layer": FusedGeometry,
                  "csr_aggregate": AggregateGeometry,
                  "cam_match": CamGeometry}


def default_config(geom):
    return CONFIG_TYPES[geom.kernel]()


def candidates(geom) -> list:
    """Deterministic, divisibility-legal candidate list (default first).

    crossbar_mvm: any (bm, bn) is legal (the ops layer pads M/N to the
    block multiples), but ``depth`` must divide the physical crossbar
    count ``n_k`` — the wrapper only pads K to ``rows_per_xbar``.
    fused_layer / csr_aggregate: any bf is legal (zero padding of F/H).
    cam_match: any (bq, be) is legal (sentinel padding of Q/E).
    """
    if geom.kernel == "fused_layer":
        cands = [FusedConfig(bf) for bf in BF_CANDIDATES]
    elif geom.kernel == "csr_aggregate":
        cands = [AggregateConfig(bf) for bf in BF_CANDIDATES]
    elif geom.kernel == "cam_match":
        cands = [CamConfig(bq, be) for bq in BQ_CANDIDATES
                 for be in BE_CANDIDATES]
    else:
        cands = [CrossbarConfig(bm, bn, d)
                 for bm in BM_CANDIDATES
                 for bn in BN_CANDIDATES
                 for d in DEPTH_CANDIDATES
                 if geom.n_k % d == 0]
    default = default_config(geom)
    return [default] + sorted(c for c in cands if c != default)


@dataclasses.dataclass(frozen=True)
class TunedKernels:
    """Immutable (geometry key -> config) bundle, hashable so it can ride
    on a static jit argument (``GNNConfig.tuned``)."""
    entries: tuple = ()           # sorted ((key, config), ...) pairs

    @classmethod
    def of(cls, mapping: dict) -> "TunedKernels":
        return cls(tuple(sorted(mapping.items())))

    def lookup(self, key: tuple):
        for k, c in self.entries:
            if k == key:
                return c
        return None

    def merged(self, other: "TunedKernels") -> "TunedKernels":
        """Right-biased union (``other`` wins on key collisions)."""
        m = dict(self.entries)
        m.update(other.entries)
        return TunedKernels.of(m)

    def __len__(self) -> int:
        return len(self.entries)
