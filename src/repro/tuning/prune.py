"""Roofline-guided candidate pruning (DESIGN.md §11).

Each candidate config implies an analytic per-launch cost — FLOPs, HBM
bytes (block re-fetch traffic is a function of the block sizes), and the
VMEM working set one grid step needs. The costs feed
``analysis.roofline.roofline_terms`` and the dominant-term bound prunes
the space *before* anything is timed:

  1. **feasibility** — a candidate whose double-buffered working set
     exceeds ``HW.vmem_bytes`` can never be scheduled; drop it.
  2. **bound**       — a candidate whose roofline lower bound is more than
     ``slack``x the best candidate's bound cannot win by more than
     measurement noise; drop it.
  3. **cap**         — measure at most ``max_survivors`` configs (bound
     order), the default always among them.

The analytic model is the TPU dataflow of the two kernels, not the
interpreter's: on CPU CI the measurement step re-ranks survivors by what
actually dominates there (grid-step overhead), which is exactly why the
pruning is a *bound* filter and not the decision.
"""
from __future__ import annotations

import dataclasses
import math

from repro.analysis.roofline import HW, V5E, roofline_terms

from .space import (AggregateGeometry, CamGeometry, CrossbarGeometry,
                    FusedGeometry, candidates)


@dataclasses.dataclass(frozen=True)
class LaunchCost:
    """Analytic per-launch cost of one (geometry, config) point — duck-
    typed to ``analysis.hlo.ModuleCost`` for ``roofline_terms``."""
    flops: float
    hbm_bytes: float
    vmem_bytes: float
    grid_steps: int
    collective_bytes: float = 0.0


def _ceil_to(x: int, b: int) -> int:
    return -(-x // b) * b


def crossbar_cost(geom: CrossbarGeometry, c) -> LaunchCost:
    """Cost of one ``crossbar_matmul_quantized`` launch at (bm, bn, depth).

    Grid is (M/bm, N/bn, K/bk) with bk = depth * rows_per_xbar and K
    innermost, so the out block stays VMEM-resident across the K sweep
    (charged once) while xq/wq blocks re-fetch per step.
    """
    m = _ceil_to(geom.m, c.bm)
    n = _ceil_to(geom.n, c.bn)
    k = _ceil_to(geom.k, geom.rows_per_xbar)
    bk = c.depth * geom.rows_per_xbar
    steps = (m // c.bm) * (n // c.bn) * max(k // bk, 1)
    # bit-serial MXU work: one bm x bk x bn matmul per DAC bit-plane
    flops = 2.0 * m * k * n * geom.in_bits
    hbm = 4.0 * (steps * (c.bm * bk + bk * c.bn) + m * n)
    vmem = 4.0 * (c.bm * bk + bk * c.bn + c.bm * c.bn) * 2   # double-buffered
    return LaunchCost(flops, hbm, vmem, steps)


def fused_cost(geom: FusedGeometry, c) -> LaunchCost:
    """Cost of one ``fused_gnn_layer`` launch at lane block bf.

    The grid is (nd, sample): every step gathers one padded feature row;
    W/bias blocks are grid-invariant (fetched once); the final step of
    each node row runs the K_pad x N_pad matmul on the VMEM-resident z.
    """
    k_pad = _ceil_to(geom.f_in, c.bf if geom.ideal else geom.rows_per_xbar)
    n_pad = _ceil_to(geom.f_out, c.bf)
    steps = geom.nd * max(geom.sample, 1)
    flops = 2.0 * geom.nd * k_pad * n_pad + 2.0 * steps * k_pad
    if not geom.ideal:
        # bit-accurate path: 2 DAC sign passes x the stack-wide 8 bit-serial
        # planes replay the matmul (plus the zmax scale pass's extra gather)
        flops *= 16
        hbm_extra = 4.0 * steps * k_pad          # second gather (zmax pass)
    else:
        hbm_extra = 0.0
    hbm = 4.0 * (steps * k_pad + k_pad * n_pad + geom.nd * n_pad) + hbm_extra
    vmem = 4.0 * (k_pad * n_pad        # W resident
                  + 2 * k_pad          # z scratch + gathered x row
                  + n_pad) * 2
    return LaunchCost(flops, hbm, vmem, steps)


def aggregate_cost(geom: AggregateGeometry, c) -> LaunchCost:
    """Cost of one standalone ``csr_aggregate`` launch at feature block bf.

    The grid is (nd, F/bf, sample): each step gathers one bf-wide slice of
    a neighbor row and accumulates it into the VMEM-resident out block
    (written back once per (nd, F/bf) pair).
    """
    f_pad = _ceil_to(geom.f, c.bf)
    steps = geom.nd * (f_pad // c.bf) * max(geom.sample, 1)
    flops = 2.0 * steps * c.bf                   # multiply-accumulate
    hbm = 4.0 * (steps * c.bf + geom.nd * f_pad)
    vmem = 4.0 * (2 * c.bf) * 2                  # gathered slice + out block
    return LaunchCost(flops, hbm, vmem, steps)


def cam_cost(geom: CamGeometry, c) -> LaunchCost:
    """Cost of one traversal CAM ``search`` launch at (bq, be).

    The grid is (Q/bq, E/be): each step holds one int32 entry block and
    one query block in VMEM, broadcasts the equality compare across the
    bq x be tile (one VPU op per cell, plus the popcount reduce), and
    writes the int8 match tile; the per-query counts accumulate in the
    VMEM-resident (bq, 1) block across the E sweep (written once).
    """
    q = _ceil_to(geom.q, c.bq)
    e = _ceil_to(geom.e, c.be)
    steps = (q // c.bq) * (e // c.be)
    flops = 2.0 * q * e                          # compare + popcount add
    # entry blocks re-fetch once per query block row; query blocks once
    # per entry block column; match written once, counts once per query
    hbm = 4.0 * (steps * c.be + steps * c.bq + q) + 1.0 * q * e
    vmem = (4.0 * (c.be + 2 * c.bq) + 1.0 * c.bq * c.be) * 2
    return LaunchCost(flops, hbm, vmem, steps)


def launch_cost(geom, config) -> LaunchCost:
    if geom.kernel == "fused_layer":
        return fused_cost(geom, config)
    if geom.kernel == "csr_aggregate":
        return aggregate_cost(geom, config)
    if geom.kernel == "cam_match":
        return cam_cost(geom, config)
    return crossbar_cost(geom, config)


def roofline_bound(geom, config, hw: HW = V5E) -> float:
    """Dominant-term lower bound [s] for one launch (the pruning score)."""
    return roofline_terms(launch_cost(geom, config), hw).bound_s


def prune(geom, cands: list | None = None, hw: HW = V5E,
          slack: float = 2.0, max_survivors: int = 4) -> list:
    """[(config, bound_s)] survivors worth timing, best bound first.

    Fully deterministic (pure arithmetic on the geometry), so the
    survivor set — unlike the measured winner — is part of a bench's
    deterministic METRICS. The default config always survives, even when
    its bound loses: it is the reference the winner is gated against.
    """
    cands = candidates(geom) if cands is None else list(cands)
    default = cands[0]
    scored = [(c, roofline_bound(geom, c, hw)) for c in cands
              if launch_cost(geom, c).vmem_bytes <= hw.vmem_bytes]
    if not scored:                      # default over VMEM: measure it alone
        return [(default, roofline_bound(geom, default, hw))]
    best = min(b for _, b in scored)
    scored.sort(key=lambda cb: (cb[1], cb[0]))
    survivors = [(c, b) for c, b in scored if b <= slack * best]
    survivors = survivors[:max_survivors]
    if all(c != default for c, _ in survivors):
        survivors.append((default, roofline_bound(geom, default, hw)))
    return survivors
