"""Roofline-guided Pallas kernel autotuner (DESIGN.md §11).

Block-size / pipeline-depth tuning for the ``fused_layer``,
``crossbar_mvm``, ``csr_aggregate`` and ``cam_match`` kernels: enumerate
legal candidates per launch geometry
(``space``), prune them with the ``analysis/roofline.py`` bounds before
anything is timed (``prune``), measure the survivors (``measure``), and
cache the winner keyed by (geometry, platform) (``cache``) the way the
mapper caches mappings. ``ExecutionPlan.tune_kernels`` threads the
winners into serving via the hashable ``TunedKernels`` bundle on
``GNNConfig.tuned`` (jit-safe); the process-level ``registry`` is the
eager fallback the kernel ops wrappers consult when their block params
are left at ``None``.

Tuned configs never change numerics: depth keeps the ADC per physical
crossbar and the accumulation order unchanged; bf/bm/bn only move zero
padding — tuned vs default outputs are bit-identical (regression-tested
across all three backends).
"""
from . import registry  # noqa: F401
from .autotune import current_platform, plan_geometries, tune, tune_plan
from .cache import DEFAULT_CACHE_PATH, TuneCache
from .prune import LaunchCost, launch_cost, prune, roofline_bound
from .space import (AggregateConfig, AggregateGeometry, CamConfig,
                    CamGeometry, CrossbarConfig, CrossbarGeometry,
                    FusedConfig, FusedGeometry, GEOMETRY_TYPES,
                    TunedKernels, candidates, default_config)

__all__ = [
    "registry", "current_platform", "plan_geometries", "tune", "tune_plan",
    "DEFAULT_CACHE_PATH", "TuneCache", "LaunchCost", "launch_cost", "prune",
    "roofline_bound", "AggregateConfig", "AggregateGeometry", "CamConfig",
    "CamGeometry", "CrossbarConfig", "CrossbarGeometry", "FusedConfig",
    "FusedGeometry", "GEOMETRY_TYPES", "TunedKernels", "candidates",
    "default_config",
]
