"""Measurement harness for tuning survivors.

Synthetic inputs are a pure function of (geometry, seed) so the work a
candidate is timed on is identical across candidates and across runs; the
timer compiles first (block_until_ready) and reports the *minimum* of
``iters`` timed calls — the standard noise-robust estimator for a
deterministic computation (mean/median absorb scheduler noise, min
doesn't).

Kernel imports happen inside the runner builders: the kernels' ops layers
import ``tuning.registry`` for their default-config resolution, so a
module-level import here would be circular.
"""
from __future__ import annotations

import time

import numpy as np

from .space import AggregateConfig, AggregateGeometry, CamConfig, \
    CamGeometry, CrossbarConfig, CrossbarGeometry, FusedConfig, \
    FusedGeometry


def time_callable(fn, iters: int = 3, warmup: int = 1) -> float:
    """Min wall-clock seconds of ``fn()`` over ``iters`` timed calls."""
    import jax
    for _ in range(max(warmup, 1)):
        jax.block_until_ready(fn())          # compile + cache warm
    best = float("inf")
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def crossbar_runner(geom: CrossbarGeometry, config: CrossbarConfig,
                    seed: int = 0, interpret: bool | None = None):
    """() -> y for one quantized crossbar MVM launch at ``config``."""
    import jax.numpy as jnp
    from repro.kernels.crossbar_mvm import CrossbarNumerics
    from repro.kernels.crossbar_mvm.crossbar_mvm import \
        crossbar_matmul_quantized
    from repro.mapper.tiling import padded_grid

    cfg = CrossbarNumerics(in_bits=geom.in_bits,
                           rows_per_xbar=geom.rows_per_xbar)
    rng = np.random.default_rng(seed)
    grid = padded_grid(geom.m, geom.k, geom.n, geom.rows_per_xbar,
                       bm=config.bm, bn=config.bn)
    xq = jnp.asarray(rng.integers(
        0, 2 ** geom.in_bits, size=(grid.m_pad, grid.k_pad)).astype(
            np.uint32))
    wq = jnp.asarray(rng.integers(
        -7, 8, size=(grid.k_pad, grid.n_pad)).astype(np.float32))

    def run():
        return crossbar_matmul_quantized(xq, wq, cfg, bm=config.bm,
                                         bn=config.bn, depth=config.depth,
                                         interpret=interpret)
    return run


def fused_runner(geom: FusedGeometry, config: FusedConfig, seed: int = 0,
                 interpret: bool | None = None):
    """() -> h for one fused GNN-layer launch at ``config``."""
    import jax.numpy as jnp
    from repro.kernels.crossbar_mvm import CrossbarNumerics
    from repro.kernels.fused_layer import fused_gnn_layer

    cfg = (CrossbarNumerics(ideal=True) if geom.ideal
           else CrossbarNumerics(rows_per_xbar=geom.rows_per_xbar))
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(geom.n, geom.f_in)).astype(np.float32))
    nbr = jnp.asarray(rng.integers(
        0, geom.n, size=(geom.nd, geom.sample)).astype(np.int32))
    wts = jnp.asarray(np.abs(rng.normal(
        size=(geom.nd, geom.sample))).astype(np.float32))
    w = jnp.asarray(rng.normal(
        size=(geom.f_in, geom.f_out)).astype(np.float32) * 0.05)
    b = jnp.zeros((geom.f_out,), jnp.float32)

    def run():
        return fused_gnn_layer(x, nbr, wts, w, b, cfg, relu=True,
                               bf=config.bf, interpret=interpret)
    return run


def aggregate_runner(geom: AggregateGeometry, config: AggregateConfig,
                     seed: int = 0, interpret: bool | None = None):
    """() -> z for one standalone aggregation launch at ``config``."""
    import jax.numpy as jnp
    from repro.kernels.csr_aggregate import aggregate

    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(geom.n, geom.f)).astype(np.float32))
    nbr = jnp.asarray(rng.integers(
        0, geom.n, size=(geom.nd, geom.sample)).astype(np.int32))
    wts = jnp.asarray(np.abs(rng.normal(
        size=(geom.nd, geom.sample))).astype(np.float32))

    def run():
        return aggregate(x, nbr, wts, backend="pallas", bf=config.bf,
                         interpret=interpret)
    return run


def cam_runner(geom: CamGeometry, config: CamConfig, seed: int = 0,
               interpret: bool | None = None):
    """() -> (match, counts) for one CAM search launch at ``config``."""
    import jax.numpy as jnp
    from repro.kernels.cam_match import search

    rng = np.random.default_rng(seed)
    ci = jnp.asarray(rng.integers(0, max(geom.e, 1),
                                  size=geom.e).astype(np.int32))
    qs = jnp.asarray(rng.integers(0, max(geom.e, 1),
                                  size=geom.q).astype(np.int32))

    def run():
        return search(ci, qs, backend="pallas", bq=config.bq, be=config.be,
                      interpret=interpret)
    return run


def make_runner(geom, config, seed: int = 0, interpret: bool | None = None):
    if geom.kernel == "fused_layer":
        return fused_runner(geom, config, seed, interpret)
    if geom.kernel == "csr_aggregate":
        return aggregate_runner(geom, config, seed, interpret)
    if geom.kernel == "cam_match":
        return cam_runner(geom, config, seed, interpret)
    return crossbar_runner(geom, config, seed, interpret)


def measure(geom, config, seed: int = 0, iters: int = 3, warmup: int = 1,
            interpret: bool | None = None) -> float:
    """Default measurement hook: build the runner, time it, seconds."""
    return time_callable(make_runner(geom, config, seed, interpret),
                        iters=iters, warmup=warmup)
