"""Decentralized GNN runtime: shard_map over clusters + halo exchange.

One device per cluster (the paper's "edge device"). Each layer needs remote
neighbor features (the paper's bidirectional e_ij communication volume); two
exchange strategies are provided:

  * ``allgather`` — every device gathers all owned feature tables and selects
    its halo rows. Simple, bandwidth = K * n_max * F per device. This is the
    paper-faithful "broadcast within the cluster" behavior.
  * ``alltoall``  — each device sends only the rows its peers actually need
    (precomputed send lists). Traffic matches the true boundary volume e_ij —
    the beyond-paper optimization (see EXPERIMENTS.md §Perf-GNN).

All tables are padded to static shapes so a single compiled program serves
every cluster (SPMD).

The per-device layer honors ``cfg.backend``: the composed ``jnp``/``pallas``
paths run aggregation then the feature transform, ``fused`` runs both stages
in one ``fused_gnn_layer`` kernel launch with Z resident in VMEM (so the
decentralized and semi-decentralized settings get the same HBM-traffic win
as the centralized path — DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.partition import Partition
from repro.kernels.crossbar_mvm import crossbar_matmul_signed_ref
from repro.kernels.csr_aggregate import aggregate, csr_aggregate_ref
from repro.kernels.fused_layer import fused_gnn_layer


@dataclasses.dataclass
class HaloPlan:
    """Static exchange plan derived from a Partition (numpy, host-side)."""
    src_cluster: np.ndarray    # [K, h_max] owner cluster of each halo row
    src_slot: np.ndarray       # [K, h_max] owner-local slot
    halo_mask: np.ndarray      # [K, h_max] bool
    send_slot: np.ndarray      # [K, K, s_max] rows device k sends to peer j
    send_mask: np.ndarray      # [K, K, s_max] bool
    recv_to_halo: np.ndarray   # [K, K, s_max] halo row filled by recv (or 0)
    recv_mask: np.ndarray      # [K, K, s_max] bool

    @property
    def s_max(self) -> int:
        return self.send_slot.shape[2]


def build_halo_plan(part: Partition) -> HaloPlan:
    from repro.core.partition import halo_exchange_tables
    src_c, src_s, mask = halo_exchange_tables(part)
    k, h_max = src_c.shape
    # send lists: sends[c][j] = local slots of c needed by j
    sends = [[[] for _ in range(k)] for _ in range(k)]
    recv_halo = [[[] for _ in range(k)] for _ in range(k)]
    for c in range(k):
        for h in range(h_max):
            if mask[c, h]:
                owner = int(src_c[c, h])
                sends[owner][c].append(int(src_s[c, h]))
                recv_halo[c][owner].append(h)
    s_max = max(max((len(s) for row in sends for s in row), default=0), 1)
    send_slot = np.zeros((k, k, s_max), np.int32)
    send_mask = np.zeros((k, k, s_max), bool)
    recv_to_halo = np.zeros((k, k, s_max), np.int32)
    recv_mask = np.zeros((k, k, s_max), bool)
    for c in range(k):
        for j in range(k):
            s = sends[c][j]
            send_slot[c, j, :len(s)] = s
            send_mask[c, j, :len(s)] = True
            r = recv_halo[c][j]
            recv_to_halo[c, j, :len(r)] = r
            recv_mask[c, j, :len(r)] = True
    return HaloPlan(src_c, src_s, mask, send_slot, send_mask,
                    recv_to_halo, recv_mask)


def _exchange_allgather(x_own, src_c, src_s, mask, axis):
    full = jax.lax.all_gather(x_own, axis)            # [K, n_max, F]
    halo = full[src_c, src_s]                         # [h_max, F]
    return halo * mask[:, None]


def _exchange_alltoall(x_own, send_slot, send_mask, recv_to_halo, recv_mask,
                       h_max, axis):
    # send[j] = rows this device owes peer j: [K, s_max, F]
    send = x_own[send_slot] * send_mask[..., None]
    recv = jax.lax.all_to_all(send, axis, split_axis=0, concat_axis=0,
                              tiled=False)            # [K, s_max, F]
    halo = jnp.zeros((h_max, x_own.shape[-1]), x_own.dtype)
    flat_idx = recv_to_halo.reshape(-1)
    flat = (recv * recv_mask[..., None]).reshape(-1, x_own.shape[-1])
    # masked scatter: padding rows all target slot 0 with zero contribution
    return halo.at[flat_idx].add(flat * recv_mask.reshape(-1)[:, None])


def _layer_step(table, nbr, wts, layer, cfg, act: bool):
    """One GNN layer on a device-local feature table, backend-dispatched.
    Honors cfg.numerics on every backend (same contract as core.gnn)."""
    if cfg.backend == "fused":
        return fused_gnn_layer(table, nbr, wts, layer["w"], layer["b"],
                               cfg.numerics, relu=act)
    z = (csr_aggregate_ref(table, nbr, wts) if cfg.backend == "jnp"
         else aggregate(table, nbr, wts, backend=cfg.backend))
    if cfg.numerics.ideal:
        x = jnp.dot(z, layer["w"], preferred_element_type=jnp.float32)
    else:
        x = crossbar_matmul_signed_ref(z, layer["w"], cfg.numerics)
    x = x + layer["b"]
    return jax.nn.relu(x) if act else x


def make_decentralized_forward(mesh, cfg, plan: HaloPlan, n_max: int,
                               mode: str = "alltoall", axis: str = "data"):
    """Build the SPMD decentralized GNN forward for a given mesh/plan.

    Inputs (sharded on the leading cluster axis over ``axis``):
      feats   [K, n_max, F_in]   owned node features
      nbr/wts [K, n_max, S]      device-local padded subgraph
    Returns [K, n_max, out_dim] embeddings for owned nodes.
    """
    h_max = plan.src_cluster.shape[1]
    consts = jax.tree.map(
        jnp.asarray,
        dict(src_c=plan.src_cluster, src_s=plan.src_slot,
             hmask=plan.halo_mask.astype(jnp.float32),
             send_slot=plan.send_slot, send_mask=plan.send_mask,
             recv_to_halo=plan.recv_to_halo, recv_mask=plan.recv_mask))

    def device_fn(params, feats, nbr, wts, src_c, src_s, hmask,
                  send_slot, send_mask, recv_to_halo, recv_mask):
        x = feats[0]                                   # [n_max, F]
        nbr, wts = nbr[0], wts[0]
        n_layers = len(params)
        for i, layer in enumerate(params):
            if mode == "allgather":
                halo = _exchange_allgather(x, src_c[0], src_s[0], hmask[0],
                                           axis)
            else:
                halo = _exchange_alltoall(x, send_slot[0], send_mask[0],
                                          recv_to_halo[0], recv_mask[0],
                                          h_max, axis)
            table = jnp.concatenate([x, halo], axis=0)  # [n_max+h_max, F]
            act = i < n_layers - 1 or cfg.final_activation
            x = _layer_step(table, nbr, wts, layer, cfg, act)
        return x[None]

    shard = P(axis)
    fn = shard_map(
        device_fn, mesh=mesh,
        in_specs=(P(), shard, shard, shard, shard, shard, shard,
                  shard, shard, shard, shard),
        out_specs=shard,
        check_rep=False)

    @jax.jit
    def forward(params, feats, nbr, wts):
        return fn(params, feats, nbr, wts, consts["src_c"], consts["src_s"],
                  consts["hmask"], consts["send_slot"], consts["send_mask"],
                  consts["recv_to_halo"], consts["recv_mask"])

    return forward


def make_emulated_forward(cfg, plan: HaloPlan):
    """Mesh-free decentralized forward: the same per-cluster dataflow and
    halo exchange as ``make_decentralized_forward``, but with the exchange
    realized as a host-side gather across the leading cluster axis instead
    of a collective. Used when the cluster count exceeds the device count
    (e.g. a 16-cluster semi-decentralized plan on a 1-CPU test host) and as
    the single-process oracle for the SPMD path.

    feats/nbr/wts: [K, n_max, {F,S}]. Returns [K, n_max, out_dim].
    """
    src_c = jnp.asarray(plan.src_cluster)
    src_s = jnp.asarray(plan.src_slot)
    hmask = jnp.asarray(plan.halo_mask.astype(np.float32))

    @jax.jit
    def forward(params, feats, nbr, wts):
        x = feats                                   # [K, n_max, F]
        k = x.shape[0]
        n_layers = len(params)
        for i, layer in enumerate(params):
            halo = x[src_c, src_s] * hmask[..., None]   # [K, h_max, F]
            table = jnp.concatenate([x, halo], axis=1)  # [K, n_max+h_max, F]
            act = i < n_layers - 1 or cfg.final_activation
            x = jnp.stack([
                _layer_step(table[c], nbr[c], wts[c], layer, cfg, act)
                for c in range(k)])
        return x

    return forward
