"""Decentralized + two-tier semi-decentralized GNN runtimes.

One device per cluster (the paper's "edge device"). Each layer needs remote
neighbor features (the paper's bidirectional e_ij communication volume); two
exchange strategies are provided:

  * ``allgather`` — every device gathers all owned feature tables and selects
    its halo rows. Simple, bandwidth = K * n_max * F per device. This is the
    paper-faithful "broadcast within the cluster" behavior.
  * ``alltoall``  — each device sends only the rows its peers actually need
    (precomputed send lists). Traffic matches the true boundary volume e_ij —
    the beyond-paper optimization (see EXPERIMENTS.md §Perf-GNN).

Both strategies exist on both runtimes: the SPMD shard_map path (collectives
over the cluster mesh axis) and the mesh-free *emulated* path (the identical
dataflow as host-side gathers/transposes over the leading cluster axis — the
single-process oracle, and the fallback when clusters outnumber devices).

The **semi-decentralized** setting (paper §5, DESIGN.md §7) is a two-tier
exchange over a ``HierPartition``:

  * tier 0 — intra-region spoke->head gather: each region head assembles its
    region feature table from its member spokes' tables (device-local in
    SPMD, where a head and its spokes share a device; a real deployment
    moves ``sum(spoke rows) * F`` bytes over the access link, which the
    traffic accountant reports).
  * tier 1 — head<->head boundary halo per layer, identical machinery to the
    decentralized exchange but over the region-level partition.

The per-device layer honors ``cfg.backend``: the composed ``jnp``/``pallas``
paths run aggregation then the feature transform, ``fused`` runs both stages
in one ``fused_gnn_layer`` kernel launch with Z resident in VMEM (so the
decentralized and semi-decentralized settings get the same HBM-traffic win
as the centralized path — DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro import telemetry as tel
from repro.core.partition import (BucketedPartition, HierPartition,
                                  Partition)
from repro.kernels.crossbar_mvm import crossbar_matmul_signed_ref
from repro.kernels.csr_aggregate import aggregate, csr_aggregate_ref
from repro.kernels.fused_layer import fused_gnn_layer

EXCHANGE_MODES = ("allgather", "alltoall")
OVERLAP_MODES = ("overlap", "serial")


@dataclasses.dataclass
class HaloPlan:
    """Static exchange plan derived from a Partition (numpy, host-side)."""
    src_cluster: np.ndarray    # [K, h_max] owner cluster of each halo row
    src_slot: np.ndarray       # [K, h_max] owner-local slot
    halo_mask: np.ndarray      # [K, h_max] bool
    send_slot: np.ndarray      # [K, K, s_max] rows device k sends to peer j
    send_mask: np.ndarray      # [K, K, s_max] bool
    recv_to_halo: np.ndarray   # [K, K, s_max] halo row filled by recv (or 0)
    recv_mask: np.ndarray      # [K, K, s_max] bool

    @property
    def s_max(self) -> int:
        return self.send_slot.shape[2]


def build_halo_plan(part: Partition) -> HaloPlan:
    from repro.core.partition import halo_exchange_tables
    src_c, src_s, mask = halo_exchange_tables(part)
    k, h_max = src_c.shape
    # send lists: sends[c][j] = local slots of c needed by j
    sends = [[[] for _ in range(k)] for _ in range(k)]
    recv_halo = [[[] for _ in range(k)] for _ in range(k)]
    for c in range(k):
        for h in range(h_max):
            if mask[c, h]:
                owner = int(src_c[c, h])
                sends[owner][c].append(int(src_s[c, h]))
                recv_halo[c][owner].append(h)
    s_max = max(max((len(s) for row in sends for s in row), default=0), 1)
    send_slot = np.zeros((k, k, s_max), np.int32)
    send_mask = np.zeros((k, k, s_max), bool)
    recv_to_halo = np.zeros((k, k, s_max), np.int32)
    recv_mask = np.zeros((k, k, s_max), bool)
    for c in range(k):
        for j in range(k):
            s = sends[c][j]
            send_slot[c, j, :len(s)] = s
            send_mask[c, j, :len(s)] = True
            r = recv_halo[c][j]
            recv_to_halo[c, j, :len(r)] = r
            recv_mask[c, j, :len(r)] = True
    return HaloPlan(src_c, src_s, mask, send_slot, send_mask,
                    recv_to_halo, recv_mask)


def _exchange_allgather(x_own, src_c, src_s, mask, axis):
    full = jax.lax.all_gather(x_own, axis)            # [K, n_max, F]
    halo = full[src_c, src_s]                         # [h_max, F]
    return halo * mask[:, None]


def _exchange_alltoall(x_own, send_slot, send_mask, recv_to_halo, recv_mask,
                       h_max, axis):
    # send[j] = rows this device owes peer j: [K, s_max, F]
    send = x_own[send_slot] * send_mask[..., None]
    recv = jax.lax.all_to_all(send, axis, split_axis=0, concat_axis=0,
                              tiled=False)            # [K, s_max, F]
    halo = jnp.zeros((h_max, x_own.shape[-1]), x_own.dtype)
    flat_idx = recv_to_halo.reshape(-1)
    flat = (recv * recv_mask[..., None]).reshape(-1, x_own.shape[-1])
    # masked scatter: padding rows all target slot 0 with zero contribution
    return halo.at[flat_idx].add(flat * recv_mask.reshape(-1)[:, None])


def _layer_step(table, nbr, wts, layer, cfg, act: bool):
    """One GNN layer on a device-local feature table, backend-dispatched.
    Honors cfg.numerics on every backend (same contract as core.gnn)."""
    if cfg.backend == "fused":
        return fused_gnn_layer(table, nbr, wts, layer["w"], layer["b"],
                               cfg.numerics, relu=act, tuned=cfg.tuned)
    z = (csr_aggregate_ref(table, nbr, wts) if cfg.backend == "jnp"
         else aggregate(table, nbr, wts, backend=cfg.backend,
                        tuned=cfg.tuned))
    if cfg.numerics.ideal:
        x = jnp.dot(z, layer["w"], preferred_element_type=jnp.float32)
    else:
        x = crossbar_matmul_signed_ref(z, layer["w"], cfg.numerics)
    x = x + layer["b"]
    return jax.nn.relu(x) if act else x


def _plan_consts(plan: HaloPlan) -> dict:
    return jax.tree.map(
        jnp.asarray,
        dict(src_c=plan.src_cluster, src_s=plan.src_slot,
             hmask=plan.halo_mask.astype(np.float32),
             send_slot=plan.send_slot,
             send_mask=plan.send_mask.astype(np.float32),
             recv_to_halo=plan.recv_to_halo,
             recv_mask=plan.recv_mask.astype(np.float32)))


def _spmd_layers(params, x, nbr, wts, cfg, t, mode, h_max, axis):
    """Per-device layer loop shared by the decentralized and semi SPMD
    forwards. ``t``: per-device exchange tables (leading axis stripped)."""
    n_layers = len(params)
    for i, layer in enumerate(params):
        if mode == "allgather":
            halo = _exchange_allgather(x, t["src_c"], t["src_s"],
                                       t["hmask"], axis)
        else:
            halo = _exchange_alltoall(x, t["send_slot"], t["send_mask"],
                                      t["recv_to_halo"], t["recv_mask"],
                                      h_max, axis)
        table = jnp.concatenate([x, halo], axis=0)      # [n_max+h_max, F]
        act = i < n_layers - 1 or cfg.final_activation
        x = _layer_step(table, nbr, wts, layer, cfg, act)
    return x


def make_decentralized_forward(mesh, cfg, plan: HaloPlan, n_max: int,
                               mode: str = "alltoall", axis: str = "data"):
    """Build the SPMD decentralized GNN forward for a given mesh/plan.

    Inputs (sharded on the leading cluster axis over ``axis``):
      feats   [K, n_max, F_in]   owned node features
      nbr/wts [K, n_max, S]      device-local padded subgraph
    Returns [K, n_max, out_dim] embeddings for owned nodes.
    """
    assert mode in EXCHANGE_MODES, mode
    h_max = plan.src_cluster.shape[1]
    consts = _plan_consts(plan)
    names = tuple(consts)

    def device_fn(params, feats, nbr, wts, *tables):
        t = {n: v[0] for n, v in zip(names, tables)}
        x = _spmd_layers(params, feats[0], nbr[0], wts[0], cfg, t, mode,
                         h_max, axis)
        return x[None]

    shard = P(axis)
    fn = shard_map(
        device_fn, mesh=mesh,
        in_specs=(P(),) + (shard,) * (3 + len(names)),
        out_specs=shard,
        check_rep=False)

    @jax.jit
    def forward(params, feats, nbr, wts):
        return fn(params, feats, nbr, wts, *(consts[n] for n in names))

    return forward


def _emulated_exchange(x, t, mode, h_max):
    """Host-side halo exchange across the leading cluster axis — the
    collective-free twin of ``_exchange_allgather``/``_exchange_alltoall``.

    ``allgather`` picks each halo row straight out of the stacked owned
    tables; ``alltoall`` routes through the same send/recv tables as the
    SPMD collective (send -> axis transpose -> masked scatter), so the
    emulated path exercises the exact tables the wire traffic is billed on.
    Both return identical halos ([K, h_max, F]).
    """
    if mode == "allgather":
        return x[t["src_c"], t["src_s"]] * t["hmask"][..., None]
    k = x.shape[0]
    dev = jnp.arange(k)[:, None, None]
    send = x[dev, t["send_slot"]] * t["send_mask"][..., None]  # [K,K,s_max,F]
    recv = jnp.swapaxes(send, 0, 1)           # recv[c, j] = send[j, c]
    halo = jnp.zeros((k, h_max, x.shape[-1]), x.dtype)
    return halo.at[dev, t["recv_to_halo"]].add(
        recv * t["recv_mask"][..., None])


def _emulated_layers(params, x, nbr, wts, cfg, t, mode, h_max):
    k = x.shape[0]
    n_layers = len(params)
    for i, layer in enumerate(params):
        halo = _emulated_exchange(x, t, mode, h_max)    # [K, h_max, F]
        table = jnp.concatenate([x, halo], axis=1)      # [K, n_max+h_max, F]
        act = i < n_layers - 1 or cfg.final_activation
        x = jnp.stack([
            _layer_step(table[c], nbr[c], wts[c], layer, cfg, act)
            for c in range(k)])
    return x


def make_emulated_forward(cfg, plan: HaloPlan, mode: str = "allgather"):
    """Mesh-free decentralized forward: the same per-cluster dataflow and
    halo exchange as ``make_decentralized_forward``, but with the exchange
    realized host-side across the leading cluster axis instead of as a
    collective (``_emulated_exchange`` — both ``allgather`` and
    ``alltoall`` route identically to the SPMD modes). Used when the
    cluster count exceeds the device count and as the single-process oracle
    for the SPMD path.

    feats/nbr/wts: [K, n_max, {F,S}]. Returns [K, n_max, out_dim].
    """
    assert mode in EXCHANGE_MODES, mode
    h_max = plan.src_cluster.shape[1]
    consts = _plan_consts(plan)

    @jax.jit
    def forward(params, feats, nbr, wts):
        return _emulated_layers(params, feats, nbr, wts, cfg, consts, mode,
                                h_max)

    return forward


@dataclasses.dataclass
class TwoTierPlan:
    """Static two-tier semi-decentralized exchange plan (DESIGN.md §7).

    ``region`` drives the tier-1 head<->head halo; the gather tables drive
    the tier-0 spoke->head assembly of each region's feature table.
    """
    region: HaloPlan
    gather_spoke: np.ndarray   # [R, n_max] spoke owning each region row
    gather_slot: np.ndarray    # [R, n_max] slot in that spoke's table
    gather_mask: np.ndarray    # [R, n_max] bool (valid region rows)
    n_max: int

    @property
    def h_max(self) -> int:
        return self.region.src_cluster.shape[1]


def build_two_tier_plan(hier: HierPartition) -> TwoTierPlan:
    return TwoTierPlan(build_halo_plan(hier.region), hier.gather_spoke,
                       hier.gather_slot, hier.region.local_mask,
                       hier.region.n_max)


def _tier0_consts(plan: TwoTierPlan) -> dict:
    return dict(gspoke=jnp.asarray(plan.gather_spoke),
                gslot=jnp.asarray(plan.gather_slot),
                gmask=jnp.asarray(plan.gather_mask.astype(np.float32)))


def make_semi_forward(mesh, cfg, plan: TwoTierPlan,
                      mode: str = "alltoall", axis: str = "data"):
    """SPMD two-tier semi-decentralized forward (one device per head).

    Inputs (sharded on the leading region axis over ``axis``):
      spoke_feats [R, P, m_max, F_in]  per-spoke feature tables
      nbr/wts     [R, n_max, S]        region-local padded subgraph
    Tier 0 assembles the head's region table from its co-located spokes
    (device-local gather — the access-link upload is billed by the traffic
    accountant, not moved over the mesh); tier 1 runs the per-layer
    head<->head halo exchange collective. Returns [R, n_max, out_dim].
    """
    assert mode in EXCHANGE_MODES, mode
    h_max = plan.h_max
    consts = dict(_tier0_consts(plan), **_plan_consts(plan.region))
    names = tuple(consts)

    def device_fn(params, spoke_feats, nbr, wts, *tables):
        t = {n: v[0] for n, v in zip(names, tables)}
        x = (spoke_feats[0][t["gspoke"], t["gslot"]]
             * t["gmask"][:, None])                     # tier 0: [n_max, F]
        x = _spmd_layers(params, x, nbr[0], wts[0], cfg, t, mode, h_max,
                         axis)
        return x[None]

    shard = P(axis)
    fn = shard_map(
        device_fn, mesh=mesh,
        in_specs=(P(),) + (shard,) * (3 + len(names)),
        out_specs=shard,
        check_rep=False)

    @jax.jit
    def forward(params, spoke_feats, nbr, wts):
        return fn(params, spoke_feats, nbr, wts,
                  *(consts[n] for n in names))

    return forward


@dataclasses.dataclass
class BucketedHaloPlan:
    """Static exchange plan for the capacity-bucketed layout (DESIGN.md §12).

    The exchange is realized as ONE gather per destination bucket out of a
    *flat* table concatenating every bucket's owned rows
    (``cluster_offset[c] = bucket base + index_in[c] * n_cap``): ragged
    per-bucket shapes stay out of the gather indices, and each bucket's
    fetch is an independent launch the scheduler can overlap with another
    bucket's layer step. Wire-level billing stays on the dense partition's
    send/recv tables (``repro.distributed.traffic``) — this plan only moves
    values.
    """
    flat_src: tuple       # per bucket [K_b, h_cap] int32 into the flat table
    halo_mask: tuple      # per bucket [K_b, h_cap] float32
    n_caps: tuple
    h_caps: tuple
    flat_rows: int        # total rows of the concatenated owned table

    @property
    def n_buckets(self) -> int:
        return len(self.flat_src)


def build_bucketed_halo_plan(bpart: BucketedPartition) -> BucketedHaloPlan:
    from repro.core.partition import halo_exchange_tables
    part = bpart.part
    src_c, src_s, mask = halo_exchange_tables(part)
    offset = np.zeros(part.n_clusters, np.int64)
    base = 0
    for b, cl in enumerate(bpart.clusters):
        for j, c in enumerate(cl):
            offset[c] = base + j * bpart.n_caps[b]
        base += len(cl) * bpart.n_caps[b]
    hcount = mask.sum(axis=1)
    fsrc, fmask = [], []
    for b, cl in enumerate(bpart.clusters):
        hc = bpart.h_caps[b]
        fs = np.zeros((len(cl), hc), np.int32)
        fm = np.zeros((len(cl), hc), np.float32)
        for j, c in enumerate(cl):
            h = int(hcount[c])
            fs[j, :h] = offset[src_c[c, :h]] + src_s[c, :h]
            fm[j, :h] = 1.0
        fsrc.append(fs)
        fmask.append(fm)
    return BucketedHaloPlan(tuple(fsrc), tuple(fmask), bpart.n_caps,
                            bpart.h_caps, base)


@jax.jit
def _flat_rows(*xs):
    """Concatenate per-bucket owned tables [K_b, n_cap, F] into the flat
    [sum(K_b * n_cap), F] table the bucketed halo gathers index."""
    return jnp.concatenate([x.reshape(-1, x.shape[-1]) for x in xs], axis=0)


@jax.jit
def _gather_halo(flat, idx, mask):
    """One bucket's halo fetch: [.., h_cap, F] rows out of the flat table,
    padding rows masked to zero."""
    return flat[idx] * mask[..., None]


@partial(jax.jit, static_argnames=("cfg", "act"))
def _bucket_layer(x, halo, nbr, wts, w, b, *, cfg, act):
    """One GNN layer over one bucket [K_b, n_cap(+h_cap), ...].

    The halo buffer is freshly allocated per layer by ``_gather_halo`` and
    dead after the concat; it is not donated here because its shape never
    matches an output (XLA would warn and ignore it) — the donation that
    kills per-tick host round-trips lives on the streaming engine's
    same-shape activation-cache scatters (DESIGN.md §12). The owned table
    ``x`` is never donated — callers hold it across repeated calls."""
    layer = {"w": w, "b": b}
    table = jnp.concatenate([x, halo], axis=1)
    return jnp.stack([
        _layer_step(table[c], nbr[c], wts[c], layer, cfg, act)
        for c in range(x.shape[0])])


def make_emulated_bucketed_forward(cfg, bplan: BucketedHaloPlan,
                                   mode: str = "alltoall",
                                   overlap: str = "overlap"):
    """Mesh-free decentralized forward over the bucketed ragged layout.

    feats/nbr/wts: tuples of per-bucket [K_b, n_cap, {F, s_cap}] tables.
    Returns a tuple of per-bucket [K_b, n_cap, out_dim] arrays.

    ``mode`` is accepted for API symmetry with the dense runtimes: both
    exchange strategies produce identical halo *values*, and the bucketed
    plan realizes them with the same flat gather — the allgather/alltoall
    distinction lives in the traffic accountant's billing of the dense
    send/recv tables, not here. ``overlap="overlap"`` dispatches every
    bucket's halo gather before any bucket's layer step, so JAX's async
    dispatch overlaps the fetches (the comm stand-in) with the MVMs;
    ``"serial"`` interleaves fetch -> step per bucket. Same values either
    way (gate: overlapped tick <= serialized, benchmarks/scale_serve.py).
    """
    assert mode in EXCHANGE_MODES, mode
    assert overlap in OVERLAP_MODES, overlap
    fidx = tuple(jnp.asarray(i) for i in bplan.flat_src)
    fmask = tuple(jnp.asarray(m) for m in bplan.halo_mask)
    nb = bplan.n_buckets

    def forward(params, feats, nbrs, wtss):
        # Spans here time *dispatch* (the loop body runs ahead of the
        # device); telemetry.device_sync closes each layer only when
        # tracing is enabled, so the overlap schedule is untouched when
        # telemetry is off.  Disabled spans are shared no-op singletons.
        tracer = tel.get_tracer()
        xs = list(feats)
        n_layers = len(params)
        for i, layer in enumerate(params):
            act = i < n_layers - 1 or cfg.final_activation
            flat = _flat_rows(*xs)
            if overlap == "overlap":
                halos = []
                for b in range(nb):
                    with tracer.span("halo.gather", layer=i, bucket=b):
                        halos.append(_gather_halo(flat, fidx[b], fmask[b]))
                xs_next = []
                for b in range(nb):
                    with tracer.span("halo.mvm", layer=i, bucket=b):
                        xs_next.append(
                            _bucket_layer(xs[b], halos[b], nbrs[b], wtss[b],
                                          layer["w"], layer["b"], cfg=cfg,
                                          act=act))
                xs = xs_next
            else:
                for b in range(nb):
                    with tracer.span("halo.gather", layer=i, bucket=b):
                        halo = _gather_halo(flat, fidx[b], fmask[b])
                    with tracer.span("halo.mvm", layer=i, bucket=b):
                        xs[b] = _bucket_layer(xs[b], halo, nbrs[b], wtss[b],
                                              layer["w"], layer["b"],
                                              cfg=cfg, act=act)
            tracer.device_sync(xs, name="halo.layer_sync")
        return tuple(xs)

    return forward


_tier0_bucket_gather = jax.jit(
    lambda spoke, cids, gs, sl, gm:
    spoke[cids[:, None], gs, sl] * gm[..., None])


def make_emulated_bucketed_semi_forward(cfg, bplan: BucketedHaloPlan,
                                        hier: HierPartition,
                                        bpart: BucketedPartition,
                                        mode: str = "alltoall",
                                        overlap: str = "overlap"):
    """Two-tier semi forward over the bucketed layout: the tier-0
    spoke->head gather assembles each bucket's region tables straight from
    the (dense) spoke tables, then the bucketed tier-1 runtime takes over.

    spoke_feats: [R, P, m_max, F]; nbr/wts: per-bucket tuples.
    Returns a tuple of per-bucket [K_b, n_cap, out_dim] arrays.
    """
    t0 = []
    n_max = hier.region.n_max
    for b, cl in enumerate(bpart.clusters):
        ncap = bplan.n_caps[b]
        w = min(ncap, n_max)
        gs = np.zeros((len(cl), ncap), np.int32)
        sl = np.zeros((len(cl), ncap), np.int32)
        gm = np.zeros((len(cl), ncap), np.float32)
        gs[:, :w] = hier.gather_spoke[cl, :w]
        sl[:, :w] = hier.gather_slot[cl, :w]
        gm[:, :w] = hier.region.local_mask[cl, :w]
        t0.append(tuple(jnp.asarray(a) for a in
                        (cl.astype(np.int32), gs, sl, gm)))
    inner = make_emulated_bucketed_forward(cfg, bplan, mode=mode,
                                           overlap=overlap)

    def forward(params, spoke_feats, nbrs, wtss):
        with tel.get_tracer().span("halo.tier0_gather", buckets=len(t0)):
            feats = tuple(_tier0_bucket_gather(spoke_feats, cids, gs, sl, gm)
                          for cids, gs, sl, gm in t0)
        return inner(params, feats, nbrs, wtss)

    return forward


def make_emulated_semi_forward(cfg, plan: TwoTierPlan,
                               mode: str = "allgather"):
    """Mesh-free two-tier semi forward — the single-process oracle for
    ``make_semi_forward`` (same tier-0 gather tables, same tier-1 exchange
    via ``_emulated_exchange``).

    spoke_feats: [R, P, m_max, F]; nbr/wts: [R, n_max, S] region-local.
    Returns [R, n_max, out_dim].
    """
    assert mode in EXCHANGE_MODES, mode
    h_max = plan.h_max
    t0 = _tier0_consts(plan)
    consts = _plan_consts(plan.region)

    @jax.jit
    def forward(params, spoke_feats, nbr, wts):
        r = spoke_feats.shape[0]
        x = (spoke_feats[jnp.arange(r)[:, None], t0["gspoke"], t0["gslot"]]
             * t0["gmask"][..., None])                  # tier 0: [R,n_max,F]
        return _emulated_layers(params, x, nbr, wts, cfg, consts, mode,
                                h_max)

    return forward
