"""Measured communication-volume accounting for ExecutionPlans.

``ExecutionPlan.predicted_metrics`` prices a setting with the paper's
Eqs. 4/5; this module reports what the runtime's exchanges actually move:
rows and bytes per device per layer, counted on the *executed* send/recv
tables (the very tables ``distributed.halo`` hands to the collectives /
emulated exchange), at the runtime's padded shapes. "Measured" therefore
means derived from the execution plan's wire schedule, not estimated from
graph statistics — for the ``alltoall`` mode the per-pair row counts equal
the pruned ``Partition.comm_volume`` e_ij by construction, which is the
predicted-vs-executed validation loop ``benchmarks/semi_runtime.py`` closes
(DESIGN.md §7, EXPERIMENTS.md §Semi-runtime).

Tier structure:

  * decentralized — one tier: per-layer halo exchange rows between peers.
  * semi          — tier 0: each spoke uploads its owned feature rows to its
    region head once per inference (the input features); tier 1: per-layer
    head<->head halo rows, identical accounting to decentralized but over
    the region partition.
"""
from __future__ import annotations

import dataclasses

import numpy as np

ITEMSIZE = 4  # float32 features on the wire


@dataclasses.dataclass(frozen=True)
class TrafficReport:
    """Measured wire traffic of one ExecutionPlan.

    ``tier1_rows[i, j]`` is the number of feature rows device i *receives*
    from peer j in one halo exchange (one exchange per GNN layer);
    ``tier0_rows[r, p]`` is the number of rows spoke p of region r uploads
    to its head (semi only — empty [0, 0] otherwise). Bytes follow from the
    per-layer feature dims: tier 0 moves input features once, tier 1 moves
    the layer's input dim every layer.
    """
    setting: str
    mode: str
    layer_dims: tuple          # feature dim entering each layer's exchange
    tier0_rows: np.ndarray     # [R, P] int64
    tier1_rows: np.ndarray     # [K, K] int64
    itemsize: int = ITEMSIZE

    @property
    def n_devices(self) -> int:
        return self.tier1_rows.shape[0]

    @property
    def n_layers(self) -> int:
        return len(self.layer_dims)

    def tier0_bytes(self) -> np.ndarray:
        """[R, P] bytes each spoke uploads (input features, once)."""
        f = self.layer_dims[0] if self.layer_dims else 0
        return self.tier0_rows * f * self.itemsize

    def tier1_bytes(self) -> np.ndarray:
        """[L, K] bytes each device receives per layer."""
        dims = np.asarray(self.layer_dims, np.int64)
        per_dev = self.tier1_rows.sum(axis=1)           # rows/exchange
        return dims[:, None] * per_dev[None, :] * self.itemsize

    def total_bytes(self) -> int:
        return int(self.tier0_bytes().sum() + self.tier1_bytes().sum())

    def summary(self) -> str:
        t0 = int(self.tier0_bytes().sum())
        t1 = int(self.tier1_bytes().sum())
        return (f"{self.setting}/{self.mode}: tier0 {t0 / 1e6:.3f} MB "
                f"(once), tier1 {t1 / 1e6:.3f} MB over {self.n_layers} "
                f"layers, total {(t0 + t1) / 1e6:.3f} MB")


def exchange_rows(plan, mode: str, n_max: int) -> np.ndarray:
    """[K, K] rows device i receives from peer j in one halo exchange.

    ``plan`` is a ``distributed.halo.HaloPlan``. ``allgather`` ships every
    peer's full padded table; ``alltoall`` ships exactly the send-list rows
    (== the pruned comm_volume e_ij).
    """
    k = plan.src_cluster.shape[0]
    if mode == "allgather":
        rows = np.full((k, k), n_max, np.int64)
        np.fill_diagonal(rows, 0)
        return rows
    assert mode == "alltoall", mode
    return plan.recv_mask.sum(axis=2).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class StreamingTrafficReport:
    """Measured wire traffic of one *incremental* refresh tick.

    Unlike ``TrafficReport`` (whose tier-1 rows repeat every layer), the
    incremental exchange ships a different row set per layer — only rows
    whose cached value changed (the dirty frontier at that level), plus
    send slots structural churn newly created. ``tier1_rows[l, i, j]`` is
    the number of feature rows device i receives from peer j in layer l's
    exchange; ``tier0_rows[r, p]`` is the number of mutated feature rows
    spoke p re-uploads to its head this tick (semi only).
    """
    setting: str
    mode: str
    layer_dims: tuple          # feature dim entering each layer's exchange
    tier0_rows: np.ndarray     # [R, P] int64
    tier1_rows: np.ndarray     # [L, K, K] int64
    itemsize: int = ITEMSIZE

    @property
    def n_devices(self) -> int:
        return self.tier1_rows.shape[1]

    @property
    def n_layers(self) -> int:
        return self.tier1_rows.shape[0]

    def tier0_bytes(self) -> np.ndarray:
        """[R, P] bytes each spoke re-uploads (mutated input rows, once)."""
        f = self.layer_dims[0] if self.layer_dims else 0
        return self.tier0_rows * f * self.itemsize

    def tier1_bytes(self) -> np.ndarray:
        """[L, K] bytes each device receives per layer."""
        dims = np.asarray(self.layer_dims, np.int64)
        per_dev = self.tier1_rows.sum(axis=2)           # [L, K]
        return dims[:, None] * per_dev * self.itemsize

    def total_bytes(self) -> int:
        return int(self.tier0_bytes().sum() + self.tier1_bytes().sum())

    def summary(self) -> str:
        t0 = int(self.tier0_bytes().sum())
        t1 = int(self.tier1_bytes().sum())
        return (f"{self.setting}/{self.mode} incremental: tier0 "
                f"{t0 / 1e6:.3f} MB, tier1 {t1 / 1e6:.3f} MB over "
                f"{self.n_layers} layers, total {(t0 + t1) / 1e6:.3f} MB")


def incremental_exchange_rows(halo_plan, dirty_local: np.ndarray, mode: str,
                              new_send: np.ndarray | None = None
                              ) -> np.ndarray:
    """[K, K] rows device i receives from peer j in one incremental halo
    exchange.

    ``dirty_local``: [K, n_max] bool — owned rows whose value changed since
    the peers last cached them. ``allgather`` re-broadcasts exactly the
    dirty rows (its peers cache *entire* tables from the cold-start
    broadcast, so a row structural churn newly exposes is already cached —
    ``new_send`` does not apply); ``alltoall`` ships the send-list slots
    whose source row is dirty, plus ``new_send`` slots (send-table entries
    created by structural churn — those peers have never cached, clean or
    not).
    """
    k = halo_plan.src_cluster.shape[0]
    if mode == "allgather":
        counts = dirty_local.sum(axis=1).astype(np.int64)   # [K]
        rows = np.tile(counts[None, :], (k, 1))
        np.fill_diagonal(rows, 0)
        return rows
    assert mode == "alltoall", mode
    ship = halo_plan.send_mask.copy()                       # [K, K, s_max]
    src_dirty = np.take_along_axis(
        dirty_local[:, None, :].repeat(k, axis=1),
        halo_plan.send_slot.astype(np.int64), axis=2)
    ship &= src_dirty if new_send is None else (src_dirty | new_send)
    return ship.sum(axis=2).T.astype(np.int64)              # recv view


def measure_incremental(plan, halo_plan, dirty_locals: np.ndarray,
                        cfg=None, mode: str = "alltoall",
                        new_send: np.ndarray | None = None
                        ) -> StreamingTrafficReport:
    """Bill one incremental tick of an ExecutionPlan's exchanges.

    ``dirty_locals``: [L+1, K, n_max] bool — the frontier masks in
    owned-row layout (level 0 = mutated input rows; level l = recomputed
    rows of h^l). Layer l's exchange ships level-l values, so its rows are
    counted against ``dirty_locals[l]``; tier 0 (semi) re-uploads only the
    level-0 mutations, attributed to the owning spoke via the hierarchy's
    gather tables.
    """
    dims = (tuple(cfg.dims[:-1]) if cfg is not None
            else (plan.graph.feature_len,))
    n_layers = len(dims)
    tier1 = np.stack([
        incremental_exchange_rows(halo_plan, dirty_locals[l], mode,
                                  new_send=new_send)
        for l in range(n_layers)])
    tier0 = np.zeros((0, 0), np.int64)
    if plan.setting == "semi":
        hier = plan.hier
        r, p = hier.n_heads, hier.spokes_per_region
        tier0 = np.zeros((r, p), np.int64)
        for reg in range(r):
            spokes = hier.gather_spoke[reg][dirty_locals[0][reg]]
            np.add.at(tier0[reg], spokes, 1)
    return StreamingTrafficReport(plan.setting, mode, dims, tier0, tier1)


def modeled_frontier(part, seed_frac: float, frac: float,
                     n_layers: int) -> np.ndarray:
    """Deterministic pseudo-frontier in owned-row layout for modeled
    incremental billing: level 0 covers the first ``ceil(seed_frac * n)``
    owned rows of each device (the churn seeds), levels 1..L the first
    ``ceil(frac * n)`` (the expanded dirty share). The planner's traffic
    evaluator feeds this to ``measure_incremental`` when it has a concrete
    partition but only a *modeled* churn profile (DESIGN.md §10); the
    streaming engine's measured masks supersede it at serve time."""
    k, n_max = part.local_mask.shape
    n_rows = part.local_mask.sum(axis=1)
    levels = np.zeros((n_layers + 1, k, n_max), bool)
    for level in range(n_layers + 1):
        f = min(max(seed_frac if level == 0 else frac, 0.0), 1.0)
        for c in range(k):
            take = int(np.ceil(n_rows[c] * f))
            levels[level, c, :take] = part.local_mask[c, :take]
    return levels


def measure_execution(plan, cfg=None, mode: str = "alltoall") -> TrafficReport:
    """Build the TrafficReport for an ExecutionPlan (any setting).

    ``cfg`` (a GNNConfig) supplies the per-layer feature dims; without it a
    single exchange at the graph's input feature dim is assumed.
    """
    from repro.distributed.halo import build_halo_plan
    dims = (tuple(cfg.dims[:-1]) if cfg is not None
            else (plan.graph.feature_len,))
    no_spokes = np.zeros((0, 0), np.int64)
    if plan.setting == "centralized":
        return TrafficReport(plan.setting, mode, dims, no_spokes,
                             np.zeros((1, 1), np.int64))
    halo_plan = build_halo_plan(plan.part)
    tier1 = exchange_rows(halo_plan, mode, plan.part.n_max)
    tier0 = (plan.hier.spoke_mask.sum(axis=2).astype(np.int64)
             if plan.setting == "semi" else no_spokes)
    return TrafficReport(plan.setting, mode, dims, tier0, tier1)
