"""Sharding rules: TP / FSDP / EP / ZeRO-1 partition specs for every arch.

Strategy (DESIGN.md §6):
  * TP over 'model': attention projections column/row-parallel on the packed
    head dim (always 16-divisible in the assigned configs), FFN wi col / wo
    row, vocab-sharded embeddings+logits when the vocab divides.
  * EP over 'model' for MoE when n_experts divides the axis (deepseek-v3);
    otherwise inner-dim TP of the expert FFN (grok-1's 8 experts).
  * FSDP over 'data' for >= 9 B archs: params (and their optimizer state)
    additionally sharded on the first divisible non-TP dim.
  * ZeRO-1 everywhere: optimizer moments get the FSDP treatment even when
    params are replicated over 'data'.
  * Multi-pod: the 'pod' axis joins data parallelism (batch + FSDP/ZeRO) —
    gradients reduce hierarchically (intra-pod first, then across).

Divisibility is always checked; anything that doesn't divide cleanly is
replicated on that dim (recorded — the roofline table shows the cost).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

from repro.models.config import ModelConfig

FSDP_ARCHS = {"yi-34b", "grok-1-314b", "deepseek-v3-671b",
              "recurrentgemma-9b"}


def dp_axes(mesh) -> tuple:
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))


def _div(size: int, mesh, axis: str) -> bool:
    return size % int(np.prod([mesh.shape[a] for a in
                               ([axis] if isinstance(axis, str) else axis)])) == 0


def _axis_size(mesh, axes) -> int:
    axes = [axes] if isinstance(axes, str) else list(axes)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _maybe(size: int, mesh, axis):
    """axis if it divides size, else None (replicated)."""
    return axis if size % _axis_size(mesh, axis) == 0 else None


def _leaf_spec(path, leaf, cfg: ModelConfig, mesh) -> P:
    """TP spec for one parameter leaf, from its key path + shape."""
    names = [k.key for k in path if isinstance(k, DictKey)]
    name = names[-1] if names else ""
    shape = leaf.shape
    scanned = "main" in names
    nd = len(shape) - (1 if scanned else 0)   # dims after the cycle axis

    def out(*spec):
        spec = tuple(spec) + (None,) * (nd - len(spec))
        return P(*(((None,) + spec) if scanned else spec))

    m = "model"
    if name == "tok":
        return out(_maybe(shape[-2], mesh, m), None)
    if name == "head":
        return out(None, _maybe(shape[-1], mesh, m))
    # attention / mixers (column-parallel in, row-parallel out)
    if name in ("wi", "wo") and nd == 3:             # MoE experts [E, ., .]
        if _div(shape[-3], mesh, m):
            return out(m, None, None)                # EP
        if name == "wi":
            return out(None, None, _maybe(shape[-1], mesh, m))
        return out(None, _maybe(shape[-2], mesh, m), None)
    if name in ("wq", "wk", "wv", "wuq", "wukv", "wx", "w_a", "w_i",
                "wr", "wg", "w1"):
        return out(None, _maybe(shape[-1], mesh, m))
    if name in ("wo",):
        return out(_maybe(shape[-2], mesh, m), None)
    if name in ("wdq", "wdkv", "w2", "proj"):
        return out(None, None)                       # small latents: replicate
    if name == "conv":
        return out(None, _maybe(shape[-1], mesh, m))
    if name == "lam":
        return out(_maybe(shape[-1], mesh, m))
    if name in ("shared_wi",):
        return out(None, _maybe(shape[-1], mesh, m))
    if name in ("shared_wo",):
        return out(_maybe(shape[-2], mesh, m), None)
    if name == "router":
        return out(None, None)
    if name in ("wi",):                              # dense FFN [D, F]
        return out(None, _maybe(shape[-1], mesh, m))
    return out(*([None] * nd))


def _fsdp_augment(spec: P, leaf, mesh, dp) -> P:
    """Add 'data'(+pod) sharding on the first free divisible dim."""
    used = set()
    for p in spec:
        for a in (p if isinstance(p, tuple) else (p,)):
            used.add(a)
    if used & set(dp):                 # already FSDP-sharded; idempotent
        return spec
    parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
    for i, (p, s) in enumerate(zip(parts, leaf.shape)):
        if p is None and s % _axis_size(mesh, dp) == 0 and s >= 1024:
            parts[i] = dp if len(dp) > 1 else dp[0]
            break
    return P(*parts)


def param_shardings(params: Any, cfg: ModelConfig, mesh,
                    fsdp: bool | None = None):
    """PartitionSpec tree for a params pytree (arrays or ShapeDtypeStructs)."""
    fsdp = (cfg.name in FSDP_ARCHS) if fsdp is None else fsdp
    dp = dp_axes(mesh)

    def one(path, leaf):
        spec = _leaf_spec(path, leaf, cfg, mesh)
        if fsdp:
            spec = _fsdp_augment(spec, leaf, mesh, dp)
        return spec

    return jax.tree_util.tree_map_with_path(one, params)


def optimizer_shardings(param_specs: Any, params: Any, mesh):
    """ZeRO-1: moments get FSDP sharding even when params don't."""
    dp = dp_axes(mesh)

    def one(spec, leaf):
        return _fsdp_augment(spec, leaf, mesh, dp)

    return jax.tree.map(one, param_specs, params)


def activation_rules(cfg: ModelConfig, mesh,
                     seq_parallel: bool = False) -> dict:
    """Logical-name -> PartitionSpec map for models.common.shard().

    ``seq_parallel``: Megatron-style sequence parallelism — the residual
    stream between blocks is sharded on seq over 'model', turning the TP
    all-reduces into reduce-scatter + all-gather pairs (half the traffic)
    and shrinking remat-saved activations by the TP degree."""
    dp = dp_axes(mesh)
    b = dp if len(dp) > 1 else dp[0]
    m = "model"
    res = P(b, m, None) if seq_parallel else P(b, None, None)
    rules = {
        "embed": res,
        "residual": res,
        "ffn_hidden": P(b, None, _maybe(2 * cfg.d_ff, mesh, m)),
        "logits": P(b, None, _maybe(cfg.vocab, mesh, m)),
        # attention-free recurrences: width-sharded, seq-local scan
        "rec_width": P(b, None, _maybe(cfg.rglru_width or cfg.d_model,
                                       mesh, m)),
    }
    if cfg.n_heads % _axis_size(mesh, m) == 0:
        rules["heads"] = P(b, None, m, None)
    if cfg.moe is not None:
        # grouped dispatch buffers [G, E, C, D]: G over data always; E over
        # model when divisible (EP), else expert-FFN hidden TP.
        if cfg.moe.n_experts % _axis_size(mesh, m) == 0:
            rules["expert_buf"] = P(b, m, None, None)
            rules["expert_hidden"] = P(b, m, None, None)
        else:
            rules["expert_buf"] = P(b, None, None, None)
            rules["expert_hidden"] = P(b, None, None, m)
        # combine reads y_buf replicated over 'model' (explicit all-gather)
        rules["expert_out"] = P(b, None, None, None)
    return rules


def batch_shardings(mesh, kind: str, batch_shape_tree: Any):
    """Input shardings: batch dim over data(+pod); everything else replicated
    unless batch == 1 (long-context: replicate)."""
    dp = dp_axes(mesh)
    b = dp if len(dp) > 1 else dp[0]

    def one(leaf):
        if not hasattr(leaf, "shape") or not leaf.shape:
            return P()
        bdim = leaf.shape[0]
        if bdim % _axis_size(mesh, dp) == 0:
            return P(b, *([None] * (len(leaf.shape) - 1)))
        return P(*([None] * len(leaf.shape)))

    return jax.tree.map(one, batch_shape_tree)


def cache_shardings(caches: Any, cfg: ModelConfig, mesh):
    """KV/state caches: batch over data(+pod) when divisible, KV-heads/latent
    over model when divisible."""
    dp = dp_axes(mesh)
    dpn = dp if len(dp) > 1 else dp[0]
    msize = _axis_size(mesh, "model")

    def one(path, leaf):
        if not hasattr(leaf, "shape") or len(leaf.shape) < 1:
            return P()
        names = [k.key for k in path if isinstance(k, DictKey)]
        scanned = "main" in names
        shape = leaf.shape[1:] if scanned else leaf.shape
        if not shape:
            return P()
        parts = [None] * len(shape)
        if shape[0] % _axis_size(mesh, dp) == 0:
            parts[0] = dpn
        # shard kv-head / latent / width dims over model where they divide
        name = names[-1] if names else ""
        if name in ("k", "v") and len(shape) == 4:
            if shape[2] % msize == 0:
                parts[2] = "model"          # KV heads
            elif shape[1] % msize == 0:
                parts[1] = "model"          # KV seq (flash-decoding style)
            elif shape[3] % msize == 0:
                parts[3] = "model"          # head_dim (partial-sum attention)
        if name in ("ckv", "kpe") and len(shape) == 3:
            # MLA latent/rope caches: shard the SEQ dim (flash-decoding) —
            # latent-dim sharding forces a per-layer all-gather of the
            # whole cache (see EXPERIMENTS.md §Perf minicpm3 iteration 1)
            if shape[1] % msize == 0:
                parts[1] = "model"
            elif shape[2] % msize == 0:
                parts[2] = "model"
        if name in ("s",) and len(shape) == 4 and shape[1] % msize == 0:
            parts[1] = "model"
        if name in ("h", "conv", "x_prev", "chan_prev") and \
                shape[-1] % msize == 0:
            parts[-1] = "model"
        if scanned:
            parts = [None] + parts
        return P(*parts)

    return jax.tree_util.tree_map_with_path(one, caches)
