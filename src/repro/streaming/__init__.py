"""Streaming inference runtime: dynamic graphs, incremental k-hop refresh,
and batched serving (DESIGN.md §9).

The §4.2 taxi workload streams — positions and demand maps move every tick
— and only a delta of the graph changes per step. This package makes that
delta first-class:

  * ``delta``       — ``GraphDelta`` mutation buffer + ``apply_deltas``
    amortized CSR rebuild (gcn_normalize contract preserved).
  * ``frontier``    — k-hop dirty-frontier masks: which rows each of the L
    layers must recompute.
  * ``incremental`` — ``IncrementalEngine``: cached per-layer activations,
    dirty-rows-only recompute through the same layer step every
    backend × setting uses, incremental traffic billing.
  * ``server``      — ``StreamingGNNServer``: ``ingest()`` tick streams,
    eager / interval / bounded-staleness refresh policies, batched
    ``query()``.

``benchmarks/streaming_replay.py`` replays a taxi tick stream over all
settings and reports full-vs-incremental wall-clock, recomputed-node
fraction, and measured traffic (EXPERIMENTS.md §Streaming-replay).
"""
from .delta import DeltaResult, GraphDelta, apply_deltas
from .frontier import FRONTIER_MODES, FrontierMasks, expand_frontier
from .incremental import IncrementalEngine, StreamingUpdate
from .server import POLICIES, StreamingGNNServer

__all__ = [
    "DeltaResult", "GraphDelta", "apply_deltas",
    "FRONTIER_MODES", "FrontierMasks", "expand_frontier",
    "IncrementalEngine", "StreamingUpdate",
    "POLICIES", "StreamingGNNServer",
]
