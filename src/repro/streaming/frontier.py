"""k-hop dirty-frontier tracking: which rows must each GNN layer recompute.

An L-layer GNN reads a node's L-hop neighborhood, so a mutation at node v
invalidates layer-l activations of every node within l hops of v — the
"dirty frontier". The expansion runs over the *padded neighbor sample* the
kernels actually read (``Graph.neighbor_sample`` truncation included), so
the masks are exact w.r.t. the runtime, not the untruncated graph: an edge
past the sample cut never dirties anything.

Mask semantics (``FrontierMasks.masks[l]``, shape [L+1, N]):

  * ``masks[0]``  — rows of the *input* table h^0 that changed
    (feature-dirty nodes).
  * ``masks[l]``  — rows of h^l (the output of layer l) that must be
    recomputed: structure-dirty rows (their sample/weights changed), plus
    any row whose sample contains a ``masks[l-1]`` node. Because the sample
    always contains the self loop, masks are monotone:
    ``masks[l-1] <= masks[l]`` wherever the row's own input was dirty.

``streaming.incremental`` consumes these masks directly; the recomputed-node
fraction they imply is the headline number ``benchmarks/streaming_replay``
reports.

The inner membership test — "does this row's sample contain a dirty
node?" — is an associative lookup, so it can run on the traversal core's
search CAM (DESIGN.md §15): load the dirty node ids as CAM entries and
search the sample's flattened column indices against them; a non-zero
match count *is* membership. ``expand_frontier(..., mode=)`` selects the
path (``numpy`` expansion, ``cam`` via the jnp kernel oracle,
``cam-pallas`` via the Pallas kernel); all modes are bit-identical by
construction — pad slots are replaced by ``-1`` sentinels, which the CAM
wrapper guarantees match nothing.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.kernels.cam_match import search as _cam_search

FRONTIER_MODES = ("numpy", "cam", "cam-pallas")

# bound on the CAM match-bitmap footprint per chunk: Qc x n_dirty int8
_BITMAP_BUDGET = 1 << 24


@dataclasses.dataclass(frozen=True)
class FrontierMasks:
    """Per-layer recompute masks over global node ids."""
    masks: np.ndarray              # [L+1, N] bool; [0] = input dirt

    @property
    def n_layers(self) -> int:
        return self.masks.shape[0] - 1

    @property
    def n_nodes(self) -> int:
        return self.masks.shape[1]

    def layer(self, l: int) -> np.ndarray:
        """[N] bool — rows of h^l to recompute (l in [1, L])."""
        return self.masks[l]

    def recompute_fraction(self) -> float:
        """Recomputed rows across layers 1..L over L*N — the fraction of
        per-layer kernel work an incremental refresh performs."""
        l, n = self.n_layers, self.n_nodes
        if l == 0 or n == 0:
            return 0.0
        return float(self.masks[1:].sum()) / float(l * n)

    def counts(self) -> np.ndarray:
        """[L+1] dirty-row count per level."""
        return self.masks.sum(axis=1)


def _dirty_hop_cam(prev: np.ndarray, flat: np.ndarray, shape: tuple,
                   backend: str, interpret: bool | None) -> np.ndarray:
    """One hop of dirt propagation on the search CAM.

    ``prev``: [N] bool dirty mask at level l-1. ``flat``: the padded
    sample's column indices flattened to [N*S] with pad slots already
    replaced by ``-1`` (negative queries match nothing). Returns the [N]
    bool "any sampled input dirty" mask — identical to
    ``(prev[neighbors] & live).any(axis=1)``.
    """
    dirty_ids = np.nonzero(prev)[0].astype(np.int32)
    if dirty_ids.size == 0:
        return np.zeros(shape[0], bool)
    entries = jnp.asarray(dirty_ids)
    chunk = max(_BITMAP_BUDGET // max(dirty_ids.size, 1), 1)
    hit = np.empty(flat.size, bool)
    for lo in range(0, flat.size, chunk):
        qc = flat[lo:lo + chunk]
        _, counts = _cam_search(entries, jnp.asarray(qc), backend=backend,
                                interpret=interpret)
        hit[lo:lo + len(qc)] = np.asarray(counts) > 0
    return hit.reshape(shape).any(axis=1)


def expand_frontier(neighbors: np.ndarray, weights: np.ndarray,
                    feature_dirty: np.ndarray, structure_dirty: np.ndarray,
                    n_layers: int, mode: str = "numpy",
                    interpret: bool | None = None) -> FrontierMasks:
    """BFS the dirt L hops through the sampled adjacency.

    ``neighbors``/``weights``: [N, S] — the *global* padded sample of the
    mutated graph (self loops included), i.e. exactly what the centralized
    runtime reads and the same edge set the per-cluster subgraphs are built
    from. Padding slots carry weight 0 and contribute nothing, so dirt does
    not propagate through them (without this, a dirty node 0 would dirty
    every padded row). ``feature_dirty`` / ``structure_dirty``: [N] bool
    from ``apply_deltas``.

    ``mode`` picks the membership-test path (``FRONTIER_MODES``); every
    mode returns bit-identical masks — ``cam``/``cam-pallas`` route the
    per-hop membership test through ``kernels.cam_match.search`` with the
    dirty ids as CAM entries.
    """
    if mode not in FRONTIER_MODES:
        raise ValueError(f"unknown frontier mode {mode!r}; "
                         f"one of {FRONTIER_MODES}")
    neighbors = np.asarray(neighbors)
    n = neighbors.shape[0]
    live = np.asarray(weights) != 0        # [N, S] real (non-padding) slots
    feature_dirty = np.asarray(feature_dirty, bool).reshape(n)
    structure_dirty = np.asarray(structure_dirty, bool).reshape(n)
    masks = np.zeros((n_layers + 1, n), bool)
    masks[0] = feature_dirty
    if mode == "numpy":
        for l in range(1, n_layers + 1):
            # a row is dirty iff its own sample changed or any sampled
            # input was
            prev = masks[l - 1]
            masks[l] = structure_dirty | (prev[neighbors] & live).any(axis=1)
        return FrontierMasks(masks)
    backend = "jnp" if mode == "cam" else "pallas"
    # pad slots -> -1 sentinel once: negative CAM queries match nothing
    flat = np.where(live, neighbors, -1).astype(np.int32).reshape(-1)
    for l in range(1, n_layers + 1):
        hop = _dirty_hop_cam(masks[l - 1], flat, neighbors.shape,
                             backend, interpret)
        masks[l] = structure_dirty | hop
    return FrontierMasks(masks)
