"""Graph mutation buffer + amortized CSR rebuild for dynamic graphs.

The §4.2 taxi case study is a streaming workload: positions and demand maps
move every tick, and occasionally the road/proximity graph itself changes.
``GraphDelta`` buffers those mutations (feature updates, edge adds/removes)
against a fixed node set, and ``apply_deltas`` commits the whole buffer in
one vectorized CSR rebuild — O(E) numpy, amortized over however many ticks
were buffered, instead of a per-mutation splice.

Renormalization contract: ``Graph.gcn_normalize`` derives every edge weight
and the implicit self-loop weight purely from the degree profile
(``w_ij = 1/sqrt((d_i+1)(d_j+1))``, diagonal ``1/(d_i+1)``). A graph that
was normalized (``self_loop is not None``) therefore stays exactly on that
contract after any structural delta: ``apply_deltas`` recomputes the
normalization from the mutated structure, so the result is
indistinguishable from rebuilding the raw graph and calling
``gcn_normalize`` from scratch (regression-tested).

Dirt tracking: the result carries two [N] masks consumed by
``streaming.frontier``:

  * ``feature_dirty``   — nodes whose input feature row changed.
  * ``structure_dirty`` — nodes whose *aggregation inputs* changed: rows
    that gained/lost an edge, plus (normalized graphs only) every row
    touched by a degree change — a degree change at u rescales u's own row
    (self loop + all in-edges) *and* every edge elsewhere that reads u as a
    source, so those destination rows are dirty too.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import Graph


@dataclasses.dataclass
class GraphDelta:
    """Buffered mutations over a fixed node set of size ``n_nodes``.

    Node additions/removals are out of scope (the serving plans pin the
    node set); ids out of ``[0, n_nodes)`` raise immediately so a bad tick
    cannot poison the buffer.
    """
    n_nodes: int
    _feat_nodes: list = dataclasses.field(default_factory=list)
    _feat_rows: list = dataclasses.field(default_factory=list)
    _add_dst: list = dataclasses.field(default_factory=list)
    _add_src: list = dataclasses.field(default_factory=list)
    _add_w: list = dataclasses.field(default_factory=list)
    _rm_dst: list = dataclasses.field(default_factory=list)
    _rm_src: list = dataclasses.field(default_factory=list)
    # per remove call: how many add-edges were buffered before it, so a
    # remove cancels earlier buffered adds but not later re-adds
    _rm_watermark: list = dataclasses.field(default_factory=list)

    def _check_ids(self, *arrays) -> None:
        for a in arrays:
            if a.size and (a.min() < 0 or a.max() >= self.n_nodes):
                raise IndexError(
                    f"node id out of range [0, {self.n_nodes}): "
                    f"[{a.min()}, {a.max()}]")

    def update_features(self, nodes, rows) -> "GraphDelta":
        """Replace the feature rows of ``nodes`` ([M] int) with ``rows``
        ([M, F]). Later updates to the same node win."""
        nodes = np.asarray(nodes, np.int64).reshape(-1)
        rows = np.asarray(rows, np.float32).reshape(len(nodes), -1)
        self._check_ids(nodes)
        self._feat_nodes.append(nodes)
        self._feat_rows.append(rows)
        return self

    def add_edges(self, dst, src, weight=None) -> "GraphDelta":
        """Append edges src -> dst (CSR rows are destinations). ``weight``
        ([M] or scalar) is only meaningful on unnormalized graphs — a
        normalized graph rederives every weight from the degree profile."""
        dst = np.asarray(dst, np.int64).reshape(-1)
        src = np.asarray(src, np.int64).reshape(-1)
        assert dst.shape == src.shape, (dst.shape, src.shape)
        self._check_ids(dst, src)
        w = np.broadcast_to(
            np.asarray(1.0 if weight is None else weight, np.float32),
            dst.shape).copy()
        self._add_dst.append(dst)
        self._add_src.append(src)
        self._add_w.append(w)
        return self

    def remove_edges(self, dst, src) -> "GraphDelta":
        """Remove every edge matching a (dst, src) pair (duplicate parallel
        edges all go) — including edges *added earlier in this buffer*; an
        add buffered after the remove survives. Unknown pairs are ignored.
        """
        dst = np.asarray(dst, np.int64).reshape(-1)
        src = np.asarray(src, np.int64).reshape(-1)
        assert dst.shape == src.shape, (dst.shape, src.shape)
        self._check_ids(dst, src)
        self._rm_dst.append(dst)
        self._rm_src.append(src)
        self._rm_watermark.append(sum(len(a) for a in self._add_dst))
        return self

    @property
    def has_structure(self) -> bool:
        return bool(self._add_dst or self._rm_dst)

    @property
    def has_features(self) -> bool:
        return bool(self._feat_nodes)

    def __len__(self) -> int:
        """Number of buffered mutations (feature rows + edge ops)."""
        return (sum(len(a) for a in self._feat_nodes)
                + sum(len(a) for a in self._add_dst)
                + sum(len(a) for a in self._rm_dst))

    def clear(self) -> None:
        for buf in (self._feat_nodes, self._feat_rows, self._add_dst,
                    self._add_src, self._add_w, self._rm_dst, self._rm_src,
                    self._rm_watermark):
            buf.clear()


@dataclasses.dataclass
class DeltaResult:
    """Mutated graph + the dirt masks ``streaming.frontier`` expands."""
    graph: Graph
    feature_dirty: np.ndarray     # [N] bool — input feature row changed
    structure_dirty: np.ndarray   # [N] bool — aggregation inputs changed

    @property
    def any_dirty(self) -> bool:
        return bool(self.feature_dirty.any() or self.structure_dirty.any())


def _edge_keys(dst: np.ndarray, src: np.ndarray, n: int) -> np.ndarray:
    return dst.astype(np.int64) * n + src.astype(np.int64)


def apply_deltas(g: Graph, delta: GraphDelta) -> DeltaResult:
    """Commit every buffered mutation in one amortized CSR rebuild.

    Returns a *new* Graph (``g`` is never mutated) plus the dirt masks.
    Within a row, surviving edges keep their original order and added edges
    append after them — so the padded-sample truncation of untouched rows
    is stable. The buffer is left intact; callers clear it after a commit.
    """
    n = g.n_nodes
    assert delta.n_nodes == n, (delta.n_nodes, n)
    normalized = g.self_loop is not None
    feature_dirty = np.zeros(n, bool)
    structure_dirty = np.zeros(n, bool)

    features = g.features
    if delta.has_features:
        features = features.copy()
        for nodes, rows in zip(delta._feat_nodes, delta._feat_rows):
            assert rows.shape[1] == features.shape[1], (
                rows.shape, features.shape)
            features[nodes] = rows
            feature_dirty[nodes] = True

    if not delta.has_structure:
        graph = Graph(g.indptr, g.indices, g.edge_weight, features,
                      g.self_loop)
        return DeltaResult(graph, feature_dirty, structure_dirty)

    deg_old = np.diff(g.indptr)
    dst_old = np.repeat(np.arange(n, dtype=np.int64), deg_old)
    keep = np.ones(g.n_edges, bool)
    add_dst = (np.concatenate(delta._add_dst) if delta._add_dst
               else np.zeros(0, np.int64))
    add_src = (np.concatenate(delta._add_src) if delta._add_src
               else np.zeros(0, np.int64))
    add_w = (np.concatenate(delta._add_w) if delta._add_w
             else np.zeros(0, np.float32))
    add_keep = np.ones(len(add_dst), bool)
    if delta._rm_dst:
        old_keys = _edge_keys(dst_old, g.indices.astype(np.int64), n)
        add_keys = _edge_keys(add_dst, add_src, n)
        add_pos = np.arange(len(add_dst))
        for rm_d, rm_s, mark in zip(delta._rm_dst, delta._rm_src,
                                    delta._rm_watermark):
            rm_keys = _edge_keys(rm_d, rm_s, n)
            keep &= ~np.isin(old_keys, rm_keys)
            # cancel adds buffered before this remove; later re-adds stand
            add_keep &= ~(np.isin(add_keys, rm_keys) & (add_pos < mark))
            structure_dirty[rm_d] = True
        add_dst, add_src, add_w = (add_dst[add_keep], add_src[add_keep],
                                   add_w[add_keep])
    structure_dirty[add_dst] = True

    old_w = (g.edge_weight[keep] if g.edge_weight is not None
             else np.ones(int(keep.sum()), np.float32))
    dst = np.concatenate([dst_old[keep], add_dst])
    src = np.concatenate([g.indices[keep].astype(np.int64), add_src])
    wts = np.concatenate([old_w, add_w])
    order = np.argsort(dst, kind="stable")     # old-before-new within a row
    dst, src, wts = dst[order], src[order], wts[order]
    indptr = np.zeros(n + 1, np.int64)
    np.add.at(indptr, dst + 1, 1)
    indptr = np.cumsum(indptr)
    graph = Graph(indptr, src.astype(np.int32), wts.astype(np.float32),
                  features)

    if normalized:
        graph = graph.gcn_normalize()          # rederives w_ij + self loop
        deg_changed = deg_old != np.diff(indptr)
        # a degree change at u rescales u's own row (self loop + in-edges)
        # and every row that reads u as a source
        structure_dirty |= deg_changed
        hit = deg_changed[graph.indices]
        structure_dirty[np.repeat(np.arange(n), np.diff(indptr))[hit]] = True
    return DeltaResult(graph, feature_dirty, structure_dirty)
