"""StreamingGNNServer: batched serving over a dynamic graph.

The streaming counterpart of ``launch.gnn.GNNServer``: a tick stream
(``core.taxi.synthetic_stream``-style feature maps, plus optional edge
events) flows in through ``ingest``, mutations buffer into a
``streaming.delta.GraphDelta``, and a refresh *policy* decides when the
buffer commits through the ``IncrementalEngine`` — so serving cost scales
with the churn, not the graph:

  * ``eager``             — commit on every tick (freshest embeddings,
    one incremental refresh per tick).
  * ``interval``          — commit every ``interval`` ticks (amortizes the
    k-hop frontier over several ticks' mutations).
  * ``bounded-staleness`` — commit when the buffered ticks exceed
    ``max_staleness`` or the pending dirty-node fraction exceeds
    ``max_dirty_frac`` — the knob the ROADMAP's heavy-traffic serving
    story needs: embeddings are at most that stale, and refresh work is
    triggered by how much of the graph actually moved.

``query`` is batched: ids are validated against the served embedding
table and gathered in one fancy index (inherited from ``GNNServer`` — see
``launch.gnn``). Between commits, queries serve the policy-bounded stale
embeddings; ``flush()`` forces a commit.
"""
from __future__ import annotations

import logging

import numpy as np

from repro import telemetry as tel
from repro.core.partition import ExecutionPlan
from repro.launch.gnn import GNNServer
from repro.streaming.delta import GraphDelta
from repro.streaming.incremental import IncrementalEngine, StreamingUpdate

POLICIES = ("eager", "interval", "bounded-staleness")

_LOG = logging.getLogger(__name__)


class StreamingGNNServer(GNNServer):
    """GNNServer over an IncrementalEngine with buffered ingest."""

    def __init__(self, plan: ExecutionPlan, cfg, params=None, mesh=None,
                 seed: int = 0, mode: str = "alltoall",
                 policy: str = "eager", interval: int = 4,
                 max_staleness: int = 8, max_dirty_frac: float = 0.25,
                 frontier_mode: str = "numpy"):
        assert policy in POLICIES, policy
        super().__init__(plan, cfg, params=params, mesh=mesh, seed=seed,
                         mode=mode)
        self.policy = policy
        self.interval = interval
        self.max_staleness = max_staleness
        self.max_dirty_frac = max_dirty_frac
        self.frontier_mode = frontier_mode
        self.engine = IncrementalEngine(plan, cfg, self.params, mode=mode,
                                        frontier_mode=frontier_mode)
        self.updates: list[StreamingUpdate] = []
        self.commits = 0
        self.full_refreshes = 0
        # commit observers: fn(server, update), called after every commit —
        # the online re-plan hook (repro.planner.ReplanMonitor) and load
        # harnesses subscribe here
        self.observers: list = []
        self._reset_buffers()

    def add_observer(self, fn) -> None:
        """Subscribe ``fn(server, update)`` to every committed tick.

        Observer exceptions are isolated: a raising observer is logged and
        skipped, never aborting the commit (the embeddings are already
        swapped by the time observers run)."""
        self.observers.append(fn)

    def remove_observer(self, fn) -> bool:
        """Unsubscribe a commit observer; returns False when absent."""
        try:
            self.observers.remove(fn)
            return True
        except ValueError:
            return False

    def _reset_buffers(self) -> None:
        n = self.engine.graph.n_nodes
        self._pending = GraphDelta(n)
        self._pending_ticks = 0
        self._pending_dirty = np.zeros(n, bool)
        # the stream's live view of node features: committed features plus
        # every buffered update, so tick diffs are against what the *next*
        # commit will see (a node reverting to its committed value between
        # commits still needs its revert recorded)
        self._live_feats = self.engine.graph.features.copy()

    # ---- ingest ---------------------------------------------------------

    def ingest(self, x_t=None, *, nodes=None, rows=None,
               add_edges=None, remove_edges=None) -> StreamingUpdate | None:
        """Consume one stream tick; commit per the refresh policy.

        ``x_t``: full [N, F] tick (synthetic_stream-style) — changed rows
        are diffed out automatically. ``nodes``/``rows``: sparse update of
        ``rows[i]`` at ``nodes[i]``. ``add_edges``/``remove_edges``:
        (dst, src) array pairs of edge events. Returns the
        ``StreamingUpdate`` when this tick triggered a commit, else None.
        """
        with tel.span("server.ingest", policy=self.policy):
            return self._ingest(x_t, nodes=nodes, rows=rows,
                                add_edges=add_edges,
                                remove_edges=remove_edges)

    def _ingest(self, x_t=None, *, nodes=None, rows=None,
                add_edges=None, remove_edges=None) -> StreamingUpdate | None:
        if x_t is not None:
            x_t = np.asarray(x_t, np.float32).reshape(self._live_feats.shape)
            changed = np.nonzero(np.any(x_t != self._live_feats, axis=1))[0]
            if len(changed):
                self._record_features(changed, x_t[changed])
        if nodes is not None:
            nodes = np.asarray(nodes, np.int64).reshape(-1)
            rows = np.asarray(rows, np.float32).reshape(len(nodes), -1)
            self._record_features(nodes, rows)
        if add_edges is not None:
            dst, src = add_edges
            self._pending.add_edges(dst, src)
            self._pending_dirty[np.asarray(dst, np.int64)] = True
        if remove_edges is not None:
            dst, src = remove_edges
            self._pending.remove_edges(dst, src)
            self._pending_dirty[np.asarray(dst, np.int64)] = True
        self._pending_ticks += 1
        if self._should_commit():
            return self._commit()
        return None

    def _record_features(self, nodes: np.ndarray, rows: np.ndarray) -> None:
        self._pending.update_features(nodes, rows)
        self._pending_dirty[nodes] = True
        self._live_feats[nodes] = rows

    def _should_commit(self) -> bool:
        if self.policy == "eager":
            return True
        if self.policy == "interval":
            return self._pending_ticks >= self.interval
        return (self._pending_ticks >= self.max_staleness
                or float(self._pending_dirty.mean()) >= self.max_dirty_frac)

    def flush(self) -> StreamingUpdate | None:
        """Force-commit whatever is buffered (no-op when nothing is)."""
        if self._pending_ticks or len(self._pending):
            return self._commit()
        return None

    @property
    def pending_ticks(self) -> int:
        return self._pending_ticks

    # ---- commit / refresh ----------------------------------------------

    def _commit(self) -> StreamingUpdate:
        eng = self.engine
        with tel.span("server.commit", policy=self.policy) as sp:
            if eng._acts is None or self._served_version != self.version:
                # cold start or params/plan moved: every cache level is
                # invalid
                eng.params = self.params
                upd = eng.commit_full(self._pending)
                self.full_refreshes += 1
            else:
                upd = eng.apply_delta(self._pending)
                if upd.full:
                    self.full_refreshes += 1
            sp.set(full=upd.full)
            tel.record_commit(upd, self.plan.setting)
            self._pending_ticks = 0
            self._pending_dirty[:] = False
            self._live_feats = eng.graph.features.copy()
            self.embeddings = eng.embeddings()
        self.commits += 1
        self.refreshes += 1
        self._served_version = self.version
        self.updates.append(upd)
        for fn in list(self.observers):
            # observer isolation: a raising observer must not abort the
            # commit — embeddings are already swapped; log and continue
            try:
                fn(self, upd)
            except Exception:
                _LOG.exception("commit observer %r raised; continuing", fn)
        return upd

    def refresh(self) -> float:
        """Bring served embeddings current (incremental when the caches are
        valid — the streaming analogue of GNNServer's full recompute)."""
        return self._commit().seconds

    def update_plan(self, plan: ExecutionPlan, cfg=None) -> None:
        """Swap the plan/graph wholesale: the engine and every stream
        buffer restart against the new node set."""
        super().update_plan(plan, cfg)
        self.engine = IncrementalEngine(plan, self.cfg, self.params,
                                        mode=self.mode,
                                        frontier_mode=self.frontier_mode)
        self._reset_buffers()
