"""Incremental GNN forward over an ExecutionPlan: recompute only the dirty
frontier, reuse cached per-layer activations for everything else.

``IncrementalEngine`` wraps an ``ExecutionPlan`` (any setting ×
any backend) and maintains:

  * the evolving ``Graph`` (mutated via ``streaming.delta``),
  * cached per-layer activations in the plan's owned-row layout
    ``[K, n_max, F_l]`` for levels 0..L (level 0 is the input table — for
    semi this is the tier-0-assembled region table),
  * the plan's structural tables, rebuilt in place on edge deltas with the
    *same* cluster assignment (nodes never migrate mid-stream, so the
    caches stay row-aligned; only the halo/send tables change).

Per tick, ``apply_delta`` commits the mutation buffer, expands the k-hop
dirty frontier (``streaming.frontier``), and re-runs each layer only on its
dirty rows — through the exact same per-device layer step
(``distributed.halo._layer_step``) every backend-setting combination uses,
so incremental output matches a full recompute to fp32 tolerance (the
property ``tests/test_streaming.py`` checks on all 3 × 3 combinations).
Halo inputs for dirty rows are gathered from the cached level-(l-1) owned
tables; the wire traffic a real deployment would ship for that gather —
only rows whose value changed, plus send slots structural churn newly
created — is billed by ``distributed.traffic.measure_incremental``.

Degradation to full refresh (DESIGN.md §9): bit-accurate crossbar numerics
(``cfg.numerics.ideal=False``) quantize against a *global* DAC scale
``max|Z|``, so a subset recompute would see a different scale than a full
pass and drift; the engine detects this and falls back to a full refresh
(``StreamingUpdate.full=True``) rather than serve non-reproducible
embeddings.

Dirty row counts vary every tick; to keep JIT recompilation bounded the
engine buckets the recompute batch to the next power of two (padded rows
are sliced off), so at most O(log n_max) variants per (layer, cluster
shape) ever compile.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry as tel
from repro.core.partition import (ExecutionPlan, _from_assignment,
                                  bucket_partition, build_bucketed_subgraphs,
                                  build_local_subgraphs,
                                  gather_bucketed_features)
from repro.distributed.halo import (HaloPlan, _bucket_layer, _flat_rows,
                                    _gather_halo, _layer_step,
                                    build_bucketed_halo_plan,
                                    build_halo_plan)
from repro.distributed.traffic import (StreamingTrafficReport,
                                       measure_incremental)
from repro.streaming.delta import DeltaResult, GraphDelta, apply_deltas
from repro.streaming.frontier import FrontierMasks, expand_frontier

_MIN_BUCKET = 8


def _bucket(n: int, cap: int) -> int:
    """Next power of two >= n (>= _MIN_BUCKET), capped at the table size."""
    b = _MIN_BUCKET
    while b < n:
        b <<= 1
    return min(b, cap)


def _pad_rows(rows: np.ndarray, cap: int) -> np.ndarray:
    """Bucket-pad a dirty-row batch by repeating its first row, so the
    scatter's shape — and hence its compiled executable — is reused across
    ticks (pad rows recompute the same value; the duplicate scatter is
    benign)."""
    padded = np.full(_bucket(len(rows), cap), rows[0], np.int64)
    padded[:len(rows)] = rows
    return padded


_rows_step = jax.jit(
    lambda table, nbr, wts, w, b, cfg, act:
    _layer_step(table, nbr, wts, {"w": w, "b": b}, cfg, act),
    static_argnames=("cfg", "act"))

# the activation-cache patch: the cache buffer is DONATED — the scatter's
# output aliases the input's pages, so per-tick updates mutate the
# device-resident cache in place instead of round-tripping a fresh
# allocation through the host every tick (DESIGN.md §12). Callers must
# rebind (``self._acts[..] = _scatter_rows(self._acts[..], ...)``) and
# never hold a second reference to the donated buffer.
_scatter_rows = jax.jit(
    lambda acts, c, rows, vals: acts.at[c, rows].set(vals),
    donate_argnums=(0,))


@dataclasses.dataclass
class StreamingUpdate:
    """Outcome of one committed tick."""
    frontier: FrontierMasks
    traffic: StreamingTrafficReport | None   # None for centralized
    seconds: float                           # wall-clock of the commit
    full: bool                               # True => degraded to full refresh

    @property
    def recompute_fraction(self) -> float:
        return 1.0 if self.full else self.frontier.recompute_fraction()


class IncrementalEngine:
    """Streaming counterpart of ``ExecutionPlan.make_forward``."""

    def __init__(self, plan: ExecutionPlan, cfg, params,
                 mode: str = "alltoall", frontier_mode: str = "numpy"):
        from repro.streaming.frontier import FRONTIER_MODES
        if frontier_mode not in FRONTIER_MODES:
            raise ValueError(f"unknown frontier mode {frontier_mode!r}; "
                             f"one of {FRONTIER_MODES}")
        self.plan = plan
        self.cfg = plan.gnn_config(cfg)
        self.params = params
        self.mode = mode
        self.frontier_mode = frontier_mode
        self.graph = plan.graph
        self.n_layers = len(params)
        self.sample = plan.sample
        # global padded sample of the live graph: frontier expansion +
        # the centralized runtime read the same truncated edge set
        self._gnbr, self._gwts = self.graph.neighbor_sample(self.sample)
        self._halo_plan: HaloPlan | None = (
            build_halo_plan(plan.part) if plan.part is not None else None)
        # bucketed ragged layout: values move through the bucketed flat
        # gather; the dense _halo_plan above stays the billing source of
        # truth for the traffic accountant (DESIGN.md §12)
        self._bp = plan.bucketed
        if self._bp is not None:
            self._bind_bucketed_tables()
        self._new_send: np.ndarray | None = None  # send slots churn created
        self._acts: list | None = None            # [K, n_max, F_l] per level
        #                                 (bucketed: per level a LIST of
        #                                  per-bucket [K_b, n_cap, F_l])
        self.last_update: StreamingUpdate | None = None
        self.ticks = 0
        # (layer, table_rows, padded_rows) triples seen by the dirty-rows
        # recompute — each new triple is a fresh _rows_step specialization,
        # the telemetry recompile-estimate counter's unit (DESIGN.md §14)
        self._compiled_keys: set = set()

    # ---- layout helpers -------------------------------------------------

    def _bind_bucketed_tables(self) -> None:
        self._bhalo = build_bucketed_halo_plan(self._bp)
        self._bfidx = tuple(jnp.asarray(i) for i in self._bhalo.flat_src)
        self._bfmask = tuple(jnp.asarray(m) for m in self._bhalo.halo_mask)

    @property
    def _k(self) -> int:
        return self.plan.n_clusters

    def _to_local(self, gmask: np.ndarray) -> np.ndarray:
        """[N] global bool -> [K, n_max] owned-row bool."""
        if self.plan.part is None:
            return gmask[None].copy()
        part = self.plan.part
        return gmask[part.local_nodes] & part.local_mask

    def _owned_features(self):
        """[K, n_max, F0] level-0 table (semi: the tier-0 assembled region
        tables — same rows the spoke gather produces). Bucketed plans
        return the per-bucket list instead."""
        from repro.core.partition import gather_features
        if self._bp is not None:
            return list(gather_bucketed_features(self.graph, self._bp))
        if self.plan.part is None:
            return self.graph.features[None].astype(np.float32)
        return gather_features(self.graph, self.plan.part)

    def _halo_table(self, owned: jax.Array) -> jax.Array | None:
        """[K, h_max, F] halo rows gathered from the stacked owned tables
        (the emulated exchange's value semantics; what a real deployment
        ships to keep this table fresh is billed separately)."""
        hp = self._halo_plan
        if hp is None:
            return None
        halo = owned[hp.src_cluster, hp.src_slot]
        return halo * jnp.asarray(hp.halo_mask.astype(np.float32))[..., None]

    # ---- full refresh ---------------------------------------------------

    def full_refresh(self) -> float:
        """(Re)compute every cached level from scratch; returns seconds.

        Caches are kept device-resident (jnp) so incremental ticks patch
        dirty rows in place instead of re-uploading whole tables."""
        with tel.span("engine.full_refresh"):
            return self._full_refresh_impl()

    def _full_refresh_impl(self) -> float:
        t0 = time.perf_counter()
        nbr, wts = self.plan.neighbors, self.plan.weights
        if self._bp is not None:
            xs = [jnp.asarray(f) for f in self._owned_features()]
            acts = [xs]
            nb = self._bp.n_buckets
            for l in range(self.n_layers):
                layer = self.params[l]
                act = l < self.n_layers - 1 or self.cfg.final_activation
                flat = _flat_rows(*acts[l])
                acts.append([
                    _bucket_layer(acts[l][b],
                                  _gather_halo(flat, self._bfidx[b],
                                               self._bfmask[b]),
                                  jnp.asarray(nbr[b]), jnp.asarray(wts[b]),
                                  layer["w"], layer["b"], cfg=self.cfg,
                                  act=act)
                    for b in range(nb)])
            jax.block_until_ready(acts[-1])
            self._acts = acts
            return time.perf_counter() - t0
        x = jnp.asarray(self._owned_features())
        acts = [x]
        for l in range(self.n_layers):
            layer = self.params[l]
            act = l < self.n_layers - 1 or self.cfg.final_activation
            halo = self._halo_table(acts[l])
            outs = []
            for c in range(self._k):
                table = (acts[l][c] if halo is None
                         else jnp.concatenate([acts[l][c], halo[c]], axis=0))
                outs.append(_rows_step(table, jnp.asarray(nbr[c]),
                                       jnp.asarray(wts[c]), layer["w"],
                                       layer["b"], self.cfg, act))
            acts.append(jnp.stack(outs))
        jax.block_until_ready(acts[-1])
        self._acts = acts
        return time.perf_counter() - t0

    def _sync_plan_feats(self, dirty0_local: np.ndarray | None = None
                         ) -> None:
        """The engine mutates the shared ExecutionPlan in place; keep its
        ``feats`` tables consistent with the live graph so a later
        ``plan.make_forward`` (or a fresh server on the same plan) sees
        current features. ``dirty0_local`` patches only mutated rows; None
        rebuilds wholesale."""
        g, plan = self.graph, self.plan
        # feature-only commits never route through _rebuild_structure, so
        # the live graph must be re-bound here too — a consumer reading
        # plan.graph (e.g. a re-planner building a replacement plan from
        # it) would otherwise see cold-start features forever
        plan.graph = g
        if plan.part is None:
            plan.feats = g.features[None]                # view, O(1)
            return
        if plan.bucketed is not None and plan.setting != "semi":
            bp = plan.bucketed
            if dirty0_local is None:
                plan.feats = gather_bucketed_features(g, bp)
                return
            for c in range(self._k):
                rows = np.nonzero(dirty0_local[c])[0]
                if len(rows):
                    plan.feats[bp.bucket_of[c]][bp.index_in[c], rows] = \
                        g.features[plan.part.local_nodes[c][rows]]
            return
        if plan.setting == "semi":
            hier = plan.hier
            if dirty0_local is None:
                from repro.core.partition import gather_spoke_features
                plan.feats = gather_spoke_features(g, hier)
                return
            for r in range(self._k):
                rows = np.nonzero(dirty0_local[r])[0]
                if len(rows):
                    plan.feats[r, hier.gather_spoke[r, rows],
                               hier.gather_slot[r, rows]] = \
                        g.features[plan.part.local_nodes[r][rows]]
            return
        if dirty0_local is None:
            from repro.core.partition import gather_features
            plan.feats = gather_features(g, plan.part)
            return
        for c in range(self._k):
            rows = np.nonzero(dirty0_local[c])[0]
            if len(rows):
                plan.feats[c][rows] = \
                    g.features[plan.part.local_nodes[c][rows]]

    # ---- structural rebuild --------------------------------------------

    def _rebuild_structure(self) -> None:
        """Re-derive the plan's tables from the mutated graph, keeping the
        node->cluster assignment (owned rows stay put; halo/send tables and
        the global sample change)."""
        g = self.graph
        plan = self.plan
        self._gnbr, self._gwts = g.neighbor_sample(self.sample)
        plan.graph = g
        if plan.part is None:
            plan.neighbors = self._gnbr[None]
            plan.weights = self._gwts[None]
            return
        part = _from_assignment(g, plan.part.assignment, self._k,
                                sample=self.sample)
        old = self._halo_plan
        new = build_halo_plan(part)
        self._new_send = _new_send_slots(old, new)
        self._halo_plan = new
        plan.part = part
        if self._bp is not None:
            # re-bucket with the previous grouping and never-shrinking caps
            # (same assignment => same cluster sizes => same groups), so
            # the cached activations keep their shapes and only the
            # halo/neighbor tables retrace — and only when a cap grew
            bp = bucket_partition(part, g, self.sample, like=self._bp)
            nbrs, wtss = build_bucketed_subgraphs(g, bp)
            self._bp = bp
            plan.bucketed = bp
            self._bind_bucketed_tables()
            plan.sub = None
            plan.neighbors = nbrs
            plan.weights = wtss
        else:
            sub = build_local_subgraphs(g, part, self.sample)
            plan.sub = sub
            plan.neighbors = sub.neighbors
            plan.weights = sub.weights
        if plan.hier is not None:
            plan.hier = dataclasses.replace(plan.hier, region=part)

    # ---- incremental tick ----------------------------------------------

    def apply_delta(self, delta: GraphDelta) -> StreamingUpdate:
        """Commit a mutation buffer and refresh only the dirty frontier.

        The buffer is cleared on success. Requires a prior ``full_refresh``
        (the caches must exist before they can be patched).
        """
        if self._acts is None:
            raise RuntimeError("call full_refresh() before apply_delta()")
        t0 = time.perf_counter()
        res = apply_deltas(self.graph, delta)
        self.graph = res.graph
        if res.structure_dirty.any():
            self._rebuild_structure()
        update = self._refresh_dirty(res, t0)
        delta.clear()
        self.ticks += 1
        self.last_update = update
        return update

    def _refresh_dirty(self, res: DeltaResult, t0: float) -> StreamingUpdate:
        l_total = self.n_layers
        fr = expand_frontier(self._gnbr, self._gwts, res.feature_dirty,
                             res.structure_dirty, l_total,
                             mode=self.frontier_mode)
        if not self.cfg.numerics.ideal:
            # global DAC scale couples every row — subset recompute would
            # quantize against a stale max|Z| (DESIGN.md §9): degrade
            self._sync_plan_feats()
            secs = self.full_refresh()
            self._new_send = None
            return StreamingUpdate(fr, self._full_traffic(), secs, full=True)
        dirty_locals = np.stack([self._to_local(fr.masks[l])
                                 for l in range(l_total + 1)])
        self._note_frontier(fr, dirty_locals)
        # level 0: patch mutated feature rows into the cached input table
        # (and the shared plan's feats tables, which track the live graph)
        self._sync_plan_feats(dirty_locals[0])
        if dirty_locals[0].any():
            part = self.plan.part
            for c in range(self._k):
                rows = np.nonzero(dirty_locals[0][c])[0]
                if not len(rows):
                    continue
                padded = _pad_rows(rows, dirty_locals.shape[2])
                ids = padded if part is None else part.local_nodes[c][padded]
                vals = jnp.asarray(self.graph.features[ids])
                if self._bp is not None:
                    b, j = int(self._bp.bucket_of[c]), \
                        int(self._bp.index_in[c])
                    self._acts[0][b] = _scatter_rows(
                        self._acts[0][b], j, jnp.asarray(padded), vals)
                else:
                    self._acts[0] = _scatter_rows(
                        self._acts[0], c, jnp.asarray(padded), vals)
        if self._bp is not None:
            self._refresh_dirty_bucketed(dirty_locals, l_total)
        else:
            self._refresh_dirty_dense(dirty_locals, l_total)
        jax.block_until_ready(self._acts[-1])
        traffic = None
        if self._halo_plan is not None:
            traffic = measure_incremental(
                self.plan, self._halo_plan, dirty_locals, self.cfg,
                mode=self.mode, new_send=self._new_send)
        self._new_send = None
        return StreamingUpdate(fr, traffic, time.perf_counter() - t0,
                               full=False)

    def _note_frontier(self, fr: FrontierMasks,
                       dirty_locals: np.ndarray) -> None:
        """Dirty-fraction / cache-reuse accounting for one tick."""
        reg = tel.get_registry()
        if not reg.enabled:
            return
        recomputed = int(dirty_locals[1:].sum())
        owned = (int(self.plan.part.local_mask.sum())
                 if self.plan.part is not None else self.graph.n_nodes)
        reg.counter("streaming.rows_recomputed").inc(recomputed)
        reg.counter("streaming.rows_cached").inc(
            max(self.n_layers * owned - recomputed, 0))
        reg.gauge("streaming.dirty_fraction").set(
            float(fr.recompute_fraction()))

    def _note_compile(self, key: tuple) -> None:
        """Count first-seen (layer, table_rows, padded_rows) shape triples —
        each is one expected _rows_step JIT specialization."""
        if key not in self._compiled_keys:
            self._compiled_keys.add(key)
            tel.counter("streaming.recompile_estimate").inc()

    def _refresh_dirty_dense(self, dirty_locals: np.ndarray,
                             l_total: int) -> None:
        tracer = tel.get_tracer()
        nbr, wts = self.plan.neighbors, self.plan.weights
        n_max = dirty_locals.shape[2]
        for l in range(l_total):
            layer = self.params[l]
            act = l < l_total - 1 or self.cfg.final_activation
            d = dirty_locals[l + 1]
            if not d.any():
                continue
            hp = self._halo_plan
            for c in range(self._k):
                rows = np.nonzero(d[c])[0]
                if not len(rows):
                    continue
                padded = _pad_rows(rows, d.shape[1])
                sub_nbr, sub_wts = nbr[c][padded], wts[c][padded]
                table = self._acts[l][c]
                if hp is not None and (sub_nbr >= n_max).any():
                    # only pay the halo gather when a dirty row reads one
                    with tracer.span("halo.gather", layer=l, cluster=c):
                        halo = (self._acts[l][hp.src_cluster[c],
                                              hp.src_slot[c]]
                                * jnp.asarray(hp.halo_mask[c].astype(
                                    np.float32))[:, None])
                        table = jnp.concatenate([table, halo], axis=0)
                self._note_compile((l, int(table.shape[0]), len(padded)))
                with tracer.span("halo.mvm", layer=l, cluster=c,
                                 rows=len(rows)):
                    out = _rows_step(table, jnp.asarray(sub_nbr),
                                     jnp.asarray(sub_wts),
                                     layer["w"], layer["b"], self.cfg, act)
                with tracer.span("cache.scatter", layer=l + 1, cluster=c):
                    self._acts[l + 1] = _scatter_rows(
                        self._acts[l + 1], c, jnp.asarray(padded), out)

    def _refresh_dirty_bucketed(self, dirty_locals: np.ndarray,
                                l_total: int) -> None:
        """Per-bucket dirty-row patch: same dirty-row indices as the dense
        layout (owned rows are the members prefix in both), halo values via
        the bucketed flat gather, caches patched with the donated scatter."""
        bp = self._bp
        tracer = tel.get_tracer()
        nbrs, wtss = self.plan.neighbors, self.plan.weights
        for l in range(l_total):
            layer = self.params[l]
            act = l < l_total - 1 or self.cfg.final_activation
            d = dirty_locals[l + 1]
            if not d.any():
                continue
            flat = None
            for c in range(self._k):
                rows = np.nonzero(d[c])[0]
                if not len(rows):
                    continue
                b, j = int(bp.bucket_of[c]), int(bp.index_in[c])
                padded = _pad_rows(rows, bp.n_caps[b])
                sub_nbr = nbrs[b][j][padded]
                sub_wts = wtss[b][j][padded]
                table = self._acts[l][b][j]
                if (sub_nbr >= bp.n_caps[b]).any():
                    # only pay the flat build + halo gather when a dirty
                    # row actually reads a halo slot this layer
                    with tracer.span("halo.gather", layer=l, bucket=b,
                                     cluster=c):
                        if flat is None:
                            flat = _flat_rows(*self._acts[l])
                        halo = _gather_halo(flat, self._bfidx[b][j],
                                            self._bfmask[b][j])
                        table = jnp.concatenate([table, halo], axis=0)
                self._note_compile((l, b, int(table.shape[0]), len(padded)))
                with tracer.span("halo.mvm", layer=l, bucket=b, cluster=c,
                                 rows=len(rows)):
                    out = _rows_step(table, jnp.asarray(sub_nbr),
                                     jnp.asarray(sub_wts),
                                     layer["w"], layer["b"], self.cfg, act)
                with tracer.span("cache.scatter", layer=l + 1, bucket=b):
                    self._acts[l + 1][b] = _scatter_rows(
                        self._acts[l + 1][b], j, jnp.asarray(padded), out)

    def commit_full(self, delta: GraphDelta | None = None) -> StreamingUpdate:
        """Apply a buffer (optional) and rebuild every cache level — the
        full-refresh path param swaps, cold starts, and the bit-accurate
        degradation route through. Unlike ``apply_delta`` it needs no
        existing caches."""
        t0 = time.perf_counter()
        n = self.graph.n_nodes
        fd = np.zeros(n, bool)
        sd = np.zeros(n, bool)
        if delta is not None and len(delta):
            res = apply_deltas(self.graph, delta)
            self.graph = res.graph
            if res.structure_dirty.any():
                self._rebuild_structure()
            fd, sd = res.feature_dirty, res.structure_dirty
            delta.clear()
            self._sync_plan_feats()
        self.full_refresh()
        fr = expand_frontier(self._gnbr, self._gwts, fd, sd, self.n_layers,
                             mode=self.frontier_mode)
        self._new_send = None
        self.ticks += 1
        self.last_update = StreamingUpdate(
            fr, self._full_traffic(), time.perf_counter() - t0, full=True)
        return self.last_update

    def _full_traffic(self) -> StreamingTrafficReport | None:
        """Per-layer billing of a full refresh (the degraded path ships
        everything every layer)."""
        if self._halo_plan is None:
            return None
        part = self.plan.part
        all_dirty = np.stack([part.local_mask] * (self.n_layers + 1))
        return measure_incremental(self.plan, self._halo_plan, all_dirty,
                                   self.cfg, mode=self.mode, new_send=None)

    # ---- outputs --------------------------------------------------------

    def embeddings(self) -> np.ndarray:
        """[N, out_dim] current embeddings in global node order."""
        if self._acts is None:
            raise RuntimeError("call full_refresh() first")
        return self.plan.scatter(self._acts[-1])


def _new_send_slots(old: HaloPlan, new: HaloPlan) -> np.ndarray | None:
    """Bool mask over ``new``'s send table marking slots absent from
    ``old`` — rows an alltoall must ship after structural churn even when
    their source value is clean (the peer has never cached them)."""
    if old is None:
        return None
    base = np.int64(max(int(old.send_slot.max(initial=0)),
                        int(new.send_slot.max(initial=0))) + 1)

    def keys(plan: HaloPlan) -> np.ndarray:
        k = plan.send_slot.shape[0]
        c = np.arange(k, dtype=np.int64)[:, None, None]
        j = np.arange(k, dtype=np.int64)[None, :, None]
        return (c * k + j) * base + plan.send_slot

    have = keys(old)[old.send_mask]
    return new.send_mask & ~np.isin(keys(new), have)
