"""Instrumentation glue between telemetry and the serving stack.

``instrument_forward`` wraps the callable ``ExecutionPlan.make_forward``
returns.  It cannot time *inside* the jitted forward (spans in traced code
would fire once, at trace time), so it does three things at the Python
boundary instead:

  1. opens a ``plan.forward`` root span tagged with the plan's
     setting/backend/clusters and closes it only after ``device_sync`` —
     async dispatch is billed to the span that caused it;
  2. bills wire bytes onto zero-duration *accounting spans* computed from
     the plan's own ``measured_traffic`` report — the same executed
     send/recv tables ``distributed.halo`` hands to the exchange.  Span-tree
     byte totals therefore equal ``TrafficReport.total_bytes()`` exactly,
     by construction (the obs_overhead gate asserts this per setting);
  3. increments the ``halo.shipped_bytes`` counter so byte totals survive
     span-ring eviction.

The traffic report is computed lazily on the first *traced* call and
cached — with telemetry disabled the wrapper is a flag check plus the
undecorated forward.
"""

from __future__ import annotations

from typing import Any, Callable, Dict

from . import get_registry, get_tracer

__all__ = ["instrument_forward", "record_commit", "record_streaming_traffic"]


def instrument_forward(plan, cfg, mode: str, fwd: Callable) -> Callable:
    """Wrap a plan forward with span + exact bytes accounting."""
    state: Dict[str, Any] = {}

    def run(params):
        tracer = get_tracer()
        if not tracer.enabled:
            return fwd(params)
        billing = state.get("billing")
        if billing is None:
            rep = plan.measured_traffic(cfg, mode=mode)
            tier0 = int(rep.tier0_bytes().sum())
            per_layer = [int(b) for b in rep.tier1_bytes().sum(axis=1)]
            billing = state["billing"] = (tier0, per_layer, tier0 + sum(per_layer))
        tier0, per_layer, total = billing
        with tracer.span("plan.forward", setting=plan.setting,
                         backend=plan.backend, clusters=plan.n_clusters):
            if tier0:
                with tracer.span("halo.tier0_upload") as s0:
                    s0.add_bytes(tier0)
            out = fwd(params)
            for layer, nbytes in enumerate(per_layer):
                if nbytes:
                    with tracer.span("halo.exchange", layer=layer) as sl:
                        sl.add_bytes(nbytes)
            if total:
                get_registry().counter("halo.shipped_bytes",
                                       setting=plan.setting).inc(total)
            tracer.device_sync(out, name="plan.forward.sync")
        return out

    return run


def record_streaming_traffic(traffic, setting: str) -> None:
    """Bill one incremental tick's wire bytes (counter + current span)."""
    reg = get_registry()
    if not reg.enabled or traffic is None:
        return
    total = int(traffic.total_bytes())
    reg.counter("streaming.shipped_bytes", setting=setting).inc(total)
    cur = get_tracer().current()
    if cur is not None:
        cur.add_bytes(total)


def record_commit(update, setting: str) -> None:
    """Fold one StreamingUpdate's accounting into the registry."""
    reg = get_registry()
    if not reg.enabled:
        return
    reg.counter("server.commits").inc()
    if update.full:
        reg.counter("server.full_refreshes").inc()
    reg.histogram("server.commit_seconds").observe(float(update.seconds))
    reg.gauge("streaming.recompute_fraction").set(
        float(update.recompute_fraction))
    record_streaming_traffic(update.traffic, setting)
