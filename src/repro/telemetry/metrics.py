"""Metrics registry: counters, gauges, log-bucket histograms, audit events.

Metric instances are created on first use and keyed by ``(name, labels)``;
repeated ``registry.counter("halo.shipped_bytes", setting="semi")`` calls
return the same object, so hot paths may look metrics up per call without
caching handles.  Every mutation is gated on ``registry.enabled`` so a
disabled registry costs one flag check per operation.

Histograms use fixed log-spaced buckets (default 4/decade over
1 µs … 100 s — wide enough for both a jitted query dispatch and a cold
compile) and report p50/p95/p99 by log-linear interpolation inside the
matched bucket.  Fixed buckets keep ``observe`` O(log n_buckets) with zero
allocation, and make histograms mergeable across exports.

Exporters: ``export_jsonl`` (one JSON object per metric/event line) and
``prometheus_text`` (text exposition format; histograms emit cumulative
``_bucket{le=...}`` lines).
"""

from __future__ import annotations

import json
import math
import re
from bisect import bisect_left
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "default_buckets"]

_LabelKey = Tuple[str, Tuple[Tuple[str, Any], ...]]


def default_buckets(lo: float = 1e-6, hi: float = 100.0,
                    per_decade: int = 4) -> Tuple[float, ...]:
    """Fixed log-spaced bucket upper bounds covering [lo, hi]."""
    n_dec = math.log10(hi / lo)
    n = int(round(n_dec * per_decade))
    return tuple(lo * 10.0 ** (i / per_decade) for i in range(n + 1))


_DEFAULT_BOUNDS = default_buckets()


class _Metric:
    __slots__ = ("name", "labels", "_reg")

    def __init__(self, name: str, labels: Tuple[Tuple[str, Any], ...],
                 reg: "MetricsRegistry"):
        self.name = name
        self.labels = labels
        self._reg = reg


class Counter(_Metric):
    """Monotonically increasing value."""

    __slots__ = ("value",)

    def __init__(self, name, labels, reg):
        super().__init__(name, labels, reg)
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if self._reg.enabled:
            self.value += n


class Gauge(_Metric):
    """Last-write-wins value."""

    __slots__ = ("value",)

    def __init__(self, name, labels, reg):
        super().__init__(name, labels, reg)
        self.value = 0.0

    def set(self, v: float) -> None:
        if self._reg.enabled:
            self.value = float(v)


class Histogram(_Metric):
    """Fixed log-spaced-bucket histogram with interpolated percentiles."""

    __slots__ = ("bounds", "counts", "count", "total", "vmin", "vmax")

    def __init__(self, name, labels, reg, bounds: Tuple[float, ...] = _DEFAULT_BOUNDS):
        super().__init__(name, labels, reg)
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)  # +1 overflow bucket
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def observe(self, v: float) -> None:
        if not self._reg.enabled:
            return
        self.counts[bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    def percentile(self, q: float) -> float:
        """q in [0, 1]; log-linear interpolation inside the matched bucket."""
        if self.count == 0:
            return 0.0
        rank = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            prev = cum
            cum += c
            if cum >= rank:
                # Bucket i spans (lower, upper]; interpolate in log space.
                if i >= len(self.bounds):  # overflow bucket
                    return self.vmax
                upper = self.bounds[i]
                lower = self.bounds[i - 1] if i > 0 else upper / 10.0
                frac = (rank - prev) / c
                lo = max(lower, self.vmin if self.vmin > 0 else lower)
                hi = min(upper, self.vmax) if self.vmax >= lo else upper
                if lo <= 0 or hi <= lo:
                    return hi
                return lo * (hi / lo) ** frac
        return self.vmax  # pragma: no cover - unreachable

    def quantiles(self) -> Dict[str, float]:
        return {
            "p50": self.percentile(0.50),
            "p95": self.percentile(0.95),
            "p99": self.percentile(0.99),
        }

    def summary(self) -> Dict[str, float]:
        d: Dict[str, float] = {
            "count": self.count,
            "sum": self.total,
            "mean": self.total / self.count if self.count else 0.0,
            "min": self.vmin if self.count else 0.0,
            "max": self.vmax if self.count else 0.0,
        }
        d.update(self.quantiles())
        return d


class _NullMetric:
    """Shared no-op metric returned by a disabled registry.

    Handles are looked up per call site, not cached, so a metric fetched
    while disabled simply resolves to the real instance after ``enable()``.
    """

    __slots__ = ()
    value = 0.0
    count = 0
    total = 0.0

    def inc(self, n: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def percentile(self, q: float) -> float:
        return 0.0

    def quantiles(self) -> Dict[str, float]:
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0}

    def summary(self) -> Dict[str, float]:
        return {"count": 0, "sum": 0.0, "mean": 0.0, "min": 0.0, "max": 0.0,
                **self.quantiles()}


_NULL_METRIC = _NullMetric()


def _label_key(name: str, labels: Dict[str, Any]) -> _LabelKey:
    return (name, tuple(sorted(labels.items())))


def _label_str(labels: Tuple[Tuple[str, Any], ...]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + inner + "}"


_PROM_SANITIZE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    return _PROM_SANITIZE.sub("_", name)


class MetricsRegistry:
    """Holds all metric instances plus an ordered audit-event log."""

    def __init__(self, enabled: bool = False, max_events: int = 4096):
        self.enabled = bool(enabled)
        self.max_events = int(max_events)
        self._metrics: Dict[_LabelKey, _Metric] = {}
        self.events: List[Dict[str, Any]] = []

    # -- creation / lookup ------------------------------------------------
    def _get(self, cls, name: str, labels: Dict[str, Any], **kw):
        if not self.enabled:
            return _NULL_METRIC
        key = _label_key(name, labels)
        m = self._metrics.get(key)
        if m is None:
            m = cls(name, key[1], self, **kw)
            self._metrics[key] = m
        elif not isinstance(m, cls):
            raise ValueError(
                f"metric {name!r}{dict(key[1])} already registered as "
                f"{type(m).__name__}, not {cls.__name__}"
            )
        return m

    def counter(self, name: str, **labels: Any) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels: Any) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, bounds: Optional[Tuple[float, ...]] = None,
                  **labels: Any) -> Histogram:
        if bounds is None:
            return self._get(Histogram, name, labels)
        return self._get(Histogram, name, labels, bounds=bounds)

    def event(self, name: str, **fields: Any) -> None:
        """Append a structured audit record (planner decisions, replans)."""
        if not self.enabled:
            return
        if len(self.events) >= self.max_events:
            del self.events[: self.max_events // 2]
        self.events.append({"event": name, **fields})

    # -- reporting ---------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """JSON-ready view: counter/gauge totals + histogram summaries."""
        counters: Dict[str, float] = {}
        gauges: Dict[str, float] = {}
        hists: Dict[str, Dict[str, float]] = {}
        for (name, labels), m in sorted(self._metrics.items()):
            key = name + _label_str(labels)
            if isinstance(m, Counter):
                counters[key] = m.value
            elif isinstance(m, Gauge):
                gauges[key] = m.value
            elif isinstance(m, Histogram):
                hists[key] = m.summary()
        return {
            "counters": counters,
            "gauges": gauges,
            "histograms": hists,
            "n_events": len(self.events),
        }

    def export_jsonl(self, path: str) -> int:
        """One JSON line per metric and per event; returns line count."""
        n = 0
        with open(path, "w") as fh:
            for (name, labels), m in sorted(self._metrics.items()):
                rec: Dict[str, Any] = {"name": name, "labels": dict(labels)}
                if isinstance(m, Histogram):
                    rec["type"] = "histogram"
                    rec.update(m.summary())
                else:
                    rec["type"] = type(m).__name__.lower()
                    rec["value"] = m.value
                fh.write(json.dumps(rec, default=str) + "\n")
                n += 1
            for ev in self.events:
                fh.write(json.dumps({"type": "event", **ev}, default=str) + "\n")
                n += 1
        return n

    def prometheus_text(self) -> str:
        """Prometheus text exposition format (counters/gauges/histograms)."""
        lines: List[str] = []
        seen_types: Dict[str, str] = {}
        for (name, labels), m in sorted(self._metrics.items()):
            pname = _prom_name(name)
            lstr = _label_str(labels)
            if isinstance(m, Counter):
                if seen_types.setdefault(pname, "counter") == "counter":
                    if f"# TYPE {pname} counter" not in lines:
                        lines.append(f"# TYPE {pname} counter")
                lines.append(f"{pname}{lstr} {m.value:g}")
            elif isinstance(m, Gauge):
                if f"# TYPE {pname} gauge" not in lines:
                    lines.append(f"# TYPE {pname} gauge")
                lines.append(f"{pname}{lstr} {m.value:g}")
            elif isinstance(m, Histogram):
                if f"# TYPE {pname} histogram" not in lines:
                    lines.append(f"# TYPE {pname} histogram")
                cum = 0
                base = dict(labels)
                for bound, c in zip(m.bounds, m.counts):
                    cum += c
                    ls = _label_str(tuple(sorted({**base, "le": f"{bound:g}"}.items())))
                    lines.append(f"{pname}_bucket{ls} {cum}")
                ls = _label_str(tuple(sorted({**base, "le": "+Inf"}.items())))
                lines.append(f"{pname}_bucket{ls} {m.count}")
                lines.append(f"{pname}_sum{lstr} {m.total:g}")
                lines.append(f"{pname}_count{lstr} {m.count}")
        return "\n".join(lines) + ("\n" if lines else "")

    def reset(self) -> None:
        self._metrics.clear()
        self.events.clear()
