"""Runtime telemetry: span tracing, metrics, drift accounting (DESIGN.md §14).

One process-wide tracer + registry pair, disabled by default.  Hot paths
instrument unconditionally —

    from repro import telemetry as tel
    with tel.span("server.commit", policy=policy):
        ...
    tel.counter("streaming.rows_recomputed").inc(rows)

— and pay one flag check per call when telemetry is off (``span`` returns a
shared null singleton; metric mutations no-op).  ``enable()`` turns on span
trees, span-duration histograms (``span_seconds{span=...}``), counters,
gauges, audit events, and the ``device_sync`` billing points.

Exporters: ``export_metrics(path)`` (JSONL), ``export_trace(path)``
(JSONL span trees), ``prometheus_text()``.  ``snapshot()`` returns the
JSON-ready summary ``benchmarks/run.py`` embeds under each record's
``info`` key.

The predicted-vs-measured layer lives in :mod:`repro.telemetry.drift`
(:class:`CommitSample` / :class:`DriftLedger`) and feeds
``planner.replan.ReplanMonitor`` through a typed interface.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

from .metrics import Counter, Gauge, Histogram, MetricsRegistry, default_buckets
from .spans import NULL_SPAN, Span, SpanTracer

_REGISTRY = MetricsRegistry()
_TRACER = SpanTracer(registry=_REGISTRY)


def get_tracer() -> SpanTracer:
    return _TRACER


def get_registry() -> MetricsRegistry:
    return _REGISTRY


def enabled() -> bool:
    return _TRACER.enabled


def enable(xla_annotations: bool = False) -> None:
    """Turn telemetry on process-wide (spans, metrics, sync points)."""
    _TRACER.enabled = True
    _TRACER.xla_annotations = bool(xla_annotations)
    _REGISTRY.enabled = True


def disable() -> None:
    _TRACER.enabled = False
    _TRACER.xla_annotations = False
    _REGISTRY.enabled = False


def reset() -> None:
    """Drop all recorded spans/metrics/events (enabled flag unchanged)."""
    _TRACER.reset()
    _REGISTRY.reset()


# -- hot-path API (delegates to the process singletons) --------------------

def span(name: str, **attrs: Any):
    return _TRACER.span(name, **attrs)


def device_sync(x: Any, name: str = "device_sync") -> Any:
    return _TRACER.device_sync(x, name=name)


def counter(name: str, **labels: Any) -> Counter:
    return _REGISTRY.counter(name, **labels)


def gauge(name: str, **labels: Any) -> Gauge:
    return _REGISTRY.gauge(name, **labels)


def histogram(name: str, bounds=None, **labels: Any) -> Histogram:
    return _REGISTRY.histogram(name, bounds=bounds, **labels)


def event(name: str, **fields: Any) -> None:
    _REGISTRY.event(name, **fields)


# -- reporting -------------------------------------------------------------

def snapshot() -> Dict[str, Any]:
    """JSON-ready state: span summary + metric totals + event count."""
    out = _REGISTRY.snapshot()
    out["spans"] = _TRACER.summary()
    return out


def export_metrics(path: str) -> int:
    """Write all metrics + audit events as JSONL; returns line count."""
    return _REGISTRY.export_jsonl(path)


def export_trace(path: str) -> int:
    """Write retained span trees as JSONL; returns tree count."""
    return _TRACER.export_trace(path)


def prometheus_text() -> str:
    return _REGISTRY.prometheus_text()


# Imported last: instrument.py pulls get_tracer/get_registry from here.
from .drift import CommitSample, DriftLedger, commit_sample          # noqa: E402
from .instrument import (instrument_forward, record_commit,          # noqa: E402
                         record_streaming_traffic)

__all__ = [
    "Span", "SpanTracer", "NULL_SPAN",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "default_buckets",
    "CommitSample", "DriftLedger", "commit_sample",
    "get_tracer", "get_registry", "enabled", "enable", "disable", "reset",
    "span", "device_sync", "counter", "gauge", "histogram", "event",
    "snapshot", "export_metrics", "export_trace", "prometheus_text",
    "instrument_forward", "record_commit", "record_streaming_traffic",
]
