"""Typed predicted-vs-measured drift accounting (DESIGN.md §14).

The planner's recommendation is a prediction (costmodel latency,
traffic-evaluator bytes); serving is the measurement.  This module is the
typed boundary between the two: a :class:`CommitSample` captures what one
committed tick actually did, and a :class:`DriftLedger` accumulates samples,
maintains the early-commit baseline, and answers the two drift questions —
"is recent latency out of band?" and "are recent bytes out of band?" —
that ``planner.replan.ReplanMonitor`` used to compute from raw float lists.

Keeping the ledger here (rather than in planner/) means the serving stack
can do drift *accounting* with telemetry alone, and the planner layer only
adds the *decision* (re-plan + swap) on top.
"""

from __future__ import annotations

import dataclasses
import statistics
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["CommitSample", "commit_sample", "DriftLedger"]


@dataclasses.dataclass(frozen=True)
class CommitSample:
    """What one committed tick measurably did.

    ``full`` marks cold starts / param swaps / bit-accurate degradations —
    ledgers skip these (they are not representative ticks; folding their
    wall-clock into the baseline would mask real drift) but count them.
    """

    seconds: float                    # commit wall-clock
    shipped_bytes: float              # incremental wire traffic this commit
    churn_frac: float                 # level-0 dirty frontier fraction
    full: bool = False                # full refresh (skipped by ledgers)
    queries: int = 0                  # lookups served since last commit
    policy: Optional[str] = None      # refresh policy the server ran under


def commit_sample(server, update) -> CommitSample:
    """Build a :class:`CommitSample` from a ``StreamingUpdate`` commit."""
    traffic = getattr(update, "traffic", None)
    frontier = getattr(update, "frontier", None)
    return CommitSample(
        seconds=float(update.seconds),
        shipped_bytes=float(traffic.total_bytes()) if traffic is not None else 0.0,
        churn_frac=float(frontier.masks[0].mean()) if frontier is not None else 0.0,
        full=bool(update.full),
        policy=getattr(server, "policy", None),
    )


class DriftLedger:
    """Rolling predicted-vs-measured ledger over commit samples.

    ``window`` controls both the baseline (median of the first ``window``
    samples) and the recency median (last ``window`` samples); drift
    checks return ``None`` until ``2 * window`` samples exist so baseline
    and recent windows never overlap.

    ``predicted_seconds`` / ``predicted_bytes`` are the model-side
    references when the planner priced them; the latency check still
    anchors to the measured early baseline (modeled crossbar/radio time
    and host wall-clock are different clocks) but both predictions are
    surfaced in :meth:`report` so the model error itself is observable.
    """

    def __init__(self, window: int = 8,
                 predicted_seconds: Optional[float] = None,
                 predicted_bytes: Optional[float] = None):
        self.window = max(int(window), 2)
        self.predicted_seconds = predicted_seconds
        self.predicted_bytes = predicted_bytes
        self.seconds: List[float] = []
        self.bytes: List[float] = []
        self.churn: List[float] = []
        self.policy: Optional[str] = None
        self.full_skipped = 0
        self._baseline_s: Optional[float] = None

    # ---- accumulation ---------------------------------------------------

    def record(self, sample: CommitSample) -> bool:
        """Fold one sample in; returns False when skipped (full refresh)."""
        if sample.full:
            self.full_skipped += 1
            return False
        self.seconds.append(float(sample.seconds))
        self.bytes.append(float(sample.shipped_bytes))
        self.churn.append(float(sample.churn_frac))
        if sample.policy is not None:
            self.policy = sample.policy
        if self._baseline_s is None and len(self.seconds) >= self.window:
            self._baseline_s = statistics.median(self.seconds[: self.window])
        return True

    @property
    def n(self) -> int:
        return len(self.seconds)

    @property
    def baseline_s(self) -> Optional[float]:
        return self._baseline_s

    # ---- drift checks ---------------------------------------------------

    def latency_drift(self, tol: float) -> Optional[Tuple[float, float]]:
        """(measured, reference) when the recent latency median exceeds
        ``tol`` x the early-commit baseline, else None."""
        if len(self.seconds) < 2 * self.window or not self._baseline_s:
            return None
        recent = statistics.median(self.seconds[-self.window:])
        if recent > tol * self._baseline_s:
            return (recent, self._baseline_s)
        return None

    def bytes_drift(self, tol: float,
                    reference: Optional[float] = None
                    ) -> Optional[Tuple[float, float]]:
        """(measured, reference) when recent shipped bytes exceed ``tol`` x
        the reference — caller-supplied (e.g. predicted bytes_per_tick
        scaled to the commit cadence), else ``predicted_bytes``, else the
        early-commit median."""
        if len(self.bytes) < 2 * self.window:
            return None
        ref = reference if reference else self.predicted_bytes
        if not ref:
            ref = statistics.median(self.bytes[: self.window])
        recent = statistics.median(self.bytes[-self.window:])
        if ref and recent > tol * ref:
            return (recent, ref)
        return None

    # ---- reporting ------------------------------------------------------

    def median_recent(self, series: List[float]) -> float:
        return statistics.median(series[-self.window:]) if series else 0.0

    def report(self) -> Dict[str, Any]:
        """Predicted-vs-measured accounting snapshot (JSON-ready)."""
        out: Dict[str, Any] = {
            "commits": self.n,
            "full_skipped": self.full_skipped,
            "baseline_s": self._baseline_s,
            "recent_s": self.median_recent(self.seconds),
            "recent_bytes": self.median_recent(self.bytes),
            "recent_churn": self.median_recent(self.churn),
        }
        if self.predicted_seconds:
            out["predicted_s"] = self.predicted_seconds
            if out["recent_s"]:
                out["latency_vs_predicted"] = out["recent_s"] / self.predicted_seconds
        if self.predicted_bytes:
            out["predicted_bytes"] = self.predicted_bytes
            if out["recent_bytes"]:
                out["bytes_vs_predicted"] = out["recent_bytes"] / self.predicted_bytes
        return out

    def reset(self) -> None:
        """Restart accounting (e.g. after a plan swap: old baselines
        describe the old plan)."""
        self.seconds.clear()
        self.bytes.clear()
        self.churn.clear()
        self.full_skipped = 0
        self._baseline_s = None
