"""Low-overhead span tracer producing nested span trees (DESIGN.md §14).

A span is one timed node: ``with tracer.span("halo.gather", bucket=3):``.
Spans nest lexically via a per-tracer stack; completed top-level spans are
retained in a bounded ring so long serving runs cannot grow without bound,
while a per-name aggregate (count/total/max) survives ring eviction.

Design constraints (the ≤5% overhead contract of benchmarks/obs_overhead.py):

- When the tracer is disabled, ``span()`` returns a shared immutable
  ``_NULL_SPAN`` singleton whose enter/exit/set/add_bytes are no-ops — the
  disabled cost of an instrumented call site is one attribute load and one
  method call, no allocation.
- Spans never force device synchronisation by themselves.  JAX dispatch is
  async, so a span around a jitted call measures *dispatch* time only; call
  sites that want execution billed to a span use ``tracer.device_sync(x)``,
  which blocks inside a dedicated child span — and only when tracing is
  enabled, so disabling telemetry also removes the sync points.
- With ``xla_annotations=True`` each span also enters a
  ``jax.profiler.TraceAnnotation`` so spans land in XLA/perfetto profiles.

Bytes accounting: ``Span.add_bytes`` attaches wire bytes to a span and
``Span.total_bytes()`` sums a subtree.  The instrumentation layer
(telemetry/instrument.py) bills bytes from the same send/recv tables that
``distributed.traffic`` uses, so span-tree totals equal
``ExecutionPlan.measured_traffic`` exactly — by construction, not by luck.
"""

from __future__ import annotations

import json
import time
from collections import deque
from typing import Any, Dict, Iterator, List, Optional

__all__ = ["Span", "SpanTracer", "NULL_SPAN"]


class Span:
    """One timed node of a span tree (also its own context manager)."""

    __slots__ = ("name", "attrs", "t_start", "t_end", "children", "_tracer", "_ann")

    def __init__(self, name: str, tracer: "Optional[SpanTracer]" = None,
                 attrs: Optional[Dict[str, Any]] = None):
        self.name = name
        self.attrs: Dict[str, Any] = dict(attrs) if attrs else {}
        self.t_start = 0.0
        self.t_end = 0.0
        self.children: List[Span] = []
        self._tracer = tracer
        self._ann = None

    # -- attribute / bytes helpers -------------------------------------
    def set(self, **attrs: Any) -> "Span":
        self.attrs.update(attrs)
        return self

    def add_bytes(self, n: int) -> "Span":
        self.attrs["bytes"] = int(self.attrs.get("bytes", 0)) + int(n)
        return self

    @property
    def duration_s(self) -> float:
        return max(self.t_end - self.t_start, 0.0)

    def total_bytes(self) -> int:
        """Sum of ``bytes`` attrs over this span and all descendants."""
        return int(self.attrs.get("bytes", 0)) + sum(
            c.total_bytes() for c in self.children
        )

    def walk(self) -> Iterator["Span"]:
        yield self
        for c in self.children:
            yield from c.walk()

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {
            "name": self.name,
            "t_start": self.t_start,
            "duration_s": self.duration_s,
        }
        if self.attrs:
            d["attrs"] = dict(self.attrs)
        if self.children:
            d["children"] = [c.to_dict() for c in self.children]
        return d

    # -- context manager -----------------------------------------------
    def __enter__(self) -> "Span":
        tr = self._tracer
        if tr is not None:
            if tr._stack:
                tr._stack[-1].children.append(self)
            tr._stack.append(self)
            if tr.xla_annotations:
                try:  # pragma: no cover - exercised only under a profiler
                    from jax.profiler import TraceAnnotation

                    self._ann = TraceAnnotation(self.name)
                    self._ann.__enter__()
                except Exception:
                    self._ann = None
        self.t_start = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.t_end = time.perf_counter()
        if self._ann is not None:
            try:  # pragma: no cover
                self._ann.__exit__(exc_type, exc, tb)
            finally:
                self._ann = None
        tr = self._tracer
        if tr is not None:
            tr._close(self)
        return False

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"Span({self.name!r}, {self.duration_s * 1e3:.3f}ms, "
            f"children={len(self.children)}, attrs={self.attrs})"
        )


class _NullSpan:
    """Shared no-op span returned by a disabled tracer."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False

    def set(self, **attrs: Any) -> "_NullSpan":
        return self

    def add_bytes(self, n: int) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()


class SpanTracer:
    """Produces span trees; keeps a bounded ring of completed root spans.

    Parameters
    ----------
    enabled:
        When False (default) ``span()`` returns ``NULL_SPAN`` and
        ``device_sync`` is an identity — the instrumented hot paths pay
        only a flag check.
    xla_annotations:
        Mirror every span into ``jax.profiler.TraceAnnotation`` so spans
        show up in XLA device profiles.
    max_roots:
        Ring-buffer capacity for completed top-level span trees.
    registry:
        Optional ``MetricsRegistry``; on span exit the duration is recorded
        into a ``span_seconds{span=<name>}`` histogram so p50/p95/p99 per
        span name fall out of tracing with no second instrumentation pass.
    """

    def __init__(self, enabled: bool = False, xla_annotations: bool = False,
                 max_roots: int = 256, registry: Any = None):
        self.enabled = bool(enabled)
        self.xla_annotations = bool(xla_annotations)
        self.registry = registry
        self.roots: deque = deque(maxlen=int(max_roots))
        self._stack: List[Span] = []
        # name -> [count, total_s, max_s]; survives ring eviction.
        self._agg: Dict[str, List[float]] = {}

    # -- span creation ---------------------------------------------------
    def span(self, name: str, **attrs: Any):
        if not self.enabled:
            return NULL_SPAN
        return Span(name, tracer=self, attrs=attrs or None)

    def current(self) -> Optional[Span]:
        return self._stack[-1] if self._stack else None

    def _close(self, sp: Span) -> None:
        # With-blocks guarantee LIFO order per thread; tolerate a foreign
        # top-of-stack (e.g. tracer reset mid-span) by searching.
        stack = self._stack
        if stack and stack[-1] is sp:
            stack.pop()
        elif sp in stack:  # pragma: no cover - defensive
            stack.remove(sp)
        if not stack:
            self.roots.append(sp)
        agg = self._agg.get(sp.name)
        dur = sp.duration_s
        if agg is None:
            self._agg[sp.name] = [1, dur, dur]
        else:
            agg[0] += 1
            agg[1] += dur
            if dur > agg[2]:
                agg[2] = dur
        reg = self.registry
        if reg is not None:
            reg.histogram("span_seconds", span=sp.name).observe(dur)

    # -- device sync -------------------------------------------------------
    def device_sync(self, x: Any, name: str = "device_sync") -> Any:
        """Block until ``x`` (any pytree of arrays) is ready, inside a span.

        JAX dispatch is async: without an explicit sync, device time leaks
        out of the span that dispatched it.  No-op pass-through when the
        tracer is disabled, so disabling telemetry also removes the
        serialization points.
        """
        if not self.enabled:
            return x
        import jax

        with self.span(name):
            return jax.block_until_ready(x)

    # -- reporting ---------------------------------------------------------
    def summary(self) -> Dict[str, Dict[str, float]]:
        """Per-name aggregate over every completed span (incl. evicted)."""
        out: Dict[str, Dict[str, float]] = {}
        for name, (count, total, mx) in sorted(self._agg.items()):
            out[name] = {
                "count": int(count),
                "total_s": float(total),
                "mean_s": float(total / count) if count else 0.0,
                "max_s": float(mx),
            }
        return out

    def export_trace(self, path: str) -> int:
        """Write retained root span trees as JSONL; returns tree count."""
        n = 0
        with open(path, "w") as fh:
            for root in self.roots:
                fh.write(json.dumps(root.to_dict()) + "\n")
                n += 1
        return n

    def reset(self) -> None:
        self.roots.clear()
        self._stack.clear()
        self._agg.clear()
