"""Online re-planning: watch a StreamingGNNServer, re-plan on drift.

The planner's recommendation is a prediction; serving is the measurement.
``ReplanMonitor`` attaches to a ``StreamingGNNServer`` through its commit
observer hook and folds every committed tick into a typed
``telemetry.DriftLedger`` (one :class:`~repro.telemetry.CommitSample` per
commit — DESIGN.md §14).  Drift is declared when either signal's recent
median leaves the tolerance band around its reference:

  * latency  — reference is the rolling baseline established over the
    first ``window`` commits (modeled crossbar/radio time and host
    wall-clock are different clocks, so the latency prediction is
    anchored to the candidate's own early measurements);
  * traffic  — reference is the planner's predicted ``bytes_per_tick``
    when the traffic evaluator priced it, else the early-commit baseline.

On drift the monitor re-estimates the workload from what the stream
actually did (measured churn from the ledger's frontier series, measured
query rate from the server's counters), re-runs ``plan`` on the live
graph, and — when the recommendation's (setting, n_clusters, backend)
differs from the serving config — builds the new ``ExecutionPlan`` and
swaps it in via ``server.update_plan``. Every decision is appended to
``self.events`` (and mirrored as a ``planner.replan`` telemetry audit
event) so the load harness can report re-plan behaviour.

``observe(sample, server=None)`` is the typed entry point: without a
server the monitor runs in *shadow mode* — drift is detected and recorded
(``swapped=False``, ``new == old``) but no re-plan/swap is attempted, so
drift accounting can run against recorded samples or remote streams.
"""
from __future__ import annotations

import dataclasses
import statistics

from repro.telemetry import CommitSample, DriftLedger, commit_sample, event

from .plan import PlannerResult, plan
from .space import Candidate, WorkloadProfile


@dataclasses.dataclass
class ReplanEvent:
    tick: int
    reason: str                     # "latency" | "traffic"
    measured: float
    reference: float
    old: Candidate
    new: Candidate
    swapped: bool
    workload: WorkloadProfile       # the measured profile the re-plan used


class ReplanMonitor:
    """Commit observer: drift detection + re-plan for a streaming server.

    ``tol`` is the multiplicative drift band (median of the last
    ``window`` commits vs the reference); ``cooldown`` commits must pass
    between re-plans so one burst cannot thrash the partition.
    """

    def __init__(self, result: PlannerResult, window: int = 8,
                 tol: float = 3.0, cooldown: int = 16,
                 shortlist: int = 0):
        self.result = result
        self.window = max(int(window), 2)
        self.tol = float(tol)
        self.cooldown = max(int(cooldown), 1)
        self.shortlist = shortlist
        self.ledger = DriftLedger(
            window=self.window,
            predicted_bytes=result.recommended.metrics.get("bytes_per_tick"))
        self.queries_seen = 0
        self.events: list = []
        self._last_replan = -(10 ** 9)

    # ---- wiring ---------------------------------------------------------

    def attach(self, server) -> "ReplanMonitor":
        server.add_observer(self)
        return self

    @property
    def serving(self) -> Candidate:
        return self.result.recommended.candidate

    # legacy views of the ledger's series — load harnesses and tests read
    # these; the ledger is the single source of truth
    @property
    def seconds(self) -> list:
        return self.ledger.seconds

    @property
    def bytes(self) -> list:
        return self.ledger.bytes

    @property
    def churn(self) -> list:
        return self.ledger.churn

    @property
    def _baseline_s(self) -> float | None:
        return self.ledger.baseline_s

    @property
    def _server_policy(self) -> str | None:
        """The policy the observed server actually commits under (refreshed
        on every commit): drift scaling must follow the real cadence, not
        the recommendation's, should the two ever diverge."""
        return self.ledger.policy

    # ---- observation ----------------------------------------------------

    def __call__(self, server, update) -> None:
        self.observe(commit_sample(server, update), server=server)

    def observe(self, sample: CommitSample,
                server=None) -> ReplanEvent | None:
        """Fold one typed commit sample in; re-plan (or shadow-record) on
        drift. Returns the ReplanEvent when this sample tripped one.

        Full refreshes are skipped by the ledger: cold starts, param
        swaps, and bit-accurate degradations are not representative ticks
        — folding their wall-clock/traffic into the baseline would mask
        real drift.
        """
        if not self.ledger.record(sample):
            return None
        if sample.queries:
            self.queries_seen += int(sample.queries)
        drift = self._drift()
        if drift is None or self.ledger.n - self._last_replan < self.cooldown:
            return None
        self._last_replan = self.ledger.n
        if server is not None:
            return self._replan(server, *drift)
        # shadow mode: record the detection without a server to swap
        reason, measured, reference = drift
        ev = ReplanEvent(self.ledger.n, reason, measured, reference,
                         self.serving, self.serving, False,
                         self.measured_workload())
        self.events.append(ev)
        event("planner.drift", reason=reason, measured=measured,
              reference=reference, serving=self.serving.key, shadow=True)
        return ev

    def _drift(self) -> tuple | None:
        """(reason, measured, reference) when out of band, else None."""
        lat = self.ledger.latency_drift(self.tol)
        if lat is not None:
            return ("latency", *lat)
        predicted = self.result.recommended.metrics.get("bytes_per_tick")
        # the measured series is per *commit*; the prediction is per tick —
        # scale it up by the serving policy's commit interval or every
        # non-eager policy would look like steady-state drift
        ref_b = (predicted * max(self._commit_ticks(), 1)
                 if predicted else None)
        byt = self.ledger.bytes_drift(self.tol, reference=ref_b)
        if byt is not None:
            return ("traffic", *byt)
        return None

    # ---- decision -------------------------------------------------------

    def _commit_ticks(self) -> int:
        """Ticks per commit under the policy the server really runs."""
        policy = self._server_policy or self.serving.policy
        return max(self.result.workload.commit_interval(policy), 1)

    def measured_workload(self) -> WorkloadProfile:
        """The workload the stream actually presented, in per-*tick* units:
        a commit's level-0 frontier accumulates ``commit_interval`` ticks
        of churn (and its query counter that many ticks of lookups), so
        both measurements are divided back down before they parameterize
        the re-plan — feeding per-commit rates in would make every
        non-eager policy look like an extreme-churn workload."""
        wl = self.result.workload
        ticks = self._commit_ticks()
        recent = self.churn[-self.window:] or [wl.churn * ticks]
        commits = max(self.ledger.n, 1)
        return dataclasses.replace(
            wl, churn=min(1.0, statistics.median(recent) / ticks),
            queries_per_tick=max(self.queries_seen / (commits * ticks),
                                 wl.queries_per_tick))

    def note_queries(self, n: int) -> None:
        """Load generators report served lookups here so the re-planned
        workload sees the real query mix."""
        self.queries_seen += int(n)

    def _replan(self, server, reason: str, measured: float,
                reference: float) -> ReplanEvent:
        old = self.serving
        at_commit = self.ledger.n
        measured_wl = self.measured_workload()
        new_result = plan(server.plan.graph, self.result.objective,
                          workload=measured_wl,
                          hw=self.result.ctx.hw,
                          inventory=self.result.ctx.inventory,
                          shortlist=self.shortlist)
        new = new_result.recommended.candidate
        swap = (new.setting, new.n_clusters, new.backend) != \
            (old.setting, old.n_clusters, old.backend)
        if swap:
            server.update_plan(new_result.build_plan(server.plan.graph))
            # the recommendation is (plan, policy) — install both, and the
            # measured workload's policy knobs with it, so the server
            # commits on the cadence the scores assumed
            server.policy = new.policy
            server.interval = measured_wl.interval
            server.max_staleness = measured_wl.max_staleness
            server.max_dirty_frac = measured_wl.max_dirty_frac
        self.result = new_result
        self.ledger.predicted_bytes = \
            new_result.recommended.metrics.get("bytes_per_tick")
        # the serving config changed: measured baselines describe the old
        # plan, so restart drift detection (and the cooldown clock, which
        # counts the same series — leaving it at the pre-clear count would
        # silently double the effective cooldown) from fresh observations
        if swap:
            self.ledger.reset()
            self.queries_seen = 0
            self._last_replan = 0
        ev = ReplanEvent(at_commit, reason, measured, reference, old, new,
                         swap, measured_wl)
        self.events.append(ev)
        event("planner.replan", reason=reason, measured=measured,
              reference=reference, old=old.key, new=new.key, swapped=swap)
        return ev
