"""Evaluator plug-ins: Candidate × PlanContext → metric dict.

The planner never prices a candidate itself — it folds the metric dicts of
a list of *evaluators* (DESIGN.md §10). The contract:

    evaluator(candidate: Candidate, ctx: PlanContext) -> dict[str, float]

  * pure in its inputs (same candidate + ctx ⇒ same dict) — the planner's
    recommendation must be reproducible and exhaustively sweepable;
  * returns ``{}`` when it cannot price the candidate (e.g. the traffic
    evaluator without a concrete graph) — never raises for "not my job";
  * later evaluators override earlier keys — a custom evaluator may
    replace a modeled quantity with a measured one. The built-ins emit
    disjoint key sets on purpose: the modeled keys decide the ranking,
    the measured traffic keys ground it (drift reference, artifacts)
    without perturbing it.

Built-ins:

  * ``cost_evaluator``    — the calibrated Eqs. 1-7 network model
    (``core.costmodel.predict``): ``t_compute`` / ``t_comm`` / power.
  * ``mapper_evaluator``  — the first-principles crossbar rollup
    (``mapper.compile_mapping``) at the candidate's geometry:
    ``t_compute_derived`` / ``energy_j`` / occupancy. The only evaluator
    that can see ``xbar_size``.
  * ``memory_evaluator`` — modeled per-device working-set bytes for the
    candidate's data-plane layout (``device_bytes``): the Pareto memory
    axis that separates dense from bucketed candidates. Closed-form, no
    partition built.
  * ``accuracy_evaluator`` — modeled p99 relative MVM error of the
    candidate's technology under conductance variation
    (``noise_p99_model``): the Pareto accuracy axis and the quantity the
    ``noise_tolerance`` infeasibility gate reads (DESIGN.md §13).
  * ``neighbor_evaluator`` — the per-commit neighbor/membership pass at
    the candidate's ``neighbor_mode`` on the traversal-CAM geometry
    (``t_neighbor_s`` — DESIGN.md §15): associative dirty-id search for
    ``cam``, serial table drain for the ``topk`` fallback. Folded into the
    serving model by ``objective.tick_costs`` for mutating workloads only.
  * ``traffic_evaluator`` — measured wire bytes on a *concrete* graph
    (``distributed.traffic.measure_execution`` / ``measure_incremental``):
    what a full refresh ships and what one policy-committed incremental
    tick ships, plus the measured layout accounting
    (``padding_ratio`` / ``peak_device_bytes``). Requires ``ctx.graph``;
    skipped otherwise.
"""
from __future__ import annotations

import dataclasses

from .space import Candidate, WorkloadProfile


@dataclasses.dataclass
class PlanContext:
    """Everything an evaluator may read: the workload statistics, the
    device inventory family, the demand profile, and (optionally) a
    concrete graph for measured evaluators. ``plan_cache`` memoizes built
    ExecutionPlans per (setting, n_clusters, layout) so the measured
    evaluators do not re-partition for every xbar/policy variant."""
    stats: object                      # core.graph.GraphStats
    workload: WorkloadProfile
    hw: object = None                  # core.costmodel.HardwareParams
    inventory: object = None           # base XbarInventory (None = paper's)
    graph: object = None               # concrete core.graph.Graph, optional
    spokes_per_head: int = 4
    plan_cache: dict = dataclasses.field(default_factory=dict)
    # built-in evaluators memoize here on the candidate fields they read
    # (the policy/backend axes multiply candidates without changing their
    # outputs — one compile_mapping per geometry, not three)
    memo: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.hw is None:
            from repro.core.costmodel import DEFAULT_HW
            self.hw = DEFAULT_HW

    def inventory_for(self, cand: Candidate):
        """The candidate's device inventory: the setting's base inventory
        re-geometried to the candidate's crossbar size and rebuilt from
        its compute-tier technology (the head tier is what the crossbar
        mapper prices; the spoke storage tier only enters the per-device
        energy model — see ``mapper_evaluator``)."""
        from repro.mapper import XbarInventory
        inv = self.inventory or XbarInventory.from_hardware(self.hw,
                                                            cand.setting)
        if cand.xbar_size is not None:
            inv = inv.with_xbar_size(cand.xbar_size)
        if cand.head_technology != inv.technology:
            inv = inv.with_technology(cand.head_technology)
        return inv

    def concrete_plan(self, cand: Candidate):
        """Build (and memoize) the candidate's ExecutionPlan on the
        concrete graph; None when no graph was supplied."""
        if self.graph is None:
            return None
        key = (cand.setting, cand.n_clusters, cand.layout)
        if key not in self.plan_cache:
            self.plan_cache[key] = cand.build_plan(
                self.graph, self.workload.sample,
                spokes_per_head=self.spokes_per_head)
        return self.plan_cache[key]


def cost_evaluator(cand: Candidate, ctx: PlanContext) -> dict:
    """Calibrated network model (Eqs. 1-7): per-inference compute and
    communication latency plus per-device power for the setting.
    Memoized per (setting, n_clusters) — it reads nothing else."""
    key = ("cost", cand.setting, cand.n_clusters)
    if key in ctx.memo:
        return ctx.memo[key]
    from repro.core import costmodel
    m = costmodel.predict(cand.setting, ctx.stats, ctx.hw,
                          n_clusters=cand.n_clusters,
                          gnn_layers=ctx.workload.gnn_layers,
                          sample=ctx.workload.sample)
    ctx.memo[key] = {
        "t_compute": m.t_compute,
        "t_comm": m.t_communicate,
        "t_net": m.t_net,
        "p_compute": m.p_compute,
        "p_comm": m.p_communicate,
    }
    return ctx.memo[key]


def mapper_evaluator(cand: Candidate, ctx: PlanContext) -> dict:
    """First-principles crossbar rollup at the candidate's geometry
    (DESIGN.md §8): derived compute latency, per-inference read energy,
    and fx schedule occupancy. Layer dims default to the calibration
    workload (feature_len → 128) exactly as ``costmodel`` does.
    Memoized per (setting, n_clusters, xbar_size, technology) — the
    compile is the planner's most expensive model evaluation.

    ``energy_per_device_j`` is the technology-aware per-device energy
    axis: the head tier's crossbar read energy plus — for semi, where the
    spoke tier stores the features — one pass over the spoke's stored
    feature cells at the *spoke* technology's read energy (how a
    ``(reram, sram)`` pair gets billed for both of its tiers)."""
    key = ("mapper", cand.setting, cand.n_clusters, cand.xbar_size,
           cand.tech_key)
    if key in ctx.memo:
        return ctx.memo[key]
    from repro.devices.bank import resolve_technology
    from repro.mapper.compile import compile_mapping
    dims = (max(ctx.stats.feature_len, 1), 128)
    m = compile_mapping(dims, ctx.stats, ctx.hw, ctx.inventory_for(cand),
                        cand.setting, cand.n_clusters,
                        sample=ctx.workload.sample)
    energy_dev = m.energy_j
    if cand.setting == "semi":
        spoke = resolve_technology(cand.spoke_technology)
        rows = -(-max(ctx.stats.n_nodes, 1) // max(cand.n_clusters, 1))
        cells_per_elem = -(-8 // max(spoke.cell_bits, 1))
        energy_dev += (rows * max(ctx.stats.feature_len, 1)
                       * cells_per_elem * spoke.read_energy_j)
    ctx.memo[key] = {
        "t_compute_derived": m.t_compute,
        "t_compute_pipelined": m.t_compute_pipelined,
        "energy_j": m.energy_j,
        "energy_per_device_j": energy_dev,
        "fx_occupancy": m.array_utilization[2],
        "weight_arrays": float(m.weight_arrays),
    }
    return ctx.memo[key]


def memory_evaluator(cand: Candidate, ctx: PlanContext) -> dict:
    """Modeled per-device working-set bytes for the candidate's data-plane
    layout — the Pareto memory axis (DESIGN.md §12). Deliberately a coarse
    closed-form model (no partition is built, so the full grid stays
    partition-free): the worst device holds ``rows`` padded feature rows
    (double-buffered activations), their sampled halo, and the int32
    neighbor/weight tables. Dense padding is priced at the modeled skew of
    the worst cluster (~2x the mean on the power-law graphs the paper
    serves); bucketed padding at the pow2-capacity average (~4/3x). The
    measured counterpart (``peak_device_bytes`` from
    ``ExecutionPlan.layout_stats``) is attached by ``traffic_evaluator``
    on the shortlist. Memoized per (n_clusters, layout)."""
    key = ("mem", cand.n_clusters, cand.layout)
    if key in ctx.memo:
        return ctx.memo[key]
    wl = ctx.workload
    f = max(int(ctx.stats.feature_len), 1)
    mean_rows = max(ctx.stats.n_nodes, 1) / max(cand.n_clusters, 1)
    if cand.n_clusters == 1:
        rows, halo = mean_rows, 0.0
    else:
        rows = mean_rows * (4.0 / 3.0 if cand.layout == "bucketed" else 2.0)
        halo = min(rows * min(ctx.stats.avg_cs, float(wl.sample)),
                   float(ctx.stats.n_nodes) - mean_rows)
        halo = max(halo, 0.0)
    ctx.memo[key] = {"device_bytes":
                     4.0 * (2 * rows * f + halo * f + 2 * rows * wl.sample)}
    return ctx.memo[key]


def accuracy_evaluator(cand: Candidate, ctx: PlanContext) -> dict:
    """Modeled accuracy bound of the candidate's compute-tier technology
    under conductance variation (DESIGN.md §13): the closed-form p99
    relative MVM output error at the candidate's aggregation geometry
    (``devices.variation.modeled_p99_error`` — zero for noiseless SRAM).
    The axis that pulls noisy-but-cheap technologies off the frontier and
    the quantity ``WorkloadProfile.noise_tolerance`` gates on; the
    Monte-Carlo bounds of ``benchmarks/tech_sweep.py`` ground it.
    Memoized per (technology, xbar_size)."""
    key = ("acc", cand.tech_key, cand.xbar_size)
    if key in ctx.memo:
        return ctx.memo[key]
    from repro.devices.variation import modeled_p99_error
    from repro.kernels.crossbar_mvm import CrossbarNumerics
    inv = ctx.inventory_for(cand)
    cfg = CrossbarNumerics(rows_per_xbar=inv.agg_rows)
    ctx.memo[key] = {"noise_p99_model": modeled_p99_error(
        cand.head_technology, max(ctx.stats.feature_len, 1), cfg)}
    return ctx.memo[key]


def neighbor_evaluator(cand: Candidate, ctx: PlanContext) -> dict:
    """Price one commit's neighbor/membership pass on the traversal CAM
    (DESIGN.md §15).

    The device's sampled neighbor table — ``rows × sample`` flat column
    indices — is the associative state; one commit must test it against
    the commit's dirty ids (``streaming.frontier``). The table occupies
    ``ceil(entries / cam_rows)`` CAM arrays, drained in
    ``serial = ceil(arrays / cam_arrays)`` rounds:

      * ``cam``  — each dirty id is one match-line-parallel search across
        every resident array: ``queries × serial × t_cam``.
      * ``topk`` — no associative path: the membership test reads the
        table out row-serially per array round, ``serial × cam_rows ×
        t_cam`` — so the CAM wins exactly when the dirty-id count stays
        under one array's depth, and loses on full-graph churn.

    Both are handed to ``objective.tick_costs`` via ``t_neighbor_s`` and
    billed per commit for mutating workloads (a static graph never pays a
    membership pass — the modes then tie and ``NEIGHBOR_RANK`` breaks it).
    Memoized per (setting, n_clusters, xbar_size, technology, policy,
    neighbor_mode)."""
    import math
    key = ("nbr", cand.setting, cand.n_clusters, cand.xbar_size,
           cand.tech_key, cand.policy, cand.neighbor_mode)
    if key in ctx.memo:
        return ctx.memo[key]
    from repro.mapper.compile import PassPrimitives, items_per_device
    wl = ctx.workload
    inv = ctx.inventory_for(cand)
    prim = PassPrimitives.derive(ctx.hw, inv, tech=cand.head_technology)
    rows = items_per_device(cand.setting, max(ctx.stats.n_nodes, 1),
                            cand.n_clusters)
    entries = rows * max(wl.sample, 1)
    arrays = math.ceil(entries / max(inv.cam_rows, 1))
    serial = math.ceil(arrays / max(inv.cam_arrays, 1))
    frac = wl.recompute_fraction(ctx.stats, wl.commit_interval(cand.policy))
    queries = max(int(math.ceil(frac * rows)), 1)
    if cand.neighbor_mode == "cam":
        t = queries * serial * prim.t_cam
    else:
        t = serial * inv.cam_rows * prim.t_cam
    ctx.memo[key] = {"t_neighbor_s": t,
                     "neighbor_rounds": float(serial),
                     "neighbor_queries": float(queries)}
    return ctx.memo[key]


def traffic_evaluator(cand: Candidate, ctx: PlanContext) -> dict:
    """Measured wire traffic on the concrete graph: bytes a full refresh
    exchanges, and bytes one policy-committed incremental tick ships (the
    commit's dirty frontier over the executed send tables, amortized back
    to per-tick). Skipped (``{}``) without a concrete graph."""
    plan = ctx.concrete_plan(cand)
    if plan is None:
        return {}
    from repro.distributed.traffic import measure_execution
    # per full refresh: the tier-1 halo repeats every layer, the semi
    # tier-0 spoke upload ships the input features exactly once
    full = measure_execution(plan, mode="alltoall")
    out = {"bytes_full_refresh":
           float(full.tier0_bytes().sum())
           + float(full.tier1_bytes().sum()) * ctx.workload.gnn_layers}
    # measured layout accounting for the concrete partition: grounds the
    # modeled ``device_bytes`` axis without touching it (disjoint keys —
    # the ranking/frontier must not depend on whether measurement ran)
    ls = plan.layout_stats()
    out["padding_ratio"] = float(ls["padding_ratio"])
    out["peak_device_bytes"] = float(ls["peak_device_bytes"])
    wl = ctx.workload
    if wl.mutating and plan.part is not None:
        import types
        from repro.distributed.halo import build_halo_plan
        from repro.distributed.traffic import (measure_incremental,
                                               modeled_frontier)
        ticks = wl.commit_interval(cand.policy)
        frac = wl.recompute_fraction(ctx.stats, ticks)
        levels = modeled_frontier(plan.part, min(1.0, wl.churn * ticks),
                                  frac, wl.gnn_layers)
        # bill every layer's exchange against its frontier level (a
        # cfg-shaped dims carrier: input-dim features per layer, matching
        # the bytes_full_refresh convention above)
        dims_cfg = types.SimpleNamespace(
            dims=(ctx.stats.feature_len,) * (wl.gnn_layers + 1))
        rep = measure_incremental(plan, build_halo_plan(plan.part),
                                  levels, cfg=dims_cfg, mode="alltoall")
        out["bytes_per_tick"] = float(rep.total_bytes()) / max(ticks, 1)
    elif not wl.mutating:
        out["bytes_per_tick"] = 0.0
    return out


DEFAULT_EVALUATORS = (cost_evaluator, mapper_evaluator, memory_evaluator,
                      accuracy_evaluator, neighbor_evaluator)


def evaluate(cand: Candidate, ctx: PlanContext,
             evaluators: tuple = DEFAULT_EVALUATORS) -> dict:
    """Fold every evaluator's metric dict (later evaluators win ties)."""
    metrics: dict = {}
    for ev in evaluators:
        metrics.update(ev(cand, ctx))
    return metrics
