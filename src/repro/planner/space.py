"""The planner's configuration space: candidates and workload profiles.

A ``Candidate`` is one point of the discrete space the planner searches —
``setting × backend × cluster count × crossbar size × refresh policy ×
data-plane layout`` —
i.e. everything that must be decided *before* an ``ExecutionPlan`` can be
built and a ``StreamingGNNServer`` brought up. ``WorkloadProfile`` is the
demand side: how much of the graph churns per tick, how many embedding
lookups arrive alongside, and the serving knobs (sample size, GNN depth,
refresh-policy parameters) the combined-objective model needs.

Dependency-light by design (numpy only): the evaluators pull in
``repro.core`` / ``repro.mapper`` lazily, so the space can be enumerated
and serialized without touching jax.
"""
from __future__ import annotations

import dataclasses
import math

SETTINGS = ("centralized", "decentralized", "semi")
BACKENDS = ("jnp", "pallas", "fused")
POLICIES = ("eager", "interval", "bounded-staleness")
LAYOUTS = ("dense", "bucketed")
# neighbor-selection paths (kept in sync with repro.neighbors.NEIGHBOR_MODES
# — this module stays numpy-only): ``cam`` runs membership/k-NN scoring on
# the traversal CAM, ``topk`` on the host sort/top-k fallback
NEIGHBOR_MODES = ("topk", "cam")

# deterministic tie-break: when two candidates score identically the planner
# prefers the faster measured backend (fused keeps Z in VMEM — DESIGN.md §5)
BACKEND_RANK = {"fused": 0, "pallas": 1, "jnp": 2}
# second tie-break: bucketed before dense — the modeled time/energy
# evaluators cannot distinguish the layouts (same partition, same math),
# and at equal score the bucketed layout strictly reduces device memory
# (it Pareto-dominates its dense twin on the ``device_bytes`` axis, so
# ranking it first keeps the recommendation on the frontier)
LAYOUT_RANK = {"bucketed": 0, "dense": 1}
# third tie-break: cam before topk — when the serving model cannot separate
# the modes (non-mutating workloads pay no per-commit membership pass) the
# CAM path is the in-memory one the paper argues for, at no modeled cost
NEIGHBOR_RANK = {"cam": 0, "topk": 1}


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the planner's search space.

    ``n_clusters`` is the device-side parallelism knob: 1 for centralized
    (by construction), cluster heads for semi, runtime clusters for
    decentralized (the paper's decentralized setting is one node per
    device; the cost model prices it that way regardless, so decentralized
    candidates carry a single representative cluster count for the
    concrete runtime). ``xbar_size`` re-geometries the MVM crossbars via
    ``XbarInventory.with_xbar_size`` (None = the paper's geometry).
    ``layout`` picks the partition data plane: ``dense`` is the uniform
    n_max padding, ``bucketed`` the capacity-bucketed ragged layout
    (DESIGN.md §12) — numerically identical, cheaper device memory.
    ``neighbor_mode`` picks where neighbor selection / dirty-frontier
    membership runs (DESIGN.md §15): ``cam`` on the traversal core's
    search CAM, ``topk`` on the host sort/top-k fallback — result-identical
    by the kernel contract, so the axis is purely a hardware/pricing
    decision (``evaluate.neighbor_evaluator``).
    ``technology`` is the device-technology axis (DESIGN.md §13): a
    registered name (``repro.devices.bank``) builds every tier from that
    technology; a ``(spoke_tech, head_tech)`` pair — semi only — builds
    the spoke storage tier and the compute-head tier from different ones
    (e.g. dense ReRAM spokes under fast SRAM heads). Names are validated
    lazily by the evaluators (``compile_mapping`` raises the registry's
    named error), keeping this module dependency-light.
    """
    setting: str
    backend: str = "fused"
    n_clusters: int = 1
    xbar_size: int | None = None
    policy: str = "eager"
    layout: str = "dense"
    technology: str | tuple = "sot-mram"
    neighbor_mode: str = "topk"

    def __post_init__(self):
        if self.setting not in SETTINGS:
            raise ValueError(f"unknown setting {self.setting!r}")
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.policy not in POLICIES:
            raise ValueError(f"unknown policy {self.policy!r}")
        if self.layout not in LAYOUTS:
            raise ValueError(f"unknown layout {self.layout!r}")
        if self.neighbor_mode not in NEIGHBOR_MODES:
            raise ValueError(f"unknown neighbor mode {self.neighbor_mode!r}")
        if self.n_clusters < 1:
            raise ValueError(f"n_clusters must be >= 1, got {self.n_clusters}")
        if self.setting == "centralized" and self.n_clusters != 1:
            raise ValueError("centralized implies n_clusters == 1")
        if isinstance(self.technology, (tuple, list)):
            object.__setattr__(self, "technology", tuple(self.technology))
            if len(self.technology) != 2:
                raise ValueError("a technology pair must be "
                                 "(spoke_tech, head_tech)")
            if self.setting != "semi":
                raise ValueError("per-tier technology pairs require the "
                                 "semi setting (spokes + heads)")
        if not self.technology or not all(
                isinstance(t, str) and t
                for t in ((self.technology,)
                          if isinstance(self.technology, str)
                          else self.technology)):
            raise ValueError(f"technology must be a non-empty name or a "
                             f"pair of names, got {self.technology!r}")

    @property
    def spoke_technology(self) -> str:
        """Storage-tier technology (= the single name when not a pair)."""
        return (self.technology[0] if isinstance(self.technology, tuple)
                else self.technology)

    @property
    def head_technology(self) -> str:
        """Compute-tier technology — what the crossbar mapper prices."""
        return (self.technology[1] if isinstance(self.technology, tuple)
                else self.technology)

    @property
    def tech_key(self) -> str:
        return ("+".join(self.technology)
                if isinstance(self.technology, tuple) else self.technology)

    @property
    def key(self) -> str:
        xb = "paper" if self.xbar_size is None else str(self.xbar_size)
        return (f"{self.setting}/{self.backend}/k{self.n_clusters}"
                f"/xb{xb}/{self.policy}/{self.layout}/{self.tech_key}"
                f"/{self.neighbor_mode}")

    def build_plan(self, graph, sample: int, seed: int = 0,
                   spokes_per_head: int = 4):
        """Materialize this candidate as a runnable ``ExecutionPlan``."""
        from repro.core.partition import plan_execution
        k = None if self.setting == "centralized" else self.n_clusters
        buckets = "auto" if self.layout == "bucketed" else None
        return plan_execution(graph, self.setting, backend=self.backend,
                              sample=sample, n_clusters=k, seed=seed,
                              spokes_per_head=spokes_per_head,
                              buckets=buckets)


@dataclasses.dataclass(frozen=True)
class WorkloadProfile:
    """The demand profile the combined objective prices a candidate under.

    ``churn`` — fraction of node feature rows mutated per stream tick;
    ``edge_churn`` — structural edge events per tick;
    ``queries_per_tick`` — embedding lookups arriving per tick;
    ``gnn_layers`` / ``sample`` — model depth and the runtime's neighbor
    sample (bounds how far dirt propagates per layer);
    ``interval`` / ``max_staleness`` / ``max_dirty_frac`` — the refresh
    policies' parameters, mirroring ``StreamingGNNServer``'s;
    ``slo_s`` — optional per-query latency bound for the throughput
    objective (a candidate whose queue wait exceeds it is infeasible);
    ``noise_tolerance`` — optional bound on the modeled p99 relative MVM
    error under conductance variation (``devices.variation``): a
    candidate whose technology's ``noise_p99_model`` exceeds it is
    infeasible — how the planner rejects technologies whose noise breaks
    the bit-accurate contract.
    """
    churn: float = 0.0
    edge_churn: int = 0
    queries_per_tick: float = 0.0
    gnn_layers: int = 2
    sample: int = 8
    interval: int = 4
    max_staleness: int = 8
    max_dirty_frac: float = 0.25
    slo_s: float | None = None
    noise_tolerance: float | None = None

    def __post_init__(self):
        if not 0.0 <= self.churn <= 1.0:
            raise ValueError(f"churn must be in [0, 1], got {self.churn}")
        if self.queries_per_tick < 0 or self.edge_churn < 0:
            raise ValueError("negative workload rates")
        if self.gnn_layers < 1 or self.sample < 1:
            raise ValueError("gnn_layers and sample must be >= 1")
        if self.noise_tolerance is not None and self.noise_tolerance < 0:
            raise ValueError("noise_tolerance must be >= 0")

    @property
    def mutating(self) -> bool:
        return self.churn > 0 or self.edge_churn > 0

    def commit_interval(self, policy: str) -> int:
        """Ticks between refresh commits under ``policy`` (>= 1).

        ``eager`` commits every tick; ``interval`` every ``interval``
        ticks; ``bounded-staleness`` when the buffered dirty fraction
        reaches ``max_dirty_frac`` (or ``max_staleness`` ticks, whichever
        comes first) — the same triggers ``StreamingGNNServer`` applies.
        """
        if policy == "eager" or not self.mutating:
            return 1
        if policy == "interval":
            return max(int(self.interval), 1)
        assert policy == "bounded-staleness", policy
        if self.churn <= 0:
            return max(int(self.max_staleness), 1)
        return max(min(int(math.ceil(self.max_dirty_frac / self.churn)),
                       int(self.max_staleness)), 1)

    def recompute_fraction(self, stats, ticks: int = 1) -> float:
        """Modeled fraction of rows a commit covering ``ticks`` ticks must
        recompute: ``ticks × churn`` seed rows, each dirtying the rows that
        read it through L layers of the *sampled* adjacency (fan-out per
        hop is bounded by both the average degree and the sample cut —
        DESIGN.md §9's frontier masks are the measured counterpart)."""
        seed = min(1.0, self.churn * max(ticks, 1)
                   + self.edge_churn * max(ticks, 1) / max(stats.n_nodes, 1))
        if seed <= 0.0:
            return 0.0
        fan = 1.0 + min(stats.avg_cs, float(self.sample))
        return min(1.0, seed * fan ** self.gnn_layers)


def candidate_space(stats,
                    settings: tuple = SETTINGS,
                    backends: tuple = ("fused",),
                    cluster_counts: tuple = (4, 8, 16),
                    xbar_sizes: tuple = (None, 128, 256),
                    policies: tuple | None = None,
                    workload: WorkloadProfile | None = None,
                    layouts: tuple = LAYOUTS,
                    technologies: tuple = ("sot-mram",),
                    neighbor_modes: tuple | None = None) -> list:
    """Enumerate the candidate grid for one workload.

    Per-setting structure is respected: centralized pins ``n_clusters=1``;
    decentralized carries one representative cluster count (the cost model
    prices it per node regardless — see ``Candidate``); semi sweeps the
    cluster-head counts (capped at the node count — a head must front at
    least one node). Refresh policies only differentiate mutating
    workloads, so a query-only profile collapses them to ``eager``.
    Layouts only differentiate partitioned settings — centralized has one
    cluster and therefore one bucket, so it stays dense.

    ``technologies`` entries are registered names or ``(spoke, head)``
    pairs; pairs only make sense with two tiers, so they enumerate under
    the semi setting only.

    Like refresh policies, neighbor modes only differentiate mutating
    workloads (the membership pass is billed per commit), so a query-only
    profile collapses ``neighbor_modes`` to the ``topk`` fallback.
    """
    if policies is None:
        policies = (POLICIES if workload is not None and workload.mutating
                    else ("eager",))
    if neighbor_modes is None:
        neighbor_modes = (NEIGHBOR_MODES
                          if workload is not None and workload.mutating
                          else ("topk",))
    counts = sorted({max(1, min(int(k), max(stats.n_nodes, 1)))
                     for k in cluster_counts})
    out = []
    for setting in settings:
        if setting == "centralized":
            ks = (1,)
        elif setting == "decentralized":
            ks = (counts[len(counts) // 2],)
        else:
            ks = tuple(counts)
        lys = ("dense",) if setting == "centralized" else tuple(layouts)
        techs = tuple(t for t in technologies
                      if setting == "semi" or isinstance(t, str))
        for backend in backends:
            for k in ks:
                for size in xbar_sizes:
                    for policy in policies:
                        for layout in lys:
                            for tech in techs:
                                for nm in neighbor_modes:
                                    out.append(Candidate(setting, backend,
                                                         k, size, policy,
                                                         layout, tech, nm))
    return out
