"""Objectives: fold a candidate's metric dict into one comparable score.

Three objectives (DESIGN.md §10):

  * ``latency``    — the paper's per-inference ``T_net = T_compute +
    T_communicate`` (Eq. 1), with the mapper-derived compute latency when
    the mapper evaluator priced the candidate's geometry.
  * ``energy``     — per-device energy of one inference: the mapper's
    crossbar read energy plus the radio's ``P_comm × T_comm``.
  * ``throughput`` — the serving objective the ROADMAP's heavy-traffic
    story needs: the per-tick makespan ``t_tick`` of the mixed
    churn+query workload; its inverse is the sustainable tick rate. An
    optional per-query SLO marks candidates whose worst-case query wait
    exceeds it infeasible.

``tick_costs`` is the combined model behind ``throughput``: one commit
every ``commit_interval`` ticks pays a dirty-frontier refresh (compute
scaled by the modeled recompute fraction, communication by the
dirty-rows-only exchange — ``costmodel.refresh_communicate_latency``),
amortized per tick, plus the query drain: each device answers its share of
the tick's lookups serially over its link (one concurrent response per
radio), so centralized serializes everything behind one inter-network
link, semi spreads the drain over its cluster heads, and decentralized
over every node. That asymmetry is exactly the paper's tension made
decidable: query-heavy mixes reward device parallelism, churn-heavy mixes
reward cheap collection, and the hybrid setting trades the two.
"""
from __future__ import annotations

from .evaluate import PlanContext
from .space import Candidate

OBJECTIVES = ("latency", "energy", "throughput")

# a candidate violating the SLO stays comparable (ranked by how badly it
# misses) but never beats a feasible one
_INFEASIBLE = 1e6


def effective_compute(metrics: dict) -> float:
    """Per-inference compute latency: mapper-derived when priced (it sees
    the candidate's crossbar geometry), calibrated otherwise."""
    return metrics.get("t_compute_derived", metrics.get("t_compute", 0.0))


def tick_costs(cand: Candidate, ctx: PlanContext, metrics: dict) -> dict:
    """The combined per-tick serving model for one candidate.

    Returns refresh/query components, the per-tick makespan ``t_tick``,
    the worst-case per-query latency ``t_query_worst`` (refresh blocking
    plus the device's full drain), and the modeled recompute fraction —
    the quantities the planner records and the drift monitor later checks
    against measurements.
    """
    from repro.core.costmodel import refresh_communicate_latency
    wl, stats, hw = ctx.workload, ctx.stats, ctx.hw
    commit_ticks = wl.commit_interval(cand.policy)
    frac = wl.recompute_fraction(stats, commit_ticks)
    refresh_compute = frac * effective_compute(metrics)
    refresh_comm = (refresh_communicate_latency(
        cand.setting, stats, hw, cand.n_clusters, frac)
        if wl.mutating else 0.0)
    # per-commit neighbor/membership pass at the candidate's neighbor_mode
    # (evaluate.neighbor_evaluator); a static graph never pays one
    refresh_neighbor = (metrics.get("t_neighbor_s", 0.0)
                        if wl.mutating else 0.0)

    if cand.setting == "centralized":
        n_serving, t_link = 1, hw.t_ln
    elif cand.setting == "semi":
        n_serving, t_link = max(cand.n_clusters, 1), hw.t_ln
    else:
        n_serving, t_link = max(stats.n_nodes, 1), hw.t_lc
    query_drain = wl.queries_per_tick / n_serving * t_link

    t_tick = ((refresh_compute + refresh_comm + refresh_neighbor)
              / commit_ticks + query_drain)
    t_query_worst = (refresh_compute + refresh_comm + refresh_neighbor
                     + query_drain + t_link)
    return {
        "commit_ticks": float(commit_ticks),
        "recompute_frac": frac,
        "refresh_compute_s": refresh_compute,
        "refresh_comm_s": refresh_comm,
        "refresh_neighbor_s": refresh_neighbor,
        "query_drain_s": query_drain,
        "t_tick": t_tick,
        "t_query_worst": t_query_worst,
        "n_serving": float(n_serving),
    }


def score(cand: Candidate, ctx: PlanContext, metrics: dict,
          objective: str) -> float:
    """Scalar score (lower is better) of one candidate under ``objective``.

    Pure in its inputs: the exhaustive-sweep validation in
    ``benchmarks/planner_sweep.py`` re-derives every candidate's score
    through this very function and asserts the planner's recommendation
    is its argmin."""
    if objective not in OBJECTIVES:
        raise ValueError(f"unknown objective {objective!r}; "
                         f"one of {OBJECTIVES}")
    if objective == "latency":
        s = effective_compute(metrics) + metrics.get("t_comm", 0.0)
    elif objective == "energy":
        base = metrics.get(
            "energy_j",
            metrics.get("p_compute", 0.0) * metrics.get("t_compute", 0.0))
        s = base + metrics.get("p_comm", 0.0) * metrics.get("t_comm", 0.0)
    else:
        costs = tick_costs(cand, ctx, metrics)
        s = costs["t_tick"]
        slo = ctx.workload.slo_s
        if slo is not None and costs["t_query_worst"] > slo:
            s += _INFEASIBLE * (costs["t_query_worst"] - slo)
    # accuracy gate (all objectives): a technology whose modeled p99 MVM
    # error exceeds the workload's noise tolerance stays comparable but
    # never beats a feasible candidate — same shape as the SLO penalty
    tol = ctx.workload.noise_tolerance
    p99 = metrics.get("noise_p99_model", 0.0)
    if tol is not None and p99 > tol:
        s += _INFEASIBLE * (p99 - tol)
    return s
