"""Adaptive hybrid execution planner (DESIGN.md §10).

The paper's cross-layer results are a tension, not a verdict: centralized
wins communication ~790x, decentralized wins computation ~1400x, and the
authors call for a hybrid. This package decides instead of tabulating:
given graph statistics, a crossbar inventory, and a churn/query workload
profile, it searches ``setting × backend × cluster count × crossbar size ×
refresh policy × device technology × neighbor mode`` through pluggable
evaluators — the
calibrated Eqs. 1-7 cost model, the first-principles mapper rollup, the
device-technology accuracy bound, and measured traffic on
the executed exchange tables — and returns a Pareto frontier plus one
recommended, materializable ``ExecutionPlan``. ``ReplanMonitor`` closes
the loop online: when a serving ``StreamingGNNServer``'s measured tick
latency or traffic drifts from the prediction, the planner re-runs on the
live graph with the measured workload and swaps the plan in place.

    from repro.planner import WorkloadProfile, plan
    result = plan(graph, "throughput",
                  WorkloadProfile(churn=0.01, queries_per_tick=64))
    server = StreamingGNNServer(result.build_plan(graph), cfg)

Validated by ``benchmarks/planner_sweep.py`` (self-consistency vs an
exhaustive sweep of the planner's own evaluators; hybrid-vs-pure on the
mixed workload) and ``benchmarks/load_serve.py`` (measured serving
throughput / latency percentiles per config).
"""
from repro.telemetry import CommitSample, DriftLedger, commit_sample

from .evaluate import (DEFAULT_EVALUATORS, PlanContext, accuracy_evaluator,
                       cost_evaluator, evaluate, mapper_evaluator,
                       memory_evaluator, neighbor_evaluator,
                       traffic_evaluator)
from .objective import OBJECTIVES, effective_compute, score, tick_costs
from .plan import (PlannerResult, ScoredCandidate, pareto_frontier, plan,
                   score_candidate)
from .replan import ReplanEvent, ReplanMonitor
from .space import (BACKENDS, LAYOUTS, NEIGHBOR_MODES, POLICIES, SETTINGS,
                    Candidate, WorkloadProfile, candidate_space)

__all__ = [
    "BACKENDS", "LAYOUTS", "NEIGHBOR_MODES", "POLICIES", "SETTINGS",
    "Candidate", "WorkloadProfile", "candidate_space",
    "DEFAULT_EVALUATORS", "PlanContext", "accuracy_evaluator",
    "cost_evaluator", "evaluate",
    "mapper_evaluator", "memory_evaluator", "neighbor_evaluator",
    "traffic_evaluator",
    "OBJECTIVES", "effective_compute", "score", "tick_costs",
    "PlannerResult", "ScoredCandidate", "pareto_frontier", "plan",
    "score_candidate",
    "ReplanEvent", "ReplanMonitor",
    "CommitSample", "DriftLedger", "commit_sample",
]
