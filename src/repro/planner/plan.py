"""The planner core: search the space, keep the Pareto frontier, recommend.

``plan(graph_or_stats, ...)`` enumerates the candidate grid
(``space.candidate_space``), prices every candidate through the evaluator
chain (``evaluate.evaluate``), scores it under the requested objective
(``objective.score``), and returns a ``PlannerResult``:

  * ``scored``      — every candidate with its metric dict and score
    (deterministically ordered: score, then backend rank, then key), so an
    exhaustive sweep of the planner's own evaluators is just
    ``result.scored[0]`` — the self-consistency contract
    ``benchmarks/planner_sweep.py`` gates on;
  * ``frontier``    — the Pareto non-dominated set over (per-inference
    latency, per-device energy incl. the semi spoke storage tier,
    per-tick serving cost, modeled per-device working-set bytes, modeled
    p99 variation error): the configs worth keeping when the objective
    weighting is uncertain — the memory axis is what keeps the bucketed
    layouts on the frontier (time/energy models cannot separate layouts),
    the accuracy axis what keeps quiet-but-slow technologies on it;
  * ``recommended`` — the argmin under the objective, materializable via
    ``result.build_plan(graph)``.

When a concrete ``Graph`` is passed, a second *measurement* phase runs the
traffic evaluator over the ``shortlist`` best candidates — partitioning
the graph and counting bytes on the executed exchange tables. Its keys
(``bytes_full_refresh`` / ``bytes_per_tick``) feed no objective, so the
ranking is unchanged by construction (the exhaustive-sweep gate compares
against model-only scoring); they exist to ground the result in measured
wire traffic — the drift reference ``ReplanMonitor`` checks serving
against, and the artifact rows the sweep benchmark records. Keeping the
phase to a shortlist keeps partition-building off the full grid.
"""
from __future__ import annotations

import dataclasses

from repro.telemetry import event as telemetry_event

from .evaluate import (DEFAULT_EVALUATORS, PlanContext, evaluate,
                       traffic_evaluator)
from .objective import OBJECTIVES, score, tick_costs
from .space import (BACKEND_RANK, LAYOUT_RANK, NEIGHBOR_RANK, Candidate,
                    WorkloadProfile, candidate_space)


@dataclasses.dataclass(frozen=True)
class ScoredCandidate:
    candidate: Candidate
    metrics: dict
    score: float

    @property
    def sort_key(self) -> tuple:
        return (self.score, BACKEND_RANK.get(self.candidate.backend, 9),
                LAYOUT_RANK.get(self.candidate.layout, 9),
                NEIGHBOR_RANK.get(self.candidate.neighbor_mode, 9),
                self.candidate.key)

    def as_record(self) -> dict:
        """JSON-ready row (the sweep benchmark's artifact format)."""
        c = self.candidate
        return dict(setting=c.setting, backend=c.backend,
                    n_clusters=c.n_clusters,
                    xbar="paper" if c.xbar_size is None else c.xbar_size,
                    policy=c.policy, layout=c.layout,
                    technology=c.tech_key,
                    neighbor_mode=c.neighbor_mode, score=self.score,
                    **{k: v for k, v in self.metrics.items()
                       if isinstance(v, (int, float))})


# per-device energy (not bare crossbar energy — the semi spoke storage tier
# bills here too) and the modeled variation bound are the DESIGN.md §13
# axes; a same-technology space is degenerate on the noise axis
_PARETO_AXES = ("t_net", "energy_per_device_j", "t_tick", "device_bytes",
                "noise_p99_model")


def _dominates(a: dict, b: dict) -> bool:
    """a Pareto-dominates b: no worse on every axis, better on one."""
    no_worse = all(a.get(ax, 0.0) <= b.get(ax, 0.0) * (1 + 1e-12)
                   for ax in _PARETO_AXES)
    better = any(a.get(ax, 0.0) < b.get(ax, 0.0) * (1 - 1e-12)
                 for ax in _PARETO_AXES)
    return no_worse and better


def pareto_frontier(scored: list) -> list:
    """Non-dominated subset over ``_PARETO_AXES``, stable order."""
    out = []
    for sc in scored:
        if not any(_dominates(o.metrics, sc.metrics) for o in scored
                   if o is not sc):
            out.append(sc)
    return out


@dataclasses.dataclass
class PlannerResult:
    objective: str
    workload: WorkloadProfile
    ctx: PlanContext
    scored: list                    # every ScoredCandidate, best first
    frontier: list                  # Pareto subset of scored

    @property
    def recommended(self) -> ScoredCandidate:
        return self.scored[0]

    def best(self, setting: str) -> ScoredCandidate | None:
        """Best-scored candidate of one setting (the pure baselines the
        hybrid recommendation is judged against)."""
        for sc in self.scored:
            if sc.candidate.setting == setting:
                return sc
        return None

    def build_plan(self, graph, seed: int = 0):
        """Materialize the recommendation as a runnable ExecutionPlan."""
        return self.recommended.candidate.build_plan(
            graph, self.workload.sample, seed=seed,
            spokes_per_head=self.ctx.spokes_per_head)

    def summary(self, top: int = 5) -> str:
        rec = self.recommended
        lines = [
            f"planner[{self.objective}] over {len(self.scored)} candidates "
            f"({len(self.frontier)} on the Pareto frontier):",
            f"  recommended: {rec.candidate.key}  score {rec.score:.3e} s",
        ]
        for sc in self.scored[1:top]:
            lines.append(f"  runner-up:   {sc.candidate.key}  "
                         f"score {sc.score:.3e} s")
        for setting in ("centralized", "decentralized", "semi"):
            b = self.best(setting)
            if b is not None and b is not rec:
                lines.append(f"  best pure {setting}: {b.candidate.key}  "
                             f"score {b.score:.3e} s "
                             f"({b.score / max(rec.score, 1e-30):.2f}x "
                             f"recommended)")
        return "\n".join(lines)


def score_candidate(cand: Candidate, ctx: PlanContext, objective: str,
                    evaluators: tuple = DEFAULT_EVALUATORS
                    ) -> ScoredCandidate:
    """Price + score one candidate — the unit the exhaustive sweep replays."""
    metrics = evaluate(cand, ctx, evaluators)
    if objective == "throughput" or ctx.workload.mutating:
        metrics = dict(metrics, **tick_costs(cand, ctx, metrics))
    return ScoredCandidate(cand, metrics,
                           score(cand, ctx, metrics, objective))


def plan(graph_or_stats, objective: str = "latency",
         workload: WorkloadProfile | None = None,
         hw=None, inventory=None,
         evaluators: tuple = DEFAULT_EVALUATORS,
         space: list | None = None,
         shortlist: int = 4,
         spokes_per_head: int = 4,
         **space_kw) -> PlannerResult:
    """Search the configuration space and recommend an execution plan.

    ``graph_or_stats``: a concrete ``Graph`` (enables the measured-traffic
    phase — see module docstring: it attaches measured bytes to the top
    candidates without changing the ranking) or bare ``GraphStats``
    (model-only). ``space`` overrides the enumerated grid; ``space_kw``
    (``backends``, ``cluster_counts``, ``xbar_sizes``, ``policies``) tune
    the default one. ``shortlist`` bounds the measurement phase (0
    disables it).
    """
    if objective not in OBJECTIVES:
        raise ValueError(f"unknown objective {objective!r}; "
                         f"one of {OBJECTIVES}")
    workload = workload or WorkloadProfile()
    graph = None if not hasattr(graph_or_stats, "stats") else graph_or_stats
    stats = graph.stats("planner") if graph is not None else graph_or_stats
    ctx = PlanContext(stats, workload, hw=hw, inventory=inventory,
                      graph=graph, spokes_per_head=spokes_per_head)
    cands = (space if space is not None
             else candidate_space(stats, workload=workload, **space_kw))
    if not cands:
        raise ValueError("empty candidate space")
    scored = sorted((score_candidate(c, ctx, objective, evaluators)
                     for c in cands), key=lambda s: s.sort_key)
    if graph is not None and shortlist > 0:
        refined = [score_candidate(sc.candidate, ctx, objective,
                                   (*evaluators, traffic_evaluator))
                   for sc in scored[:shortlist]]
        scored = sorted(refined + scored[shortlist:],
                        key=lambda s: s.sort_key)
    result = PlannerResult(objective, workload, ctx, scored,
                           pareto_frontier(scored))
    # planner decision audit record (telemetry no-ops when disabled):
    # enough to reconstruct *why* this plan is serving from an exported
    # metrics dump alone (DESIGN.md §14)
    telemetry_event(
        "planner.plan", objective=objective,
        recommended=result.recommended.candidate.key,
        score=result.recommended.score, candidates=len(scored),
        frontier=len(result.frontier), measured=graph is not None,
        shortlist=shortlist, churn=workload.churn,
        queries_per_tick=workload.queries_per_tick)
    return result
