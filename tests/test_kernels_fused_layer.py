"""Fused GNN-layer kernel vs the composed aggregate -> crossbar_matmul path.

Tolerances: the ideal path runs the same f32 ops in the same order as the
composed path, so it is checked essentially exactly (atol 1e-5 for the
sequential-vs-einsum reduction order of the gather). The bit-accurate path
performs the identical integer-domain DAC/ADC math; the only divergence is
f32 summation order of the (integer-valued, lsb-scaled) partials, so
atol=1e-4 * full-scale-output, rtol=1e-4 covers it with margin.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from _hyp import given, settings, st
from repro.core import gnn, random_graph
from repro.kernels.crossbar_mvm import CrossbarNumerics
from repro.kernels.fused_layer import (fused_gnn_forward,
                                       fused_gnn_forward_batched,
                                       fused_gnn_layer, fused_layer_ref)

QUANT = CrossbarNumerics(in_bits=8, w_bits=8, adc_bits=12, rows_per_xbar=64)


def _case(n, f, h, nd, s, seed=0, weight_sign=True):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, f)).astype(np.float32))
    nbr = jnp.asarray(rng.integers(0, n, size=(nd, s)).astype(np.int32))
    wts = rng.normal(size=(nd, s)).astype(np.float32)
    if not weight_sign:
        wts = np.abs(wts)
    w = jnp.asarray(rng.normal(size=(f, h)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(h,)).astype(np.float32))
    return x, nbr, jnp.asarray(wts), w, b


def _check(x, nbr, wts, w, b, cfg, relu):
    ref = fused_layer_ref(x, nbr, wts, w, b, cfg, relu=relu)
    out = fused_gnn_layer(x, nbr, wts, w, b, cfg, relu=relu, bf=32)
    scale = float(jnp.abs(ref).max()) or 1.0
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4 * scale)


@pytest.mark.parametrize("relu", [False, True])
@pytest.mark.parametrize("n,f,h,nd,s", [
    (20, 32, 16, 20, 4),       # aligned
    (23, 50, 17, 11, 5),       # odd shapes, Nd != N
    (7, 300, 33, 7, 1),        # F > rows_per_xbar (multi K-tile), S = 1
    (40, 16, 128, 40, 9),      # H > F
])
def test_matches_composed_ideal(n, f, h, nd, s, relu):
    x, nbr, wts, w, b = _case(n, f, h, nd, s, seed=n + f)
    _check(x, nbr, wts, w, b, CrossbarNumerics(ideal=True), relu)


@pytest.mark.parametrize("relu", [False, True])
@pytest.mark.parametrize("n,f,h,nd,s", [
    (20, 32, 16, 20, 4),
    (23, 50, 17, 11, 5),
    (7, 130, 33, 7, 3),        # 130 -> three 64-row crossbars after padding
])
def test_matches_composed_quantized(n, f, h, nd, s, relu):
    x, nbr, wts, w, b = _case(n, f, h, nd, s, seed=n + f)
    _check(x, nbr, wts, w, b, QUANT, relu)


def test_signed_activations_quantized():
    """Negative Z exercises the neg-DAC pass + its separate global scale."""
    x, nbr, wts, w, b = _case(16, 48, 8, 16, 6, seed=3, weight_sign=True)
    _check(x, nbr, wts, w, b, QUANT, relu=False)


def test_zero_degree_nodes():
    """All-zero edge weights (zero-degree / fully padded rows) must yield
    exactly act(b) on both numerics paths."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(12, 32)).astype(np.float32))
    nbr = jnp.asarray(rng.integers(0, 12, size=(5, 4)).astype(np.int32))
    wts = jnp.zeros((5, 4), jnp.float32)
    w = jnp.asarray(rng.normal(size=(32, 8)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(8,)).astype(np.float32))
    for cfg in (CrossbarNumerics(ideal=True), QUANT):
        out = fused_gnn_layer(x, nbr, wts, w, b, cfg, relu=True, bf=32)
        np.testing.assert_allclose(np.asarray(out),
                                   np.tile(np.maximum(np.asarray(b), 0),
                                           (5, 1)), atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 30), f=st.sampled_from([8, 48, 100]),
       h=st.sampled_from([4, 24]), s=st.integers(1, 8),
       ideal=st.booleans(), seed=st.integers(0, 2**31 - 1))
def test_property_fused_composed_equivalence(n, f, h, s, ideal, seed):
    x, nbr, wts, w, b = _case(n, f, h, n, s, seed=seed)
    cfg = CrossbarNumerics(ideal=True) if ideal else QUANT
    _check(x, nbr, wts, w, b, cfg, relu=bool(seed % 2))


def test_multilayer_driver_matches_gnn_forward():
    g = random_graph(40, 200, 24, seed=5).gcn_normalize()
    cfg = gnn.GNNConfig(in_dim=24, hidden_dims=(32, 16), out_dim=6, sample=8)
    params = gnn.init_params(jax.random.key(0), cfg)
    nbr, wts = g.neighbor_sample(8)
    args = (jnp.asarray(g.features), jnp.asarray(nbr), jnp.asarray(wts))
    ref = gnn.forward(params, *args, cfg)
    out = fused_gnn_forward(params, *args, cfg.numerics)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    # batched driver: two stacked copies of the same subgraph
    batched = fused_gnn_forward_batched(
        params, *(jnp.stack([a, a]) for a in args), cfg.numerics)
    for k in range(2):
        np.testing.assert_allclose(np.asarray(batched[k]), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


def test_gnn_forward_backend_dispatch(backend, make_graph):
    """GNNConfig(backend=...) routes each backend of the shared conftest
    axis through its kernel path and agrees with the jnp composed oracle
    for both numerics (the grid that used to be a fused-only loop)."""
    import dataclasses
    g = make_graph(30, 150, 16, seed=6)
    nbr, wts = g.neighbor_sample(8)
    args = (jnp.asarray(g.features), jnp.asarray(nbr), jnp.asarray(wts))
    for numerics in (CrossbarNumerics(ideal=True), QUANT):
        cfg = gnn.GNNConfig(in_dim=16, hidden_dims=(24,), out_dim=5,
                            sample=8, numerics=numerics)
        params = gnn.init_params(jax.random.key(1), cfg)
        ref = np.asarray(gnn.forward(params, *args, cfg))
        got = np.asarray(gnn.forward(
            params, *args, dataclasses.replace(cfg, backend=backend)))
        scale = np.abs(ref).max() or 1.0
        np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4 * scale)
