"""Chunked online-softmax attention vs a dense oracle (hypothesis sweep),
canonical-mask parity, and sliding-window semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.models.attention import NEG_INF, chunked_attention


def _dense_oracle(q, k, v, causal, window):
    b, sq, h, dk = q.shape
    kv = k.shape[2]
    g = h // kv
    qf = np.asarray(q, np.float32).reshape(b, sq, kv, g, dk)
    kf = np.asarray(k, np.float32)
    vf = np.asarray(v, np.float32)
    s = np.einsum("bqkgd,bckd->bqkgc", qf, kf) * dk ** -0.5
    sk = kf.shape[1]
    if causal:
        rel = np.arange(sq)[:, None] - np.arange(sk)[None, :]
        mask = rel >= 0
        if window:
            mask &= rel < window
        s = np.where(mask[None, :, None, None, :], s, NEG_INF)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    out = np.einsum("bqkgc,bckd->bqkgd", p, vf)
    return out.reshape(b, sq, h, -1)


@pytest.mark.slow          # >10s on the CI CPU (--durations=15)
@settings(max_examples=15, deadline=None)
@given(sq=st.integers(3, 33), h=st.sampled_from([2, 4, 6]),
       kv_div=st.sampled_from([1, 2]), dk=st.sampled_from([4, 8]),
       chunk=st.sampled_from([4, 8, 16]), causal=st.booleans(),
       window=st.sampled_from([0, 5]), seed=st.integers(0, 999))
def test_chunked_matches_dense(sq, h, kv_div, dk, chunk, causal, window,
                               seed):
    kv = h // kv_div
    if h % kv:
        return
    if window and not causal:
        window = 0
    key = jax.random.key(seed)
    b = 2
    q = jax.random.normal(key, (b, sq, h, dk), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, sq, kv, dk))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, sq, kv, dk))
    pos = jnp.broadcast_to(jnp.arange(sq)[None], (b, sq))
    ref = _dense_oracle(q, k, v, causal, window)
    for canonical in (False, True):
        got = chunked_attention(q, k, v, pos, pos, causal=causal,
                                window=window, chunk=chunk,
                                canonical=canonical)
        np.testing.assert_allclose(np.asarray(got, np.float32), ref,
                                   rtol=2e-4, atol=2e-4)


def test_gradients_flow_through_remat():
    key = jax.random.key(0)
    b, s, h, dk = 1, 16, 2, 4
    q = jax.random.normal(key, (b, s, h, dk))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, dk))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, dk))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def f(q, k, v):
        o = chunked_attention(q, k, v, pos, pos, causal=True, window=0,
                              chunk=4, canonical=True)
        return jnp.sum(o ** 2)

    grads = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    for gx in grads:
        assert np.isfinite(np.asarray(gx)).all()
        assert float(jnp.abs(gx).max()) > 0
