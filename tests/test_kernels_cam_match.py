"""Traversal-core CAM kernel: search/scan vs oracle + CSR semantics."""
import numpy as np
import pytest
import jax.numpy as jnp
from _hyp import given, settings, st

from repro.kernels.cam_match import search, scan, cam_search_ref, cam_scan_ref


@pytest.mark.parametrize("e,q,nodes", [(128, 8, 16), (300, 13, 30),
                                       (1024, 64, 100), (17, 3, 5)])
def test_search_matches_oracle(e, q, nodes):
    rng = np.random.default_rng(e + q)
    ci = jnp.asarray(rng.integers(0, nodes, size=(e,)).astype(np.int32))
    qs = jnp.asarray(rng.integers(0, nodes, size=(q,)).astype(np.int32))
    m_ref, c_ref = cam_search_ref(ci, qs)
    m, c = search(ci, qs, backend="pallas", interpret=True)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(m_ref))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c_ref))


@pytest.mark.slow          # >10s on the CI CPU (--durations=15)
@settings(max_examples=15, deadline=None)
@given(e=st.integers(1, 200), q=st.integers(1, 20), nodes=st.integers(1, 50),
       seed=st.integers(0, 2**31 - 1))
def test_property_search(e, q, nodes, seed):
    rng = np.random.default_rng(seed)
    ci = jnp.asarray(rng.integers(0, nodes, size=(e,)).astype(np.int32))
    qs = jnp.asarray(rng.integers(0, nodes, size=(q,)).astype(np.int32))
    m_ref, c_ref = cam_search_ref(ci, qs)
    m, c = search(ci, qs, backend="pallas", bq=8, be=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(m_ref))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c_ref))


def test_scan_resolves_sources():
    # RP of the Fig. 3 style CSR: rows [0,2) [2,3) [3,3) [3,6)
    rp = jnp.asarray(np.array([0, 2, 3, 3, 6], np.int32))
    pos = jnp.asarray(np.array([0, 1, 2, 3, 4, 5], np.int32))
    src = scan(rp, pos)
    np.testing.assert_array_equal(np.asarray(src), [0, 0, 1, 3, 3, 3])


def test_search_counts_equal_degree():
    """Counts from the search CAM == in-degree from the CSR, the invariant the
    traversal core relies on to schedule the aggregation core."""
    rng = np.random.default_rng(7)
    nodes, e = 20, 150
    ci = rng.integers(0, nodes, size=(e,)).astype(np.int32)
    qs = np.arange(nodes, dtype=np.int32)
    _, c = search(jnp.asarray(ci), jnp.asarray(qs))
    degree = np.bincount(ci, minlength=nodes)
    np.testing.assert_array_equal(np.asarray(c).ravel(), degree)


def test_explicit_zero_block_raises():
    """bq=0 / be=0 is a caller bug, not a default request — the falsy-or
    resolution this guards against silently substituted the defaults."""
    ci = jnp.asarray(np.arange(16, dtype=np.int32))
    qs = jnp.asarray(np.arange(4, dtype=np.int32))
    for backend in ("jnp", "pallas"):
        for kw in ({"bq": 0}, {"be": 0}, {"bq": -3}):
            with pytest.raises(ValueError, match="positive block"):
                search(ci, qs, backend=backend, interpret=True, **kw)


def test_kernel_divisibility_error_names_dim_and_padding_api():
    """The raw kernel's shape check must say which dim is wrong and point
    at the padding ops wrapper (crossbar_matmul_quantized precedent)."""
    from repro.kernels.cam_match.cam_match import cam_search
    with pytest.raises(ValueError, match=r"E divisible.*got E=100"):
        cam_search(jnp.zeros(100, jnp.int32), jnp.zeros(8, jnp.int32),
                   bq=8, be=128, interpret=True)
    with pytest.raises(ValueError, match=r"Q divisible.*ops layer pads"):
        cam_search(jnp.zeros(128, jnp.int32), jnp.zeros(5, jnp.int32),
                   bq=8, be=128, interpret=True)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_negative_queries_match_nothing(backend):
    """A -1 query must return an all-zero row and count 0 — it used to
    activate every -1 pad slot of the padded entry array."""
    ci = jnp.asarray(np.array([3, -1, 5, -1, 3], np.int32))
    qs = jnp.asarray(np.array([-1, 3, -2, 5], np.int32))
    m, c = search(ci, qs, backend=backend, interpret=True)
    np.testing.assert_array_equal(np.asarray(c), [0, 2, 0, 1])
    m = np.asarray(m)
    assert m[0].sum() == 0 and m[2].sum() == 0
    np.testing.assert_array_equal(m[1], [1, 0, 0, 0, 1])


@pytest.mark.parametrize("bq,be", [(1, 8), (3, 32), (8, 128)])
def test_block_configs_bit_identical(bq, be):
    """Any (bq, be) pair only re-tiles independent compares — results must
    be bit-identical to the oracle on odd (non-multiple) shapes."""
    rng = np.random.default_rng(bq * 100 + be)
    ci = jnp.asarray(rng.integers(0, 23, size=(157,)).astype(np.int32))
    qs = jnp.asarray(rng.integers(0, 23, size=(11,)).astype(np.int32))
    m_ref, c_ref = cam_search_ref(ci, qs)
    m, c = search(ci, qs, backend="pallas", bq=bq, be=be, interpret=True)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(m_ref))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c_ref))


def test_tuned_config_resolution_precedence():
    """Explicit > TunedKernels bundle > process registry > default."""
    from repro.kernels.cam_match.ops import (DEFAULT_BE, DEFAULT_BQ,
                                             _resolve_blocks)
    from repro.tuning import registry
    from repro.tuning.space import CamConfig, CamGeometry, TunedKernels
    ci = jnp.zeros(64, jnp.int32)
    qs = jnp.zeros(4, jnp.int32)
    geom = CamGeometry(e=64, q=4)
    saved = registry.active()
    try:
        registry.clear()
        assert _resolve_blocks(ci, qs, None, None, None) == \
            (DEFAULT_BQ, DEFAULT_BE)
        registry.register(geom.key(), CamConfig(bq=2, be=32))
        assert _resolve_blocks(ci, qs, None, None, None) == (2, 32)
        tuned = TunedKernels.of({geom.key(): CamConfig(bq=4, be=16)})
        assert _resolve_blocks(ci, qs, None, None, tuned) == (4, 16)
        assert _resolve_blocks(ci, qs, 1, 8, tuned) == (1, 8)
        # a partial explicit keeps the other side on the resolved config
        assert _resolve_blocks(ci, qs, 2, None, tuned) == (2, 16)
    finally:
        registry.clear()
        for k, v in saved.items():
            registry.register(k, v)
