"""Traversal-core CAM kernel: search/scan vs oracle + CSR semantics."""
import numpy as np
import pytest
import jax.numpy as jnp
from _hyp import given, settings, st

from repro.kernels.cam_match import search, scan, cam_search_ref, cam_scan_ref


@pytest.mark.parametrize("e,q,nodes", [(128, 8, 16), (300, 13, 30),
                                       (1024, 64, 100), (17, 3, 5)])
def test_search_matches_oracle(e, q, nodes):
    rng = np.random.default_rng(e + q)
    ci = jnp.asarray(rng.integers(0, nodes, size=(e,)).astype(np.int32))
    qs = jnp.asarray(rng.integers(0, nodes, size=(q,)).astype(np.int32))
    m_ref, c_ref = cam_search_ref(ci, qs)
    m, c = search(ci, qs, backend="pallas", interpret=True)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(m_ref))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c_ref))


@pytest.mark.slow          # >10s on the CI CPU (--durations=15)
@settings(max_examples=15, deadline=None)
@given(e=st.integers(1, 200), q=st.integers(1, 20), nodes=st.integers(1, 50),
       seed=st.integers(0, 2**31 - 1))
def test_property_search(e, q, nodes, seed):
    rng = np.random.default_rng(seed)
    ci = jnp.asarray(rng.integers(0, nodes, size=(e,)).astype(np.int32))
    qs = jnp.asarray(rng.integers(0, nodes, size=(q,)).astype(np.int32))
    m_ref, c_ref = cam_search_ref(ci, qs)
    m, c = search(ci, qs, backend="pallas", bq=8, be=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(m_ref))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c_ref))


def test_scan_resolves_sources():
    # RP of the Fig. 3 style CSR: rows [0,2) [2,3) [3,3) [3,6)
    rp = jnp.asarray(np.array([0, 2, 3, 3, 6], np.int32))
    pos = jnp.asarray(np.array([0, 1, 2, 3, 4, 5], np.int32))
    src = scan(rp, pos)
    np.testing.assert_array_equal(np.asarray(src), [0, 0, 1, 3, 3, 3])


def test_search_counts_equal_degree():
    """Counts from the search CAM == in-degree from the CSR, the invariant the
    traversal core relies on to schedule the aggregation core."""
    rng = np.random.default_rng(7)
    nodes, e = 20, 150
    ci = rng.integers(0, nodes, size=(e,)).astype(np.int32)
    qs = np.arange(nodes, dtype=np.int32)
    _, c = search(jnp.asarray(ci), jnp.asarray(qs))
    degree = np.bincount(ci, minlength=nodes)
    np.testing.assert_array_equal(np.asarray(c).ravel(), degree)
