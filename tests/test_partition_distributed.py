"""Partitioner invariants + multi-device decentralized == centralized
(the system's key correctness property, run in a subprocess with forced
host devices so the main test process keeps a 1-device view)."""
import os
import subprocess
import sys

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import random_graph
from repro.core.partition import (partition, build_local_subgraphs,
                                  gather_features, halo_exchange_tables)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(8, 120), e=st.integers(10, 600), k=st.integers(1, 6),
       seed=st.integers(0, 100))
def test_partition_invariants(n, e, k, seed):
    g = random_graph(n, e, 4, seed=seed)
    part = partition(g, k, seed=seed)
    # every node assigned exactly once
    assert (part.assignment >= 0).all() and (part.assignment < k).all()
    counts = np.bincount(part.assignment, minlength=k)
    assert counts.sum() == n
    # balance: BFS-growth targets ceil(n/k)
    assert counts.max() <= -(-n // k) + max(2, n // max(k, 1) // 2)
    # local_nodes holds each node exactly once
    all_local = part.local_nodes[part.local_mask]
    assert sorted(all_local.tolist()) == list(range(n))
    # halo nodes are never owned by the requesting cluster
    for c in range(k):
        valid = part.halo_src[c] >= 0
        assert (part.assignment[part.halo_nodes[c][valid]] != c).all()
    # comm volume diagonal is zero (no self communication)
    assert (np.diag(part.comm_volume) == 0).all()


def test_comm_volume_counts_boundary_rows():
    """e_ij counts the unique remote rows each cluster receives — exactly
    what the alltoall exchange ships (and what traffic accounting bills)."""
    g = random_graph(40, 200, 4, seed=3)
    part = partition(g, 4)
    dst = np.repeat(np.arange(g.n_nodes), np.diff(g.indptr))
    boundary = part.assignment[dst] != part.assignment[g.indices]
    rows = len({(int(part.assignment[d]), int(s))
                for d, s in zip(dst[boundary], g.indices[boundary])})
    assert part.comm_volume.sum() == rows
    # per cluster, the e_ij row sum is that cluster's halo size
    for c in range(4):
        assert part.comm_volume[c].sum() == (part.halo_src[c] >= 0).sum()


def test_halo_tables_point_to_owners():
    g = random_graph(30, 150, 4, seed=4)
    part = partition(g, 3)
    src_c, src_s, mask = halo_exchange_tables(part)
    for c in range(3):
        for h in range(part.h_max):
            if mask[c, h]:
                owner, slot = src_c[c, h], src_s[c, h]
                assert part.local_nodes[owner, slot] == part.halo_nodes[c, h]


_DISTRIBUTED_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import random_graph, gnn
from repro.core.partition import partition, build_local_subgraphs, gather_features
from repro.distributed.halo import build_halo_plan, make_decentralized_forward

g = random_graph(80, 400, 24, seed=7).gcn_normalize()
cfg = gnn.GNNConfig(in_dim=24, hidden_dims=(16, 16), out_dim=6, sample=96)
params = gnn.init_params(jax.random.key(0), cfg)
nbr, wts = g.neighbor_sample(96)
ref = np.asarray(gnn.forward(params, jnp.asarray(g.features),
                             jnp.asarray(nbr), jnp.asarray(wts), cfg))
part = partition(g, 8)
sub = build_local_subgraphs(g, part, sample=96)
feats = gather_features(g, part)
plan = build_halo_plan(part)
mesh = jax.make_mesh((8,), ("data",))
for mode in ("allgather", "alltoall"):
    fwd = make_decentralized_forward(mesh, cfg, plan, part.n_max, mode=mode)
    out = np.asarray(fwd(params, jnp.asarray(feats),
                         jnp.asarray(sub.neighbors), jnp.asarray(sub.weights)))
    for c in range(8):
        m = part.local_mask[c]
        np.testing.assert_allclose(out[c][m], ref[part.local_nodes[c][m]],
                                   rtol=1e-4, atol=1e-4)
print("DISTRIBUTED_OK")
"""


@pytest.mark.slow
def test_decentralized_equals_centralized_8dev():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", _DISTRIBUTED_SCRIPT],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)) or ".")
    assert "DISTRIBUTED_OK" in r.stdout, r.stdout + r.stderr
