"""Property-based backbone for ``streaming.delta`` (tests/_hyp shim-safe).

The contract under test (DESIGN.md §9): a ``GraphDelta`` buffer holding any
interleaved sequence of feature updates, edge adds, and edge removes —
including ordered cancellation (add→remove nets out, remove→add survives)
— must commit, via ``apply_deltas``, to exactly the graph a from-scratch
rebuild produces: same CSR structure, same renormalized edge weights and
1/(d+1) self loops, same features. And not just for the whole buffer: for
*every prefix* of the sequence, because a refresh policy may commit at any
tick boundary and the committed state must never depend on where the
buffer was cut.

The oracle replays the ops on a plain (dst, src) edge list — adds append,
removes drop every currently-present match — then rebuilds the raw CSR
and calls ``gcn_normalize`` from scratch. Dirt channels are validated
against the graphs themselves: ``feature_dirty`` must be exactly the
touched-node set, and every row whose aggregation inputs (neighbor list,
edge weights, or self-loop weight) differ from the base graph must be
``structure_dirty`` (soundness — a clean-marked row with changed inputs
would serve stale embeddings forever).
"""
import numpy as np

from _hyp import given, settings, st
from repro.core.graph import Graph, random_graph
from repro.streaming import GraphDelta, apply_deltas


def _ops(rng, g, n_ops: int) -> list:
    """Random interleaved op sequence over ``g``'s node set, biased toward
    collisions (removes drawn from live edges) and always ending in an
    explicit add→remove→re-add cancellation chain."""
    n, f = g.n_nodes, g.feature_len
    dst0 = np.repeat(np.arange(n), np.diff(g.indptr))
    live = list(zip(dst0.tolist(), g.indices.tolist()))
    ops = []
    for _ in range(n_ops):
        r = rng.random()
        if r < 0.4:
            m = int(rng.integers(1, 4))
            nodes = rng.choice(n, size=m, replace=False)
            ops.append(("feat", nodes,
                        rng.normal(size=(m, f)).astype(np.float32)))
        elif r < 0.7:
            m = int(rng.integers(1, 3))
            d, s = rng.integers(0, n, m), rng.integers(0, n, m)
            ops.append(("add", d, s))
            live += list(zip(d.tolist(), s.tolist()))
        else:
            if live and rng.random() < 0.8:
                pair = live[int(rng.integers(0, len(live)))]
            else:
                pair = (int(rng.integers(0, n)), int(rng.integers(0, n)))
            ops.append(("rm", np.array([pair[0]]), np.array([pair[1]])))
    d, s = int(rng.integers(0, n)), int(rng.integers(0, n))
    ops += [("add", np.array([d]), np.array([s])),
            ("rm", np.array([d]), np.array([s])),
            ("add", np.array([d]), np.array([s]))]
    return ops


def _delta_from(ops, n: int) -> GraphDelta:
    delta = GraphDelta(n)
    for kind, a, b in ops:
        if kind == "feat":
            delta.update_features(a, b)
        elif kind == "add":
            delta.add_edges(a, b)
        else:
            delta.remove_edges(a, b)
    return delta


def _oracle_rebuild(g_raw: Graph, ops) -> Graph:
    """From-scratch replay: plain edge list + feature table, then a fresh
    CSR build and gcn_normalize — no delta machinery involved."""
    n = g_raw.n_nodes
    dst0 = np.repeat(np.arange(n), np.diff(g_raw.indptr))
    pairs = list(zip(dst0.tolist(), g_raw.indices.tolist()))
    feats = g_raw.features.copy()
    for kind, a, b in ops:
        if kind == "feat":
            feats[a] = b
        elif kind == "add":
            pairs += list(zip(a.tolist(), b.tolist()))
        else:
            gone = (int(a[0]), int(b[0]))
            pairs = [p for p in pairs if p != gone]
    dst = np.array([p[0] for p in pairs], np.int64)
    src = np.array([p[1] for p in pairs], np.int64)
    order = np.argsort(dst, kind="stable")
    indptr = np.zeros(n + 1, np.int64)
    np.add.at(indptr, dst + 1, 1)
    return Graph(np.cumsum(indptr), src[order].astype(np.int32), None,
                 feats).gcn_normalize()


def _changed_rows(base: Graph, new: Graph) -> np.ndarray:
    """[N] bool: rows whose aggregation inputs differ between two
    normalized graphs (neighbor ids, edge weights, or self-loop)."""
    n = base.n_nodes
    changed = np.zeros(n, bool)
    for u in range(n):
        b = slice(int(base.indptr[u]), int(base.indptr[u + 1]))
        m = slice(int(new.indptr[u]), int(new.indptr[u + 1]))
        changed[u] = (
            b.stop - b.start != m.stop - m.start
            or not np.array_equal(base.indices[b], new.indices[m])
            or not np.allclose(base.edge_weight[b], new.edge_weight[m],
                               rtol=1e-6)
            or not np.isclose(base.self_loop[u], new.self_loop[u],
                              rtol=1e-6))
    return changed


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_ops=st.integers(3, 8),
       n=st.sampled_from([6, 13, 20]))
def test_property_every_prefix_equals_scratch_rebuild(seed, n_ops, n):
    rng = np.random.default_rng(seed)
    g_raw = random_graph(n, 3 * n, 4, seed=seed % 1000, weighted=False)
    g = g_raw.gcn_normalize()
    ops = _ops(rng, g, n_ops)
    for cut in range(len(ops) + 1):
        prefix = ops[:cut]
        res = apply_deltas(g, _delta_from(prefix, n))
        oracle = _oracle_rebuild(g_raw, prefix)

        # 1) graph identity with the from-scratch rebuild
        np.testing.assert_array_equal(res.graph.indptr, oracle.indptr,
                                      err_msg=f"prefix {cut}")
        np.testing.assert_array_equal(res.graph.indices, oracle.indices,
                                      err_msg=f"prefix {cut}")
        np.testing.assert_allclose(res.graph.edge_weight,
                                   oracle.edge_weight, rtol=1e-6,
                                   err_msg=f"prefix {cut}")
        np.testing.assert_allclose(res.graph.self_loop, oracle.self_loop,
                                   rtol=1e-6, err_msg=f"prefix {cut}")
        np.testing.assert_array_equal(res.graph.features, oracle.features,
                                      err_msg=f"prefix {cut}")

        # 2) feature dirt is exactly the touched-node set
        touched = np.zeros(n, bool)
        for kind, a, _ in prefix:
            if kind == "feat":
                touched[a] = True
        np.testing.assert_array_equal(res.feature_dirty, touched,
                                      err_msg=f"prefix {cut}")

        # 3) structure dirt is sound: every row whose aggregation inputs
        # moved vs the base graph is marked (the converse — over-marking —
        # costs recompute, never correctness)
        changed = _changed_rows(g, res.graph)
        missed = changed & ~res.structure_dirty
        assert not missed.any(), (
            f"prefix {cut}: rows {np.nonzero(missed)[0]} changed but "
            f"not structure_dirty")


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_cancelled_buffer_is_clean_structurally(seed):
    """A buffer whose every structural op cancels (add e → remove e, for e
    not in the base graph) must commit to the base structure exactly —
    prefix cuts inside the chain still see the intermediate states."""
    rng = np.random.default_rng(seed)
    g_raw = random_graph(12, 30, 3, seed=seed % 997, weighted=False)
    g = g_raw.gcn_normalize()
    present = set(zip(
        np.repeat(np.arange(12), np.diff(g.indptr)).tolist(),
        g.indices.tolist()))
    fresh = [(d, s) for d in range(12) for s in range(12)
             if (d, s) not in present]
    pairs = [fresh[int(rng.integers(0, len(fresh)))] for _ in range(3)]
    delta = GraphDelta(12)
    for d, s in pairs:
        delta.add_edges([d], [s])
    for d, s in pairs:
        delta.remove_edges([d], [s])
    res = apply_deltas(g, delta)
    np.testing.assert_array_equal(res.graph.indptr, g.indptr)
    np.testing.assert_array_equal(res.graph.indices, g.indices)
    np.testing.assert_allclose(res.graph.edge_weight, g.edge_weight,
                               rtol=1e-6)
    np.testing.assert_allclose(res.graph.self_loop, g.self_loop, rtol=1e-6)
