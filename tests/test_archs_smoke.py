"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step + a few decode steps on CPU; shapes + finiteness."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.models import build

# archs whose reduced-config step still exceeds ~10s on the CI CPU — run
# them under `-m slow` awareness (pytest --durations=15 polices the list)
SLOW_ARCHS = {"deepseek-v3-671b", "whisper-base", "recurrentgemma-9b",
              "minicpm3-4b", "grok-1-314b", "rwkv6-3b"}


def _arch_params(archs):
    return [pytest.param(a, marks=pytest.mark.slow) if a in SLOW_ARCHS
            else a for a in archs]


def _batch(cfg, b=2, s=16, key=0):
    k = jax.random.key(key)
    batch = {
        "tokens": jax.random.randint(k, (b, s), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.fold_in(k, 1), (b, s), 0,
                                     cfg.vocab),
    }
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(k, 2), (b, cfg.encoder.n_frames, cfg.d_model),
            jnp.dtype(cfg.dtype))
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(jnp.arange(s)[None, None], (3, b, s))
        batch["mrope_pos"] = pos
    return batch


@pytest.mark.parametrize("arch", _arch_params(ARCHS))
def test_train_step_smoke(arch):
    cfg = get_config(arch, smoke=True)
    model = build(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    (loss, aux), grads = jax.value_and_grad(model.loss, has_aux=True)(
        params, batch)
    assert np.isfinite(float(loss)), (arch, float(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves, arch
    for g in leaves:
        assert np.isfinite(np.asarray(g, dtype=np.float32)).all(), arch


@pytest.mark.parametrize("arch", _arch_params(ARCHS))
def test_decode_smoke(arch):
    cfg = get_config(arch, smoke=True)
    model = build(cfg)
    params = model.init(jax.random.key(1))
    b, cap = 2, 8
    caches = model.init_caches(b, cap)
    enc_kvs = None
    if cfg.is_encdec:
        frames = jax.random.normal(jax.random.key(2),
                                   (b, cfg.encoder.n_frames, cfg.d_model),
                                   jnp.dtype(cfg.dtype))
        enc_kvs = model._cross_kvs(params, model.encode(params, frames))
    tok = jnp.zeros((b, 1), jnp.int32)
    for i in range(3):
        logits, caches = model.decode_step(params, tok, caches,
                                           jnp.int32(i), enc_kvs=enc_kvs)
        assert logits.shape == (b, 1, cfg.vocab), arch
        assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
        tok = jnp.argmax(logits[:, :, :64], axis=-1).astype(jnp.int32)


@pytest.mark.parametrize("arch", _arch_params(["internlm2-1.8b", "rwkv6-3b",
                                  "recurrentgemma-9b", "h2o-danube-3-4b",
                                  "minicpm3-4b", "qwen2-vl-2b"]))
def test_prefill_decode_consistency(arch):
    """Greedy continuation from a prefill == teacher-forced decode chain."""
    cfg = get_config(arch, smoke=True)
    model = build(cfg)
    params = model.init(jax.random.key(3))
    b, s = 1, 8
    toks = jax.random.randint(jax.random.key(4), (b, s), 0, cfg.vocab)
    mrope = (jnp.broadcast_to(jnp.arange(s)[None, None], (3, b, s))
             if cfg.mrope_sections else None)
    last_logits, _ = model.prefill(params, toks, mrope_pos=mrope)
    # replay through decode: feed tokens one by one
    caches = model.init_caches(b, s + 2)
    logits = None
    for i in range(s):
        logits, caches = model.decode_step(params, toks[:, i:i + 1], caches,
                                           jnp.int32(i))
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(last_logits, np.float32),
                               rtol=0.15, atol=0.15)


def test_param_counts_in_range():
    """Full configs land near the published sizes (sanity on param math)."""
    expect = {"minicpm3-4b": (3e9, 6e9), "internlm2-1.8b": (1.4e9, 2.4e9),
              "h2o-danube-3-4b": (3e9, 5e9), "yi-34b": (30e9, 38e9),
              "grok-1-314b": (280e9, 340e9),
              "deepseek-v3-671b": (600e9, 720e9),
              "recurrentgemma-9b": (7e9, 11e9), "rwkv6-3b": (2.2e9, 4e9),
              "qwen2-vl-2b": (1.2e9, 2.4e9), "whisper-base": (5e7, 1.5e8)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, f"{n:.3g}")
