"""Ensure the tests directory is importable (for the _hyp hypothesis shim)
regardless of pytest's import mode / invocation directory."""
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running multi-device subprocess test")
