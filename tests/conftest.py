"""Shared fixtures: the canonical (setting, backend) parity grid, the
small-graph factory, and the centralized-oracle case — one definition for
the 3-backend x 3-setting loops that used to be copy-pasted across
test_semi_runtime.py, test_streaming.py, and test_kernels_fused_layer.py.

Also ensures the tests directory is importable (for the _hyp hypothesis
shim) regardless of pytest's import mode / invocation directory.
"""
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

import numpy as np
import pytest

# the canonical axes every parity grid draws from (keep in sync with
# repro.core.gnn.BACKENDS / repro.core.partition settings — asserted in
# test_semi_runtime.py)
SETTINGS = ("centralized", "decentralized", "semi")
BACKENDS = ("jnp", "pallas", "fused")
DISTRIBUTED_SETTINGS = ("decentralized", "semi")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running multi-device subprocess test")


@pytest.fixture(params=BACKENDS)
def backend(request):
    """Every kernel backend (jnp oracle, composed pallas, fused)."""
    return request.param


@pytest.fixture(params=SETTINGS)
def setting(request):
    """Every execution setting (paper Fig. 4 + §5)."""
    return request.param


@pytest.fixture(params=DISTRIBUTED_SETTINGS)
def distributed_setting(request):
    """Settings with an exchange to measure (centralized has none)."""
    return request.param


@pytest.fixture(params=[(s, b) for s in SETTINGS for b in BACKENDS],
                ids=lambda p: f"{p[0]}-{p[1]}")
def setting_backend(request):
    """The full 3-setting x 3-backend parity grid."""
    return request.param


@pytest.fixture
def make_graph():
    """Small-graph factory: a (by default gcn-normalized) random CSR graph
    with the skewed degree profile the runtime sees."""
    from repro.core.graph import random_graph

    def make(n=40, e=200, f=12, seed=1, normalize=True, weighted=True):
        g = random_graph(n, e, f, seed=seed, weighted=weighted)
        return g.gcn_normalize() if normalize else g
    return make


@pytest.fixture(scope="session")
def oracle_case():
    """Shared parity case: (graph, cfg, params, ref) where ``ref`` is the
    centralized full-graph embedding every setting/backend must match."""
    import jax
    from repro.core import gnn
    from repro.core.graph import random_graph
    from repro.core.partition import plan_execution
    g = random_graph(40, 200, 8, seed=0).gcn_normalize()
    cfg = gnn.GNNConfig(in_dim=8, hidden_dims=(16,), out_dim=4, sample=8)
    params = gnn.init_params(jax.random.key(0), cfg)
    cent = plan_execution(g, "centralized", sample=8)
    ref = cent.scatter(np.asarray(cent.make_forward(cfg)(params)))
    return g, cfg, params, ref
