"""Mapper/compiler subsystem (DESIGN.md §8): tiling exactness, allocation
under scarce vs plentiful inventories, the derived-vs-calibrated
cross-validation contract, and mapper-padded end-to-end execution."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import costmodel, gnn
from repro.core.graph import TABLE2_DATASETS, TAXI_STATS, random_graph
from repro.core.partition import plan_execution
from repro.kernels.crossbar_mvm import CrossbarNumerics
from repro.mapper import XbarInventory, execute_tiled, padded_grid, tile_layer
from repro.mapper.allocate import allocate
from repro.mapper.compile import compile_mapping, items_per_device


# ---------------------------------------------------------------- tiling

@settings(max_examples=30, deadline=None)
@given(f_in=st.integers(1, 400), f_out=st.integers(1, 200),
       rows=st.integers(1, 96), cols=st.integers(1, 96),
       seed=st.integers(0, 2**31 - 1))
def test_property_tiled_execution_equals_dense(f_in, f_out, rows, cols, seed):
    """Mapper-tiled execution on ideal numerics is *exactly* the dense
    matmul for any layer shape x crossbar geometry: integer-valued inputs
    make the tile-order-independent sum bit-exact."""
    rng = np.random.default_rng(seed)
    x = rng.integers(-8, 9, size=(5, f_in)).astype(np.float64)
    w = rng.integers(-8, 9, size=(f_in, f_out)).astype(np.float64)
    t = tile_layer(f_in, f_out, rows, cols)
    out = execute_tiled(x, w, t)
    np.testing.assert_array_equal(out, x @ w)


def test_padded_grid_divisibility_and_minimality():
    g = padded_grid(33, 216, 100, rows_per_xbar=128, bm=8, bn=16)
    assert g.m_pad % g.bm == 0 and g.k_pad % g.bk == 0 and g.n_pad % g.bn == 0
    assert g.m_pad - 33 < g.bm and g.k_pad - 216 < g.bk
    assert g.n_pad - 100 < g.bn
    assert g.grid == (g.m_pad // 8, g.n_pad // 16, g.k_pad // 128)
    with pytest.raises(ValueError):
        padded_grid(0, 216, 100, 128)
    with pytest.raises(ValueError):
        padded_grid(1, 1, 1, 0)


def test_bit_slicing_plan():
    # 8-bit weights on 2-bit cells: 4 physical columns per logical weight
    t = tile_layer(216, 128, rows=128, cols=128, w_bits=8, cell_bits=2)
    assert t.bit_slices == 4
    assert t.logical_cols == 32
    assert t.n_tiles == 4 and t.k_tiles == 2
    # slicing multiplies occupied arrays but stores the same useful bits
    base = tile_layer(216, 128, rows=128, cols=128)
    assert t.n_arrays == 4 * base.n_arrays
    assert t.utilization == pytest.approx(base.utilization)
    with pytest.raises(ValueError):    # one weight cannot span the array
        tile_layer(8, 8, rows=8, cols=2, w_bits=8, cell_bits=1)


def test_tiling_matches_calibration_workload():
    """The taxi calibration layer (216 -> 128 on 128x128 fx crossbars) must
    occupy exactly 2 arrays — the fx pass count the cost model inverts."""
    t = tile_layer(216, 128, rows=128, cols=128)
    assert t.n_arrays == 2 and t.k_tiles == 2 and t.n_tiles == 1
    assert t.pad_k == 40 and t.pad_n == 0
    assert 0.8 < t.utilization < 0.9


# ------------------------------------------------------------ allocation

def test_allocation_scarce_serializes():
    """One item's tiles overflow the pool -> time-multiplexed groups."""
    a = allocate("fx", tiles_per_item=10, n_items=4, arrays=3)
    assert a.groups == 4 and a.copies == 1 and not a.resident
    assert a.rounds == 4 * 4            # ceil(4/1) * 4 groups
    assert a.tile_passes == 40
    assert a.arrays_used == 3
    assert 0 < a.occupancy <= 1.0


def test_allocation_plentiful_duplicates():
    """Arrays to spare -> weight duplication, items processed in parallel."""
    a = allocate("fx", tiles_per_item=2, n_items=1000, arrays=256)
    assert a.copies == 128 and a.groups == 1 and a.resident
    assert a.rounds == -(-1000 // 128)  # 8 parallel waves
    assert a.arrays_used == 256
    assert a.tile_passes == 2000
    # more arrays -> never more rounds
    b = allocate("fx", tiles_per_item=2, n_items=1000, arrays=512)
    assert b.rounds <= a.rounds


def test_allocation_monotone_in_arrays():
    for tiles in (1, 3, 7):
        rounds = [allocate("agg", tiles, 500, arrays).rounds
                  for arrays in (1, 2, 8, 64, 1024)]
        assert rounds == sorted(rounds, reverse=True)
        assert rounds[-1] >= 1


# ------------------------------------- derived vs calibrated cross-check

@pytest.mark.parametrize("setting", ["centralized", "decentralized"])
def test_derived_matches_calibrated_at_paper_geometry(setting):
    """The contract: at the paper's own crossbar geometry the mapper-derived
    rollup reproduces the calibrated Table-1 taxi latencies (< 10%; the
    residual is ceil-rounding of fractional pass rounds)."""
    cal = costmodel.predict(setting, TAXI_STATS)
    der = costmodel.predict(setting, TAXI_STATS, mode="derived")
    assert der.t_compute == pytest.approx(cal.t_compute, rel=0.10)
    # per-core rows too, not just the sum
    for core in ("traversal", "aggregation", "feature_extraction"):
        assert getattr(der.compute, core) == pytest.approx(
            getattr(cal.compute, core), rel=0.10)


def test_derived_diverges_beyond_calibration():
    """Away from the calibration point the two modes *must* part ways: the
    calibrated constants are workload-independent, the derived rollup sees
    cora's 1433-dim features (more aggregation/fx tiles). That divergence
    is the mapper's added information, not an error."""
    stats = TABLE2_DATASETS["cora"]
    cal = costmodel.predict("centralized", stats)
    der = costmodel.predict("centralized", stats, mode="derived")
    assert der.t_compute > cal.t_compute * 1.5


def test_derived_sees_geometry():
    """Re-geometried inventories move the derived rollup; the calibrated
    path cannot react at all."""
    inv = XbarInventory.from_hardware(costmodel.DEFAULT_HW, "centralized")
    der_paper = costmodel.predict("centralized", TAXI_STATS, mode="derived",
                                  inventory=inv)
    der_small = costmodel.predict("centralized", TAXI_STATS, mode="derived",
                                  inventory=inv.with_xbar_size(64))
    assert der_small.t_compute != pytest.approx(der_paper.t_compute,
                                                rel=1e-3)


def test_predict_rejects_unknown_mode():
    with pytest.raises(ValueError, match="unknown mode"):
        costmodel.predict("centralized", TAXI_STATS, mode="tabulated")


def test_compile_mapping_report_and_energy():
    m = compile_mapping((216, 128), TAXI_STATS, setting="centralized")
    rep = m.mapping_report()
    for needle in ("CompiledMapping[centralized]", "inventory:", "layer 0",
                   "allocation:", "T_compute"):
        assert needle in rep, needle
    assert m.energy_j > 0
    assert 0 < m.weight_utilization <= 1
    assert m.t_compute_pipelined <= m.t_compute
    assert items_per_device("centralized", 10_000) == 9999
    assert items_per_device("decentralized", 10_000) == 1
    assert items_per_device("semi", 10_000, 16) == 624


def test_compile_mapping_bit_slices_on_low_precision_cells():
    """Weight precision defaults to the stack-wide 8 bits (a numerics
    property), so 2-bit cells must quadruple the occupied fx arrays — it
    used to default to cell_bits, silently disabling bit-slicing."""
    import dataclasses
    base = compile_mapping((216, 128), TAXI_STATS, setting="centralized")
    inv2 = dataclasses.replace(base.inventory, cell_bits=2)
    sliced = compile_mapping((216, 128), TAXI_STATS, setting="centralized",
                             inventory=inv2)
    assert sliced.layers[0].tiling.bit_slices == 4
    assert sliced.weight_arrays == 4 * base.weight_arrays
    assert sliced.energy_j > base.energy_j


def test_compile_mapping_validates_inputs():
    with pytest.raises(ValueError):
        compile_mapping((216,), TAXI_STATS)          # < 2 dims
    with pytest.raises(ValueError):
        compile_mapping((216, 128), TAXI_STATS, setting="federated")
    with pytest.raises(ValueError):
        XbarInventory(fx_arrays=0)


# --------------------------------------------- end-to-end through the plan

def test_plan_carries_mapping():
    g = random_graph(64, 400, 216, seed=0).gcn_normalize()
    plan = plan_execution(g, "decentralized", backend="fused", sample=4,
                          n_clusters=2)
    assert plan.mapping is None
    cfg = gnn.GNNConfig(in_dim=216, hidden_dims=(40,), out_dim=8, sample=4)
    rep = plan.mapping_report(cfg)
    assert "216x40" in rep and plan.mapping is not None
    assert plan.mapping.setting == "decentralized"
    # cached: a second bare call reuses the compiled mapping
    assert plan.mapping_report() == rep
    # ... but any argument (including hw) forces a recompile
    import dataclasses
    slow = dataclasses.replace(costmodel.DEFAULT_HW, t2=costmodel.DEFAULT_HW.t2 * 100)
    assert plan.mapping_report(hw=slow) != rep


def test_unmappable_shape_executes_via_mapper_padding():
    """F_in=216 with rows_per_xbar=128 (non-divisible, the ISSUE's example)
    runs end-to-end through ExecutionPlan on the fused backend with
    bit-accurate numerics, matching the composed jnp oracle."""
    quant = CrossbarNumerics(in_bits=8, w_bits=8, adc_bits=12,
                             rows_per_xbar=128)
    g = random_graph(48, 300, 216, seed=1).gcn_normalize()
    cfg = gnn.GNNConfig(in_dim=216, hidden_dims=(40,), out_dim=8, sample=4,
                        numerics=quant, backend="fused")
    import jax
    params = gnn.init_params(jax.random.key(0), cfg)
    plan = plan_execution(g, "centralized", backend="fused", sample=4)
    out = plan.scatter(np.asarray(plan.make_forward(cfg)(params)))
    ref_plan = plan_execution(g, "centralized", backend="jnp", sample=4)
    ref = ref_plan.scatter(np.asarray(ref_plan.make_forward(cfg)(params)))
    scale = float(np.abs(ref).max()) or 1.0
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4 * scale)
    # and the mapper's tiling is what the ops layer padded to
    grid = padded_grid(48, 216, 40, 128)
    assert grid.k_pad == 256 and grid.k_tiles == 2
