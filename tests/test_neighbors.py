"""CAM-backed k-NN construction: signatures, scoring, selection, scenarios.

The contract under test is *result equivalence*: the CAM path (jnp oracle
or Pallas kernel) and the host top-k fallback must produce bit-identical
graphs — same CSR triple, same weights — on every input. Everything else
(signature determinism, tag injectivity, selection ordering) feeds that.
"""
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.neighbors import (NEIGHBOR_MODES, SCENARIOS, band_match_counts,
                             knn_graph, lsh_signatures, scenario_features,
                             scenario_graph, select_topk, tag_bands)


def _feats(n, f, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal((n, f)) * scale).astype(np.float32)


# ---------------------------------------------------------------- signatures

def test_signatures_deterministic_and_seeded():
    x = _feats(40, 16, seed=1)
    a = lsh_signatures(x, n_bands=4, band_bits=6, seed=7)
    b = lsh_signatures(x, n_bands=4, band_bits=6, seed=7)
    c = lsh_signatures(x, n_bands=4, band_bits=6, seed=8)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    assert a.shape == (40, 4) and a.dtype == np.int32
    assert a.min() >= 0 and a.max() < 2 ** 6


def test_identical_rows_identical_signatures():
    x = _feats(8, 12, seed=2)
    x[5] = x[0]
    s = lsh_signatures(x, n_bands=6, band_bits=8)
    np.testing.assert_array_equal(s[5], s[0])


def test_signature_validation():
    x = _feats(4, 8)
    with pytest.raises(ValueError, match="n_bands"):
        lsh_signatures(x, n_bands=0)
    with pytest.raises(ValueError, match="band_bits"):
        lsh_signatures(x, band_bits=0)
    with pytest.raises(ValueError, match=r"\[N, F\]"):
        lsh_signatures(x[0])
    with pytest.raises(ValueError, match="int32 CAM entry"):
        lsh_signatures(x, n_bands=4096, band_bits=20)


def test_tag_bands_injective_across_bands():
    """Band b's tag range never overlaps band b+1's: a CAM equality match
    on tags can only come from the *same* band agreeing."""
    sigs = np.stack([np.zeros(3, np.int32),
                     np.full(3, (1 << 8) - 1, np.int32)]).T  # [3, 2]
    tags = tag_bands(sigs, band_bits=8).reshape(3, 2)
    assert tags[0, 0] == 0
    assert tags[0, 1] == 2 * 256 - 1
    # max tag of band 0 (255) < min tag of band 1 (256)
    assert tags[:, 0].max() < 256 <= tags[:, 1].min()


def test_tag_bands_range_guard():
    with pytest.raises(ValueError, match="must lie in"):
        tag_bands(np.full((2, 2), 300, np.int32), band_bits=8)


# ------------------------------------------------------------------- scoring

@pytest.mark.parametrize("n,f", [(17, 8), (64, 24)])
def test_band_match_counts_three_paths_identical(n, f):
    x = _feats(n, f, seed=3)
    sig = lsh_signatures(x, n_bands=5, band_bits=7)
    ref = band_match_counts(sig, sig, mode="topk", band_bits=7)
    for mode, backend in (("cam", "jnp"), ("cam", "pallas")):
        got = band_match_counts(sig, sig, mode=mode, backend=backend,
                                band_bits=7, interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_band_match_counts_diagonal_is_band_count():
    """Every node agrees with itself on all bands."""
    x = _feats(12, 8, seed=4)
    sig = lsh_signatures(x, n_bands=6, band_bits=5)
    counts = np.asarray(band_match_counts(sig, sig, mode="topk",
                                          band_bits=5))
    np.testing.assert_array_equal(np.diag(counts), np.full(12, 6))


# ----------------------------------------------------------------- selection

def test_select_topk_orders_by_count_then_id():
    counts = np.array([[3, 9, 9, 1, 5]], np.int32)
    nbr, score = select_topk(counts, k=3)
    np.testing.assert_array_equal(nbr[0], [1, 2, 4])
    np.testing.assert_array_equal(score[0], [9, 9, 5])


def test_select_topk_exclude_self():
    counts = np.array([[9, 2, 5], [1, 9, 5], [1, 2, 9]], np.int32)
    nbr, _ = select_topk(counts, k=1, exclude_self=True)
    np.testing.assert_array_equal(nbr.ravel(), [2, 2, 1])


def test_select_topk_k_bounds():
    counts = np.ones((2, 4), np.int32)
    with pytest.raises(ValueError, match="k"):
        select_topk(counts, k=0)
    with pytest.raises(ValueError, match="k"):
        select_topk(counts, k=5, exclude_self=True)


def test_select_topk_large_counts_no_overflow():
    """Counts near the packing headroom still order correctly; counts past
    it raise instead of silently wrapping in the int32 top-k key."""
    counts = np.array([[2 ** 20, 2 ** 20 + 1, 1]], np.int32)
    nbr, _ = select_topk(counts, k=2)
    np.testing.assert_array_equal(nbr[0], [1, 0])
    with pytest.raises(ValueError, match="overflow"):
        select_topk(np.array([[2 ** 30, 1, 0]], np.int32), k=1)


# --------------------------------------------------------------- full graphs

@pytest.mark.parametrize("mode,backend", [("cam", "jnp"), ("cam", "pallas")])
def test_knn_graph_equivalent_to_topk(mode, backend):
    x = _feats(50, 16, seed=5, scale=2.0)
    ref = knn_graph(x, k=6, mode="topk")
    got = knn_graph(x, k=6, mode=mode, backend=backend, interpret=True)
    np.testing.assert_array_equal(got.indptr, ref.indptr)
    np.testing.assert_array_equal(got.indices, ref.indices)
    np.testing.assert_array_equal(got.edge_weight, ref.edge_weight)


def test_knn_graph_min_bands_prunes():
    x, _ = scenario_features("recsys", n_nodes=60, feature_len=16, seed=6)
    loose = knn_graph(x, k=5, min_bands=1)
    tight = knn_graph(x, k=5, min_bands=4)
    assert 0 < tight.n_edges <= loose.n_edges
    assert float(tight.edge_weight.min()) >= 4 / 8 - 1e-6


def test_knn_graph_validation():
    x = _feats(10, 8)
    with pytest.raises(ValueError, match="mode"):
        knn_graph(x, k=3, mode="hash")
    with pytest.raises(ValueError, match="k"):
        knn_graph(x, k=0)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(12, 40), f=st.integers(4, 20),
       k=st.integers(1, 6), seed=st.integers(0, 5))
def test_knn_graph_property_equivalence(n, f, k, seed):
    """Property sweep: any size/seed, the three paths agree bit-for-bit."""
    x = _feats(n, f, seed=seed)
    k = min(k, n - 1)
    ref = knn_graph(x, k=k, mode="topk")
    for backend in ("jnp", "pallas"):
        got = knn_graph(x, k=k, mode="cam", backend=backend, interpret=True)
        np.testing.assert_array_equal(got.indptr, ref.indptr)
        np.testing.assert_array_equal(got.indices, ref.indices)
        np.testing.assert_array_equal(got.edge_weight, ref.edge_weight)


# ----------------------------------------------------------------- scenarios

def test_scenario_features_shapes_and_determinism():
    for name in SCENARIOS:
        x1, y1 = scenario_features(name, n_nodes=64, feature_len=16, seed=3)
        x2, y2 = scenario_features(name, n_nodes=64, feature_len=16, seed=3)
        assert x1.shape == (64, 16) and x1.dtype == np.float32
        assert y1.shape == (64,)
        np.testing.assert_array_equal(x1, x2)
        np.testing.assert_array_equal(y1, y2)


def test_recsys_topics_cluster_in_graph():
    """Same-topic nodes share LSH bands far more often than cross-topic:
    the built graph should connect mostly within topics."""
    x, topics = scenario_features("recsys", n_nodes=96, feature_len=24,
                                  seed=0, n_topics=4)
    g = knn_graph(x, k=5)
    src = np.repeat(np.arange(g.n_nodes), np.diff(g.indptr))
    same = topics[src] == topics[g.indices]
    assert same.mean() > 0.8


def test_anomaly_labels_marked():
    _, y = scenario_features("anomaly", n_nodes=200, feature_len=16,
                             anomaly_frac=0.1, seed=1)
    assert 10 <= int(y.sum()) <= 30


def test_scenario_graph_paths_agree():
    for name in SCENARIOS:
        ref = scenario_graph(name, n_nodes=48, feature_len=12, k=4,
                             neighbor_mode="topk")
        got = scenario_graph(name, n_nodes=48, feature_len=12, k=4,
                             neighbor_mode="cam", interpret=True)
        np.testing.assert_array_equal(got.indices, ref.indices)
        np.testing.assert_array_equal(got.edge_weight, ref.edge_weight)


def test_scenario_validation():
    with pytest.raises(ValueError, match="scenario"):
        scenario_features("webscale")
    with pytest.raises(ValueError, match="n_nodes"):
        scenario_features("recsys", n_nodes=0)


def test_modes_tuple_matches_planner_axis():
    """repro.neighbors and the (numpy-only) planner space must agree on
    the mode vocabulary — they are kept in sync by hand."""
    from repro.planner import NEIGHBOR_MODES as planner_modes
    assert tuple(planner_modes) == tuple(NEIGHBOR_MODES)
