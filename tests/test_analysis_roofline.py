"""analysis/roofline.py and analysis/breakdown.py against hand-computed
ground truth — the bound arithmetic the tuner's pruning (repro.tuning)
and the perf-trajectory benches now lean on.
"""
import json
import sys

import jax
import jax.numpy as jnp

from repro.analysis.breakdown import instruction_rows
from repro.analysis.hlo import ModuleCost
from repro.analysis.roofline import HW, V5E, roofline_terms


def _compiled_text(fn, *structs):
    return jax.jit(fn).lower(*structs).compile().as_text()


# ---- roofline_terms bound correctness ------------------------------------

def test_bound_is_max_term_and_dominant_names_it():
    hw = HW(peak_flops=100.0, hbm_bw=10.0, link_bw=1.0, n_links=2)
    t = roofline_terms(ModuleCost(flops=200.0, hbm_bytes=30.0,
                                  collective_bytes=2.0), hw)
    assert t.compute_s == 2.0
    assert t.memory_s == 3.0
    assert t.collective_s == 1.0
    assert t.bound_s == 3.0
    assert t.dominant == "memory"


def test_hand_computed_v5e_intensity_crossover():
    """The v5e compute/memory balance point is peak_flops/hbm_bw ≈ 240.5
    flops/byte: a kernel above it must be compute-dominant, below it
    memory-dominant."""
    balance = V5E.peak_flops / V5E.hbm_bw
    above = roofline_terms(ModuleCost(flops=(balance * 2) * 1e6,
                                      hbm_bytes=1e6))
    below = roofline_terms(ModuleCost(flops=(balance / 2) * 1e6,
                                      hbm_bytes=1e6))
    assert above.dominant == "compute" and below.dominant == "memory"
    assert above.bound_s == above.compute_s
    assert below.bound_s == below.memory_s


def test_degenerate_zero_cost_is_all_zero_not_nan():
    t = roofline_terms(ModuleCost(flops=0.0, hbm_bytes=0.0,
                                  collective_bytes=0.0))
    assert t.compute_s == t.memory_s == t.collective_s == 0.0
    assert t.bound_s == 0.0
    assert t.useful_ratio == 0.0          # no division by zero flops
    assert t.dominant in ("compute", "memory", "collective")
    d = t.as_dict()
    assert d["flops"] == 0.0 and d["useful_ratio"] == 0.0


def test_zero_flop_memory_only_cost():
    t = roofline_terms(ModuleCost(flops=0.0, hbm_bytes=V5E.hbm_bw))
    assert t.compute_s == 0.0
    assert abs(t.memory_s - 1.0) < 1e-12
    assert t.dominant == "memory" and t.bound_s == t.memory_s


def test_duck_typed_launch_cost_matches_module_cost():
    """repro.tuning.LaunchCost feeds the same roofline math ModuleCost
    does — the pruning contract (DESIGN.md §11)."""
    from repro.tuning import LaunchCost
    lc = LaunchCost(flops=3.94e12, hbm_bytes=8.19e9, vmem_bytes=0,
                    grid_steps=1, collective_bytes=4e9)
    mc = ModuleCost(flops=3.94e12, hbm_bytes=8.19e9, collective_bytes=4e9)
    a, b = roofline_terms(lc), roofline_terms(mc)
    assert (a.compute_s, a.memory_s, a.collective_s) == \
           (b.compute_s, b.memory_s, b.collective_s)


# ---- roofline_table agreement with hand-computed terms -------------------

def test_roofline_table_renders_hand_computed_terms(tmp_path, capsys):
    """The table's ms columns must be exactly the recorded roofline terms
    (x1e3), records dedup by (arch, shape, mesh) with last-wins, and
    failed records count toward the return code."""
    t = roofline_terms(ModuleCost(flops=197e12 * 0.25,
                                  hbm_bytes=819e9 * 0.125),
                       model_flops=197e12 * 0.125)
    stale = dict(arch="a1", shape="s", mesh="1x1", ok=True,
                 roofline=dict(t.as_dict(), compute_s=99.0),
                 memory={"live_bytes": 2 ** 30})
    fresh = dict(stale, roofline=t.as_dict())
    bad = dict(arch="a2", shape="s", mesh="1x1", ok=False, error="boom")
    path = tmp_path / "dryrun.jsonl"
    path.write_text("not json\n" + "\n".join(
        json.dumps(r) for r in (stale, fresh, bad)) + "\n")

    sys.path.insert(0, "benchmarks")
    from benchmarks import roofline_table
    rc = roofline_table.main(path=str(path))
    out = capsys.readouterr().out
    assert rc == 1                              # the failed record
    assert f"{0.25 * 1e3:9.2f}" in out          # compute_s == 0.25 s
    assert f"{0.125 * 1e3:9.2f}" in out         # memory_s  == 0.125 s
    assert "compute" in out                     # dominant column
    assert f"{0.5:7.2f}" in out                 # useful_ratio
    assert "99000" not in out                   # stale record superseded
    assert "boom" in out


# ---- breakdown.instruction_rows ------------------------------------------

def test_instruction_rows_charges_dot_flops_exactly():
    a = jax.ShapeDtypeStruct((32, 48), jnp.float32)
    b = jax.ShapeDtypeStruct((48, 16), jnp.float32)
    rows = instruction_rows(_compiled_text(lambda a, b: a @ b, a, b))
    dot_flops = sum(f for _, f, _, op, _ in rows if op.startswith("dot"))
    assert dot_flops == 2 * 32 * 48 * 16


def test_instruction_rows_scales_by_while_trip_count():
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        c, _ = jax.lax.scan(body, x, w)
        return c

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((12, 64, 64), jnp.float32)
    rows = instruction_rows(_compiled_text(f, x, w))
    total_flops = sum(f for _, f, _, _, _ in rows)
    assert total_flops == 2 * 64 ** 3 * 12
    # the scan-body dot is charged with the x12 multiplier, visibly
    assert any(m == 12 and f > 0 for _, f, m, _, _ in rows)


def test_instruction_rows_agrees_with_analyze_module():
    """The per-instruction rows are the decomposition of analyze_module's
    totals: summing them must reproduce the module-level dot flops."""
    from repro.analysis import analyze_module

    def f(a, b, c):
        return (a @ b) @ c

    a = jax.ShapeDtypeStruct((16, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((32, 24), jnp.float32)
    c = jax.ShapeDtypeStruct((24, 8), jnp.float32)
    text = _compiled_text(f, a, b, c)
    rows = instruction_rows(text)
    assert sum(f for _, f, _, _, _ in rows) == analyze_module(text).flops
