"""Optimizer + data-pipeline invariants (unit + hypothesis property tests)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.data.tokens import TokenStream, synthetic_batch
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, compressed_psum, int8_compress,
                         int8_decompress)


# ------------------------------------------------------------ AdamW
def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.2, weight_decay=0.0, warmup=1)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(150):
        grads = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(loss(params)) < 1e-3


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 16),
       max_norm=st.floats(0.1, 10.0))
def test_clip_by_global_norm_property(seed, max_norm):
    k = jax.random.key(seed)
    g = {"a": jax.random.normal(k, (7,)) * 10,
         "b": jax.random.normal(jax.random.fold_in(k, 1), (3, 2)) * 10}
    clipped, gn = clip_by_global_norm(g, max_norm)
    cn = float(jnp.sqrt(sum(jnp.sum(x ** 2)
                            for x in jax.tree.leaves(clipped))))
    assert cn <= max_norm * 1.01
    if float(gn) <= max_norm:   # below threshold: untouched
        for x, y in zip(jax.tree.leaves(g), jax.tree.leaves(clipped)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-6)


def test_adamw_step_counter_and_dtype():
    params = {"w": jnp.zeros((4,), jnp.bfloat16)}
    state = adamw_init(params)
    g = {"w": jnp.ones((4,), jnp.bfloat16)}
    params, state, _ = adamw_update(params, g, state, AdamWConfig())
    assert int(state["step"]) == 1
    assert params["w"].dtype == jnp.bfloat16
    assert state["m"]["w"].dtype == jnp.float32


# ------------------------------------------------------------ compression
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 16), scale=st.floats(1e-3, 1e3))
def test_int8_roundtrip_error_bound(seed, scale):
    g = jax.random.normal(jax.random.key(seed), (64,)) * scale
    q, s, resid = int8_compress(g, jnp.zeros_like(g))
    back = int8_decompress(q, s)
    # quantization error bounded by one step, and captured by the residual
    assert float(jnp.max(jnp.abs(back - g))) <= float(s) * 0.5 + 1e-9
    np.testing.assert_allclose(np.asarray(back + resid), np.asarray(g),
                               rtol=1e-5, atol=1e-6)


def test_error_feedback_unbiased_over_steps():
    """With error feedback, the cumulative transmitted sum tracks the true
    cumulative gradient (bias does not accumulate)."""
    g = jnp.full((16,), 0.003)
    resid = jnp.zeros_like(g)
    sent = jnp.zeros_like(g)
    for _ in range(200):
        q, s, resid = int8_compress(g, resid)
        sent = sent + int8_decompress(q, s)
    np.testing.assert_allclose(np.asarray(sent), np.asarray(g) * 200,
                               rtol=0.02)


def test_compressed_psum_matches_mean():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))
    g = jnp.linspace(-1, 1, 32).reshape(4, 8)
    r = jnp.zeros_like(g)
    fn = shard_map(lambda g, r: compressed_psum(g, r, "data"), mesh=mesh,
                   in_specs=(P(), P()), out_specs=(P(), P()),
                   check_rep=False)
    mean, _ = fn(g, r)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(g), atol=0.02)


# ------------------------------------------------------------ data
def test_token_stream_deterministic_and_step_indexed():
    s1 = TokenStream(vocab=97, batch=4, seq=16, seed=3)
    s2 = TokenStream(vocab=97, batch=4, seq=16, seed=3)
    b5 = s1.batch_at(5)
    np.testing.assert_array_equal(np.asarray(b5["tokens"]),
                                  np.asarray(s2.batch_at(5)["tokens"]))
    assert not np.array_equal(np.asarray(b5["tokens"]),
                              np.asarray(s1.batch_at(6)["tokens"]))


def test_token_labels_shifted():
    b = synthetic_batch(53, 2, 12, seed=0, step=0)
    np.testing.assert_array_equal(np.asarray(b["labels"][:, :-1]),
                                  np.asarray(b["tokens"][:, 1:]))
    assert np.all(np.asarray(b["labels"][:, -1]) == -1)


def test_graph_batches_deterministic():
    from repro.core.graph import random_graph
    from repro.data.graphs import graph_batches
    g = random_graph(64, 256, 8, seed=0)
    it1 = graph_batches(g, 16, 4, seed=1)
    it2 = graph_batches(g, 16, 4, seed=1)
    for _ in range(3):
        b1, b2 = next(it1), next(it2)
        np.testing.assert_array_equal(b1["node_ids"], b2["node_ids"])
        np.testing.assert_array_equal(b1["neighbors"], b2["neighbors"])
