"""MoE grouped gather-dispatch: dense-oracle equivalence + properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.models.config import ModelConfig, MoEConfig
from repro.models.moe import _group_dispatch, _route, init_moe, moe_ffn


def _cfg(e=8, k=2, cap_f=8.0, d=32, f=16, shared=0, router="softmax"):
    return ModelConfig(
        name="t", n_layers=2, d_model=d, n_heads=4, n_kv_heads=4, d_ff=64,
        vocab=64, moe=MoEConfig(n_experts=e, top_k=k, d_ff_expert=f,
                                capacity_factor=cap_f, n_shared=shared,
                                router=router))


def _dense_oracle(params, x2d, ids, gates, cfg):
    e_ff = cfg.moe.d_ff_expert
    out = np.zeros((x2d.shape[0], cfg.d_model), np.float32)
    wi = np.asarray(params["wi"], np.float32)
    wo = np.asarray(params["wo"], np.float32)
    xf = np.asarray(x2d, np.float32)
    for t in range(x2d.shape[0]):
        for j in range(cfg.moe.top_k):
            e = int(ids[t, j])
            g = float(gates[t, j])
            h = xf[t] @ wi[e]
            gt, up = h[:e_ff], h[e_ff:]
            out[t] += g * ((gt / (1 + np.exp(-gt))) * up @ wo[e])
    return out


@pytest.mark.parametrize("router", ["softmax", "sigmoid"])
def test_moe_matches_dense_oracle_no_drops(router):
    cfg = _cfg(router=router)
    params = init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    out, aux = moe_ffn(params, x, cfg)
    assert float(aux["dropped_frac"]) == 0.0
    ids, gates = _route(params, x.reshape(-1, cfg.d_model), cfg)
    ref = _dense_oracle(params, x.reshape(-1, cfg.d_model), ids, gates, cfg)
    got = np.asarray(out.reshape(-1, cfg.d_model), np.float32)
    np.testing.assert_allclose(got, ref,
                               atol=0.05 * np.abs(ref).max() + 1e-3)


def test_shared_expert_added():
    cfg0 = _cfg(shared=0)
    cfg1 = _cfg(shared=1)
    p1 = init_moe(jax.random.key(0), cfg1)
    x = jax.random.normal(jax.random.key(1), (1, 8, cfg1.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    out1, _ = moe_ffn(p1, x, cfg1)
    p0 = {k: v for k, v in p1.items() if not k.startswith("shared")}
    out0, _ = moe_ffn(p0, x, cfg0)
    assert not np.allclose(np.asarray(out0, np.float32),
                           np.asarray(out1, np.float32))


@pytest.mark.slow          # >10s on the CI CPU (--durations=15)
@settings(max_examples=20, deadline=None)
@given(s=st.integers(4, 32), e=st.integers(2, 8), k=st.integers(1, 3),
       seed=st.integers(0, 2 ** 16))
def test_dispatch_properties(s, e, k, seed):
    """Every kept slot lands in the right expert row; capacity respected."""
    k = min(k, e)
    key = jax.random.key(seed)
    d = 8
    x = jax.random.normal(key, (s, d), jnp.float32)
    ids = jax.random.randint(jax.random.fold_in(key, 1), (s, k), 0, e)
    cap = max(int(2.0 * s * k / e), 1)
    buf, (flat_ids, rank, keep) = _group_dispatch(x, ids, e, cap)
    buf = np.asarray(buf)
    flat_ids, rank, keep = map(np.asarray, (flat_ids, rank, keep))
    assert buf.shape == (e, cap, d)
    # kept slots: buf[expert, rank] == x[token]
    for slot in range(s * k):
        t = slot // k
        if keep[slot]:
            np.testing.assert_array_equal(buf[flat_ids[slot], rank[slot]],
                                          np.asarray(x)[t])
    # per-expert kept count never exceeds capacity
    for ee in range(e):
        assert (keep & (flat_ids == ee)).sum() <= cap
    # unfilled capacity rows are zero
    counts = np.bincount(flat_ids[keep], minlength=e)
    for ee in range(e):
        assert np.all(buf[ee, counts[ee]:] == 0)


def test_capacity_drops_accounted():
    cfg = _cfg(e=2, k=1, cap_f=0.5)
    params = init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, 32, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    _, aux = moe_ffn(params, x, cfg)
    assert 0.0 < float(aux["dropped_frac"]) < 1.0
