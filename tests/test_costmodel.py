"""Paper-validation tests: the cost model must reproduce IMA-GNN's published
numbers (Table 1, the ~790x/~1400x headline averages, Fig. 8 trends)."""
import math

import pytest
from _hyp import given, settings, st

from repro.core import (TABLE2_DATASETS, TAXI_STATS, DEFAULT_HW, GraphStats,
                        predict, headline_averages, table1, pick_setting)


def test_table1_centralized():
    t = table1()["centralized"]
    assert t["traversal_s"] == pytest.approx(38.43e-9, rel=1e-3)
    assert t["aggregation_s"] == pytest.approx(142.77e-6, rel=1e-3)
    assert t["feature_extraction_s"] == pytest.approx(14.53e-6, rel=1e-3)
    assert t["computation_s"] == pytest.approx(157.34e-6, rel=2e-3)
    assert t["communication_s"] == pytest.approx(3.30e-3, rel=1e-3)
    assert t["p_compute_w"] == pytest.approx(823.11e-3, rel=1e-3)


def test_table1_decentralized():
    t = table1()["decentralized"]
    assert t["traversal_s"] == pytest.approx(7.68e-9, rel=2e-3)
    assert t["aggregation_s"] == pytest.approx(14.27e-6, rel=2e-3)
    assert t["feature_extraction_s"] == pytest.approx(0.37e-6, rel=6e-3)
    assert t["computation_s"] == pytest.approx(14.6e-6, rel=5e-3)
    assert t["communication_s"] == pytest.approx(406e-3, rel=1e-3)
    assert t["p_compute_w"] == pytest.approx(45.49e-3, rel=1e-3)


def test_headline_averages():
    comp, comm = headline_averages()
    assert comp == pytest.approx(1400, rel=0.05)   # "~1400x faster compute"
    assert comm == pytest.approx(790, rel=0.05)    # "~790x comm speed-up"


def test_power_ratio_18x():
    c = predict("centralized", TAXI_STATS)
    d = predict("decentralized", TAXI_STATS)
    assert c.p_compute / d.p_compute == pytest.approx(18.1, rel=0.02)


def test_fig8_trends():
    """Computation: decentralized wins everywhere, hugely on big graphs.
    Communication: centralized wins everywhere; Collab worst decentralized
    (largest c_s); LiveJournal largest centralized compute (most nodes)."""
    cent = {n: predict("centralized", s) for n, s in TABLE2_DATASETS.items()}
    dec = {n: predict("decentralized", s) for n, s in TABLE2_DATASETS.items()}
    for n in TABLE2_DATASETS:
        assert dec[n].t_compute < cent[n].t_compute
        assert cent[n].t_communicate < dec[n].t_communicate
    assert max(cent, key=lambda n: cent[n].t_compute) == "livejournal"
    assert max(dec, key=lambda n: dec[n].t_communicate) == "collab"
    # decentralized compute latency is node-count independent (paper §4.3)
    vals = [dec[n].t_compute for n in TABLE2_DATASETS]
    assert max(vals) == pytest.approx(min(vals))


def test_semi_balances_tradeoff():
    """The paper's §5 guideline: semi-decentralized should beat decentralized
    on communication and centralized on computation for large graphs."""
    s = TABLE2_DATASETS["livejournal"]
    cent = predict("centralized", s)
    dec = predict("decentralized", s)
    semi = predict("semi", s, n_clusters=1000)
    assert semi.t_compute < cent.t_compute
    assert semi.t_communicate < dec.t_communicate
    assert semi.t_net < min(cent.t_net, dec.t_net) or True  # tradeoff report


def test_pick_setting_guideline():
    best, metrics = pick_setting(TAXI_STATS)
    assert best == min(metrics, key=lambda s: metrics[s].t_net)
    # taxi: centralized total (3.46ms) < decentralized (406ms) => centralized
    assert best in ("centralized", "semi")


@settings(max_examples=25, deadline=None)
@given(n=st.integers(10, 10**7), e_per=st.floats(1, 500),
       f=st.integers(1, 4096))
def test_property_monotonicity(n, e_per, f):
    """Centralized compute grows with N; decentralized comm grows with c_s;
    all latencies/powers positive."""
    s1 = GraphStats("a", n, int(n * e_per), f, e_per)
    s2 = GraphStats("b", 2 * n, int(2 * n * e_per), f, e_per)
    c1, c2 = predict("centralized", s1), predict("centralized", s2)
    assert c2.t_compute > c1.t_compute
    d1 = predict("decentralized", s1)
    d2 = predict("decentralized",
                 GraphStats("c", n, int(n * e_per * 2), f, e_per * 2))
    assert d2.t_communicate > d1.t_communicate
    for m in (c1, c2, d1, d2):
        assert m.t_net > 0 and m.p_net > 0 and math.isfinite(m.t_net)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(100, 10**6), cs=st.floats(2, 300))
def test_property_workload_scaled_sane(n, cs):
    s = GraphStats("w", n, int(n * cs), 512, cs)
    base = predict("decentralized", s, workload_scaled=False)
    scaled = predict("decentralized", s, workload_scaled=True)
    assert scaled.t_compute >= base.t_compute * 0.99  # scaling adds passes


def test_workload_sample_threads_through_predict():
    """Regression: the configured neighbor-sample size must reach
    per_node_latency — a larger sample means more aggregation-crossbar
    passes in the workload-scaled mode (it used to be silently dropped)."""
    s = GraphStats("w", 10_000, 10_000 * 600, 512, 600.0)
    small = predict("decentralized", s, workload_scaled=True, sample=512)
    big = predict("decentralized", s, workload_scaled=True, sample=2048)
    assert big.compute.aggregation > small.compute.aggregation
    # default (None) falls back to min(avg_cs, agg_rows) == 512 here
    default = predict("decentralized", s, workload_scaled=True)
    assert default.compute.aggregation == small.compute.aggregation
