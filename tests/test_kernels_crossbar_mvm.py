"""Crossbar MVM kernel: shape/dtype sweeps vs the pure-jnp oracle, plus
properties of the quantization numerics."""
import numpy as np
import pytest
import jax.numpy as jnp
from _hyp import given, settings, st

from repro.kernels.crossbar_mvm import (
    CrossbarNumerics, crossbar_matmul, crossbar_matmul_ref,
    crossbar_matmul_signed, crossbar_matmul_signed_ref)


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.normal(size=shape).astype(dtype))


@pytest.mark.parametrize("m,k,n", [(8, 32, 16), (64, 200, 96), (1, 512, 128),
                                   (33, 100, 7), (128, 128, 128)])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_matches_oracle_shapes(m, k, n, dtype):
    rng = np.random.default_rng(m * 1000 + k + n)
    cfg = CrossbarNumerics(in_bits=4, w_bits=4, adc_bits=6, rows_per_xbar=64)
    x = jnp.abs(_rand(rng, (m, k), dtype))
    w = _rand(rng, (k, n), dtype)
    ref = crossbar_matmul_ref(x, w, cfg)
    out = crossbar_matmul(x, w, cfg, bm=8, bn=8, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("in_bits,w_bits,adc_bits,rows", [
    (8, 8, 8, 128), (4, 8, 6, 256), (2, 2, 4, 32), (8, 4, 10, 512)])
def test_matches_oracle_numerics_sweep(in_bits, w_bits, adc_bits, rows):
    rng = np.random.default_rng(in_bits * 7 + w_bits)
    cfg = CrossbarNumerics(in_bits=in_bits, w_bits=w_bits,
                           adc_bits=adc_bits, rows_per_xbar=rows)
    x = jnp.abs(_rand(rng, (16, 300), np.float32))
    w = _rand(rng, (300, 24), np.float32)
    ref = crossbar_matmul_ref(x, w, cfg)
    out = crossbar_matmul(x, w, cfg, bm=16, bn=8, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


def test_signed_variant():
    rng = np.random.default_rng(3)
    cfg = CrossbarNumerics(in_bits=6, w_bits=6, adc_bits=8, rows_per_xbar=128)
    x = _rand(rng, (12, 160), np.float32)      # signed activations
    w = _rand(rng, (160, 40), np.float32)
    ref = crossbar_matmul_signed_ref(x, w, cfg)
    out = crossbar_matmul_signed(x, w, cfg, bm=4, bn=8, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


def test_ideal_mode_is_exact_matmul():
    rng = np.random.default_rng(4)
    x, w = _rand(rng, (9, 33), np.float32), _rand(rng, (33, 5), np.float32)
    out = crossbar_matmul(x, w, CrossbarNumerics(ideal=True))
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w), rtol=1e-5)


def test_quantization_error_shrinks_with_resolution():
    """Property of the numerics model: more DAC/ADC bits -> closer to ideal."""
    rng = np.random.default_rng(5)
    x = jnp.abs(_rand(rng, (32, 256), np.float32))
    w = _rand(rng, (256, 32), np.float32)
    ideal = np.asarray(x @ w)
    errs = []
    for bits in (2, 4, 8):
        cfg = CrossbarNumerics(in_bits=bits, w_bits=bits, adc_bits=bits + 4,
                               rows_per_xbar=128)
        y = np.asarray(crossbar_matmul_ref(x, w, cfg))
        errs.append(np.linalg.norm(y - ideal) / np.linalg.norm(ideal))
    assert errs[0] > errs[1] > errs[2], errs
    assert errs[2] < 0.05, errs


@pytest.mark.slow          # >10s on the CI CPU (--durations=15)
@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 24), k=st.integers(1, 100), n=st.integers(1, 24),
       seed=st.integers(0, 2**31 - 1))
def test_property_oracle_kernel_equivalence(m, k, n, seed):
    rng = np.random.default_rng(seed)
    cfg = CrossbarNumerics(in_bits=3, w_bits=3, adc_bits=5, rows_per_xbar=32)
    x = jnp.abs(_rand(rng, (m, k), np.float32))
    w = _rand(rng, (k, n), np.float32)
    ref = crossbar_matmul_ref(x, w, cfg)
    out = crossbar_matmul(x, w, cfg, bm=8, bn=8, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


def test_quantized_kernel_rejects_nondivisible_shapes():
    """Regression: ``crossbar_matmul_quantized`` used to assert (or, under
    ``python -O``, crash deep in Pallas) on non-divisible M/K/N. It must
    raise an early ValueError naming the offending dim and pointing at the
    mapper API that produces valid shapes."""
    from repro.kernels.crossbar_mvm.crossbar_mvm import (
        crossbar_matmul_quantized)
    cfg = CrossbarNumerics(rows_per_xbar=128)
    ok = dict(m=128, k=256, n=128)

    def codes(m, k, n):
        return (jnp.zeros((m, k), jnp.uint32), jnp.zeros((k, n), jnp.float32))

    # each dim individually non-divisible -> named in the error, which
    # also points at the mapper API producing valid shapes
    for dim, shape in (("M", dict(ok, m=100)), ("K", dict(ok, k=200)),
                       ("N", dict(ok, n=70))):
        xq, wq = codes(**shape)
        with pytest.raises(ValueError, match=rf"{dim}.*divisible") as ei:
            crossbar_matmul_quantized(xq, wq, cfg, interpret=True)
        assert "repro.mapper.tiling.padded_grid" in str(ei.value)
    # mismatched contraction dims
    with pytest.raises(ValueError, match="contraction mismatch"):
        crossbar_matmul_quantized(jnp.zeros((128, 256), jnp.uint32),
                                  jnp.zeros((128, 128), jnp.float32),
                                  cfg, interpret=True)
    # the ops-layer wrapper maps the same shapes fine (mapper padding)
    rng = np.random.default_rng(9)
    x = jnp.abs(_rand(rng, (100, 200), np.float32))
    w = _rand(rng, (200, 70), np.float32)
    out = crossbar_matmul(x, w, cfg, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(crossbar_matmul_ref(x, w, cfg)),
                               rtol=1e-5, atol=1e-4)


def test_scale_invariance_property():
    """Quantization is scale-calibrated: y(ax, w) ~= a*y(x, w)."""
    rng = np.random.default_rng(6)
    cfg = CrossbarNumerics()
    x = jnp.abs(_rand(rng, (8, 64), np.float32))
    w = _rand(rng, (64, 8), np.float32)
    y1 = np.asarray(crossbar_matmul_ref(x, w, cfg))
    y2 = np.asarray(crossbar_matmul_ref(4.0 * x, w, cfg))
    np.testing.assert_allclose(y2, 4.0 * y1, rtol=1e-4, atol=1e-4)
