"""Benchmark artifacts must be reproducible: running a --smoke bench twice
with the same seed/argv must produce byte-identical METRICS (modulo the
sanctioned volatile fields — wall-clock under ``timing`` keys, the
runner's ``seconds``/``git_sha``), per the determinism convention in
benchmarks/run.py. A drifting artifact would make the CI perf-trajectory
JSONs (BENCH_<name>.json) undiffable across commits.
"""
import copy
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import run as runner  # noqa: E402


def _smoke_twice(name: str, extra_argv: tuple = ()) -> tuple:
    """Run one bench's smoke path twice in-process; returns the two
    canonical METRICS serializations plus the raw final METRICS."""
    mod = runner.discover([name])[name]
    outs = []
    for _ in range(2):
        saved = sys.argv
        try:
            sys.argv = ([f"benchmarks/{name}.py"]
                        + list(getattr(mod, "SMOKE_ARGV", []))
                        + list(extra_argv))
            rc = int(mod.main() or 0)
        finally:
            sys.argv = saved
        assert rc == 0, f"{name} smoke failed (rc={rc})"
        outs.append(json.dumps(
            runner.canonical_metrics(copy.deepcopy(mod.METRICS)),
            sort_keys=True, default=str))
    return outs[0], outs[1], mod.METRICS


def test_canonical_metrics_strips_volatile_recursively():
    rec = dict(bench="x", seconds=1.23, git_sha="abc",
               metrics=dict(rows=[dict(v=1, timing=dict(ms=9.9))], n=2))
    canon = runner.canonical_metrics(rec)
    assert canon == dict(bench="x", metrics=dict(n=2, rows=[dict(v=1)]))
    # key order is canonical: two dict orderings serialize identically
    a = runner.canonical_metrics(dict(b=1, a=2))
    b = runner.canonical_metrics(dict(a=2, b=1))
    assert json.dumps(a) == json.dumps(b)


def test_mapper_sweep_smoke_metrics_deterministic(capsys):
    first, second, _ = _smoke_twice("mapper_sweep")
    assert first == second
    capsys.readouterr()


def test_planner_sweep_model_metrics_deterministic(capsys):
    """The planner sweep's decisions, scores, and Pareto frontier are pure
    functions of the workload — two runs must agree byte for byte
    (--no-serve keeps the measured serving phase out of this fast test;
    its timings are under 'timing' keys and stripped anyway)."""
    first, second, raw = _smoke_twice("planner_sweep", ("--no-serve",))
    assert first == second
    assert raw["datasets"] and raw["adaptivity"]["taxi_mixed"] == "semi"
    capsys.readouterr()


@pytest.mark.slow
def test_load_serve_smoke_metrics_deterministic(capsys):
    """The load harness measures wall-clock — exactly what the convention
    quarantines under 'timing'. Everything outside it (served counts,
    commits, config grid) must reproduce; the quarantine must actually
    contain the percentiles."""
    first, second, raw = _smoke_twice("load_serve")
    assert first == second
    assert "p50_ms" not in first and "qps" not in first    # quarantined
    assert any("timing" in r for r in raw["configs"])      # ... but present
    capsys.readouterr()
