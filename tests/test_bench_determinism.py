"""Benchmark artifacts must be reproducible: running a --smoke bench twice
with the same seed/argv must produce byte-identical METRICS (modulo the
sanctioned volatile fields — wall-clock under ``timing`` keys, the
runner's ``seconds``/``git_sha``), per the determinism convention in
benchmarks/run.py. A drifting artifact would make the CI perf-trajectory
JSONs (BENCH_<name>.json) undiffable across commits.
"""
import copy
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import run as runner  # noqa: E402


def _smoke_twice(name: str, extra_argv: tuple = ()) -> tuple:
    """Run one bench's smoke path twice in-process; returns the two
    canonical METRICS serializations plus the raw final METRICS."""
    mod = runner.discover([name])[name]
    outs = []
    for _ in range(2):
        saved = sys.argv
        try:
            sys.argv = ([f"benchmarks/{name}.py"]
                        + list(getattr(mod, "SMOKE_ARGV", []))
                        + list(extra_argv))
            rc = int(mod.main() or 0)
        finally:
            sys.argv = saved
        assert rc == 0, f"{name} smoke failed (rc={rc})"
        outs.append(json.dumps(
            runner.canonical_metrics(copy.deepcopy(mod.METRICS)),
            sort_keys=True, default=str))
    return outs[0], outs[1], mod.METRICS


def test_canonical_metrics_strips_volatile_recursively():
    rec = dict(bench="x", seconds=1.23, git_sha="abc",
               metrics=dict(rows=[dict(v=1, timing=dict(ms=9.9))], n=2))
    canon = runner.canonical_metrics(rec)
    assert canon == dict(bench="x", metrics=dict(n=2, rows=[dict(v=1)]))
    # key order is canonical: two dict orderings serialize identically
    a = runner.canonical_metrics(dict(b=1, a=2))
    b = runner.canonical_metrics(dict(a=2, b=1))
    assert json.dumps(a) == json.dumps(b)


def test_mapper_sweep_smoke_metrics_deterministic(capsys):
    first, second, _ = _smoke_twice("mapper_sweep")
    assert first == second
    capsys.readouterr()


def test_planner_sweep_model_metrics_deterministic(capsys):
    """The planner sweep's decisions, scores, and Pareto frontier are pure
    functions of the workload — two runs must agree byte for byte
    (--no-serve keeps the measured serving phase out of this fast test;
    its timings are under 'timing' keys and stripped anyway)."""
    first, second, raw = _smoke_twice("planner_sweep", ("--no-serve",))
    assert first == second
    assert raw["datasets"] and raw["adaptivity"]["taxi_mixed"] == "semi"
    capsys.readouterr()


# ---- the --compare perf-trajectory gate ----------------------------------

def _rec(metrics, argv=("--smoke",), seconds=1.0, sha="aaa"):
    return dict(bench="b", argv=list(argv), smoke=True, returncode=0,
                seconds=seconds, git_sha=sha, metrics=metrics)


def test_compare_identical_records_pass():
    m = dict(n=3, timing=dict(ms=12.5), rows=[dict(v=1.0)])
    assert runner.compare_records("b", _rec(m), _rec(m)) == []


def test_compare_never_trips_on_volatile_fields():
    """seconds / git_sha / small timing jitter are sanctioned volatility —
    the gate must stay quiet on all of them."""
    base = _rec(dict(n=3, timing=dict(ms=100.0)), seconds=1.0, sha="aaa")
    cur = _rec(dict(n=3, timing=dict(ms=100.0 * 1.49)),   # under 50%
               seconds=999.0, sha="bbb")
    assert runner.compare_records("b", base, cur, threshold=0.5) == []
    # timing improvements never fail, however large
    faster = _rec(dict(n=3, timing=dict(ms=0.1)))
    assert runner.compare_records("b", base, faster, threshold=0.5) == []
    # a timing leaf with no baseline counterpart is ignored, not an error
    grew = _rec(dict(n=3, timing=dict(ms=100.0, new_ms=9.9)))
    assert runner.compare_records("b", base, grew, threshold=0.5) == []


def test_compare_fails_on_injected_regression_naming_bench_and_key():
    """Perturb a timing leaf just past the threshold: the gate must fail
    and the message must name the bench and the exact metric path."""
    thr = 0.5
    base = _rec(dict(cases=[dict(s="semi",
                                 timing=dict(t_inc_ms=200.0))]))
    eps = 0.01
    cur = _rec(dict(cases=[dict(s="semi",
                                timing=dict(t_inc_ms=200.0 * (1 + thr + eps)))]))
    msgs = runner.compare_records("streaming_replay", base, cur,
                                  threshold=thr)
    assert len(msgs) == 1
    assert "streaming_replay" in msgs[0]
    assert "cases[0].timing.t_inc_ms" in msgs[0]
    # ... and just under the threshold passes
    ok = _rec(dict(cases=[dict(s="semi",
                               timing=dict(t_inc_ms=200.0 * (1 + thr - eps)))]))
    assert runner.compare_records("streaming_replay", base, ok,
                                  threshold=thr) == []


def test_compare_is_direction_aware_for_throughput_leaves():
    """qps/rate/throughput leaves are higher-is-better: a rise (however
    large) never fails, a drop past the threshold does — the mirror image
    of latency-style leaves. Non-numeric timing leaves (e.g. a winner
    config recorded as a string) are never compared."""
    base = _rec(dict(timing=dict(closed_qps=100.0, p99_ms=10.0)))
    surge = _rec(dict(timing=dict(closed_qps=500.0, p99_ms=10.0)))
    assert runner.compare_records("b", base, surge, threshold=0.5) == []
    drop = _rec(dict(timing=dict(closed_qps=100.0 / 1.51, p99_ms=10.0)))
    msgs = runner.compare_records("b", base, drop, threshold=0.5)
    assert len(msgs) == 1 and "timing.closed_qps" in msgs[0]
    small_drop = _rec(dict(timing=dict(closed_qps=100.0 / 1.49, p99_ms=10.0)))
    assert runner.compare_records("b", base, small_drop, threshold=0.5) == []
    # strings under timing (machine-dependent but not a measurement)
    cfg_base = _rec(dict(timing=dict(tuned="bf=128", ms=1.0)))
    cfg_cur = _rec(dict(timing=dict(tuned="bf=256", ms=1.0)))
    assert runner.compare_records("b", cfg_base, cfg_cur, threshold=0.5) == []


def test_compare_fails_on_deterministic_drift_and_argv_change():
    base = _rec(dict(n=3, frac=0.25))
    drift = _rec(dict(n=3, frac=0.26))
    msgs = runner.compare_records("b", base, drift)
    assert msgs and "frac" in msgs[0] and "drift" in msgs[0]
    # floats within serialization tolerance are NOT drift
    close = _rec(dict(n=3, frac=0.25 * (1 + 1e-9)))
    assert runner.compare_records("b", base, close) == []
    # argv mismatch short-circuits with the re-record suggestion
    moved = _rec(dict(n=3, frac=0.25), argv=("--smoke", "--iters", "2"))
    msgs = runner.compare_records("b", base, moved)
    assert len(msgs) == 1 and "--update-baseline" in msgs[0]


def test_collect_timings_flattens_only_timing_subtrees():
    m = dict(a=1.0, timing=dict(ms=2.0, nested=dict(s=3.0)),
             rows=[dict(v=4.0, timing=dict(ms=5.0))])
    got = runner.collect_timings(m)
    assert got == {"timing.ms": 2.0, "timing.nested.s": 3.0,
                   "rows[0].timing.ms": 5.0}
    assert "a" not in got and all("v" not in k for k in got)


def test_compare_gate_end_to_end(tmp_path, capsys, monkeypatch):
    """The full CLI loop — argv recording, artifact write, baseline load,
    exit codes — on a stub bench injected through discover(). A stub
    rather than a real bench: each in-process bench run piles another set
    of XLA executables/thread pools onto the suite's single process (the
    real-bench pass is the CI `--smoke --compare` job). Missing baseline
    fails pointing at --update-baseline; --update-baseline records it; an
    identical re-run passes --compare; an injected regression (baseline
    timings scaled down past the threshold) fails naming the bench."""
    import time
    import types
    stub = types.ModuleType("benchmarks.stub_bench")
    stub.SMOKE_ARGV = ["--iters", "1"]
    stub.METRICS = {}

    def stub_main():
        t0 = time.perf_counter()
        x = float(sum(i * i for i in range(1000)))   # deterministic work
        stub.METRICS.clear()
        stub.METRICS.update(
            dict(cases=[dict(s="semi", v=x)],
                 timing=dict(t_ms=(time.perf_counter() - t0) * 1e3)))
        return 0

    stub.main = stub_main
    monkeypatch.setattr(runner, "discover",
                        lambda names=None: {"stub_bench": stub})

    argv = ["stub_bench", "--smoke", "--baseline-dir", str(tmp_path)]
    with pytest.raises(SystemExit, match="baseline comparisons failed"):
        runner.main(argv + ["--compare"])
    assert "--update-baseline" in capsys.readouterr().out

    runner.main(argv + ["--update-baseline"])
    capsys.readouterr()
    base_path = tmp_path / "BENCH_stub_bench.json"
    assert base_path.exists()
    assert json.loads(base_path.read_text())["argv"] == ["--iters", "1"]

    # identical re-run: deterministic metrics reproduce; a loose threshold
    # absorbs scheduler noise on the genuinely-measured timing
    runner.main(argv + ["--compare", "--compare-threshold", "50"])
    assert "baselines match" in capsys.readouterr().out

    baseline = json.loads(base_path.read_text())
    baseline["metrics"]["timing"]["t_ms"] /= 1e6   # current looks 10^6x slower
    base_path.write_text(json.dumps(baseline))
    with pytest.raises(SystemExit, match="baseline comparisons failed"):
        runner.main(argv + ["--compare", "--compare-threshold", "50"])
    out = capsys.readouterr().out
    assert "timing regression" in out and "stub_bench" in out


@pytest.mark.slow
def test_load_serve_smoke_metrics_deterministic(capsys):
    """The load harness measures wall-clock — exactly what the convention
    quarantines under 'timing'. Everything outside it (served counts,
    commits, config grid) must reproduce; the quarantine must actually
    contain the percentiles."""
    first, second, raw = _smoke_twice("load_serve")
    assert first == second
    assert "p50_ms" not in first and "qps" not in first    # quarantined
    assert any("timing" in r for r in raw["configs"])      # ... but present
    capsys.readouterr()
