"""Capacity-bucketed ragged data plane (DESIGN.md §12).

The contract under test: bucketing is a *layout* change, never a numerics
change. A bucketed plan's forward must equal the dense plan's bit for bit
on every setting × backend, under both halo-exchange schedules
(overlapped and serialized), and through the streaming engine's
incremental refresh. The property backbone drives heavily skewed
power-law partitions (the layout's reason to exist) through
``partition``/``hier_partition``/``build_local_subgraphs`` and checks the
structural invariants: every cluster lands in exactly one bucket, every
bucket capacity covers its clusters, and re-bucketing with ``like=``
never shrinks a capacity (jit shape stability across streaming rebuilds).
"""
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core import gnn
from repro.core.graph import random_graph
from repro.core.partition import (PARTITION_METHODS, bucket_partition,
                                  build_local_subgraphs, hier_partition,
                                  partition, plan_execution)


def _forward_scattered(g, cfg, params, setting, backend, buckets,
                       **plan_kw):
    plan = plan_execution(g, setting, backend=backend, sample=cfg.sample,
                          n_clusters=None if setting == "centralized"
                          else 4, seed=2, buckets=buckets, **plan_kw)
    return plan, plan.scatter(plan.make_forward(cfg)(params))


# ------------------------------------------------- forward parity grid

def test_bucketed_equals_dense_exactly(setting_backend, make_graph):
    """Bit-for-bit: dense [K, n_max] padding vs per-bucket [K_b, n_cap]
    ragged layout, full 3-setting x 3-backend grid."""
    import jax
    setting, backend = setting_backend
    g = make_graph(n=50, e=260, f=8, seed=3)
    cfg = gnn.GNNConfig(in_dim=8, hidden_dims=(10,), out_dim=4, sample=5,
                        backend=backend)
    params = gnn.init_params(jax.random.key(1), cfg)
    _, ref = _forward_scattered(g, cfg, params, setting, backend, None)
    plan, out = _forward_scattered(g, cfg, params, setting, backend, "auto")
    assert plan.bucketed is not None and plan.bucketed.covers()
    assert np.array_equal(ref, out), \
        f"{setting}/{backend}: maxdiff {np.abs(ref - out).max()}"


def test_overlap_and_serial_schedules_identical(make_graph):
    """The double-buffered (overlap) and serialized halo schedules are the
    same dataflow in a different dispatch order — identical outputs."""
    import jax
    g = make_graph(n=60, e=320, f=8, seed=4)
    cfg = gnn.GNNConfig(in_dim=8, hidden_dims=(12,), out_dim=4, sample=6)
    params = gnn.init_params(jax.random.key(0), cfg)
    for setting in ("decentralized", "semi"):
        plan = plan_execution(g, setting, backend="jnp", sample=6,
                              n_clusters=4, seed=1, buckets="auto")
        a = plan.make_forward(cfg, overlap="overlap")(params)
        b = plan.make_forward(cfg, overlap="serial")(params)
        for x, y in zip(a, b):
            assert np.array_equal(np.asarray(x), np.asarray(y)), setting


# --------------------------------------------- skewed-partition properties

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([30, 70, 120]),
       k=st.integers(2, 8), method=st.sampled_from(PARTITION_METHODS),
       max_buckets=st.sampled_from([0, 1, 2, 3]))
def test_property_buckets_cover_every_skewed_cluster(seed, n, k, method,
                                                     max_buckets):
    """Power-law graphs through every partition heuristic: the bucketed
    layout must place each cluster in exactly one bucket whose capacities
    cover the cluster's rows, halo, and sampled slots — including under a
    forced bucket-count cap (merging never drops a cluster)."""
    g = random_graph(n, 5 * n, 6, seed=seed % 9973).gcn_normalize()
    part = partition(g, min(k, n), seed=seed % 17, sample=4, method=method)
    bp = bucket_partition(part, g, sample=4, max_buckets=max_buckets)
    assert bp.covers()
    if max_buckets:
        assert bp.n_buckets <= max_buckets
    sizes = part.local_mask.sum(axis=1)
    seen = np.zeros(part.n_clusters, int)
    for b, cl in enumerate(bp.clusters):
        seen[cl] += 1
        assert bp.n_caps[b] >= int(sizes[cl].max())
        assert bp.s_caps[b] >= 1
        for c in cl.tolist():
            assert bp.bucket_of[c] == b
    assert (seen == 1).all()                    # a partition of the clusters
    assert bp.padded_rows() >= int(sizes.sum())
    # pow2 capacities at most double any cluster's dense rows (plus the
    # _MIN_CAP floor) — bucketed only *wins* on skewed partitions, but it
    # can never blow past this bound on balanced ones
    assert bp.padded_rows() <= 2 * bp.dense_padded_rows() \
        + 8 * part.n_clusters


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([40, 90]),
       method=st.sampled_from(PARTITION_METHODS))
def test_property_bucketed_forward_equals_dense_on_skew(seed, n, method):
    """Numerical identity holds for arbitrary skewed partitions, not just
    the well-balanced BFS default the parity grid uses."""
    import jax
    g = random_graph(n, 6 * n, 6, seed=seed % 7919).gcn_normalize()
    cfg = gnn.GNNConfig(in_dim=6, hidden_dims=(8,), out_dim=3, sample=4)
    params = gnn.init_params(jax.random.key(seed % 13), cfg)
    _, ref = _forward_scattered(g, cfg, params, "decentralized", "jnp",
                                None, partition_method=method)
    plan, out = _forward_scattered(g, cfg, params, "decentralized", "jnp",
                                   "auto", partition_method=method)
    assert plan.bucketed is not None
    assert np.array_equal(ref, out), f"method={method}"


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), heads=st.integers(2, 6))
def test_property_hier_partition_buckets_cover_heads(seed, heads):
    """Semi's tier-1 head partition buckets the same way: the head-level
    clusters of a skewed two-tier hierarchy are covered, and the dense
    spoke tables feeding them stay consistent (build_local_subgraphs on
    the head partition still works off the same partition object)."""
    g = random_graph(80, 400, 6, seed=seed % 4999).gcn_normalize()
    hier = hier_partition(g, heads, seed=seed % 23, sample=4)
    bp = bucket_partition(hier.region, g, sample=4)
    assert bp.covers()
    sub = build_local_subgraphs(g, hier.region, 4)
    sizes = hier.region.local_mask.sum(axis=1)
    for b, cl in enumerate(bp.clusters):
        assert bp.n_caps[b] >= int(sizes[cl].max())
        assert bp.s_caps[b] <= sub.neighbors.shape[-1]


def test_rebucket_like_keeps_groups_and_never_shrinks(make_graph):
    """Streaming rebuilds re-bucket with ``like=``: same cluster grouping,
    capacities only ever grow (jit shape stability across ticks)."""
    g = make_graph(n=60, e=300, f=8, seed=6)
    part = partition(g, 4, seed=0, sample=5, method="edge")
    bp0 = bucket_partition(part, g, sample=5)
    bp1 = bucket_partition(part, g, sample=5, like=bp0)
    assert [c.tolist() for c in bp1.clusters] == \
        [c.tolist() for c in bp0.clusters]
    for b in range(bp0.n_buckets):
        assert bp1.n_caps[b] >= bp0.n_caps[b]
        assert bp1.h_caps[b] >= bp0.h_caps[b]
        assert bp1.s_caps[b] >= bp0.s_caps[b]


def test_partition_method_dispatch(make_graph):
    g = make_graph(n=40, e=200, f=6, seed=2)
    for method in PARTITION_METHODS:
        part = partition(g, 4, seed=0, sample=4, method=method)
        assert part.n_clusters == 4
        # every node owned exactly once
        owned = np.sort(part.local_nodes[part.local_mask])
        assert np.array_equal(owned, np.arange(g.n_nodes))
    with pytest.raises(ValueError, match="method"):
        partition(g, 4, method="metis")


def test_layout_stats_report_bucketing_win_on_skew():
    """On a power-law graph with an edge-balanced partition the bucketed
    layout must waste strictly less padding than dense, and the stats
    must price both from the same partition."""
    g = random_graph(4000, 16000, 8, seed=0).gcn_normalize()
    cfg = gnn.GNNConfig(in_dim=8, hidden_dims=(8,), out_dim=4, sample=6)
    plan = plan_execution(g, "decentralized", backend="jnp", sample=6,
                          n_clusters=16, seed=0, buckets="auto",
                          partition_method="edge")
    ls = plan.layout_stats(cfg)
    assert ls["layout"] == "bucketed"
    assert ls["real_rows"] == g.n_nodes
    assert ls["padded_rows"] < ls["dense_padded_rows"]
    assert ls["padding_ratio"] < ls["dense_padding_ratio"]
    assert ls["peak_device_bytes"] > 0
    # the tentpole gate at test scale: bucketed waste well under dense
    waste = ls["padding_ratio"] - 1.0
    dense_waste = ls["dense_padding_ratio"] - 1.0
    assert waste <= 0.5 * dense_waste


# ------------------------------------------------- streaming incremental

@pytest.mark.parametrize("backend", ["jnp", "fused"])
def test_bucketed_streaming_refresh_matches_dense(setting, backend):
    """Dense and bucketed IncrementalEngines fed identical churn commit to
    the same embeddings — the bucketed dirty-refresh path (per-cluster
    row scatter into donated per-bucket activation caches) is exercised
    through feature and structural deltas."""
    import jax
    from repro.streaming import GraphDelta
    from repro.streaming.incremental import IncrementalEngine

    g = random_graph(50, 240, 8, seed=5).gcn_normalize()
    cfg = gnn.GNNConfig(in_dim=8, hidden_dims=(10,), out_dim=4, sample=5,
                        backend=backend)
    params = gnn.init_params(jax.random.key(1), cfg)
    engines = {}
    for name, buckets in (("dense", None), ("bucketed", "auto")):
        plan = plan_execution(g, setting, backend=backend, sample=5,
                              n_clusters=4, seed=2, buckets=buckets)
        eng = IncrementalEngine(plan, cfg, params)
        eng.full_refresh()
        engines[name] = eng
    rng = np.random.default_rng(0)
    for tick in range(3):
        ids = rng.choice(50, 5, replace=False)
        rows = rng.normal(size=(5, 8)).astype(np.float32)
        u, v = int(rng.integers(0, 50)), int(rng.integers(0, 50))
        for eng in engines.values():
            d = GraphDelta(50)
            d.update_features(ids, rows)
            d.add_edges([u], [v], [0.5])
            eng.apply_delta(d)
        a = engines["dense"].embeddings()
        b = engines["bucketed"].embeddings()
        np.testing.assert_allclose(a, b, atol=1e-5,
                                   err_msg=f"tick {tick}")
