"""ReplanMonitor drift paths through the typed telemetry interface
(DESIGN.md §14): feed synthetic :class:`~repro.telemetry.CommitSample`
streams into ``observe()`` in shadow mode (no server attached) and check
exactly which samples trip which drift reason — latency vs the
early-commit baseline, bytes vs the planner's prediction (and its
early-median fallback), full-refresh skipping, and the steady state."""
import pytest

from repro.core.graph import TAXI_STATS
from repro.planner import (Candidate, CommitSample, DriftLedger,
                           ReplanMonitor, WorkloadProfile, plan)


def _pinned(predicted_bytes=None):
    """Model-only planner result on a single pinned candidate (no graph,
    no traffic evaluator — ``bytes_per_tick`` only when injected)."""
    wl = WorkloadProfile(churn=0.05, queries_per_tick=8, sample=4)
    result = plan(TAXI_STATS, "throughput", workload=wl,
                  space=[Candidate("decentralized", "jnp", 3)])
    assert "bytes_per_tick" not in result.recommended.metrics
    if predicted_bytes is not None:
        result.recommended.metrics["bytes_per_tick"] = predicted_bytes
    return result


def _monitor(predicted_bytes=None, **kw):
    kw.setdefault("window", 2)
    kw.setdefault("tol", 2.0)
    kw.setdefault("cooldown", 1)
    return ReplanMonitor(_pinned(predicted_bytes), **kw)


def _sample(seconds=0.01, shipped=1000.0, churn=0.05, full=False,
            queries=8):
    return CommitSample(seconds=seconds, shipped_bytes=shipped,
                        churn_frac=churn, full=full, queries=queries,
                        policy="eager")


# ---- path 1: latency drift ----------------------------------------------

def test_latency_drift_trips_in_shadow_mode():
    mon = _monitor()
    # window=2 fast commits: baseline_s = 0.01; no drift check until
    # 2*window samples exist (baseline and recent windows never overlap)
    assert mon.observe(_sample(seconds=0.01)) is None
    assert mon.observe(_sample(seconds=0.01)) is None
    assert mon.ledger.baseline_s == pytest.approx(0.01)
    ev = None
    for _ in range(2):
        ev = ev or mon.observe(_sample(seconds=0.05))
    assert ev is not None and ev.reason == "latency"
    assert ev.measured == pytest.approx(0.05)
    assert ev.reference == pytest.approx(0.01)
    assert ev.measured > mon.tol * ev.reference
    # shadow mode: detection only — nothing to swap to, nothing swapped
    assert not ev.swapped and ev.new is ev.old is mon.serving
    assert mon.events == [ev]


def test_latency_drift_replan_event_carries_measured_workload():
    mon = _monitor()
    for s in (0.01, 0.01, 0.08, 0.08):
        ev = mon.observe(_sample(seconds=s, churn=0.4, queries=40))
    # the shadow event reports the workload the re-plan *would* use:
    # per-tick churn from the ledger's frontier series, measured queries
    assert ev.workload.churn == pytest.approx(0.4)
    assert ev.workload.queries_per_tick >= 10


# ---- path 2: bytes drift ------------------------------------------------

def test_bytes_drift_trips_against_predicted_reference():
    mon = _monitor(predicted_bytes=1000.0)
    # constant latency so only the traffic signal can trip
    assert mon.observe(_sample(shipped=1000.0)) is None
    assert mon.observe(_sample(shipped=1000.0)) is None
    ev = None
    for _ in range(2):
        ev = ev or mon.observe(_sample(shipped=9000.0))
    assert ev is not None and ev.reason == "traffic"
    assert ev.measured == pytest.approx(9000.0)
    # eager policy: one tick per commit, the prediction is used unscaled
    assert ev.reference == pytest.approx(1000.0)
    assert not ev.swapped


def test_bytes_drift_falls_back_to_early_median_without_prediction():
    mon = _monitor(predicted_bytes=None)
    for _ in range(2):
        assert mon.observe(_sample(shipped=500.0)) is None
    ev = None
    for _ in range(2):
        ev = ev or mon.observe(_sample(shipped=5000.0))
    assert ev is not None and ev.reason == "traffic"
    assert ev.reference == pytest.approx(500.0)   # median of first window


def test_bytes_within_band_does_not_trip():
    mon = _monitor(predicted_bytes=1000.0)
    for _ in range(8):
        assert mon.observe(_sample(shipped=1500.0)) is None   # 1.5x < tol
    assert not mon.events


# ---- path 3: full refreshes are skipped ---------------------------------

def test_full_refresh_samples_are_skipped_not_folded():
    mon = _monitor()
    assert mon.observe(_sample(full=True, seconds=9.9)) is None
    assert mon.ledger.n == 0 and mon.ledger.full_skipped == 1
    assert mon.ledger.baseline_s is None
    # a cold start's 9.9s never contaminates the baseline: the quiet
    # stream that follows establishes it from representative ticks only
    for _ in range(4):
        assert mon.observe(_sample(seconds=0.01)) is None
    assert mon.ledger.baseline_s == pytest.approx(0.01)
    assert mon.ledger.full_skipped == 1
    assert not mon.events
    rep = mon.ledger.report()
    assert rep["commits"] == 4 and rep["full_skipped"] == 1


# ---- path 4: steady state never trips -----------------------------------

def test_steady_state_stays_quiet():
    mon = _monitor(predicted_bytes=1000.0)
    for _ in range(20):
        assert mon.observe(_sample()) is None
    assert not mon.events
    rep = mon.ledger.report()
    assert rep["commits"] == 20
    assert rep["recent_s"] == pytest.approx(0.01)
    assert rep["bytes_vs_predicted"] == pytest.approx(1.0)


# ---- supporting contracts ------------------------------------------------

def test_drift_event_mirrored_to_telemetry_audit_log():
    from repro import telemetry as tel
    tel.reset()
    tel.enable()
    try:
        mon = _monitor()
        for s in (0.01, 0.01, 0.05, 0.05):
            mon.observe(_sample(seconds=s))
        drift_events = [e for e in tel.get_registry().events
                        if e["event"] == "planner.drift"]
        assert len(drift_events) == 1
        assert drift_events[0]["reason"] == "latency"
        assert drift_events[0]["shadow"] is True
    finally:
        tel.reset()
        tel.disable()


def test_cooldown_suppresses_repeat_detections():
    mon = _monitor(cooldown=50)
    for s in (0.01, 0.01, 0.05, 0.05):
        mon.observe(_sample(seconds=s))
    assert len(mon.events) == 1
    for _ in range(10):                    # still drifting, still cooling
        assert mon.observe(_sample(seconds=0.05)) is None
    assert len(mon.events) == 1


def test_ledger_reset_restarts_accounting():
    led = DriftLedger(window=2, predicted_bytes=100.0)
    for _ in range(4):
        led.record(CommitSample(0.01, 100.0, 0.1))
    assert led.n == 4 and led.baseline_s is not None
    led.reset()
    assert led.n == 0 and led.baseline_s is None
    assert led.latency_drift(2.0) is None and led.bytes_drift(2.0) is None
    assert led.predicted_bytes == 100.0    # predictions survive the reset
