"""Streaming subsystem: delta rebuild contract, k-hop frontier exactness,
incremental-vs-full equivalence on every backend x setting (the shared
conftest ``setting_backend`` grid), incremental traffic invariants, and
the StreamingGNNServer refresh policies."""
import numpy as np
import jax
import pytest

from repro.core import gnn
from repro.core.graph import Graph, random_graph
from repro.core.partition import plan_execution
from repro.kernels.crossbar_mvm import CrossbarNumerics
from repro.streaming import (GraphDelta, IncrementalEngine,
                             StreamingGNNServer, apply_deltas,
                             expand_frontier)


def _raw_edges(g: Graph):
    dst = np.repeat(np.arange(g.n_nodes), np.diff(g.indptr))
    return dst, g.indices.astype(np.int64)


# ---- delta: amortized rebuild + renormalization contract ----------------

def test_feature_only_delta_keeps_structure(make_graph):
    g = make_graph()
    d = GraphDelta(g.n_nodes)
    rows = np.ones((3, g.feature_len), np.float32)
    d.update_features([5, 1, 9], rows)
    res = apply_deltas(g, d)
    assert res.graph is not g and res.graph.features is not g.features
    np.testing.assert_array_equal(res.graph.indptr, g.indptr)
    np.testing.assert_array_equal(res.graph.indices, g.indices)
    np.testing.assert_array_equal(res.graph.features[[1, 5, 9]], rows)
    assert set(np.nonzero(res.feature_dirty)[0]) == {1, 5, 9}
    assert not res.structure_dirty.any()


def test_structural_delta_matches_scratch_renormalization():
    """apply_deltas on a normalized graph must equal rebuilding the raw
    graph with the same edits and calling gcn_normalize from scratch."""
    g_raw = random_graph(30, 150, 4, seed=3, weighted=False)
    g = g_raw.gcn_normalize()
    d = GraphDelta(g.n_nodes)
    d.add_edges([2, 17, 17], [9, 4, 4])
    rm_dst, rm_src = int(np.repeat(np.arange(30), np.diff(g.indptr))[0]), \
        int(g.indices[0])
    d.remove_edges([rm_dst], [rm_src])
    res = apply_deltas(g, d)

    # scratch oracle: same edit applied to the raw edge list
    dst, src = _raw_edges(g_raw)
    keep = ~((dst == rm_dst) & (src == rm_src))
    dst = np.concatenate([dst[keep], [2, 17, 17]])
    src = np.concatenate([src[keep], [9, 4, 4]])
    order = np.argsort(dst, kind="stable")
    indptr = np.zeros(31, np.int64)
    np.add.at(indptr, dst + 1, 1)
    oracle = Graph(np.cumsum(indptr), src[order].astype(np.int32), None,
                   g_raw.features).gcn_normalize()

    np.testing.assert_array_equal(res.graph.indptr, oracle.indptr)
    np.testing.assert_array_equal(res.graph.indices, oracle.indices)
    np.testing.assert_allclose(res.graph.edge_weight, oracle.edge_weight,
                               rtol=1e-6)
    np.testing.assert_allclose(res.graph.self_loop, oracle.self_loop,
                               rtol=1e-6)
    # the edited rows are structure-dirty, as is everything a degree change
    # touches (rows reading node 2 / 17 / rm_dst as a source)
    for u in (2, 17, rm_dst):
        assert res.structure_dirty[u]


def test_remove_edges_drops_all_parallel_duplicates():
    g = Graph(np.array([0, 0, 3]), np.array([0, 0, 1], np.int32),
              np.ones(3, np.float32), np.zeros((2, 2), np.float32))
    d = GraphDelta(2).remove_edges([1], [0])
    res = apply_deltas(g, d)
    assert res.graph.n_edges == 1 and res.graph.indices[0] == 1


def test_remove_cancels_earlier_buffered_add_but_not_later(make_graph):
    """Buffered policies replay ops in order: add-then-remove nets out,
    remove-then-add survives (regression: removes used to apply only to
    pre-existing edges, so a removed-after-added edge leaked through)."""
    g = make_graph(20, 60, 4, seed=5)
    has = (np.repeat(np.arange(20), np.diff(g.indptr)) * 20
           + g.indices).tolist()
    pair = next((d, s) for d in range(20) for s in range(20)
                if d * 20 + s not in has)
    d = GraphDelta(20).add_edges([pair[0]], [pair[1]])
    d.remove_edges([pair[0]], [pair[1]])
    assert apply_deltas(g, d).graph.n_edges == g.n_edges    # netted out
    d2 = GraphDelta(20).remove_edges([pair[0]], [pair[1]])
    d2.add_edges([pair[0]], [pair[1]])
    assert apply_deltas(g, d2).graph.n_edges == g.n_edges + 1


def test_engine_keeps_shared_plan_consistent(make_graph):
    """The engine mutates the ExecutionPlan in place; after streaming, the
    plan's own make_forward must reproduce the engine's embeddings (feats
    and structural tables both tracked the live graph)."""
    g = make_graph(30, 140, 8, seed=2)
    plan = plan_execution(g, "decentralized", backend="jnp", sample=4,
                          n_clusters=2)
    cfg = gnn.GNNConfig(in_dim=8, hidden_dims=(8,), out_dim=4, sample=4)
    params = gnn.init_params(jax.random.key(0), cfg)
    eng = IncrementalEngine(plan, cfg, params)
    eng.full_refresh()
    rng = np.random.default_rng(3)
    # feature-only tick first: plan.graph must track the live graph even
    # when no structural rebuild runs (a re-planner building a replacement
    # plan from plan.graph would otherwise revert every committed update)
    d = GraphDelta(g.n_nodes).update_features(
        [4], rng.normal(size=(1, 8)).astype(np.float32))
    eng.apply_delta(d)
    assert plan.graph is eng.graph
    np.testing.assert_array_equal(plan.graph.features, eng.graph.features)
    d = GraphDelta(g.n_nodes).update_features(
        [2, 8], rng.normal(size=(2, 8)).astype(np.float32))
    d.add_edges([6], [19])
    eng.apply_delta(d)
    assert plan.graph is eng.graph
    out = plan.scatter(np.asarray(plan.make_forward(cfg)(params)))
    np.testing.assert_allclose(out, eng.embeddings(), atol=1e-5)


def test_delta_rejects_out_of_range_ids():
    d = GraphDelta(10)
    with pytest.raises(IndexError):
        d.update_features([10], np.zeros((1, 3), np.float32))
    with pytest.raises(IndexError):
        d.add_edges([0], [-1])


# ---- frontier: exact k-hop masks over the sampled adjacency -------------

def _chain_graph(n=6, f=4):
    """Row i reads node i-1 (row 0 empty): dirt at 0 walks one hop/layer."""
    indptr = np.concatenate([[0], np.arange(n)]).astype(np.int64)
    indices = np.arange(n - 1, dtype=np.int32)
    return Graph(indptr, indices, np.ones(n - 1, np.float32),
                 np.zeros((n, f), np.float32))


def test_frontier_walks_one_hop_per_layer_and_ignores_padding():
    g = _chain_graph(6)
    nbr, wts = g.neighbor_sample(4)
    fd = np.zeros(6, bool)
    fd[0] = True
    fr = expand_frontier(nbr, wts, fd, np.zeros(6, bool), 3)
    # row 0 has padding slots pointing at index 0 with weight 0: rows > l
    # must stay clean even though node 0 is dirty
    assert set(np.nonzero(fr.masks[1])[0]) == {0, 1}
    assert set(np.nonzero(fr.masks[2])[0]) == {0, 1, 2}
    assert set(np.nonzero(fr.masks[3])[0]) == {0, 1, 2, 3}
    assert 0.0 < fr.recompute_fraction() < 1.0


def test_frontier_monotone_and_structure_dirty_everywhere(make_graph):
    g = make_graph(50, 300, 4, seed=7)
    nbr, wts = g.neighbor_sample(6)
    rng = np.random.default_rng(0)
    fd = rng.random(50) < 0.1
    sd = rng.random(50) < 0.05
    fr = expand_frontier(nbr, wts, fd, sd, 3)
    for l in range(1, 3):
        assert not (fr.masks[l] & ~fr.masks[l + 1]).any()   # monotone
    for l in range(1, 4):
        assert (fr.masks[l] | ~sd).all()                    # sd always dirty


# ---- incremental == full on every backend x setting ---------------------

def test_incremental_matches_full_recompute(setting_backend, make_graph):
    setting, backend = setting_backend
    g = make_graph(30, 140, 8, seed=2)
    k = None if setting == "centralized" else 2
    plan = plan_execution(g, setting, backend=backend, sample=4,
                          n_clusters=k)
    cfg = gnn.GNNConfig(in_dim=8, hidden_dims=(8,), out_dim=4, sample=4)
    params = gnn.init_params(jax.random.key(0), cfg)
    eng = IncrementalEngine(plan, cfg, params)
    eng.full_refresh()

    rng = np.random.default_rng(5)
    # tick 1: feature churn; tick 2: feature + structural churn
    d = GraphDelta(g.n_nodes).update_features(
        [3, 11], rng.normal(size=(2, 8)).astype(np.float32))
    upd = eng.apply_delta(d)
    assert not upd.full and upd.recompute_fraction < 1.0
    d = GraphDelta(g.n_nodes)
    d.update_features([7], rng.normal(size=(1, 8)).astype(np.float32))
    d.add_edges([4, 9], [22, 1]).remove_edges([3], [g.indices[g.indptr[3]]]
                                              if g.indptr[4] > g.indptr[3]
                                              else [0])
    eng.apply_delta(d)

    fresh = plan_execution(eng.graph, setting, backend=backend, sample=4,
                           n_clusters=k)
    ref = fresh.scatter(np.asarray(fresh.make_forward(cfg)(params)))
    err = np.abs(eng.embeddings() - ref).max()
    assert err < 1e-4, (setting, backend, err)


def test_bit_accurate_numerics_degrade_to_full_refresh(make_graph):
    """The global DAC scale couples every row: incremental must fall back
    to a full refresh rather than quantize against a stale max|Z|."""
    g = make_graph(30, 140, 8, seed=2)
    plan = plan_execution(g, "centralized", backend="jnp", sample=4)
    cfg = gnn.GNNConfig(in_dim=8, hidden_dims=(8,), out_dim=4, sample=4,
                        numerics=CrossbarNumerics(ideal=False))
    params = gnn.init_params(jax.random.key(0), cfg)
    eng = IncrementalEngine(plan, cfg, params)
    eng.full_refresh()
    d = GraphDelta(g.n_nodes).update_features(
        [0], np.ones((1, 8), np.float32) * 3)
    upd = eng.apply_delta(d)
    assert upd.full and upd.recompute_fraction == 1.0
    fresh = plan_execution(eng.graph, "centralized", backend="jnp", sample=4)
    ref = fresh.scatter(np.asarray(fresh.make_forward(cfg)(params)))
    assert np.abs(eng.embeddings() - ref).max() < 1e-4


# ---- incremental traffic invariants -------------------------------------

def test_incremental_traffic_bounded_by_full(distributed_setting,
                                             make_graph):
    setting = distributed_setting
    from repro.distributed.traffic import measure_execution
    g = make_graph(60, 400, 8, seed=4)
    plan = plan_execution(g, setting, backend="jnp", sample=4, n_clusters=3)
    cfg = gnn.GNNConfig(in_dim=8, hidden_dims=(8,), out_dim=4, sample=4)
    params = gnn.init_params(jax.random.key(0), cfg)
    eng = IncrementalEngine(plan, cfg, params)
    eng.full_refresh()
    rng = np.random.default_rng(1)
    d = GraphDelta(g.n_nodes).update_features(
        [0, 5], rng.normal(size=(2, 8)).astype(np.float32))
    upd = eng.apply_delta(d)
    full = measure_execution(plan, cfg=cfg, mode="alltoall")
    assert upd.traffic.total_bytes() <= full.total_bytes()
    # per layer, per pair: incremental rows never exceed the full exchange
    assert (upd.traffic.tier1_rows <= full.tier1_rows[None]).all()
    if setting == "semi":
        assert (upd.traffic.tier0_rows <= full.tier0_rows).all()
        assert upd.traffic.tier0_rows.sum() == 2   # the two mutated rows


def test_empty_delta_recomputes_and_ships_nothing(make_graph):
    g = make_graph(40, 200, 8, seed=6)
    plan = plan_execution(g, "decentralized", backend="jnp", sample=4,
                          n_clusters=3)
    cfg = gnn.GNNConfig(in_dim=8, hidden_dims=(8,), out_dim=4, sample=4)
    eng = IncrementalEngine(plan, cfg,
                            gnn.init_params(jax.random.key(0), cfg))
    eng.full_refresh()
    before = eng.embeddings().copy()
    upd = eng.apply_delta(GraphDelta(g.n_nodes))
    assert upd.recompute_fraction == 0.0
    assert upd.traffic.total_bytes() == 0
    np.testing.assert_array_equal(eng.embeddings(), before)


# ---- StreamingGNNServer policies ---------------------------------------

def _streaming_server(make_graph, policy="eager", **kw):
    g = make_graph(40, 200, 12, seed=8)
    plan = plan_execution(g, "decentralized", backend="jnp", sample=4,
                          n_clusters=3)
    cfg = gnn.GNNConfig(in_dim=12, hidden_dims=(8,), out_dim=4, sample=4)
    srv = StreamingGNNServer(plan, cfg, policy=policy, **kw)
    srv.refresh()
    return srv, g


def _tick(srv, g, seed):
    rng = np.random.default_rng(seed)
    nodes = rng.choice(g.n_nodes, 3, replace=False)
    return srv.ingest(nodes=nodes,
                      rows=rng.normal(size=(3, g.feature_len)))


def test_eager_policy_commits_every_tick(make_graph):
    srv, g = _streaming_server(make_graph, "eager")
    for t in range(3):
        assert _tick(srv, g, t) is not None
    assert srv.commits == 4 and srv.full_refreshes == 1   # 1 = cold start
    assert all(not u.full for u in srv.updates[1:])


def test_interval_policy_buffers_between_commits(make_graph):
    srv, g = _streaming_server(make_graph, "interval", interval=3)
    assert _tick(srv, g, 0) is None and _tick(srv, g, 1) is None
    upd = _tick(srv, g, 2)
    assert upd is not None and srv.pending_ticks == 0
    # the buffered three ticks' nodes are all in the committed frontier
    assert upd.frontier.masks[0].sum() >= 3


def test_bounded_staleness_triggers_on_dirty_fraction(make_graph):
    srv, g = _streaming_server(make_graph, "bounded-staleness",
                               max_staleness=100, max_dirty_frac=0.2)
    committed = 0
    for t in range(12):
        if _tick(srv, g, t) is not None:
            committed += 1
            assert not srv._pending_dirty.any()
    assert committed >= 1               # 3 fresh nodes/tick over 40 nodes
    assert srv.commits < 13             # ... but not every tick


def test_flush_and_param_update_force_full_refresh(make_graph):
    srv, g = _streaming_server(make_graph, "interval", interval=100)
    _tick(srv, g, 0)
    assert srv.flush() is not None and srv.flush() is None
    srv.update_params(gnn.init_params(jax.random.key(9), srv.cfg))
    _tick(srv, g, 1)
    upd = srv.flush()
    assert upd is not None and upd.full          # params moved: full rebuild
    assert srv.full_refreshes == 2


def test_streaming_query_serves_policy_bounded_staleness(make_graph):
    srv, g = _streaming_server(make_graph, "interval", interval=5)
    before = srv.query(np.arange(4)).copy()
    _tick(srv, g, 0)
    np.testing.assert_array_equal(srv.query(np.arange(4)), before)  # stale
    srv.flush()
    assert not np.allclose(srv.query(np.arange(4)), before)


# ---- CAM-backed frontier membership: bit-identity contract --------------

def test_frontier_cam_modes_bit_identical(make_graph):
    from _hyp import given, settings, st
    from repro.streaming import FRONTIER_MODES

    @settings(max_examples=8, deadline=None)
    @given(n=st.integers(10, 60), e=st.integers(20, 200),
           frac=st.floats(0.0, 0.6), seed=st.integers(0, 4))
    def run(n, e, frac, seed):
        g = make_graph(n, min(e, n * (n - 1)), 4, seed=seed)
        nbr, wts = g.neighbor_sample(5)
        rng = np.random.default_rng(seed + 100)
        fd = rng.random(n) < frac
        sd = rng.random(n) < frac / 3
        ref = expand_frontier(nbr, wts, fd, sd, 3, mode="numpy")
        for mode in FRONTIER_MODES[1:]:
            fr = expand_frontier(nbr, wts, fd, sd, 3, mode=mode,
                                 interpret=True)
            np.testing.assert_array_equal(fr.masks, ref.masks)
    run()


def test_frontier_cam_empty_and_full_dirty(make_graph):
    """Degenerate dirty sets: no dirty ids (CAM search never runs) and
    everything dirty must both match the numpy expansion exactly."""
    from repro.streaming import FRONTIER_MODES
    g = make_graph(30, 120, 4, seed=11)
    nbr, wts = g.neighbor_sample(4)
    for fd in (np.zeros(30, bool), np.ones(30, bool)):
        ref = expand_frontier(nbr, wts, fd, np.zeros(30, bool), 2)
        for mode in FRONTIER_MODES[1:]:
            fr = expand_frontier(nbr, wts, fd, np.zeros(30, bool), 2,
                                 mode=mode, interpret=True)
            np.testing.assert_array_equal(fr.masks, ref.masks)


def test_frontier_mode_validation(make_graph):
    g = make_graph(10, 30, 4)
    nbr, wts = g.neighbor_sample(3)
    fd = np.zeros(10, bool)
    with pytest.raises(ValueError, match="frontier mode"):
        expand_frontier(nbr, wts, fd, fd, 2, mode="bloom")
    plan = plan_execution(g, "centralized", n_clusters=2)
    cfg = gnn.GNNConfig(in_dim=g.feature_len, hidden_dims=(8,), out_dim=4,
                        sample=3)
    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="frontier"):
        IncrementalEngine(plan, cfg, params, frontier_mode="bloom")


def test_engine_cam_frontier_matches_numpy(make_graph):
    """The incremental engine's dirty sets (and therefore its refresh
    output) are identical whichever membership path expands the frontier."""
    g = make_graph(24, 100, 6, seed=3)
    cfg = gnn.GNNConfig(in_dim=6, hidden_dims=(8,), out_dim=4, sample=4)
    params = gnn.init_params(jax.random.PRNGKey(1), cfg)
    outs, fracs = {}, {}
    for fm in ("numpy", "cam"):
        plan = plan_execution(g, "centralized", n_clusters=2)
        eng = IncrementalEngine(plan, cfg, params, frontier_mode=fm)
        eng.full_refresh()
        d = GraphDelta(g.n_nodes)
        d.update_features([2, 9], np.ones((2, 6), np.float32))
        upd = eng.apply_delta(d)
        outs[fm] = eng.embeddings()
        fracs[fm] = upd.recompute_fraction
    assert fracs["cam"] == fracs["numpy"]
    np.testing.assert_allclose(outs["cam"], outs["numpy"],
                               rtol=1e-6, atol=1e-6)
