"""End-to-end launcher tests: train loop + fault recovery + resume + serve."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.serve import Request, Server
from repro.launch.train import TrainConfig, train


class _Fault(Exception):
    pass


def test_train_learns_and_checkpoints(tmp_path):
    cfg = TrainConfig(arch="internlm2-1.8b", smoke=True, steps=8, batch=2,
                      seq=16, ckpt_dir=str(tmp_path), ckpt_every=4,
                      log_every=100)
    losses = []
    out = train(cfg, hooks={"on_step": lambda s, m: losses.append(
        float(m["loss"]))})
    assert out["last_step"] == 7
    assert len(losses) == 8
    assert all(np.isfinite(losses))
    import os
    assert any(d.startswith("step_") for d in os.listdir(str(tmp_path)))


def test_train_fault_recovery(tmp_path):
    fired = {"done": False}

    def fault(step):
        if step == 5 and not fired["done"]:
            fired["done"] = True
            raise _Fault("injected")

    cfg = TrainConfig(arch="internlm2-1.8b", smoke=True, steps=8, batch=2,
                      seq=16, ckpt_dir=str(tmp_path), ckpt_every=2,
                      log_every=100)
    seen = []
    out = train(cfg, hooks={"fault": fault,
                            "on_step": lambda s, m: seen.append(s)})
    assert out["last_step"] == 7
    assert fired["done"]
    assert 5 in seen                      # the failed step was replayed


@pytest.mark.slow          # >10s on the CI CPU (--durations=15)
def test_train_resume_continues(tmp_path):
    cfg = TrainConfig(arch="internlm2-1.8b", smoke=True, steps=4, batch=2,
                      seq=16, ckpt_dir=str(tmp_path), ckpt_every=2,
                      log_every=100)
    train(cfg)
    seen = []
    cfg2 = TrainConfig(arch="internlm2-1.8b", smoke=True, steps=7, batch=2,
                       seq=16, ckpt_dir=str(tmp_path), ckpt_every=2,
                       log_every=100)
    train(cfg2, hooks={"on_step": lambda s, m: seen.append(s)})
    assert seen and seen[0] == 5          # resumed after the step-4 ckpt


@pytest.mark.slow          # >10s on the CI CPU (--durations=15)
def test_serve_greedy_matches_direct_decode():
    srv = Server("internlm2-1.8b", smoke=True, slots=2, capacity=32)
    prompts = [[3, 1, 4], [1, 5, 9]]
    reqs = [Request(i, p, max_new=5) for i, p in enumerate(prompts)]
    for r in reqs:
        srv.submit(r)
    srv.run()
    # direct single-sequence decode oracle
    for r in reqs:
        caches = srv.model.init_caches(1, 32)
        tok = None
        logits = None
        for p, t in enumerate(r.prompt):
            logits, caches = srv.model.decode_step(
                srv.params, jnp.array([[t]], jnp.int32), caches,
                jnp.int32(p))
        got = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for n in range(r.max_new):
            got.append(int(tok[0, 0]))
            if n == r.max_new - 1:
                break
            logits, caches = srv.model.decode_step(
                srv.params, tok, caches, jnp.int32(len(r.prompt) + n))
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        assert r.out == got, (r.rid, r.out, got)


def test_serve_buckets_mixed_lengths():
    srv = Server("rwkv6-3b", smoke=True, slots=2, capacity=32)
    reqs = [Request(i, [1] * ln, max_new=3)
            for i, ln in enumerate([2, 2, 4, 4, 4])]
    for r in reqs:
        srv.submit(r)
    total = srv.run()
    assert total == 15
    assert all(r.done for r in reqs)
