"""hetGNN-LSTM taxi forecaster (§4.2 case study)."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import random_graph, taxi


def _setup(n=30):
    cfg = taxi.TaxiConfig(m=4, n=4, p_hist=5, q_future=2, hidden=16,
                          lstm_hidden=16, sample=4)
    key = jax.random.key(0)
    params = taxi.init_params(key, cfg)
    # three edge types = three random graphs over the same taxis
    nbrs, wtss = [], []
    for r in range(cfg.n_edge_types):
        g = random_graph(n, n * 3, 1, seed=r).gcn_normalize()
        nbr, wts = g.neighbor_sample(cfg.sample)
        nbrs.append(nbr)
        wtss.append(wts)
    neighbors = jnp.asarray(np.stack(nbrs))
    weights = jnp.asarray(np.stack(wtss))
    return cfg, params, neighbors, weights, key


def test_init_params_relational_shapes():
    """w_rel must be one independent glorot transform per edge type
    (regression: a dead ``[...] * 0 +`` artifact used to sit in the
    construction; the per-edge-type fold_in keys are the contract)."""
    cfg = taxi.TaxiConfig(m=4, n=4, hidden=16, n_edge_types=3)
    params = taxi.init_params(jax.random.key(0), cfg)
    assert params["w_rel"].shape == (cfg.n_edge_types, cfg.region,
                                     cfg.hidden)
    # fold_in keys: the per-type slices are distinct transforms
    for r in range(1, cfg.n_edge_types):
        assert not np.allclose(params["w_rel"][0], params["w_rel"][r])
    assert params["w_self"].shape == (cfg.region, cfg.hidden)


def test_forward_shapes_no_nan():
    cfg, params, nbr, wts, key = _setup()
    x = taxi.synthetic_stream(key, 30, cfg.p_hist, cfg)
    out = taxi.forward(params, x, nbr, wts, cfg)
    assert out.shape == (30, cfg.q_future, cfg.m, cfg.n)
    assert not np.isnan(np.asarray(out)).any()


def test_training_reduces_mse():
    cfg, params, nbr, wts, key = _setup()
    stream = taxi.synthetic_stream(key, 30, cfg.p_hist + cfg.q_future, cfg)
    x_hist = stream[:cfg.p_hist]
    target = stream[cfg.p_hist:].transpose(1, 0, 2).reshape(
        30, cfg.q_future, cfg.m, cfg.n)
    l0, _ = taxi.grad_fn(params, x_hist, nbr, wts, target, cfg)
    for _ in range(150):
        _, grads = taxi.grad_fn(params, x_hist, nbr, wts, target, cfg)
        params = jax.tree.map(lambda p, g: p - 0.3 * g, params, grads)
    l1, _ = taxi.grad_fn(params, x_hist, nbr, wts, target, cfg)
    assert float(l1) < float(l0) * 0.7, (float(l0), float(l1))
