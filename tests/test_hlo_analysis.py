"""The HLO cost analyzer against compiled modules with known ground truth."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import analyze_module, roofline_terms
from repro.analysis.hlo import _type_bytes


def _compiled_text(fn, *structs):
    return jax.jit(fn).lower(*structs).compile().as_text()


def test_scan_trip_count_multiplies_flops():
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        c, _ = jax.lax.scan(body, x, w)
        return c

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((12, 64, 64), jnp.float32)
    cost = analyze_module(_compiled_text(f, x, w))
    expect = 2 * 64 ** 3 * 12
    assert cost.flops == expect, (cost.flops, expect)
    assert 12 in cost.while_trips


def test_single_dot_flops_exact():
    a = jax.ShapeDtypeStruct((32, 48), jnp.float32)
    b = jax.ShapeDtypeStruct((48, 16), jnp.float32)
    cost = analyze_module(_compiled_text(lambda a, b: a @ b, a, b))
    assert cost.flops == 2 * 32 * 48 * 16


def test_batched_dot_flops():
    a = jax.ShapeDtypeStruct((4, 8, 16), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 16, 8), jnp.float32)
    cost = analyze_module(_compiled_text(
        lambda a, b: jnp.einsum("bij,bjk->bik", a, b), a, b))
    assert cost.flops == 2 * 4 * 8 * 16 * 8


def test_dus_charged_at_update_not_buffer():
    def f(buf, upd):
        return jax.lax.dynamic_update_slice(buf, upd, (0, 0))

    buf = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)   # 4 MB
    upd = jax.ShapeDtypeStruct((1, 1024), jnp.float32)      # 4 KB
    # donate the buffer so no defensive copy is emitted: the in-place DUS
    # must then be charged near the update size, not 2x the buffer
    text = jax.jit(f, donate_argnums=(0,)).lower(buf, upd) \
        .compile().as_text()
    cost = analyze_module(text)
    assert cost.hbm_bytes < 0.5 * 4 * 1024 * 1024, cost.hbm_bytes


def test_type_bytes_parser():
    assert _type_bytes("f32[128,128]{1,0}") == 128 * 128 * 4
    assert _type_bytes("bf16[2,3]{1,0}") == 12
    assert _type_bytes("(f32[4]{0}, s32[2]{0})") == 24
    assert _type_bytes("pred[7]{0}") == 7


def test_roofline_terms_math():
    from repro.analysis.hlo import ModuleCost
    c = ModuleCost(flops=197e12, hbm_bytes=819e9, collective_bytes=200e9)
    t = roofline_terms(c, model_flops=98.5e12)
    assert abs(t.compute_s - 1.0) < 1e-9
    assert abs(t.memory_s - 1.0) < 1e-9
    assert abs(t.collective_s - 1.0) < 1e-9
    assert abs(t.useful_ratio - 0.5) < 1e-9
    assert t.dominant in ("compute", "memory", "collective")


def test_collective_traffic_ring_model():
    """all-reduce over 4 devices: ring traffic = 2 * bytes * 3/4."""
    import os
    # use the analyzer directly on a hand-written HLO snippet
    hlo = """
HloModule m, is_scheduled=true

ENTRY %main (p: f32[256]) -> f32[256] {
  %p = f32[256]{0} parameter(0)
  ROOT %ar = f32[256]{0} all-reduce(%p), replica_groups=[4,4]<=[16], to_apply=%add
}
"""
    cost = analyze_module(hlo, default_group=4)
    expect = 2.0 * 256 * 4 * 3 / 4
    assert abs(cost.collective_bytes - expect) < 1e-6
    assert cost.collective_counts["all-reduce"] == 1
