"""Aggregation-core kernel: sweeps + CSR conversion properties."""
import numpy as np
import pytest
import jax.numpy as jnp
from _hyp import given, settings, st

from repro.kernels.csr_aggregate import aggregate, csr_aggregate_ref, pad_neighbors


@pytest.mark.parametrize("n,f,nd,s", [(10, 128, 4, 3), (50, 256, 20, 7),
                                      (100, 64, 100, 1), (7, 300, 5, 16)])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_matches_oracle(n, f, nd, s, dtype):
    rng = np.random.default_rng(n + f + nd + s)
    x = jnp.asarray(rng.normal(size=(n, f)).astype(dtype))
    nbr = jnp.asarray(rng.integers(0, n, size=(nd, s)).astype(np.int32))
    wts = jnp.asarray(rng.normal(size=(nd, s)).astype(np.float32))
    ref = csr_aggregate_ref(x, nbr, wts)
    out = aggregate(x, nbr, wts, backend="pallas", bf=128, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 40), f=st.sampled_from([32, 100, 128]),
       nd=st.integers(1, 20), s=st.integers(1, 8),
       seed=st.integers(0, 2**31 - 1))
def test_property_oracle_kernel_equivalence(n, f, nd, s, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, f)).astype(np.float32))
    nbr = jnp.asarray(rng.integers(0, n, size=(nd, s)).astype(np.int32))
    wts = jnp.asarray(rng.normal(size=(nd, s)).astype(np.float32))
    ref = csr_aggregate_ref(x, nbr, wts)
    out = aggregate(x, nbr, wts, backend="pallas", bf=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


def test_zero_weight_padding_is_identity():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(10, 32)).astype(np.float32))
    nbr = jnp.asarray(rng.integers(0, 10, size=(4, 6)).astype(np.int32))
    wts = jnp.zeros((4, 6), np.float32)
    out = aggregate(x, nbr, wts)
    np.testing.assert_allclose(np.asarray(out), 0.0)


def test_pad_neighbors_roundtrip():
    # CSR of a small known graph
    indptr = np.array([0, 2, 3, 3, 6])
    indices = np.array([1, 3, 2, 0, 1, 2])
    ew = np.arange(1, 7, dtype=np.float32)
    nbr, wts = pad_neighbors(indptr, indices, ew, sample=4)
    assert nbr.shape == (4, 4)
    np.testing.assert_array_equal(nbr[0, :2], [1, 3])
    np.testing.assert_array_equal(wts[0], [1, 2, 0, 0])
    np.testing.assert_array_equal(wts[2], [0, 0, 0, 0])   # isolated node
    # aggregation through padded format == explicit CSR SpMV
    x = np.random.default_rng(1).normal(size=(4, 16)).astype(np.float32)
    z = np.asarray(csr_aggregate_ref(jnp.asarray(x), jnp.asarray(nbr),
                                     jnp.asarray(wts)))
    dense = np.zeros((4, 4), np.float32)
    for i in range(4):
        for p in range(indptr[i], indptr[i + 1]):
            dense[i, indices[p]] += ew[p]
    np.testing.assert_allclose(z, dense @ x, rtol=1e-5, atol=1e-5)


def test_pad_neighbors_truncates_and_self_loops():
    indptr = np.array([0, 5])
    indices = np.array([0, 0, 0, 0, 0])
    nbr, wts = pad_neighbors(indptr, indices, None, sample=3, self_loops=True)
    assert nbr.shape == (1, 3)
    assert nbr[0, 2] == 0 and wts[0, 2] == 1.0   # self loop in last slot
    assert (wts[0, :2] == 1.0).all()


def test_pad_neighbors_self_loop_weight_array():
    indptr = np.array([0, 2, 3])
    indices = np.array([1, 1, 0])
    slw = np.array([0.25, 0.5], np.float32)
    nbr, wts = pad_neighbors(indptr, indices, None, sample=4,
                             self_loops=True, self_loop_weight=slw)
    assert nbr[0, 2] == 0 and wts[0, 2] == np.float32(0.25)
    assert nbr[1, 1] == 1 and wts[1, 1] == np.float32(0.5)


def test_gcn_sample_matches_dense_a_hat_oracle():
    """Regression (self-loop weight): the sampled aggregation of a
    gcn_normalize'd graph must equal the dense oracle
    ``A_hat @ X`` with ``A_hat = D^-1/2 (A+I) D^-1/2`` — the implicit self
    loop carries A_hat's diagonal 1/(d_i+1), not 1.0 (the old hard-coded
    1.0 diverged by ~2.9 max-abs on this very graph)."""
    from repro.core.graph import random_graph
    g = random_graph(12, 40, 5, seed=3).gcn_normalize()
    n, deg = g.n_nodes, np.diff(g.indptr)
    a = np.zeros((n, n), np.float64)
    dst = np.repeat(np.arange(n), deg)
    for e, (i, j) in enumerate(zip(dst, g.indices)):
        a[i, j] += g.edge_weight[e]
    a[np.arange(n), np.arange(n)] += 1.0 / (deg + 1)
    nbr, wts = g.neighbor_sample(int(deg.max()) + 1)
    z = np.asarray(csr_aggregate_ref(jnp.asarray(g.features),
                                     jnp.asarray(nbr), jnp.asarray(wts)))
    np.testing.assert_allclose(z, a @ g.features, rtol=1e-5, atol=1e-5)


def test_explicit_zero_bf_raises():
    """bf=0 is a caller bug, not a default request — the falsy-or
    resolution this guards against silently substituted DEFAULT_BF."""
    x = jnp.zeros((4, 8), jnp.float32)
    nbr = jnp.zeros((4, 2), jnp.int32)
    wts = jnp.ones((4, 2), jnp.float32)
    for backend in ("jnp", "pallas"):
        for bf in (0, -16):
            with pytest.raises(ValueError, match="positive feature block"):
                aggregate(x, nbr, wts, backend=backend, bf=bf,
                          interpret=True)
