"""Telemetry subsystem (DESIGN.md §14): span trees, the disabled fast
path, histogram percentiles, exporters, the span-bytes == measured-traffic
contract, observer isolation, streaming counters, and the benchmark
runner's ``info`` snapshot embedding."""
import json
import logging

import numpy as np
import pytest

from repro import telemetry as tel
from repro.telemetry import (NULL_SPAN, MetricsRegistry, SpanTracer,
                             default_buckets)


@pytest.fixture(autouse=True)
def _clean_telemetry():
    """Process-wide singletons: every test starts and ends disabled+empty."""
    tel.reset()
    tel.disable()
    yield
    tel.reset()
    tel.disable()


# ---- span trees ---------------------------------------------------------

def test_span_nesting_builds_tree():
    tr = SpanTracer(enabled=True)
    with tr.span("tick", n=1):
        with tr.span("halo.gather", bucket=0) as g:
            g.add_bytes(100)
        with tr.span("halo.mvm"):
            with tr.span("halo.mvm.inner") as inner:
                inner.add_bytes(28)
    assert len(tr.roots) == 1
    root = tr.roots[0]
    assert root.name == "tick" and root.attrs == {"n": 1}
    assert [c.name for c in root.children] == ["halo.gather", "halo.mvm"]
    assert root.children[1].children[0].name == "halo.mvm.inner"
    # subtree byte totals roll up; durations are measured and ordered
    assert root.total_bytes() == 128
    assert root.children[0].total_bytes() == 100
    assert root.duration_s >= root.children[0].duration_s >= 0.0
    assert [s.name for s in root.walk()] == [
        "tick", "halo.gather", "halo.mvm", "halo.mvm.inner"]
    d = root.to_dict()
    assert d["name"] == "tick" and len(d["children"]) == 2
    # per-name aggregates survive independently of the ring
    assert tr.summary()["halo.gather"]["count"] == 1


def test_root_ring_is_bounded_but_aggregates_are_not():
    tr = SpanTracer(enabled=True, max_roots=4)
    for i in range(10):
        with tr.span("t"):
            pass
    assert len(tr.roots) == 4
    assert tr.summary()["t"]["count"] == 10


# ---- the disabled fast path (the ≤5% overhead contract) -----------------

def test_disabled_tracer_returns_shared_null_span():
    tr = SpanTracer(enabled=False)
    s = tr.span("anything", k=1)
    assert s is NULL_SPAN and tr.span("other") is s
    with s as inner:                       # all no-ops, no allocation
        inner.set(a=1).add_bytes(5)
    assert not tr.roots and tr.summary() == {}


def test_disabled_device_sync_is_identity():
    tr = SpanTracer(enabled=False)
    x = object()
    assert tr.device_sync(x) is x
    assert not tr.roots


def test_disabled_registry_mutations_do_not_register():
    reg = MetricsRegistry(enabled=False)
    reg.counter("c").inc(5)
    reg.gauge("g").set(1.0)
    reg.histogram("h").observe(0.5)
    reg.event("e", k=1)
    snap = reg.snapshot()
    assert snap["counters"] == {} and snap["gauges"] == {}
    assert snap["histograms"] == {} and snap["n_events"] == 0
    # handles resolve to live metrics after enable (call-site lookups)
    reg.enabled = True
    reg.counter("c").inc(2)
    assert reg.snapshot()["counters"] == {"c": 2.0}


def test_enable_disable_roundtrip_on_module_singletons():
    assert not tel.enabled()
    tel.enable()
    assert tel.enabled()
    with tel.span("x"):
        tel.counter("hits").inc()
    tel.disable()
    assert tel.span("y") is NULL_SPAN
    snap = tel.snapshot()                  # data survives disable
    assert snap["counters"]["hits"] == 1.0 and "x" in snap["spans"]


# ---- histograms ---------------------------------------------------------

def test_histogram_percentiles_monotone_and_bounded():
    reg = MetricsRegistry(enabled=True)
    h = reg.histogram("lat")
    rng = np.random.default_rng(0)
    vals = rng.lognormal(mean=-6.0, sigma=1.5, size=500)
    for v in vals:
        h.observe(float(v))
    q = h.quantiles()
    assert q["p50"] <= q["p95"] <= q["p99"]
    assert vals.min() <= q["p50"] and q["p99"] <= vals.max() * (1 + 1e-9)
    assert h.count == 500
    assert h.percentile(0.0) == pytest.approx(h.vmin)
    assert h.percentile(1.0) == pytest.approx(h.vmax)


def test_histogram_empty_and_buckets():
    reg = MetricsRegistry(enabled=True)
    h = reg.histogram("empty")
    assert h.percentile(0.5) == 0.0 and h.quantiles()["p99"] == 0.0
    b = default_buckets(1e-3, 1.0, per_decade=2)
    assert all(x < y for x, y in zip(b, b[1:]))
    assert b[0] == pytest.approx(1e-3) and b[-1] >= 1.0 - 1e-12


# ---- exporters ----------------------------------------------------------

def test_exporters_parse(tmp_path):
    tel.enable()
    with tel.span("tick"):
        with tel.span("halo.gather") as s:
            s.add_bytes(64)
    tel.counter("reqs", setting="semi").inc(3)
    tel.gauge("frac").set(0.25)
    tel.histogram("lat").observe(1e-3)
    tel.event("planner.plan", recommended="c1k4", score=1.0)

    mpath, tpath = tmp_path / "m.jsonl", tmp_path / "t.jsonl"
    n_m = tel.export_metrics(str(mpath))
    n_t = tel.export_trace(str(tpath))
    mlines = [json.loads(line) for line in mpath.read_text().splitlines()]
    assert len(mlines) == n_m and n_m >= 4
    kinds = {m["type"] for m in mlines}
    assert {"counter", "gauge", "histogram", "event"} <= kinds
    tlines = [json.loads(line) for line in tpath.read_text().splitlines()]
    assert len(tlines) == n_t == 1
    assert tlines[0]["name"] == "tick"
    assert tlines[0]["children"][0]["attrs"]["bytes"] == 64

    text = tel.prometheus_text()
    assert 'reqs{setting="semi"} 3' in text
    assert "lat_bucket{" in text and 'le="+Inf"' in text


# ---- span bytes == measured traffic (the exactness contract) ------------

@pytest.mark.parametrize("setting,buckets", [
    ("centralized", None), ("decentralized", None), ("semi", None),
    ("decentralized", "auto")])
def test_span_bytes_equal_measured_traffic(make_graph, setting, buckets):
    """The forward's span tree bills wire bytes from the same executed
    send/recv tables ``measured_traffic`` counts — totals must be equal,
    exactly (benchmarks/obs_overhead.py gates the same identity)."""
    import jax
    from repro.core import gnn
    from repro.core.partition import plan_execution
    g = make_graph(40, 200, 8, seed=0)
    cfg = gnn.GNNConfig(in_dim=8, hidden_dims=(8,), out_dim=4, sample=4)
    plan = plan_execution(g, setting, backend="jnp", sample=4,
                          n_clusters=None if setting == "centralized" else 3,
                          buckets=buckets)
    params = gnn.init_params(jax.random.key(0), plan.gnn_config(cfg))
    tel.enable()
    jax.block_until_ready(plan.make_forward(cfg)(params))
    span_bytes = sum(r.total_bytes() for r in tel.get_tracer().roots
                     if r.name == "plan.forward")
    measured = int(plan.measured_traffic(plan.gnn_config(cfg)).total_bytes())
    assert span_bytes == measured
    if setting == "centralized":
        assert measured == 0               # no exchange to bill
    else:
        assert measured > 0
        key = f'halo.shipped_bytes{{setting="{setting}"}}'
        assert tel.snapshot()["counters"][key] == measured


def test_disabled_forward_is_undecorated(make_graph):
    """With telemetry off the wrapped forward must produce no spans and
    bit-identical outputs to the enabled run."""
    import jax
    from repro.core import gnn
    from repro.core.partition import plan_execution
    g = make_graph(30, 120, 8, seed=1)
    cfg = gnn.GNNConfig(in_dim=8, hidden_dims=(8,), out_dim=4, sample=4)
    plan = plan_execution(g, "decentralized", backend="jnp", sample=4,
                          n_clusters=3)
    params = gnn.init_params(jax.random.key(1), plan.gnn_config(cfg))
    fwd = plan.make_forward(cfg)
    off = np.asarray(fwd(params))
    assert not tel.get_tracer().roots
    tel.enable()
    on = np.asarray(fwd(params))
    assert tel.get_tracer().roots
    np.testing.assert_array_equal(off, on)


# ---- streaming server: observer isolation + counters --------------------

def _tiny_server(make_graph, policy="eager"):
    from repro.core import gnn
    from repro.core.partition import plan_execution
    from repro.streaming import StreamingGNNServer
    g = make_graph(30, 120, 8, seed=2)
    plan = plan_execution(g, "decentralized", backend="jnp", sample=4,
                          n_clusters=3)
    cfg = gnn.GNNConfig(in_dim=8, hidden_dims=(8,), out_dim=4, sample=4)
    srv = StreamingGNNServer(plan, cfg, policy=policy)
    srv.refresh()
    return g, srv


def _mutate(g, srv, rng, frac=0.2):
    n = max(int(g.n_nodes * frac), 1)
    nodes = rng.choice(g.n_nodes, n, replace=False)
    return srv.ingest(nodes=nodes,
                      rows=rng.normal(size=(n, 8)).astype(np.float32))


def test_observer_exception_is_isolated(make_graph, caplog):
    """A raising observer is logged and skipped — later observers still
    run and the commit itself succeeds (the ISSUE-9 bugfix)."""
    g, srv = _tiny_server(make_graph)
    calls = []

    def bad(server, update):
        raise RuntimeError("observer boom")

    def good(server, update):
        calls.append(update)

    srv.add_observer(bad)
    srv.add_observer(good)
    rng = np.random.default_rng(0)
    with caplog.at_level(logging.ERROR, logger="repro.streaming.server"):
        upd = _mutate(g, srv, rng)
    assert upd is not None                 # commit survived the bad observer
    assert calls == [upd]                  # later observer still notified
    assert any("observer" in r.message for r in caplog.records)

    assert srv.remove_observer(bad) is True
    assert srv.remove_observer(bad) is False   # already gone: no raise
    caplog.clear()
    with caplog.at_level(logging.ERROR, logger="repro.streaming.server"):
        _mutate(g, srv, rng)
    assert not caplog.records              # removed: nothing to isolate
    assert len(calls) == 2


def test_streaming_counters_and_spans(make_graph):
    tel.enable()
    g, srv = _tiny_server(make_graph)
    rng = np.random.default_rng(1)
    for _ in range(3):
        _mutate(g, srv, rng)
    snap = tel.snapshot()
    c = snap["counters"]
    assert c["server.commits"] == srv.commits == 4    # cold full + 3 ticks
    assert c["server.full_refreshes"] == srv.full_refreshes == 1
    assert c["streaming.rows_recomputed"] > 0
    assert c["streaming.rows_cached"] >= 0
    assert c["streaming.recompile_estimate"] >= 1
    assert 0.0 <= snap["gauges"]["streaming.dirty_fraction"] <= 1.0
    for name in ("server.commit", "server.ingest", "engine.full_refresh"):
        assert name in snap["spans"], name
    # span durations feed the histogram registry automatically
    assert 'span_seconds{span="server.commit"}' in snap["histograms"]


def test_query_histogram_via_gnn_server(make_graph):
    from repro.core import gnn
    from repro.core.partition import plan_execution
    from repro.launch.gnn import GNNServer
    tel.enable()
    g = make_graph(30, 120, 8, seed=3)
    plan = plan_execution(g, "centralized", backend="jnp", sample=4)
    cfg = gnn.GNNConfig(in_dim=8, hidden_dims=(8,), out_dim=4, sample=4)
    srv = GNNServer(plan, cfg)
    srv.refresh()
    srv.query(np.arange(6))
    srv.query(np.arange(3))
    snap = tel.snapshot()
    assert snap["counters"]["server.queries"] == 9
    assert snap["spans"]["server.query"]["count"] == 2
    h = snap["histograms"]['span_seconds{span="server.query"}']
    assert h["count"] == 2 and h["p50"] <= h["p99"]


# ---- benchmark runner embedding -----------------------------------------

def test_run_one_embeds_telemetry_info():
    """Every bench record carries the run's telemetry snapshot under the
    record-level ``info`` key, which the determinism projection drops."""
    import types

    from benchmarks.run import canonical_metrics, run_one

    def fake_main():
        tel.counter("fake.hits").inc(7)
        with tel.span("fake.phase"):
            pass
        fake.METRICS.update(answer=42)
        return 0

    fake = types.SimpleNamespace(main=fake_main, METRICS={}, SMOKE_ARGV=[])
    rc, record = run_one("fake", fake, smoke=True)
    assert rc == 0 and record["metrics"]["answer"] == 42
    snap = record["info"]["telemetry"]
    assert snap["counters"]["fake.hits"] == 7.0
    assert "fake.phase" in snap["spans"]
    # info is volatile: two runs' canonical records agree regardless of it
    assert "info" not in canonical_metrics(record)
    assert not tel.enabled()               # run_one restored the off state
