"""repro.tuning: the roofline-guided kernel autotuner (DESIGN.md §11).

Contracts under test:
  * candidate enumeration and roofline pruning are deterministic pure
    functions of the geometry (default always survives);
  * with a deterministic measure_fn the winner and the serialized cache
    are byte-identical across runs of the same (geometry, platform, seed);
  * a cache hit answers without re-measuring;
  * tuned configs never change numerics — forward outputs are
    bit-identical to the hand-picked defaults on every backend;
  * ExecutionPlan.tune_kernels threads the bundle into the serving path.
"""
import numpy as np
import pytest

from repro.tuning import (AggregateConfig, AggregateGeometry, CrossbarConfig,
                          CrossbarGeometry, FusedConfig, FusedGeometry,
                          TuneCache, TunedKernels, candidates,
                          current_platform, default_config, launch_cost,
                          prune, registry, tune)


@pytest.fixture(autouse=True)
def _clean_registry():
    registry.clear()
    yield
    registry.clear()


XGEOM = CrossbarGeometry(m=40, k=700, n=64, rows_per_xbar=128)
FGEOM = FusedGeometry(nd=40, n=40, f_in=12, f_out=16, sample=8)


# ---- candidate space + pruning determinism --------------------------------

def test_candidates_default_first_unique_and_legal():
    for geom in (XGEOM, FGEOM):
        cands = candidates(geom)
        assert cands[0] == default_config(geom)
        assert len(set(cands)) == len(cands)
        assert cands == candidates(geom)           # deterministic
    # depth must divide the physical crossbar count (k=700 @128 -> n_k=6)
    assert all(XGEOM.n_k % c.depth == 0 for c in candidates(XGEOM))


def test_prune_deterministic_and_keeps_default():
    for geom in (XGEOM, FGEOM):
        a, b = prune(geom), prune(geom)
        assert a == b
        assert any(c == default_config(geom) for c, _ in a)
        assert len(a) <= 4 + 1                     # max_survivors (+default)
        bounds = [bd for _, bd in a if True]
        assert all(bd > 0 for bd in bounds)


def test_prune_bounds_sorted_and_slack_filtered():
    survivors = prune(XGEOM, slack=2.0, max_survivors=16)
    bounds = [b for _, b in survivors]
    # default may be appended out of order at the end; the rest is sorted
    body = bounds[:-1] if survivors[-1][0] == default_config(XGEOM) \
        else bounds
    assert body == sorted(body)
    assert all(b <= 2.0 * min(bounds) for b in body)


def test_launch_cost_scales_with_geometry():
    c = CrossbarConfig(bm=8, bn=128, depth=1)   # bm | m: no padding slack
    small = launch_cost(XGEOM, c)
    big = launch_cost(CrossbarGeometry(m=80, k=700, n=64,
                                       rows_per_xbar=128), c)
    assert big.flops == 2 * small.flops
    assert big.hbm_bytes > small.hbm_bytes
    assert small.vmem_bytes > 0 and small.grid_steps >= 1


# ---- tune(): determinism, caching, registry -------------------------------

def _fake_measure():
    """Deterministic measure_fn preferring large bn then large bm/bf,
    counting invocations."""
    calls = []

    def fn(geom, config):
        calls.append(config)
        d = config.as_dict()
        return 1.0 / (1 + sum(d.values()))
    return fn, calls


def test_tune_deterministic_cache_bytes():
    dumps = []
    for _ in range(2):
        cache = TuneCache()
        fn, _ = _fake_measure()
        winner, info = tune(XGEOM, cache=cache, seed=3, measure_fn=fn,
                            register_result=False)
        assert not info["cached"]
        dumps.append(cache.dumps())
    assert dumps[0] == dumps[1]                   # byte-identical
    assert f'"{current_platform()}"' in dumps[0]


def test_tune_winner_never_slower_than_default():
    fn, _ = _fake_measure()
    winner, info = tune(FGEOM, measure_fn=fn, register_result=False)
    assert info["winner_s"] <= info["default_s"]
    assert any(c == default_config(FGEOM).as_dict()
               for c, _ in info["measured"])


def test_cache_hit_skips_measurement(tmp_path):
    path = str(tmp_path / "tuned.json")
    cache = TuneCache(path)
    fn, calls = _fake_measure()
    w1, info1 = tune(XGEOM, cache=cache, measure_fn=fn)
    n_measured = len(calls)
    assert n_measured == info1["n_candidates"] > 0

    # same cache object and a reloaded one: no new measurements
    w2, info2 = tune(XGEOM, cache=cache, measure_fn=fn)
    w3, info3 = tune(XGEOM, cache=TuneCache.load(path), measure_fn=fn)
    assert info2["cached"] and info3["cached"]
    assert (w1, w1) == (w2, w3)
    assert len(calls) == n_measured
    # force=True re-measures
    _, info4 = tune(XGEOM, cache=cache, measure_fn=fn, force=True)
    assert not info4["cached"] and len(calls) == 2 * n_measured


def test_tune_registers_winner_for_eager_resolution():
    fn, _ = _fake_measure()
    winner, _ = tune(FGEOM, measure_fn=fn)
    assert registry.lookup(FGEOM.key()) == winner
    assert registry.lookup(XGEOM.key()) is None


def test_registry_activate_from_cache(tmp_path):
    path = str(tmp_path / "tuned.json")
    cache = TuneCache(path)
    fn, _ = _fake_measure()
    winner, _ = tune(FGEOM, cache=cache, measure_fn=fn,
                     register_result=False)
    assert registry.lookup(FGEOM.key()) is None
    n = registry.activate(TuneCache.load(path))
    assert n == 1 and registry.lookup(FGEOM.key()) == winner


def test_tuned_kernels_bundle_is_hashable_and_ordered():
    a = TunedKernels.of({FGEOM.key(): FusedConfig(256),
                         XGEOM.key(): CrossbarConfig(64, 256, 2)})
    b = TunedKernels.of({XGEOM.key(): CrossbarConfig(64, 256, 2),
                         FGEOM.key(): FusedConfig(256)})
    assert a == b and hash(a) == hash(b)          # insertion-order free
    assert a.lookup(FGEOM.key()) == FusedConfig(256)
    assert a.lookup(("nope",)) is None
    merged = a.merged(TunedKernels.of({FGEOM.key(): FusedConfig(512)}))
    assert merged.lookup(FGEOM.key()) == FusedConfig(512)
    assert len(merged) == 2


# ---- numerics invariance: tuned == default, bit for bit -------------------

def test_forward_bit_identical_with_tuned_configs(backend, make_graph):
    """A non-default tuned bundle threaded through GNNConfig.tuned must
    not change a single output bit on any backend — block sizes only move
    zero padding, depth only regroups whole-crossbar accumulation."""
    import dataclasses
    import jax
    from repro.core import gnn

    g = make_graph(n=40, e=200, f=12, seed=1)
    nbr, wts = g.neighbor_sample(8)
    cfg = gnn.GNNConfig(in_dim=12, hidden_dims=(16,), out_dim=4, sample=8,
                        backend=backend)
    params = gnn.init_params(jax.random.key(0), cfg)
    x = np.asarray(g.features, np.float32)
    ref = np.asarray(gnn.forward(params, x, nbr, wts, cfg))

    geoms = [FusedGeometry(nd=40, n=40, f_in=f_in, f_out=f_out, sample=8,
                           ideal=True, rows_per_xbar=512)
             for f_in, f_out in zip(cfg.dims[:-1], cfg.dims[1:])]
    tuned = TunedKernels.of({gm.key(): FusedConfig(256) for gm in geoms})
    out = np.asarray(gnn.forward(params, x, nbr, wts,
                                 dataclasses.replace(cfg, tuned=tuned)))
    assert np.array_equal(ref, out)


def test_crossbar_kernel_bit_identical_across_depth_and_blocks():
    """The quantized crossbar kernel at tuned (bm, bn, depth) equals the
    default launch bit for bit (the ADC stays per physical crossbar)."""
    import jax.numpy as jnp
    from repro.kernels.crossbar_mvm import CrossbarNumerics
    from repro.kernels.crossbar_mvm.ops import crossbar_matmul

    rng = np.random.default_rng(0)
    x = jnp.asarray(np.abs(rng.normal(size=(24, 700))).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(700, 48)).astype(np.float32))
    cfg = CrossbarNumerics(rows_per_xbar=128)
    ref = np.asarray(crossbar_matmul(x, w, cfg, bm=8, bn=8, interpret=True))
    for bm, bn, depth in ((8, 16, 2), (16, 8, 3), (8, 8, 6)):
        got = np.asarray(crossbar_matmul(x, w, cfg, bm=bm, bn=bn,
                                         depth=depth, interpret=True))
        assert np.array_equal(ref, got), (bm, bn, depth)


def test_aggregate_kernel_bit_identical_across_bf():
    """The pallas csr_aggregate at any tuned feature-block width equals
    the default launch bit for bit — bf only moves zero padding, the
    per-slot accumulation order is unchanged."""
    from repro.kernels.csr_aggregate import aggregate

    rng = np.random.default_rng(0)
    x = rng.normal(size=(40, 24)).astype(np.float32)
    nbr = rng.integers(0, 40, size=(40, 6)).astype(np.int32)
    wts = np.abs(rng.normal(size=(40, 6))).astype(np.float32)
    ref = np.asarray(aggregate(x, nbr, wts, backend="pallas"))
    for bf in (8, 16, 64, 256):
        got = np.asarray(aggregate(x, nbr, wts, backend="pallas", bf=bf))
        assert np.array_equal(ref, got), bf
    # and through GNNConfig.tuned (the jit-threaded resolution path)
    geom = AggregateGeometry(nd=40, n=40, f=24, sample=6)
    tuned = TunedKernels.of({geom.key(): AggregateConfig(16)})
    got = np.asarray(aggregate(x, nbr, wts, backend="pallas", tuned=tuned))
    assert np.array_equal(ref, got)


def test_crossbar_depth_must_divide_crossbar_count():
    import jax.numpy as jnp
    from repro.kernels.crossbar_mvm import CrossbarNumerics
    from repro.kernels.crossbar_mvm.crossbar_mvm import \
        crossbar_matmul_quantized

    xq = jnp.zeros((8, 256), jnp.uint32)
    wq = jnp.zeros((256, 128), jnp.float32)
    with pytest.raises(ValueError, match="depth 3 must divide"):
        crossbar_matmul_quantized(xq, wq, CrossbarNumerics(rows_per_xbar=128),
                                  bm=8, bn=128, depth=3, interpret=True)


def test_ops_resolve_through_registry_eagerly():
    """With no explicit block args, the ops wrapper picks up a registry
    entry registered *after* a previous call — no stale-trace capture."""
    import jax.numpy as jnp
    from repro.kernels.fused_layer import fused_gnn_layer
    from repro.kernels.crossbar_mvm import CrossbarNumerics

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(12, 20)).astype(np.float32))
    nbr = jnp.asarray(rng.integers(0, 12, size=(12, 4)).astype(np.int32))
    wts = jnp.asarray(np.abs(rng.normal(size=(12, 4))).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(20, 8)).astype(np.float32))
    b = jnp.zeros((8,), jnp.float32)
    cfg = CrossbarNumerics(ideal=True)
    ref = np.asarray(fused_gnn_layer(x, nbr, wts, w, b, cfg))
    geom = FusedGeometry(nd=12, n=12, f_in=20, f_out=8, sample=4,
                         ideal=True, rows_per_xbar=cfg.rows_per_xbar)
    registry.register(geom.key(), FusedConfig(256))
    out = np.asarray(fused_gnn_layer(x, nbr, wts, w, b, cfg))
    assert np.array_equal(ref, out)               # still bit-identical


# ---- plan integration -----------------------------------------------------

def test_execution_plan_tune_kernels_end_to_end(tmp_path, make_graph):
    import jax
    from repro.core import gnn
    from repro.core.partition import plan_execution

    g = make_graph(n=30, e=120, f=10, seed=2)
    plan = plan_execution(g, "decentralized", backend="fused", sample=4,
                          n_clusters=2)
    cfg = gnn.GNNConfig(in_dim=10, hidden_dims=(8,), out_dim=4, sample=4)
    fn, _ = _fake_measure()
    cache = TuneCache(str(tmp_path / "tuned.json"))
    tuned = plan.tune_kernels(cfg, cache=cache, measure_fn=fn)
    assert len(tuned) == len(cfg.dims) - 1        # one geometry per layer
    assert plan.tuned is tuned
    assert plan.gnn_config(cfg).tuned == tuned
    # the tuned forward still matches the untuned one bit for bit
    params = gnn.init_params(jax.random.key(0), plan.gnn_config(cfg))
    out_tuned = np.asarray(plan.make_forward(cfg)(params))
    plan.tuned = None
    registry.clear()
    out_plain = np.asarray(plan.make_forward(cfg)(params))
    assert np.array_equal(out_tuned, out_plain)


def test_plan_geometries_per_backend(make_graph):
    from repro.core import gnn
    from repro.core.partition import plan_execution
    from repro.tuning import AggregateGeometry, plan_geometries

    g = make_graph(n=20, e=80, f=6, seed=0)
    cfg = gnn.GNNConfig(in_dim=6, hidden_dims=(8,), out_dim=4, sample=4)
    # jnp is pure XLA: nothing tunable
    plan = plan_execution(g, "centralized", backend="jnp", sample=4)
    assert plan_geometries(plan, plan.gnn_config(cfg)) == []
    assert len(plan.tune_kernels(cfg)) == 0
    # composed pallas launches the standalone aggregation kernel per layer
    plan = plan_execution(g, "centralized", backend="pallas", sample=4)
    geoms = plan_geometries(plan, plan.gnn_config(cfg))
    assert len(geoms) == len(cfg.dims) - 1
    assert all(isinstance(gm, AggregateGeometry) for gm in geoms)
    assert [gm.f for gm in geoms] == [6, 8]
    assert all(gm.nd == g.n_nodes and gm.sample == 4 for gm in geoms)


def test_plan_geometries_bucketed_one_shape_per_bucket(make_graph):
    from repro.core import gnn
    from repro.core.partition import plan_execution
    from repro.tuning import plan_geometries

    g = make_graph(n=40, e=200, f=6, seed=1)
    cfg = gnn.GNNConfig(in_dim=6, hidden_dims=(8,), out_dim=4, sample=4)
    plan = plan_execution(g, "decentralized", backend="fused", sample=4,
                          n_clusters=4, buckets="auto")
    geoms = plan_geometries(plan, plan.gnn_config(cfg))
    bp = plan.bucketed
    shapes = {(bp.n_caps[b], bp.n_caps[b] + bp.h_caps[b], bp.s_caps[b])
              for b in range(bp.n_buckets)}
    assert len(geoms) == len(shapes) * (len(cfg.dims) - 1)
    assert {(gm.nd, gm.n, gm.sample) for gm in geoms} == shapes
