"""Checkpointing: atomic save/restore, dtypes, corruption fallback, GC."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)


def _tree(seed=0):
    k = jax.random.key(seed)
    return {"a": jax.random.normal(k, (4, 8), jnp.float32),
            "b": {"w": jax.random.normal(k, (3,), jnp.float32)
                  .astype(jnp.bfloat16),
                  "step": jnp.int32(7)}}


def test_roundtrip_with_bf16(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 5, t)
    like = jax.tree.map(lambda x: np.zeros(x.shape, x.dtype), t)
    got, step = restore_checkpoint(str(tmp_path), like)
    assert step == 5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_corruption_falls_back(tmp_path):
    t0, t1 = _tree(0), _tree(1)
    save_checkpoint(str(tmp_path), 1, t0)
    save_checkpoint(str(tmp_path), 2, t1)
    # corrupt the newest
    npz = os.path.join(str(tmp_path), "step_0000000002", "arrays.npz")
    with open(npz, "r+b") as f:
        f.seek(100)
        f.write(b"\xde\xad\xbe\xef")
    like = jax.tree.map(lambda x: np.zeros(x.shape, x.dtype), t0)
    got, step = restore_checkpoint(str(tmp_path), like)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(t0["a"]))


def test_manager_gc_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=1, keep=2)
    t = _tree()
    for s in range(5):
        mgr.maybe_save(s, t, blocking=False)
    mgr.finalize()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(str(tmp_path))
                   if d.startswith("step_"))
    assert len(steps) <= 3 and steps[-1] == 4
    assert latest_step(str(tmp_path)) == 4


def test_elastic_restore_onto_mesh(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t)
    mgr = CheckpointManager(str(tmp_path))
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))
    from jax.sharding import PartitionSpec as P
    like = jax.tree.map(lambda x: np.zeros(x.shape, x.dtype), t)
    specs = jax.tree.map(lambda x: P(), like)
    got, step = mgr.restore(like, mesh=mesh, shardings=specs)
    assert step == 3
    assert all(x.sharding.mesh.shape["data"] == 1
               for x in jax.tree.leaves(got))
