"""Scale features: gradient accumulation equivalence + straggler rebalance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.graph import random_graph
from repro.core.partition import partition, rebalance
from repro.launch.steps import make_train_step
from repro.models import build
from repro.optim import AdamWConfig, adamw_init


@pytest.mark.slow          # >10s on the CI CPU (--durations=15)
def test_grad_accum_matches_full_batch():
    cfg = get_config("internlm2-1.8b", smoke=True)
    model = build(cfg)
    params = model.init(jax.random.key(0))
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=1e-3)
    k = jax.random.key(1)
    batch = {"tokens": jax.random.randint(k, (4, 16), 0, cfg.vocab),
             "labels": jax.random.randint(jax.random.fold_in(k, 1), (4, 16),
                                          0, cfg.vocab)}
    p1, _, m1 = make_train_step(model, ocfg)(params, opt, batch)
    p2, _, m2 = make_train_step(model, ocfg, accum_steps=2)(params, opt,
                                                            batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-3)


def test_rebalance_moves_load_off_stragglers():
    g = random_graph(256, 1024, 4, seed=0)
    k = 4
    part = partition(g, k)
    sizes0 = np.array([part.local_mask[c].sum() for c in range(k)])
    latency = np.array([1.0, 1.0, 1.0, 10.0])       # cluster 3 is a straggler
    newp = rebalance(g, part, latency)
    sizes1 = np.array([(newp.assignment == c).sum() for c in range(k)])
    assert sizes1[3] < sizes0[3]                     # straggler shed load
    assert sizes1.sum() == 256                       # nothing lost
    # tables remain consistent: every halo node is owned by its halo_src
    for c in range(k):
        valid = newp.halo_src[c] >= 0
        for u, o in zip(newp.halo_nodes[c][valid], newp.halo_src[c][valid]):
            assert newp.assignment[u] == o
    # runtime still works on the rebalanced partition
    from repro.core.partition import build_local_subgraphs, gather_features
    sub = build_local_subgraphs(g, newp, sample=4)
    feats = gather_features(g, newp)
    assert feats.shape[0] == k and sub.neighbors.shape[0] == k


def test_rebalance_noop_when_balanced():
    g = random_graph(128, 512, 4, seed=1)
    part = partition(g, 4)
    newp = rebalance(g, part, np.ones(4))
    np.testing.assert_array_equal(part.assignment, newp.assignment)


def test_preferred_tp_divisibility():
    from repro.launch.mesh import preferred_tp
    cases = {"internlm2-1.8b": 16,   # 16 heads, 8192 ffn
             "yi-34b": 8,            # 56 heads: 8 | 56, 16 does not
             "grok-1-314b": 8,       # 8 experts
             "qwen2-vl-2b": 4,       # 12 heads
             "minicpm3-4b": 8,       # 40 heads
             "deepseek-v3-671b": 16}  # 128 heads, 256 experts
    for arch, want in cases.items():
        cfg = get_config(arch)
        got = preferred_tp(cfg, 256)
        assert got == want, (arch, got, want)
        assert cfg.n_heads % got == 0
