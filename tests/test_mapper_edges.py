"""Mapper edge cases the planner hits while sweeping (DESIGN.md §8/§10).

The planner enumerates setting × cluster count × crossbar geometry over
arbitrary graph statistics; every point must either compile to a
well-formed ``CompiledMapping`` or raise the documented ``ValueError`` —
never silently mis-schedule (a wrong round count would silently corrupt
every latency/energy rollup the planner ranks candidates by).
"""
import dataclasses

import numpy as np
import pytest

from repro.core.costmodel import DEFAULT_HW
from repro.core.graph import Graph, GraphStats, random_graph
from repro.core.partition import plan_execution
from repro.mapper import XbarInventory, tile_layer
from repro.mapper.compile import compile_mapping, items_per_device

SETTINGS = ("centralized", "decentralized", "semi")


def _zero_edge_graph(n: int = 9, f: int = 6) -> Graph:
    rng = np.random.default_rng(0)
    return Graph(np.zeros(n + 1, np.int64), np.zeros(0, np.int32), None,
                 rng.normal(size=(n, f)).astype(np.float32))


# ---------------------------------------------------- zero-edge graphs

@pytest.mark.parametrize("setting", SETTINGS)
def test_zero_edge_stats_compile(setting):
    """n_edges = 0, avg_cs = 0: every core still schedules at least its
    self-row work — no zero-division, no zero-round schedule."""
    stats = GraphStats("empty", 32, 0, 8, 0.0)
    m = compile_mapping((8, 16), stats, setting=setting, n_clusters=4)
    assert m.cam.rounds >= 1 and m.agg.rounds >= 1 and m.fx.rounds >= 1
    assert m.t_compute > 0 and m.energy_j > 0
    assert all(0 < occ <= 1.0 for occ in m.array_utilization)


def test_zero_edge_graph_serves_end_to_end():
    """A concrete edgeless graph flows through partition + forward: every
    row aggregates only its self loop (weight 1/(0+1) = 1)."""
    import jax
    from repro.core import gnn
    g = _zero_edge_graph().gcn_normalize()
    np.testing.assert_allclose(g.self_loop, 1.0)
    cfg = gnn.GNNConfig(in_dim=6, hidden_dims=(8,), out_dim=4, sample=4)
    params = gnn.init_params(jax.random.key(0), cfg)
    cent = plan_execution(g, "centralized", sample=4)
    ref = cent.scatter(np.asarray(cent.make_forward(cfg)(params)))
    dec = plan_execution(g, "decentralized", sample=4, n_clusters=3)
    out = dec.scatter(np.asarray(dec.make_forward(cfg)(params)))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)
    assert dec.part.comm_volume.sum() == 0       # nothing to exchange


# ------------------------------------------------- single-node clusters

def test_single_node_clusters_compile_and_run():
    """k == n (every cluster one node): items_per_device floors at 1 and
    the concrete runtime still matches the centralized oracle."""
    import jax
    from repro.core import gnn
    assert items_per_device("semi", 8, 8) == 1
    assert items_per_device("semi", 8, 100) == 1      # k > n floors too
    stats = GraphStats("tiny", 8, 24, 6, 3.0)
    m = compile_mapping((6, 16), stats, setting="semi", n_clusters=8)
    assert m.items_per_device == 1 and m.t_compute > 0
    g = random_graph(8, 24, 6, seed=3).gcn_normalize()
    cfg = gnn.GNNConfig(in_dim=6, hidden_dims=(8,), out_dim=4, sample=4)
    params = gnn.init_params(jax.random.key(0), cfg)
    cent = plan_execution(g, "centralized", sample=4)
    ref = cent.scatter(np.asarray(cent.make_forward(cfg)(params)))
    plan = plan_execution(g, "decentralized", sample=4, n_clusters=8)
    assert plan.part.n_max == 1
    out = plan.scatter(np.asarray(plan.make_forward(cfg)(params)))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_plan_execution_clamps_cluster_count_to_nodes():
    """k > n would build empty devices; the runtime clamps instead (the
    planner sweeps cluster counts over arbitrarily small graphs)."""
    g = random_graph(6, 20, 4, seed=0).gcn_normalize()
    for setting in ("decentralized", "semi"):
        p = plan_execution(g, setting, sample=4, n_clusters=50)
        assert p.n_clusters == 6
        owned = p.part.local_nodes[p.part.local_mask]
        assert sorted(owned.tolist()) == list(range(6))


# ------------------------------------- scarcity: no duplication possible

def test_scarce_inventory_serializes_never_duplicates():
    """One array per core against a 12-tile weight set: the only legal
    schedule is full serialization (copies == 1, groups == tiles), and the
    rollup must price every round."""
    inv = XbarInventory(cam_arrays=1, agg_arrays=1, fx_arrays=1)
    stats = GraphStats("wide", 100, 1000, 1433, 10.0)
    m = compile_mapping((1433, 128), stats, setting="centralized",
                        inventory=inv)
    t = m.layers[0].tiling
    assert t.k_tiles == 12 and t.n_tiles == 1       # 1433/128 rows
    assert m.fx.copies == 1 and m.fx.groups == 12 and not m.fx.resident
    assert m.fx.rounds == m.fx.n_items * 12
    assert 0 < m.fx.occupancy <= 1.0
    rich = compile_mapping((1433, 128), stats, setting="centralized")
    assert m.t_compute > rich.t_compute             # scarcity costs rounds
    assert m.energy_j == pytest.approx(rich.energy_j)   # same work, though


# ------------------------- re-geometried arrays: both axes overflow one

def test_with_xbar_size_overflows_both_axes():
    """A 216x300 layer on 64x64 arrays spans >1 array in rows *and*
    columns; the tiling, the kernel grid, and the rollup must all agree."""
    inv = XbarInventory().with_xbar_size(64)
    stats = GraphStats("g", 500, 5000, 216, 10.0)
    m = compile_mapping((216, 300, 16), stats, setting="centralized",
                        inventory=inv)
    t0 = m.layers[0].tiling
    assert t0.rows == 64 and t0.cols == 64
    assert t0.k_tiles == 4 and t0.n_tiles == 5      # both axes > 1 array
    assert t0.n_arrays == 20
    assert t0.pad_k == 4 * 64 - 216 and t0.pad_n == 5 * 64 - 300
    g0 = m.layers[0].grid
    assert g0.k_pad % 64 == 0 and g0.bk == 64
    assert m.weight_arrays == sum(lm.tiling.n_arrays for lm in m.layers)
    # iso-cell re-geometry keeps the silicon budget (±1 array rounding)
    iso = XbarInventory().with_xbar_size(64, iso_cells=True)
    assert iso.fx_arrays * 64 * 64 <= XbarInventory().total_cells[2]


# ----------------------------------------- documented failure surfaces

def test_documented_value_errors_not_silent_misschedules():
    stats = GraphStats("g", 100, 1000, 16, 4.0)
    # one weight cannot span an array: cols < bit_slices
    with pytest.raises(ValueError, match="cannot hold"):
        tile_layer(8, 8, rows=8, cols=4, w_bits=8, cell_bits=1)
    with pytest.raises(ValueError, match="cannot hold"):
        compile_mapping(
            (16, 8), stats,
            inventory=dataclasses.replace(XbarInventory().with_xbar_size(4),
                                          cell_bits=1))
    # degenerate layer dims
    with pytest.raises(ValueError, match="positive layer dims"):
        compile_mapping((16, 0), stats)
    # inventory fields must be physical
    with pytest.raises(ValueError, match=">= 1"):
        XbarInventory(agg_arrays=0)
    # unknown setting names the valid ones
    with pytest.raises(ValueError, match="centralized"):
        compile_mapping((16, 8), stats, setting="federated")


def test_planner_sweep_space_compiles_everywhere():
    """The exact grid the planner enumerates (settings x cluster counts x
    crossbar sizes) compiles on hostile stats — zero edges, single node,
    huge features — or raises ValueError; nothing else escapes."""
    hostile = (GraphStats("empty", 16, 0, 4, 0.0),
               GraphStats("one", 1, 0, 4, 0.0),
               GraphStats("wide", 64, 600, 3703, 2.0))
    for stats in hostile:
        for setting in SETTINGS:
            for k in (1, 4, 64):
                for size in (None, 64, 512):
                    inv = XbarInventory.from_hardware(DEFAULT_HW, setting)
                    if size is not None:
                        inv = inv.with_xbar_size(size)
                    m = compile_mapping((max(stats.feature_len, 1), 32),
                                        stats, inventory=inv,
                                        setting=setting, n_clusters=k)
                    assert m.t_compute > 0
                    assert m.cam.rounds >= 1 and m.fx.rounds >= 1
