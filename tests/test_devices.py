"""Device-technology subsystem (DESIGN.md §13): bank registry, anchor
bit-exactness through the mapper, Monte-Carlo variation determinism across
backends, host calibration round-trip + staleness, and the planner's
technology axis (mixed-tier frontier, noise-tolerance rejection)."""
import dataclasses
import json

import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core import costmodel
from repro.core.graph import TAXI_STATS
from repro.devices import (ANCHOR, CalibrationStaleError, HostCalibration,
                           NOISE_GRID, UnknownTechnologyError,
                           known_technologies, load_calibration,
                           modeled_p99_error, mvm_error_bounds,
                           primitive_scales, resolve_technology,
                           sample_conductance_noise, save_calibration,
                           technology_table)
from repro.devices.params import SOT_MRAM, TechnologyParams
from repro.kernels.crossbar_mvm import CrossbarNumerics
from repro.mapper.compile import compile_mapping
from repro.planner import WorkloadProfile, plan

DIMS = (max(TAXI_STATS.feature_len, 1), 128)
TECHS = ("sot-mram", "reram", "sram", "fefet")
PAIR = ("reram", "sram")


# ------------------------------------------------------------- bank

def test_registry_contents():
    names = known_technologies()
    assert len(names) >= 4 and set(TECHS) <= set(names)
    for name in names:
        t = resolve_technology(name)
        assert t.name == name
        assert t.read_latency_s > 0 and t.read_energy_j > 0
    # a record resolves to itself (ad-hoc records need no registration)
    assert resolve_technology(SOT_MRAM) is SOT_MRAM
    assert {r["name"] for r in technology_table()} == set(names)


def test_unknown_technology_error_names_the_registry():
    with pytest.raises(UnknownTechnologyError, match="sot-mram.*reram"):
        resolve_technology("nvmeee")
    err = pytest.raises(UnknownTechnologyError,
                        resolve_technology, "nvmeee").value
    assert err.name == "nvmeee" and set(TECHS) <= set(err.known)


def test_compile_mapping_unknown_technology_is_named():
    # the regression the satellite asks for: a typo'd --tech fails with the
    # named registry error before any latency rollup
    with pytest.raises(UnknownTechnologyError, match="registered"):
        compile_mapping(DIMS, TAXI_STATS, technology="sot_mram")


def test_anchor_scales_are_exact_identity():
    assert primitive_scales(ANCHOR) == (1.0, 1.0)
    lat, ene = primitive_scales("reram")
    assert lat > 1.0 and ene < 1.0        # slower reads, cheaper reads


def test_anchor_compile_is_bit_identical():
    for setting in ("centralized", "decentralized", "semi"):
        base = compile_mapping(DIMS, TAXI_STATS, setting=setting,
                               n_clusters=16)
        anch = compile_mapping(DIMS, TAXI_STATS, setting=setting,
                               n_clusters=16, technology=ANCHOR)
        assert anch.t_compute == base.t_compute      # ==, not allclose
        assert anch.energy_j == base.energy_j
        assert base.technology == anch.technology == ANCHOR


def test_technology_scales_latency_and_energy():
    base = compile_mapping(DIMS, TAXI_STATS)
    reram = compile_mapping(DIMS, TAXI_STATS, technology="reram")
    sram = compile_mapping(DIMS, TAXI_STATS, technology="sram")
    assert reram.t_compute > base.t_compute > sram.t_compute
    assert sram.energy_j > base.energy_j > reram.energy_j
    assert reram.technology == "reram"


def test_calibrated_mode_rejects_technology():
    with pytest.raises(ValueError, match="derived"):
        costmodel.predict("centralized", TAXI_STATS, technology="reram")


# ------------------------------------------------------------- variation

def test_noise_draws_are_grid_quantized_and_seeded():
    nz = sample_conductance_noise(7, (16, 8), "reram")
    assert nz.shape == (16, 8) and nz.dtype == np.float32
    assert np.array_equal(nz * NOISE_GRID, np.round(nz * NOISE_GRID))
    assert np.array_equal(nz, sample_conductance_noise(7, (16, 8), "reram"))
    assert not np.array_equal(
        nz, sample_conductance_noise(8, (16, 8), "reram"))
    assert np.all(sample_conductance_noise(7, (16, 8), "sram") == 0.0)


BOUNDS_KW = dict(m=8, k=64, n=16, trials=4, seed=0)


def test_bounds_byte_identical_across_exact_backends():
    # jnp and pallas share the oracle crossbar stage bit-for-bit; the same
    # seed must therefore produce byte-identical *bounds*, not just close
    jnp_b = mvm_error_bounds("reram", **BOUNDS_KW, backend="jnp")
    pal_b = mvm_error_bounds("reram", **BOUNDS_KW, backend="pallas")
    assert jnp_b == pal_b                      # dataclass field equality
    assert jnp_b.mean_err > 0 and jnp_b.p99_err >= jnp_b.mean_err


def test_bounds_seed_deterministic_rerun():
    for backend in ("jnp", "pallas"):
        a = mvm_error_bounds("fefet", **BOUNDS_KW, backend=backend)
        b = mvm_error_bounds("fefet", **BOUNDS_KW, backend=backend)
        assert a == b


def test_sram_zero_noise_is_exactly_clean():
    b = mvm_error_bounds("sram", **BOUNDS_KW)
    assert b.mean_err == 0.0 and b.p99_err == 0.0 and b.ci95 == 0.0


def test_bounds_monotone_in_sigma():
    errs = {t: mvm_error_bounds(t, **BOUNDS_KW).mean_err for t in TECHS}
    order = sorted(TECHS, key=lambda t: resolve_technology(t).noise_sigma)
    vals = [errs[t] for t in order]
    assert vals == sorted(vals)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(min_value=1, max_value=2 ** 20))
def test_different_seeds_agree_within_ci(seed):
    # a different-seed rerun estimates the same population mean: the two
    # bounds must agree within their combined confidence intervals
    ref = mvm_error_bounds("reram", m=8, k=64, n=16, trials=6, seed=0)
    other = mvm_error_bounds("reram", m=8, k=64, n=16, trials=6, seed=seed)
    assert ref.within_ci(other)
    assert other.seed == seed


def test_modeled_p99_error_shape():
    assert modeled_p99_error("sram", 128) == 0.0
    assert modeled_p99_error("reram", 128) > modeled_p99_error("fefet", 128)
    # more active rows average more noise away per line
    cfg = CrossbarNumerics()
    assert modeled_p99_error("reram", 8, cfg) > \
        modeled_p99_error("reram", cfg.rows_per_xbar, cfg)


# ------------------------------------------------------------- calibration

def test_calibration_roundtrip_and_staleness(tmp_path):
    from repro.tuning import current_platform
    path = str(tmp_path / "cal.json")
    cal = HostCalibration(platform=current_platform(), t_cam=1e-4,
                          t_agg=2e-3, t_fx=3e-4)
    save_calibration(cal, path)
    assert load_calibration(path) == cal           # strict: platform match
    stale = dataclasses.replace(cal, platform="tpu")
    with open(path, "w") as f:
        json.dump(stale.as_dict(), f)
    with pytest.raises(CalibrationStaleError, match="tpu"):
        load_calibration(path)
    assert load_calibration(path, strict=False) == stale


def test_calibration_validates_positive():
    with pytest.raises(ValueError, match="t_agg"):
        HostCalibration(platform="cpu", t_cam=1e-4, t_agg=0.0, t_fx=1e-4)


def test_calibration_reanchors_derived_primitives():
    from repro.tuning import current_platform
    cal = HostCalibration(platform=current_platform(), t_cam=1e-4,
                          t_agg=2e-3, t_fx=3e-4)
    base = compile_mapping(DIMS, TAXI_STATS)
    recal = compile_mapping(DIMS, TAXI_STATS, calibration=cal)
    # wall-clock anchors are ~ms vs the paper's ~ns primitives: the rollup
    # must actually consume them
    assert recal.t_compute > base.t_compute * 100
    # and the technology scaling still rides on top of the new anchor
    sram = compile_mapping(DIMS, TAXI_STATS, calibration=cal,
                           technology="sram")
    assert sram.t_compute < recal.t_compute


# ------------------------------------------------------------- planner axis

MIXED = WorkloadProfile(churn=0.01, queries_per_tick=64, sample=8)


def test_planner_mixed_technology_on_frontier():
    result = plan(TAXI_STATS, "throughput", workload=MIXED,
                  technologies=(*TECHS, PAIR))
    assert any("+" in sc.candidate.tech_key for sc in result.frontier)
    # a pair candidate is semi-only and splits into spoke/head tiers
    pair = next(sc.candidate for sc in result.scored
                if sc.candidate.tech_key == "reram+sram")
    assert pair.setting == "semi"
    assert (pair.spoke_technology, pair.head_technology) == PAIR


def test_noise_tolerance_rejects_noisy_heads():
    loose = plan(TAXI_STATS, "energy", workload=MIXED, technologies=TECHS)
    tight = plan(TAXI_STATS, "energy",
                 workload=dataclasses.replace(MIXED, noise_tolerance=1e-4),
                 technologies=TECHS)
    noisy = resolve_technology(loose.recommended.candidate.head_technology)
    quiet = resolve_technology(tight.recommended.candidate.head_technology)
    assert noisy.noise_sigma > 0.0              # cheap-but-noisy wins loose
    assert quiet.noise_sigma == 0.0             # tolerance flips to quiet


def test_register_technology_type_checked():
    from repro.devices import register_technology
    with pytest.raises(TypeError, match="TechnologyParams"):
        register_technology({"name": "bogus"})
    assert isinstance(SOT_MRAM, TechnologyParams)
