"""GNN runtime: oracle consistency, quantized inference, training step."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import random_graph, gnn
from repro.kernels.crossbar_mvm import CrossbarNumerics


@pytest.fixture(scope="module")
def small_graph():
    return random_graph(50, 250, 16, seed=1).gcn_normalize()


def test_forward_shapes_no_nan(small_graph):
    g = small_graph
    cfg = gnn.GNNConfig(in_dim=16, hidden_dims=(32,), out_dim=7, sample=8)
    params = gnn.init_params(jax.random.key(0), cfg)
    nbr, wts = g.neighbor_sample(8)
    out = gnn.forward(params, jnp.asarray(g.features), jnp.asarray(nbr),
                      jnp.asarray(wts), cfg)
    assert out.shape == (50, 7)
    assert not np.isnan(np.asarray(out)).any()


def test_forward_matches_dense_spmm(small_graph):
    """Padded-sample aggregation == dense adjacency matmul when S >= degree."""
    g = small_graph
    cfg = gnn.GNNConfig(in_dim=16, hidden_dims=(), out_dim=4, sample=64)
    params = gnn.init_params(jax.random.key(1), cfg)
    nbr, wts = g.neighbor_sample(64)
    out = gnn.forward(params, jnp.asarray(g.features), jnp.asarray(nbr),
                      jnp.asarray(wts), cfg)
    # dense A_hat = D^-1/2 (A+I) D^-1/2 (diagonal weight 1/(d_i+1))
    a = np.zeros((50, 50), np.float32)
    for i in range(50):
        for p in range(g.indptr[i], g.indptr[i + 1]):
            if p - g.indptr[i] < 63:
                a[i, g.indices[p]] += g.edge_weight[p]
        a[i, i] += 1.0 / (g.indptr[i + 1] - g.indptr[i] + 1)
    ref = (a @ g.features) @ np.asarray(params[0]["w"]) + np.asarray(params[0]["b"])
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-4)


def test_quantized_inference_close_to_ideal(small_graph):
    g = small_graph
    k = dict(in_dim=16, hidden_dims=(32,), out_dim=7, sample=8)
    cfg_i = gnn.GNNConfig(**k)
    cfg_q = gnn.GNNConfig(**k, numerics=CrossbarNumerics(in_bits=8, w_bits=8,
                                                         adc_bits=14,
                                                         rows_per_xbar=512))
    params = gnn.init_params(jax.random.key(2), cfg_i)
    nbr, wts = g.neighbor_sample(8)
    args = (jnp.asarray(g.features), jnp.asarray(nbr), jnp.asarray(wts))
    y_i = np.asarray(gnn.forward(params, *args, cfg_i))
    y_q = np.asarray(gnn.forward(params, *args, cfg_q))
    rel = np.linalg.norm(y_q - y_i) / np.linalg.norm(y_i)
    assert rel < 0.05, rel           # in-memory numerics track ideal closely
    assert not np.isnan(y_q).any()


def test_training_reduces_loss(small_graph):
    g = small_graph
    cfg = gnn.GNNConfig(in_dim=16, hidden_dims=(32,), out_dim=4, sample=8)
    params = gnn.init_params(jax.random.key(3), cfg)
    nbr, wts = g.neighbor_sample(8)
    labels = jnp.asarray(np.random.default_rng(0).integers(0, 4, 50))
    args = (jnp.asarray(g.features), jnp.asarray(nbr), jnp.asarray(wts),
            labels, cfg)
    l0, grads = gnn.grad_fn(params, *args)
    for _ in range(40):
        l, grads = gnn.grad_fn(params, *args)
        params = jax.tree.map(lambda p, g_: p - 0.5 * g_, params, grads)
    l1, _ = gnn.grad_fn(params, *args)
    assert float(l1) < float(l0) * 0.8
