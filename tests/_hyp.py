"""Hypothesis import shim: real hypothesis when installed, a deterministic
mini-sweep fallback otherwise.

The test modules' property tests only use ``@settings``/``@given`` with the
``integers``/``floats``/``sampled_from``/``booleans`` strategies. When
hypothesis is absent (it is an optional dev dep — see requirements-dev.txt),
the fallback runs each property test on a fixed-seed random sweep of
``max_examples`` draws instead of skipping it, so kernel/oracle equivalence
coverage survives on a bare interpreter. Shrinking, the example database, and
edge-case biasing are hypothesis-only; install it for the full treatment.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda r: r.choice(elements))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: bool(r.getrandbits(1)))

    st = _Strategies()

    def settings(max_examples: int = 20, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def run(*args, **kwargs):
                rng = random.Random(0)
                n = getattr(run, "_max_examples", 20)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)
            # hide the strategy-drawn params from pytest's fixture resolver:
            # expose a signature containing only the remaining (fixture) args
            sig = inspect.signature(fn)
            params = [p for name, p in sig.parameters.items()
                      if name not in strategies]
            del run.__wrapped__
            run.__signature__ = sig.replace(parameters=params)
            return run
        return deco

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
