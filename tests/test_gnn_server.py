"""GNNServer staleness contract: ``query`` must refresh whenever the
params/plan version moved, not only when embeddings were never computed
(the docstring always promised "refresh if stale"; it used to refresh only
on ``embeddings is None``)."""
import numpy as np
import jax

from repro.core import gnn
from repro.core.graph import random_graph
from repro.core.partition import plan_execution
from repro.launch.gnn import GNNServer


def _server(seed=0, **plan_kw):
    g = random_graph(40, 200, 24, seed=seed).gcn_normalize()
    plan = plan_execution(g, plan_kw.pop("setting", "centralized"),
                          sample=4, **plan_kw)
    cfg = gnn.GNNConfig(in_dim=24, hidden_dims=(16,), out_dim=8, sample=4)
    return GNNServer(plan, cfg, seed=seed), cfg, g


def test_query_refreshes_on_param_update():
    srv, cfg, _ = _server()
    ids = np.arange(5)
    first = srv.query(ids).copy()
    assert srv.refreshes == 1
    # same version: queries serve the cached embeddings, no refresh
    srv.query(ids)
    assert srv.refreshes == 1 and not srv.stale
    # new params: stale -> next query refreshes and the embeddings move
    new_params = gnn.init_params(jax.random.key(123), srv.cfg)
    srv.update_params(new_params)
    assert srv.stale
    second = srv.query(ids)
    assert srv.refreshes == 2
    assert not np.allclose(first, second)


def test_query_refreshes_on_plan_update():
    srv, cfg, _ = _server()
    srv.query(np.arange(3))
    assert srv.refreshes == 1
    g2 = random_graph(40, 200, 24, seed=7).gcn_normalize()
    srv.update_plan(plan_execution(g2, "centralized", sample=4), cfg)
    assert srv.stale
    srv.query(np.arange(3))
    assert srv.refreshes == 2 and not srv.stale


def test_explicit_refresh_clears_staleness():
    srv, _, _ = _server()
    srv.update_params(srv.params)      # bump version before any serve
    srv.refresh()
    assert not srv.stale
    srv.query(np.arange(2))
    assert srv.refreshes == 1          # query reused the explicit refresh


def test_batched_query_handles_duplicates_and_shape():
    srv, _, g = _server()
    ids = np.array([3, 7, 3, 0, 7, 7])
    out = srv.query(ids)
    assert out.shape == (6, srv.cfg.out_dim)
    np.testing.assert_array_equal(out[0], out[2])      # duplicate rows agree
    np.testing.assert_array_equal(out[1], out[4])
    np.testing.assert_array_equal(out, srv.embeddings[ids])
    # nd batches keep their shape
    out2 = srv.query(ids.reshape(2, 3))
    assert out2.shape == (2, 3, srv.cfg.out_dim)
    np.testing.assert_array_equal(out2.reshape(6, -1), out)


def test_query_rejects_out_of_range_ids():
    srv, _, g = _server()
    with np.testing.assert_raises(IndexError):
        srv.query([0, g.n_nodes])                      # one past the end
    with np.testing.assert_raises(IndexError):
        srv.query([-1])
    assert srv.query(np.zeros(0, np.int64)).shape == (0, srv.cfg.out_dim)


def test_update_plan_to_different_node_count_swaps_staleness_domain():
    """After swapping to a smaller graph, the refreshed table serves the
    new node set and ids valid only in the old graph fail loudly."""
    srv, cfg, g = _server()
    srv.query([g.n_nodes - 1])
    g2 = random_graph(24, 120, 24, seed=11).gcn_normalize()
    srv.update_plan(plan_execution(g2, "centralized", sample=4), cfg)
    assert srv.stale
    out = srv.query(np.arange(24))                     # refresh on new graph
    assert out.shape == (24, cfg.out_dim) and srv.refreshes == 2
    with np.testing.assert_raises(IndexError):
        srv.query([g.n_nodes - 1])                     # old-domain id: 39
