"""Two-tier semi-decentralized runtime: emulated/SPMD parity on every
backend, exchange-mode equivalence, measured-traffic accounting, and the
satellite bugfix regressions (dataset_like validation, sample-pruned halo
tables, platform-aware interpret default). Parity axes come from the
shared conftest grid (``backend`` / ``distributed_setting`` /
``oracle_case``)."""
import os
import subprocess
import sys

import numpy as np
import jax
import pytest

import conftest
from repro.core import gnn
from repro.core.graph import dataset_like, random_graph
from repro.core.partition import (build_local_subgraphs, partition,
                                  plan_execution)


def test_conftest_grid_matches_runtime_axes():
    """The shared fixture grid must track the runtime's real axes — a new
    backend or setting must widen every parity loop at once."""
    assert conftest.BACKENDS == gnn.BACKENDS
    assert set(conftest.SETTINGS) == {"centralized", "decentralized", "semi"}
    assert set(conftest.DISTRIBUTED_SETTINGS) == \
        set(conftest.SETTINGS) - {"centralized"}


def test_semi_two_tier_matches_centralized(oracle_case, backend):
    """plan_execution(g, "semi") runs the genuine two-tier forward (tier-0
    spoke->head gather, tier-1 head halo) on every kernel backend and still
    equals the centralized full-graph oracle."""
    g, cfg, params, ref = oracle_case
    plan = plan_execution(g, "semi", backend=backend, sample=8, n_clusters=3)
    assert plan.hier is not None          # no longer the decentralized path
    out = plan.scatter(np.asarray(plan.make_forward(cfg)(params)))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_emulated_alltoall_equals_allgather(oracle_case, distributed_setting):
    """The emulated exchange must route identically through both strategies
    (the alltoall path exercises the same send/recv tables as the SPMD
    collective — the tables traffic is billed on)."""
    g, cfg, params, ref = oracle_case
    plan = plan_execution(g, distributed_setting, sample=8, n_clusters=3)
    out_ag, out_aa = (np.asarray(plan.make_forward(cfg, mode=m)(params))
                      for m in ("allgather", "alltoall"))
    np.testing.assert_allclose(out_ag, out_aa, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(plan.scatter(out_aa), ref,
                               rtol=1e-4, atol=1e-4)


def test_semi_plan_is_two_tier(oracle_case):
    g, *_ = oracle_case
    plan = plan_execution(g, "semi", sample=8, n_clusters=3,
                          spokes_per_head=2)
    h = plan.hier
    assert h.spokes_per_region == 2
    # spokes hold every node exactly once
    owned = h.spoke_nodes[h.spoke_mask]
    assert sorted(owned.tolist()) == list(range(g.n_nodes))
    # gather tables point each valid region row at its spoke slot
    for r in range(3):
        for i in np.nonzero(h.region.local_mask[r])[0]:
            s, t = h.gather_spoke[r, i], h.gather_slot[r, i]
            assert h.spoke_nodes[r, s, t] == h.region.local_nodes[r, i]


_SEMI_SPMD_SCRIPT = r"""
import numpy as np, jax
from repro.core import gnn
from repro.core.graph import random_graph
from repro.core.partition import plan_execution
from repro.launch.mesh import make_mesh

g = random_graph(60, 300, 12, seed=7).gcn_normalize()
cfg = gnn.GNNConfig(in_dim=12, hidden_dims=(16,), out_dim=6, sample=8)
params = gnn.init_params(jax.random.key(0), cfg)
plan = plan_execution(g, "semi", sample=8, n_clusters=4)
mesh = make_mesh((4,), ("data",))
for mode in ("allgather", "alltoall"):
    spmd = np.asarray(plan.make_forward(cfg, mesh=mesh, mode=mode)(params))
    emu = np.asarray(plan.make_forward(cfg, mode=mode)(params))
    np.testing.assert_allclose(spmd, emu, rtol=1e-4, atol=1e-4)
print("SEMI_SPMD_OK")
"""


@pytest.mark.slow
def test_semi_spmd_matches_emulated_4dev():
    """Emulated == SPMD parity for the two-tier forward (both exchange
    modes), run in a subprocess with 4 forced host devices."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", _SEMI_SPMD_SCRIPT],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)) or ".")
    assert "SEMI_SPMD_OK" in r.stdout, r.stdout + r.stderr


def test_measured_traffic_matches_pruned_comm_volume(distributed_setting):
    """The validation loop's core invariant: alltoall rows counted on the
    executed exchange tables == the pruned comm_volume e_ij, per pair."""
    setting = distributed_setting
    g = dataset_like("taxi", scale=0.005, seed=1).gcn_normalize()
    cfg = gnn.GNNConfig(in_dim=g.feature_len, hidden_dims=(8,), out_dim=4,
                        sample=4)
    plan = plan_execution(g, setting, sample=4, n_clusters=3)
    rep = plan.measured_traffic(cfg, mode="alltoall")
    np.testing.assert_array_equal(rep.tier1_rows, plan.part.comm_volume)
    assert rep.tier1_bytes().shape == (2, 3)   # [layers, devices]
    if setting == "semi":
        assert rep.tier0_rows.sum() == g.n_nodes
        assert (rep.tier0_bytes().sum()
                == g.n_nodes * g.feature_len * rep.itemsize)
    else:
        assert rep.tier0_rows.size == 0
    # allgather ships full padded tables — strictly more rows
    ag = plan.measured_traffic(cfg, mode="allgather")
    assert ag.tier1_rows.sum() >= rep.tier1_rows.sum()


def test_halo_tables_pruned_to_sample():
    """Satellite: halo/send tables must only contain rows the padded-sample
    kernels actually read."""
    g = random_graph(60, 600, 4, seed=2).gcn_normalize()
    full = partition(g, 4)
    pruned = partition(g, 4, sample=4)
    assert pruned.comm_volume.sum() < full.comm_volume.sum()
    sub = build_local_subgraphs(g, pruned, sample=4)
    n_max = pruned.n_max
    for c in range(4):
        valid = set(np.nonzero(pruned.halo_src[c] >= 0)[0].tolist())
        idx = sub.neighbors[c][sub.weights[c] != 0]
        referenced = {int(i) - n_max for i in idx if i >= n_max}
        assert referenced == valid


def test_partition_records_and_enforces_pruning_sample():
    """A pruned partition remembers its sample: rebalance preserves it, and
    building subgraphs with a larger sample is a clear error instead of a
    KeyError deep in the halo mapping."""
    from repro.core.partition import rebalance
    g = random_graph(60, 600, 4, seed=2).gcn_normalize()
    part = partition(g, 4, sample=4)
    assert part.sample == 4
    moved = rebalance(g, part, np.array([1.0, 1.0, 1.0, 10.0]))
    assert moved.sample == 4
    # rebalanced tables stay pruned: e_ij still counts only readable rows
    sub = build_local_subgraphs(g, moved, sample=4)
    n_max = moved.n_max
    for c in range(4):
        valid = set(np.nonzero(moved.halo_src[c] >= 0)[0].tolist())
        idx = sub.neighbors[c][sub.weights[c] != 0]
        assert {int(i) - n_max for i in idx if i >= n_max} == valid
    with pytest.raises(ValueError, match="pruned"):
        build_local_subgraphs(g, part, sample=8)


def test_dataset_like_rejects_unknown_names():
    with pytest.raises(ValueError, match="taxi"):
        dataset_like("texi", scale=0.01)
    assert dataset_like("taxi", scale=0.01).n_nodes > 0


def test_interpret_default_is_platform_aware():
    from repro.kernels._interpret import resolve_interpret
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    assert resolve_interpret(None) == (jax.default_backend() != "tpu")
