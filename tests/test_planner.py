"""Adaptive planner (DESIGN.md §10): space validation, workload model,
objective scoring, Pareto frontier invariants, concrete-graph refinement,
and the online re-plan hook on a live StreamingGNNServer."""
import dataclasses

import numpy as np
import pytest

from repro.core.graph import TABLE2_DATASETS, TAXI_STATS
from repro.planner import (Candidate, PlanContext, ReplanMonitor,
                           WorkloadProfile, candidate_space, pareto_frontier,
                           plan, score_candidate, traffic_evaluator)

MIXED = WorkloadProfile(churn=0.01, queries_per_tick=64)


# ------------------------------------------------------------- space

def test_candidate_validation():
    with pytest.raises(ValueError, match="setting"):
        Candidate("federated")
    with pytest.raises(ValueError, match="backend"):
        Candidate("semi", backend="tpu")
    with pytest.raises(ValueError, match="policy"):
        Candidate("semi", policy="never")
    with pytest.raises(ValueError, match="centralized"):
        Candidate("centralized", n_clusters=4)
    assert "k16" in Candidate("semi", n_clusters=16).key


def test_candidate_space_structure():
    cands = candidate_space(TAXI_STATS, workload=MIXED)
    keys = {c.key for c in cands}
    assert len(keys) == len(cands)                    # no duplicates
    assert {c.setting for c in cands} == \
        {"centralized", "decentralized", "semi"}
    assert all(c.n_clusters == 1 for c in cands
               if c.setting == "centralized")
    semi_ks = {c.n_clusters for c in cands if c.setting == "semi"}
    assert len(semi_ks) >= 2                          # head count is swept
    # query-only workload collapses the policy axis (nothing to refresh)
    static = candidate_space(TAXI_STATS,
                             workload=WorkloadProfile(queries_per_tick=10))
    assert {c.policy for c in static} == {"eager"}
    # cluster counts never exceed the node count
    tiny = dataclasses.replace(TAXI_STATS, n_nodes=3)
    assert max(c.n_clusters for c in candidate_space(tiny)) <= 3


def test_workload_profile_model():
    wl = WorkloadProfile(churn=0.05, queries_per_tick=8, sample=4,
                         interval=6, max_staleness=20, max_dirty_frac=0.3)
    assert wl.commit_interval("eager") == 1
    assert wl.commit_interval("interval") == 6
    assert wl.commit_interval("bounded-staleness") == 6   # ceil(0.3/0.05)
    capped = dataclasses.replace(wl, churn=0.001)
    assert capped.commit_interval("bounded-staleness") == 20  # staleness cap
    # recompute fraction: in (0, 1], monotone in buffered ticks
    fr1 = wl.recompute_fraction(TAXI_STATS, 1)
    fr4 = wl.recompute_fraction(TAXI_STATS, 4)
    assert 0 < fr1 <= fr4 <= 1.0
    assert WorkloadProfile().recompute_fraction(TAXI_STATS) == 0.0
    with pytest.raises(ValueError, match="churn"):
        WorkloadProfile(churn=1.5)


# --------------------------------------------------------- objectives

def test_objective_decisions_follow_the_workload():
    """The paper's tension, decided per workload: latency → centralized
    (taxi), mixed churn+query → semi beats both pures, churn-only →
    centralized again (Eq. 5's one concurrent transfer)."""
    lat = plan(TAXI_STATS, "latency")
    assert lat.recommended.candidate.setting == "centralized"
    mixed = plan(TAXI_STATS, "throughput", workload=MIXED)
    rec = mixed.recommended
    assert rec.candidate.setting == "semi"
    for pure in ("centralized", "decentralized"):
        assert rec.score < mixed.best(pure).score
    q0 = plan(TAXI_STATS, "throughput",
              workload=dataclasses.replace(MIXED, queries_per_tick=0))
    assert q0.recommended.candidate.setting == "centralized"
    with pytest.raises(ValueError, match="objective"):
        plan(TAXI_STATS, "goodness")


def test_energy_objective_penalizes_the_radio():
    """Per-device energy: decentralized pays Eq. 7's per-bit radio over the
    long ad-hoc exchange, so its energy score must carry that term."""
    r = plan(TABLE2_DATASETS["cora"], "energy")
    dec = r.best("decentralized")
    m = dec.metrics
    assert dec.score > m["energy_j"]        # comm energy strictly added
    assert r.recommended.score <= dec.score


def test_slo_marks_infeasible_candidates():
    tight = dataclasses.replace(MIXED, slo_s=1e-6)
    loose = dataclasses.replace(MIXED, slo_s=10.0)
    r_tight = plan(TAXI_STATS, "throughput", workload=tight)
    r_loose = plan(TAXI_STATS, "throughput", workload=loose)
    # an unmeetable SLO inflates every score; a loose one changes nothing
    assert r_tight.recommended.score > r_loose.recommended.score * 100
    assert (r_loose.recommended.candidate
            == plan(TAXI_STATS, "throughput", workload=MIXED)
            .recommended.candidate)


# ----------------------------------------------------- frontier + plan

def test_pareto_frontier_nondomination():
    result = plan(TAXI_STATS, "throughput", workload=MIXED)
    axes = ("t_net", "energy_j", "t_tick")
    front = result.frontier
    assert front and any(sc.candidate == result.recommended.candidate
                         for sc in front)
    for a in front:
        for b in result.scored:
            if b.candidate == a.candidate:
                continue
            dominates = (all(b.metrics[x] <= a.metrics[x] for x in axes)
                         and any(b.metrics[x] < a.metrics[x] * (1 - 1e-9)
                                 for x in axes))
            assert not dominates, (b.candidate.key, a.candidate.key)
    # frontier spans the latency/energy trade-off: it is not one setting
    assert len({sc.candidate.setting for sc in front}) >= 2
    assert pareto_frontier([]) == []


def test_recommendation_is_exhaustive_argmin():
    """Self-consistency at unit level: re-scoring every candidate through
    score_candidate finds nothing better than the recommendation."""
    result = plan(TAXI_STATS, "throughput", workload=MIXED)
    ctx = PlanContext(TAXI_STATS, MIXED)
    best = min((score_candidate(c, ctx, "throughput")
                for c in candidate_space(TAXI_STATS, workload=MIXED)),
               key=lambda s: s.sort_key)
    assert result.recommended.score <= best.score * 1.0 + 1e-12
    assert result.recommended.candidate == best.candidate


def test_concrete_graph_refinement_and_build(make_graph):
    """With a concrete graph the shortlist is re-priced by the measured
    traffic evaluator (bytes on the executed exchange tables) and the
    recommendation materializes as a runnable ExecutionPlan."""
    import jax
    from repro.core import gnn
    g = make_graph(40, 200, 8, seed=0)
    wl = WorkloadProfile(churn=0.05, queries_per_tick=8, sample=4)
    result = plan(g, "throughput", workload=wl, shortlist=3)
    rec = result.recommended
    assert "bytes_full_refresh" in rec.metrics       # measured phase ran
    if rec.candidate.setting != "centralized":
        assert rec.metrics["bytes_per_tick"] <= \
            rec.metrics["bytes_full_refresh"] + 1e-9
    ep = result.build_plan(g)
    assert ep.setting == rec.candidate.setting
    cfg = gnn.GNNConfig(in_dim=8, hidden_dims=(8,), out_dim=4, sample=4)
    params = gnn.init_params(jax.random.key(0), cfg)
    # no np.asarray: a bucketed recommendation's forward returns a ragged
    # tuple of per-bucket arrays — scatter handles both forms
    out = ep.scatter(ep.make_forward(cfg)(params))
    assert out.shape == (g.n_nodes, 4) and np.isfinite(out).all()


def test_traffic_evaluator_requires_graph():
    ctx = PlanContext(TAXI_STATS, MIXED)
    assert traffic_evaluator(Candidate("semi", n_clusters=4), ctx) == {}


# ------------------------------------------------------ online re-plan

def test_replan_monitor_swaps_plan_on_traffic_drift(make_graph):
    """Serve a deliberately wrong plan (decentralized pinned), then spike
    the churn: measured incremental traffic leaves the drift band, the
    monitor re-plans with the *measured* workload, and the server is
    swapped to the new recommendation mid-stream."""
    import jax
    from repro.core import gnn
    from repro.streaming import StreamingGNNServer
    g = make_graph(40, 200, 8, seed=2)
    wl = WorkloadProfile(churn=0.05, queries_per_tick=0, sample=4)
    pinned = plan(g.stats("t"), "throughput", workload=wl,
                  space=[Candidate("decentralized", "jnp", 3)])
    assert pinned.recommended.candidate.setting == "decentralized"
    cfg = gnn.GNNConfig(in_dim=8, hidden_dims=(8,), out_dim=4, sample=4)
    srv = StreamingGNNServer(pinned.build_plan(g), cfg, policy="eager")
    mon = ReplanMonitor(pinned, window=2, tol=2.0, cooldown=1,
                        shortlist=0).attach(srv)
    srv.refresh()
    rng = np.random.default_rng(0)

    def tick(frac):
        n = max(int(g.n_nodes * frac), 1)
        nodes = rng.choice(g.n_nodes, n, replace=False)
        srv.ingest(nodes=nodes,
                   rows=rng.normal(size=(n, 8)).astype(np.float32))

    for _ in range(4):
        tick(0.05)                      # establish the quiet baseline
    assert not mon.events
    for _ in range(4):
        tick(0.9)                       # traffic spike: ~everything dirty
    assert mon.events, "drift never detected"
    ev = mon.events[0]
    assert ev.reason in ("latency", "traffic")
    assert ev.old.setting == "decentralized"
    # churn-only workload: the full-space re-plan lands on centralized
    assert ev.new.setting == "centralized" and ev.swapped
    assert srv.plan.setting == "centralized"
    assert ev.measured > ev.reference * mon.tol     # genuinely out of band
    # re-planned with measured churn (window median spans the spike onset,
    # so well above the assumed 0.05 even if not yet the full 0.9)
    assert ev.workload.churn > 4 * wl.churn
    # the swapped server keeps serving correctly
    from repro.core.partition import plan_execution
    srv.flush()
    out = srv.query(np.arange(5))
    ref_plan = plan_execution(srv.engine.graph, "centralized", sample=4)
    ref = ref_plan.scatter(np.asarray(
        ref_plan.make_forward(cfg)(srv.params)))
    np.testing.assert_allclose(out, ref[:5], rtol=1e-4, atol=1e-4)


# ------------------------------------------------- neighbor-mode axis

def test_candidate_neighbor_mode_validation_and_key():
    with pytest.raises(ValueError, match="neighbor"):
        Candidate("semi", neighbor_mode="bloom")
    c = Candidate("semi", n_clusters=16, neighbor_mode="cam")
    assert c.key.endswith("/cam")
    assert Candidate("semi", n_clusters=16).neighbor_mode == "topk"


def test_candidate_space_neighbor_axis_follows_workload():
    from repro.planner import NEIGHBOR_MODES
    mutating = candidate_space(TAXI_STATS, workload=MIXED)
    assert {c.neighbor_mode for c in mutating} == set(NEIGHBOR_MODES)
    # a static workload has no dirty sets to test membership on: the axis
    # collapses exactly like the refresh-policy axis does
    static = candidate_space(TAXI_STATS,
                             workload=WorkloadProfile(queries_per_tick=10))
    assert {c.neighbor_mode for c in static} == {"topk"}


def test_neighbor_evaluator_prices_both_modes_positive():
    from repro.planner import neighbor_evaluator
    ctx = PlanContext(TAXI_STATS, MIXED)
    for nm in ("cam", "topk"):
        c = Candidate("semi", n_clusters=16, neighbor_mode=nm)
        m = neighbor_evaluator(c, ctx)
        assert m["t_neighbor_s"] > 0.0
        assert m["neighbor_rounds"] >= 1.0
        assert m["neighbor_queries"] >= 1.0


def test_neighbor_tradeoff_crosses_with_dirty_count():
    """CAM membership wins while the per-commit query count stays under
    one array's row budget; a huge dirty set flips the decision to the
    serial top-k drain — the pricing must reproduce that crossover."""
    from repro.planner import neighbor_evaluator

    def costs(churn):
        wl = WorkloadProfile(churn=churn, queries_per_tick=64)
        ctx = PlanContext(TAXI_STATS, wl)
        out = {}
        for nm in ("cam", "topk"):
            c = Candidate("semi", n_clusters=16, neighbor_mode=nm)
            out[nm] = neighbor_evaluator(c, ctx)["t_neighbor_s"]
        return out

    quiet = costs(1e-4)          # few dirty ids per commit
    stormy = costs(0.9)          # nearly every row dirty
    assert quiet["cam"] < quiet["topk"]
    assert stormy["cam"] >= stormy["topk"]


def test_tick_costs_fold_neighbor_refresh_only_when_mutating():
    from repro.planner import tick_costs

    def refresh(wl):
        ctx = PlanContext(TAXI_STATS, wl)
        c = Candidate("semi", n_clusters=16, neighbor_mode="cam")
        sc = score_candidate(c, ctx, "throughput")
        return sc.metrics.get("refresh_neighbor_s", 0.0)

    assert refresh(MIXED) > 0.0
    assert refresh(WorkloadProfile(queries_per_tick=10)) == 0.0


def test_neighbor_axis_never_breaks_self_consistency():
    """The new axis doubles the grid; the recommendation must still be the
    exhaustive argmin of the planner's own evaluators."""
    result = plan(TAXI_STATS, "throughput", workload=MIXED)
    ctx = PlanContext(TAXI_STATS, MIXED)
    rescored = [score_candidate(c, ctx, "throughput")
                for c in candidate_space(TAXI_STATS, workload=MIXED)]
    best = min(rescored, key=lambda s: s.sort_key)
    assert result.recommended.candidate == best.candidate
    assert result.recommended.candidate.neighbor_mode in ("cam", "topk")
