"""Planner sweep: self-consistency, hybrid-vs-pure, and serving validation.

Three questions, per dataset (taxi + the Fig.-8 datasets):

  1. **Self-consistency** — does ``repro.planner.plan``'s recommendation
     match the optimum of an *exhaustive* sweep of its own evaluators?
     Every candidate in the space is re-scored independently through
     ``score_candidate`` and the recommendation must be the argmin
     (within 5% on the objective) — the planner may prune or refine, but
     it may never disagree with its own pricing.
  2. **Hybrid vs pure** — on the mixed churn+query workload, does the
     recommended semi/hybrid plan beat the best pure centralized *and*
     the best pure decentralized candidate on the combined objective?
     (The paper's tension, decided: the ~790x-communication and
     ~1400x-computation winners both lose to the two-tier hybrid once
     refresh and query drain are priced together.) Plus adaptivity: the
     same dataset with the queries removed must flip the decision to
     centralized (Eq. 5's one concurrent transfer wins churn-only), i.e.
     the planner decides per workload, not per graph.
  3. **Serving validation** — the recommended and the two pure configs
     are actually served through ``benchmarks.load_serve.run_config`` on
     a concrete (scaled) graph; measured p50/p99 latencies land in the
     ``--json-out`` artifact next to the Pareto frontier.

Usage:
  PYTHONPATH=src python benchmarks/planner_sweep.py            # full sweep
  PYTHONPATH=src python benchmarks/planner_sweep.py --smoke    # CI gate

METRICS: deterministic planner decisions + frontier at the top level,
measured serving numbers under ``"timing"`` keys (benchmarks/run.py's
determinism convention).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys

# direct `python benchmarks/planner_sweep.py` must resolve both repro
# (src/) and the sibling benchmarks package (load_serve import below)
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from repro.core.graph import TABLE2_DATASETS, TAXI_STATS  # noqa: E402
from repro.planner import (WorkloadProfile, candidate_space,  # noqa: E402
                           plan, score_candidate)
from repro.planner.evaluate import PlanContext  # noqa: E402

SMOKE_ARGV = ["--smoke"]
METRICS: dict = {}

# the mixed churn+query serving workload the acceptance gates on: 1% of
# the nodes move per tick, 64 lookup batches arrive alongside
MIXED = WorkloadProfile(churn=0.01, queries_per_tick=64, sample=8)


def sweep_dataset(name: str, stats, workload: WorkloadProfile,
                  objective: str = "throughput") -> dict:
    """Plan one dataset and exhaustively re-validate the recommendation."""
    result = plan(stats, objective, workload=workload)
    # independent exhaustive sweep: fresh context, every candidate scored
    # through the planner's own evaluator chain — no reuse of result.scored
    ctx = PlanContext(stats, workload)
    rescored = [score_candidate(c, ctx, objective)
                for c in candidate_space(stats, workload=workload)]
    optimum = min(rescored, key=lambda s: s.sort_key)
    rec = result.recommended
    pure = {s: result.best(s) for s in
            ("centralized", "decentralized", "semi")}
    return dict(
        name=name, objective=objective,
        n_candidates=len(rescored),
        recommended=rec.as_record(),
        optimum=optimum.as_record(),
        self_consistent=rec.score <= optimum.score * 1.05,
        recommended_on_frontier=any(sc.candidate == rec.candidate
                                    for sc in result.frontier),
        frontier=[sc.as_record() for sc in result.frontier],
        pure_scores={s: (p.score if p else None) for s, p in pure.items()},
        result=result)


def serve_validation(rows: list, dataset: str, scale: float,
                     requests: int, seed: int = 0) -> list:
    """Serve the recommended + pure configs on a concrete graph through
    the load harness; returns measured rows (timing under 'timing')."""
    from benchmarks.load_serve import run_config
    from repro.core import gnn
    from repro.core.graph import dataset_like
    g = dataset_like(dataset, scale=scale, seed=seed).gcn_normalize()
    cfg = gnn.GNNConfig(in_dim=g.feature_len, hidden_dims=(32,), out_dim=16,
                        sample=8)
    taxi_row = next(r for r in rows if r["name"] == dataset)
    result = taxi_row["result"]
    out = []
    for label, sc in [("recommended", result.recommended),
                      ("pure-centralized", result.best("centralized")),
                      ("pure-decentralized", result.best("decentralized"))]:
        c = sc.candidate
        r = run_config(g, cfg, c.setting, c.backend, policy=c.policy,
                       n_clusters=min(c.n_clusters, max(g.n_nodes // 4, 1)),
                       requests=requests, batch=8,
                       churn=result.workload.churn * 4, tick_every=4,
                       seed=seed)
        r["label"] = label
        r["model_score"] = sc.score
        out.append(r)
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep + hard asserts (the CI gate)")
    ap.add_argument("--objective", default="throughput",
                    choices=("latency", "energy", "throughput"))
    ap.add_argument("--churn", type=float, default=MIXED.churn)
    ap.add_argument("--queries", type=float, default=MIXED.queries_per_tick)
    ap.add_argument("--scale", type=float, default=0.02,
                    help="concrete-graph scale for the serving validation")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--no-serve", action="store_true",
                    help="skip the measured serving validation")
    args = ap.parse_args()

    workload = dataclasses.replace(MIXED, churn=args.churn,
                                   queries_per_tick=args.queries)
    datasets = {"taxi": TAXI_STATS, "cora": TABLE2_DATASETS["cora"],
                "citeseer": TABLE2_DATASETS["citeseer"]}
    if not args.smoke:
        datasets.update({k: TABLE2_DATASETS[k]
                         for k in ("collab", "livejournal")})

    print(f"{'dataset':12s} {'recommended':42s} {'score':>10s} "
          f"{'vs cent':>8s} {'vs dec':>8s} {'optimum?':>9s}")
    rows = []
    for name, stats in datasets.items():
        r = sweep_dataset(name, stats, workload, args.objective)
        rows.append(r)
        rec = r["recommended"]
        cent = r["pure_scores"]["centralized"]
        dec = r["pure_scores"]["decentralized"]
        key = (f"{rec['setting']}/k{rec['n_clusters']}/xb{rec['xbar']}"
               f"/{rec['policy']}/{rec['neighbor_mode']}")
        print(f"{name:12s} {key:42s} {rec['score']:10.3e} "
              f"{cent / rec['score']:7.1f}x {dec / rec['score']:7.1f}x "
              f"{'yes' if r['self_consistent'] else 'NO':>9s}")

    # adaptivity probes: same graph, different workload => different plan
    q0 = plan(TAXI_STATS, args.objective,
              dataclasses.replace(workload, queries_per_tick=0))
    lat = plan(TAXI_STATS, "latency")
    print(f"adaptivity: taxi queries=0 -> "
          f"{q0.recommended.candidate.setting}; "
          f"latency objective -> {lat.recommended.candidate.setting}")

    serving = []
    if not args.no_serve:
        serving = serve_validation(rows, "taxi", args.scale,
                                   8 if args.smoke else args.requests)
        for r in serving:
            t = r["timing"]
            print(f"serving[{r['label']:18s}] {r['setting']:14s} "
                  f"p50 {t['closed']['p50_ms']:.2f} ms, "
                  f"p99 {t['open']['p99_ms']:.2f} ms, "
                  f"{r['served']} lookups, {r['commits']} commits")

    METRICS.clear()
    METRICS.update(
        objective=args.objective,
        workload=dataclasses.asdict(workload),
        datasets=[{k: v for k, v in r.items() if k != "result"}
                  for r in rows],
        adaptivity=dict(
            taxi_mixed=rows[0]["recommended"]["setting"],
            taxi_q0=q0.recommended.candidate.setting,
            taxi_latency=lat.recommended.candidate.setting),
        serving=serving)

    if not args.smoke:
        return 0
    failures = []
    for r in rows:
        if not r["self_consistent"]:
            failures.append(
                f"{r['name']}: recommendation {r['recommended']['score']:.3e}"
                f" is not the exhaustive optimum "
                f"{r['optimum']['score']:.3e} (±5%)")
        if not r["recommended_on_frontier"]:
            failures.append(f"{r['name']}: recommendation off the Pareto "
                            f"frontier")
    taxi = rows[0]
    rec = taxi["recommended"]
    if rec["setting"] != "semi":
        failures.append(f"taxi mixed workload: expected the hybrid/semi "
                        f"setting, got {rec['setting']}")
    for s in ("centralized", "decentralized"):
        p = taxi["pure_scores"][s]
        if not (rec["score"] < p):
            failures.append(f"taxi: recommended hybrid {rec['score']:.3e} "
                            f"does not beat pure {s} {p:.3e}")
    if q0.recommended.candidate.setting != "centralized":
        failures.append(f"taxi churn-only workload: expected centralized, "
                        f"got {q0.recommended.candidate.setting}")
    if len(rows) < 3:
        failures.append(f"sweep too small: {len(rows)} datasets")
    for r in serving:
        if r["served"] <= 0 or r["commits"] < 1:
            failures.append(f"serving[{r['label']}]: nothing served or no "
                            f"commits")
        for loop in ("closed", "open"):
            p = r["timing"][loop]
            if not p["p50_ms"] <= p["p99_ms"]:
                failures.append(f"serving[{r['label']}] {loop}: p50 > p99")
    if failures:
        print("SMOKE FAILURES:")
        for f in failures:
            print(" ", f)
        return 1
    print(f"PLANNER_SWEEP_SMOKE_OK: recommendation == exhaustive optimum on "
          f"{len(rows)} datasets; taxi mixed workload picks semi over both "
          f"pure settings "
          f"({taxi['pure_scores']['centralized'] / rec['score']:.1f}x vs "
          f"centralized, "
          f"{taxi['pure_scores']['decentralized'] / rec['score']:.1f}x vs "
          f"decentralized); churn-only flips to centralized; "
          f"{len(serving)} configs load-validated")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
