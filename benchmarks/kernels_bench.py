"""Per-kernel micro-benchmarks: wall time of the jnp reference path on CPU
(interpret-mode Pallas is a correctness oracle, not a perf path) plus the
analytic crossbar-pass counts the cost model assigns the same workload —
tying the kernel layer to the paper's latency primitives."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.cam_match import ops as cam_ops
from repro.kernels.crossbar_mvm import ref as mvm_ref
from repro.kernels.csr_aggregate import ops as agg_ops


def _time(fn, *args, iters: int = 20) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6   # us


def rows():
    k = jax.random.key(0)
    out = []

    # traversal: CAM search of 1 destination over E edges
    for e in (4096, 65536):
        ci = jax.random.randint(k, (e,), 0, 10_000, jnp.int32)
        q = jnp.arange(128, dtype=jnp.int32)
        fn = lambda ci, q: cam_ops.search(ci, q, backend="jnp")
        out.append((f"cam_search/E={e}", _time(fn, ci, q)))

    # aggregation: padded-neighbor gather-reduce
    for n, s, f in ((1024, 16, 256), (4096, 32, 512)):
        x = jax.random.normal(k, (n, f), jnp.float32)
        nb = jax.random.randint(k, (n, s), 0, n, jnp.int32)
        w = jnp.ones((n, s), jnp.float32)
        fn = lambda x, nb, w: agg_ops.aggregate(x, nb, w, backend="jnp")
        out.append((f"csr_aggregate/N={n},S={s},F={f}", _time(fn, x, nb, w)))

    # feature extraction: crossbar quantized matmul (jnp integer-domain path)
    for m, kk, n2 in ((128, 128, 128), (512, 512, 512)):
        x = jax.random.normal(k, (m, kk), jnp.float32)
        w = jax.random.normal(k, (kk, n2), jnp.float32) * 0.05
        fn = lambda x, w: mvm_ref.crossbar_matmul_signed_ref(x, w)
        out.append((f"crossbar_mvm/{m}x{kk}x{n2}", _time(fn, x, w)))
    return out


def main(csv: bool = False) -> int:
    print(f"{'kernel':36s} {'us_per_call':>12s}")
    for name, us in rows():
        print(f"{name:36s} {us:12.1f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
