"""Streaming replay: full vs incremental refresh over a taxi tick stream.

Replays a ``core.taxi.synthetic_stream``-style feature stream (plus optional
edge churn) through ``streaming.StreamingGNNServer`` and, per
(setting, churn) case, reports:

  * mean wall-clock of an incremental commit vs a full refresh,
  * mean recomputed-node fraction (the k-hop dirty frontier's share of the
    per-layer kernel work),
  * measured incremental traffic vs the full-refresh exchange traffic
    (``distributed.traffic.measure_incremental`` vs ``measure_execution``),
  * parity of the incrementally maintained embeddings against a fresh
    full recompute on the final mutated graph.

This is the streaming counterpart of ``benchmarks/semi_runtime.py``'s
predicted-vs-executed loop: the paper's ~790x/~1400x centralized-vs-
decentralized tradeoff (Table 3) is a one-shot number; at the edge the
update stream dominates, and the ratio that matters is incremental/full.

Usage:
  PYTHONPATH=src python benchmarks/streaming_replay.py             # sweep
  PYTHONPATH=src python benchmarks/streaming_replay.py --smoke     # CI gate
  (--csv for machine-readable rows)

Smoke asserts: recomputed-node fraction < 1.0, incremental traffic <= the
full-refresh traffic, and parity within fp32 tolerance, on every
setting — the acceptance loop for the incremental path.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.core import gnn  # noqa: E402
from repro.core.graph import dataset_like  # noqa: E402
from repro.core.partition import plan_execution  # noqa: E402
from repro.streaming import StreamingGNNServer  # noqa: E402

SETTINGS = ("centralized", "decentralized", "semi")
SMOKE_ARGV = ["--smoke"]        # benchmarks.run --smoke path
METRICS: dict = {}              # filled by main(); run.py --json-out reads it


def feature_ticks(n_nodes: int, f: int, ticks: int, churn: float,
                  seed: int = 0):
    """synthetic_stream-style full-map ticks where only a ``churn``
    fraction of nodes moves per tick (the stream diff picks them out)."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n_nodes, f)).astype(np.float32)
    base = x.copy()
    t = 0.0
    out = []
    for _ in range(ticks):
        t += 0.3
        moved = rng.random(n_nodes) < churn
        x = x.copy()
        x[moved] = (base[moved]
                    + np.sin(t + np.arange(f, dtype=np.float32)[None, :]
                             + rng.normal(size=(int(moved.sum()), 1))))
        out.append(x)
    return out


def run_case(setting: str, g, cfg, ticks, edge_churn: int,
             seed: int = 0) -> dict:
    """Replay one tick stream; returns the per-case metric row."""
    import jax
    plan = plan_execution(g, setting, backend=cfg.backend, sample=cfg.sample,
                          n_clusters=None if setting == "centralized" else 4,
                          seed=seed)
    srv = StreamingGNNServer(plan, cfg, seed=seed, policy="eager")
    srv.refresh()                                  # cold start (full)

    rng = np.random.default_rng(seed + 1)
    t_full = [srv.engine.full_refresh() for _ in range(3)]
    fracs, t_inc, inc_bytes, full_bytes = [], [], 0, 0
    for x_t in ticks:
        kw = {}
        if edge_churn:
            dst = rng.integers(0, g.n_nodes, edge_churn)
            src = rng.integers(0, g.n_nodes, edge_churn)
            kw["add_edges"] = (dst, src)
        upd = srv.ingest(x_t, **kw)
        assert upd is not None                      # eager policy commits
        fracs.append(upd.recompute_fraction)
        t_inc.append(upd.seconds)
        if upd.traffic is not None:
            inc_bytes += upd.traffic.total_bytes()
        if setting != "centralized" and edge_churn:
            # full-refresh baseline re-measured on the *live* plan: edge
            # churn grows the exchange tables, and the incremental<=full
            # bound is against what a full refresh would ship now
            full_bytes += plan.measured_traffic(
                srv.cfg, mode="alltoall").total_bytes()
    if setting != "centralized" and not edge_churn:
        # feature-only churn never touches the exchange tables: one
        # measurement prices every tick
        full_bytes = plan.measured_traffic(
            srv.cfg, mode="alltoall").total_bytes() * len(ticks)

    # parity: incremental embeddings vs fresh full recompute on the final
    # mutated graph (fresh plan => fresh partition; global order compares)
    final = srv.query(np.arange(g.n_nodes))
    eng = srv.engine
    plan2 = plan_execution(eng.graph, setting, backend=cfg.backend,
                           sample=cfg.sample,
                           n_clusters=None if setting == "centralized"
                           else 4, seed=seed)
    ref = plan2.scatter(np.asarray(plan2.make_forward(cfg)(srv.params)))
    parity = float(np.abs(final - ref).max())

    n_ticks = len(ticks)
    # medians: the first ticks pay one-off JIT compiles of the bucketed
    # recompute shapes; steady-state cost is the serving-relevant number
    return dict(setting=setting, n_nodes=g.n_nodes, ticks=n_ticks,
                frac=float(np.mean(fracs)),
                t_full_ms=float(np.median(t_full)) * 1e3,
                t_inc_ms=float(np.median(t_inc)) * 1e3,
                inc_mb=inc_bytes / 1e6, full_mb=full_bytes / 1e6,
                parity=parity)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny stream + hard asserts (the CI gate)")
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--dataset", default="taxi")
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--ticks", type=int, default=20)
    ap.add_argument("--churn", type=float, nargs="*", default=None,
                    help="per-tick fraction of nodes whose features move")
    ap.add_argument("--edge-churn", type=int, default=0,
                    help="edges added per tick (structural churn)")
    ap.add_argument("--backend", default="jnp", choices=gnn.BACKENDS)
    ap.add_argument("--sample", type=int, default=8)
    ap.add_argument("--hidden", type=int, default=32)
    args = ap.parse_args()

    scale = 0.01 if args.smoke else args.scale
    n_ticks = 4 if args.smoke else args.ticks
    churns = tuple(args.churn) if args.churn else (
        (0.02,) if args.smoke else (0.01, 0.05, 0.2))
    edge_churn = args.edge_churn or (1 if args.smoke else 0)

    g = dataset_like(args.dataset, scale=scale, seed=0).gcn_normalize()
    cfg = gnn.GNNConfig(in_dim=g.feature_len, hidden_dims=(args.hidden,),
                        out_dim=16, sample=args.sample,
                        backend=args.backend)

    hdr = (f"{'setting':14s} {'nodes':>6s} {'churn':>6s} {'frac':>6s} "
           f"{'full ms':>8s} {'inc ms':>8s} {'speedup':>7s} "
           f"{'inc MB':>8s} {'full MB':>8s} {'parity':>9s}")
    if args.csv:
        print("setting,nodes,churn,frac,t_full_ms,t_inc_ms,inc_mb,full_mb,"
              "parity")
    else:
        print(hdr)

    failures = []
    rows = []
    for churn in churns:
        ticks = feature_ticks(g.n_nodes, g.feature_len, n_ticks, churn,
                              seed=int(churn * 1000))
        for setting in SETTINGS:
            r = run_case(setting, g, cfg, ticks, edge_churn)
            r["churn"] = churn
            rows.append(r)
            speed = r["t_full_ms"] / max(r["t_inc_ms"], 1e-9)
            if args.csv:
                print(f"{r['setting']},{r['n_nodes']},{churn},"
                      f"{r['frac']:.4f},{r['t_full_ms']:.3f},"
                      f"{r['t_inc_ms']:.3f},{r['inc_mb']:.6f},"
                      f"{r['full_mb']:.6f},{r['parity']:.3e}")
            else:
                print(f"{r['setting']:14s} {r['n_nodes']:6d} {churn:6.2f} "
                      f"{r['frac']:6.3f} {r['t_full_ms']:8.2f} "
                      f"{r['t_inc_ms']:8.2f} {speed:6.1f}x "
                      f"{r['inc_mb']:8.4f} {r['full_mb']:8.4f} "
                      f"{r['parity']:9.2e}")
            if args.smoke:
                if not (r["frac"] < 1.0):
                    failures.append(f"{setting}: recompute fraction "
                                    f"{r['frac']:.3f} not < 1.0")
                if r["inc_mb"] > r["full_mb"] + 1e-12:
                    failures.append(f"{setting}: incremental traffic "
                                    f"{r['inc_mb']:.6f} MB exceeds full "
                                    f"{r['full_mb']:.6f} MB")
                if not (r["parity"] < 1e-4):
                    failures.append(f"{setting}: parity {r['parity']:.2e}")

    METRICS.clear()
    METRICS.update(
        dataset=args.dataset, backend=args.backend, ticks=n_ticks,
        edge_churn=edge_churn,
        # determinism convention (benchmarks/run.py): measured wall-clock
        # lives under "timing"; the remaining fields are seed-deterministic
        cases=[dict({k: r[k] for k in ("setting", "churn", "frac",
                                       "inc_mb", "full_mb", "parity")},
                    timing={k: r[k] for k in ("t_full_ms", "t_inc_ms")})
               for r in rows])

    if args.smoke:
        if failures:
            print("SMOKE FAILURES:")
            for f in failures:
                print(" ", f)
            return 1
        print("STREAMING_REPLAY_SMOKE_OK: incremental refresh recomputes a "
              "strict subset, ships no more bytes than full refresh, and "
              f"matches the full-recompute oracle on {len(rows)} cases")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
