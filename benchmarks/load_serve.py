"""High-throughput load harness: closed+open-loop serving benchmark.

Drives batched embedding queries *and* churn ticks through
``StreamingGNNServer`` per configuration (setting × backend × refresh
policy) and reports measured serving behaviour — the runtime counterpart
of the planner's model (DESIGN.md §10):

  * **closed loop** — one client issues query batches back-to-back;
    latency is pure service time, throughput is the server's capacity.
  * **open loop**   — batches arrive on a Poisson process at ``--rate``
    regardless of completion (a virtual arrival clock against measured
    service times), so queueing delay is visible: p99 blows up as the
    rate approaches capacity, exactly what an SLO check needs.

Churn ticks are interleaved every ``--tick-every`` requests; a commit
blocks the serving thread (the incremental refresh runs on the device that
answers queries), so refresh cost shows up in the tail percentiles.
``--auto`` additionally runs the planner's recommended config with a
``ReplanMonitor`` attached and reports any online re-plans. Every row
also reports the data plane's padding-waste ratio and modeled peak
live-buffer bytes (``ExecutionPlan.layout_stats``); ``--buckets auto``
swaps the uniform dense padding for the capacity-bucketed ragged layout
(DESIGN.md §12) so the two layouts can be compared under load.

Usage:
  PYTHONPATH=src python benchmarks/load_serve.py            # full sweep
  PYTHONPATH=src python benchmarks/load_serve.py --smoke    # CI gate

METRICS follows the determinism convention (benchmarks/run.py): measured
wall-clock quantities live under ``"timing"`` keys; everything else is a
deterministic function of seed+argv.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

sys.path.insert(0, "src")

from repro.core import gnn  # noqa: E402
from repro.core.graph import dataset_like  # noqa: E402
from repro.streaming import StreamingGNNServer  # noqa: E402

SETTINGS = ("centralized", "decentralized", "semi")
SMOKE_ARGV = ["--smoke"]
METRICS: dict = {}


def percentiles(lats) -> dict:
    if not len(lats):
        return {"p50_ms": 0.0, "p95_ms": 0.0, "p99_ms": 0.0}
    p50, p95, p99 = np.percentile(np.asarray(lats, np.float64) * 1e3,
                                  [50, 95, 99])
    return {"p50_ms": float(p50), "p95_ms": float(p95), "p99_ms": float(p99)}


def _tick(srv, g, rng, churn: float, edge_churn: int):
    n_mut = max(int(g.n_nodes * churn), 1)
    nodes = rng.choice(g.n_nodes, n_mut, replace=False)
    rows = rng.normal(size=(n_mut, g.feature_len)).astype(np.float32)
    kw = {}
    if edge_churn:
        kw["add_edges"] = (rng.integers(0, g.n_nodes, edge_churn),
                           rng.integers(0, g.n_nodes, edge_churn))
    return srv.ingest(nodes=nodes, rows=rows, **kw)


def closed_loop(srv, g, requests: int, batch: int, rng,
                churn: float = 0.0, edge_churn: int = 0,
                tick_every: int = 4, monitor=None) -> dict:
    """Back-to-back batches from one client; latency == service time."""
    lats, served, ticks = [], 0, 0
    t0 = time.perf_counter()
    for i in range(requests):
        if churn > 0 and i % tick_every == 0:
            _tick(srv, g, rng, churn, edge_churn)
            ticks += 1
        ids = rng.integers(0, srv.plan.graph.n_nodes, batch)
        t = time.perf_counter()
        out = srv.query(ids)
        lats.append(time.perf_counter() - t)
        served += len(out)
        if monitor is not None:
            monitor.note_queries(len(out))
    wall = time.perf_counter() - t0
    return dict(mode="closed", requests=requests, served=served,
                ticks=ticks, lats=lats, wall_s=wall,
                qps=served / max(wall, 1e-12))


def open_loop(srv, g, requests: int, batch: int, rate: float, rng,
              churn: float = 0.0, edge_churn: int = 0,
              tick_every: int = 4, monitor=None) -> dict:
    """Poisson arrivals at ``rate`` batches/s against a virtual clock.

    The server is a single queue: request i starts at
    ``max(arrival_i, free)`` where ``free`` is when the previous request
    (or interleaved commit) finished; reported latency includes the queue
    wait, so overload shows up as a growing tail, not a lower rate."""
    arrivals = np.cumsum(rng.exponential(1.0 / max(rate, 1e-9), requests))
    free, lats, served, ticks = 0.0, [], 0, 0
    t0 = time.perf_counter()
    for i, arr in enumerate(arrivals):
        if churn > 0 and i % tick_every == 0:
            t = time.perf_counter()
            _tick(srv, g, rng, churn, edge_churn)
            free = max(free, arr) + (time.perf_counter() - t)
            ticks += 1
        ids = rng.integers(0, srv.plan.graph.n_nodes, batch)
        start = max(arr, free)
        t = time.perf_counter()
        out = srv.query(ids)
        dt = time.perf_counter() - t
        free = start + dt
        lats.append(free - arr)
        served += len(out)
        if monitor is not None:
            monitor.note_queries(len(out))
    wall = time.perf_counter() - t0
    return dict(mode="open", requests=requests, served=served, ticks=ticks,
                rate=rate, lats=lats, wall_s=wall,
                qps=served / max(arrivals[-1], free, 1e-12))


def run_config(g, cfg, setting: str, backend: str, policy: str = "eager",
               n_clusters: int = 4, requests: int = 64, batch: int = 16,
               rate: float | None = None, churn: float = 0.02,
               edge_churn: int = 0, tick_every: int = 4, seed: int = 0,
               buckets=None, monitor_factory=None) -> dict:
    """Measure one configuration under both loops; returns the result row.

    ``buckets`` selects the data-plane layout (DESIGN.md §12): ``None`` /
    ``"off"`` keeps the uniform dense padding, ``"auto"`` / N the
    capacity-bucketed ragged layout. ``monitor_factory`` (optional):
    called with the built server, returns an attached observer (e.g. a
    ``repro.planner.ReplanMonitor``) whose re-plan events are reported in
    the row."""
    import dataclasses
    from repro.core.partition import plan_execution
    plan = plan_execution(g, setting, backend=backend,
                          sample=cfg.sample,
                          n_clusters=None if setting == "centralized"
                          else n_clusters, seed=seed, buckets=buckets)
    layout = plan.layout_stats(cfg)
    srv = StreamingGNNServer(plan, dataclasses.replace(cfg, backend=backend),
                             seed=seed, policy=policy)
    monitor = monitor_factory(srv) if monitor_factory is not None else None
    t_cold = srv.refresh()
    rng = np.random.default_rng(seed)
    closed = closed_loop(srv, g, requests, batch, rng, churn=churn,
                         edge_churn=edge_churn, tick_every=tick_every,
                         monitor=monitor)
    # default open-loop rate: 80% of the measured closed-loop capacity —
    # loaded but sustainable, so the tail reflects commits, not overload
    eff_rate = rate or 0.8 * closed["requests"] / max(closed["wall_s"], 1e-9)
    opened = open_loop(srv, g, requests, batch, eff_rate, rng, churn=churn,
                       edge_churn=edge_churn, tick_every=tick_every,
                       monitor=monitor)
    row = dict(setting=setting, backend=backend, policy=policy,
               n_clusters=plan.n_clusters,
               layout=layout["layout"],
               padding_ratio=round(float(layout["padding_ratio"]), 4),
               peak_device_bytes=int(layout["peak_device_bytes"]),
               requests=requests, batch=batch,
               served=closed["served"] + opened["served"],
               ticks=closed["ticks"] + opened["ticks"],
               commits=srv.commits, full_refreshes=srv.full_refreshes,
               replans=len(monitor.events) if monitor is not None else 0,
               timing=dict(cold_refresh_ms=t_cold * 1e3,
                           closed_qps=closed["qps"],
                           open_rate=eff_rate, open_qps=opened["qps"],
                           closed=percentiles(closed["lats"]),
                           open=percentiles(opened["lats"])))
    return row


def _print_row(r: dict) -> None:
    t = r["timing"]
    print(f"{r['setting']:14s} {r['backend']:7s} {r['policy']:18s} "
          f"{r['layout']:8s} {r['padding_ratio']:5.2f} "
          f"{r['peak_device_bytes'] / 1e6:7.2f} "
          f"{r['served']:6d} {r['commits']:4d} "
          f"{t['closed_qps']:9.0f} {t['closed']['p50_ms']:8.2f} "
          f"{t['closed']['p99_ms']:8.2f} {t['open']['p50_ms']:8.2f} "
          f"{t['open']['p99_ms']:8.2f} {r['replans']:3d}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep + hard asserts (the CI gate)")
    ap.add_argument("--dataset", default="taxi")
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--requests", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--rate", type=float, default=None,
                    help="open-loop arrival rate, batches/s (default: 80%% "
                         "of measured closed-loop capacity)")
    ap.add_argument("--churn", type=float, default=0.02)
    ap.add_argument("--edge-churn", type=int, default=0)
    ap.add_argument("--tick-every", type=int, default=4)
    ap.add_argument("--clusters", type=int, default=4)
    ap.add_argument("--policy", default="eager",
                    choices=("eager", "interval", "bounded-staleness"))
    ap.add_argument("--backends", nargs="*", default=None,
                    help="backends to sweep (default: fused; full: +jnp)")
    ap.add_argument("--sample", type=int, default=8)
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--buckets", default="off", metavar="auto|off|N",
                    help="partition data-plane layout: 'off' = uniform "
                         "dense padding, 'auto'/N = capacity-bucketed "
                         "ragged layout (DESIGN.md §12)")
    ap.add_argument("--auto", action="store_true",
                    help="also run the planner's recommended config with "
                         "an online ReplanMonitor attached")
    args = ap.parse_args()

    scale = 0.008 if args.smoke else args.scale
    requests = 24 if args.smoke else args.requests
    backends = tuple(args.backends or
                     (("fused",) if args.smoke else ("fused", "jnp")))
    buckets = (args.buckets if args.buckets in ("auto", "off")
               else int(args.buckets))

    g = dataset_like(args.dataset, scale=scale, seed=0).gcn_normalize()
    cfg = gnn.GNNConfig(in_dim=g.feature_len, hidden_dims=(args.hidden,),
                        out_dim=16, sample=args.sample)

    print(f"{'setting':14s} {'backend':7s} {'policy':18s} {'layout':8s} "
          f"{'pad':>5s} {'peakMB':>7s} {'served':>6s} "
          f"{'cmts':>4s} {'qps':>9s} {'c.p50ms':>8s} {'c.p99ms':>8s} "
          f"{'o.p50ms':>8s} {'o.p99ms':>8s} {'rpl':>3s}")
    rows = []
    for setting in SETTINGS:
        for backend in backends:
            r = run_config(g, cfg, setting, backend, policy=args.policy,
                           n_clusters=args.clusters, requests=requests,
                           batch=args.batch, rate=args.rate,
                           churn=args.churn, edge_churn=args.edge_churn,
                           tick_every=args.tick_every, buckets=buckets)
            rows.append(r)
            _print_row(r)

    if args.auto:
        from repro.planner import ReplanMonitor, WorkloadProfile, plan
        wl = WorkloadProfile(churn=args.churn, edge_churn=args.edge_churn,
                             queries_per_tick=args.batch * args.tick_every,
                             sample=args.sample)
        result = plan(g, "throughput", wl, shortlist=2)
        rec = result.recommended.candidate
        print(f"planner recommends {rec.key}")
        r = run_config(g, cfg, rec.setting, rec.backend, policy=rec.policy,
                       n_clusters=rec.n_clusters, requests=requests,
                       batch=args.batch, rate=args.rate, churn=args.churn,
                       edge_churn=args.edge_churn,
                       tick_every=args.tick_every,
                       buckets="auto" if rec.layout == "bucketed" else None,
                       monitor_factory=lambda srv:
                       ReplanMonitor(result).attach(srv))
        r["auto"] = True
        rows.append(r)
        _print_row(r)

    METRICS.clear()
    METRICS.update(
        dataset=args.dataset, n_nodes=g.n_nodes, requests=requests,
        batch=args.batch, churn=args.churn, backends=list(backends),
        buckets=str(buckets), configs=rows)

    if not args.smoke:
        return 0
    failures = []
    want = requests * args.batch * 2          # closed + open phases
    for r in rows:
        t = r["timing"]
        if r["served"] != want:
            failures.append(f"{r['setting']}/{r['backend']}: served "
                            f"{r['served']} != {want}")
        if r["commits"] < 1:
            failures.append(f"{r['setting']}/{r['backend']}: no commits "
                            f"despite churn")
        for loop in ("closed", "open"):
            p = t[loop]
            if not p["p50_ms"] <= p["p95_ms"] <= p["p99_ms"]:
                failures.append(f"{r['setting']}/{r['backend']} {loop}: "
                                f"percentiles not monotone {p}")
        # open-loop latency includes queue wait: its median cannot beat
        # the closed-loop pure service median
        if t["open"]["p50_ms"] < t["closed"]["p50_ms"] * 0.5:
            failures.append(f"{r['setting']}/{r['backend']}: open-loop p50 "
                            f"below closed-loop service time")
    if failures:
        print("SMOKE FAILURES:")
        for f in failures:
            print(" ", f)
        return 1
    print(f"LOAD_SERVE_SMOKE_OK: {len(rows)} configs served {want} lookups "
          f"each through closed+open loops with monotone latency "
          f"percentiles and churn commits interleaved")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
