"""Crossbar-size study: mapper-derived vs Table-1-calibrated cost model.

For crossbar geometry {paper, 64, 128, 256, 512} x setting {centralized,
decentralized, semi} x the Table-2 datasets (+ the taxi calibration
workload), compile the workload onto the inventory with ``repro.mapper``
(DESIGN.md §8) and report:

  * **T_cal / T_der** — calibrated (Eqs. 1-3, Table-1 constants) vs
    mapper-derived compute latency. At the paper's own geometry (the
    ``paper`` row) the two agree to ceil-rounding on the centralized and
    decentralized settings — that is the cross-validation contract, and
    ``--smoke`` asserts it within 10% on taxi. Away from the calibration
    point they diverge: the calibrated path cannot see geometry at all
    (its constants *are* the paper's geometry), so the divergence **is**
    the measurement — e.g. small crossbars cut the per-pass ADC latency
    but multiply pass rounds, and the semi setting's fractional-array
    speed-ups round up to whole pass rounds.
  * **E_der** — derived energy (tile passes x per-array read energy) next
    to the calibrated ``P_compute x T_compute`` product.
  * **util / occ** — weight-cell utilization of the occupied fx arrays
    (padding + bit-slicing waste) and fx pass-schedule occupancy
    (duplication/serialization efficiency).

Usage:
  PYTHONPATH=src python benchmarks/mapper_sweep.py            # full sweep
  PYTHONPATH=src python benchmarks/mapper_sweep.py --smoke    # CI gate
  (--csv for machine-readable rows, --iso-cells for the iso-silicon
  comparison where array counts rescale to keep each core's cell budget)
"""
from __future__ import annotations

import argparse
import sys

sys.path.insert(0, "src")

from repro.core import costmodel  # noqa: E402
from repro.core.graph import TABLE2_DATASETS, TAXI_STATS  # noqa: E402
from repro.mapper import XbarInventory  # noqa: E402
from repro.mapper.compile import compile_mapping  # noqa: E402

SIZES = (None, 64, 128, 256, 512)       # None == the paper's geometry
SETTINGS = ("centralized", "decentralized", "semi")
SMOKE_ARGV = ["--smoke"]
METRICS: dict = {}              # filled by main(); run.py --json-out reads it


def run_case(name: str, stats, setting: str, size: int | None,
             layer_dims=(0, 128), n_clusters: int = 16,
             iso_cells: bool = False) -> dict:
    hw = costmodel.DEFAULT_HW
    inv = XbarInventory.from_hardware(hw, setting)
    if size is not None:
        inv = inv.with_xbar_size(size, iso_cells=iso_cells)
    dims = (max(stats.feature_len, 1), *layer_dims[1:])
    cal = costmodel.predict(setting, stats, hw, n_clusters=n_clusters)
    # one compilation per case; predict(mode="derived") is the same rollup
    # (cross-checked in tests/test_mapper.py)
    m = compile_mapping(dims, stats, hw, inv, setting, n_clusters)
    t_der = m.t_compute
    return dict(
        dataset=name, setting=setting,
        xbar="paper" if size is None else str(size),
        t_cal=cal.t_compute, t_der=t_der,
        ratio=t_der / max(cal.t_compute, 1e-30),
        e_cal=cal.p_compute * cal.t_compute, e_der=m.energy_j,
        util=m.weight_utilization, occ=m.array_utilization[2],
        fx_arrays=m.weight_arrays, fx_copies=m.fx.copies,
        fx_groups=m.fx.groups)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep + hard asserts (the CI gate)")
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--iso-cells", action="store_true",
                    help="rescale array counts to keep each core's total "
                         "cell budget when re-geometrying")
    ap.add_argument("--clusters", type=int, default=16,
                    help="semi-setting cluster-head count")
    args = ap.parse_args()

    datasets = dict(TABLE2_DATASETS, taxi=TAXI_STATS)
    if args.smoke:
        datasets = {"taxi": TAXI_STATS, "cora": TABLE2_DATASETS["cora"]}
    sizes = (None, 128, 256) if args.smoke else SIZES

    cols = ("dataset", "setting", "xbar", "t_cal", "t_der", "ratio",
            "e_cal", "e_der", "util", "occ")
    if args.csv:
        print(",".join(cols))
    else:
        print(f"{'dataset':12s} {'setting':14s} {'xbar':>6s} "
              f"{'T_cal s':>10s} {'T_der s':>10s} {'der/cal':>8s} "
              f"{'E_cal J':>10s} {'E_der J':>10s} {'util':>6s} {'occ':>6s}")

    rows = []
    for name, stats in datasets.items():
        for setting in SETTINGS:
            for size in sizes:
                r = run_case(name, stats, setting, size,
                             n_clusters=args.clusters,
                             iso_cells=args.iso_cells)
                rows.append(r)
                if args.csv:
                    print(",".join(
                        f"{r[c]:.6e}" if isinstance(r[c], float) else str(r[c])
                        for c in cols))
                else:
                    print(f"{r['dataset']:12s} {r['setting']:14s} "
                          f"{r['xbar']:>6s} {r['t_cal']:10.3e} "
                          f"{r['t_der']:10.3e} {r['ratio']:8.3f} "
                          f"{r['e_cal']:10.3e} {r['e_der']:10.3e} "
                          f"{r['util']:6.1%} {r['occ']:6.1%}")

    METRICS.clear()
    METRICS.update(iso_cells=args.iso_cells, clusters=args.clusters,
                   rows=rows)     # fully analytic — seed-deterministic

    if not args.smoke:
        return 0
    # the cross-validation contract: at the paper's geometry the derived
    # rollup must reproduce the calibrated Table-1 taxi latencies (<10%)
    # for both Table-1 settings; divergence is only legitimate away from
    # the calibration point.
    failures = []
    for r in rows:
        if (r["dataset"] == "taxi" and r["xbar"] == "paper"
                and r["setting"] in ("centralized", "decentralized")):
            if abs(r["ratio"] - 1.0) > 0.10:
                failures.append(
                    f"taxi/{r['setting']}@paper geometry: derived "
                    f"{r['ratio']:.3f}x calibrated (contract: within 10%)")
    settings_seen = {r["setting"] for r in rows}
    sizes_seen = {r["xbar"] for r in rows} - {"paper"}
    if len(sizes_seen) < 2 or len(settings_seen) < 3:
        failures.append(f"sweep too small: sizes {sorted(sizes_seen)}, "
                        f"settings {sorted(settings_seen)}")
    if failures:
        print("SMOKE FAILURES:")
        for f in failures:
            print(" ", f)
        return 1
    print(f"MAPPER_SWEEP_SMOKE_OK: derived matches calibrated Table-1 taxi "
          f"latencies at the paper geometry; swept {len(sizes_seen)} "
          f"crossbar sizes x {len(settings_seen)} settings x "
          f"{len(datasets)} datasets")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
