"""Device-technology sweep: cost scaling, variation bounds, calibration.

For technology {sot-mram, reram, sram, fefet} x setting {centralized,
decentralized, semi} x the Table-2 datasets (+ taxi), compile the
workload with ``compile_mapping(technology=...)`` (DESIGN.md §13) and
report:

  * **T_der / E_der** — technology-scaled derived latency and energy next
    to the SOT-MRAM anchor's. At the anchor the scaling is *exact
    identity* (the paper's Table-1 fixed point survives bit-for-bit), and
    on taxi at the paper geometry the anchor rows must still match the
    calibrated ``costmodel.predict`` within 10% — the mapper_sweep
    contract, re-asserted here because the technology pass rides on the
    same primitives.
  * **MC bounds** — Monte-Carlo mean/p99 relative MVM output error per
    technology (``devices.mvm_error_bounds``), next to the closed-form
    ``modeled_p99_error`` the planner's accuracy evaluator prices with.
    Noise draws are grid-quantized (exactly representable partial sums),
    so the bounds are a pure function of (technology, seed) — fully
    deterministic METRICS. ``--smoke`` asserts the errors are monotone in
    each technology's ``noise_sigma``.
  * **Planner frontier** — the taxi mixed churn+query workload planned
    over all four technologies *plus* the per-tier ``reram+sram`` pair
    (ReRAM spokes, SRAM heads); ``--smoke`` asserts a mixed-technology
    semi candidate survives on the Pareto frontier.
  * **Calibration** — ``devices.calibrate()`` measures the per-pass
    primitives on this host and writes the platform-stamped artifact CI
    uploads; measured wall-clocks (and the calibration-anchored derived
    latency they imply) live under ``timing`` keys — the runner's
    determinism convention quarantines them.

Usage:
  PYTHONPATH=src python benchmarks/tech_sweep.py            # full sweep
  PYTHONPATH=src python benchmarks/tech_sweep.py --smoke    # CI gate
  (--csv for machine-readable rows, --no-calibrate to skip the measured
  calibration pass)
"""
from __future__ import annotations

import argparse
import sys

sys.path.insert(0, "src")

from repro.core import costmodel  # noqa: E402
from repro.core.graph import TABLE2_DATASETS, TAXI_STATS  # noqa: E402
from repro.devices import (calibrate, load_calibration,  # noqa: E402
                           modeled_p99_error, mvm_error_bounds,
                           resolve_technology)
from repro.mapper.compile import compile_mapping  # noqa: E402
from repro.planner import WorkloadProfile, plan  # noqa: E402

TECHNOLOGIES = ("sot-mram", "reram", "sram", "fefet")
SETTINGS = ("centralized", "decentralized", "semi")
# the per-tier pair the planner sweep adds on top: dense/cheap ReRAM
# spokes storing the partition, fast SRAM heads running the passes
PAIR = ("reram", "sram")
SMOKE_ARGV = ["--smoke"]
METRICS: dict = {}              # filled by main(); run.py --json-out reads it

# the mixed serving workload the planner gate uses (planner_sweep's MIXED)
MIXED = WorkloadProfile(churn=0.01, queries_per_tick=64, sample=8)


def run_case(name: str, stats, setting: str, tech: str,
             layer_dims=(0, 128), n_clusters: int = 16) -> dict:
    """One (dataset, setting, technology) compile; anchor-relative ratios."""
    hw = costmodel.DEFAULT_HW
    dims = (max(stats.feature_len, 1), *layer_dims[1:])
    cal = costmodel.predict(setting, stats, hw, n_clusters=n_clusters)
    anchor = compile_mapping(dims, stats, hw, None, setting, n_clusters)
    m = (anchor if tech == anchor.technology
         else compile_mapping(dims, stats, hw, None, setting, n_clusters,
                              technology=tech))
    return dict(
        dataset=name, setting=setting, technology=tech,
        t_cal=cal.t_compute, t_der=m.t_compute, e_der=m.energy_j,
        ratio_cal=m.t_compute / max(cal.t_compute, 1e-30),
        t_vs_anchor=m.t_compute / max(anchor.t_compute, 1e-30),
        e_vs_anchor=m.energy_j / max(anchor.energy_j, 1e-30),
        anchor_exact=(m.t_compute == anchor.t_compute
                      and m.energy_j == anchor.energy_j))


def variation_case(tech: str, trials: int, seed: int = 0,
                   m: int = 8, k: int = 64, n: int = 16) -> dict:
    """Deterministic MC bounds + the closed-form model for one technology."""
    b = mvm_error_bounds(tech, m=m, k=k, n=n, trials=trials, seed=seed)
    return dict(technology=tech,
                sigma=resolve_technology(tech).noise_sigma,
                trials=b.trials, seed=b.seed, mean_err=b.mean_err,
                p99_err=b.p99_err, ci95=b.ci95,
                p99_model=modeled_p99_error(tech, k))


def planner_case(workload: WorkloadProfile) -> dict:
    """Plan taxi's mixed workload over the full technology axis."""
    result = plan(TAXI_STATS, "throughput", workload=workload,
                  technologies=(*TECHNOLOGIES, PAIR))
    frontier = [sc.as_record() for sc in result.frontier]
    return dict(
        n_candidates=len(result.scored),
        recommended=result.recommended.as_record(),
        frontier=frontier,
        mixed_on_frontier=[r["technology"] for r in frontier
                           if "+" in r["technology"]])


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep + hard asserts (the CI gate)")
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--trials", type=int, default=8,
                    help="Monte-Carlo trials per technology")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--clusters", type=int, default=16,
                    help="semi-setting cluster-head count")
    ap.add_argument("--no-calibrate", action="store_true",
                    help="skip the measured host-calibration pass")
    ap.add_argument("--calibration-out", default=None, metavar="PATH",
                    help="calibration artifact path (default: the "
                         "devices.CALIBRATION_PATH CI uploads)")
    args = ap.parse_args()

    datasets = dict(TABLE2_DATASETS, taxi=TAXI_STATS)
    if args.smoke:
        datasets = {"taxi": TAXI_STATS, "cora": TABLE2_DATASETS["cora"]}
    trials = min(args.trials, 4) if args.smoke else args.trials

    cols = ("dataset", "setting", "technology", "t_der", "e_der",
            "t_vs_anchor", "e_vs_anchor", "ratio_cal")
    if args.csv:
        print(",".join(cols))
    else:
        print(f"{'dataset':12s} {'setting':14s} {'tech':>9s} "
              f"{'T_der s':>10s} {'E_der J':>10s} {'T/anchor':>9s} "
              f"{'E/anchor':>9s} {'der/cal':>8s}")

    rows = []
    for name, stats in datasets.items():
        for setting in SETTINGS:
            for tech in TECHNOLOGIES:
                r = run_case(name, stats, setting, tech,
                             n_clusters=args.clusters)
                rows.append(r)
                if args.csv:
                    print(",".join(
                        f"{r[c]:.6e}" if isinstance(r[c], float) else str(r[c])
                        for c in cols))
                else:
                    print(f"{r['dataset']:12s} {r['setting']:14s} "
                          f"{r['technology']:>9s} {r['t_der']:10.3e} "
                          f"{r['e_der']:10.3e} {r['t_vs_anchor']:9.3f} "
                          f"{r['e_vs_anchor']:9.3f} {r['ratio_cal']:8.3f}")

    print(f"\n{'tech':>9s} {'sigma':>6s} {'mean_err':>10s} {'p99_err':>10s} "
          f"{'ci95':>10s} {'p99_model':>10s}")
    variation = []
    for tech in TECHNOLOGIES:
        v = variation_case(tech, trials, seed=args.seed)
        variation.append(v)
        print(f"{v['technology']:>9s} {v['sigma']:6.3f} "
              f"{v['mean_err']:10.3e} {v['p99_err']:10.3e} "
              f"{v['ci95']:10.3e} {v['p99_model']:10.3e}")

    planner = planner_case(MIXED)
    rec = planner["recommended"]
    print(f"\nplanner[taxi mixed, {planner['n_candidates']} candidates]: "
          f"recommended {rec['setting']}/{rec['technology']}"
          f"/k{rec['n_clusters']}; mixed-technology frontier entries: "
          f"{planner['mixed_on_frontier'] or 'none'}")

    timing: dict = {}
    if not args.no_calibrate:
        # measured wall-clocks: quarantined under the "timing" key, like
        # every measured quantity in the BENCH determinism convention
        cal = calibrate(path=args.calibration_out, hw=costmodel.DEFAULT_HW,
                        iters=1 if args.smoke else 3, seed=args.seed)
        cal_path = args.calibration_out
        if cal_path is None:
            from repro.devices import CALIBRATION_PATH, save_calibration
            cal_path = save_calibration(cal, CALIBRATION_PATH)
        m_cal = compile_mapping(
            (max(TAXI_STATS.feature_len, 1), 128), TAXI_STATS,
            costmodel.DEFAULT_HW, None, "centralized", args.clusters,
            calibration=cal)
        timing = dict(platform=cal.platform, t_cam=cal.t_cam,
                      t_agg=cal.t_agg, t_fx=cal.t_fx,
                      taxi_centralized_t_der_calibrated=m_cal.t_compute)
        print(f"calibration[{cal.platform}]: t_cam {cal.t_cam:.3e} s, "
              f"t_agg {cal.t_agg:.3e} s, t_fx {cal.t_fx:.3e} s "
              f"-> taxi centralized derived {m_cal.t_compute:.3e} s "
              f"(artifact: {cal_path})")

    METRICS.clear()
    METRICS.update(clusters=args.clusters, trials=trials, seed=args.seed,
                   rows=rows, variation=variation, planner=planner,
                   timing=timing)

    if not args.smoke:
        return 0
    failures = []
    # 1. the anchor contract: SOT-MRAM rows are exact identities of the
    #    technology-free compile, and on taxi at the paper geometry they
    #    still match the calibrated Table-1 latencies within 10%
    for r in rows:
        if r["technology"] == "sot-mram" and not r["anchor_exact"]:
            failures.append(f"{r['dataset']}/{r['setting']}: sot-mram row "
                            f"is not bit-identical to the anchor compile")
        if (r["dataset"] == "taxi" and r["technology"] == "sot-mram"
                and r["setting"] in ("centralized", "decentralized")
                and abs(r["ratio_cal"] - 1.0) > 0.10):
            failures.append(
                f"taxi/{r['setting']}@sot-mram: derived "
                f"{r['ratio_cal']:.3f}x calibrated (contract: within 10%)")
    techs_seen = {r["technology"] for r in rows}
    settings_seen = {r["setting"] for r in rows}
    if len(techs_seen) < 4 or len(settings_seen) < 3 or len(datasets) < 2:
        failures.append(f"sweep too small: techs {sorted(techs_seen)}, "
                        f"settings {sorted(settings_seen)}, "
                        f"{len(datasets)} datasets")
    # 2. MC errors monotone in noise_sigma (sram == 0 exactly)
    by_sigma = sorted(variation, key=lambda v: v["sigma"])
    for a, b in zip(by_sigma, by_sigma[1:]):
        if a["mean_err"] > b["mean_err"] or a["p99_err"] > b["p99_err"]:
            failures.append(
                f"MC errors not monotone in sigma: {a['technology']} "
                f"(sigma {a['sigma']}) error exceeds {b['technology']} "
                f"(sigma {b['sigma']})")
    sram = next(v for v in variation if v["technology"] == "sram")
    if sram["mean_err"] != 0.0:
        failures.append(f"sram (sigma 0) mean error {sram['mean_err']} != 0")
    # 3. a mixed-technology semi plan survives on the Pareto frontier
    if not planner["mixed_on_frontier"]:
        failures.append("no mixed-technology (reram+sram) candidate on the "
                        "taxi Pareto frontier")
    # 4. calibration measured something sane and round-trips strictly
    if timing:
        if min(timing["t_cam"], timing["t_agg"], timing["t_fx"]) <= 0:
            failures.append(f"non-positive calibration primitive: {timing}")
        reloaded = load_calibration(cal_path)      # strict: platform match
        if reloaded != cal:
            failures.append(f"calibration round-trip drift: {reloaded} "
                            f"!= {cal}")
    if failures:
        print("SMOKE FAILURES:")
        for f in failures:
            print(" ", f)
        return 1
    print(f"TECH_SWEEP_SMOKE_OK: {len(techs_seen)} technologies x "
          f"{len(settings_seen)} settings x {len(datasets)} datasets; "
          f"sot-mram anchor exact + within 10% of Table-1 taxi; MC error "
          f"monotone in sigma; mixed-technology semi on the frontier"
          + ("" if not timing else "; host calibration measured + "
             "round-tripped"))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
