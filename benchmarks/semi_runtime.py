"""Predicted-vs-executed validation loop for the two-tier semi runtime.

Sweeps head counts over Table-2-like graphs and, per (dataset, heads):

  * builds the two-tier semi ``ExecutionPlan`` (``hier_partition`` + the
    tier-0 spoke->head / tier-1 head<->head exchanges, DESIGN.md §7),
  * runs the emulated two-tier forward and checks it against the
    centralized full-graph oracle (the runtime really executes, it isn't
    just priced),
  * reports **measured** tier-0/tier-1 traffic from the executed exchange
    tables (``ExecutionPlan.measured_traffic``) next to the cost model's
    Eq. 4/5 communication-latency predictions and the pruned
    ``comm_volume`` e_ij tables — the alltoall row counts must agree with
    e_ij exactly (they are built from the same pruned edge set).

Usage:
  PYTHONPATH=src python benchmarks/semi_runtime.py            # full sweep
  PYTHONPATH=src python benchmarks/semi_runtime.py --smoke    # CI gate
  (--csv for machine-readable rows)

Columns: tier0/tier1 MB are measured bytes for a ``--layers``-layer GNN at
the dataset's feature dim; ``rows/e_ij`` is measured alltoall rows over the
tabulated pruned comm_volume (1.000 == exact agreement); Eq.4/Eq.5 are the
decentralized/semi communication-latency predictions for context.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.core import costmodel, gnn  # noqa: E402
from repro.core.graph import dataset_like  # noqa: E402
from repro.core.partition import plan_execution  # noqa: E402

DATASETS = ("taxi", "collab", "cora", "citeseer")
HEADS = (2, 4, 8)
SMOKE_ARGV = ["--smoke"]        # benchmarks.run --smoke path


def run_case(name: str, scale: float, heads: int, sample: int,
             hidden: int, check_parity: bool, seed: int = 0) -> dict:
    import jax

    g = dataset_like(name, scale=scale, seed=seed).gcn_normalize()
    plan = plan_execution(g, "semi", sample=sample, n_clusters=heads,
                          seed=seed)
    cfg = gnn.GNNConfig(in_dim=g.feature_len, hidden_dims=(hidden,),
                        out_dim=8, sample=sample)
    rep = plan.measured_traffic(cfg, mode="alltoall")

    e_ij = plan.part.comm_volume
    ratio = (float(rep.tier1_rows.sum()) / float(e_ij.sum())
             if e_ij.sum() else 1.0)

    semi = plan.predicted_metrics()
    dec = costmodel.predict("decentralized", g.stats(name),
                            n_clusters=heads, sample=sample)

    err = float("nan")
    if check_parity:
        params = gnn.init_params(jax.random.key(seed), cfg)
        cent = plan_execution(g, "centralized", sample=sample)
        ref = cent.scatter(np.asarray(cent.make_forward(cfg)(params)))
        out = plan.scatter(np.asarray(
            plan.make_forward(cfg, mode="alltoall")(params)))
        err = float(np.abs(out - ref).max())

    return dict(dataset=name, n_nodes=g.n_nodes, heads=heads,
                spokes=plan.hier.spokes_per_region,
                tier0_mb=rep.tier0_bytes().sum() / 1e6,
                tier1_mb=rep.tier1_bytes().sum() / 1e6,
                rows_over_eij=ratio,
                t_comm_dec=dec.t_communicate,
                t_comm_semi=semi.t_communicate,
                parity_err=err)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny scales + hard asserts (the CI gate)")
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--scale", type=float, default=0.01)
    ap.add_argument("--sample", type=int, default=8)
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--heads", type=int, nargs="*", default=None)
    args = ap.parse_args()

    scale = 0.002 if args.smoke else args.scale
    heads = tuple(args.heads) if args.heads else (
        (2, 3) if args.smoke else HEADS)
    datasets = ("taxi", "cora", "citeseer") if args.smoke else DATASETS

    hdr = (f"{'dataset':10s} {'nodes':>6s} {'heads':>5s} {'spokes':>6s} "
           f"{'tier0MB':>9s} {'tier1MB':>9s} {'rows/e_ij':>9s} "
           f"{'Eq4 dec s':>10s} {'Eq5 semi s':>10s} {'parity':>9s}")
    if args.csv:
        print("dataset,nodes,heads,spokes,tier0_mb,tier1_mb,rows_over_eij,"
              "t_comm_dec,t_comm_semi,parity_err")
    else:
        print(hdr)

    failures = []
    for name in datasets:
        for k in heads:
            r = run_case(name, scale, k, args.sample, args.hidden,
                         check_parity=args.smoke)
            if args.csv:
                print(f"{r['dataset']},{r['n_nodes']},{r['heads']},"
                      f"{r['spokes']},{r['tier0_mb']:.6f},"
                      f"{r['tier1_mb']:.6f},{r['rows_over_eij']:.4f},"
                      f"{r['t_comm_dec']:.4e},{r['t_comm_semi']:.4e},"
                      f"{r['parity_err']:.3e}")
            else:
                print(f"{r['dataset']:10s} {r['n_nodes']:6d} {r['heads']:5d} "
                      f"{r['spokes']:6d} {r['tier0_mb']:9.4f} "
                      f"{r['tier1_mb']:9.4f} {r['rows_over_eij']:9.3f} "
                      f"{r['t_comm_dec']:10.3e} {r['t_comm_semi']:10.3e} "
                      f"{r['parity_err']:9.2e}")
            if args.smoke:
                if abs(r["rows_over_eij"] - 1.0) > 0.10:
                    failures.append(f"{name}/k={k}: measured rows deviate "
                                    f"{r['rows_over_eij']:.3f}x from e_ij")
                if not (r["parity_err"] < 1e-4):
                    failures.append(f"{name}/k={k}: parity err "
                                    f"{r['parity_err']:.2e}")
    if args.smoke:
        if failures:
            print("SMOKE FAILURES:")
            for f in failures:
                print(" ", f)
            return 1
        print("SEMI_RUNTIME_SMOKE_OK: measured tier-1 rows match pruned "
              "e_ij and two-tier forward matches the centralized oracle "
              f"on {len(datasets) * len(heads)} workloads")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
