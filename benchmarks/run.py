"""Benchmark runner — auto-registers every benchmarks/ module with a main().

Discovery replaces the hand-kept list that drifted (fused_vs_composed and
semi_runtime were never registered): any module in this package exposing a
callable ``main() -> int`` is a benchmark. Module conventions:

  * ``SMOKE_ARGV``      — argv the module's CLI gets under ``--smoke``
    (e.g. ``["--smoke"]``, ``["--iters", "1"]``); modules without it run
    their default path in both modes.
  * ``INFORMATIONAL``   — nonzero return is reported but does not fail the
    run (e.g. roofline_table when no dry-run file exists).
  * ``METRICS``         — a dict the bench's ``main()`` fills with its
    headline numbers; ``--json-out DIR`` persists it (plus name, argv,
    return code, wall-clock, git sha) as ``DIR/BENCH_<name>.json`` — the
    perf-trajectory artifact the CI smoke gate uploads on every PR.

Determinism convention: everything in ``METRICS`` must be a pure function
of (seed, argv) — running a ``--smoke`` bench twice must reproduce it
byte-identically (regression-tested in tests/test_bench_determinism.py).
Measured wall-clock quantities are the sanctioned exception: they live
under keys named ``timing`` (any nesting level), which
``canonical_metrics`` strips alongside the runner's own volatile fields
(``seconds``, ``git_sha``) before any artifact comparison.

``python -m benchmarks.run`` runs everything and exits non-zero on any
paper-validation mismatch; ``--smoke`` runs every bench's smoke path (the
CI gate — registry drift or bench breakage fails the build);
``python -m benchmarks.run table1_taxi semi_sweep`` runs a subset.
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import pkgutil
import subprocess
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)   # `python benchmarks/run.py` == `-m` form

import benchmarks  # noqa: E402


def discover(names: list | None = None) -> dict:
    """name -> module for benchmarks/ modules exposing main().

    ``names`` restricts discovery — and therefore the jax-heavy imports —
    to that subset (in the given order); unknown names abort with the full
    candidate list."""
    candidates = sorted(i.name for i in
                        pkgutil.iter_modules(benchmarks.__path__)
                        if i.name != "run")
    if names:
        unknown = [n for n in names if n not in candidates]
        if unknown:
            sys.exit(f"unknown benchmark(s) {unknown}; "
                     f"candidates: {candidates}")
    registry = {}
    for name in (names or candidates):
        mod = importlib.import_module(f"benchmarks.{name}")
        if callable(getattr(mod, "main", None)):
            registry[name] = mod
        elif names:
            sys.exit(f"{name} is a library module (no main()); "
                     f"candidates: {candidates}")
    return registry


# keys holding measured wall-clock (or equivalently volatile) values —
# excluded from artifact determinism comparisons at any nesting depth
VOLATILE_KEYS = frozenset({"timing", "seconds", "git_sha"})


def canonical_metrics(obj, volatile: frozenset = VOLATILE_KEYS):
    """The deterministic projection of a METRICS dict / BENCH record:
    volatile keys dropped recursively, dict keys sorted — two runs of the
    same bench with the same seed+argv must serialize identically."""
    if isinstance(obj, dict):
        return {k: canonical_metrics(obj[k], volatile)
                for k in sorted(obj) if k not in volatile}
    if isinstance(obj, (list, tuple)):
        return [canonical_metrics(v, volatile) for v in obj]
    return obj


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=_ROOT, timeout=10).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def run_one(name: str, mod, smoke: bool, json_out: str | None = None) -> int:
    """Run one benchmark under a controlled argv; returns its failure count.

    ``json_out``: directory to persist a ``BENCH_<name>.json`` artifact —
    bench name, effective argv, return code, wall-clock seconds, git sha,
    and whatever the module left in its ``METRICS`` dict."""
    argv = [f"benchmarks/{name}.py"]
    if smoke:
        argv += list(getattr(mod, "SMOKE_ARGV", []))
    saved = sys.argv
    t0 = time.perf_counter()
    try:
        sys.argv = argv
        rc = int(mod.main() or 0)
    finally:
        sys.argv = saved
    seconds = time.perf_counter() - t0
    if json_out:
        os.makedirs(json_out, exist_ok=True)
        record = dict(bench=name, argv=argv[1:], smoke=smoke,
                      returncode=rc, seconds=round(seconds, 3),
                      git_sha=_git_sha(),
                      metrics=getattr(mod, "METRICS", {}))
        path = os.path.join(json_out, f"BENCH_{name}.json")
        with open(path, "w") as f:
            json.dump(record, f, indent=2, default=str)
        print(f"(wrote {path})")
    if rc and getattr(mod, "INFORMATIONAL", False):
        print(f"({name} is informational — not counted as a failure)")
        return 0
    return rc


def main(argv: list | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("modules", nargs="*",
                    help="subset of registered benchmarks (default: all)")
    ap.add_argument("--smoke", action="store_true",
                    help="run every bench's smoke path (the CI gate)")
    ap.add_argument("--list", action="store_true",
                    help="print the registry and exit")
    ap.add_argument("--json-out", metavar="DIR",
                    help="persist a BENCH_<name>.json artifact per bench "
                         "(name, argv, metrics, git sha) into DIR")
    args = ap.parse_args(argv)

    registry = discover(args.modules or None)
    if args.list:
        for name, mod in registry.items():
            extras = []
            if getattr(mod, "SMOKE_ARGV", None):
                extras.append(f"smoke: {' '.join(mod.SMOKE_ARGV)}")
            if getattr(mod, "INFORMATIONAL", False):
                extras.append("informational")
            print(f"{name:20s} {'(' + ', '.join(extras) + ')' if extras else ''}")
        return

    failures = 0
    for name, mod in registry.items():
        print(f"\n===== {name}{' (smoke)' if args.smoke else ''} =====")
        failures += run_one(name, mod, args.smoke, json_out=args.json_out)
    if failures:
        sys.exit(f"{failures} benchmark validations failed")
    print(f"\nall {len(registry)} benchmark validations passed")


if __name__ == "__main__":
    main()
