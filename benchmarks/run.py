"""Benchmark runner — auto-registers every benchmarks/ module with a main().

Discovery replaces the hand-kept list that drifted (fused_vs_composed and
semi_runtime were never registered): any module in this package exposing a
callable ``main() -> int`` is a benchmark. Module conventions:

  * ``SMOKE_ARGV``      — argv the module's CLI gets under ``--smoke``
    (e.g. ``["--smoke"]``, ``["--iters", "1"]``); modules without it run
    their default path in both modes.
  * ``INFORMATIONAL``   — nonzero return is reported but does not fail the
    run (e.g. roofline_table when no dry-run file exists).
  * ``METRICS``         — a dict the bench's ``main()`` fills with its
    headline numbers; ``--json-out DIR`` persists it (plus name, argv,
    return code, wall-clock, git sha) as ``DIR/BENCH_<name>.json`` — the
    perf-trajectory artifact the CI smoke gate uploads on every PR.

Determinism convention: everything in ``METRICS`` must be a pure function
of (seed, argv) — running a ``--smoke`` bench twice must reproduce it
byte-identically (regression-tested in tests/test_bench_determinism.py).
Measured wall-clock quantities are the sanctioned exception: they live
under keys named ``timing`` (any nesting level), which
``canonical_metrics`` strips alongside the runner's own volatile fields
(``seconds``, ``git_sha``) before any artifact comparison.

``python -m benchmarks.run`` runs everything and exits non-zero on any
paper-validation mismatch; ``--smoke`` runs every bench's smoke path (the
CI gate — registry drift or bench breakage fails the build);
``python -m benchmarks.run table1_taxi semi_sweep`` runs a subset.

Perf trajectory (``--compare``): each commit carries its baseline
artifacts as repo-root ``BENCH_<name>.json`` files. ``--compare`` diffs
the current run against them — deterministic metrics must agree exactly
(float tolerance), and every numeric leaf under a ``timing`` key may not
regress by more than ``--compare-threshold`` (a fraction: 5.0 == 6x
worse fails). "Worse" is direction-aware: slower for latency-style
leaves, lower for throughput-style ones (``qps``/``rate``/... in the
leaf name). Timing *improvements* and the runner's own
``seconds``/``git_sha`` never trip it. ``--update-baseline`` re-records
the repo-root artifacts — run it (and commit the result) whenever a bench
legitimately changes its metrics or argv.
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import pkgutil
import subprocess
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)   # `python benchmarks/run.py` == `-m` form

import benchmarks  # noqa: E402


def discover(names: list | None = None) -> dict:
    """name -> module for benchmarks/ modules exposing main().

    ``names`` restricts discovery — and therefore the jax-heavy imports —
    to that subset (in the given order); unknown names abort with the full
    candidate list."""
    candidates = sorted(i.name for i in
                        pkgutil.iter_modules(benchmarks.__path__)
                        if i.name != "run")
    if names:
        unknown = [n for n in names if n not in candidates]
        if unknown:
            sys.exit(f"unknown benchmark(s) {unknown}; "
                     f"candidates: {candidates}")
    registry = {}
    for name in (names or candidates):
        mod = importlib.import_module(f"benchmarks.{name}")
        if callable(getattr(mod, "main", None)):
            registry[name] = mod
        elif names:
            sys.exit(f"{name} is a library module (no main()); "
                     f"candidates: {candidates}")
    return registry


# keys holding measured wall-clock (or equivalently volatile) values —
# excluded from artifact determinism comparisons at any nesting depth
# ("info" carries the per-run telemetry snapshot: span timings and
# counters are measurements, never compared)
VOLATILE_KEYS = frozenset({"timing", "seconds", "git_sha", "info"})


def canonical_metrics(obj, volatile: frozenset = VOLATILE_KEYS):
    """The deterministic projection of a METRICS dict / BENCH record:
    volatile keys dropped recursively, dict keys sorted — two runs of the
    same bench with the same seed+argv must serialize identically."""
    if isinstance(obj, dict):
        return {k: canonical_metrics(obj[k], volatile)
                for k in sorted(obj) if k not in volatile}
    if isinstance(obj, (list, tuple)):
        return [canonical_metrics(v, volatile) for v in obj]
    return obj


def collect_timings(obj, under_timing: bool = False,
                    prefix: str = "") -> dict:
    """path -> float for every numeric leaf under a ``timing`` key.

    The complement of ``canonical_metrics``: the measured wall-clock
    quantities the determinism contract quarantines are exactly the ones
    the perf-trajectory gate compares (with a relative threshold, since
    they are machine-noisy by nature)."""
    out = {}
    if isinstance(obj, dict):
        for k in sorted(obj):
            p = f"{prefix}.{k}" if prefix else str(k)
            out.update(collect_timings(obj[k], under_timing or k == "timing",
                                       p))
    elif isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            out.update(collect_timings(v, under_timing, f"{prefix}[{i}]"))
    elif under_timing and isinstance(obj, (int, float)) \
            and not isinstance(obj, bool):
        out[prefix] = float(obj)
    return out


def diff_deterministic(base, cur, path: str = "", rtol: float = 1e-5,
                       atol: float = 1e-8) -> list:
    """Paths where two canonical (volatile-stripped) metric trees disagree.

    Floats compare with (rtol, atol) so a serialization round-trip never
    counts as drift; everything else must match exactly."""
    if isinstance(base, dict) and isinstance(cur, dict):
        msgs = []
        for k in sorted(set(base) | set(cur)):
            p = f"{path}.{k}" if path else str(k)
            if k not in cur:
                msgs.append(f"{p}: missing from current run")
            elif k not in base:
                msgs.append(f"{p}: not in baseline")
            else:
                msgs += diff_deterministic(base[k], cur[k], p, rtol, atol)
        return msgs
    if isinstance(base, (list, tuple)) and isinstance(cur, (list, tuple)):
        if len(base) != len(cur):
            return [f"{path}: length {len(base)} -> {len(cur)}"]
        return [m for i, (b, c) in enumerate(zip(base, cur))
                for m in diff_deterministic(b, c, f"{path}[{i}]", rtol, atol)]
    if isinstance(base, float) or isinstance(cur, float):
        try:
            if abs(float(base) - float(cur)) <= atol + rtol * abs(float(base)):
                return []
        except (TypeError, ValueError):
            pass
        return [f"{path}: {base!r} -> {cur!r}"]
    if base != cur:
        return [f"{path}: {base!r} -> {cur!r}"]
    return []


# timing leaves where *higher* is better (throughput-style): a drop past
# the threshold is the regression, a rise never is. Matched against the
# leaf key name (last path segment).
HIGHER_IS_BETTER_MARKERS = ("qps", "rate", "throughput", "per_sec")


def _higher_is_better(path: str) -> bool:
    leaf = path.rsplit(".", 1)[-1]
    return any(m in leaf for m in HIGHER_IS_BETTER_MARKERS)


def compare_records(name: str, baseline: dict, current: dict,
                    threshold: float = 5.0) -> list:
    """Failure messages for one bench record vs its committed baseline.

    Three failure classes: (1) the bench's effective argv changed — the
    baseline measures a different configuration, re-record it; (2)
    deterministic drift — any non-``timing`` metric disagrees; (3) timing
    regression — a ``timing`` leaf more than ``threshold`` (fractional)
    *worse* than baseline, where worse means slower for latency-style
    leaves and lower for throughput-style ones (``qps``/``rate``/
    ``throughput``/``per_sec`` in the leaf name). Timing leaves only in
    one of the two records are ignored (new measurements have no baseline
    yet); improvements never fail."""
    if baseline.get("argv") != current.get("argv"):
        return [f"{name}: argv changed {baseline.get('argv')} -> "
                f"{current.get('argv')}; re-record the baseline with "
                f"--update-baseline"]
    fails = [f"{name}: deterministic drift at {m}" for m in
             diff_deterministic(canonical_metrics(baseline.get("metrics", {})),
                                canonical_metrics(current.get("metrics", {})))]
    base_t = collect_timings(baseline.get("metrics", {}))
    cur_t = collect_timings(current.get("metrics", {}))
    for key in sorted(set(base_t) & set(cur_t)):
        b, c = base_t[key], cur_t[key]
        if b <= 0:
            continue
        if _higher_is_better(key):
            if c < b / (1.0 + threshold):
                fails.append(
                    f"{name}: timing regression at {key}: {c:.6g} vs "
                    f"baseline {b:.6g} (-{(1 - c / b) * 100:.1f}% "
                    f"throughput > {threshold * 100:g}% threshold)")
        elif c > b * (1.0 + threshold):
            fails.append(
                f"{name}: timing regression at {key}: {c:.6g} vs baseline "
                f"{b:.6g} (+{(c / b - 1) * 100:.1f}% > "
                f"{threshold * 100:g}% threshold)")
    return fails


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=_ROOT, timeout=10).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def write_record(record: dict, out_dir: str) -> str:
    """Persist one BENCH_<name>.json artifact; returns its path."""
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{record['bench']}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=2, default=str)
    return path


def run_one(name: str, mod, smoke: bool,
            json_out: str | None = None) -> tuple:
    """Run one benchmark under a controlled argv.

    Returns ``(failures, record)`` — the failure count (0 for
    informational benches) and the BENCH artifact record: bench name,
    effective argv, return code, wall-clock seconds, git sha, and whatever
    the module left in its ``METRICS`` dict. ``json_out``: directory to
    persist the record as ``BENCH_<name>.json``."""
    argv = [f"benchmarks/{name}.py"]
    if smoke:
        argv += list(getattr(mod, "SMOKE_ARGV", []))
    saved = sys.argv
    # run every bench under telemetry so artifacts say where time went,
    # not just totals; the snapshot lands under the record-level ``info``
    # key, which --compare never inspects (it diffs argv + metrics only)
    try:
        from repro import telemetry
    except ImportError:                              # pragma: no cover
        telemetry = None
    tel_was_enabled = False
    if telemetry is not None:
        tel_was_enabled = telemetry.enabled()
        telemetry.reset()
        telemetry.enable()
    t0 = time.perf_counter()
    try:
        sys.argv = argv
        rc = int(mod.main() or 0)
    finally:
        sys.argv = saved
    seconds = time.perf_counter() - t0
    info = {}
    if telemetry is not None:
        info = {"telemetry": telemetry.snapshot()}
        telemetry.reset()
        if not tel_was_enabled:
            telemetry.disable()
    # round-trip through JSON so in-memory records and ones re-read from
    # disk (the baselines --compare loads) are structurally identical
    # (tuples -> lists, numpy scalars -> str/float)
    record = json.loads(json.dumps(
        dict(bench=name, argv=argv[1:], smoke=smoke, returncode=rc,
             seconds=round(seconds, 3), git_sha=_git_sha(),
             metrics=getattr(mod, "METRICS", {}), info=info), default=str))
    if json_out:
        print(f"(wrote {write_record(record, json_out)})")
    if rc and getattr(mod, "INFORMATIONAL", False):
        print(f"({name} is informational — not counted as a failure)")
        return 0, record
    return rc, record


def main(argv: list | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("modules", nargs="*",
                    help="subset of registered benchmarks (default: all)")
    ap.add_argument("--smoke", action="store_true",
                    help="run every bench's smoke path (the CI gate)")
    ap.add_argument("--list", action="store_true",
                    help="print the registry and exit")
    ap.add_argument("--json-out", metavar="DIR",
                    help="persist a BENCH_<name>.json artifact per bench "
                         "(name, argv, metrics, git sha) into DIR")
    ap.add_argument("--compare", action="store_true",
                    help="diff each bench against its committed baseline "
                         "(BENCH_<name>.json in --baseline-dir): fail on "
                         "deterministic drift or timing regression beyond "
                         "--compare-threshold")
    ap.add_argument("--compare-threshold", type=float, default=5.0,
                    metavar="FRAC",
                    help="allowed fractional timing regression before "
                         "--compare fails (default 5.0 == 6x worse: "
                         "interpret-mode CPU micro-timings jitter several-"
                         "fold run-to-run, so the default catches order-of-"
                         "magnitude pathologies; tighten on real hardware)")
    ap.add_argument("--baseline-dir", default=_ROOT, metavar="DIR",
                    help="where baseline BENCH_<name>.json artifacts live "
                         "(default: repo root — the per-commit convention)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline artifacts in --baseline-dir "
                         "from this run (commit the result)")
    args = ap.parse_args(argv)

    registry = discover(args.modules or None)
    if args.list:
        for name, mod in registry.items():
            extras = []
            if getattr(mod, "SMOKE_ARGV", None):
                extras.append(f"smoke: {' '.join(mod.SMOKE_ARGV)}")
            if getattr(mod, "INFORMATIONAL", False):
                extras.append("informational")
            print(f"{name:20s} {'(' + ', '.join(extras) + ')' if extras else ''}")
        return

    failures = 0
    compare_failures = []
    for name, mod in registry.items():
        print(f"\n===== {name}{' (smoke)' if args.smoke else ''} =====")
        rc, record = run_one(name, mod, args.smoke, json_out=args.json_out)
        failures += rc
        if args.update_baseline:
            print(f"(baseline updated: {write_record(record, args.baseline_dir)})")
        elif args.compare:
            base_path = os.path.join(args.baseline_dir,
                                     f"BENCH_{name}.json")
            if not os.path.exists(base_path):
                compare_failures.append(
                    f"{name}: no baseline at {base_path}; record one with "
                    f"--update-baseline (and commit it)")
                continue
            with open(base_path) as f:
                baseline = json.load(f)
            msgs = compare_records(name, baseline, record,
                                   threshold=args.compare_threshold)
            for m in msgs:
                print(f"COMPARE FAIL: {m}")
            if not msgs:
                print(f"(compare vs {base_path}: ok)")
            compare_failures += msgs
    if compare_failures:
        print(f"\n{len(compare_failures)} baseline comparison failure(s):")
        for m in compare_failures:
            print(f"  - {m}")
    if failures or compare_failures:
        sys.exit(f"{failures} benchmark validations and "
                 f"{len(compare_failures)} baseline comparisons failed")
    print(f"\nall {len(registry)} benchmark validations passed"
          + (" (baselines match)" if args.compare else ""))


if __name__ == "__main__":
    main()
