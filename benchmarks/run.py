"""Benchmark runner — auto-registers every benchmarks/ module with a main().

Discovery replaces the hand-kept list that drifted (fused_vs_composed and
semi_runtime were never registered): any module in this package exposing a
callable ``main() -> int`` is a benchmark. Module conventions:

  * ``SMOKE_ARGV``      — argv the module's CLI gets under ``--smoke``
    (e.g. ``["--smoke"]``, ``["--iters", "1"]``); modules without it run
    their default path in both modes.
  * ``INFORMATIONAL``   — nonzero return is reported but does not fail the
    run (e.g. roofline_table when no dry-run file exists).

``python -m benchmarks.run`` runs everything and exits non-zero on any
paper-validation mismatch; ``--smoke`` runs every bench's smoke path (the
CI gate — registry drift or bench breakage fails the build);
``python -m benchmarks.run table1_taxi semi_sweep`` runs a subset.
"""
from __future__ import annotations

import argparse
import importlib
import os
import pkgutil
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)   # `python benchmarks/run.py` == `-m` form

import benchmarks  # noqa: E402


def discover(names: list | None = None) -> dict:
    """name -> module for benchmarks/ modules exposing main().

    ``names`` restricts discovery — and therefore the jax-heavy imports —
    to that subset (in the given order); unknown names abort with the full
    candidate list."""
    candidates = sorted(i.name for i in
                        pkgutil.iter_modules(benchmarks.__path__)
                        if i.name != "run")
    if names:
        unknown = [n for n in names if n not in candidates]
        if unknown:
            sys.exit(f"unknown benchmark(s) {unknown}; "
                     f"candidates: {candidates}")
    registry = {}
    for name in (names or candidates):
        mod = importlib.import_module(f"benchmarks.{name}")
        if callable(getattr(mod, "main", None)):
            registry[name] = mod
        elif names:
            sys.exit(f"{name} is a library module (no main()); "
                     f"candidates: {candidates}")
    return registry


def run_one(name: str, mod, smoke: bool) -> int:
    """Run one benchmark under a controlled argv; returns its failure count."""
    argv = [f"benchmarks/{name}.py"]
    if smoke:
        argv += list(getattr(mod, "SMOKE_ARGV", []))
    saved = sys.argv
    try:
        sys.argv = argv
        rc = int(mod.main() or 0)
    finally:
        sys.argv = saved
    if rc and getattr(mod, "INFORMATIONAL", False):
        print(f"({name} is informational — not counted as a failure)")
        return 0
    return rc


def main(argv: list | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("modules", nargs="*",
                    help="subset of registered benchmarks (default: all)")
    ap.add_argument("--smoke", action="store_true",
                    help="run every bench's smoke path (the CI gate)")
    ap.add_argument("--list", action="store_true",
                    help="print the registry and exit")
    args = ap.parse_args(argv)

    registry = discover(args.modules or None)
    if args.list:
        for name, mod in registry.items():
            extras = []
            if getattr(mod, "SMOKE_ARGV", None):
                extras.append(f"smoke: {' '.join(mod.SMOKE_ARGV)}")
            if getattr(mod, "INFORMATIONAL", False):
                extras.append("informational")
            print(f"{name:20s} {'(' + ', '.join(extras) + ')' if extras else ''}")
        return

    failures = 0
    for name, mod in registry.items():
        print(f"\n===== {name}{' (smoke)' if args.smoke else ''} =====")
        failures += run_one(name, mod, args.smoke)
    if failures:
        sys.exit(f"{failures} benchmark validations failed")
    print(f"\nall {len(registry)} benchmark validations passed")


if __name__ == "__main__":
    main()
