"""Benchmark runner — one module per paper table/figure.

  table1_taxi     Table 1 (taxi case study latency/power, both settings)
  fig8_datasets   Fig. 8 breakdown + the ~790x / ~1400x headline averages
  semi_sweep      beyond-paper semi-decentralized cluster sweep (paper §5)
  kernels_bench   kernel micro-benchmarks
  roofline_table  §Roofline render of results/dryrun.jsonl (if present)

``python -m benchmarks.run`` runs everything and exits non-zero on any
paper-validation mismatch."""
from __future__ import annotations

import sys

from benchmarks import (fig8_datasets, kernels_bench, roofline_table,
                        semi_sweep, table1_taxi)


def main() -> None:
    failures = 0
    for name, mod in (("table1_taxi", table1_taxi),
                      ("fig8_datasets", fig8_datasets),
                      ("semi_sweep", semi_sweep),
                      ("kernels_bench", kernels_bench)):
        print(f"\n===== {name} =====")
        failures += mod.main()
    import os
    # roofline tables are informational here; a missing dry-run file is not
    # a benchmark failure (the sweep is a separate, long-running step)
    print("\n===== roofline_table (paper-faithful baseline) =====")
    roofline_table.main()
    if os.path.exists("results/dryrun_opt.jsonl"):
        print("\n===== roofline_table (optimized — EXPERIMENTS.md §Perf) ====")
        roofline_table.main(path="results/dryrun_opt.jsonl")
    if failures:
        sys.exit(f"{failures} benchmark validations failed")
    print("\nall benchmark validations passed")


if __name__ == "__main__":
    main()
