"""Fused GNN-layer kernel vs the composed csr_aggregate -> crossbar_mvm path.

Reports, per layer shape, two deltas (EXPERIMENTS.md §Fused-layer):

  * analytic HBM traffic — bytes each path moves per layer on a TPU, from
    the dataflow itself (DESIGN.md §5). The composed path round-trips the
    aggregation output Z through HBM between the two kernels (and, on the
    bit-accurate path, re-reads it for the DAC quantization passes); the
    fused kernel keeps Z in VMEM, paying instead a second gather pass on the
    bit-accurate path for the global DAC scale.

      composed ideal : gather S*F + Z write F + Z read F        + out H
      fused    ideal : gather S*F                               + out H
      composed quant : gather S*F + Z write F + 2x(read F,
                       write codes F, kernel read F)            + out H
      fused    quant : 2x gather S*F + zmax write/read 2        + out H

    (per node, x4 bytes; the fused bit-accurate win therefore shrinks as S
    grows — the sweep includes shapes on both sides of the crossover.)

  * measured wall-clock — interpret-mode on CPU. Interpret mode is a
    correctness oracle, not a perf path (each grid step is interpreted), so
    wall-clock here tracks kernel-launch/grid overhead, not HBM bandwidth;
    the analytic column is the TPU-relevant number.

A third section gates the autotuner (repro.tuning, DESIGN.md §11): each
fused-layer shape is tuned (roofline-pruned candidates, measured
survivors) and the winner must be no slower than the hand-picked default
under the same measurement protocol — guaranteed by construction (the
default is always a measured candidate) and verified here; tuned and
default outputs must also be bit-identical (block sizes only move zero
padding). Winners cache to ``results/tuned_configs.json`` (the CI
artifact).

  PYTHONPATH=src python benchmarks/fused_vs_composed.py [--iters 3] [--csv]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.crossbar_mvm import CrossbarNumerics
from repro.kernels.crossbar_mvm.ops import crossbar_matmul_signed
from repro.kernels.csr_aggregate import aggregate
from repro.kernels.fused_layer import fused_gnn_layer

SHAPES = [
    # (nodes, in-feats, out-feats, sample)
    (256, 128, 64, 4),
    (256, 128, 64, 16),
    (512, 216, 128, 8),     # the paper's taxi calibration layer
    (128, 512, 128, 4),
]

SMOKE_ARGV = ["--iters", "1", "--tune-iters", "1"]  # benchmarks.run --smoke

# headline numbers for the BENCH_<name>.json perf-trajectory artifact;
# measured wall-clock (and the machine-dependent tuning winners/parity
# residual) quarantined under 'timing' per the determinism convention
METRICS: dict = {}


def _composed_layer(x, nbr, wts, w, b, cfg):
    z = aggregate(x, nbr, wts, backend="pallas")
    if cfg.ideal:
        h = jnp.dot(z, w, preferred_element_type=jnp.float32)
    else:
        h = crossbar_matmul_signed(z, w, cfg)
    return jnp.maximum(h + b, 0.0)


def _fused_layer(x, nbr, wts, w, b, cfg):
    return fused_gnn_layer(x, nbr, wts, w, b, cfg, relu=True)


def _time(fn, args, iters: int) -> float:
    jax.block_until_ready(fn(*args))              # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e3   # ms


def traffic_bytes(nd: int, f: int, h: int, s: int, ideal: bool):
    """(composed, fused) analytic HBM bytes per layer (model in docstring)."""
    gather = nd * s * f * 4
    out = nd * h * 4
    if ideal:
        composed = gather + 2 * nd * f * 4 + out
        fused = gather + out
    else:
        composed = gather + 7 * nd * f * 4 + out
        fused = 2 * gather + 2 * nd * 2 * 4 + out
    return composed, fused


def rows(iters: int):
    rng = np.random.default_rng(0)
    out = []
    for nd, f, h, s in SHAPES:
        x = jnp.asarray(rng.normal(size=(nd, f)).astype(np.float32))
        nbr = jnp.asarray(rng.integers(0, nd, size=(nd, s)).astype(np.int32))
        wts = jnp.asarray(np.abs(rng.normal(size=(nd, s))).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(f, h)).astype(np.float32) * 0.05)
        b = jnp.zeros((h,), jnp.float32)
        for cfg in (CrossbarNumerics(ideal=True),
                    CrossbarNumerics(adc_bits=12, rows_per_xbar=128)):
            args = (x, nbr, wts, w, b, cfg)
            t_c = _time(_composed_layer, args, iters)
            t_f = _time(_fused_layer, args, iters)
            err = float(jnp.abs(_fused_layer(*args)
                                - _composed_layer(*args)).max())
            b_c, b_f = traffic_bytes(nd, f, h, s, cfg.ideal)
            out.append({
                "shape": f"Nd={nd},F={f},H={h},S={s}",
                "numerics": "ideal" if cfg.ideal else "quant",
                "composed_ms": t_c, "fused_ms": t_f,
                "composed_MB": b_c / 1e6, "fused_MB": b_f / 1e6,
                "traffic_saving": 1.0 - b_f / b_c,
                "max_err": err,
            })
    return out


def tuned_rows(tune_iters: int, seed: int = 0) -> tuple:
    """Tune every fused-layer shape; returns (rows, gate_failures).

    Gate: the tuned winner must be no slower than the hand-picked default
    under the tuner's own measurement protocol, and must produce
    bit-identical outputs (padding-only block changes). The survivor set
    and roofline bounds are pure geometry arithmetic (deterministic); the
    measured winner and its seconds are machine facts (quarantined).
    """
    from repro.tuning import (DEFAULT_CACHE_PATH, FusedGeometry, TuneCache,
                              default_config, tune)
    from repro.tuning.measure import make_runner

    cache = TuneCache.load(DEFAULT_CACHE_PATH)
    rows_out, failures = [], []
    for nd, f, h, s in SHAPES:
        for cfg in (CrossbarNumerics(ideal=True),
                    CrossbarNumerics(adc_bits=12, rows_per_xbar=128)):
            geom = FusedGeometry(nd=nd, n=nd, f_in=f, f_out=h, sample=s,
                                 ideal=cfg.ideal,
                                 rows_per_xbar=cfg.rows_per_xbar)
            winner, info = tune(geom, cache=cache, seed=seed,
                                iters=tune_iters, warmup=1, force=True,
                                register_result=False)
            default = default_config(geom)
            y_tuned = np.asarray(make_runner(geom, winner, seed=seed)())
            y_default = np.asarray(make_runner(geom, default, seed=seed)())
            bit_identical = bool(np.array_equal(y_tuned, y_default))
            row = {
                "shape": f"Nd={nd},F={f},H={h},S={s}",
                "numerics": "ideal" if cfg.ideal else "quant",
                "survivors": [c for c, _ in info["survivors"]],
                "bounds_us": [round(b * 1e6, 4) for _, b in
                              info["survivors"]],
                "bit_identical": bit_identical,
                "timing": {
                    # winner config as a *string*: machine-dependent (so it
                    # must live under timing) but not a timing quantity (so
                    # the --compare gate must not diff it numerically)
                    "tuned": " ".join(f"{k}={v}" for k, v in
                                      sorted(winner.as_dict().items())),
                    "tuned_ms": info["winner_s"] * 1e3,
                    "default_ms": info["default_s"] * 1e3,
                },
            }
            rows_out.append(row)
            if info["winner_s"] > info["default_s"]:
                failures.append(
                    f"{row['shape']}/{row['numerics']}: tuned "
                    f"{info['winner_s']:.6f}s > default "
                    f"{info['default_s']:.6f}s")
            if not bit_identical:
                failures.append(
                    f"{row['shape']}/{row['numerics']}: tuned output "
                    f"differs from default (must be bit-identical)")
    return rows_out, failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--tune-iters", type=int, default=2,
                    help="timed reps per tuning survivor (0: skip the "
                         "autotuner section)")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    rs = rows(args.iters)
    METRICS.clear()
    METRICS["rows"] = [{
        "shape": r["shape"], "numerics": r["numerics"],
        "composed_MB": round(r["composed_MB"], 6),
        "fused_MB": round(r["fused_MB"], 6),
        "traffic_saving": round(r["traffic_saving"], 6),
        # fused/composed parity residual is platform-dependent float noise
        # (different accumulation orders) — quarantine with the wall-clock
        "parity_ok": r["max_err"] < 2e-4,
        "timing": {"composed_ms": r["composed_ms"],
                   "fused_ms": r["fused_ms"], "max_err": r["max_err"]},
    } for r in rs]
    if args.csv:
        keys = list(rs[0])
        print(",".join(keys))
        for r in rs:
            print(",".join(str(r[k]) for k in keys))
        return 0
    print(f"{'shape':26s} {'numerics':8s} {'composed':>9s} {'fused':>9s} "
          f"{'HBM MB':>8s} {'HBM MB':>8s} {'saved':>6s} {'max|err|':>9s}")
    print(f"{'':26s} {'':8s} {'ms':>9s} {'ms':>9s} "
          f"{'composed':>8s} {'fused':>8s} {'':>6s}")
    for r in rs:
        print(f"{r['shape']:26s} {r['numerics']:8s} {r['composed_ms']:9.1f} "
              f"{r['fused_ms']:9.1f} {r['composed_MB']:8.2f} "
              f"{r['fused_MB']:8.2f} {r['traffic_saving']:5.0%} "
              f"{r['max_err']:9.2e}")
    if args.tune_iters <= 0:
        return 0
    trs, failures = tuned_rows(args.tune_iters)
    METRICS["tuned"] = trs
    print(f"\n{'shape':26s} {'numerics':8s} {'tuned':>16s} {'tuned':>9s} "
          f"{'default':>9s} {'bit-id':>6s} {'survivors':>9s}")
    for r in trs:
        print(f"{r['shape']:26s} {r['numerics']:8s} "
              f"{str(r['timing']['tuned']):>16s} "
              f"{r['timing']['tuned_ms']:9.2f} "
              f"{r['timing']['default_ms']:9.2f} "
              f"{str(r['bit_identical']):>6s} {len(r['survivors']):9d}")
    for msg in failures:
        print(f"TUNE GATE FAIL: {msg}")
    return len(failures)


if __name__ == "__main__":
    raise SystemExit(main())
