"""Beyond-paper: the semi-decentralized design guideline, made executable.

The paper's conclusion calls for a hybrid setting balancing decentralized
compute with centralized communication. We sweep the cluster count for the
semi-decentralized planner over all Table-2 datasets + the taxi graph and
report where T_net is minimized — the design rule ``pick_setting`` applies
at serve time."""
from __future__ import annotations

from repro.core import costmodel
from repro.core.graph import TABLE2_DATASETS, TAXI_STATS

CLUSTERS = (1, 4, 16, 64, 256, 1024)


def rows():
    out = []
    datasets = dict(TABLE2_DATASETS, taxi=TAXI_STATS)
    for name, stats in datasets.items():
        for k in CLUSTERS:
            m = costmodel.predict("semi", stats, n_clusters=k)
            out.append((name, k, m.t_compute, m.t_communicate, m.t_net))
    return out


def main(csv: bool = False) -> int:
    print(f"{'dataset':14s} {'clusters':>8s} {'T_comp':>11s} {'T_comm':>11s} "
          f"{'T_net':>11s}")
    best = {}
    for name, k, tc, tm, tn in rows():
        print(f"{name:14s} {k:8d} {tc:11.4e} {tm:11.4e} {tn:11.4e}")
        if name not in best or tn < best[name][1]:
            best[name] = (k, tn)
    print("\nbest setting per dataset (guideline):")
    datasets = dict(TABLE2_DATASETS, taxi=TAXI_STATS)
    for name, stats in datasets.items():
        choice, metrics = costmodel.pick_setting(stats,
                                                 n_clusters=best[name][0])
        cent = metrics["centralized"].t_net
        dec = metrics["decentralized"].t_net
        semi = metrics["semi"].t_net
        print(f"  {name:14s} -> {choice:14s} (cent {cent:.3e}s, "
              f"dec {dec:.3e}s, semi@{best[name][0]} {semi:.3e}s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
