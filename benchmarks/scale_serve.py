"""Million-node serving gate for the capacity-bucketed data plane.

The tentpole check of DESIGN.md §12: serve a synthetic power-law graph at
``--nodes`` scale (default 1M) through the bucketed ragged layout under
churn, and report the four headline numbers the layout exists for —
query throughput (qps), tail latency (p99), padding-waste ratio, and the
peak per-device working set. The uniform dense layout is priced
*analytically* from the same partition (``ExecutionPlan.layout_stats``'s
``dense_*`` keys) so the comparison never materializes dense ``[K, n_max]``
tensors at full scale.

Three gates (hard-asserted under ``--smoke``, reported always):

  * **padding waste** — the bucketed layout's wasted rows must be at most
    half the dense layout's on the same (edge-balanced, power-law skewed)
    partition: ``(padded/real - 1) <= 0.5 * (dense_padded/real - 1)``.
  * **overlap** — the double-buffered halo exchange (dispatch every
    bucket's halo gather before any layer step) must not be slower than
    the serialized schedule, min-of-``--iters`` (lenient factor under
    smoke: CPU interpret-mode timing jitters).
  * **parity** — bucketed and dense forwards agree bit-for-bit
    (smoke scale only; full scale trusts tests/test_bucketed.py).

Usage:
  PYTHONPATH=src python benchmarks/scale_serve.py            # 1M nodes
  PYTHONPATH=src python benchmarks/scale_serve.py --smoke    # CI gate

METRICS follows the determinism convention (benchmarks/run.py): measured
wall-clock quantities live under ``"timing"`` keys; everything else is a
deterministic function of seed+argv.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

sys.path.insert(0, "src")

from repro.core import gnn  # noqa: E402
from repro.core.graph import random_graph  # noqa: E402
from repro.core.partition import plan_execution  # noqa: E402

SMOKE_ARGV = ["--smoke"]
METRICS: dict = {}


def _pct(lats) -> dict:
    if not len(lats):
        return {"p50_ms": 0.0, "p95_ms": 0.0, "p99_ms": 0.0}
    p50, p95, p99 = np.percentile(np.asarray(lats, np.float64) * 1e3,
                                  [50, 95, 99])
    return {"p50_ms": float(p50), "p95_ms": float(p95), "p99_ms": float(p99)}


def _block(out):
    """Force completion of a forward's (possibly tuple) output."""
    for o in (out if isinstance(out, (list, tuple)) else (out,)):
        o.block_until_ready()


def time_forward(fn, params, iters: int) -> float:
    """Min-of-iters wall-clock of one full forward (seconds)."""
    _block(fn(params))                                   # compile
    best = float("inf")
    for _ in range(max(iters, 1)):
        t = time.perf_counter()
        _block(fn(params))
        best = min(best, time.perf_counter() - t)
    return best


def serve_under_churn(plan, cfg, ticks: int, batch: int, churn_rows: int,
                      seed: int = 0) -> dict:
    """Closed-loop serving: per tick, ingest ``churn_rows`` feature
    mutations (committed eagerly — the incremental refresh runs on the
    serving path) then answer one ``batch``-query lookup."""
    from repro.streaming import StreamingGNNServer
    srv = StreamingGNNServer(plan, cfg, seed=seed, policy="eager")
    t0 = time.perf_counter()
    cold = srv.refresh()
    rng = np.random.default_rng(seed)
    n = plan.graph.n_nodes
    q_lats, t_lats, served = [], [], 0
    for _ in range(ticks):
        nodes = rng.choice(n, churn_rows, replace=False)
        rows = rng.normal(size=(churn_rows, plan.graph.feature_len)) \
            .astype(np.float32)
        t = time.perf_counter()
        srv.ingest(nodes=nodes, rows=rows)
        t_lats.append(time.perf_counter() - t)
        ids = rng.integers(0, n, batch)
        t = time.perf_counter()
        out = srv.query(ids)
        q_lats.append(time.perf_counter() - t)
        served += len(out)
    wall = time.perf_counter() - t0
    return dict(served=served, commits=srv.commits,
                qps=served / max(sum(q_lats), 1e-12),
                cold_refresh_s=cold, wall_s=wall,
                query=_pct(q_lats), tick=_pct(t_lats))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="scaled-down run + hard asserts (the CI gate)")
    ap.add_argument("--nodes", type=int, default=1_000_000)
    ap.add_argument("--edges", type=int, default=4_000_000)
    ap.add_argument("--feat", type=int, default=16)
    ap.add_argument("--hidden", type=int, default=16)
    ap.add_argument("--out", type=int, default=8)
    ap.add_argument("--sample", type=int, default=8)
    ap.add_argument("--clusters", type=int, default=64)
    ap.add_argument("--method", default="edge",
                    help="partition heuristic (edge-balanced skews node "
                         "counts on power-law graphs — the layout's worst "
                         "case for dense padding)")
    ap.add_argument("--buckets", default="auto", metavar="auto|N")
    ap.add_argument("--ticks", type=int, default=6)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--churn-rows", type=int, default=512)
    ap.add_argument("--iters", type=int, default=3,
                    help="min-of-iters for the overlap/serial timing")
    args = ap.parse_args()

    if args.smoke:
        args.nodes, args.edges = 20_000, 80_000
        args.feat, args.hidden, args.out = 12, 12, 8
        args.clusters, args.ticks, args.batch = 16, 3, 64
        args.churn_rows, args.iters = 64, 2
    buckets = args.buckets if args.buckets == "auto" else int(args.buckets)

    t = time.perf_counter()
    g = random_graph(args.nodes, args.edges, args.feat,
                     seed=0).gcn_normalize()
    t_graph = time.perf_counter() - t
    cfg = gnn.GNNConfig(in_dim=args.feat, hidden_dims=(args.hidden,),
                        out_dim=args.out, sample=args.sample, backend="jnp")

    t = time.perf_counter()
    plan = plan_execution(g, "decentralized", backend="jnp",
                          sample=args.sample, n_clusters=args.clusters,
                          seed=0, buckets=buckets,
                          partition_method=args.method)
    t_plan = time.perf_counter() - t
    bp = plan.bucketed
    ls = plan.layout_stats(cfg)
    waste = ls["padding_ratio"] - 1.0
    dense_waste = ls["dense_padding_ratio"] - 1.0
    waste_vs_dense = waste / max(dense_waste, 1e-12)
    caps = sorted({(int(bp.n_caps[b]), len(bp.clusters[b]))
                   for b in range(bp.n_buckets)})
    print(f"graph: {g.n_nodes} nodes / {g.n_edges} edges "
          f"(power-law, built in {t_graph:.1f}s)")
    print(f"plan:  {plan.n_clusters} clusters via '{args.method}', "
          f"{bp.n_buckets} buckets {caps} (built in {t_plan:.1f}s)")
    print(f"layout: padded {ls['padded_rows']} vs dense "
          f"{ls['dense_padded_rows']} rows over {ls['real_rows']} real "
          f"(waste {waste:.3f} vs dense {dense_waste:.3f} -> "
          f"{waste_vs_dense:.3f}x)")
    print(f"peak device bytes: {ls['peak_device_bytes'] / 1e6:.1f} MB "
          f"bucketed vs {ls['dense_peak_device_bytes'] / 1e6:.1f} MB dense")

    import jax
    params = gnn.init_params(jax.random.key(0), cfg)
    fwd_o = plan.make_forward(cfg, overlap="overlap")
    fwd_s = plan.make_forward(cfg, overlap="serial")
    out_o, out_s = fwd_o(params), fwd_s(params)
    overlap_equal = all(bool((a == b).all())
                        for a, b in zip(out_o, out_s))
    t_overlap = time_forward(fwd_o, params, args.iters)
    t_serial = time_forward(fwd_s, params, args.iters)
    print(f"halo exchange: overlap {t_overlap * 1e3:.1f} ms vs serial "
          f"{t_serial * 1e3:.1f} ms per forward "
          f"({t_overlap / max(t_serial, 1e-12):.2f}x, identical="
          f"{overlap_equal})")

    parity = "skipped"
    if args.smoke:
        dense_plan = plan_execution(g, "decentralized", backend="jnp",
                                    sample=args.sample,
                                    n_clusters=args.clusters, seed=0,
                                    partition_method=args.method)
        a = dense_plan.scatter(dense_plan.make_forward(cfg)(params))
        b = plan.scatter(out_o)
        parity = "exact" if np.array_equal(a, b) else "MISMATCH"
        print(f"parity vs dense layout: {parity}")

    srv = serve_under_churn(plan, cfg, args.ticks, args.batch,
                            args.churn_rows)
    print(f"serving: {srv['served']} lookups over {args.ticks} churn "
          f"ticks, {srv['qps']:.0f} qps, query p99 "
          f"{srv['query']['p99_ms']:.2f} ms, tick p99 "
          f"{srv['tick']['p99_ms']:.1f} ms "
          f"(cold refresh {srv['cold_refresh_s']:.2f}s)")

    METRICS.clear()
    METRICS.update(
        n_nodes=g.n_nodes, n_edges=g.n_edges, clusters=plan.n_clusters,
        method=args.method, buckets=str(buckets),
        n_buckets=bp.n_buckets, bucket_caps=[list(c) for c in caps],
        layout={k: (round(v, 4) if isinstance(v, float) else v)
                for k, v in ls.items()},
        waste_vs_dense=round(waste_vs_dense, 4),
        covers_all_clusters=bool(bp.covers()),
        overlap_equal=overlap_equal, parity=parity,
        served=srv["served"], commits=srv["commits"],
        timing=dict(graph_build_s=t_graph, plan_build_s=t_plan,
                    forward_overlap_s=t_overlap, forward_serial_s=t_serial,
                    cold_refresh_s=srv["cold_refresh_s"],
                    qps=srv["qps"], query=srv["query"], tick=srv["tick"]))

    # gates: hard-asserted in smoke (CI); at full scale a violation is the
    # benchmark's failure too — this is the acceptance check of DESIGN §12
    overlap_slack = 1.25 if args.smoke else 1.05
    failures = []
    if not bp.covers():
        failures.append("bucketed layout does not cover every cluster")
    if parity == "MISMATCH":
        failures.append("bucketed forward differs from dense")
    if not overlap_equal:
        failures.append("overlap and serial schedules disagree")
    if waste_vs_dense > 0.5:
        failures.append(f"padding waste {waste_vs_dense:.3f}x dense "
                        f"exceeds the 0.5x gate")
    if t_overlap > t_serial * overlap_slack:
        failures.append(f"overlapped exchange slower than serialized: "
                        f"{t_overlap * 1e3:.1f} ms vs "
                        f"{t_serial * 1e3:.1f} ms")
    if srv["served"] != args.ticks * args.batch:
        failures.append(f"served {srv['served']} != "
                        f"{args.ticks * args.batch}")
    if srv["commits"] < args.ticks:
        failures.append("eager policy must commit every tick")
    q = srv["query"]
    if not q["p50_ms"] <= q["p95_ms"] <= q["p99_ms"]:
        failures.append(f"query percentiles not monotone: {q}")
    if failures:
        print("SCALE_SERVE FAILURES:")
        for f in failures:
            print(" ", f)
        return 1
    print(f"SCALE_SERVE_OK: {g.n_nodes}-node power-law graph served "
          f"through {bp.n_buckets} capacity buckets with "
          f"{waste_vs_dense:.3f}x the dense padding waste and the "
          f"overlapped halo exchange no slower than serialized")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
