"""CAM-vs-top-k neighbor selection: parity gate, timings, planner pricing.

The CAM consumers (DESIGN.md §15) promise *result-identical* fallbacks:
k-NN graph construction over LSH band signatures (``repro.neighbors``)
and dirty-frontier membership (``streaming.frontier``) must produce the
same edges / the same masks whether they run on the traversal CAM kernel
(jnp oracle or Pallas) or the host sort/top-k path. This bench gates that
equivalence and reports where each path spends its time:

  * **k-NN parity** — for each feature-similarity scenario
    (``recsys`` / ``anomaly``) the graph is built three ways —
    ``topk`` (host fallback), ``cam-jnp``, ``cam-pallas`` — and the CSR
    triples must match bit-for-bit; per-path wall-clocks land under
    ``timing`` keys (the runner's determinism convention).
  * **Frontier parity** — random dirty sets expanded through the padded
    sample on all ``FRONTIER_MODES``; masks must be bit-identical
    (pad slots and the negative-query contract included).
  * **Planner pricing** — the taxi mixed churn+query workload planned
    with the ``neighbor_mode`` axis: the recommendation (visible in
    ``planner_sweep`` too) plus the per-commit ``t_neighbor_s`` the
    ``neighbor_evaluator`` assigns each mode of the recommended
    candidate — the modeled CAM-vs-drain tradeoff (CAM wins while the
    dirty-id count stays under one array's depth).

Usage:
  PYTHONPATH=src python benchmarks/cam_topk.py            # full sizes
  PYTHONPATH=src python benchmarks/cam_topk.py --smoke    # CI gate
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.core.graph import TAXI_STATS, random_graph  # noqa: E402
from repro.neighbors import SCENARIOS, scenario_features  # noqa: E402
from repro.neighbors import knn_graph  # noqa: E402
from repro.planner import (WorkloadProfile, neighbor_evaluator,  # noqa: E402
                           plan)
from repro.streaming.frontier import (FRONTIER_MODES,  # noqa: E402
                                      expand_frontier)

SMOKE_ARGV = ["--smoke"]
METRICS: dict = {}              # filled by main(); run.py --json-out reads it

# (mode, backend) -> display label: the three scoring paths under parity
PATHS = (("topk", "jnp", "topk"),
         ("cam", "jnp", "cam-jnp"),
         ("cam", "pallas", "cam-pallas"))


def _time_ms(fn, iters: int) -> float:
    fn()                                    # warm (jit/trace) once
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / max(iters, 1) * 1e3


def knn_rows(n: int, f: int, k: int, iters: int) -> tuple:
    """Per-scenario parity + timing rows; (rows, all_parities_held)."""
    rows, all_ok = [], True
    for name in SCENARIOS:
        x, _ = scenario_features(name, n_nodes=n, feature_len=f, seed=0)
        built = {label: knn_graph(x, k=k, mode=mode, backend=backend)
                 for mode, backend, label in PATHS}
        ref = built["topk"]
        ok = all(np.array_equal(g.indptr, ref.indptr)
                 and np.array_equal(g.indices, ref.indices)
                 and np.array_equal(g.edge_weight, ref.edge_weight)
                 for g in built.values())
        all_ok &= ok
        timing = {label: round(_time_ms(
            lambda m=mode, b=backend: knn_graph(x, k=k, mode=m, backend=b),
            iters), 3) for mode, backend, label in PATHS}
        rows.append(dict(scenario=name, n_nodes=n, k=k,
                         edges=int(ref.n_edges),
                         mean_weight=round(float(ref.edge_weight.mean()), 6),
                         parity=bool(ok), timing=timing))
    return rows, all_ok


def frontier_rows(n: int, e: int, sample: int, layers: int,
                  iters: int) -> tuple:
    """Frontier-mask bit-identity across FRONTIER_MODES + timings."""
    g = random_graph(n, e, 8, seed=2)
    nbr, wts = g.neighbor_sample(sample)
    rng = np.random.default_rng(3)
    rows, all_ok = [], True
    for dirty_frac in (0.02, 0.25):
        fd = rng.random(n) < dirty_frac
        sd = rng.random(n) < dirty_frac / 2
        masks = {m: expand_frontier(nbr, wts, fd, sd, layers, mode=m)
                 for m in FRONTIER_MODES}
        ref = masks["numpy"]
        ok = all(np.array_equal(fm.masks, ref.masks)
                 for fm in masks.values())
        all_ok &= ok
        timing = {m: round(_time_ms(
            lambda m=m: expand_frontier(nbr, wts, fd, sd, layers, mode=m),
            iters), 3) for m in FRONTIER_MODES}
        rows.append(dict(n_nodes=n, sample=sample, layers=layers,
                         dirty_frac=dirty_frac,
                         dirty_rows=[int(c) for c in ref.counts()],
                         parity=bool(ok), timing=timing))
    return rows, all_ok


def planner_pricing() -> dict:
    """Plan the taxi mixed workload; price both neighbor modes of the
    recommendation — the modeled CAM-vs-serial-drain tradeoff."""
    wl = WorkloadProfile(churn=0.01, queries_per_tick=64, sample=8)
    result = plan(TAXI_STATS, "throughput", workload=wl)
    rec = result.recommended
    out = dict(recommended=rec.candidate.key,
               neighbor_mode=rec.candidate.neighbor_mode,
               score=rec.score)
    for nm in ("cam", "topk"):
        twin = dataclasses.replace(rec.candidate, neighbor_mode=nm)
        out[f"t_neighbor_{nm}_s"] = \
            neighbor_evaluator(twin, result.ctx)["t_neighbor_s"]
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes, single timing iteration (CI gate)")
    args = ap.parse_args()
    if args.smoke:
        n, f, k, iters = 96, 16, 5, 1
        fn, fe, sample, layers = 160, 800, 6, 2
    else:
        n, f, k, iters = 256, 32, 8, 3
        fn, fe, sample, layers = 512, 2600, 8, 3

    knn, knn_ok = knn_rows(n, f, k, iters)
    print(f"{'scenario':10s} {'edges':>6s} {'parity':>7s} "
          + " ".join(f"{lb + ' ms':>14s}" for _, _, lb in PATHS))
    for r in knn:
        print(f"{r['scenario']:10s} {r['edges']:6d} "
              f"{'yes' if r['parity'] else 'NO':>7s} "
              + " ".join(f"{r['timing'][lb]:14.3f}" for _, _, lb in PATHS))

    fr, fr_ok = frontier_rows(fn, fe, sample, layers, iters)
    print(f"\n{'dirty_frac':>10s} {'levels':>16s} {'parity':>7s} "
          + " ".join(f"{m + ' ms':>14s}" for m in FRONTIER_MODES))
    for r in fr:
        print(f"{r['dirty_frac']:10.2f} {str(r['dirty_rows']):>16s} "
              f"{'yes' if r['parity'] else 'NO':>7s} "
              + " ".join(f"{r['timing'][m]:14.3f}"
                         for m in FRONTIER_MODES))

    pricing = planner_pricing()
    print(f"\nplanner[throughput] taxi mixed workload -> "
          f"{pricing['recommended']}")
    print(f"  per-commit membership pass: cam "
          f"{pricing['t_neighbor_cam_s']:.3e} s vs topk "
          f"{pricing['t_neighbor_topk_s']:.3e} s "
          f"(mode picked: {pricing['neighbor_mode']})")

    METRICS.update(knn=knn, frontier=fr, planner=pricing)
    failures = []
    if not knn_ok:
        failures.append("k-NN edge lists diverge across CAM/top-k paths")
    if not fr_ok:
        failures.append("frontier masks diverge across modes")
    if not (pricing["t_neighbor_cam_s"] > 0
            and pricing["t_neighbor_topk_s"] > 0):
        failures.append("neighbor_evaluator priced a mode at zero")
    if failures:
        print("CAM_TOPK_FAIL: " + "; ".join(failures))
        return 1
    print(f"\nCAM_TOPK_OK: {len(knn)} scenario graphs identical on "
          f"{len(PATHS)} paths; {len(fr)} frontier sweeps bit-identical on "
          f"{len(FRONTIER_MODES)} modes; planner prices both neighbor "
          f"modes")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
