"""Render the roofline table from results/dryrun.jsonl (§Roofline).

Reads every record the dry-run sweep appended and prints, per
(arch x shape x mesh): the three roofline terms, the dominant one,
MODEL_FLOPS/HLO_FLOPs, and per-device live bytes. Renders the optimized
sweep (results/dryrun_opt.jsonl, EXPERIMENTS.md §Perf) too when present."""
from __future__ import annotations

import json
import os

INFORMATIONAL = True    # a missing dry-run file is not a benchmark failure


def load(path: str = "results/dryrun.jsonl") -> list:
    recs, seen = [], {}
    if not os.path.exists(path):
        return recs
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            key = (r.get("arch"), r.get("shape"), r.get("mesh"))
            seen[key] = r                      # last record wins
    return list(seen.values())


def main(csv: bool = False, path: str = "results/dryrun.jsonl") -> int:
    recs = load(path)
    opt = "results/dryrun_opt.jsonl"
    if path == "results/dryrun.jsonl" and os.path.exists(opt):
        # render the optimized sweep even when the baseline file is absent
        if recs:
            print("(paper-faithful baseline; optimized sweep follows)")
            rc = _render(recs)
        else:
            print("no baseline dry-run records (results/dryrun.jsonl)")
            rc = 0
        print("\n--- optimized (EXPERIMENTS.md §Perf) ---")
        return rc + main(csv, path=opt)
    if not recs:
        print("no dry-run records found — run "
              "`python -m repro.launch.dryrun --all --out "
              "results/dryrun.jsonl` first")
        return 1
    return _render(recs)


def _render(recs: list) -> int:
    recs.sort(key=lambda r: (r.get("arch", ""), r.get("shape", ""),
                             r.get("mesh", "")))
    print(f"{'arch':18s} {'shape':12s} {'mesh':8s} {'comp_ms':>9s} "
          f"{'mem_ms':>9s} {'coll_ms':>9s} {'dominant':>10s} {'useful':>7s} "
          f"{'GiB/dev':>8s}")
    n_fail = 0
    for r in recs:
        a, s, m = r.get("arch", "?"), r.get("shape", "?"), r.get("mesh", "?")
        if r.get("skipped"):
            print(f"{a:18s} {s:12s} {m:8s} {'skip: ' + r['reason'][:58]}")
            continue
        if not r.get("ok"):
            n_fail += 1
            print(f"{a:18s} {s:12s} {m:8s} FAILED: {r.get('error','')[:58]}")
            continue
        t = r["roofline"]
        print(f"{a:18s} {s:12s} {m:8s} {t['compute_s']*1e3:9.2f} "
              f"{t['memory_s']*1e3:9.2f} {t['collective_s']*1e3:9.2f} "
              f"{t['dominant']:>10s} {t['useful_ratio']:7.2f} "
              f"{r['memory']['live_bytes']/2**30:8.2f}")
    return n_fail


if __name__ == "__main__":
    raise SystemExit(main())
