"""Paper Table 1 — taxi case study (10 000 nodes, c_s = 10): computation and
communication latency/power of IMA-GNN in centralized vs decentralized
settings, reproduced from the calibrated cost model (Eqs. 1-7)."""
from __future__ import annotations

from repro.core import costmodel

# Published Table 1 values (seconds / watts)
PUBLISHED = {
    "centralized": {
        "traversal_s": 38.43e-9, "aggregation_s": 142.77e-6,
        "feature_extraction_s": 14.53e-6, "computation_s": 157.34e-6,
        "communication_s": 3.30e-3, "p_compute_w": 823.11e-3,
    },
    "decentralized": {
        "traversal_s": 7.68e-9, "aggregation_s": 14.27e-6,
        "feature_extraction_s": 0.37e-6, "computation_s": 14.6e-6,
        "communication_s": 406e-3, "p_compute_w": 45.49e-3,
    },
}


def rows():
    model = costmodel.table1()
    out = []
    for setting in ("centralized", "decentralized"):
        for metric, pub in PUBLISHED[setting].items():
            got = model[setting][metric]
            err = abs(got - pub) / pub
            out.append((f"table1/{setting}/{metric}", got, pub, err))
    return out


def main(csv: bool = False) -> int:
    bad = 0
    print(f"{'metric':46s} {'model':>12s} {'published':>12s} {'rel.err':>8s}")
    for name, got, pub, err in rows():
        flag = "" if err < 0.05 else "  <-- MISMATCH"
        bad += err >= 0.05
        print(f"{name:46s} {got:12.4e} {pub:12.4e} {err:7.2%}{flag}")
    return bad


if __name__ == "__main__":
    raise SystemExit(main())
