"""Observability overhead gate: telemetry must be ~free and exactly right.

Two contracts from DESIGN.md §14, both enforced here (the CI smoke gate):

  1. **Overhead** — serving with telemetry enabled may cost at most
     ``--threshold`` (default 1.05x == 5%) over telemetry-off on the
     ``load_serve`` taxi configuration (decentralized/fused streaming
     server, closed-loop queries with churn ticks interleaved). Off/on
     trials alternate and each side takes its min over ``--repeats``
     rounds, so one scheduler hiccup cannot decide the ratio; a failing
     ratio gets one re-measure round before it counts.
  2. **Exactness** — the span tree's shipped-bytes total must equal
     ``ExecutionPlan.measured_traffic(...).total_bytes()`` *exactly* (not
     approximately) on all three settings plus the bucketed layout. The
     instrumentation bills bytes from the same executed send/recv tables
     the exchange runs on (telemetry/instrument.py), so any inequality
     means the accounting and the data plane have diverged.

Also exports ``results/obs_metrics.jsonl`` + ``results/obs_trace.jsonl``
(one serving trial's metrics dump and span trees) — the CI workflow
uploads them as the ``obs-telemetry`` artifact.

METRICS follows the determinism convention (benchmarks/run.py): the
bytes-accounting rows are a pure function of seed+argv; measured
wall-clock ratios live under ``"timing"``.

Usage:
  PYTHONPATH=src python benchmarks/obs_overhead.py            # full
  PYTHONPATH=src python benchmarks/obs_overhead.py --smoke    # CI gate
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time

import numpy as np

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro import telemetry as tel  # noqa: E402
from repro.core import gnn  # noqa: E402
from repro.core.graph import dataset_like  # noqa: E402
from repro.core.partition import plan_execution  # noqa: E402
from repro.streaming import StreamingGNNServer  # noqa: E402

SMOKE_ARGV = ["--smoke"]
METRICS: dict = {}

# (setting, n_clusters, buckets) — the three paper settings plus the
# bucketed ragged layout, whose per-bucket spans bill through a different
# code path (distributed/halo.py) and must land on the same total
BYTE_CASES = (
    ("centralized", None, None),
    ("decentralized", 4, None),
    ("semi", 4, None),
    ("decentralized", 4, "auto"),
)


def bytes_accounting(g, cfg, seed: int = 0) -> list:
    """Run one forward per case under tracing; compare span-tree bytes
    against the plan's own measured traffic report. Exact or bust."""
    rows = []
    for setting, n_clusters, buckets in BYTE_CASES:
        plan = plan_execution(g, setting, backend=cfg.backend,
                              sample=cfg.sample, n_clusters=n_clusters,
                              seed=seed, buckets=buckets)
        params = gnn.init_params(jax.random.key(seed), plan.gnn_config(cfg))
        tel.reset()
        tel.enable()
        out = plan.make_forward(cfg)(params)
        jax.block_until_ready(out)
        span_bytes = sum(r.total_bytes() for r in tel.get_tracer().roots
                         if r.name == "plan.forward")
        measured = int(plan.measured_traffic(plan.gnn_config(cfg))
                       .total_bytes())
        snap = tel.snapshot()
        counter_key = f'halo.shipped_bytes{{setting="{setting}"}}'
        counter_bytes = int(snap["counters"].get(counter_key, 0))
        rows.append(dict(setting=setting,
                         layout="bucketed" if buckets else "dense",
                         span_bytes=int(span_bytes),
                         counter_bytes=counter_bytes,
                         measured_bytes=measured,
                         equal=bool(span_bytes == measured
                                    and counter_bytes == measured)))
        tel.reset()
        tel.disable()
    return rows


def build_server(g, cfg, clusters: int, seed: int = 0) -> StreamingGNNServer:
    plan = plan_execution(g, "decentralized", backend=cfg.backend,
                          sample=cfg.sample, n_clusters=clusters, seed=seed)
    srv = StreamingGNNServer(plan, dataclasses.replace(cfg,
                                                       backend=cfg.backend),
                             seed=seed, policy="eager")
    srv.refresh()
    return srv


def serve_trial(srv, g, requests: int, batch: int, seed: int,
                churn: float, tick_every: int) -> float:
    """One closed-loop serving pass (queries + churn ticks), wall seconds.

    Same seed => same mutation/query stream, so off/on trials do identical
    work and differ only in the telemetry they pay for."""
    rng = np.random.default_rng(seed)
    out = None
    t0 = time.perf_counter()
    for i in range(requests):
        if i % tick_every == 0:
            n_mut = max(int(g.n_nodes * churn), 1)
            nodes = rng.choice(g.n_nodes, n_mut, replace=False)
            rows = rng.normal(size=(n_mut, g.feature_len)).astype(np.float32)
            srv.ingest(nodes=nodes, rows=rows)
        out = srv.query(rng.integers(0, g.n_nodes, batch))
    jax.block_until_ready(out)
    return time.perf_counter() - t0


def measure_overhead(srv, g, requests: int, batch: int, repeats: int,
                     churn: float, tick_every: int, seed: int = 0) -> dict:
    """Alternating off/on trials; min per side is the comparison point."""
    # warmup with telemetry ON: compiles every shape *and* triggers the
    # instrumentation's lazy one-time costs (traffic billing cache), so
    # neither side's measured trials pay first-call work
    tel.reset()
    tel.enable()
    serve_trial(srv, g, requests, batch, seed, churn, tick_every)
    off, on = [], []
    for r in range(repeats):
        tel.disable()
        off.append(serve_trial(srv, g, requests, batch, seed, churn,
                               tick_every))
        tel.reset()
        tel.enable()
        on.append(serve_trial(srv, g, requests, batch, seed, churn,
                              tick_every))
    return dict(off=off, on=on)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config + hard asserts (the CI gate)")
    ap.add_argument("--dataset", default="taxi")
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--requests", type=int, default=96)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--churn", type=float, default=0.02)
    ap.add_argument("--tick-every", type=int, default=4)
    ap.add_argument("--clusters", type=int, default=4)
    ap.add_argument("--repeats", type=int, default=None,
                    help="off/on trial pairs (default: 3 smoke, 5 full)")
    ap.add_argument("--threshold", type=float, default=1.05,
                    help="max telemetry-on/off wall-clock ratio (the "
                         "DESIGN.md §14 overhead contract)")
    ap.add_argument("--backend", default="fused")
    ap.add_argument("--sample", type=int, default=8)
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--out-dir", default="results",
                    help="where obs_metrics.jsonl / obs_trace.jsonl land "
                         "(the CI obs-telemetry artifact)")
    args = ap.parse_args()

    scale = 0.008 if args.smoke else args.scale
    requests = 24 if args.smoke else args.requests
    repeats = args.repeats or (3 if args.smoke else 5)
    entry_enabled = tel.enabled()

    g = dataset_like(args.dataset, scale=scale, seed=0).gcn_normalize()
    cfg = gnn.GNNConfig(in_dim=g.feature_len, hidden_dims=(args.hidden,),
                        out_dim=16, sample=args.sample,
                        backend=args.backend)

    # -- contract 2: span bytes == measured traffic, exactly ------------
    byte_cfg = dataclasses.replace(cfg, backend="jnp")
    byte_rows = bytes_accounting(g, byte_cfg)
    print(f"{'setting':14s} {'layout':8s} {'span_bytes':>12s} "
          f"{'measured':>12s}  equal")
    for r in byte_rows:
        print(f"{r['setting']:14s} {r['layout']:8s} {r['span_bytes']:12d} "
              f"{r['measured_bytes']:12d}  {r['equal']}")
    bytes_ok = all(r["equal"] for r in byte_rows)

    # -- contract 1: <= threshold serving overhead ----------------------
    srv = build_server(g, cfg, args.clusters)
    trials = measure_overhead(srv, g, requests, args.batch, repeats,
                              args.churn, args.tick_every)
    remeasured = False
    ratio = min(trials["on"]) / max(min(trials["off"]), 1e-12)
    if ratio > args.threshold:
        # one re-measure round before a noisy host fails the gate
        remeasured = True
        extra = measure_overhead(srv, g, requests, args.batch, 2,
                                 args.churn, args.tick_every)
        trials["off"] += extra["off"]
        trials["on"] += extra["on"]
        ratio = min(trials["on"]) / max(min(trials["off"]), 1e-12)
    off_s, on_s = min(trials["off"]), min(trials["on"])
    print(f"serving {requests} reqs x{args.batch}: off {off_s * 1e3:.1f}ms "
          f"on {on_s * 1e3:.1f}ms ratio {ratio:.3f} "
          f"(threshold {args.threshold:.2f}, "
          f"{len(trials['off'])} trial pairs)")

    # -- export the on-phase telemetry (CI artifact) --------------------
    os.makedirs(args.out_dir, exist_ok=True)
    metrics_path = os.path.join(args.out_dir, "obs_metrics.jsonl")
    trace_path = os.path.join(args.out_dir, "obs_trace.jsonl")
    tel.enable()   # exports describe the last telemetry-on trial
    n_metrics = tel.export_metrics(metrics_path)
    n_traces = tel.export_trace(trace_path)
    print(f"exported {n_metrics} metric lines -> {metrics_path}, "
          f"{n_traces} span trees -> {trace_path}")
    if entry_enabled:
        tel.enable()   # leave the on-phase data for run.py's info snapshot
    else:
        tel.reset()
        tel.disable()

    METRICS.clear()
    METRICS.update(
        dataset=args.dataset, n_nodes=g.n_nodes, requests=requests,
        batch=args.batch, churn=args.churn, backend=args.backend,
        repeats=repeats, threshold=args.threshold,
        bytes_accounting=byte_rows, bytes_all_equal=bytes_ok,
        timing=dict(off_s=off_s, on_s=on_s, overhead_frac=ratio - 1.0,
                    trials_off=trials["off"], trials_on=trials["on"],
                    remeasured=remeasured))

    failures = []
    if not bytes_ok:
        failures += [f"{r['setting']}/{r['layout']}: span bytes "
                     f"{r['span_bytes']} != measured {r['measured_bytes']}"
                     for r in byte_rows if not r["equal"]]
    if not any(r["measured_bytes"] > 0 for r in byte_rows):
        failures.append("no case shipped any bytes — accounting untested")
    if ratio > args.threshold:
        failures.append(f"telemetry overhead {ratio:.3f}x exceeds "
                        f"{args.threshold:.2f}x")
    if n_traces < 1 or n_metrics < 1:
        failures.append("telemetry exports are empty")
    if failures:
        print("OBS_OVERHEAD FAILURES:")
        for f in failures:
            print(" ", f)
        return 1
    print(f"OBS_OVERHEAD_OK: span bytes == measured traffic on "
          f"{len(byte_rows)} cases; serving overhead "
          f"{(ratio - 1) * 100:+.1f}% within "
          f"{(args.threshold - 1) * 100:.0f}% budget")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
