"""Parse collective ops + traffic estimates out of compiled SPMD HLO text.

After SPMD partitioning the module is the per-device program, so result
shapes are per-device. Per-device link traffic is estimated with the ring
model:
  all-reduce       2 * bytes * (n-1)/n      (bytes = per-shard payload)
  all-gather       bytes_result * (n-1)/n   (result = gathered full)
  reduce-scatter   bytes_result * (n-1)     (operand = n * result)
  all-to-all       bytes_result * (n-1)/n
  collective-permute  bytes_result
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(pred|[suf]\d+|bf16|c64|c128)\[([\d,]*)\]")
_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
        "collective-permute")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return default


def collective_stats(hlo_text: str, default_group: int = 16) -> dict:
    """Returns {op: {count, result_bytes, traffic_bytes}} + 'total'."""
    stats = defaultdict(lambda: {"count": 0, "result_bytes": 0,
                                 "traffic_bytes": 0.0})
    for line in hlo_text.splitlines():
        for op in _OPS:
            # match '<op>(' or '<op>-start(' but never '-done('
            idx = line.find(f" {op}")
            if idx < 0:
                continue
            after = line[idx + 1 + len(op):]
            if after.startswith("-done") or not (
                    after.startswith("(") or after.startswith("-start(")):
                continue
            eq = line.find("=")
            if eq < 0:
                continue
            rb = _shape_bytes(line[eq:idx])
            n = max(_group_size(line, default_group), 2)
            if op == "all-reduce":
                traffic = 2.0 * rb * (n - 1) / n
            elif op == "reduce-scatter":
                traffic = float(rb) * (n - 1)
            elif op == "collective-permute":
                traffic = float(rb)
            else:
                traffic = float(rb) * (n - 1) / n
            s = stats[op]
            s["count"] += 1
            s["result_bytes"] += rb
            s["traffic_bytes"] += traffic
            break
    total = {"count": sum(s["count"] for s in stats.values()),
             "result_bytes": sum(s["result_bytes"] for s in stats.values()),
             "traffic_bytes": sum(s["traffic_bytes"] for s in stats.values())}
    out = dict(stats)
    out["total"] = total
    return out


def scan_trip_counts(hlo_text: str) -> list:
    """Trip counts of while loops (layer scans) — collective/flop totals for
    ops inside a scan body must be multiplied by these when the body is
    invoked per iteration."""
    return [int(m) for m in re.findall(
        r"trip_count=(\d+)", hlo_text)]
