"""Paper Fig. 8 + headline claims — per-dataset computation/communication
latency breakdown (LiveJournal / Collab / Cora / Citeseer) for centralized
vs decentralized IMA-GNN, and the two published averages:
  * centralized communication ~790x faster than decentralized,
  * decentralized computation ~1400x faster than centralized."""
from __future__ import annotations

from repro.core import costmodel
from repro.core.graph import TABLE2_DATASETS


def rows():
    out = []
    for name, stats in TABLE2_DATASETS.items():
        c = costmodel.predict("centralized", stats)
        d = costmodel.predict("decentralized", stats)
        out.append((name, c, d))
    return out


def main(csv: bool = False) -> int:
    print(f"{'dataset':14s} {'cent.comp':>11s} {'cent.comm':>11s} "
          f"{'dec.comp':>11s} {'dec.comm':>11s} {'comp x':>9s} {'comm x':>9s}")
    for name, c, d in rows():
        print(f"{name:14s} {c.t_compute:11.4e} {c.t_communicate:11.4e} "
              f"{d.t_compute:11.4e} {d.t_communicate:11.4e} "
              f"{c.t_compute / d.t_compute:9.1f} "
              f"{d.t_communicate / c.t_communicate:9.1f}")
    comp_x, comm_x = costmodel.headline_averages()
    ok_comp = 1400 * 0.85 <= comp_x <= 1400 * 1.15
    ok_comm = 790 * 0.85 <= comm_x <= 790 * 1.15
    print(f"\n4-dataset averages: decentralized computes {comp_x:.0f}x faster "
          f"(paper ~1400x) {'OK' if ok_comp else 'MISMATCH'}")
    print(f"                    centralized communicates {comm_x:.0f}x faster "
          f"(paper ~790x) {'OK' if ok_comm else 'MISMATCH'}")
    return int(not ok_comp) + int(not ok_comm)


if __name__ == "__main__":
    raise SystemExit(main())
