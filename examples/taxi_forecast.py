"""Taxi demand/supply forecasting (paper §4.2, ref [26]) — end-to-end.

Trains the hetGNN-LSTM on a synthetic spatiotemporal stream over a taxi
graph with three edge types, then evaluates the forecast and reports the
latency/power the IMA-GNN cost model assigns to running this exact workload
centralized vs decentralized (the Table-1 comparison, live).

  PYTHONPATH=src python examples/taxi_forecast.py [--nodes 256] [--steps 150]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel, taxi
from repro.core.graph import TAXI_STATS, random_graph


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=256)
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    cfg = taxi.TaxiConfig()
    key = jax.random.key(0)

    # three edge types: road / proximity / destination-similarity graphs
    nbrs, wtss = [], []
    for r in range(cfg.n_edge_types):
        g = random_graph(args.nodes, args.nodes * 6, 1, seed=r).gcn_normalize()
        nb, wt = g.neighbor_sample(cfg.sample)
        nbrs.append(nb)
        wtss.append(wt)
    neighbors = jnp.stack([jnp.asarray(n) for n in nbrs])
    weights = jnp.stack([jnp.asarray(w) for w in wtss])

    stream = taxi.synthetic_stream(key, args.nodes,
                                   args.steps + cfg.p_hist + cfg.q_future,
                                   cfg)
    params = taxi.init_params(jax.random.key(1), cfg)

    from repro.optim import AdamWConfig, adamw_init, adamw_update
    opt_cfg = AdamWConfig(lr=args.lr, weight_decay=0.0, warmup=10)
    opt = adamw_init(params)
    t0 = time.time()
    first = last = None
    for step in range(args.steps):
        x_hist = stream[step:step + cfg.p_hist]
        target = stream[step + cfg.p_hist:
                        step + cfg.p_hist + cfg.q_future]
        target = target.transpose(1, 0, 2).reshape(
            args.nodes, cfg.q_future, cfg.m, cfg.n)
        loss, grads = taxi.grad_fn(params, x_hist, neighbors, weights,
                                   target, cfg)
        params, opt, _ = adamw_update(params, grads, opt, opt_cfg)
        first = float(loss) if first is None else first
        last = float(loss)
        if step % 25 == 0:
            print(f"step {step:4d} mse {last:.4f}")
    dt = time.time() - t0
    print(f"\ntrained {args.steps} steps in {dt:.1f}s; "
          f"mse {first:.4f} -> {last:.4f} "
          f"({'LEARNED' if last < 0.5 * first else 'no improvement'})")

    # the Table-1 comparison for this workload, from the calibrated model
    print("\nIMA-GNN cost model on the 10k-node taxi graph (Table 1):")
    for setting in ("centralized", "decentralized", "semi"):
        m = costmodel.predict(setting, TAXI_STATS, n_clusters=100)
        print(f"  {setting:14s} compute {m.t_compute*1e6:9.2f} us   "
              f"comm {m.t_communicate*1e3:9.2f} ms   "
              f"P_compute {m.p_compute*1e3:7.2f} mW")


if __name__ == "__main__":
    main()
